//! Design-space exploration: sweep array geometry and PE sparsity
//! patterns for a workload of your choice — the tool a hardware team
//! would use to size KAN-SAs for a new application.
//!
//! ```bash
//! cargo run --release --example design_space [-- app-name]
//! ```

use kan_sas::arch::ArrayConfig;
use kan_sas::cost::{array_area_mm2, normalized_energy, PeCost};
use kan_sas::report::Table;
use kan_sas::sim::analytic;
use kan_sas::workloads;

fn main() {
    let target = std::env::args().nth(1).unwrap_or_else(|| "MNIST-KAN".to_string());
    let apps = workloads::table2();
    let app = apps
        .iter()
        .find(|a| a.name.eq_ignore_ascii_case(&target))
        .unwrap_or_else(|| {
            eprintln!(
                "unknown app '{target}'; available: {}",
                apps.iter().map(|a| a.name).collect::<Vec<_>>().join(", ")
            );
            std::process::exit(1);
        });
    let wls = workloads::app_workloads(app, workloads::DEFAULT_BS, None);
    let (g, p) = (app.g, app.p);
    let (n, m) = (p + 1, g + p);

    let mut t = Table::new(&[
        "config", "area mm^2", "cycles", "util %", "runtime us @fmax", "norm. energy/PE",
    ])
    .with_title(format!("design space — {} (G={g}, P={p}, N:M = {n}:{m})", app.name).as_str());
    for (r, c) in [(4, 4), (8, 8), (16, 16), (32, 32), (8, 16), (16, 32)] {
        for kan in [false, true] {
            let cfg = if kan {
                ArrayConfig::kan_sas(r, c, n, m)
            } else {
                ArrayConfig::conventional(r, c)
            };
            let s = analytic::simulate_app(&cfg, &wls);
            let pe = PeCost::of(cfg.pe);
            let us = s.cycles as f64 * pe.delay_ns * 1e-3;
            t.row(vec![
                cfg.label(),
                format!("{:.3}", array_area_mm2(&cfg)),
                s.cycles.to_string(),
                format!("{:.1}", s.utilization() * 100.0),
                format!("{us:.1}"),
                format!(
                    "{:.2}",
                    if kan { normalized_energy(n, m) } else { 1.0 }
                ),
            ]);
        }
    }
    print!("{}", t.render());
    println!("(runtime uses each PE's own critical-path delay as the clock)");
}
