//! Manual phase profiling of the int8 engine hot path (perf events are
//! unavailable in the build sandbox). Times each stage of MNIST-KAN
//! layer 1 in isolation.

use std::time::Instant;

use kan_sas::bspline::BsplineUnit;
use kan_sas::kan::{Engine, QuantizedModel, Scratch};
use kan_sas::util::rng::Rng;

fn main() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = Engine::new(QuantizedModel::load(&dir.join("mnist_kan.kanq")).unwrap_or_else(
        |_| {
            eprintln!("(artifacts not built — profiling a synthetic MNIST-shaped model)");
            QuantizedModel::synthetic("mnist_kan_synth", &[784, 64, 10], 5, 3, 3)
        },
    ));
    let l = &engine.model.layers[0];
    let (kdim, n, m, p) = (l.in_dim, l.out_dim, l.num_bases(), l.degree);
    let bs = 128;
    let mut rng = Rng::new(3);
    let x_q: Vec<u8> = (0..bs * kdim).map(|_| rng.below(256) as u8).collect();
    let unit = BsplineUnit::new(l.lut.clone(), l.grid);
    let coeff = l.coeff.data();
    let reps = 50;

    // (a) unit evals only
    let t0 = Instant::now();
    let mut sink = 0u64;
    for _ in 0..reps {
        for &xq in &x_q {
            let (v, k) = unit.eval_into(xq);
            sink = sink.wrapping_add(v[0] as u64 + k as u64);
        }
    }
    println!("unit evals:      {:?}  (sink {sink})", t0.elapsed() / reps);

    // (b) spline MACs, feature-major, fused 4-row
    let t0 = Instant::now();
    let mut acc = vec![0i32; bs * n];
    for _ in 0..reps {
        acc.iter_mut().for_each(|a| *a = 0);
        for feat in 0..kdim {
            let crow = &coeff[feat * m * n..(feat + 1) * m * n];
            for b in 0..bs {
                let (vals, k) = unit.eval_into(x_q[b * kdim + feat]);
                let wbase = (k - p) * n;
                let arow = &mut acc[b * n..(b + 1) * n];
                let (v0, v1, v2, v3) =
                    (vals[0] as i32, vals[1] as i32, vals[2] as i32, vals[3] as i32);
                let w = &crow[wbase..wbase + 4 * n];
                let (w0, rest) = w.split_at(n);
                let (w1, rest) = rest.split_at(n);
                let (w2, w3) = rest.split_at(n);
                for i in 0..n {
                    arow[i] += v0 * w0[i] as i32
                        + v1 * w1[i] as i32
                        + v2 * w2[i] as i32
                        + v3 * w3[i] as i32;
                }
            }
        }
    }
    println!("spline fused:    {:?}  (acc[0] {})", t0.elapsed() / reps, acc[0]);

    // (c) spline MACs, batch-major j-loop (the original layout)
    let t0 = Instant::now();
    for _ in 0..reps {
        acc.iter_mut().for_each(|a| *a = 0);
        for b in 0..bs {
            let arow = &mut acc[b * n..(b + 1) * n];
            for feat in 0..kdim {
                let (vals, k) = unit.eval_into(x_q[b * kdim + feat]);
                let crow = &coeff[feat * m * n..(feat + 1) * m * n];
                let wbase = (k - p) * n;
                for (j, &v) in vals.iter().enumerate() {
                    if v == 0 {
                        continue;
                    }
                    let v = v as i32;
                    let wrow = &crow[wbase + j * n..wbase + (j + 1) * n];
                    for (a, &w) in arow.iter_mut().zip(wrow) {
                        *a += v * w as i32;
                    }
                }
            }
        }
    }
    println!("spline j-loop:   {:?}  (acc[0] {})", t0.elapsed() / reps, acc[0]);

    // (d) i16-pair trick: widen weights once to i16, use i32 muls — or
    //     precompute per-feature transposed layout? measure plain i16 copy
    let coeff16: Vec<i16> = coeff.iter().map(|&w| w as i16).collect();
    let t0 = Instant::now();
    for _ in 0..reps {
        acc.iter_mut().for_each(|a| *a = 0);
        for feat in 0..kdim {
            let crow = &coeff16[feat * m * n..(feat + 1) * m * n];
            for b in 0..bs {
                let (vals, k) = unit.eval_into(x_q[b * kdim + feat]);
                let wbase = (k - p) * n;
                let arow = &mut acc[b * n..(b + 1) * n];
                let (v0, v1, v2, v3) =
                    (vals[0] as i32, vals[1] as i32, vals[2] as i32, vals[3] as i32);
                let w = &crow[wbase..wbase + 4 * n];
                let (w0, rest) = w.split_at(n);
                let (w1, rest) = rest.split_at(n);
                let (w2, w3) = rest.split_at(n);
                for i in 0..n {
                    arow[i] += v0 * w0[i] as i32
                        + v1 * w1[i] as i32
                        + v2 * w2[i] as i32
                        + v3 * w3[i] as i32;
                }
            }
        }
    }
    println!("spline i16 wts:  {:?}  (acc[0] {})", t0.elapsed() / reps, acc[0]);

    // (d2) blocked batch: acc chunk stays in L1
    let coeff16b: Vec<i16> = coeff.iter().map(|&w| w as i16).collect();
    let t0 = Instant::now();
    for _ in 0..reps {
        acc.iter_mut().for_each(|a| *a = 0);
        const BB: usize = 16;
        for b0 in (0..bs).step_by(BB) {
            let bl = BB.min(bs - b0);
            for feat in 0..kdim {
                let crow = &coeff16b[feat * m * n..(feat + 1) * m * n];
                for b in b0..b0 + bl {
                    let (vals, k) = unit.eval_into(x_q[b * kdim + feat]);
                    let wbase = (k - p) * n;
                    let arow = &mut acc[b * n..(b + 1) * n];
                    let (v0, v1, v2, v3) =
                        (vals[0] as i32, vals[1] as i32, vals[2] as i32, vals[3] as i32);
                    let w = &crow[wbase..wbase + 4 * n];
                    let (w0, rest) = w.split_at(n);
                    let (w1, rest) = rest.split_at(n);
                    let (w2, w3) = rest.split_at(n);
                    for i in 0..n {
                        arow[i] += v0 * w0[i] as i32
                            + v1 * w1[i] as i32
                            + v2 * w2[i] as i32
                            + v3 * w3[i] as i32;
                    }
                }
            }
        }
    }
    println!("spline blocked16:{:?}  (acc[0] {})", t0.elapsed() / reps, acc[0]);

    // (e) full engine reference (allocating compatibility wrapper)
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(engine.forward_from_q(&x_q, bs).unwrap());
    }
    println!("full forward:    {:?}", t0.elapsed() / reps);

    // (f) compiled plan + reused scratch arena — the zero-allocation
    //     path the serving pool runs in steady state
    let mut scratch = Scratch::for_plan(engine.plan(), bs);
    let t0 = Instant::now();
    for _ in 0..reps {
        let t = engine.forward_into(&x_q, bs, &mut scratch).unwrap();
        std::hint::black_box(t[0]);
    }
    println!("plan fwd_into:   {:?}", t0.elapsed() / reps);
}
