//! Quickstart: load the tiny trained KAN, run one inference through both
//! engines (bit-exact int8 + PJRT fp32), and simulate it on KAN-SAs.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::path::PathBuf;

use anyhow::{Context, Result};
use kan_sas::arch::ArrayConfig;
use kan_sas::cost::array_area_mm2;
use kan_sas::kan::{Engine, QuantizedModel};
use kan_sas::runtime::{FloatEngine, ModelArtifacts};

fn main() -> Result<()> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");

    // 1. the bit-exact integer engine (the accelerated datapath)
    let qm = QuantizedModel::load(&dir.join("quickstart_kan.kanq"))
        .context("run `make artifacts` first")?;
    println!(
        "loaded {}: dims {:?}, G={}, P={}, {} int8 params",
        qm.name,
        qm.dims,
        qm.layers[0].grid,
        qm.layers[0].degree,
        qm.num_params()
    );
    let engine = Engine::new(qm);
    let x = [0.25f32, -0.5, 0.75, 0.1];
    let fwd = engine.forward(&x, 1)?;
    println!("int8 engine: accumulators {:?} -> class {}", fwd.t, fwd.predictions()[0]);

    // 2. the same model through the AOT fp32 path (jax -> HLO -> PJRT)
    let client = xla::PjRtClient::cpu()?;
    let fe = FloatEngine::load(&client, &ModelArtifacts::new(&dir, "quickstart_kan"), 1)?;
    let logits = fe.execute(&x)?;
    println!("pjrt fp32: logits {logits:?} -> class {}", fe.predictions(&logits)[0]);

    // 3. what would this batch cost on the accelerator?
    for cfg in [ArrayConfig::conventional(8, 8), ArrayConfig::kan_sas(8, 8, 4, 8)] {
        let s = engine.simulate_batch(&cfg, 1);
        println!(
            "simulated {} ({:.3} mm^2): {} cycles, {:.1}% utilization",
            cfg.label(),
            array_area_mm2(&cfg),
            s.cycles,
            s.utilization() * 100.0
        );
    }
    Ok(())
}
