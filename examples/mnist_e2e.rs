//! End-to-end driver (EXPERIMENTS.md "E2E"): the full system on a real
//! small workload.
//!
//! * regenerates the synth-digits test set exactly as training did
//!   (same generator, same seed — see python/compile/data.py);
//! * classifies it with the bit-exact int8 engine (the KAN-SAs datapath)
//!   and with the AOT fp32 PJRT path;
//! * reports accuracy (fp32 vs int8, the paper's <1% claim), CPU
//!   throughput, and the simulated accelerator cycles on both the
//!   conventional SA and KAN-SAs at similar area.
//!
//! ```bash
//! make artifacts && cargo run --release --example mnist_e2e
//! ```

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{Context, Result};
use kan_sas::arch::ArrayConfig;
use kan_sas::cost::array_area_mm2;
use kan_sas::kan::{Engine, QuantizedModel};
use kan_sas::quant;
use kan_sas::runtime::{FloatEngine, ModelArtifacts};
use kan_sas::util::container::Container;

fn main() -> Result<()> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let qm = QuantizedModel::load(&dir.join("mnist_kan.kanq"))
        .context("run `make artifacts` first")?;
    let engine = Engine::new(qm);

    // the golden container carries a labelled slice of the test set
    let golden = Container::open(&dir.join("mnist_kan_golden.kgld"))?;
    let (x_q, xs) = golden.u8("x_q")?;
    let (labels, _) = golden.i32("labels")?;
    let (n, in_dim) = (xs[0], xs[1]);
    println!("MNIST-KAN [784, 64, 10] G=10 P=3 — {n} labelled test digits");

    // 1. int8 engine accuracy + throughput
    let t0 = Instant::now();
    let fwd = engine.forward_from_q(&x_q, n)?;
    let dt = t0.elapsed();
    let int8_correct = fwd
        .predictions()
        .iter()
        .zip(&labels)
        .filter(|&(&p, &l)| p as i32 == l)
        .count();
    println!(
        "int8 engine:  {}/{} = {:.2}%  ({:.1} rows/s on CPU)",
        int8_correct,
        n,
        100.0 * int8_correct as f64 / n as f64,
        n as f64 / dt.as_secs_f64()
    );

    // 2. fp32 PJRT path on the same rows
    let client = xla::PjRtClient::cpu()?;
    let art = ModelArtifacts::new(&dir, "mnist_kan");
    let bs = 32;
    let fe = FloatEngine::load(&client, &art, bs)?;
    let mut fp_correct = 0usize;
    let mut counted = 0usize;
    let t0 = Instant::now();
    for chunk in 0..n / bs {
        let rows = &x_q[chunk * bs * in_dim..(chunk + 1) * bs * in_dim];
        let x: Vec<f32> = rows.iter().map(|&q| quant::dequantize_activation(q)).collect();
        let logits = fe.execute(&x)?;
        for (i, p) in fe.predictions(&logits).into_iter().enumerate() {
            if p as i32 == labels[chunk * bs + i] {
                fp_correct += 1;
            }
            counted += 1;
        }
    }
    let dt = t0.elapsed();
    println!(
        "fp32 (PJRT):  {}/{} = {:.2}%  ({:.1} rows/s on CPU)",
        fp_correct,
        counted,
        100.0 * fp_correct as f64 / counted as f64,
        counted as f64 / dt.as_secs_f64()
    );
    println!(
        "accuracy drop int8 vs fp32: {:.2} pp (paper target: < 1 pp)",
        100.0 * (fp_correct as f64 / counted as f64 - int8_correct as f64 / n as f64)
    );

    // 3. accelerator cost at similar area (the Fig. 8 pair)
    println!("\nsimulated accelerator cost for the {n}-digit batch:");
    for cfg in [ArrayConfig::conventional(32, 32), ArrayConfig::kan_sas(16, 16, 4, 13)] {
        let s = engine.simulate_batch(&cfg, n);
        println!(
            "  {} ({:.3} mm^2): {:>9} cycles ({:.1} us @500MHz), util {:.1}%",
            cfg.label(),
            array_area_mm2(&cfg),
            s.cycles,
            s.cycles as f64 * 2e-3,
            s.utilization() * 100.0
        );
    }
    println!("\nmnist_e2e OK");
    Ok(())
}
