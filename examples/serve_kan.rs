//! Serving demo: the replica fleet under batched KAN inference —
//! closed-loop throughput scaling across replica counts, an open-loop
//! flash-crowd showing admission control shedding load, then the
//! multi-tenant Gateway serving an application mix over one fleet (what
//! a deployment of the paper's accelerator would look like from the
//! software side; the mix is Fig. 8 at the serving tier).
//!
//! ```bash
//! cargo run --release --example serve_kan
//! ```
//!
//! Uses `artifacts/mnist_kan.kanq` when built (`make artifacts`), else a
//! synthetic model of the same shape, so the demo runs offline.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::Result;
use kan_sas::arch::ArrayConfig;
use kan_sas::coordinator::{
    BatchPolicy, Dispatch, GatewayBuilder, GatewayConfig, Pool, PoolConfig, QuotaPolicy,
    ShedPolicy, TelemetryConfig,
};
use kan_sas::kan::{Engine, QuantizedModel};
use kan_sas::loadgen::{self, MixEntry, Scenario};

fn pool_config(replicas: usize, shed: ShedPolicy) -> PoolConfig {
    PoolConfig {
        replicas,
        queue_cap: 512,
        shed,
        policy: BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(1) },
        sim_array: ArrayConfig::kan_sas(16, 16, 4, 13),
        dispatch: Dispatch::FairSteal,
        quota: QuotaPolicy::None,
        telemetry: TelemetryConfig::default(),
        ..Default::default()
    }
}

fn main() -> Result<()> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let qm = QuantizedModel::load(&dir.join("mnist_kan.kanq")).unwrap_or_else(|_| {
        eprintln!("(artifacts not built — serving a synthetic MNIST-shaped model)");
        QuantizedModel::synthetic("mnist_kan_synth", &[784, 64, 10], 5, 3, 9)
    });
    let engine = Engine::new(qm);
    println!(
        "model {} — {} KiB of weights, Arc-shared by every replica\n",
        engine.model.name,
        engine.param_bytes() / 1024
    );

    // 1. closed-loop saturation: replicas multiply throughput, weights don't
    let mut baseline = 0.0f64;
    for replicas in [1usize, 2, 4] {
        let pool = Pool::start(engine.clone(), pool_config(replicas, ShedPolicy::Block));
        let rep = loadgen::closed_loop(&pool.handle(), 8, Duration::from_millis(600), None, 42);
        let stats = pool.shutdown();
        let rows_s = stats.merged.batch_rows as f64 / rep.wall.as_secs_f64();
        if replicas == 1 {
            baseline = rows_s;
        }
        println!(
            "{replicas} replica(s): {rows_s:>8.0} rows/s ({:.2}x)  mean-batch {:>4.1}  p99 {:>6} us",
            rows_s / baseline.max(1.0),
            stats.merged.mean_batch_size(),
            rep.latency.map(|l| l.p99_us).unwrap_or(0)
        );
    }

    // 2. open-loop flash crowd: the spike overruns capacity, admission
    //    control sheds explicitly instead of letting latency run away
    let pool = Pool::start(engine.clone(), pool_config(2, ShedPolicy::RejectNew));
    let sc = Scenario::flash_crowd(1500.0, 6.0, Duration::from_millis(1500));
    let rep = loadgen::run(&pool.handle(), &sc, 7);
    let stats = pool.shutdown();
    println!("\n{}", rep.summary());
    println!(
        "peak queue {} / shed {} of {} — load-shedding kept the pool live through the spike",
        stats.peak_depth, stats.shed, stats.submitted
    );

    // 3. multi-tenant gateway: the MNIST model and a HAR-shaped tenant
    //    share ONE fleet and admission queue; batches never mix models,
    //    accounting is per model, and dispatch is weighted-fair with
    //    work stealing (the HAR tenant is service-weighted 4x, so the
    //    3:1 MNIST arrival majority cannot starve it)
    let mut builder = GatewayBuilder::with_config(GatewayConfig {
        replicas: 2,
        queue_cap: 512,
        shed: ShedPolicy::RejectNew,
        policy: BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(1) },
        sim_array: ArrayConfig::kan_sas(16, 16, 4, 13),
        dispatch: Dispatch::FairSteal,
        quota: QuotaPolicy::None,
        telemetry: TelemetryConfig::default(),
        ..Default::default()
    });
    let mnist = builder.register("mnist", engine.clone());
    let har = builder.register_weighted(
        "har",
        Engine::new(QuantizedModel::synthetic("har_synth", &[16, 32, 6], 5, 3, 3)),
        4,
    );
    let gateway = builder.start();
    let entries = [
        MixEntry { handle: gateway.handle(mnist), weight: 3.0 },
        MixEntry { handle: gateway.handle(har), weight: 1.0 },
    ];
    let mix = loadgen::run_mix(&entries, &Scenario::steady(2000.0, Duration::from_millis(1000)), 5);
    let gstats = gateway.shutdown();
    println!("\nmulti-tenant gateway (3:1 mnist:har mix over one 2-replica fleet):");
    for rep in &mix.per_model {
        println!("  {}", rep.summary());
    }
    for m in &gstats.per_model {
        println!(
            "  {} (w{}): conserved={} ({} == {} ok + {} shed + {} failed)  queue {:.0} us + service {:.0} us",
            m.name,
            m.weight,
            m.conserved(),
            m.submitted,
            m.completed,
            m.shed,
            m.failed,
            m.metrics.mean_queue_us(),
            m.metrics.mean_service_us(),
        );
    }
    println!(
        "  fairness index {:.3} (Jain, weight-normalized rows), stolen batches {}",
        gstats.fairness_index(),
        gstats.stolen_batches()
    );
    println!(
        "serve_kan OK — replicas scale throughput; admission control bounds overload; \
         one fleet serves the whole model mix"
    );
    Ok(())
}
