//! Serving demo: batched KAN inference through the coordinator —
//! concurrent clients, dynamic batching, latency/throughput report
//! (what a deployment of the paper's accelerator would look like from
//! the software side).
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_kan
//! ```

use std::path::PathBuf;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use kan_sas::arch::ArrayConfig;
use kan_sas::coordinator::{BatchPolicy, Server, ServerConfig};
use kan_sas::kan::{Engine, QuantizedModel};
use kan_sas::util::rng::Rng;

fn main() -> Result<()> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let qm = QuantizedModel::load(&dir.join("mnist_kan.kanq"))
        .context("run `make artifacts` first")?;
    let in_dim = qm.in_dim();
    let engine = Engine::new(qm);

    for (max_batch, clients) in [(1usize, 8usize), (16, 8), (64, 8)] {
        let server = Server::start(
            engine.clone(),
            ServerConfig {
                policy: BatchPolicy { max_batch, max_wait: Duration::from_millis(1) },
                sim_array: ArrayConfig::kan_sas(16, 16, 4, 13),
            },
        );
        let per_client = 128;
        let t0 = Instant::now();
        let mut threads = Vec::new();
        for c in 0..clients {
            let h = server.handle();
            threads.push(std::thread::spawn(move || {
                let mut rng = Rng::new(c as u64);
                for _ in 0..per_client {
                    let x: Vec<f32> =
                        (0..in_dim).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
                    h.infer(&x).expect("infer");
                }
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        let wall = t0.elapsed();
        let m = server.shutdown();
        let lat = m.latency().unwrap();
        println!(
            "max_batch {max_batch:>3}: {:>6.0} req/s  mean-batch {:>5.1}  p50 {:>6} us  p99 {:>6} us  sim {:>9} cycles",
            (clients * per_client) as f64 / wall.as_secs_f64(),
            m.mean_batch_size(),
            lat.p50_us,
            lat.p99_us,
            m.sim_cycles
        );
    }
    println!("serve_kan OK — batching trades latency for throughput as expected");
    Ok(())
}
