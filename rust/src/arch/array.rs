//! Array-level configuration of the weight-stationary systolic array.

use super::pe::PeKind;

/// How tile (coefficient) loads are accounted in the cycle model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightLoad {
    /// Loads overlap with compute (double-buffered weight registers) —
    /// the paper's runtime numbers are consistent with this policy
    /// ("coefficients are loaded in the PE and then reused for several
    /// cycles"), so it is the default.
    Amortized,
    /// Loads serialize with compute: one tile row per cycle through the
    /// C-wide weight bus (R cycles for a scalar tile, R*M / R*N for
    /// vector tiles). Exposed for the ablation bench.
    Counted,
}

/// A weight-stationary systolic array: R x C grid of `pe` elements, one
/// B-spline unit per row (Fig. 3 / Fig. 6).
#[derive(Clone, Copy, Debug)]
pub struct ArrayConfig {
    pub rows: usize,
    pub cols: usize,
    pub pe: PeKind,
    pub weight_load: WeightLoad,
}

impl ArrayConfig {
    pub fn conventional(rows: usize, cols: usize) -> Self {
        Self { rows, cols, pe: PeKind::Scalar, weight_load: WeightLoad::Amortized }
    }

    pub fn kan_sas(rows: usize, cols: usize, n: usize, m: usize) -> Self {
        assert!(n >= 1 && m >= n, "need M >= N >= 1");
        Self { rows, cols, pe: PeKind::Vector { n, m }, weight_load: WeightLoad::Amortized }
    }

    /// Total multiplier lanes in the array (the utilization denominator
    /// is `lanes * cycles`).
    pub fn lanes(&self) -> usize {
        self.rows * self.cols * self.pe.lanes()
    }

    /// Reduction rows one coefficient tile covers for a KAN (spline)
    /// workload, measured in *expanded* B-spline rows: scalar tiles
    /// cover R rows; vector tiles cover R*M (each PE holds a feature's
    /// full M-wide basis).
    pub fn kan_tile_rows(&self) -> usize {
        match self.pe {
            PeKind::Scalar => self.rows,
            PeKind::Vector { m, .. } => self.rows * m,
        }
    }

    /// Reduction rows per tile for a dense (non-KAN) workload: R for
    /// scalar, R*N for vector (all lanes carry dense inputs).
    pub fn dense_tile_rows(&self) -> usize {
        match self.pe {
            PeKind::Scalar => self.rows,
            PeKind::Vector { n, .. } => self.rows * n,
        }
    }

    pub fn label(&self) -> String {
        format!("{}x{} {}", self.rows, self.cols, self.pe.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_row_coverage() {
        let conv = ArrayConfig::conventional(16, 16);
        assert_eq!(conv.kan_tile_rows(), 16);
        assert_eq!(conv.dense_tile_rows(), 16);
        assert_eq!(conv.lanes(), 256);

        let ks = ArrayConfig::kan_sas(16, 16, 4, 8);
        assert_eq!(ks.kan_tile_rows(), 128); // R * M
        assert_eq!(ks.dense_tile_rows(), 64); // R * N
        assert_eq!(ks.lanes(), 1024); // R * C * N
        assert_eq!(ks.label(), "16x16 4:8");
    }

    #[test]
    #[should_panic]
    fn rejects_n_gt_m() {
        ArrayConfig::kan_sas(4, 4, 6, 3);
    }
}
