//! Architectural models: processing elements and the systolic array
//! organization (paper Secs. III-IV).

pub mod array;
pub mod pe;

pub use array::{ArrayConfig, WeightLoad};
pub use pe::{PeKind, ScalarPe, VectorPe};
