//! Processing elements: the conventional scalar MAC PE and the paper's
//! N:M sparsity-aware vector PE (Sec. IV-B, Fig. 6).
//!
//! The structs here are *functional* models used by the cycle-level
//! simulator and the integer engine; their timing/area/power live in
//! `crate::cost::pe`.

/// Which PE the array is built from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeKind {
    /// Conventional scalar multiply-accumulate (the paper's "1:1").
    Scalar,
    /// N:M density-bound-block vector PE: `n` multiplier lanes, `m`
    /// coefficient registers, an M-to-N mux steered by the streamed
    /// index k, and an (n+1)-operand adder tree.
    Vector { n: usize, m: usize },
}

impl PeKind {
    /// For a KAN layer with grid G and degree P the paper instantiates
    /// N = P+1, M = G+P.
    pub fn for_kan(g: usize, p: usize) -> Self {
        PeKind::Vector { n: p + 1, m: g + p }
    }

    /// Multiplier lanes per PE (1 for scalar).
    pub fn lanes(&self) -> usize {
        match self {
            PeKind::Scalar => 1,
            PeKind::Vector { n, .. } => *n,
        }
    }

    /// Coefficient registers per PE.
    pub fn coeff_regs(&self) -> usize {
        match self {
            PeKind::Scalar => 1,
            PeKind::Vector { m, .. } => *m,
        }
    }

    pub fn label(&self) -> String {
        match self {
            PeKind::Scalar => "1:1".to_string(),
            PeKind::Vector { n, m } => format!("{n}:{m}"),
        }
    }
}

/// Conventional weight-stationary scalar PE: holds one weight, performs
/// `psum += a * w` per cycle.
#[derive(Clone, Debug, Default)]
pub struct ScalarPe {
    pub weight: i8,
    /// MACs performed with a non-zero activation operand (the paper's
    /// utilization numerator).
    pub useful_macs: u64,
    /// Total cycles the PE was clocked while the array was active.
    pub cycles: u64,
}

impl ScalarPe {
    pub fn load(&mut self, w: i8) {
        self.weight = w;
    }

    /// One cycle: multiply the incoming activation, add to the incoming
    /// partial sum, pass both along. Returns the outgoing psum.
    #[inline]
    pub fn step(&mut self, a: u8, psum_in: i32) -> i32 {
        self.cycles += 1;
        if a != 0 {
            self.useful_macs += 1;
        }
        psum_in + a as i32 * self.weight as i32
    }
}

/// The paper's N:M vector PE: `m` stationary coefficients, `n` multiplier
/// lanes fed by the B-spline unit's non-zero values, a mux selecting the
/// coefficient window `[k-P, k]`, and an (n+1)-operand adder tree.
#[derive(Clone, Debug)]
pub struct VectorPe {
    pub coeffs: Vec<i8>, // m stationary coefficients
    pub n: usize,
    pub useful_macs: u64,
    pub cycles: u64,
}

impl VectorPe {
    pub fn new(n: usize, m: usize) -> Self {
        assert!(n >= 1 && m >= n, "need M >= N >= 1, got {n}:{m}");
        Self { coeffs: vec![0; m], n, useful_macs: 0, cycles: 0 }
    }

    pub fn load(&mut self, coeffs: &[i8]) {
        assert_eq!(coeffs.len(), self.coeffs.len(), "coefficient tile width");
        self.coeffs.copy_from_slice(coeffs);
    }

    /// One cycle of the KAN path: multiply the `n` streamed non-zero
    /// B-spline values against the mux-selected window ending at
    /// register `sel_end` (= basis index k), accumulate all lanes.
    ///
    /// `sel_end` is the index streamed alongside the activations
    /// (Fig. 6); the window is `[sel_end + 1 - n, sel_end]`.
    #[inline]
    pub fn step_kan(&mut self, vals: &[u8], sel_end: usize, psum_in: i32) -> i32 {
        debug_assert_eq!(vals.len(), self.n);
        debug_assert!(sel_end < self.coeffs.len() && sel_end + 1 >= self.n);
        self.cycles += 1;
        let base = sel_end + 1 - self.n;
        let mut acc = psum_in;
        for (j, &v) in vals.iter().enumerate() {
            if v != 0 {
                self.useful_macs += 1;
                acc += v as i32 * self.coeffs[base + j] as i32;
            }
        }
        acc
    }

    /// One cycle of the dense (MLP base term) path: all `n` lanes consume
    /// `n` consecutive dense activations against the first `n` registers
    /// (the paper's `(R x N, C)` tiling of non-KAN workloads).
    #[inline]
    pub fn step_dense(&mut self, vals: &[u8], psum_in: i32) -> i32 {
        debug_assert!(vals.len() <= self.n);
        self.cycles += 1;
        let mut acc = psum_in;
        for (j, &v) in vals.iter().enumerate() {
            if v != 0 {
                self.useful_macs += 1;
                acc += v as i32 * self.coeffs[j] as i32;
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_for_kan() {
        assert_eq!(PeKind::for_kan(5, 3), PeKind::Vector { n: 4, m: 8 });
        assert_eq!(PeKind::for_kan(10, 3).label(), "4:13");
        assert_eq!(PeKind::Scalar.lanes(), 1);
        assert_eq!(PeKind::Vector { n: 2, m: 6 }.coeff_regs(), 6);
    }

    #[test]
    fn scalar_pe_mac() {
        let mut pe = ScalarPe::default();
        pe.load(3);
        assert_eq!(pe.step(2, 10), 16);
        assert_eq!(pe.step(0, 16), 16); // zero operand: no useful mac
        assert_eq!(pe.useful_macs, 1);
        assert_eq!(pe.cycles, 2);
    }

    #[test]
    fn scalar_pe_negative_weights() {
        let mut pe = ScalarPe::default();
        pe.load(-128i8 as i8);
        assert_eq!(pe.step(255, 0), 255 * -128);
    }

    #[test]
    fn vector_pe_window_selection() {
        let mut pe = VectorPe::new(4, 8);
        pe.load(&[1, 2, 3, 4, 5, 6, 7, 8]);
        // k = 3 selects registers [0..=3]
        let out = pe.step_kan(&[1, 1, 1, 1], 3, 0);
        assert_eq!(out, 1 + 2 + 3 + 4);
        // k = 7 selects registers [4..=7]
        let out = pe.step_kan(&[1, 1, 1, 1], 7, 0);
        assert_eq!(out, 5 + 6 + 7 + 8);
        assert_eq!(pe.useful_macs, 8);
    }

    #[test]
    fn vector_pe_zero_lanes_not_useful() {
        let mut pe = VectorPe::new(4, 8);
        pe.load(&[1; 8]);
        pe.step_kan(&[0, 5, 0, 7], 3, 0);
        assert_eq!(pe.useful_macs, 2);
    }

    #[test]
    fn vector_pe_dense_path() {
        let mut pe = VectorPe::new(4, 8);
        pe.load(&[1, 2, 3, 4, 0, 0, 0, 0]);
        let out = pe.step_dense(&[10, 10, 10, 10], 5);
        assert_eq!(out, 5 + 10 * (1 + 2 + 3 + 4));
    }

    #[test]
    #[should_panic(expected = "M >= N")]
    fn vector_pe_bad_shape() {
        VectorPe::new(4, 2);
    }

    #[test]
    fn vector_pe_equals_scalar_sum() {
        // one vector-PE KAN step == N scalar-PE steps on the same window
        use crate::util::rng::{check, Rng};
        check(100, 41, |rng: &mut Rng| {
            let (n, m) = (4usize, 8usize);
            let coeffs: Vec<i8> = (0..m).map(|_| rng.range_i64(-127, 127) as i8).collect();
            let vals: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let k = n - 1 + rng.below(m - n + 1);
            let mut vpe = VectorPe::new(n, m);
            vpe.load(&coeffs);
            let got = vpe.step_kan(&vals, k, 0);
            let mut want = 0i32;
            for j in 0..n {
                let mut spe = ScalarPe::default();
                spe.load(coeffs[k + 1 - n + j]);
                want = spe.step(vals[j], want);
            }
            assert_eq!(got, want);
        });
    }
}
