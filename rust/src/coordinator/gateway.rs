//! Multi-tenant serving gateway: one typed front door for many models
//! over one replica fleet, with a **live tenant registry**.
//!
//! The paper evaluates KAN-SAs across a *mix* of applications (Fig. 8:
//! MNIST, CIFAR, HAR, …) time-sharing one accelerator; the [`Gateway`]
//! is that picture at the serving tier. A [`GatewayBuilder`] registers N
//! models ([`GatewayBuilder::register`] → [`ModelId`]); the started
//! gateway shares **one bounded admission queue and one worker fleet**
//! across all of them, routing each admitted request to its model's
//! compiled [`ExecutionPlan`](crate::kan::ExecutionPlan):
//!
//! * every worker serves *all* registered models through the registry's
//!   `Arc`-shared engines (~1x total model memory) and **one**
//!   [`Scratch`](crate::kan::Scratch) arena sized to the widest model;
//! * each worker runs **per-model batchers**, so a served batch is never
//!   mixed-model — exactly like the accelerator, which must reconfigure
//!   LUT ROMs and N:M windows between applications. Each tenant may
//!   carry its own [`BatchPolicy`] (max rows / max wait), defaulting to
//!   the fleet policy;
//! * admission control is shared: one queue capacity, one
//!   [`ShedPolicy`], with [`Priority`] classes ordering
//!   [`ShedPolicy::DropOldest`] eviction (low-priority victims first).
//!   Under [`QuotaPolicy::Weighted`] each tenant also gets
//!   **weight-proportional reserved queue slots** plus a shared
//!   overflow region, so one tenant's burst can no longer shed every
//!   tenant's new arrivals (and `DropOldest` evicts from the most
//!   *oversubscribed* tenant first).
//!
//! # The dynamic registry
//!
//! The tenant set is **not** frozen at start. All per-tenant tables
//! (engine, weight, batch policy, buffer pool, counters, metrics cells,
//! reserved quota slots) live in an immutable, epoch-versioned
//! registry snapshot behind an `Arc`. Control-plane mutations —
//! [`Gateway::add_model`], [`Gateway::remove_model`],
//! [`Gateway::set_weight`] — build a new snapshot and swap the `Arc`
//! atomically under the admission lock; workers notice the epoch bump
//! at their next batch boundary and reload. The steady-state hot path
//! therefore pays one integer compare per dispatch loop and zero extra
//! allocations (`tests/gateway_alloc.rs` still gates this with a
//! counting allocator).
//!
//! Removal honours a **drain contract**: the tenant stops accepting
//! first (snapshot swap), its backlog is then either served to
//! completion or answered `QueueFull` per [`DrainMode`], and its
//! [`BufferPool`] is retired only once every in-flight response has
//! been sent — per-model conservation
//! (`submitted == completed + shed + failed`) holds across the whole
//! transition, and the removed tenant's counters stay visible in
//! [`GatewayStats`] (`live == false`).
//!
//! Dispatch is **weighted and work-conserving** ([`Dispatch`], default
//! [`Dispatch::FairSteal`]). Each model registers with a service weight
//! ([`GatewayBuilder::register_weighted`], re-weightable live via
//! [`Gateway::set_weight`]); per-model batchers live in per-worker
//! *shards* that the whole fleet can reach:
//!
//! * a worker picks its next batch by **deficit round-robin** over its
//!   shard's due batchers — every round a tenant earns credit in
//!   proportion to its weight and pays in rows served, so a starved
//!   high-weight tenant is served before a saturated low-weight one, and
//!   a lone tenant still gets the whole machine (work conservation);
//! * pulls from the shared admission queue **skip past** head-of-line
//!   requests whose batcher is already full, so a saturated tenant's
//!   burst cannot wall off the *dispatch* of other tenants' already
//!   admitted requests (per-model FIFO order is preserved — only
//!   *other* models' requests are overtaken);
//! * a worker with nothing due **steals** from the most backlogged
//!   peer's shard instead of sleeping (the per-shard backlog index is
//!   atomic, so victim selection takes no locks). An over-full backlog
//!   is *split*: the thief takes roughly half so owner and thief serve
//!   the remainder concurrently, and the leftover items keep their
//!   original arrival clocks ([`Batcher::drain_upto`]). Steals are
//!   counted per model and per replica ([`Metrics::stolen_batches`]).
//!
//! [`Dispatch::Fixed`] keeps the pre-fair behaviour (strict FIFO pulls
//! that stop at a full batcher, model-index serve order, idle workers
//! sleep) as the measured baseline for the fairness sweep in the
//! `serving_scale` bench.
//!
//! The client surface is typed end to end: [`ModelHandle`] submits a
//! [`Request`] (quantized or f32 row, optional deadline, priority) and
//! gets a [`Ticket`]; every terminal outcome is a [`ServeError`] — one
//! enum for the whole serving stack. [`GatewayStats`] breaks the
//! counters down per model *and* per replica, with the conservation
//! invariant held **per model**: `submitted == completed + shed +
//! failed` (deadline-lapsed requests are answered
//! [`ServeError::DeadlineExceeded`] and counted inside `shed`, reported
//! separately as `expired`). The invariant is indifferent to *which*
//! worker served a batch, so it holds across steals — including batches
//! stolen during the shutdown flush — and across registry churn
//! (integration-tested in `tests/registry_churn.rs`).
//!
//! Response buffers are pooled: each answered request's pre-sized
//! `Vec<i64>` returns to a per-model free-list ([`BufferPool`]) when the
//! [`Response`] drops, so steady-state submission pays no buffer
//! allocation (asserted by `tests/gateway_alloc.rs` with a counting
//! allocator).
//!
//! `coordinator::pool::Pool` is the 1-model special case of the gateway
//! and `coordinator::server::Server` the 1-model/1-replica one.

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::arch::ArrayConfig;
use crate::kan::{Engine, Scratch};

use super::autoscale::{
    pin_current_thread, AutoscaleConfig, Controller, FleetSignals, ScaleDecision, ScaleEvent,
    SCALE_EVENT_CAP,
};
use super::batcher::{BatchPolicy, Batcher};
use super::clock::Clock;
use super::metrics::{jain_fairness, jain_fairness_normalized, Metrics};
use super::telemetry::{ChurnKind, EventKind, Telemetry, TelemetryConfig, NO_TENANT};

/// What to do with a new submission when the admission queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Reject the new arrival with [`ServeError::QueueFull`].
    RejectNew,
    /// Evict a queued request — the oldest among the *lowest*
    /// [`Priority`] class present — answer it `QueueFull`, and admit the
    /// newcomer. A newcomer whose priority is below everything queued is
    /// itself rejected (eviction never sacrifices a higher class). Under
    /// [`QuotaPolicy::Weighted`] the victim scan is restricted to the
    /// most *oversubscribed* tenant (largest overflow usage), so a
    /// bursting tenant pays for its own burst first.
    DropOldest,
    /// Block the submitting thread until a worker frees space.
    Block,
}

/// Request priority class. Only [`ShedPolicy::DropOldest`] eviction
/// looks at it (victims are chosen lowest-class-first, oldest within the
/// class); dispatch order within the queue stays FIFO.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// First to be evicted (bulk / best-effort traffic).
    Low,
    /// The default class.
    #[default]
    Normal,
    /// Last to be evicted (interactive traffic).
    High,
}

/// How fleet workers pick the next batch to serve.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Dispatch {
    /// Weighted deficit-round-robin over per-model batchers plus work
    /// stealing from backlogged peers: registration weights
    /// ([`GatewayBuilder::register_weighted`], live-tunable via
    /// [`Gateway::set_weight`]) set each tenant's service share under
    /// contention, queue pulls skip past head-of-line requests of
    /// saturated tenants, and idle workers steal ready batches instead
    /// of sleeping. The default.
    #[default]
    FairSteal,
    /// The pre-fair baseline: strictly FIFO pulls that stop at the first
    /// request whose batcher is full (so one tenant's burst head-of-line
    /// blocks the others), model-index serve order that ignores weights,
    /// and idle workers that sleep rather than steal. Kept so the
    /// `serving_scale` fairness sweep can measure the improvement
    /// against it.
    Fixed,
}

/// Per-tenant admission quotas over the shared bounded queue.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub enum QuotaPolicy {
    /// No reservations: the queue is one shared region and a full queue
    /// sheds *every* tenant's new arrivals (the pre-quota behaviour).
    #[default]
    None,
    /// Reserve `reserve` (a fraction in `[0, 1]`) of the queue capacity,
    /// split across live tenants in proportion to their service weights;
    /// the remainder is a shared overflow region. A tenant's submission
    /// is admissible while it is under its own reservation *or* the
    /// overflow region has room — so a majority tenant's burst fills its
    /// reservation plus the overflow, but can never consume the slots
    /// reserved for the others. Reservations are recomputed on every
    /// registry change (add/remove/re-weight).
    Weighted {
        /// Fraction of the queue capacity set aside for per-tenant
        /// reservations (clamped to `[0, 1]`; the `--quota` CLI default
        /// is 0.5).
        reserve: f64,
    },
}

impl QuotaPolicy {
    /// The standard weighted quota: half the queue reserved by weight,
    /// half shared overflow.
    pub fn weighted() -> Self {
        QuotaPolicy::Weighted { reserve: 0.5 }
    }
}

/// How [`Gateway::remove_model`] disposes of the tenant's backlog.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DrainMode {
    /// Serve everything already admitted before retiring the tenant
    /// (graceful). Non-due batches are expedited so the drain does not
    /// wait out their batching windows.
    Serve,
    /// Answer everything still queued or batched `QueueFull` (counted as
    /// shed); only batches already being served complete. The fast path
    /// for pulling a misbehaving tenant.
    Shed,
}

/// Gateway sizing and policy, shared by every registered model.
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    /// Worker threads; each serves every registered model (engines are
    /// `Arc`-shared, so the fleet costs ~1x total model memory).
    pub replicas: usize,
    /// Admission queue capacity (requests, not batches; shared across
    /// models, optionally partitioned by `quota`).
    pub queue_cap: usize,
    /// What to do with a new submission when the admission queue is
    /// full.
    pub shed: ShedPolicy,
    /// Default per-model dynamic batching policy (tenants may override
    /// it at registration).
    pub policy: BatchPolicy,
    /// Accelerator config used to attach simulated cycle counts to each
    /// served batch.
    pub sim_array: ArrayConfig,
    /// How workers pick the next batch (weighted fair dispatch with
    /// stealing, or the fixed pre-fair baseline).
    pub dispatch: Dispatch,
    /// Per-tenant admission quotas over the shared queue.
    pub quota: QuotaPolicy,
    /// Telemetry spine configuration (event rings, windowed stats,
    /// flight recorder, trace sampling). On by default;
    /// [`TelemetryConfig::off`] removes even the ring writes.
    pub telemetry: TelemetryConfig,
    /// SLO-driven worker autoscaling. `None` (the default) keeps the
    /// fixed fleet of `replicas` workers; `Some` starts the fleet at
    /// [`AutoscaleConfig::min_workers`], pre-sizes every per-worker
    /// structure to `max_workers`, and runs the controller loop
    /// (telemetry is force-enabled — the controller is blind without
    /// its windowed signals).
    pub autoscale: Option<AutoscaleConfig>,
    /// The gateway's time source: request timestamps, batching
    /// deadlines, telemetry windows, and autoscale decisions all read
    /// it. Defaults to the monotonic wall clock; tests inject
    /// [`Clock::manual`] and advance virtual time explicitly.
    pub clock: Clock,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            replicas: super::pool::default_replicas(),
            queue_cap: 1024,
            shed: ShedPolicy::RejectNew,
            policy: BatchPolicy::default(),
            sim_array: ArrayConfig::kan_sas(16, 16, 4, 8),
            dispatch: Dispatch::FairSteal,
            quota: QuotaPolicy::None,
            telemetry: TelemetryConfig::default(),
            autoscale: None,
            clock: Clock::real(),
        }
    }
}

/// Identifies a registered model within its [`Gateway`] (returned by
/// [`GatewayBuilder::register`], embedded in every [`ModelHandle`]).
/// Slots are never reused: a removed model's id stays valid for stats
/// lookups forever and a hot-added model always gets a fresh slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelId(pub(crate) usize);

impl ModelId {
    /// Index into [`GatewayStats::per_model`].
    pub fn index(self) -> usize {
        self.0
    }
}

impl fmt::Display for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "m{}", self.0)
    }
}

/// Terminal outcomes across the whole serving stack — gateway, pool, and
/// server answer with this one enum (no more `PoolError` here,
/// `anyhow` there).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Shed by admission control: rejected at submit, evicted under
    /// [`ShedPolicy::DropOldest`], or flushed by a
    /// [`DrainMode::Shed`] removal.
    QueueFull,
    /// The request's deadline lapsed before a worker could serve it.
    DeadlineExceeded,
    /// The gateway shut down before the request could be admitted.
    Closed,
    /// Input validation failed (wrong dimension), or an invalid
    /// control-plane argument (zero weight, duplicate name).
    InvalidInput(String),
    /// No model registered under that name or id — including models
    /// already removed from a live gateway.
    UnknownModel(String),
    /// The engine rejected the whole batch.
    Inference(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::QueueFull => write!(f, "admission queue full (request shed)"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before service"),
            ServeError::Closed => write!(f, "gateway stopped"),
            ServeError::InvalidInput(m) => write!(f, "{m}"),
            ServeError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            ServeError::Inference(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// A free-list of pre-sized response buffers, one per registered model.
///
/// [`BufferPool::acquire`] pops a recycled `Vec<i64>` (or allocates one
/// to exact `out_dim` capacity on a miss); the buffer rides through the
/// worker's scatter into the [`Response`], and returns to the list when
/// the response drops. After warmup, acquire/release cycles perform zero
/// heap allocations (`tests/gateway_alloc.rs`); the list is capped so an
/// overload burst cannot pin unbounded memory. Removing a model
/// [`BufferPool::retire`]s its pool: the free-list is emptied and late
/// releases (responses the client still holds) free normally instead of
/// re-pinning memory.
#[derive(Debug)]
pub struct BufferPool {
    free: Mutex<Vec<Vec<i64>>>,
    /// Row width every buffer is pre-sized to.
    out_dim: usize,
    /// Maximum buffers retained on the free-list.
    retain: usize,
    /// Set once the owning model is removed; releases stop recycling.
    retired: AtomicBool,
    created: AtomicU64,
    recycled: AtomicU64,
}

impl BufferPool {
    /// An empty pool of `out_dim`-capacity buffers retaining at most
    /// `retain` on its free-list.
    pub fn new(out_dim: usize, retain: usize) -> Self {
        Self {
            free: Mutex::new(Vec::new()),
            out_dim,
            retain,
            retired: AtomicBool::new(false),
            created: AtomicU64::new(0),
            recycled: AtomicU64::new(0),
        }
    }

    /// An empty buffer with capacity `out_dim` — recycled when the
    /// free-list has one, freshly allocated otherwise.
    pub fn acquire(&self) -> Vec<i64> {
        if let Some(buf) = self.free.lock().unwrap().pop() {
            self.recycled.fetch_add(1, Ordering::Relaxed);
            return buf;
        }
        self.created.fetch_add(1, Ordering::Relaxed);
        Vec::with_capacity(self.out_dim)
    }

    /// Return a buffer to the free-list (dropped if the list is full,
    /// the pool is retired, or the buffer was grown past the model's row
    /// width).
    pub fn release(&self, mut buf: Vec<i64>) {
        if self.retired.load(Ordering::Relaxed) {
            return; // model removed; let late buffers free normally
        }
        if buf.capacity() < self.out_dim || buf.capacity() > 4 * self.out_dim.max(1) {
            return; // wrong-sized stray; let it free normally
        }
        buf.clear();
        let mut free = self.free.lock().unwrap();
        if free.len() < self.retain {
            free.push(buf);
        }
    }

    /// Empty the free-list and stop recycling: called when the owning
    /// model is removed, after its last in-flight response was sent.
    /// In-flight [`Response`]s the client still holds keep the pool
    /// alive through their own `Arc`s; their eventual drops free their
    /// buffers instead of growing a dead free-list.
    pub fn retire(&self) {
        self.retired.store(true, Ordering::Relaxed);
        self.free.lock().unwrap().clear();
    }

    /// `(fresh allocations, recycled acquires, buffers currently free)`.
    pub fn counts(&self) -> (u64, u64, usize) {
        (
            self.created.load(Ordering::Relaxed),
            self.recycled.load(Ordering::Relaxed),
            self.free.lock().unwrap().len(),
        )
    }
}

/// A free-list of pre-sized quantized *input-row* buffers, one per
/// registered model — the admission-side twin of [`BufferPool`].
///
/// Submitters that care about steady-state allocation (the network
/// front door's frame decoder, the load generators) acquire a row via
/// [`ModelHandle::acquire_row`], fill it, and submit; the serving
/// worker returns the buffer here right after gathering it into the
/// batch staging area. Plain `submit` calls with caller-allocated rows
/// still work — their buffers simply join the free-list after service,
/// seeding it. Same lifecycle rules as [`BufferPool`]: capped
/// retention, retire-on-removal, strays free normally.
#[derive(Debug)]
pub struct RowPool {
    free: Mutex<Vec<Vec<u8>>>,
    /// Row width every buffer is pre-sized to.
    in_dim: usize,
    /// Maximum buffers retained on the free-list.
    retain: usize,
    /// Set once the owning model is removed; releases stop recycling.
    retired: AtomicBool,
    created: AtomicU64,
    recycled: AtomicU64,
}

impl RowPool {
    /// An empty pool of `in_dim`-capacity row buffers retaining at most
    /// `retain` on its free-list.
    pub fn new(in_dim: usize, retain: usize) -> Self {
        Self {
            free: Mutex::new(Vec::new()),
            in_dim,
            retain,
            retired: AtomicBool::new(false),
            created: AtomicU64::new(0),
            recycled: AtomicU64::new(0),
        }
    }

    /// An empty row buffer with capacity `in_dim` — recycled when the
    /// free-list has one, freshly allocated otherwise.
    pub fn acquire(&self) -> Vec<u8> {
        if let Some(buf) = self.free.lock().unwrap().pop() {
            self.recycled.fetch_add(1, Ordering::Relaxed);
            return buf;
        }
        self.created.fetch_add(1, Ordering::Relaxed);
        Vec::with_capacity(self.in_dim)
    }

    /// Return a row buffer to the free-list (dropped if the list is
    /// full, the pool is retired, or the buffer is the wrong size).
    pub fn release(&self, mut buf: Vec<u8>) {
        if self.retired.load(Ordering::Relaxed) {
            return;
        }
        if buf.capacity() < self.in_dim || buf.capacity() > 4 * self.in_dim.max(1) {
            return;
        }
        buf.clear();
        let mut free = self.free.lock().unwrap();
        if free.len() < self.retain {
            free.push(buf);
        }
    }

    /// Empty the free-list and stop recycling (model removal).
    pub fn retire(&self) {
        self.retired.store(true, Ordering::Relaxed);
        self.free.lock().unwrap().clear();
    }

    /// `(fresh allocations, recycled acquires, buffers currently free)`.
    pub fn counts(&self) -> (u64, u64, usize) {
        (
            self.created.load(Ordering::Relaxed),
            self.recycled.load(Ordering::Relaxed),
            self.free.lock().unwrap().len(),
        )
    }
}

/// Response: i64 accumulators for the row (argmax = class) + split
/// timing. The accumulator buffer is pooled — dropping the response
/// recycles it through the model's [`BufferPool`].
#[derive(Debug)]
pub struct Response {
    /// Final-layer i64 accumulators for the row.
    pub t: Vec<i64>,
    /// Microseconds from admission to the start of the serving batch
    /// (queueing + batching delay).
    pub queue_us: u64,
    /// Microseconds from batch-serve start to the response being sent
    /// (compute + scatter).
    pub service_us: u64,
    /// Recycles `t` on drop when set.
    pool: Option<Arc<BufferPool>>,
}

impl Response {
    /// End-to-end latency: `queue_us + service_us` (the pre-split
    /// `latency_us` field, kept as a method for compatibility).
    pub fn latency_us(&self) -> u64 {
        self.queue_us + self.service_us
    }

    /// The predicted class (argmax over the accumulators).
    pub fn prediction(&self) -> usize {
        crate::util::argmax(&self.t)
    }
}

impl Clone for Response {
    fn clone(&self) -> Self {
        Self {
            t: self.t.clone(),
            queue_us: self.queue_us,
            service_us: self.service_us,
            // the clone's buffer is fresh (not pool-sized bookkeeping);
            // only the original recycles
            pool: None,
        }
    }
}

impl Drop for Response {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.release(std::mem::take(&mut self.t));
        }
    }
}

/// One inference request, built with options before submission.
///
/// # Examples
///
/// Submit a float row with a deadline and a priority class through a
/// [`ModelHandle`], then block on the [`Ticket`] for the logits:
///
/// ```
/// use std::time::Duration;
/// use kan_sas::coordinator::{GatewayBuilder, GatewayConfig, Priority, Request};
/// use kan_sas::kan::{Engine, QuantizedModel};
///
/// let mut builder = GatewayBuilder::with_config(GatewayConfig {
///     replicas: 1,
///     ..Default::default()
/// });
/// let id = builder.register(
///     "tiny",
///     Engine::new(QuantizedModel::synthetic("tiny", &[4, 6, 3], 5, 3, 7)),
/// );
/// let gateway = builder.start();
/// let handle = gateway.handle(id);
///
/// let ticket = handle.submit(
///     Request::from_f32(&[0.25, -0.5, 0.75, 0.1])
///         .with_deadline(Duration::from_secs(5))
///         .with_priority(Priority::High),
/// )?;
/// let response = ticket.wait()?;
/// assert_eq!(response.t.len(), 3, "one accumulator per output class");
/// assert!(gateway.shutdown().conserved());
/// # Ok::<(), kan_sas::coordinator::ServeError>(())
/// ```
#[derive(Clone, Debug)]
pub struct Request {
    x_q: Vec<u8>,
    /// Service deadline relative to submission; a request still queued
    /// when it lapses is answered [`ServeError::DeadlineExceeded`].
    /// `None` falls back to the tenant's registered
    /// [`TenantDefaults::deadline`], then to no deadline.
    deadline: Option<Duration>,
    /// `None` falls back to the tenant's registered
    /// [`TenantDefaults::priority`], then to [`Priority::Normal`].
    priority: Option<Priority>,
}

impl Request {
    /// A request over an already-quantized activation row.
    pub fn from_q(x_q: Vec<u8>) -> Self {
        Self { x_q, deadline: None, priority: None }
    }

    /// A request over a float (spline-domain) row; quantized here, on
    /// the client thread.
    pub fn from_f32(x: &[f32]) -> Self {
        Self::from_q(crate::quant::quantize_activations(x))
    }

    /// Give the request a service deadline (measured from submission).
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Assign a [`Priority`] class (eviction ordering under
    /// [`ShedPolicy::DropOldest`]).
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = Some(priority);
        self
    }
}

/// Per-tenant request defaults carried on the registry entry
/// ([`GatewayBuilder::register_with_defaults`]). A default applies only
/// when the submitted [`Request`] did not set the corresponding option
/// itself — an SLO-bound tenant gets its deadline and priority class on
/// every bare submission without each client repeating them.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantDefaults {
    /// Deadline (relative to submission) for requests that set none.
    pub deadline: Option<Duration>,
    /// Priority class for requests that set none.
    pub priority: Option<Priority>,
}

impl TenantDefaults {
    /// Defaults with only a deadline.
    pub fn with_deadline(deadline: Duration) -> Self {
        Self { deadline: Some(deadline), priority: None }
    }

    /// Defaults with only a priority class.
    pub fn with_priority(priority: Priority) -> Self {
        Self { deadline: None, priority: Some(priority) }
    }
}

/// One admitted request flowing through the shared queue: routed by
/// `model`, carrying its pooled output buffer so the worker's scatter is
/// a pure `extend_from_slice`.
struct GwRequest {
    model: ModelId,
    x_q: Vec<u8>,
    /// Pre-sized (capacity `out_dim`) pooled response buffer.
    out: Vec<i64>,
    /// Admission stamp, µs on the gateway clock.
    submitted: u64,
    /// Absolute service deadline, µs on the gateway clock.
    deadline: Option<u64>,
    priority: Priority,
    /// Telemetry span id (nonzero for 1-in-N sampled requests).
    trace: u64,
    resp: Sender<Result<Response, ServeError>>,
}

/// One worker's mutable metrics slot for one model (shared across
/// registry snapshots through the tenant's `cells` Arc).
type MetricsCell = Mutex<Metrics>;

/// Worker-side per-model counters (atomics: workers never take the queue
/// lock to account a served batch). Shared across registry snapshots
/// through an `Arc`, so a tenant's history survives re-weighting and
/// removal.
#[derive(Default)]
struct ModelCounters {
    /// Requests answered with logits.
    completed: AtomicU64,
    /// Requests answered with an inference error.
    failed: AtomicU64,
    /// Requests answered `DeadlineExceeded` (a subset of the model's
    /// `shed` total).
    expired: AtomicU64,
    /// Requests admitted but not yet answered (queued, batched, or
    /// mid-serve). [`Gateway::remove_model`] drains until this hits 0
    /// before retiring the tenant.
    inflight: AtomicU64,
}

/// One tenant's slot in a [`RegistrySnapshot`]: the immutable view the
/// data plane reads. Mutable history (counters, metrics, buffer pool)
/// is `Arc`-shared across snapshots so epoch swaps never lose counts.
#[derive(Clone)]
struct Tenant {
    name: Arc<str>,
    /// Service weight (deficit-round-robin quantum; also the quota
    /// reservation share).
    weight: u32,
    /// Present while the tenant can still be served (live or draining);
    /// `None` once retired — the weights-freeing point of removal.
    engine: Option<Engine>,
    /// Cleared first on removal: no new admissions, backlog still
    /// served.
    accepting: bool,
    /// This tenant's batching policy (the fleet default unless
    /// registered with an explicit one).
    policy: BatchPolicy,
    in_dim: usize,
    out_dim: usize,
    /// Queue slots reserved for this tenant under
    /// [`QuotaPolicy::Weighted`] (0 otherwise; recomputed per snapshot).
    reserved: usize,
    /// Request options applied when a submission sets none.
    defaults: TenantDefaults,
    buffers: Arc<BufferPool>,
    /// Pooled quantized input-row buffers (admission-side twin of
    /// `buffers`; fed back by the serving worker's gather).
    rows: Arc<RowPool>,
    counters: Arc<ModelCounters>,
    /// `[replica]` metrics cells.
    cells: Arc<Vec<MetricsCell>>,
    /// Signalled when *this tenant's* blocked submitters may retry:
    /// its reservation or the overflow has room, or the tenant died.
    /// Per-tenant (vs. the old gateway-wide condvar) so a freed slot in
    /// one tenant's reservation never wakes — and loses a race to —
    /// another tenant's blocked crowd. `Arc` so the condvar survives
    /// registry snapshot clones.
    space: Arc<Condvar>,
}

impl Tenant {
    #[allow(clippy::too_many_arguments)]
    fn new(
        name: &str,
        engine: Engine,
        weight: u32,
        policy: BatchPolicy,
        defaults: TenantDefaults,
        queue_cap: usize,
        replicas: usize,
        exact_metrics: bool,
    ) -> Self {
        // retain enough for a full queue of this model plus every
        // replica's in-flight batch
        let retain = queue_cap + replicas * policy.max_batch;
        let (in_dim, out_dim) = (engine.in_dim(), engine.out_dim());
        let cell = || {
            Mutex::new(if exact_metrics { Metrics::exact() } else { Metrics::default() })
        };
        Self {
            name: Arc::from(name),
            weight,
            engine: Some(engine),
            accepting: true,
            policy,
            in_dim,
            out_dim,
            reserved: 0,
            defaults,
            buffers: Arc::new(BufferPool::new(out_dim, retain)),
            rows: Arc::new(RowPool::new(in_dim, retain)),
            counters: Arc::new(ModelCounters::default()),
            cells: Arc::new((0..replicas).map(|_| cell()).collect()),
            space: Arc::new(Condvar::new()),
        }
    }

    /// Live = accepting new submissions and still able to serve.
    fn is_live(&self) -> bool {
        self.accepting && self.engine.is_some()
    }
}

/// The epoch-versioned tenant table. Immutable once built; every
/// control-plane mutation swaps in a new snapshot with `epoch + 1`.
/// Slots are append-only (a removed tenant keeps its slot as a
/// non-accepting, engine-less entry), so `ModelId` indexing stays valid
/// across churn.
struct RegistrySnapshot {
    epoch: u64,
    tenants: Vec<Tenant>,
    /// Queue slots not reserved by any tenant — the shared overflow
    /// region under [`QuotaPolicy::Weighted`]; the whole capacity
    /// otherwise.
    overflow_cap: usize,
}

impl RegistrySnapshot {
    /// The tenant at `m` if it is live (accepting and serving).
    fn live(&self, m: ModelId) -> Option<&Tenant> {
        self.tenants.get(m.0).filter(|t| t.is_live())
    }
}

/// Recompute per-tenant reserved queue slots for a (new) snapshot;
/// returns the shared overflow capacity. With weighted quotas, a
/// `reserve` fraction of the queue is split over live tenants in
/// proportion to weight (floor division, so the overflow absorbs the
/// rounding remainder); dead or draining tenants reserve nothing.
fn apply_quota(tenants: &mut [Tenant], queue_cap: usize, quota: QuotaPolicy) -> usize {
    let QuotaPolicy::Weighted { reserve } = quota else {
        for t in tenants.iter_mut() {
            t.reserved = 0;
        }
        return queue_cap;
    };
    let total_w: u64 = tenants.iter().filter(|t| t.is_live()).map(|t| u64::from(t.weight)).sum();
    let budget = (queue_cap as f64 * reserve.clamp(0.0, 1.0)) as usize;
    let mut reserved_total = 0usize;
    for t in tenants.iter_mut() {
        t.reserved = if total_w > 0 && t.is_live() {
            (budget as u64 * u64::from(t.weight) / total_w) as usize
        } else {
            0
        };
        reserved_total += t.reserved;
    }
    queue_cap - reserved_total
}

/// Build the next registry snapshot (quota reservations recomputed).
fn build_snapshot(
    epoch: u64,
    mut tenants: Vec<Tenant>,
    queue_cap: usize,
    quota: QuotaPolicy,
) -> Arc<RegistrySnapshot> {
    let overflow_cap = apply_quota(&mut tenants, queue_cap, quota);
    Arc::new(RegistrySnapshot { epoch, tenants, overflow_cap })
}

/// Mutex-guarded queue state + the submit-side per-model counters.
/// `registry` lives here so admission reads the snapshot under the lock
/// it already holds, and workers refresh their cached `Arc` during the
/// pull phase (one `u64` epoch compare per loop in steady state).
struct GwState {
    /// The current registry snapshot (swapped whole on every mutation).
    registry: Arc<RegistrySnapshot>,
    items: VecDeque<GwRequest>,
    open: bool,
    /// Per-slot: valid submissions counted by admission control
    /// (admitted or rejected-new; Block submissions that observe
    /// `Closed` are not counted). Grows with the registry.
    submitted: Vec<u64>,
    /// Per-slot: requests answered `QueueFull` at admission (submit
    /// rejection, eviction, or removal flush).
    shed: Vec<u64>,
    /// Per-slot: requests currently waiting in the shared queue (the
    /// quota accountant; items pulled into shards are not counted).
    depth: Vec<usize>,
    /// Queue slots used beyond their owners' reservations — the cached
    /// occupancy of the shared overflow region. Maintained incrementally
    /// by [`depth_inc`]/[`depth_dec`] (reservations are constant between
    /// snapshots) and recomputed from scratch at every registry swap, so
    /// the weighted-quota admission check stays O(1) per submit.
    overflow: usize,
    /// Per-slot: submitters currently parked in the [`ShedPolicy::Block`]
    /// arm on their tenant's condvar — [`wake_space`] only signals slots
    /// with waiters that can actually make progress.
    blocked: Vec<usize>,
    peak_depth: usize,
}

/// Full recount of the overflow occupancy (slots used beyond their
/// owners' reservations) — the registry-swap resync for
/// [`GwState::overflow`].
fn overflow_scan(st: &GwState) -> usize {
    st.depth
        .iter()
        .zip(st.registry.tenants.iter())
        .map(|(&d, t)| d.saturating_sub(t.reserved))
        .sum()
}

/// Count one request entering slot `m`'s queue depth, tracking the
/// cached overflow occupancy.
fn depth_inc(st: &mut GwState, m: usize) {
    st.depth[m] += 1;
    if st.depth[m] > st.registry.tenants[m].reserved {
        st.overflow += 1;
    }
}

/// Count one request leaving slot `m`'s queue depth (pulled, evicted, or
/// flushed), tracking the cached overflow occupancy.
fn depth_dec(st: &mut GwState, m: usize) {
    if st.depth[m] > st.registry.tenants[m].reserved {
        st.overflow -= 1;
    }
    st.depth[m] -= 1;
}

struct Shared {
    state: Mutex<GwState>,
    /// Signalled when a request is admitted (workers wait here).
    /// Blocked submitters wait on their *tenant's* condvar instead
    /// ([`Tenant::space`], woken quota-aware by [`wake_space`]).
    nonempty: Condvar,
    /// Signalled (with `state`) by workers whenever they answer requests
    /// while a removal is draining; `remove_model` waits here for the
    /// tenant's in-flight count to reach zero.
    drained: Condvar,
    /// Serializes control-plane mutations (add/remove/set_weight).
    admin: Mutex<()>,
    /// True while a removal is waiting on its drain — tells workers to
    /// ping `drained` after serving (one relaxed load per batch
    /// otherwise).
    draining: AtomicBool,
    cap: usize,
    shed_policy: ShedPolicy,
    dispatch: Dispatch,
    quota: QuotaPolicy,
    /// Worker *slots* (the fleet ceiling). Shards, tenant metrics
    /// cells, and telemetry rings are all sized to this at start; the
    /// *active* subset (`fleet.active`) may be smaller under
    /// autoscaling and moves at runtime.
    replicas: usize,
    /// Fleet-default batch policy for tenants registered without one.
    default_policy: BatchPolicy,
    /// One batcher shard per worker slot. A shard is *owned* by its
    /// worker (only the owner pulls admissions into it) but *shared*
    /// with the fleet: idle peers steal due batches out of it.
    shards: Vec<Shard>,
    /// The telemetry spine: per-worker event rings plus the admission
    /// ring (whose single producer is whoever holds `state`).
    telemetry: Arc<Telemetry>,
    /// The time source every stamp in this gateway reads (batcher
    /// deadlines, telemetry windows, autoscale evaluation).
    clock: Clock,
    /// Accelerator-sim geometry, kept past start so runtime scale-up
    /// can spawn workers with the same config the initial fleet got.
    sim_array: ArrayConfig,
    /// Elastic-fleet state: which slots run, their thread handles, and
    /// the worker-seconds ledger.
    fleet: Fleet,
}

/// Runtime state of the elastic worker fleet. Slots `0..replicas` are
/// pre-sized at start; slots `0..active` hold running (or draining)
/// workers — the active set is always a contiguous prefix, so scale-up
/// spawns slot `active` and scale-down drains slot `active - 1`.
struct Fleet {
    /// Running workers (slots `0..active`). Moves only under
    /// `scale_lock`.
    active: AtomicUsize,
    /// Per-slot drain flag: a stopping worker pulls no admissions,
    /// flush-serves its own shard, steals nothing, and exits when its
    /// backlog hits zero (peers may steal the tail out from under it —
    /// either way every queued request is answered).
    stopping: Vec<AtomicBool>,
    /// Per-slot thread handles (`None` = not running). Scale-down and
    /// shutdown take and join.
    handles: Mutex<Vec<Option<JoinHandle<()>>>>,
    /// Per-slot start stamp, µs on the gateway clock **plus one** (0 =
    /// not running) — the worker-seconds ledger for running slots.
    started_us: Vec<AtomicU64>,
    /// Accumulated worker-µs of slots that have already exited.
    busy_us: AtomicU64,
    /// Orders worker-exit accounting (move span from `started_us` to
    /// `busy_us`) against `worker_time_us` readers, so the ledger never
    /// transiently drops or double-counts an exiting worker's span.
    ledger: Mutex<()>,
    /// Pin each worker thread to core `slot % ncores`.
    pin_cores: bool,
    /// Serializes scaling actions (the autoscaler thread and any
    /// `Gateway::scale_to` callers).
    scale_lock: Mutex<()>,
}

/// The autoscaler's mutable half, shared between the gateway handle
/// (synchronous [`Gateway::autoscale_tick`]) and the controller thread.
struct AutoRuntime {
    ctl: Mutex<AutoCtl>,
    /// Set by shutdown before the clock wake so the controller thread
    /// exits instead of evaluating another window.
    stop: AtomicBool,
}

struct AutoCtl {
    controller: Controller,
    /// Applied scaling actions, newest last, capped at
    /// [`SCALE_EVENT_CAP`].
    events: VecDeque<ScaleEvent>,
}

/// Wake blocked submitters whose tenant can now make progress. Called
/// under the state lock wherever queue space frees or admissibility
/// changes (worker pulls, removal flushes, registry swaps, shutdown).
/// Quota-aware: under [`QuotaPolicy::Weighted`] a tenant's waiters are
/// woken only when *its* reservation or the shared overflow has room —
/// by reservation availability, not plain FIFO over one global condvar —
/// so another tenant's freed reserved slot no longer triggers a
/// thundering herd that re-parks. Dead, draining, or closed-gateway
/// states wake everyone so waiters can observe their terminal error.
fn wake_space(shared: &Shared, st: &GwState) {
    for (m, t) in st.registry.tenants.iter().enumerate() {
        if st.blocked.get(m).copied().unwrap_or(0) == 0 {
            continue;
        }
        let full = st.items.len() >= shared.cap
            || match shared.quota {
                QuotaPolicy::None => false,
                QuotaPolicy::Weighted { .. } => {
                    st.depth[m] >= t.reserved && st.overflow >= st.registry.overflow_cap
                }
            };
        if !st.open || !t.is_live() || !full {
            t.space.notify_all();
        }
    }
}

/// One worker's per-model batchers, reachable by the whole fleet.
struct Shard {
    queues: Mutex<ShardQueues>,
    /// Requests queued across this shard's batchers — the backlog index
    /// peers consult lock-free when picking a steal victim. Incremented
    /// under the admission-queue lock on pull (so a drained admission
    /// queue plus all-zero backlog indexes really means "nothing left to
    /// serve"), decremented under the shard lock on drain.
    backlog: AtomicUsize,
}

/// The lockable interior of a [`Shard`]: per-model batchers plus the
/// deficit-round-robin state of the owning worker. Grows (never shrinks)
/// to match the registry snapshot — a removed tenant's batcher simply
/// stays empty.
struct ShardQueues {
    batchers: Vec<Batcher<GwRequest>>,
    /// Per-model DRR credit, in rows. Earned `weight` per round while
    /// the model has a due batch; spent on dispatch (cost = rows
    /// served); reset when the model's batcher empties.
    deficit: Vec<u64>,
    /// Per-model "serve now" override: set while the tenant is draining
    /// for removal, so non-due batches don't wait out their windows.
    expedite: Vec<bool>,
    /// Registry epoch this shard last synced to — [`ShardQueues::grow`]
    /// early-returns on a match, so pulls pay one compare in steady
    /// state (epochs start at 1; 0 means never synced).
    synced_epoch: u64,
    /// Round-robin scan start (one past the last dispatched model).
    cursor: usize,
}

impl ShardQueues {
    /// An empty shard; [`ShardQueues::grow`] populates it from the
    /// registry at the owner's first pull.
    fn empty() -> Self {
        Self {
            batchers: Vec::new(),
            deficit: Vec::new(),
            expedite: Vec::new(),
            synced_epoch: 0,
            cursor: 0,
        }
    }

    /// A shard with `n_models` batchers sharing one policy (tests only —
    /// production shards grow from the registry, which carries
    /// per-tenant policies).
    #[cfg(test)]
    fn new(n_models: usize, policy: BatchPolicy) -> Self {
        Self {
            batchers: (0..n_models).map(|_| Batcher::new(policy)).collect(),
            deficit: vec![0; n_models],
            expedite: vec![false; n_models],
            synced_epoch: 0,
            cursor: 0,
        }
    }

    /// Match the registry snapshot: append batchers for new slots (each
    /// with its tenant's policy) and refresh the per-slot expedite flags
    /// (draining tenants serve immediately). Called under the shard lock
    /// on every pull; one `u64` compare except across an epoch change.
    fn grow(&mut self, reg: &RegistrySnapshot) {
        if self.synced_epoch == reg.epoch {
            return;
        }
        while self.batchers.len() < reg.tenants.len() {
            let t = &reg.tenants[self.batchers.len()];
            self.batchers.push(Batcher::new(t.policy));
            self.deficit.push(0);
            self.expedite.push(false);
        }
        for (i, t) in reg.tenants.iter().enumerate() {
            self.expedite[i] = t.engine.is_some() && !t.accepting;
        }
        self.synced_epoch = reg.epoch;
    }

    /// Is model `i`'s batcher due for dispatch at `now_us`? (`flush` =
    /// shutdown drain: everything nonempty is due. A draining tenant's
    /// batches are always due.)
    fn due(&self, i: usize, flush: bool, now_us: u64) -> bool {
        let b = &self.batchers[i];
        !b.is_empty() && (flush || self.expedite[i] || b.ready(now_us))
    }

    /// Weighted deficit-round-robin pick: scan due batchers from the
    /// cursor, crediting each `weight` rows per round, and dispatch the
    /// first whose accumulated deficit covers its batch cost (rows).
    /// A tenant passed over keeps its credit, so a starved high-weight
    /// tenant overtakes a saturated low-weight one within a few rounds;
    /// a lone due tenant is always dispatched (work conservation).
    /// Returns the picked model with its deficit already charged.
    fn next_drr(&mut self, weights: &[u32], flush: bool, now_us: u64) -> Option<usize> {
        let n = self.batchers.len();
        if n == 0 {
            return None;
        }
        // Each round adds >= 1 row of credit to every due batcher and a
        // batch costs at most its batcher's max_batch rows, so
        // max(max_batch) rounds always suffice to dispatch *something*
        // when anything is due.
        let max_round = self.batchers.iter().map(Batcher::max_batch).max().unwrap_or(1);
        for _round in 0..=max_round {
            let mut any_due = false;
            for k in 0..n {
                let i = (self.cursor + k) % n;
                if self.batchers[i].is_empty() {
                    // classic DRR: an emptied queue forfeits its credit
                    self.deficit[i] = 0;
                    continue;
                }
                if !self.due(i, flush, now_us) {
                    continue; // still coalescing; keeps its credit
                }
                any_due = true;
                self.deficit[i] += u64::from(*weights.get(i).unwrap_or(&1));
                let b = &self.batchers[i];
                let cost = b.len().min(b.max_batch()) as u64;
                if self.deficit[i] >= cost {
                    self.deficit[i] -= cost;
                    self.cursor = (i + 1) % n;
                    return Some(i);
                }
            }
            if !any_due {
                return None;
            }
        }
        None
    }

    /// The fixed-dispatch pick: lowest model index that is due,
    /// weight-blind (the pre-fair baseline).
    fn next_fixed(&self, flush: bool, now_us: u64) -> Option<usize> {
        (0..self.batchers.len()).find(|&i| self.due(i, flush, now_us))
    }

    /// Smallest time-to-due across nonempty batchers (`None` when the
    /// shard is empty) — the owning worker's wait bound. An expedited
    /// (draining) batcher is due now.
    fn soonest_due(&self, now_us: u64) -> Option<Duration> {
        self.batchers
            .iter()
            .enumerate()
            .filter(|(_, b)| !b.is_empty())
            .map(|(i, b)| if self.expedite[i] { Duration::ZERO } else { b.time_left(now_us) })
            .min()
    }
}

/// How many items a thief takes from a victim batcher holding `len`
/// items with batch cap `max_batch`. A backlog that fits one batch is
/// taken whole (it is due as a unit); an over-full backlog is *split* —
/// the thief takes roughly half (still capped at one batch) so owner
/// and thief serve the remainder concurrently instead of the thief
/// walking off with a full batch while the owner's next batch re-coalesces.
fn steal_limit(len: usize, max_batch: usize) -> usize {
    if len > max_batch {
        len.div_ceil(2).min(max_batch)
    } else {
        len
    }
}

/// A pending response. Dropping it abandons the answer (the gateway
/// still serves and counts the request).
pub struct Ticket {
    rx: Receiver<Result<Response, ServeError>>,
    /// When the request was submitted (admission-queue entry time), µs
    /// on the gateway's [`Clock`].
    pub submitted: u64,
}

impl Ticket {
    /// Block until the request resolves. A worker failure that loses the
    /// channel maps to [`ServeError::Closed`], so this can never hang.
    pub fn wait(self) -> Result<Response, ServeError> {
        self.rx.recv().unwrap_or(Err(ServeError::Closed))
    }

    /// Non-blocking poll; `None` while still in flight. A lost worker
    /// (disconnected channel) is a terminal [`ServeError::Closed`], not
    /// `None` — pollers must never spin forever on a dead ticket.
    pub fn try_wait(&self) -> Option<Result<Response, ServeError>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => Some(Err(ServeError::Closed)),
        }
    }
}

/// Cloneable, typed client handle for one registered model. All
/// submissions go through the gateway's shared admission queue but are
/// validated against — and routed to — this model only. A handle may
/// outlive its model: submissions after [`Gateway::remove_model`]
/// resolve [`ServeError::UnknownModel`].
///
/// # Examples
///
/// ```
/// use kan_sas::coordinator::{GatewayBuilder, GatewayConfig};
/// use kan_sas::kan::{Engine, QuantizedModel};
///
/// let mut builder = GatewayBuilder::with_config(GatewayConfig {
///     replicas: 1,
///     ..Default::default()
/// });
/// let id = builder.register(
///     "demo",
///     Engine::new(QuantizedModel::synthetic("demo", &[4, 6, 3], 5, 3, 9)),
/// );
/// let gateway = builder.start();
///
/// let handle = gateway.handle(id);
/// assert_eq!((handle.name(), handle.in_dim(), handle.out_dim()), ("demo", 4, 3));
/// // blocking convenience over submit + Ticket::wait
/// let response = handle.infer_q(vec![10, 20, 30, 40])?;
/// assert_eq!(response.t.len(), 3);
/// // a wrong-width row is rejected before admission
/// assert!(handle.infer_q(vec![1, 2]).is_err());
/// gateway.shutdown();
/// # Ok::<(), kan_sas::coordinator::ServeError>(())
/// ```
#[derive(Clone)]
pub struct ModelHandle {
    shared: Arc<Shared>,
    model: ModelId,
    name: Arc<str>,
    in_dim: usize,
    out_dim: usize,
    rows: Arc<RowPool>,
}

impl ModelHandle {
    /// The id this model was registered as.
    pub fn model_id(&self) -> ModelId {
        self.model
    }

    /// The name the model was registered under.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Input row width (quantized activations).
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    /// Output row width (final-layer accumulators).
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// An empty, `in_dim`-capacity row buffer from this model's
    /// [`RowPool`]. Fill it and [`submit`](ModelHandle::submit) — the
    /// serving worker recycles it after gathering the batch, so a
    /// steady-state submitter reuses the same buffers instead of
    /// allocating one per request (the network front door's decode path
    /// and the load generators both lean on this).
    pub fn acquire_row(&self) -> Vec<u8> {
        self.rows.acquire()
    }

    /// `(fresh allocations, recycled acquires, free)` counters of this
    /// model's input-row pool.
    pub fn row_pool_counts(&self) -> (u64, u64, usize) {
        self.rows.counts()
    }

    /// Requests currently waiting in the shared admission queue (all
    /// models; requests already pulled into a worker's batcher shard are
    /// not counted).
    pub fn queue_depth(&self) -> usize {
        self.shared.state.lock().unwrap().items.len()
    }

    /// Submit a built [`Request`]; returns a [`Ticket`] without waiting
    /// for the result. Admission control applies: a full queue — or,
    /// under [`QuotaPolicy::Weighted`], an exhausted reservation plus a
    /// full overflow region — sheds per the gateway's [`ShedPolicy`],
    /// with [`Priority`] ordering `DropOldest` eviction.
    pub fn submit(&self, req: Request) -> Result<Ticket, ServeError> {
        let Request { x_q, deadline, priority } = req;
        if x_q.len() != self.in_dim {
            return Err(ServeError::InvalidInput(format!(
                "input dim {} != model '{}' dim {}",
                x_q.len(),
                self.name,
                self.in_dim
            )));
        }
        let submitted = self.shared.clock.now_us();
        let m = self.model.0;
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if !st.open {
                return Err(ServeError::Closed);
            }
            // Clone the snapshot Arc so tenant reads don't borrow `st`
            // (refcount bump, no allocation). Re-read every lap: a Block
            // wake or eviction may span a registry swap.
            let reg = Arc::clone(&st.registry);
            let Some(tenant) = reg.live(self.model) else {
                return Err(ServeError::UnknownModel(self.name.to_string()));
            };
            // Registry defaults fill whatever the request left unset
            // (re-resolved per lap: a Block wake may span a swap that
            // changed the tenant's defaults).
            let deadline = deadline
                .or(tenant.defaults.deadline)
                .map(|d| submitted + d.as_micros() as u64);
            let priority = priority.or(tenant.defaults.priority).unwrap_or_default();
            // Full = the whole queue is at capacity, or (weighted
            // quotas) this tenant's reservation is exhausted AND the
            // shared overflow region is full. The first clause is also
            // the safety belt that keeps total depth bounded across
            // reservation changes mid-flight (re-weights redistribute
            // slots under live traffic).
            let full = st.items.len() >= self.shared.cap
                || match self.shared.quota {
                    QuotaPolicy::None => false,
                    QuotaPolicy::Weighted { .. } => {
                        st.depth[m] >= tenant.reserved && st.overflow >= reg.overflow_cap
                    }
                };
            if !full {
                // admitted: only now pay for the response channel; the
                // output buffer comes from the model's free-list, so
                // steady-state submission allocates no buffer (shed
                // requests allocate nothing)
                let (tx, rx) = channel();
                let out = tenant.buffers.acquire();
                tenant.counters.inflight.fetch_add(1, Ordering::SeqCst);
                st.submitted[m] += 1;
                depth_inc(&mut st, m);
                let trace = self.shared.telemetry.next_trace();
                st.items.push_back(GwRequest {
                    model: self.model,
                    x_q,
                    out,
                    submitted,
                    deadline,
                    priority,
                    resp: tx,
                    trace,
                });
                st.peak_depth = st.peak_depth.max(st.items.len());
                let depth = st.items.len() as u64;
                self.shared.telemetry.emit_admission(
                    EventKind::Admitted,
                    m as u32,
                    1,
                    depth,
                    0,
                    trace,
                );
                drop(st);
                self.shared.nonempty.notify_one();
                return Ok(Ticket { rx, submitted });
            }
            match self.shared.shed_policy {
                ShedPolicy::RejectNew => {
                    st.submitted[m] += 1;
                    st.shed[m] += 1;
                    self.shared.telemetry.emit_admission(EventKind::Shed, m as u32, 1, 0, 0, 0);
                    return Err(ServeError::QueueFull);
                }
                ShedPolicy::DropOldest => {
                    // Victim pool: under weighted quotas, the requests of
                    // the most OVERSUBSCRIBED tenant (largest overflow
                    // usage) — the burster pays first; otherwise (or when
                    // nobody is over reserve, e.g. right after a
                    // re-weight shrank the overflow) the whole queue.
                    let sat: Option<ModelId> = match self.shared.quota {
                        QuotaPolicy::None => None,
                        QuotaPolicy::Weighted { .. } => (0..st.depth.len())
                            .filter(|&i| st.depth[i] > reg.tenants[i].reserved)
                            .max_by_key(|&i| st.depth[i] - reg.tenants[i].reserved)
                            .map(ModelId),
                    };
                    // Within the pool: the first (oldest) occurrence of
                    // the lowest priority class, stopping early once
                    // `Low` (the global minimum) is seen.
                    let mut victim: Option<(usize, Priority)> = None;
                    for (i, r) in st.items.iter().enumerate() {
                        if let Some(s) = sat {
                            if r.model != s {
                                continue;
                            }
                        }
                        let lower = match victim {
                            None => true,
                            Some((_, p)) => r.priority < p,
                        };
                        if lower {
                            victim = Some((i, r.priority));
                            if r.priority == Priority::Low {
                                break;
                            }
                        }
                    }
                    let Some((idx, min_pri)) = victim else {
                        // full with an empty candidate pool (transient
                        // post-re-weight states): shed the newcomer
                        st.submitted[m] += 1;
                        st.shed[m] += 1;
                        self.shared.telemetry.emit_admission(EventKind::Shed, m as u32, 1, 0, 0, 0);
                        return Err(ServeError::QueueFull);
                    };
                    if min_pri > priority {
                        // eviction never sacrifices a higher class
                        st.submitted[m] += 1;
                        st.shed[m] += 1;
                        self.shared.telemetry.emit_admission(EventKind::Shed, m as u32, 1, 0, 0, 0);
                        return Err(ServeError::QueueFull);
                    }
                    let old = st.items.remove(idx).expect("index in bounds");
                    let om = old.model.0;
                    st.shed[om] += 1;
                    depth_dec(&mut st, om);
                    self.shared.telemetry.emit_admission(
                        EventKind::Shed,
                        om as u32,
                        1,
                        0,
                        0,
                        old.trace,
                    );
                    let ot = &reg.tenants[om];
                    ot.counters.inflight.fetch_sub(1, Ordering::SeqCst);
                    // recycle the victim's pooled buffers: the shed
                    // path must not drain the free-lists under overload
                    ot.buffers.release(old.out);
                    ot.rows.release(old.x_q);
                    let _ = old.resp.send(Err(ServeError::QueueFull));
                    // loop: re-evaluate fullness and admit
                }
                ShedPolicy::Block => {
                    // Park on THIS tenant's condvar; [`wake_space`] only
                    // signals tenants whose admission check can now pass
                    // (quota-aware, not plain FIFO over a global condvar).
                    let space = Arc::clone(&tenant.space);
                    st.blocked[m] += 1;
                    st = space.wait(st).unwrap();
                    st.blocked[m] -= 1;
                    // loop: re-check open, liveness, and fullness
                }
            }
        }
    }

    /// Submit one quantized row with default options; returns a
    /// [`Ticket`] without waiting (the open-loop load generator's entry
    /// point).
    pub fn submit_q(&self, x_q: Vec<u8>) -> Result<Ticket, ServeError> {
        self.submit(Request::from_q(x_q))
    }

    /// Submit one quantized row and block for its logits.
    pub fn infer_q(&self, x_q: Vec<u8>) -> Result<Response, ServeError> {
        self.submit_q(x_q)?.wait()
    }

    /// Submit a float (spline-domain) row and block for its logits.
    pub fn infer(&self, x: &[f32]) -> Result<Response, ServeError> {
        self.submit(Request::from_f32(x))?.wait()
    }
}

/// Per-model accounting: admission + service counters, the model's own
/// merged [`Metrics`] (rows, batches, latency percentiles, simulated
/// cycles), and buffer-pool health. Removed tenants keep their row
/// (`live == false`) so conservation stays checkable across churn.
#[derive(Clone, Debug, Default)]
pub struct ModelStats {
    /// The name the model was registered under.
    pub name: String,
    /// The model's service weight (deficit-round-robin quantum; 1 for
    /// [`GatewayBuilder::register`], explicit for
    /// [`GatewayBuilder::register_weighted`], mutable live via
    /// [`Gateway::set_weight`]).
    pub weight: u32,
    /// False once the model was removed (its counters remain final).
    pub live: bool,
    /// Queue slots currently reserved for this tenant under
    /// [`QuotaPolicy::Weighted`] (0 otherwise).
    pub reserved: usize,
    /// Valid submissions counted by admission control.
    pub submitted: u64,
    /// Requests answered with logits.
    pub completed: u64,
    /// Requests answered without inference: `QueueFull` (at submit, by
    /// eviction, or by a removal flush) plus `DeadlineExceeded` (see
    /// `expired`).
    pub shed: u64,
    /// Deadline-lapsed requests — a subset of `shed`, broken out so shed
    /// policy and deadline pressure can be read separately.
    pub expired: u64,
    /// Requests answered with an inference error. Conservation per
    /// model: `submitted == completed + shed + failed` once drained.
    pub failed: u64,
    /// This model's rows/batches/latency/sim counters, merged across
    /// every replica that served it.
    pub metrics: Metrics,
    /// Fresh response-buffer allocations (free-list misses).
    pub buffers_created: u64,
    /// Response buffers served from the free-list.
    pub buffers_recycled: u64,
}

impl ModelStats {
    /// `submitted == completed + shed + failed` — every counted
    /// submission answered exactly once.
    pub fn conserved(&self) -> bool {
        self.submitted == self.completed + self.shed + self.failed
    }

    /// Fraction of counted submissions shed by admission control or
    /// deadline expiry.
    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            return 0.0;
        }
        self.shed as f64 / self.submitted as f64
    }
}

/// Assemble one tenant's [`ModelStats`] row from its snapshot entry plus
/// the submit-side counters.
fn make_model_stats(t: &Tenant, submitted: u64, shed_admission: u64) -> ModelStats {
    let mut metrics = Metrics::default();
    for cell in t.cells.iter() {
        metrics.merge(&cell.lock().unwrap());
    }
    let expired = t.counters.expired.load(Ordering::Relaxed);
    let (created, recycled, _) = t.buffers.counts();
    ModelStats {
        name: t.name.to_string(),
        weight: t.weight,
        live: t.is_live(),
        reserved: t.reserved,
        submitted,
        completed: t.counters.completed.load(Ordering::Relaxed),
        // expired requests are shed too: they were answered without
        // inference
        shed: shed_admission + expired,
        expired,
        failed: t.counters.failed.load(Ordering::Relaxed),
        metrics,
        buffers_created: created,
        buffers_recycled: recycled,
    }
}

/// Gateway-level statistics: per-model and per-replica breakdowns plus
/// the shared-queue counters and the registry epoch.
#[derive(Clone, Debug, Default)]
pub struct GatewayStats {
    /// Everything, merged (all models, all replicas).
    pub merged: Metrics,
    /// Per-replica metrics (all models served by that worker) — the
    /// load-balance view.
    pub per_replica: Vec<Metrics>,
    /// Per-model accounting, indexed by [`ModelId::index`]. Includes
    /// removed tenants (`live == false`) — slots are never reused.
    pub per_model: Vec<ModelStats>,
    /// High-water mark of the shared admission queue.
    pub peak_depth: usize,
    /// Queue depth at snapshot time.
    pub queue_depth: usize,
    /// Worker fleet size.
    pub replicas: usize,
    /// Registry epoch at snapshot time: bumps once per add_model /
    /// set_weight and twice per remove_model (stop-accepting, then
    /// retire).
    pub epoch: u64,
}

impl GatewayStats {
    /// Total valid submissions across all models.
    pub fn submitted(&self) -> u64 {
        self.per_model.iter().map(|m| m.submitted).sum()
    }

    /// Total requests answered with logits.
    pub fn completed(&self) -> u64 {
        self.per_model.iter().map(|m| m.completed).sum()
    }

    /// Total requests shed (admission rejection, eviction, removal
    /// flush, or deadline expiry).
    pub fn shed(&self) -> u64 {
        self.per_model.iter().map(|m| m.shed).sum()
    }

    /// Total requests answered with an inference error.
    pub fn failed(&self) -> u64 {
        self.per_model.iter().map(|m| m.failed).sum()
    }

    /// Batches served via work stealing, across all models and
    /// replicas (0 under [`Dispatch::Fixed`]).
    pub fn stolen_batches(&self) -> u64 {
        self.per_model.iter().map(|m| m.metrics.stolen_batches).sum()
    }

    /// Number of live (registered, not removed) models.
    pub fn live_models(&self) -> usize {
        self.per_model.iter().filter(|m| m.live).count()
    }

    /// Jain's fairness index over weight-normalized served rows
    /// (`rows / weight` per model with any submissions): 1.0 means every
    /// tenant got service in proportion to its weight, `1/n` means one
    /// tenant monopolized the fleet.
    ///
    /// This is a *service-share* index: it is meaningful when tenants
    /// are contending (backlogged), where shares are the scheduler's
    /// doing. Below saturation — or when a tenant's offered load is
    /// under its weighted share — served rows simply mirror the arrival
    /// mix, so a skewed mix reads as a low index without any tenant
    /// being starved. [`GatewayStats::fairness_index_normalized`]
    /// corrects for exactly that; the dispatch experiments report both,
    /// alongside the per-tenant p95 *queueing* delay
    /// ([`Metrics::queue_latency`]), which is the direct starvation
    /// metric the acceptance criteria gate on.
    pub fn fairness_index(&self) -> f64 {
        jain_fairness(
            self.per_model
                .iter()
                .filter(|m| m.submitted > 0)
                .map(|m| m.metrics.batch_rows as f64 / m.weight.max(1) as f64),
        )
    }

    /// Demand-normalized Jain fairness: each tenant is scored by served
    /// rows over `min(its demand, its weighted share of total service)`,
    /// so a tenant that offered less than its entitlement and got all of
    /// it reads as perfectly served instead of dragging the index down.
    /// This isolates *scheduler* fairness from the arrival mix — the
    /// raw [`GatewayStats::fairness_index`] is the right lens only at
    /// saturation. See
    /// [`jain_fairness_normalized`](crate::coordinator::metrics::jain_fairness_normalized).
    pub fn fairness_index_normalized(&self) -> f64 {
        let rows: Vec<(f64, f64, f64)> = self
            .per_model
            .iter()
            .filter(|m| m.submitted > 0)
            .map(|m| (m.metrics.batch_rows as f64, m.submitted as f64, f64::from(m.weight.max(1))))
            .collect();
        jain_fairness_normalized(&rows)
    }

    /// True when every model's counters balance.
    pub fn conserved(&self) -> bool {
        self.per_model.iter().all(ModelStats::conserved)
    }
}

/// One tenant registration queued on a [`GatewayBuilder`].
struct TenantSpec {
    name: String,
    engine: Engine,
    weight: u32,
    /// `None` inherits the fleet policy.
    policy: Option<BatchPolicy>,
    /// Registry defaults applied to requests that leave deadline /
    /// priority unset.
    defaults: TenantDefaults,
}

/// Registers models (each with a service weight and optional per-tenant
/// batch policy), then [`GatewayBuilder::start`]s the fleet. More models
/// can be added to the running gateway with [`Gateway::add_model`].
///
/// # Examples
///
/// Two tenants over one fleet, the minority tenant weighted 4x so a
/// majority-tenant burst cannot starve it:
///
/// ```
/// use kan_sas::coordinator::{GatewayBuilder, GatewayConfig};
/// use kan_sas::kan::{Engine, QuantizedModel};
///
/// let mut builder = GatewayBuilder::with_config(GatewayConfig {
///     replicas: 1,
///     ..Default::default()
/// });
/// let mnist = builder.register(
///     "mnist",
///     Engine::new(QuantizedModel::synthetic("mnist", &[8, 12, 10], 5, 3, 1)),
/// );
/// let har = builder.register_weighted(
///     "har",
///     Engine::new(QuantizedModel::synthetic("har", &[6, 8, 4], 5, 3, 2)),
///     4,
/// );
/// let gateway = builder.start();
///
/// let response = gateway.handle(har).infer_q(vec![0, 50, 100, 150, 200, 250])?;
/// assert_eq!(response.t.len(), 4);
/// let _ = gateway.handle(mnist).infer_q(vec![7; 8])?;
///
/// let stats = gateway.shutdown();
/// assert!(stats.conserved());
/// assert_eq!(stats.per_model[har.index()].weight, 4);
/// # Ok::<(), kan_sas::coordinator::ServeError>(())
/// ```
pub struct GatewayBuilder {
    cfg: GatewayConfig,
    models: Vec<TenantSpec>,
}

impl Default for GatewayBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl GatewayBuilder {
    /// A builder over the default [`GatewayConfig`].
    pub fn new() -> Self {
        Self { cfg: GatewayConfig::default(), models: Vec::new() }
    }

    /// A builder over an explicit [`GatewayConfig`].
    pub fn with_config(cfg: GatewayConfig) -> Self {
        Self { cfg, models: Vec::new() }
    }

    /// Register a model under `name` with service weight 1. The returned
    /// [`ModelId`] indexes [`GatewayStats::per_model`] and resolves to a
    /// [`ModelHandle`] once the gateway starts. Names must be unique.
    pub fn register(&mut self, name: &str, engine: Engine) -> ModelId {
        self.register_weighted(name, engine, 1)
    }

    /// Register a model under `name` with an explicit service `weight`
    /// (>= 1). Under [`Dispatch::FairSteal`] contention, tenants are
    /// served rows in proportion to their weights: a weight-4 tenant
    /// saturating the fleet alongside a weight-1 tenant gets ~4x the
    /// rows, and a *starved* high-weight tenant's backlog is dispatched
    /// before a saturated low-weight one's. Weights are ignored by
    /// [`Dispatch::Fixed`].
    pub fn register_weighted(&mut self, name: &str, engine: Engine, weight: u32) -> ModelId {
        self.push(name, engine, weight, None, TenantDefaults::default())
    }

    /// Register a model with an explicit per-tenant [`BatchPolicy`]
    /// (max batch rows / max wait) instead of the fleet default — a
    /// latency-sensitive tenant can run small fast batches while a
    /// throughput tenant coalesces large ones, on the same fleet.
    pub fn register_with_policy(
        &mut self,
        name: &str,
        engine: Engine,
        weight: u32,
        policy: BatchPolicy,
    ) -> ModelId {
        self.push(name, engine, weight, Some(policy), TenantDefaults::default())
    }

    /// Register a model with per-tenant [`TenantDefaults`]: the deadline
    /// and/or priority the gateway fills in whenever a [`Request`]
    /// leaves those fields unset. An explicit `Request::with_deadline`
    /// / `Request::with_priority` always overrides the registry default.
    pub fn register_with_defaults(
        &mut self,
        name: &str,
        engine: Engine,
        weight: u32,
        defaults: TenantDefaults,
    ) -> ModelId {
        self.push(name, engine, weight, None, defaults)
    }

    fn push(
        &mut self,
        name: &str,
        engine: Engine,
        weight: u32,
        policy: Option<BatchPolicy>,
        defaults: TenantDefaults,
    ) -> ModelId {
        assert!(weight >= 1, "model '{name}' needs weight >= 1 (got {weight})");
        assert!(
            self.models.iter().all(|s| s.name != name),
            "model '{name}' registered twice"
        );
        self.models.push(TenantSpec { name: name.to_string(), engine, weight, policy, defaults });
        ModelId(self.models.len() - 1)
    }

    /// Spawn the worker fleet and return the running [`Gateway`].
    pub fn start(self) -> Gateway {
        Gateway::start(self.cfg, self.models)
    }
}

/// A running multi-model serving gateway; [`Gateway::shutdown`] drains
/// and joins. The tenant set is live: [`Gateway::add_model`],
/// [`Gateway::remove_model`], and [`Gateway::set_weight`] mutate the
/// registry while traffic flows.
///
/// # Examples
///
/// Hot-add a tenant to a running gateway, serve it, re-weight it, then
/// remove it gracefully — conservation holds across the whole cycle:
///
/// ```
/// use kan_sas::coordinator::{DrainMode, GatewayBuilder, GatewayConfig};
/// use kan_sas::kan::{Engine, QuantizedModel};
///
/// let mut builder = GatewayBuilder::with_config(GatewayConfig {
///     replicas: 1,
///     ..Default::default()
/// });
/// builder.register(
///     "base",
///     Engine::new(QuantizedModel::synthetic("base", &[4, 6, 3], 5, 3, 1)),
/// );
/// let gateway = builder.start();
///
/// let late = gateway.add_model(
///     "late",
///     Engine::new(QuantizedModel::synthetic("late", &[6, 8, 5], 5, 3, 2)),
/// )?;
/// assert_eq!(late.infer_q(vec![1, 2, 3, 4, 5, 6])?.t.len(), 5);
/// gateway.set_weight(late.model_id(), 4)?;
/// let removed = gateway.remove_model(late.model_id(), DrainMode::Serve)?;
/// assert!(removed.conserved() && !removed.live);
/// assert!(late.infer_q(vec![1, 2, 3, 4, 5, 6]).is_err(), "removed tenants reject");
/// assert!(gateway.shutdown().conserved());
/// # Ok::<(), kan_sas::coordinator::ServeError>(())
/// ```
pub struct Gateway {
    shared: Arc<Shared>,
    replicas: usize,
    telemetry: Arc<Telemetry>,
    collector: Option<JoinHandle<()>>,
    auto: Option<Arc<AutoRuntime>>,
    autoscaler: Option<JoinHandle<()>>,
}

impl Gateway {
    /// A [`GatewayBuilder`] over the default config.
    pub fn builder() -> GatewayBuilder {
        GatewayBuilder::new()
    }

    fn start(cfg: GatewayConfig, models: Vec<TenantSpec>) -> Self {
        assert!(cfg.replicas >= 1, "gateway needs at least one replica");
        assert!(cfg.queue_cap >= 1, "admission queue needs capacity");
        assert!(!models.is_empty(), "gateway needs at least one registered model");
        // Fleet geometry: a fixed fleet runs `replicas` workers forever;
        // under autoscaling the *slots* (shards, metrics cells,
        // telemetry rings) are pre-sized to `max_workers` so scaling
        // never reallocates shared state, and only `min_workers` start.
        if let Some(a) = &cfg.autoscale {
            assert!(
                a.min_workers >= 1 && a.min_workers <= a.max_workers,
                "autoscale bounds need 1 <= min ({}) <= max ({})",
                a.min_workers,
                a.max_workers
            );
        }
        let slots = cfg.autoscale.map_or(cfg.replicas, |a| a.max_workers);
        let initial = cfg.autoscale.map_or(cfg.replicas, |a| a.min_workers);
        let mut telemetry_cfg = cfg.telemetry;
        if cfg.autoscale.is_some() {
            // the controller is blind without windowed signals
            telemetry_cfg.enabled = true;
        }
        let tenants: Vec<Tenant> = models
            .into_iter()
            .map(|s| {
                Tenant::new(
                    &s.name,
                    s.engine,
                    s.weight,
                    s.policy.unwrap_or(cfg.policy),
                    s.defaults,
                    cfg.queue_cap,
                    slots,
                    telemetry_cfg.exact_samples,
                )
            })
            .collect();
        let n_models = tenants.len();
        let names: Vec<&str> = tenants.iter().map(|t| &*t.name).collect();
        let telemetry = Arc::new(Telemetry::new_with_clock(
            telemetry_cfg,
            slots,
            &names,
            cfg.clock.clone(),
        ));
        drop(names);
        for (i, t) in tenants.iter().enumerate() {
            telemetry.record_churn(ChurnKind::Registered, i as u32, &t.name, t.weight, 1);
        }
        let registry = build_snapshot(1, tenants, cfg.queue_cap, cfg.quota);
        let shards = (0..slots)
            .map(|_| Shard {
                queues: Mutex::new(ShardQueues::empty()),
                backlog: AtomicUsize::new(0),
            })
            .collect();
        let shared = Arc::new(Shared {
            state: Mutex::new(GwState {
                registry,
                items: VecDeque::new(),
                open: true,
                submitted: vec![0; n_models],
                shed: vec![0; n_models],
                depth: vec![0; n_models],
                overflow: 0,
                blocked: vec![0; n_models],
                peak_depth: 0,
            }),
            nonempty: Condvar::new(),
            drained: Condvar::new(),
            admin: Mutex::new(()),
            draining: AtomicBool::new(false),
            cap: cfg.queue_cap,
            shed_policy: cfg.shed,
            dispatch: cfg.dispatch,
            quota: cfg.quota,
            replicas: slots,
            default_policy: cfg.policy,
            shards,
            telemetry: Arc::clone(&telemetry),
            clock: cfg.clock.clone(),
            sim_array: cfg.sim_array,
            fleet: Fleet {
                active: AtomicUsize::new(0),
                stopping: (0..slots).map(|_| AtomicBool::new(false)).collect(),
                handles: Mutex::new((0..slots).map(|_| None).collect()),
                started_us: (0..slots).map(|_| AtomicU64::new(0)).collect(),
                busy_us: AtomicU64::new(0),
                ledger: Mutex::new(()),
                pin_cores: cfg.autoscale.is_some_and(|a| a.pin_cores),
                scale_lock: Mutex::new(()),
            },
        });
        for slot in 0..initial {
            spawn_worker(&shared, slot);
        }
        shared.fleet.active.store(initial, Ordering::SeqCst);
        let collector = telemetry.enabled().then(|| {
            let tel = Arc::clone(&telemetry);
            std::thread::Builder::new()
                .name("kansas-telemetry".into())
                .spawn(move || tel.run_collector())
                .expect("spawn telemetry collector")
        });
        let auto = cfg.autoscale.map(|a| {
            Arc::new(AutoRuntime {
                ctl: Mutex::new(AutoCtl {
                    controller: Controller::new(a),
                    events: VecDeque::new(),
                }),
                stop: AtomicBool::new(false),
            })
        });
        // Under a manual clock no controller thread is spawned: tests
        // drive evaluation synchronously through `autoscale_tick`, so a
        // clock advance for a batching window never races a background
        // scaling action.
        let autoscaler = match &auto {
            Some(rt) if !cfg.clock.is_manual() => {
                let (shared_a, tel_a, rt_a) =
                    (Arc::clone(&shared), Arc::clone(&telemetry), Arc::clone(rt));
                Some(
                    std::thread::Builder::new()
                        .name("kansas-autoscale".into())
                        .spawn(move || autoscale_loop(&shared_a, &tel_a, &rt_a))
                        .expect("spawn autoscale controller"),
                )
            }
            _ => None,
        };
        Self { shared, replicas: slots, telemetry, collector, auto, autoscaler }
    }

    /// The gateway's telemetry spine: live windowed stats, flight
    /// recorder dumps, and trace spans. Inert (cheap no-op emitters)
    /// when [`TelemetryConfig::enabled`] is false.
    pub fn telemetry(&self) -> Arc<Telemetry> {
        Arc::clone(&self.telemetry)
    }

    /// Number of live (registered, not removed) models.
    pub fn n_models(&self) -> usize {
        let st = self.shared.state.lock().unwrap();
        st.registry.tenants.iter().filter(|t| t.is_live()).count()
    }

    /// The registry epoch: bumps on every add_model / set_weight and
    /// twice per remove_model. Workers adopt a new epoch at their next
    /// batch boundary.
    pub fn registry_epoch(&self) -> u64 {
        self.shared.state.lock().unwrap().registry.epoch
    }

    fn handle_of(&self, t: &Tenant, slot: usize) -> ModelHandle {
        ModelHandle {
            shared: Arc::clone(&self.shared),
            model: ModelId(slot),
            name: Arc::clone(&t.name),
            in_dim: t.in_dim,
            out_dim: t.out_dim,
            rows: Arc::clone(&t.rows),
        }
    }

    /// The typed handle for a registered model.
    ///
    /// # Panics
    ///
    /// Panics if `id` was never registered on this gateway. A *removed*
    /// model still resolves (its submissions then answer
    /// [`ServeError::UnknownModel`]).
    pub fn handle(&self, id: ModelId) -> ModelHandle {
        let st = self.shared.state.lock().unwrap();
        let reg = Arc::clone(&st.registry);
        drop(st);
        let t = reg.tenants.get(id.0).expect("ModelId registered on this gateway");
        self.handle_of(t, id.0)
    }

    /// Resolve a handle by registered name (live tenants only).
    pub fn handle_by_name(&self, name: &str) -> Result<ModelHandle, ServeError> {
        let st = self.shared.state.lock().unwrap();
        let reg = Arc::clone(&st.registry);
        drop(st);
        reg.tenants
            .iter()
            .enumerate()
            .find(|(_, t)| t.is_live() && &*t.name == name)
            .map(|(slot, t)| self.handle_of(t, slot))
            .ok_or_else(|| ServeError::UnknownModel(name.to_string()))
    }

    /// All live handles, in registration (slot) order.
    pub fn handles(&self) -> Vec<ModelHandle> {
        let st = self.shared.state.lock().unwrap();
        let reg = Arc::clone(&st.registry);
        drop(st);
        reg.tenants
            .iter()
            .enumerate()
            .filter(|(_, t)| t.is_live())
            .map(|(slot, t)| self.handle_of(t, slot))
            .collect()
    }

    /// Hot-add a model (service weight 1, fleet batch policy) to the
    /// running gateway. The new tenant is admissible immediately;
    /// workers pick it up at their next batch boundary. Quota
    /// reservations are recomputed over the new tenant set.
    pub fn add_model(&self, name: &str, engine: Engine) -> Result<ModelHandle, ServeError> {
        self.add_model_with(name, engine, 1, None)
    }

    /// Hot-add a model with an explicit service weight.
    pub fn add_model_weighted(
        &self,
        name: &str,
        engine: Engine,
        weight: u32,
    ) -> Result<ModelHandle, ServeError> {
        self.add_model_with(name, engine, weight, None)
    }

    /// Hot-add a model with an explicit weight and (optionally) its own
    /// [`BatchPolicy`]. Errors: [`ServeError::InvalidInput`] for a zero
    /// weight or a name already live, [`ServeError::Closed`] after
    /// shutdown began.
    pub fn add_model_with(
        &self,
        name: &str,
        engine: Engine,
        weight: u32,
        policy: Option<BatchPolicy>,
    ) -> Result<ModelHandle, ServeError> {
        if weight == 0 {
            return Err(ServeError::InvalidInput(format!(
                "model '{name}' needs weight >= 1"
            )));
        }
        let _admin = self.shared.admin.lock().unwrap();
        let mut st = self.shared.state.lock().unwrap();
        if !st.open {
            return Err(ServeError::Closed);
        }
        if st.registry.tenants.iter().any(|t| t.is_live() && &*t.name == name) {
            return Err(ServeError::InvalidInput(format!(
                "model '{name}' already registered"
            )));
        }
        let tenant = Tenant::new(
            name,
            engine,
            weight,
            policy.unwrap_or(self.shared.default_policy),
            TenantDefaults::default(),
            self.shared.cap,
            self.shared.replicas,
            self.shared.telemetry.config().exact_samples,
        );
        let slot = st.registry.tenants.len();
        let handle = self.handle_of(&tenant, slot);
        let mut tenants = st.registry.tenants.clone();
        tenants.push(tenant);
        st.registry =
            build_snapshot(st.registry.epoch + 1, tenants, self.shared.cap, self.shared.quota);
        st.submitted.push(0);
        st.shed.push(0);
        st.depth.push(0);
        st.blocked.push(0);
        st.overflow = overflow_scan(&st);
        // reservations just redistributed: blocked submitters of other
        // tenants may have gained headroom
        wake_space(&self.shared, &st);
        let epoch = st.registry.epoch;
        self.shared
            .telemetry
            .record_churn(ChurnKind::Added, slot as u32, name, weight, epoch);
        Ok(handle)
    }

    /// Set a live tenant's [`TenantDefaults`] — the deadline and
    /// priority applied to every [`Request`] that leaves the field
    /// unset. Takes effect for submissions that acquire the state lock
    /// after this call returns (including `Block`-parked ones, which
    /// re-resolve on wake).
    pub fn set_defaults(&self, id: ModelId, defaults: TenantDefaults) -> Result<(), ServeError> {
        let _admin = self.shared.admin.lock().unwrap();
        let mut st = self.shared.state.lock().unwrap();
        match st.registry.tenants.get(id.0) {
            None => return Err(ServeError::UnknownModel(id.to_string())),
            Some(t) if !t.is_live() => {
                return Err(ServeError::UnknownModel(t.name.to_string()))
            }
            Some(_) => {}
        }
        let mut tenants = st.registry.tenants.clone();
        tenants[id.0].defaults = defaults;
        st.registry =
            build_snapshot(st.registry.epoch + 1, tenants, self.shared.cap, self.shared.quota);
        st.overflow = overflow_scan(&st);
        Ok(())
    }

    /// Re-weight a live tenant. Takes effect at every worker's next
    /// batch boundary (DRR quanta) and immediately for quota
    /// reservations, which are recomputed over the new weights.
    pub fn set_weight(&self, id: ModelId, weight: u32) -> Result<(), ServeError> {
        if weight == 0 {
            return Err(ServeError::InvalidInput("service weight must be >= 1".to_string()));
        }
        let _admin = self.shared.admin.lock().unwrap();
        let mut st = self.shared.state.lock().unwrap();
        match st.registry.tenants.get(id.0) {
            None => return Err(ServeError::UnknownModel(id.to_string())),
            Some(t) if !t.is_live() => {
                return Err(ServeError::UnknownModel(t.name.to_string()))
            }
            Some(t) if t.weight == weight => return Ok(()),
            Some(_) => {}
        }
        let mut tenants = st.registry.tenants.clone();
        tenants[id.0].weight = weight;
        st.registry =
            build_snapshot(st.registry.epoch + 1, tenants, self.shared.cap, self.shared.quota);
        st.overflow = overflow_scan(&st);
        // a re-weight moves reservations: some parked submitter may now
        // fit its tenant's (grown) reserve
        wake_space(&self.shared, &st);
        let epoch = st.registry.epoch;
        let name = Arc::clone(&st.registry.tenants[id.0].name);
        self.shared
            .telemetry
            .record_churn(ChurnKind::Reweighted, id.0 as u32, &name, weight, epoch);
        Ok(())
    }

    /// Remove a live tenant from the running gateway.
    ///
    /// The drain contract, in order: (1) the tenant stops accepting —
    /// a registry swap makes new submissions resolve
    /// [`ServeError::UnknownModel`]; (2) its backlog is disposed of per
    /// [`DrainMode`] — served to completion (non-due batches are
    /// expedited) or answered `QueueFull`; (3) once the tenant's
    /// in-flight count reaches zero its engine is dropped (freeing the
    /// model memory) and its [`BufferPool`] retired. Blocks until the
    /// drain completes and returns the tenant's final [`ModelStats`]
    /// (which also stay visible in [`GatewayStats`] with
    /// `live == false`). Per-model conservation holds across the whole
    /// transition.
    pub fn remove_model(&self, id: ModelId, mode: DrainMode) -> Result<ModelStats, ServeError> {
        let _admin = self.shared.admin.lock().unwrap();
        let counters;
        let buffers;
        let rows;
        {
            let mut st = self.shared.state.lock().unwrap();
            if !st.open {
                return Err(ServeError::Closed);
            }
            match st.registry.tenants.get(id.0) {
                None => return Err(ServeError::UnknownModel(id.to_string())),
                Some(t) if !t.is_live() => {
                    return Err(ServeError::UnknownModel(t.name.to_string()))
                }
                Some(t) => {
                    counters = Arc::clone(&t.counters);
                    buffers = Arc::clone(&t.buffers);
                    rows = Arc::clone(&t.rows);
                }
            }
            // (1) stop accepting; reservations redistribute to the
            // survivors; workers see the epoch bump and expedite this
            // tenant's batches
            let mut tenants = st.registry.tenants.clone();
            tenants[id.0].accepting = false;
            st.registry =
                build_snapshot(st.registry.epoch + 1, tenants, self.shared.cap, self.shared.quota);
            st.overflow = overflow_scan(&st);
            {
                let t = &st.registry.tenants[id.0];
                self.shared.telemetry.record_churn(
                    ChurnKind::RemoveBegin,
                    id.0 as u32,
                    &t.name,
                    t.weight,
                    st.registry.epoch,
                );
            }
            // (2, Shed) flush the backlog: everything still in the
            // shared queue or a shard batcher is answered QueueFull.
            // Batches already being served complete normally — both
            // outcomes keep `submitted == completed + shed + failed`.
            if mode == DrainMode::Shed {
                let mut answered = 0u64;
                let mut kept = VecDeque::with_capacity(st.items.len());
                while let Some(r) = st.items.pop_front() {
                    if r.model == id {
                        answered += 1;
                        self.shared.telemetry.emit_admission(
                            EventKind::Shed,
                            id.0 as u32,
                            1,
                            0,
                            0,
                            r.trace,
                        );
                        buffers.release(r.out);
                        rows.release(r.x_q);
                        let _ = r.resp.send(Err(ServeError::QueueFull));
                    } else {
                        kept.push_back(r);
                    }
                }
                st.items = kept;
                for _ in 0..st.depth[id.0] {
                    depth_dec(&mut st, id.0);
                }
                // state → shard lock order, same as the pull path
                let mut swept: Vec<GwRequest> = Vec::new();
                for shard in &self.shared.shards {
                    let mut q = shard.queues.lock().unwrap();
                    if id.0 >= q.batchers.len() {
                        continue;
                    }
                    loop {
                        let took = q.batchers[id.0].drain_upto(&mut swept, usize::MAX);
                        if took == 0 {
                            break;
                        }
                        shard.backlog.fetch_sub(took, Ordering::Relaxed);
                        answered += took as u64;
                        for r in swept.drain(..) {
                            self.shared.telemetry.emit_admission(
                                EventKind::Shed,
                                id.0 as u32,
                                1,
                                0,
                                0,
                                r.trace,
                            );
                            buffers.release(r.out);
                            rows.release(r.x_q);
                            let _ = r.resp.send(Err(ServeError::QueueFull));
                        }
                    }
                }
                st.shed[id.0] += answered;
                counters.inflight.fetch_sub(answered, Ordering::SeqCst);
            }
            // the removed tenant's flushed slots (and redistributed
            // reservations) may unblock parked submitters of survivors;
            // the removed tenant's own waiters are woken to observe
            // UnknownModel
            wake_space(&self.shared, &st);
        }
        // (2, Serve) / tail of Shed: wait until everything admitted for
        // the tenant has been answered. Workers are nudged each lap so
        // sleeping ones reload the registry and see the expedite flags;
        // progress is theirs, the 500us timeout only bounds a missed
        // wakeup.
        self.shared.draining.store(true, Ordering::SeqCst);
        {
            let mut st = self.shared.state.lock().unwrap();
            while counters.inflight.load(Ordering::SeqCst) > 0 {
                self.shared.nonempty.notify_all();
                let (g, _) = self
                    .shared
                    .drained
                    .wait_timeout(st, Duration::from_micros(500))
                    .unwrap();
                st = g;
            }
        }
        self.shared.draining.store(false, Ordering::SeqCst);
        // (3) retire: drop the engine (frees the model's share of the
        // Arc'd weights once stale worker snapshots refresh) and empty
        // the buffer free-list. In-flight Responses still hold pool Arcs
        // and free their buffers on drop.
        let stats;
        {
            let mut st = self.shared.state.lock().unwrap();
            let mut tenants = st.registry.tenants.clone();
            tenants[id.0].engine = None;
            tenants[id.0].accepting = false;
            st.registry =
                build_snapshot(st.registry.epoch + 1, tenants, self.shared.cap, self.shared.quota);
            st.overflow = overflow_scan(&st);
            let reg = Arc::clone(&st.registry);
            let t = &reg.tenants[id.0];
            self.shared.telemetry.record_churn(
                ChurnKind::Removed,
                id.0 as u32,
                &t.name,
                t.weight,
                reg.epoch,
            );
            stats = make_model_stats(t, st.submitted[id.0], st.shed[id.0]);
        }
        buffers.retire();
        rows.retire();
        Ok(stats)
    }

    /// Live snapshot (the gateway keeps serving).
    pub fn stats(&self) -> GatewayStats {
        self.snapshot()
    }

    /// Stop admitting, serve everything already queued, join all
    /// workers, and return the final stats.
    pub fn shutdown(mut self) -> GatewayStats {
        // Retire the autoscaler first so no scaling action races the
        // drain (it holds no locks while parked; the clock wake cuts
        // its interval sleep short).
        if let Some(rt) = &self.auto {
            rt.stop.store(true, Ordering::SeqCst);
        }
        self.shared.clock.wake_all();
        if let Some(a) = self.autoscaler.take() {
            let _ = a.join();
        }
        {
            let mut st = self.shared.state.lock().unwrap();
            st.open = false;
            // closed gateways admit nothing: every parked submitter must
            // wake to observe `Closed` (wake_space signals all waiters of
            // a non-open gateway)
            wake_space(&self.shared, &st);
        }
        self.shared.nonempty.notify_all();
        let workers: Vec<JoinHandle<()>> = {
            let mut handles = self.shared.fleet.handles.lock().unwrap();
            handles.iter_mut().filter_map(|h| h.take()).collect()
        };
        for w in workers {
            let _ = w.join();
        }
        self.telemetry.stop();
        if let Some(c) = self.collector.take() {
            let _ = c.join();
        }
        self.snapshot()
    }

    /// Workers currently running (scale actions move this between the
    /// autoscale bounds; fixed fleets stay at `replicas`). A draining
    /// victim counts until its thread is joined.
    pub fn active_workers(&self) -> usize {
        self.shared.fleet.active.load(Ordering::SeqCst)
    }

    /// Worker slots (the ceiling the gateway was pre-sized to).
    pub fn worker_slots(&self) -> usize {
        self.replicas
    }

    /// Total worker-µs the fleet has consumed: exited workers'
    /// accumulated spans plus the running span of every live slot. The
    /// autoscale bench divides this by wall time to report fleet cost
    /// against a fixed peak-size fleet.
    pub fn worker_time_us(&self) -> u64 {
        let now = self.shared.clock.now_us();
        let fleet = &self.shared.fleet;
        let _ledger = fleet.ledger.lock().unwrap();
        let running: u64 = fleet
            .started_us
            .iter()
            .map(|s| match s.load(Ordering::SeqCst) {
                0 => 0,
                stamp => now.saturating_sub(stamp - 1),
            })
            .sum();
        fleet.busy_us.load(Ordering::SeqCst) + running
    }

    /// Scale the fleet to `target` active workers (clamped to
    /// `1..=worker_slots`), synchronously: scale-up returns once the
    /// new workers are spawned, scale-down once each drained victim is
    /// joined (its backlog flushed — no request is dropped). Returns
    /// the active count after the action. Serialized against the
    /// background autoscaler's own actions.
    pub fn scale_to(&self, target: usize) -> usize {
        fleet_scale_to(&self.shared, target)
    }

    /// One synchronous autoscale evaluation over the *live* telemetry
    /// snapshot: reduce it to [`FleetSignals`], ask the controller, and
    /// apply the decision. Returns the applied event, or `None` on
    /// hold / when the gateway has no autoscale policy. This is the
    /// manual-clock path — tests advance the [`Clock`], let the
    /// telemetry collector roll a window, then tick.
    pub fn autoscale_tick(&self) -> Option<ScaleEvent> {
        let sig = FleetSignals::from_snapshot(&self.telemetry.snapshot());
        self.autoscale_apply(&sig)
    }

    /// Like [`Gateway::autoscale_tick`], but over caller-built signals —
    /// the deterministic harness for controller-and-actuator tests (a
    /// synthetic p95 breach scales the real fleet without any traffic).
    pub fn autoscale_apply(&self, sig: &FleetSignals) -> Option<ScaleEvent> {
        let rt = self.auto.as_ref()?;
        apply_decision(&self.shared, rt, sig)
    }

    /// The applied scale actions, oldest first (bounded at
    /// [`SCALE_EVENT_CAP`]). Empty for fixed fleets.
    pub fn scale_events(&self) -> Vec<ScaleEvent> {
        match &self.auto {
            Some(rt) => rt.ctl.lock().unwrap().events.iter().copied().collect(),
            None => Vec::new(),
        }
    }

    fn snapshot(&self) -> GatewayStats {
        let st = self.shared.state.lock().unwrap();
        let reg = Arc::clone(&st.registry);
        let queue_depth = st.items.len();
        let peak_depth = st.peak_depth;
        let submitted = st.submitted.clone();
        let shed = st.shed.clone();
        drop(st);
        let mut merged = Metrics::default();
        let mut per_replica = vec![Metrics::default(); self.replicas];
        let mut per_model = Vec::with_capacity(reg.tenants.len());
        for (m, t) in reg.tenants.iter().enumerate() {
            for (r, cell) in t.cells.iter().enumerate() {
                let mm = cell.lock().unwrap().clone();
                merged.merge(&mm);
                per_replica[r].merge(&mm);
            }
            per_model.push(make_model_stats(t, submitted[m], shed[m]));
        }
        GatewayStats {
            merged,
            per_replica,
            per_model,
            peak_depth,
            queue_depth,
            replicas: self.replicas,
            epoch: reg.epoch,
        }
    }
}

/// Re-sync worker-local caches with a (new) registry snapshot: the DRR
/// weight table, and scratch-arena fitting for tenants this worker has
/// not seen yet (slots are append-only, so `fitted` is a watermark).
/// Runs outside every lock; only on an epoch change in steady state.
fn refresh_tenants(
    snap: &RegistrySnapshot,
    weights: &mut Vec<u32>,
    scratch: &mut Scratch,
    fitted: &mut usize,
) {
    weights.clear();
    weights.extend(snap.tenants.iter().map(|t| t.weight));
    for t in &snap.tenants[*fitted..] {
        if let Some(e) = &t.engine {
            scratch.fit(e.plan(), t.policy.max_batch);
        }
    }
    *fitted = snap.tenants.len();
}

/// Spawn the worker thread for `slot` and store its handle in the
/// fleet. The slot's shard, metrics cells, and telemetry ring were all
/// pre-sized at gateway start, so this allocates nothing shared.
fn spawn_worker(shared: &Arc<Shared>, slot: usize) {
    shared.fleet.stopping[slot].store(false, Ordering::SeqCst);
    let shared_w = Arc::clone(shared);
    let sim_array = shared.sim_array;
    let w = std::thread::Builder::new()
        .name(format!("kansas-gw-{slot}"))
        .spawn(move || worker_loop(slot, sim_array, shared_w))
        .expect("spawn gateway worker");
    shared.fleet.handles.lock().unwrap()[slot] = Some(w);
}

/// A worker's last act: fold its running span into the fleet's
/// worker-seconds ledger and mark the slot not-running.
fn worker_exit(shared: &Shared, me: usize) {
    // Under the ledger lock: swapping the stamp out and banking the
    // span are two steps, and a worker_time_us reader landing between
    // them would count this worker in neither sum (the ledger would
    // appear to go backwards between two reads).
    let _ledger = shared.fleet.ledger.lock().unwrap();
    let stamp = shared.fleet.started_us[me].swap(0, Ordering::SeqCst);
    if stamp > 0 {
        let span = shared.clock.now_us().saturating_sub(stamp - 1);
        shared.fleet.busy_us.fetch_add(span, Ordering::SeqCst);
    }
}

/// Move the active fleet to `target` workers (clamped to
/// `1..=replicas`), serially. Scale-up spawns slot `active` upward;
/// scale-down generalizes the `remove_model` drain contract to
/// replicas: flag slot `active - 1` as stopping (no new dispatch to
/// it), wake the fleet so it and stealing peers flush its shard
/// backlog, and join the thread — it exits only at backlog zero, so
/// every queued request is answered and per-model conservation holds
/// through the drain. Returns the resulting active count.
fn fleet_scale_to(shared: &Arc<Shared>, target: usize) -> usize {
    let _scale = shared.fleet.scale_lock.lock().unwrap();
    fleet_scale_locked(shared, target)
}

/// [`fleet_scale_to`] body; the caller must hold `scale_lock`.
fn fleet_scale_locked(shared: &Arc<Shared>, target: usize) -> usize {
    let fleet = &shared.fleet;
    let target = target.clamp(1, shared.replicas);
    let mut active = fleet.active.load(Ordering::SeqCst);
    while active < target {
        spawn_worker(shared, active);
        active += 1;
        fleet.active.store(active, Ordering::SeqCst);
        // a new worker must observe any backlog that predates it
        shared.nonempty.notify_all();
    }
    while active > target {
        let victim = active - 1;
        fleet.stopping[victim].store(true, Ordering::SeqCst);
        // Wake everyone: the victim to notice the flag, peers to steal
        // its tail. Notify under the state mutex — workers decide to
        // park only while holding it and re-read the flag there, so
        // the victim is either parked (receives this wakeup) or will
        // see the flag before its next wait; without the lock the
        // store+notify can land mid-iteration and the victim parks on
        // an untimed wait forever, wedging this join.
        {
            let _st = shared.state.lock().unwrap();
            shared.nonempty.notify_all();
        }
        let handle = fleet.handles.lock().unwrap()[victim].take();
        if let Some(h) = handle {
            let _ = h.join();
        }
        fleet.stopping[victim].store(false, Ordering::SeqCst);
        active -= 1;
        fleet.active.store(active, Ordering::SeqCst);
    }
    active
}

/// One controller evaluation + actuation: ask the policy, move the
/// fleet, record the applied [`ScaleEvent`]. Returns `None` on hold.
fn apply_decision(
    shared: &Arc<Shared>,
    rt: &AutoRuntime,
    sig: &FleetSignals,
) -> Option<ScaleEvent> {
    // Hold scale_lock across read → evaluate → actuate so a concurrent
    // `Gateway::scale_to` can't move the fleet between the decision
    // and its application (a stale `from` would mis-size the doubling
    // target and misreport ScaleEvent.from).
    let _scale = shared.fleet.scale_lock.lock().unwrap();
    let from = shared.fleet.active.load(Ordering::SeqCst);
    let decision = rt.ctl.lock().unwrap().controller.evaluate(from, sig);
    let target = match decision {
        ScaleDecision::Hold => return None,
        ScaleDecision::Up(n) => from + n,
        ScaleDecision::Down(n) => from.saturating_sub(n),
    };
    let to = fleet_scale_locked(shared, target);
    let event = ScaleEvent {
        at_us: shared.clock.now_us(),
        from,
        to,
        p95_queue_us: sig.p95_queue_us,
        shed_rate: sig.shed_rate,
    };
    let mut ctl = rt.ctl.lock().unwrap();
    ctl.events.push_back(event);
    while ctl.events.len() > SCALE_EVENT_CAP {
        ctl.events.pop_front();
    }
    Some(event)
}

/// The production controller loop (real clock only): every
/// [`AutoscaleConfig::interval`], reduce the live telemetry snapshot to
/// [`FleetSignals`] and apply the policy. Exits when the gateway's
/// shutdown sets the stop flag and wakes the clock.
fn autoscale_loop(shared: &Arc<Shared>, telemetry: &Telemetry, rt: &AutoRuntime) {
    let interval = rt.ctl.lock().unwrap().controller.config().interval;
    loop {
        shared.clock.sleep(interval);
        if rt.stop.load(Ordering::SeqCst) {
            return;
        }
        let sig = FleetSignals::from_snapshot(&telemetry.snapshot());
        apply_decision(shared, rt, &sig);
    }
}

/// One fleet worker: serves every registered model through the registry
/// snapshot, owns a fleet-visible shard of per-model batchers, one
/// scratch arena sized to the widest model, two reusable batch Vecs.
/// Each turn of the loop: refresh the registry cache if the epoch moved
/// (one u64 compare otherwise), pull admissions into the own shard,
/// dispatch ONE batch (own shard by the configured [`Dispatch`] policy,
/// else steal a due batch from the most backlogged peer), serve it,
/// repeat. The worker sleeps only when nothing is due anywhere it can
/// reach, and exits only when the gateway is closed and fully drained.
fn worker_loop(me: usize, sim_array: ArrayConfig, shared: Arc<Shared>) {
    if shared.fleet.pin_cores {
        pin_current_thread(me);
    }
    shared.fleet.started_us[me].store(shared.clock.now_us() + 1, Ordering::SeqCst);
    let mut scratch = Scratch::new();
    let mut batch: Vec<GwRequest> = Vec::new();
    let mut live: Vec<GwRequest> = Vec::new();
    let mut snap = Arc::clone(&shared.state.lock().unwrap().registry);
    let mut weights: Vec<u32> = Vec::new();
    let mut fitted = 0usize;
    refresh_tenants(&snap, &mut weights, &mut scratch, &mut fitted);
    loop {
        // Phase 1: adopt any registry change, then move admitted
        // requests into this worker's shard (the pull also grows the
        // shard to the current snapshot under the same locks). A
        // *stopping* worker (scale-down victim) pulls nothing — new
        // admissions belong to the survivors.
        let closed;
        let stopping = shared.fleet.stopping[me].load(Ordering::SeqCst);
        let mut reloaded = false;
        {
            let mut st = shared.state.lock().unwrap();
            if st.registry.epoch != snap.epoch {
                snap = Arc::clone(&st.registry);
                reloaded = true;
            }
            closed = !st.open;
            let admitted = if stopping { false } else { pull_into(&mut st, &shared, me) };
            let more_queued = !st.items.is_empty();
            if admitted {
                // quota-aware: only tenants whose admission check can
                // now pass are signalled (must run under the state lock)
                wake_space(&shared, &st);
            }
            drop(st);
            if more_queued && (admitted || stopping) {
                // this shard can't hold the remainder (batchers full, or
                // this worker is draining out); wake a peer to pull it
                shared.nonempty.notify_one();
            }
        }
        if reloaded {
            // outside the locks: fit the scratch for unseen tenants and
            // rebuild the DRR weight table before dispatching them
            refresh_tenants(&snap, &mut weights, &mut scratch, &mut fitted);
            shared.telemetry.emit_worker(
                me,
                EventKind::EpochAdopted,
                NO_TENANT,
                0,
                snap.epoch,
                0,
                0,
            );
        }
        // Phase 2: dispatch one batch — own shard first, then steal.
        // Batches never mix models: each drain comes from one model's
        // batcher and runs on that model's registry engine (shared by
        // the whole fleet, so stolen batches serve anywhere). A
        // stopping worker *flushes*: its own batches are all due now
        // (drain them out fast), and it never steals new work.
        let flush = closed || stopping;
        let now_us = shared.clock.now_us();
        let mut picked: Option<(usize, bool)> = None;
        {
            let shard = &shared.shards[me];
            let mut q = shard.queues.lock().unwrap();
            let pick = match shared.dispatch {
                Dispatch::FairSteal => q.next_drr(&weights, flush, now_us),
                Dispatch::Fixed => q.next_fixed(flush, now_us),
            };
            if let Some(m) = pick {
                let age = q.batchers[m].oldest_age(now_us).unwrap_or_default();
                let took = q.batchers[m].drain_into(&mut batch);
                shard.backlog.fetch_sub(took, Ordering::Relaxed);
                shared.telemetry.emit_worker(
                    me,
                    EventKind::BatchFormed,
                    m as u32,
                    took as u32,
                    age.as_micros() as u64,
                    0,
                    0,
                );
                picked = Some((m, false));
            }
        }
        if picked.is_none() && !stopping && shared.dispatch == Dispatch::FairSteal {
            picked = steal_batch(&shared, &snap, me, closed, &mut batch).map(|m| (m, true));
        }
        if let Some((m, stolen)) = picked {
            // span echoes: a rows==0 event per *traced* request marks
            // which batch its lifecycle rode (skipped by all counters)
            for r in batch.iter().filter(|r| r.trace != 0) {
                let kind = if stolen { EventKind::Stolen } else { EventKind::BatchFormed };
                shared.telemetry.emit_worker(me, kind, m as u32, 0, 0, 0, r.trace);
            }
            serve_batch(
                &snap.tenants[m],
                m,
                me,
                &sim_array,
                &mut batch,
                &mut live,
                &mut scratch,
                &shared,
                stolen,
            );
            continue;
        }
        // Phase 3: nothing due anywhere. A drained stopping worker
        // exits (scale-down join point); a closed-and-drained fleet
        // exits; otherwise sleep, bounded by the soonest moment a batch
        // this worker could serve comes due (its own shard's always, a
        // backlogged peer's too when stealing is on) so straggler
        // windows and steal opportunities are never overslept.
        let st = shared.state.lock().unwrap();
        // Re-read the drain flag under the state mutex: fleet_scale_to
        // sets it and notifies while holding this lock, so a flip that
        // landed after the loop-top read is observed here instead of
        // being lost to the untimed wait below.
        let stopping_now = shared.fleet.stopping[me].load(Ordering::SeqCst);
        if stopping_now {
            if shared.shards[me].backlog.load(Ordering::Relaxed) == 0 {
                // own shard flushed (phase 2 serves it flush-due; peers
                // may steal the tail) — admission-queue items are the
                // survivors' to pull, never this worker's again
                drop(st);
                worker_exit(&shared, me);
                return;
            }
            if !stopping {
                // flagged mid-iteration with work still in the shard:
                // spin again so phase 2 flush-serves it
                drop(st);
                continue;
            }
        }
        if !st.items.is_empty() {
            continue; // arrivals raced in between phases
        }
        if !st.open {
            let drained = match shared.dispatch {
                Dispatch::Fixed => shared.shards[me].backlog.load(Ordering::Relaxed) == 0,
                Dispatch::FairSteal => {
                    shared.shards.iter().all(|s| s.backlog.load(Ordering::Relaxed) == 0)
                }
            };
            if drained {
                drop(st);
                worker_exit(&shared, me);
                return;
            }
            // a peer's shard still holds work this worker can steal on
            // the next spin (its owner may be mid-serve); don't sleep on
            // a condvar nobody will signal again
            drop(st);
            std::thread::yield_now();
            continue;
        }
        match wait_hint(&shared, me) {
            Some(d) if d.is_zero() => { /* something just came due; spin again */ }
            Some(d) => {
                let _ = shared.nonempty.wait_timeout(st, d).unwrap();
            }
            None => {
                let _ = shared.nonempty.wait(st).unwrap();
            }
        }
    }
}

/// Move queued requests into worker `me`'s shard (growing it to the
/// current registry first). [`Dispatch::Fixed`] preserves the pre-fair
/// behaviour: strict FIFO that stops at the first request whose batcher
/// is full, so a one-tenant burst head-of-line blocks every other
/// tenant. [`Dispatch::FairSteal`] scans past such requests — a
/// saturated tenant's overflow stays queued while other tenants'
/// arrivals keep flowing (per-model FIFO order is preserved; only
/// *other* models' requests are overtaken). Returns whether anything
/// entered the shard. Runs under the admission-queue lock, and updates
/// the shard's backlog index and per-tenant queue depths there too, so
/// "queue empty + all backlogs zero" is an exact drained check and the
/// quota accountant never double-counts.
fn pull_into(st: &mut GwState, shared: &Shared, me: usize) -> bool {
    let reg = Arc::clone(&st.registry);
    let shard = &shared.shards[me];
    let mut q = shard.queues.lock().unwrap();
    q.grow(&reg);
    let mut admitted = 0usize;
    match shared.dispatch {
        Dispatch::Fixed => {
            while let Some(front) = st.items.front() {
                let b = &mut q.batchers[front.model.0];
                if b.len() >= b.max_batch() {
                    break;
                }
                let r = st.items.pop_front().expect("front just observed");
                depth_dec(st, r.model.0);
                shared
                    .telemetry
                    .emit_worker(me, EventKind::Enqueued, r.model.0 as u32, 1, 0, 0, r.trace);
                b.push_arrived(r.submitted, r);
                admitted += 1;
            }
        }
        Dispatch::FairSteal => {
            // Read-only pre-scan: under a saturated burst the queue is
            // mostly one tenant's overflow with no batcher room, and
            // this runs under the hottest lock in the system — don't
            // pay the rotation's writes unless something will admit.
            let admissible = q.batchers.iter().any(|b| b.len() < b.max_batch())
                && st.items.iter().any(|r| {
                    let b = &q.batchers[r.model.0];
                    b.len() < b.max_batch()
                });
            if admissible {
                // One O(n) rotation: route each request into its
                // batcher if there's room, else re-queue it at the back
                // — processing in order and appending in order
                // preserves the queue's relative (per-model FIFO) order
                // for the skipped remainder. The pass must run to
                // completion: stopping mid-cycle would leave the queue
                // rotated and break per-model FIFO.
                let scan = st.items.len();
                for _ in 0..scan {
                    let r = st.items.pop_front().expect("count just observed");
                    let b = &mut q.batchers[r.model.0];
                    if b.len() >= b.max_batch() {
                        st.items.push_back(r);
                    } else {
                        depth_dec(st, r.model.0);
                        shared.telemetry.emit_worker(
                            me,
                            EventKind::Enqueued,
                            r.model.0 as u32,
                            1,
                            0,
                            0,
                            r.trace,
                        );
                        b.push_arrived(r.submitted, r);
                        admitted += 1;
                    }
                }
            }
        }
    }
    if admitted > 0 {
        shard.backlog.fetch_add(admitted, Ordering::Relaxed);
    }
    admitted > 0
}

/// Steal a due batch from a backlogged peer's shard, trying peers in
/// descending-backlog order (the index reads are lock-free atomics;
/// only probed shards are locked). A heavily backlogged peer whose
/// batches are all still coalescing must not mask a lighter peer with a
/// batch due *now* — the thief keeps probing until it finds due work or
/// runs out of backlogged peers. Within the victim shard the longest
/// due batcher is drained; an over-full backlog is *split* (the thief
/// takes ~half, [`steal_limit`]) and the leftover items keep their
/// arrival clocks. Slots the thief's registry snapshot doesn't know yet
/// (or whose engine is already retired) are skipped — the owner, whose
/// snapshot is necessarily current for anything it pulled, serves
/// those. Returns the model stolen, or `None` when no peer has a due
/// batch.
fn steal_batch(
    shared: &Shared,
    snap: &RegistrySnapshot,
    me: usize,
    flush: bool,
    batch: &mut Vec<GwRequest>,
) -> Option<usize> {
    // Victim preference order, allocation-free: the most backlogged
    // peer first (atomic reads only), then every other backlogged peer
    // in index order.
    let heaviest = shared
        .shards
        .iter()
        .enumerate()
        .filter(|&(i, _)| i != me)
        .map(|(i, s)| (i, s.backlog.load(Ordering::Relaxed)))
        .filter(|&(_, backlog)| backlog > 0)
        .max_by_key(|&(_, backlog)| backlog)
        .map(|(i, _)| i)?;
    if let Some(m) = try_steal_from(shared, snap, me, heaviest, flush, batch) {
        return Some(m);
    }
    for (i, shard) in shared.shards.iter().enumerate() {
        if i == me || i == heaviest || shard.backlog.load(Ordering::Relaxed) == 0 {
            continue;
        }
        if let Some(m) = try_steal_from(shared, snap, me, i, flush, batch) {
            return Some(m);
        }
    }
    None
}

/// Probe one victim shard: split-drain its longest due batcher (among
/// the slots this thief can serve) into `batch`, or `None` when nothing
/// in it is due.
fn try_steal_from(
    shared: &Shared,
    snap: &RegistrySnapshot,
    me: usize,
    victim: usize,
    flush: bool,
    batch: &mut Vec<GwRequest>,
) -> Option<usize> {
    let shard = &shared.shards[victim];
    let now_us = shared.clock.now_us();
    let mut q = shard.queues.lock().unwrap();
    let m = (0..q.batchers.len())
        .filter(|&i| {
            snap.tenants.get(i).map(|t| t.engine.is_some()).unwrap_or(false)
                && q.due(i, flush, now_us)
        })
        .max_by_key(|&i| q.batchers[i].len())?;
    let limit = steal_limit(q.batchers[m].len(), q.batchers[m].max_batch());
    let took = q.batchers[m].drain_upto(batch, limit);
    shard.backlog.fetch_sub(took, Ordering::Relaxed);
    shared
        .telemetry
        .emit_worker(me, EventKind::Stolen, m as u32, took as u32, victim as u64, 0, 0);
    Some(m)
}

/// Upper bound on how long an idle worker may sleep: the soonest
/// time-to-due across every batch it could serve — its own shard's
/// batchers always, plus any backlogged peer's under
/// [`Dispatch::FairSteal`] (it would steal those). `None` means nothing
/// is queued anywhere reachable; sleep until an admission signal.
fn wait_hint(shared: &Shared, me: usize) -> Option<Duration> {
    let now_us = shared.clock.now_us();
    let mut hint: Option<Duration> = None;
    for (i, shard) in shared.shards.iter().enumerate() {
        if i != me
            && (shared.dispatch != Dispatch::FairSteal
                || shard.backlog.load(Ordering::Relaxed) == 0)
        {
            continue;
        }
        if let Some(d) = shard.queues.lock().unwrap().soonest_due(now_us) {
            hint = Some(match hint {
                Some(h) => h.min(d),
                None => d,
            });
        }
    }
    hint
}

/// Account `answered` responses against the tenant's in-flight count
/// and, when a removal is draining, ping the waiting remover.
fn finish_answered(shared: &Shared, counters: &ModelCounters, answered: u64) {
    if answered == 0 {
        return;
    }
    counters.inflight.fetch_sub(answered, Ordering::SeqCst);
    if shared.draining.load(Ordering::SeqCst) {
        shared.drained.notify_all();
    }
}

/// Serve one single-model batch on the tenant's registry engine.
/// Deadline-lapsed requests are answered `DeadlineExceeded` before any
/// compute; survivors' rows are gathered straight into the scratch's
/// staging buffer and outputs scattered as slices into each request's
/// pooled, pre-sized response buffer — the gather/forward/scatter core
/// allocates nothing per request (the mpsc response send and latency
/// recording still do). `stolen` marks a batch taken from a peer's
/// shard; it is recorded in the serving worker's metrics cell for the
/// model, so steal traffic shows up per replica and per model.
#[allow(clippy::too_many_arguments)]
fn serve_batch(
    tenant: &Tenant,
    model: usize,
    me: usize,
    sim_array: &ArrayConfig,
    batch: &mut Vec<GwRequest>,
    live: &mut Vec<GwRequest>,
    scratch: &mut Scratch,
    shared: &Shared,
    stolen: bool,
) {
    let engine =
        tenant.engine.as_ref().expect("drain contract: a tenant with queued work keeps its engine");
    let counters = &*tenant.counters;
    let metrics = &tenant.cells[me];
    let in_dim = engine.in_dim();
    let out_dim = engine.out_dim();
    let serve_start_us = shared.clock.now_us();
    let mut answered = 0u64;
    live.clear();
    {
        let staging = scratch.stage_input(batch.len() * in_dim);
        for req in batch.drain(..) {
            match req.deadline {
                Some(d) if d <= serve_start_us => {
                    counters.expired.fetch_add(1, Ordering::Relaxed);
                    shared.telemetry.emit_worker(
                        me,
                        EventKind::Expired,
                        model as u32,
                        1,
                        0,
                        0,
                        req.trace,
                    );
                    tenant.buffers.release(req.out);
                    tenant.rows.release(req.x_q);
                    let _ = req.resp.send(Err(ServeError::DeadlineExceeded));
                    answered += 1;
                }
                _ => {
                    let mut req = req;
                    staging.extend_from_slice(&req.x_q);
                    // the row is copied into staging; hand the buffer
                    // back to the admission-side pool immediately so a
                    // steady-state submitter runs allocation-free
                    tenant.rows.release(std::mem::take(&mut req.x_q));
                    live.push(req);
                }
            }
        }
    }
    let bs = live.len();
    if bs == 0 {
        finish_answered(shared, counters, answered);
        return;
    }
    shared.telemetry.emit_worker(me, EventKind::ServeStart, model as u32, bs as u32, 0, 0, 0);
    for r in live.iter().filter(|r| r.trace != 0) {
        // rows==0 span echo (see the batch-formed echoes in the worker)
        shared.telemetry.emit_worker(me, EventKind::ServeStart, model as u32, 0, 0, 0, r.trace);
    }
    let result = engine.forward_staged(bs, scratch);
    let sim = engine.simulate_batch(sim_array, bs);
    shared.telemetry.emit_worker(
        me,
        EventKind::ServeEnd,
        model as u32,
        bs as u32,
        sim.useful_macs,
        sim.active_slots,
        0,
    );
    let mut m = metrics.lock().unwrap();
    m.record_batch_sim(bs, &sim);
    if stolen {
        m.record_steal();
    }
    match result {
        Ok(t) => {
            let service_us = shared.clock.now_us().saturating_sub(serve_start_us);
            let service = Duration::from_micros(service_us);
            for (i, mut req) in live.drain(..).enumerate() {
                let queue_us = serve_start_us.saturating_sub(req.submitted);
                m.record_request_split(Duration::from_micros(queue_us), service);
                counters.completed.fetch_add(1, Ordering::Relaxed);
                shared.telemetry.emit_worker(
                    me,
                    EventKind::Responded,
                    model as u32,
                    1,
                    queue_us,
                    service_us,
                    req.trace,
                );
                req.out.extend_from_slice(&t[i * out_dim..(i + 1) * out_dim]);
                let _ = req.resp.send(Ok(Response {
                    t: req.out,
                    queue_us,
                    service_us,
                    pool: Some(Arc::clone(&tenant.buffers)),
                }));
                answered += 1;
            }
        }
        Err(e) => {
            let msg = format!("inference failed: {e}");
            for req in live.drain(..) {
                counters.failed.fetch_add(1, Ordering::Relaxed);
                tenant.buffers.release(req.out);
                let _ = req.resp.send(Err(ServeError::Inference(msg.clone())));
                answered += 1;
            }
        }
    }
    drop(m);
    finish_answered(shared, counters, answered);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kan::QuantizedModel;

    fn two_model_gateway(replicas: usize, queue_cap: usize, shed: ShedPolicy) -> Gateway {
        let mut b = GatewayBuilder::with_config(GatewayConfig {
            replicas,
            queue_cap,
            shed,
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
            dispatch: Dispatch::FairSteal,
            quota: QuotaPolicy::None,
            telemetry: TelemetryConfig::default(),
            ..Default::default()
        });
        let ea = Engine::new(QuantizedModel::synthetic("a", &[4, 6, 3], 5, 3, 5));
        let eb = Engine::new(QuantizedModel::synthetic("b", &[6, 8, 5], 5, 3, 9));
        let a = b.register("alpha", ea);
        let c = b.register("beta", eb);
        assert_eq!(a, ModelId(0));
        assert_eq!(c, ModelId(1));
        b.start()
    }

    /// A worker-less `Shared` over a real registry snapshot: admission
    /// control in isolation, fully deterministic (no racing consumers).
    fn bare_shared(
        weights: &[u32],
        cap: usize,
        shed: ShedPolicy,
        quota: QuotaPolicy,
    ) -> Arc<Shared> {
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
        let tenants: Vec<Tenant> = weights
            .iter()
            .enumerate()
            .map(|(m, &w)| {
                let name = format!("m{m}");
                let e = Engine::new(QuantizedModel::synthetic(
                    &name,
                    &[4, 6, 3],
                    5,
                    3,
                    m as u64 + 1,
                ));
                Tenant::new(&name, e, w, policy, TenantDefaults::default(), cap, 0, false)
            })
            .collect();
        bare_from_tenants(tenants, cap, shed, quota)
    }

    /// Like [`bare_shared`] but over caller-built tenants (custom
    /// defaults, weights, policies).
    fn bare_from_tenants(
        tenants: Vec<Tenant>,
        cap: usize,
        shed: ShedPolicy,
        quota: QuotaPolicy,
    ) -> Arc<Shared> {
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
        let n = tenants.len();
        Arc::new(Shared {
            state: Mutex::new(GwState {
                registry: build_snapshot(1, tenants, cap, quota),
                items: VecDeque::new(),
                open: true,
                submitted: vec![0; n],
                shed: vec![0; n],
                depth: vec![0; n],
                overflow: 0,
                blocked: vec![0; n],
                peak_depth: 0,
            }),
            nonempty: Condvar::new(),
            drained: Condvar::new(),
            admin: Mutex::new(()),
            draining: AtomicBool::new(false),
            cap,
            shed_policy: shed,
            dispatch: Dispatch::FairSteal,
            quota,
            replicas: 0,
            default_policy: policy,
            shards: Vec::new(),
            telemetry: Arc::new(Telemetry::new(TelemetryConfig::off(), 0, &[])),
            clock: Clock::real(),
            sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
            fleet: Fleet {
                active: AtomicUsize::new(0),
                stopping: Vec::new(),
                handles: Mutex::new(Vec::new()),
                started_us: Vec::new(),
                busy_us: AtomicU64::new(0),
                ledger: Mutex::new(()),
                pin_cores: false,
                scale_lock: Mutex::new(()),
            },
        })
    }

    fn handles_of(shared: &Arc<Shared>) -> Vec<ModelHandle> {
        let reg = Arc::clone(&shared.state.lock().unwrap().registry);
        reg.tenants
            .iter()
            .enumerate()
            .map(|(m, t)| ModelHandle {
                shared: Arc::clone(shared),
                model: ModelId(m),
                name: Arc::clone(&t.name),
                in_dim: t.in_dim,
                out_dim: t.out_dim,
                rows: Arc::clone(&t.rows),
            })
            .collect()
    }

    fn bare_handles(n_models: usize, cap: usize, shed: ShedPolicy) -> Vec<ModelHandle> {
        let shared = bare_shared(&vec![1; n_models], cap, shed, QuotaPolicy::None);
        handles_of(&shared)
    }

    /// `(created, recycled, free)` of slot `m`'s buffer pool.
    fn tenant_buffers(h: &ModelHandle, m: usize) -> (u64, u64, usize) {
        let st = h.shared.state.lock().unwrap();
        st.registry.tenants[m].buffers.counts()
    }

    #[test]
    fn routes_and_counts_per_model() {
        let gw = two_model_gateway(2, 64, ShedPolicy::RejectNew);
        let ha = gw.handle(ModelId(0));
        let hb = gw.handle_by_name("beta").unwrap();
        assert_eq!(ha.name(), "alpha");
        assert_eq!(hb.in_dim(), 6);
        assert!(gw.handle_by_name("nope").is_err());
        for _ in 0..12 {
            let r = ha.infer_q(vec![1, 2, 3, 4]).unwrap();
            assert_eq!(r.t.len(), 3);
        }
        for _ in 0..7 {
            let r = hb.infer_q(vec![9, 8, 7, 6, 5, 4]).unwrap();
            assert_eq!(r.t.len(), 5);
            let _ = r.prediction();
        }
        let stats = gw.shutdown();
        assert_eq!(stats.per_model.len(), 2);
        let (a, b) = (&stats.per_model[0], &stats.per_model[1]);
        assert_eq!((a.submitted, a.completed, a.shed, a.failed), (12, 12, 0, 0));
        assert_eq!((b.submitted, b.completed, b.shed, b.failed), (7, 7, 0, 0));
        assert!(a.conserved() && b.conserved());
        assert!(a.live && b.live);
        assert_eq!(a.metrics.batch_rows, 12);
        assert_eq!(b.metrics.batch_rows, 7);
        assert_eq!(stats.merged.batch_rows, 19);
        assert_eq!(stats.per_replica.len(), 2);
        let per_replica_rows: u64 = stats.per_replica.iter().map(|m| m.batch_rows).sum();
        assert_eq!(per_replica_rows, 19);
        assert!(stats.conserved());
        assert_eq!(stats.submitted(), 19);
        assert_eq!(stats.epoch, 1, "no churn: the start snapshot");
    }

    #[test]
    fn wrong_model_dim_rejected_before_admission() {
        let gw = two_model_gateway(1, 8, ShedPolicy::RejectNew);
        // a row sized for beta must not pass alpha's validation
        let err = gw.handle(ModelId(0)).infer_q(vec![1, 2, 3, 4, 5, 6]).unwrap_err();
        assert!(matches!(err, ServeError::InvalidInput(_)));
        let stats = gw.shutdown();
        assert_eq!(stats.submitted(), 0);
    }

    #[test]
    fn closed_gateway_rejects_submissions() {
        let gw = two_model_gateway(1, 8, ShedPolicy::RejectNew);
        let h = gw.handle(ModelId(0));
        let stats = gw.shutdown();
        assert_eq!(stats.submitted(), 0);
        assert_eq!(h.infer_q(vec![1, 2, 3, 4]).unwrap_err(), ServeError::Closed);
    }

    #[test]
    fn reject_new_sheds_at_capacity() {
        let hs = bare_handles(2, 2, ShedPolicy::RejectNew);
        let _t1 = hs[0].submit_q(vec![1, 1, 1, 1]).unwrap();
        let _t2 = hs[1].submit_q(vec![2, 2, 2, 2]).unwrap();
        assert_eq!(hs[0].queue_depth(), 2);
        assert_eq!(hs[0].submit_q(vec![3, 3, 3, 3]).unwrap_err(), ServeError::QueueFull);
        assert_eq!(hs[0].queue_depth(), 2, "rejected arrival never enters the queue");
        let st = hs[0].shared.state.lock().unwrap();
        assert_eq!(st.submitted, vec![2, 1]);
        assert_eq!(st.shed, vec![1, 0]);
        assert_eq!(st.depth, vec![1, 1], "rejected arrivals don't count toward depth");
        assert_eq!(st.peak_depth, 2);
    }

    #[test]
    fn drop_oldest_evicts_stalest_and_admits() {
        let hs = bare_handles(2, 2, ShedPolicy::DropOldest);
        let t1 = hs[0].submit_q(vec![1, 1, 1, 1]).unwrap();
        let t2 = hs[1].submit_q(vec![2, 2, 2, 2]).unwrap();
        // queue full: #3 evicts #1, #4 evicts #2 — the newcomer always
        // wins among equals, and the shed is charged to the VICTIM's model
        let t3 = hs[0].submit_q(vec![3, 3, 3, 3]).unwrap();
        assert_eq!(t1.wait(), Err(ServeError::QueueFull), "oldest answered on eviction");
        let t4 = hs[0].submit_q(vec![4, 4, 4, 4]).unwrap();
        assert_eq!(t2.wait(), Err(ServeError::QueueFull));
        assert_eq!(hs[0].queue_depth(), 2);
        assert!(t3.try_wait().is_none(), "survivors stay in flight");
        assert!(t4.try_wait().is_none());
        let st = hs[0].shared.state.lock().unwrap();
        assert_eq!(st.submitted, vec![3, 1]);
        assert_eq!(st.shed, vec![1, 1], "each model shed its own evicted request");
        assert_eq!(st.depth, vec![2, 0]);
        drop(st);
        // eviction must recycle the victim's buffer, not drop it: #3's
        // acquire reuses #1's released buffer (model 0); #2's buffer sits
        // on model 1's free-list
        let (c0, r0, f0) = tenant_buffers(&hs[0], 0);
        assert_eq!((c0, r0, f0), (2, 1, 0), "evicted model-0 buffer was reacquired");
        let (c1, _r1, f1) = tenant_buffers(&hs[0], 1);
        assert_eq!((c1, f1), (1, 1), "evicted model-1 buffer returned to its free-list");
    }

    #[test]
    fn drop_oldest_evicts_lowest_priority_first() {
        let hs = bare_handles(1, 2, ShedPolicy::DropOldest);
        let h = &hs[0];
        let t_high = h.submit(Request::from_q(vec![1; 4]).with_priority(Priority::High)).unwrap();
        let t_low = h.submit(Request::from_q(vec![2; 4]).with_priority(Priority::Low)).unwrap();
        // normal newcomer: the LOW request is the victim even though the
        // high one is older
        let t_norm = h.submit(Request::from_q(vec![3; 4])).unwrap();
        assert_eq!(t_low.wait(), Err(ServeError::QueueFull));
        assert!(t_high.try_wait().is_none(), "higher class survives eviction");
        assert!(t_norm.try_wait().is_none());
        // a LOW newcomer against a {High, Normal} queue sheds itself
        let err =
            h.submit(Request::from_q(vec![4; 4]).with_priority(Priority::Low)).unwrap_err();
        assert_eq!(err, ServeError::QueueFull);
        assert_eq!(h.queue_depth(), 2, "queue untouched by the self-shed newcomer");
        assert!(t_high.try_wait().is_none());
    }

    #[test]
    fn priority_orders() {
        assert!(Priority::Low < Priority::Normal && Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
    }

    #[test]
    fn quota_reserves_slots_for_idle_tenant() {
        // cap 8, reserve 0.5, equal weights: 2 slots each + 4 overflow
        let shared = bare_shared(&[1, 1], 8, ShedPolicy::RejectNew, QuotaPolicy::weighted());
        let hs = handles_of(&shared);
        {
            let st = shared.state.lock().unwrap();
            let reserved: Vec<usize> = st.registry.tenants.iter().map(|t| t.reserved).collect();
            assert_eq!(reserved, vec![2, 2]);
            assert_eq!(st.registry.overflow_cap, 4);
        }
        // tenant 0's burst takes its reserve plus the whole overflow…
        let _burst: Vec<Ticket> =
            (0..6u8).map(|i| hs[0].submit_q(vec![i; 4]).unwrap()).collect();
        assert_eq!(hs[0].submit_q(vec![9; 4]).unwrap_err(), ServeError::QueueFull);
        // …but cannot touch tenant 1's reserved slots
        let _k1 = hs[1].submit_q(vec![1; 4]).unwrap();
        let _k2 = hs[1].submit_q(vec![2; 4]).unwrap();
        // now the queue really is at capacity for everyone
        assert_eq!(hs[1].submit_q(vec![3; 4]).unwrap_err(), ServeError::QueueFull);
        let st = shared.state.lock().unwrap();
        assert_eq!(st.depth, vec![6, 2]);
        assert_eq!(st.shed, vec![1, 1]);
        assert_eq!(st.overflow, 4, "t0's 4 overflow slots");
        assert_eq!(overflow_scan(&st), st.overflow, "cache matches a full recount");
    }

    #[test]
    fn quota_drop_oldest_evicts_saturated_tenant_first() {
        let shared = bare_shared(&[1, 1], 8, ShedPolicy::DropOldest, QuotaPolicy::weighted());
        let hs = handles_of(&shared);
        // t0 floods its reserve + the overflow; t1 fills its own reserve
        let burst: Vec<Ticket> =
            (0..6u8).map(|i| hs[0].submit_q(vec![i; 4]).unwrap()).collect();
        let k1 = hs[1].submit_q(vec![10; 4]).unwrap();
        let _k2 = hs[1].submit_q(vec![11; 4]).unwrap();
        // full queue: t1's newcomer evicts the OVERSUBSCRIBED tenant's
        // oldest request — the burster pays, not the victim of the burst
        let _k3 = hs[1].submit_q(vec![12; 4]).unwrap();
        assert!(matches!(burst[0].try_wait(), Some(Err(ServeError::QueueFull))));
        assert!(k1.try_wait().is_none(), "t1's own queue entries survive");
        let st = shared.state.lock().unwrap();
        assert_eq!(st.shed, vec![1, 0], "the shed is charged to the saturated tenant");
        assert_eq!(st.depth, vec![5, 3]);
    }

    #[test]
    fn quota_reservation_math_tracks_weights_and_liveness() {
        let policy = BatchPolicy::default();
        let mk = |name: &str, w: u32, seed: u64| {
            let e = Engine::new(QuantizedModel::synthetic(name, &[4, 6, 3], 5, 3, seed));
            Tenant::new(name, e, w, policy, TenantDefaults::default(), 16, 0, false)
        };
        let mut tenants = vec![mk("a", 3, 1), mk("b", 1, 2)];
        let overflow = apply_quota(&mut tenants, 16, QuotaPolicy::Weighted { reserve: 0.5 });
        assert_eq!(
            (tenants[0].reserved, tenants[1].reserved, overflow),
            (6, 2, 8),
            "budget 8 split 3:1"
        );
        // a draining tenant's reservation redistributes to the survivors
        tenants[0].accepting = false;
        let overflow = apply_quota(&mut tenants, 16, QuotaPolicy::Weighted { reserve: 0.5 });
        assert_eq!((tenants[0].reserved, tenants[1].reserved, overflow), (0, 8, 8));
        // quota off: everything is overflow
        let overflow = apply_quota(&mut tenants, 16, QuotaPolicy::None);
        assert_eq!((tenants[0].reserved, tenants[1].reserved, overflow), (0, 0, 16));
    }

    /// A request shell for exercising the dispatch machinery without a
    /// running fleet (the response channel's receiver is dropped, so
    /// sends are harmless no-ops).
    fn dummy_req(m: usize) -> GwRequest {
        let (tx, _rx) = channel();
        GwRequest {
            model: ModelId(m),
            x_q: Vec::new(),
            out: Vec::new(),
            submitted: 0,
            deadline: None,
            priority: Priority::Normal,
            resp: tx,
            trace: 0,
        }
    }

    /// Virtual "now" far past every test arrival stamp (60s in µs) —
    /// the dispatch tests run in pure virtual time, no clock reads.
    const LATER_US: u64 = 60_000_000;

    #[test]
    fn drr_dispatch_tracks_weights_under_saturation() {
        // two tenants kept saturated (batchers refilled after every
        // dispatch): rows served must track the 4:1 weights
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) };
        let mut q = ShardQueues::new(2, policy);
        let weights = [4u32, 1];
        let mut rows = [0usize; 2];
        let mut out = Vec::new();
        for _ in 0..100 {
            for m in 0..2 {
                while q.batchers[m].len() < policy.max_batch {
                    q.batchers[m].push_arrived(0, dummy_req(m));
                }
            }
            let pick = q.next_drr(&weights, false, LATER_US).expect("both tenants due");
            rows[pick] += q.batchers[pick].drain_into(&mut out);
        }
        assert_eq!(rows[0] + rows[1], 400, "every dispatch drains a full batch");
        let ratio = rows[0] as f64 / rows[1] as f64;
        assert!((3.0..=5.0).contains(&ratio), "rows {rows:?} — want ~4:1, got {ratio:.2}");
    }

    #[test]
    fn drr_starved_high_weight_tenant_overtakes() {
        // cursor parked past tenant 1; a lone due item of the
        // high-weight tenant must still be dispatched before the
        // saturated low-weight tenant
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) };
        let mut q = ShardQueues::new(2, policy);
        let weights = [1u32, 8];
        for _ in 0..4 {
            q.batchers[0].push_arrived(0, dummy_req(0));
        }
        q.batchers[1].push_arrived(0, dummy_req(1));
        let pick = q.next_drr(&weights, false, LATER_US);
        assert_eq!(pick, Some(1), "starved weight-8 tenant beats the saturated weight-1 one");
    }

    #[test]
    fn drr_single_tenant_is_work_conserving() {
        // a weight-1 tenant alone must be dispatched even though its
        // batch cost exceeds one round's quantum (credit accrues over
        // rounds within the pick)
        let policy = BatchPolicy { max_batch: 32, max_wait: Duration::from_secs(10) };
        let mut q = ShardQueues::new(3, policy);
        let weights = [1u32, 1, 1];
        for _ in 0..32 {
            q.batchers[2].push_arrived(0, dummy_req(2));
        }
        assert_eq!(q.next_drr(&weights, false, LATER_US), Some(2));
        let mut out = Vec::new();
        q.batchers[2].drain_into(&mut out);
        assert_eq!(q.next_drr(&weights, false, LATER_US), None, "nothing due");
        // a fresh arrival is not due within its window without flush,
        // but is on flush
        q.batchers[0].push_arrived(LATER_US, dummy_req(0));
        assert_eq!(q.next_drr(&weights, false, LATER_US), None);
        assert_eq!(q.next_drr(&weights, true, LATER_US), Some(0));
    }

    #[test]
    fn steal_limit_splits_overfull_backlogs() {
        assert_eq!(steal_limit(5, 8), 5, "a one-batch backlog is taken whole");
        assert_eq!(steal_limit(8, 8), 8);
        assert_eq!(steal_limit(12, 8), 6, "over-full: the thief takes half");
        assert_eq!(steal_limit(13, 8), 7, "odd halves round up");
        assert_eq!(steal_limit(40, 8), 8, "half is still capped at one batch");
        assert_eq!(steal_limit(0, 8), 0);
    }

    #[test]
    fn split_steal_leaves_arrival_clocks_intact() {
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(40) };
        let mut b: Batcher<GwRequest> = Batcher::new(policy);
        // arrivals 200ms before the thief's now, 1ms apart
        for i in 0..12u64 {
            b.push_arrived(i * 1_000, dummy_req(0));
        }
        let now_us = 200_000 + 11_000;
        let mut out = Vec::new();
        let took = b.drain_upto(&mut out, steal_limit(b.len(), b.max_batch()));
        assert_eq!(took, 6, "12 queued, cap 8: the thief takes half");
        assert_eq!(b.len(), 6);
        assert!(b.ready(now_us), "leftover items keep their (long past) arrival clocks");
        assert_eq!(b.time_left(now_us), Duration::ZERO);
    }

    #[test]
    fn draining_tenants_are_expedited() {
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_secs(60) };
        let e = Engine::new(QuantizedModel::synthetic("d", &[4, 6, 3], 5, 3, 5));
        let mut t = Tenant::new("d", e, 1, policy, TenantDefaults::default(), 8, 0, false);
        t.accepting = false;
        let reg = build_snapshot(2, vec![t], 8, QuotaPolicy::None);
        let mut q = ShardQueues::empty();
        q.grow(&reg);
        q.batchers[0].push_arrived(0, dummy_req(0));
        assert!(!q.batchers[0].ready(0), "a 60s window is not due on its own");
        assert!(q.due(0, false, 0), "draining tenant batches are expedited");
        assert_eq!(q.soonest_due(0), Some(Duration::ZERO));
        assert_eq!(q.next_drr(&[1], false, 0), Some(0));
    }

    #[test]
    fn registry_control_plane_validates() {
        let gw = two_model_gateway(1, 16, ShedPolicy::RejectNew);
        assert_eq!(gw.registry_epoch(), 1);
        assert_eq!(gw.n_models(), 2);
        // duplicate live name rejected
        let e = Engine::new(QuantizedModel::synthetic("alpha", &[4, 6, 3], 5, 3, 3));
        assert!(matches!(gw.add_model("alpha", e), Err(ServeError::InvalidInput(_))));
        // zero weight rejected
        let e = Engine::new(QuantizedModel::synthetic("z", &[4, 6, 3], 5, 3, 3));
        assert!(matches!(gw.add_model_weighted("z", e, 0), Err(ServeError::InvalidInput(_))));
        // set_weight validation
        assert!(matches!(gw.set_weight(ModelId(9), 2), Err(ServeError::UnknownModel(_))));
        assert!(matches!(gw.set_weight(ModelId(0), 0), Err(ServeError::InvalidInput(_))));
        // live re-weight bumps the epoch and surfaces in stats
        gw.set_weight(ModelId(0), 7).unwrap();
        assert_eq!(gw.stats().per_model[0].weight, 7);
        assert_eq!(gw.registry_epoch(), 2);
        // remove, then double-remove errors
        let removed = gw.remove_model(ModelId(0), DrainMode::Serve).unwrap();
        assert!(removed.conserved() && !removed.live);
        assert!(matches!(
            gw.remove_model(ModelId(0), DrainMode::Serve),
            Err(ServeError::UnknownModel(_))
        ));
        assert_eq!(gw.n_models(), 1);
        assert!(gw.handle_by_name("alpha").is_err());
        // the name is reusable after removal; the slot is not
        let e = Engine::new(QuantizedModel::synthetic("alpha", &[4, 6, 3], 5, 3, 4));
        let h = gw.add_model("alpha", e).unwrap();
        assert_eq!(h.model_id().index(), 2, "slots are never reused");
        assert_eq!(h.infer_q(vec![1, 2, 3, 4]).unwrap().t.len(), 3);
        let stats = gw.shutdown();
        assert!(stats.conserved());
        assert_eq!(stats.per_model.len(), 3, "removed tenants keep their stats row");
        assert!(!stats.per_model[0].live && stats.per_model[2].live);
    }

    #[test]
    fn fixed_dispatch_still_serves_and_conserves() {
        let mut b = GatewayBuilder::with_config(GatewayConfig {
            replicas: 2,
            queue_cap: 64,
            shed: ShedPolicy::Block,
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
            dispatch: Dispatch::Fixed,
            quota: QuotaPolicy::None,
            telemetry: TelemetryConfig::default(),
            ..Default::default()
        });
        let ea = Engine::new(QuantizedModel::synthetic("a", &[4, 6, 3], 5, 3, 5));
        let eb = Engine::new(QuantizedModel::synthetic("b", &[6, 8, 5], 5, 3, 9));
        let a = b.register("alpha", ea);
        let c = b.register("beta", eb);
        let gw = b.start();
        for i in 0..20u8 {
            assert_eq!(gw.handle(a).infer_q(vec![i; 4]).unwrap().t.len(), 3);
            assert_eq!(gw.handle(c).infer_q(vec![i; 6]).unwrap().t.len(), 5);
        }
        let stats = gw.shutdown();
        assert!(stats.conserved());
        assert_eq!(stats.completed(), 40);
        assert_eq!(stats.stolen_batches(), 0, "fixed dispatch never steals");
    }

    #[test]
    fn weights_surface_in_stats_and_fairness_index() {
        let mut b = GatewayBuilder::with_config(GatewayConfig {
            replicas: 1,
            queue_cap: 16,
            shed: ShedPolicy::Block,
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
            dispatch: Dispatch::FairSteal,
            quota: QuotaPolicy::None,
            telemetry: TelemetryConfig::default(),
            ..Default::default()
        });
        let ea = Engine::new(QuantizedModel::synthetic("a", &[4, 6, 3], 5, 3, 5));
        let eb = Engine::new(QuantizedModel::synthetic("b", &[6, 8, 5], 5, 3, 9));
        let a = b.register("alpha", ea);
        let _ = b.register_weighted("beta", eb, 5);
        let gw = b.start();
        gw.handle(a).infer_q(vec![1, 2, 3, 4]).unwrap();
        let stats = gw.shutdown();
        assert_eq!(stats.per_model[0].weight, 1);
        assert_eq!(stats.per_model[1].weight, 5);
        // only alpha submitted, so the index covers alpha alone: fair
        assert!((stats.fairness_index() - 1.0).abs() < 1e-9);
        // alpha's demand was fully served: the normalized index agrees
        assert!((stats.fairness_index_normalized() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn per_tenant_batch_policy_is_honored() {
        // beta registers a 1-row policy: every beta batch is a single
        // row even while alpha coalesces, and both conserve
        let mut b = GatewayBuilder::with_config(GatewayConfig {
            replicas: 1,
            queue_cap: 64,
            shed: ShedPolicy::Block,
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
            dispatch: Dispatch::FairSteal,
            quota: QuotaPolicy::None,
            telemetry: TelemetryConfig::default(),
            ..Default::default()
        });
        let ea = Engine::new(QuantizedModel::synthetic("a", &[4, 6, 3], 5, 3, 5));
        let eb = Engine::new(QuantizedModel::synthetic("b", &[6, 8, 5], 5, 3, 9));
        let a = b.register("alpha", ea);
        let c = b.register_with_policy(
            "beta",
            eb,
            1,
            BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
        );
        let gw = b.start();
        for i in 0..10u8 {
            assert_eq!(gw.handle(a).infer_q(vec![i; 4]).unwrap().t.len(), 3);
            assert_eq!(gw.handle(c).infer_q(vec![i; 6]).unwrap().t.len(), 5);
        }
        let stats = gw.shutdown();
        assert!(stats.conserved());
        let beta = &stats.per_model[c.index()];
        assert_eq!(beta.metrics.batch_rows, 10);
        assert_eq!(beta.metrics.batches, 10, "1-row policy: one batch per request");
    }

    #[test]
    fn expired_deadline_resolves_and_counts_as_shed() {
        let gw = two_model_gateway(1, 64, ShedPolicy::RejectNew);
        let h = gw.handle(ModelId(0));
        // an already-lapsed deadline: the worker must answer (not hang)
        // with DeadlineExceeded before spending compute
        let t = h.submit(Request::from_q(vec![1, 2, 3, 4]).with_deadline(Duration::ZERO)).unwrap();
        assert_eq!(t.wait(), Err(ServeError::DeadlineExceeded));
        // generous deadline: served normally
        let r = h
            .submit(Request::from_q(vec![1, 2, 3, 4]).with_deadline(Duration::from_secs(60)))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.t.len(), 3);
        let stats = gw.shutdown();
        let a = &stats.per_model[0];
        assert_eq!(a.submitted, 2);
        assert_eq!(a.completed, 1);
        assert_eq!(a.expired, 1);
        assert_eq!(a.shed, 1, "expired requests count inside shed");
        assert!(a.conserved());
    }

    #[test]
    fn responses_carry_split_latency() {
        let gw = two_model_gateway(1, 16, ShedPolicy::Block);
        let h = gw.handle(ModelId(1));
        let r = h.infer_q(vec![0, 50, 100, 150, 200, 250]).unwrap();
        assert_eq!(r.latency_us(), r.queue_us + r.service_us);
        let clone = r.clone();
        assert_eq!(clone.t, r.t);
        drop(r);
        drop(clone);
        gw.shutdown();
    }

    #[test]
    fn buffer_pool_recycles() {
        let pool = BufferPool::new(4, 8);
        let a = pool.acquire();
        assert!(a.capacity() >= 4);
        pool.release(a);
        let b = pool.acquire();
        let (created, recycled, free) = pool.counts();
        assert_eq!((created, recycled, free), (1, 1, 0));
        pool.release(b);
        // oversized strays are dropped, not retained
        pool.release(Vec::with_capacity(1024));
        let (_, _, free) = pool.counts();
        assert_eq!(free, 1);
        // undersized strays too
        pool.release(Vec::new());
        let (_, _, free) = pool.counts();
        assert_eq!(free, 1);
        // retirement empties the list and stops recycling late releases
        pool.retire();
        let (_, _, free) = pool.counts();
        assert_eq!(free, 0, "retire clears the free-list");
        pool.release(Vec::with_capacity(4));
        let (_, _, free) = pool.counts();
        assert_eq!(free, 0, "a retired pool never re-pins buffers");
    }

    #[test]
    fn response_drop_returns_buffer_to_pool() {
        let gw = two_model_gateway(1, 16, ShedPolicy::Block);
        let h = gw.handle(ModelId(0));
        for _ in 0..20 {
            let r = h.infer_q(vec![5, 6, 7, 8]).unwrap();
            drop(r); // recycle before the next submit
        }
        let stats = gw.shutdown();
        let a = &stats.per_model[0];
        assert_eq!(a.completed, 20);
        assert!(
            a.buffers_created <= 2,
            "serial traffic needs at most a couple of live buffers, created {}",
            a.buffers_created
        );
        assert!(a.buffers_recycled >= 18, "recycled only {}", a.buffers_recycled);
    }

    #[test]
    fn batches_never_mix_models() {
        // one replica, both models loaded concurrently: every batch must
        // be single-model (otherwise dims would mismatch and inference
        // would fail — completed counts prove correctness)
        let gw = two_model_gateway(1, 256, ShedPolicy::Block);
        let ha = gw.handle(ModelId(0));
        let hb = gw.handle(ModelId(1));
        let mut tickets = Vec::new();
        for i in 0..40u8 {
            tickets.push((3usize, ha.submit_q(vec![i, i, i, i]).unwrap()));
            tickets.push((5usize, hb.submit_q(vec![i, i, i, i, i, i]).unwrap()));
        }
        for (want_dim, t) in tickets {
            assert_eq!(t.wait().unwrap().t.len(), want_dim);
        }
        let stats = gw.shutdown();
        assert_eq!(stats.per_model[0].completed, 40);
        assert_eq!(stats.per_model[1].completed, 40);
        assert_eq!(stats.per_model[0].failed + stats.per_model[1].failed, 0);
    }

    #[test]
    fn registry_defaults_apply_when_request_is_bare() {
        // the tenant registers with an already-lapsed default deadline:
        // a BARE request inherits it and expires, while an explicit
        // per-request deadline overrides the registry default and serves
        let mut b = GatewayBuilder::with_config(GatewayConfig {
            replicas: 1,
            ..Default::default()
        });
        let e = Engine::new(QuantizedModel::synthetic("d", &[4, 6, 3], 5, 3, 7));
        let id = b.register_with_defaults(
            "deadliner",
            e,
            1,
            TenantDefaults::with_deadline(Duration::ZERO),
        );
        let gw = b.start();
        let h = gw.handle(id);
        assert_eq!(h.infer_q(vec![1, 2, 3, 4]), Err(ServeError::DeadlineExceeded));
        let r = h
            .submit(Request::from_q(vec![1, 2, 3, 4]).with_deadline(Duration::from_secs(60)))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.t.len(), 3, "explicit deadline overrides the registry default");
        let stats = gw.shutdown();
        let d = &stats.per_model[0];
        assert_eq!((d.submitted, d.completed, d.expired), (2, 1, 1));
        assert!(d.conserved());
    }

    #[test]
    fn default_priority_orders_eviction() {
        // tenant 0's registry default is Low: its BARE requests are
        // evicted ahead of tenant 1's (default Normal), even when newer
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
        let mk = |m: usize, defaults: TenantDefaults| {
            let name = format!("m{m}");
            let e =
                Engine::new(QuantizedModel::synthetic(&name, &[4, 6, 3], 5, 3, m as u64 + 1));
            Tenant::new(&name, e, 1, policy, defaults, 2, 0, false)
        };
        let tenants = vec![
            mk(0, TenantDefaults::with_priority(Priority::Low)),
            mk(1, TenantDefaults::default()),
        ];
        let shared =
            bare_from_tenants(tenants, 2, ShedPolicy::DropOldest, QuotaPolicy::None);
        let hs = handles_of(&shared);
        let t_norm = hs[1].submit_q(vec![1; 4]).unwrap();
        let t_bulk = hs[0].submit_q(vec![2; 4]).unwrap();
        // a Normal newcomer: the default-Low request is the victim even
        // though the Normal one is older
        let t_new = hs[1].submit_q(vec![3; 4]).unwrap();
        assert_eq!(t_bulk.wait(), Err(ServeError::QueueFull));
        assert!(t_norm.try_wait().is_none(), "default-Normal survives");
        assert!(t_new.try_wait().is_none());
        let st = shared.state.lock().unwrap();
        assert_eq!(st.shed, vec![1, 0], "the shed charged to the default-Low tenant");
    }

    #[test]
    fn block_wake_is_quota_aware() {
        use std::sync::atomic::AtomicBool as Flag;
        // cap 8, reserve 0.5, equal weights: 2 reserved each + 4 overflow
        let shared = bare_shared(&[1, 1], 8, ShedPolicy::Block, QuotaPolicy::weighted());
        let hs = handles_of(&shared);
        // t0 fills its reserve + the whole overflow; t1 fills its reserve
        let _burst: Vec<Ticket> =
            (0..6u8).map(|i| hs[0].submit_q(vec![i; 4]).unwrap()).collect();
        let k1 = hs[1].submit_q(vec![1; 4]).unwrap();
        let _k2 = hs[1].submit_q(vec![2; 4]).unwrap();
        // both tenants are now inadmissible: park one submitter each
        let done0 = Arc::new(Flag::new(false));
        let done1 = Arc::new(Flag::new(false));
        let spawn_blocked = |h: ModelHandle, done: Arc<Flag>| {
            std::thread::spawn(move || {
                let r = h.submit_q(vec![9; 4]);
                done.store(true, Ordering::SeqCst);
                r
            })
        };
        let j0 = spawn_blocked(hs[0].clone(), Arc::clone(&done0));
        let j1 = spawn_blocked(hs[1].clone(), Arc::clone(&done1));
        // wait until both are parked on their tenants' condvars
        loop {
            let st = shared.state.lock().unwrap();
            if st.blocked.iter().sum::<usize>() == 2 {
                break;
            }
            drop(st);
            std::thread::yield_now();
        }
        // free ONE of t1's slots by hand (no workers in a bare Shared)
        // and wake: only t1's submitter can make progress — t0 is still
        // over reserve with a full overflow region
        {
            let mut st = shared.state.lock().unwrap();
            let idx = st
                .items
                .iter()
                .position(|r| r.model == ModelId(1))
                .expect("t1 has queued items");
            let old = st.items.remove(idx).unwrap();
            depth_dec(&mut st, 1);
            let t1 = &st.registry.tenants[1];
            t1.counters.inflight.fetch_sub(1, Ordering::SeqCst);
            t1.buffers.release(old.out);
            let _ = old.resp.send(Err(ServeError::QueueFull));
            wake_space(&shared, &st);
        }
        let t1_ticket = j1.join().unwrap().expect("t1's blocked submitter admits");
        assert!(done1.load(Ordering::SeqCst));
        // t0's submitter must still be parked: its tenant gained nothing
        std::thread::sleep(Duration::from_millis(50));
        assert!(
            !done0.load(Ordering::SeqCst),
            "t0 woke without reservation headroom (FIFO wake, not quota-aware)"
        );
        {
            let st = shared.state.lock().unwrap();
            assert_eq!(st.blocked, vec![1, 0]);
            assert_eq!(st.depth, vec![6, 2]);
        }
        // closing the gateway must wake the parked t0 submitter to an
        // orderly Closed error
        {
            let mut st = shared.state.lock().unwrap();
            st.open = false;
            wake_space(&shared, &st);
        }
        assert_eq!(j0.join().unwrap(), Err(ServeError::Closed));
        drop(t1_ticket);
        drop(k1);
    }
}
