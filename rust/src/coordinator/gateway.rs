//! Multi-tenant serving gateway: one typed front door for many models
//! over one replica fleet.
//!
//! The paper evaluates KAN-SAs across a *mix* of applications (Fig. 8:
//! MNIST, CIFAR, HAR, …) time-sharing one accelerator; the [`Gateway`]
//! is that picture at the serving tier. A [`GatewayBuilder`] registers N
//! models ([`GatewayBuilder::register`] → [`ModelId`]); the started
//! gateway shares **one bounded admission queue and one worker fleet**
//! across all of them, routing each admitted request to its model's
//! compiled [`ExecutionPlan`](crate::kan::ExecutionPlan):
//!
//! * every worker owns engine replicas for *all* registered models
//!   (clones alias the originals' weights through `Arc`, so the fleet
//!   costs ~1x total model memory) and **one**
//!   [`Scratch`](crate::kan::Scratch) arena sized to the widest model;
//! * each worker runs **per-model batchers**, so a served batch is never
//!   mixed-model — exactly like the accelerator, which must reconfigure
//!   LUT ROMs and N:M windows between applications;
//! * admission control is shared: one queue capacity, one
//!   [`ShedPolicy`], with [`Priority`] classes ordering
//!   [`ShedPolicy::DropOldest`] eviction (low-priority victims first).
//!
//! Dispatch is **weighted and work-conserving** ([`Dispatch`], default
//! [`Dispatch::FairSteal`]). Each model registers with a service weight
//! ([`GatewayBuilder::register_weighted`]); per-model batchers live in
//! per-worker *shards* that the whole fleet can reach:
//!
//! * a worker picks its next batch by **deficit round-robin** over its
//!   shard's due batchers — every round a tenant earns credit in
//!   proportion to its weight and pays in rows served, so a starved
//!   high-weight tenant is served before a saturated low-weight one, and
//!   a lone tenant still gets the whole machine (work conservation);
//! * pulls from the shared admission queue **skip past** head-of-line
//!   requests whose batcher is already full, so a saturated tenant's
//!   burst cannot wall off the *dispatch* of other tenants' already
//!   admitted requests (per-model FIFO order is preserved — only
//!   *other* models' requests are overtaken). Admission capacity
//!   itself stays shared: a burst that fills the bounded queue still
//!   sheds everyone's new arrivals per [`ShedPolicy`] — per-tenant
//!   admission quotas are future work (see ROADMAP);
//! * a worker with nothing due **steals** a ready batch from the most
//!   backlogged peer's shard instead of sleeping (the per-shard backlog
//!   index is atomic, so victim selection takes no locks). Every worker
//!   holds replicas of every model, which is what makes a stolen batch
//!   servable anywhere; steals are counted per model and per replica
//!   ([`Metrics::stolen_batches`]).
//!
//! [`Dispatch::Fixed`] keeps the pre-fair behaviour (strict FIFO pulls
//! that stop at a full batcher, model-index serve order, idle workers
//! sleep) as the measured baseline for the fairness sweep in the
//! `serving_scale` bench.
//!
//! The client surface is typed end to end: [`ModelHandle`] submits a
//! [`Request`] (quantized or f32 row, optional deadline, priority) and
//! gets a [`Ticket`]; every terminal outcome is a [`ServeError`] — one
//! enum for the whole serving stack, replacing the old
//! `PoolError`-vs-`anyhow` split. [`GatewayStats`] breaks the counters
//! down per model *and* per replica, with the conservation invariant
//! held **per model**: `submitted == completed + shed + failed`
//! (deadline-lapsed requests are answered
//! [`ServeError::DeadlineExceeded`] and counted inside `shed`, reported
//! separately as `expired`). The invariant is indifferent to *which*
//! worker served a batch, so it holds across steals — including batches
//! stolen during the shutdown flush (integration-tested).
//!
//! Response buffers are pooled: each answered request's pre-sized
//! `Vec<i64>` returns to a per-model free-list ([`BufferPool`]) when the
//! [`Response`] drops, so steady-state submission pays no buffer
//! allocation (asserted by `tests/gateway_alloc.rs` with a counting
//! allocator).
//!
//! `coordinator::pool::Pool` is the 1-model special case of the gateway
//! and `coordinator::server::Server` the 1-model/1-replica one.

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::arch::ArrayConfig;
use crate::kan::{Engine, Scratch};

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::{jain_fairness, Metrics};

/// What to do with a new submission when the admission queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Reject the new arrival with [`ServeError::QueueFull`].
    RejectNew,
    /// Evict a queued request — the oldest among the *lowest*
    /// [`Priority`] class present — answer it `QueueFull`, and admit the
    /// newcomer. A newcomer whose priority is below everything queued is
    /// itself rejected (eviction never sacrifices a higher class).
    DropOldest,
    /// Block the submitting thread until a worker frees space.
    Block,
}

/// Request priority class. Only [`ShedPolicy::DropOldest`] eviction
/// looks at it (victims are chosen lowest-class-first, oldest within the
/// class); dispatch order within the queue stays FIFO.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// First to be evicted (bulk / best-effort traffic).
    Low,
    /// The default class.
    #[default]
    Normal,
    /// Last to be evicted (interactive traffic).
    High,
}

/// How fleet workers pick the next batch to serve.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Dispatch {
    /// Weighted deficit-round-robin over per-model batchers plus work
    /// stealing from backlogged peers: registration weights
    /// ([`GatewayBuilder::register_weighted`]) set each tenant's service
    /// share under contention, queue pulls skip past head-of-line
    /// requests of saturated tenants, and idle workers steal ready
    /// batches instead of sleeping. The default.
    #[default]
    FairSteal,
    /// The pre-fair baseline: strictly FIFO pulls that stop at the first
    /// request whose batcher is full (so one tenant's burst head-of-line
    /// blocks the others), model-index serve order that ignores weights,
    /// and idle workers that sleep rather than steal. Kept so the
    /// `serving_scale` fairness sweep can measure the improvement
    /// against it.
    Fixed,
}

/// Gateway sizing and policy, shared by every registered model.
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    /// Worker threads; each owns one replica of *every* registered model
    /// (replicas alias the registered engines' weights via `Arc`).
    pub replicas: usize,
    /// Admission queue capacity (requests, not batches; shared across
    /// models).
    pub queue_cap: usize,
    /// What to do with a new submission when the admission queue is
    /// full.
    pub shed: ShedPolicy,
    /// Per-worker, per-model dynamic batching policy.
    pub policy: BatchPolicy,
    /// Accelerator config used to attach simulated cycle counts to each
    /// served batch.
    pub sim_array: ArrayConfig,
    /// How workers pick the next batch (weighted fair dispatch with
    /// stealing, or the fixed pre-fair baseline).
    pub dispatch: Dispatch,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        Self {
            replicas: super::pool::default_replicas(),
            queue_cap: 1024,
            shed: ShedPolicy::RejectNew,
            policy: BatchPolicy::default(),
            sim_array: ArrayConfig::kan_sas(16, 16, 4, 8),
            dispatch: Dispatch::FairSteal,
        }
    }
}

/// Identifies a registered model within its [`Gateway`] (returned by
/// [`GatewayBuilder::register`], embedded in every [`ModelHandle`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelId(pub(crate) usize);

impl ModelId {
    /// Index into [`GatewayStats::per_model`].
    pub fn index(self) -> usize {
        self.0
    }
}

impl fmt::Display for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "m{}", self.0)
    }
}

/// Terminal outcomes across the whole serving stack — gateway, pool, and
/// server answer with this one enum (no more `PoolError` here,
/// `anyhow` there).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Shed by admission control: rejected at submit, or evicted under
    /// [`ShedPolicy::DropOldest`].
    QueueFull,
    /// The request's deadline lapsed before a worker could serve it.
    DeadlineExceeded,
    /// The gateway shut down before the request could be admitted.
    Closed,
    /// Input validation failed (wrong dimension).
    InvalidInput(String),
    /// No model registered under that name ([`Gateway::handle_by_name`]
    /// and the CLI's `--models` routing).
    UnknownModel(String),
    /// The engine rejected the whole batch.
    Inference(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::QueueFull => write!(f, "admission queue full (request shed)"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before service"),
            ServeError::Closed => write!(f, "gateway stopped"),
            ServeError::InvalidInput(m) => write!(f, "{m}"),
            ServeError::UnknownModel(m) => write!(f, "unknown model '{m}'"),
            ServeError::Inference(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// A free-list of pre-sized response buffers, one per registered model.
///
/// [`BufferPool::acquire`] pops a recycled `Vec<i64>` (or allocates one
/// to exact `out_dim` capacity on a miss); the buffer rides through the
/// worker's scatter into the [`Response`], and returns to the list when
/// the response drops. After warmup, acquire/release cycles perform zero
/// heap allocations (`tests/gateway_alloc.rs`); the list is capped so an
/// overload burst cannot pin unbounded memory.
#[derive(Debug)]
pub struct BufferPool {
    free: Mutex<Vec<Vec<i64>>>,
    /// Row width every buffer is pre-sized to.
    out_dim: usize,
    /// Maximum buffers retained on the free-list.
    retain: usize,
    created: AtomicU64,
    recycled: AtomicU64,
}

impl BufferPool {
    /// An empty pool of `out_dim`-capacity buffers retaining at most
    /// `retain` on its free-list.
    pub fn new(out_dim: usize, retain: usize) -> Self {
        Self {
            free: Mutex::new(Vec::new()),
            out_dim,
            retain,
            created: AtomicU64::new(0),
            recycled: AtomicU64::new(0),
        }
    }

    /// An empty buffer with capacity `out_dim` — recycled when the
    /// free-list has one, freshly allocated otherwise.
    pub fn acquire(&self) -> Vec<i64> {
        if let Some(buf) = self.free.lock().unwrap().pop() {
            self.recycled.fetch_add(1, Ordering::Relaxed);
            return buf;
        }
        self.created.fetch_add(1, Ordering::Relaxed);
        Vec::with_capacity(self.out_dim)
    }

    /// Return a buffer to the free-list (dropped if the list is full or
    /// the buffer was grown past the model's row width).
    pub fn release(&self, mut buf: Vec<i64>) {
        if buf.capacity() < self.out_dim || buf.capacity() > 4 * self.out_dim.max(1) {
            return; // wrong-sized stray; let it free normally
        }
        buf.clear();
        let mut free = self.free.lock().unwrap();
        if free.len() < self.retain {
            free.push(buf);
        }
    }

    /// `(fresh allocations, recycled acquires, buffers currently free)`.
    pub fn counts(&self) -> (u64, u64, usize) {
        (
            self.created.load(Ordering::Relaxed),
            self.recycled.load(Ordering::Relaxed),
            self.free.lock().unwrap().len(),
        )
    }
}

/// Response: i64 accumulators for the row (argmax = class) + split
/// timing. The accumulator buffer is pooled — dropping the response
/// recycles it through the model's [`BufferPool`].
#[derive(Debug)]
pub struct Response {
    /// Final-layer i64 accumulators for the row.
    pub t: Vec<i64>,
    /// Microseconds from admission to the start of the serving batch
    /// (queueing + batching delay).
    pub queue_us: u64,
    /// Microseconds from batch-serve start to the response being sent
    /// (compute + scatter).
    pub service_us: u64,
    /// Recycles `t` on drop when set.
    pool: Option<Arc<BufferPool>>,
}

impl Response {
    /// End-to-end latency: `queue_us + service_us` (the pre-split
    /// `latency_us` field, kept as a method for compatibility).
    pub fn latency_us(&self) -> u64 {
        self.queue_us + self.service_us
    }

    /// The predicted class (argmax over the accumulators).
    pub fn prediction(&self) -> usize {
        crate::util::argmax(&self.t)
    }
}

impl Clone for Response {
    fn clone(&self) -> Self {
        Self {
            t: self.t.clone(),
            queue_us: self.queue_us,
            service_us: self.service_us,
            // the clone's buffer is fresh (not pool-sized bookkeeping);
            // only the original recycles
            pool: None,
        }
    }
}

impl Drop for Response {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.release(std::mem::take(&mut self.t));
        }
    }
}

/// One inference request, built with options before submission.
///
/// # Examples
///
/// Submit a float row with a deadline and a priority class through a
/// [`ModelHandle`], then block on the [`Ticket`] for the logits:
///
/// ```
/// use std::time::Duration;
/// use kan_sas::coordinator::{GatewayBuilder, GatewayConfig, Priority, Request};
/// use kan_sas::kan::{Engine, QuantizedModel};
///
/// let mut builder = GatewayBuilder::with_config(GatewayConfig {
///     replicas: 1,
///     ..Default::default()
/// });
/// let id = builder.register(
///     "tiny",
///     Engine::new(QuantizedModel::synthetic("tiny", &[4, 6, 3], 5, 3, 7)),
/// );
/// let gateway = builder.start();
/// let handle = gateway.handle(id);
///
/// let ticket = handle.submit(
///     Request::from_f32(&[0.25, -0.5, 0.75, 0.1])
///         .with_deadline(Duration::from_secs(5))
///         .with_priority(Priority::High),
/// )?;
/// let response = ticket.wait()?;
/// assert_eq!(response.t.len(), 3, "one accumulator per output class");
/// assert!(gateway.shutdown().conserved());
/// # Ok::<(), kan_sas::coordinator::ServeError>(())
/// ```
#[derive(Clone, Debug)]
pub struct Request {
    x_q: Vec<u8>,
    /// Service deadline relative to submission; a request still queued
    /// when it lapses is answered [`ServeError::DeadlineExceeded`].
    deadline: Option<Duration>,
    priority: Priority,
}

impl Request {
    /// A request over an already-quantized activation row.
    pub fn from_q(x_q: Vec<u8>) -> Self {
        Self { x_q, deadline: None, priority: Priority::Normal }
    }

    /// A request over a float (spline-domain) row; quantized here, on
    /// the client thread.
    pub fn from_f32(x: &[f32]) -> Self {
        Self::from_q(crate::quant::quantize_activations(x))
    }

    /// Give the request a service deadline (measured from submission).
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Assign a [`Priority`] class (eviction ordering under
    /// [`ShedPolicy::DropOldest`]).
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }
}

/// One admitted request flowing through the shared queue: routed by
/// `model`, carrying its pooled output buffer so the worker's scatter is
/// a pure `extend_from_slice`.
struct GwRequest {
    model: ModelId,
    x_q: Vec<u8>,
    /// Pre-sized (capacity `out_dim`) pooled response buffer.
    out: Vec<i64>,
    submitted: Instant,
    deadline: Option<Instant>,
    priority: Priority,
    resp: Sender<Result<Response, ServeError>>,
}

/// Mutex-guarded queue state + the submit-side per-model counters.
struct GwState {
    items: VecDeque<GwRequest>,
    open: bool,
    /// Per-model: valid submissions counted by admission control
    /// (admitted or rejected-new; Block submissions that observe
    /// `Closed` are not counted).
    submitted: Vec<u64>,
    /// Per-model: requests answered `QueueFull` at admission (submit
    /// rejection or eviction).
    shed: Vec<u64>,
    peak_depth: usize,
}

/// Worker-side per-model counters (atomics: workers never take the queue
/// lock to account a served batch).
#[derive(Default)]
struct ModelCounters {
    /// Requests answered with logits.
    completed: AtomicU64,
    /// Requests answered with an inference error.
    failed: AtomicU64,
    /// Requests answered `DeadlineExceeded` (a subset of the model's
    /// `shed` total).
    expired: AtomicU64,
}

struct Shared {
    state: Mutex<GwState>,
    /// Signalled when a request is admitted (workers wait here).
    nonempty: Condvar,
    /// Signalled when a worker frees queue space (Block submitters wait).
    space: Condvar,
    cap: usize,
    shed_policy: ShedPolicy,
    dispatch: Dispatch,
    /// Per-model service weights (deficit-round-robin quanta).
    weights: Vec<u32>,
    counters: Vec<ModelCounters>,
    buffers: Vec<Arc<BufferPool>>,
    /// One batcher shard per worker. A shard is *owned* by its worker
    /// (only the owner pulls admissions into it) but *shared* with the
    /// fleet: idle peers steal due batches out of it.
    shards: Vec<Shard>,
}

/// One worker's per-model batchers, reachable by the whole fleet.
struct Shard {
    queues: Mutex<ShardQueues>,
    /// Requests queued across this shard's batchers — the backlog index
    /// peers consult lock-free when picking a steal victim. Incremented
    /// under the admission-queue lock on pull (so a drained admission
    /// queue plus all-zero backlog indexes really means "nothing left to
    /// serve"), decremented under the shard lock on drain.
    backlog: AtomicUsize,
}

/// The lockable interior of a [`Shard`]: per-model batchers plus the
/// deficit-round-robin state of the owning worker.
struct ShardQueues {
    batchers: Vec<Batcher<GwRequest>>,
    /// Per-model DRR credit, in rows. Earned `weight` per round while
    /// the model has a due batch; spent on dispatch (cost = rows
    /// served); reset when the model's batcher empties.
    deficit: Vec<u64>,
    /// Round-robin scan start (one past the last dispatched model).
    cursor: usize,
}

impl ShardQueues {
    fn new(n_models: usize, policy: BatchPolicy) -> Self {
        Self {
            batchers: (0..n_models).map(|_| Batcher::new(policy)).collect(),
            deficit: vec![0; n_models],
            cursor: 0,
        }
    }

    /// Is model `i`'s batcher due for dispatch? (`flush` = shutdown
    /// drain: everything nonempty is due.)
    fn due(&self, i: usize, flush: bool) -> bool {
        let b = &self.batchers[i];
        !b.is_empty() && (b.ready() || flush)
    }

    /// Weighted deficit-round-robin pick: scan due batchers from the
    /// cursor, crediting each `weight` rows per round, and dispatch the
    /// first whose accumulated deficit covers its batch cost (rows).
    /// A tenant passed over keeps its credit, so a starved high-weight
    /// tenant overtakes a saturated low-weight one within a few rounds;
    /// a lone due tenant is always dispatched (work conservation).
    /// Returns the picked model with its deficit already charged.
    fn next_drr(&mut self, weights: &[u32], max_batch: usize, flush: bool) -> Option<usize> {
        let n = self.batchers.len();
        // Each round adds >= 1 row of credit to every due batcher and a
        // batch costs at most max_batch rows, so max_batch rounds always
        // suffice to dispatch *something* when anything is due.
        for _round in 0..=max_batch {
            let mut any_due = false;
            for k in 0..n {
                let i = (self.cursor + k) % n;
                if self.batchers[i].is_empty() {
                    // classic DRR: an emptied queue forfeits its credit
                    self.deficit[i] = 0;
                    continue;
                }
                if !self.due(i, flush) {
                    continue; // still coalescing; keeps its credit
                }
                any_due = true;
                self.deficit[i] += weights[i] as u64;
                let cost = self.batchers[i].len().min(max_batch) as u64;
                if self.deficit[i] >= cost {
                    self.deficit[i] -= cost;
                    self.cursor = (i + 1) % n;
                    return Some(i);
                }
            }
            if !any_due {
                return None;
            }
        }
        None
    }

    /// The fixed-dispatch pick: lowest model index that is due,
    /// weight-blind (the pre-fair baseline).
    fn next_fixed(&self, flush: bool) -> Option<usize> {
        (0..self.batchers.len()).find(|&i| self.due(i, flush))
    }

    /// Smallest time-to-due across nonempty batchers (`None` when the
    /// shard is empty) — the owning worker's wait bound.
    fn soonest_due(&self) -> Option<Duration> {
        self.batchers
            .iter()
            .filter(|b| !b.is_empty())
            .map(Batcher::time_left)
            .min()
    }
}

/// A pending response. Dropping it abandons the answer (the gateway
/// still serves and counts the request).
pub struct Ticket {
    rx: Receiver<Result<Response, ServeError>>,
    /// When the request was submitted (admission-queue entry time).
    pub submitted: Instant,
}

impl Ticket {
    /// Block until the request resolves. A worker failure that loses the
    /// channel maps to [`ServeError::Closed`], so this can never hang.
    pub fn wait(self) -> Result<Response, ServeError> {
        self.rx.recv().unwrap_or(Err(ServeError::Closed))
    }

    /// Non-blocking poll; `None` while still in flight. A lost worker
    /// (disconnected channel) is a terminal [`ServeError::Closed`], not
    /// `None` — pollers must never spin forever on a dead ticket.
    pub fn try_wait(&self) -> Option<Result<Response, ServeError>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => Some(Err(ServeError::Closed)),
        }
    }
}

/// Cloneable, typed client handle for one registered model. All
/// submissions go through the gateway's shared admission queue but are
/// validated against — and routed to — this model only.
///
/// # Examples
///
/// ```
/// use kan_sas::coordinator::{GatewayBuilder, GatewayConfig};
/// use kan_sas::kan::{Engine, QuantizedModel};
///
/// let mut builder = GatewayBuilder::with_config(GatewayConfig {
///     replicas: 1,
///     ..Default::default()
/// });
/// let id = builder.register(
///     "demo",
///     Engine::new(QuantizedModel::synthetic("demo", &[4, 6, 3], 5, 3, 9)),
/// );
/// let gateway = builder.start();
///
/// let handle = gateway.handle(id);
/// assert_eq!((handle.name(), handle.in_dim(), handle.out_dim()), ("demo", 4, 3));
/// // blocking convenience over submit + Ticket::wait
/// let response = handle.infer_q(vec![10, 20, 30, 40])?;
/// assert_eq!(response.t.len(), 3);
/// // a wrong-width row is rejected before admission
/// assert!(handle.infer_q(vec![1, 2]).is_err());
/// gateway.shutdown();
/// # Ok::<(), kan_sas::coordinator::ServeError>(())
/// ```
#[derive(Clone)]
pub struct ModelHandle {
    shared: Arc<Shared>,
    model: ModelId,
    name: Arc<str>,
    in_dim: usize,
    out_dim: usize,
}

impl ModelHandle {
    /// The id this model was registered as.
    pub fn model_id(&self) -> ModelId {
        self.model
    }

    /// The name the model was registered under.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Input row width (quantized activations).
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    /// Output row width (final-layer accumulators).
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Requests currently waiting in the shared admission queue (all
    /// models; requests already pulled into a worker's batcher shard are
    /// not counted).
    pub fn queue_depth(&self) -> usize {
        self.shared.state.lock().unwrap().items.len()
    }

    /// Submit a built [`Request`]; returns a [`Ticket`] without waiting
    /// for the result. Admission control applies: a full queue sheds per
    /// the gateway's [`ShedPolicy`], with [`Priority`] ordering
    /// `DropOldest` eviction.
    pub fn submit(&self, req: Request) -> Result<Ticket, ServeError> {
        let Request { x_q, deadline, priority } = req;
        if x_q.len() != self.in_dim {
            return Err(ServeError::InvalidInput(format!(
                "input dim {} != model '{}' dim {}",
                x_q.len(),
                self.name,
                self.in_dim
            )));
        }
        let submitted = Instant::now();
        let deadline = deadline.map(|d| submitted + d);
        let m = self.model.0;
        let mut st = self.shared.state.lock().unwrap();
        if !st.open {
            return Err(ServeError::Closed);
        }
        while st.items.len() >= self.shared.cap {
            match self.shared.shed_policy {
                ShedPolicy::RejectNew => {
                    st.submitted[m] += 1;
                    st.shed[m] += 1;
                    return Err(ServeError::QueueFull);
                }
                ShedPolicy::DropOldest => {
                    // victim: oldest request of the lowest priority class
                    // queued — but never a class above the newcomer's.
                    // One pass under the shared lock: track the first
                    // (oldest) occurrence of the lowest class, stopping
                    // early once `Low` (the global minimum) is seen.
                    let mut victim: Option<(usize, Priority)> = None;
                    for (i, r) in st.items.iter().enumerate() {
                        let lower = match victim {
                            None => true,
                            Some((_, p)) => r.priority < p,
                        };
                        if lower {
                            victim = Some((i, r.priority));
                            if r.priority == Priority::Low {
                                break;
                            }
                        }
                    }
                    let (idx, min_pri) = victim.expect("full queue nonempty");
                    if min_pri > priority {
                        st.submitted[m] += 1;
                        st.shed[m] += 1;
                        return Err(ServeError::QueueFull);
                    }
                    let old = st.items.remove(idx).expect("index in bounds");
                    st.shed[old.model.0] += 1;
                    // recycle the victim's pooled buffer: the shed path
                    // must not drain the free-list under overload
                    self.shared.buffers[old.model.0].release(old.out);
                    let _ = old.resp.send(Err(ServeError::QueueFull));
                }
                ShedPolicy::Block => {
                    st = self.shared.space.wait(st).unwrap();
                    if !st.open {
                        return Err(ServeError::Closed);
                    }
                }
            }
        }
        // admitted: only now pay for the response channel; the output
        // buffer comes from the model's free-list, so steady-state
        // submission allocates no buffer (shed requests allocate nothing)
        let (tx, rx) = channel();
        let out = self.shared.buffers[m].acquire();
        st.submitted[m] += 1;
        st.items.push_back(GwRequest {
            model: self.model,
            x_q,
            out,
            submitted,
            deadline,
            priority,
            resp: tx,
        });
        st.peak_depth = st.peak_depth.max(st.items.len());
        drop(st);
        self.shared.nonempty.notify_one();
        Ok(Ticket { rx, submitted })
    }

    /// Submit one quantized row with default options; returns a
    /// [`Ticket`] without waiting (the open-loop load generator's entry
    /// point).
    pub fn submit_q(&self, x_q: Vec<u8>) -> Result<Ticket, ServeError> {
        self.submit(Request::from_q(x_q))
    }

    /// Submit one quantized row and block for its logits.
    pub fn infer_q(&self, x_q: Vec<u8>) -> Result<Response, ServeError> {
        self.submit_q(x_q)?.wait()
    }

    /// Submit a float (spline-domain) row and block for its logits.
    pub fn infer(&self, x: &[f32]) -> Result<Response, ServeError> {
        self.submit(Request::from_f32(x))?.wait()
    }
}

/// Per-model accounting: admission + service counters, the model's own
/// merged [`Metrics`] (rows, batches, latency percentiles, simulated
/// cycles), and buffer-pool health.
#[derive(Clone, Debug, Default)]
pub struct ModelStats {
    /// The name the model was registered under.
    pub name: String,
    /// The model's service weight (deficit-round-robin quantum; 1 for
    /// [`GatewayBuilder::register`], explicit for
    /// [`GatewayBuilder::register_weighted`]).
    pub weight: u32,
    /// Valid submissions counted by admission control.
    pub submitted: u64,
    /// Requests answered with logits.
    pub completed: u64,
    /// Requests answered without inference: `QueueFull` (at submit or by
    /// eviction) plus `DeadlineExceeded` (see `expired`).
    pub shed: u64,
    /// Deadline-lapsed requests — a subset of `shed`, broken out so shed
    /// policy and deadline pressure can be read separately.
    pub expired: u64,
    /// Requests answered with an inference error. Conservation per
    /// model: `submitted == completed + shed + failed` once drained.
    pub failed: u64,
    /// This model's rows/batches/latency/sim counters, merged across
    /// every replica that served it.
    pub metrics: Metrics,
    /// Fresh response-buffer allocations (free-list misses).
    pub buffers_created: u64,
    /// Response buffers served from the free-list.
    pub buffers_recycled: u64,
}

impl ModelStats {
    /// `submitted == completed + shed + failed` — every counted
    /// submission answered exactly once.
    pub fn conserved(&self) -> bool {
        self.submitted == self.completed + self.shed + self.failed
    }

    /// Fraction of counted submissions shed by admission control or
    /// deadline expiry.
    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            return 0.0;
        }
        self.shed as f64 / self.submitted as f64
    }
}

/// Gateway-level statistics: per-model and per-replica breakdowns plus
/// the shared-queue counters.
#[derive(Clone, Debug, Default)]
pub struct GatewayStats {
    /// Everything, merged (all models, all replicas).
    pub merged: Metrics,
    /// Per-replica metrics (all models served by that worker) — the
    /// load-balance view.
    pub per_replica: Vec<Metrics>,
    /// Per-model accounting, indexed by [`ModelId::index`].
    pub per_model: Vec<ModelStats>,
    /// High-water mark of the shared admission queue.
    pub peak_depth: usize,
    /// Queue depth at snapshot time.
    pub queue_depth: usize,
    /// Worker fleet size.
    pub replicas: usize,
}

impl GatewayStats {
    /// Total valid submissions across all models.
    pub fn submitted(&self) -> u64 {
        self.per_model.iter().map(|m| m.submitted).sum()
    }

    /// Total requests answered with logits.
    pub fn completed(&self) -> u64 {
        self.per_model.iter().map(|m| m.completed).sum()
    }

    /// Total requests shed (admission rejection, eviction, or deadline
    /// expiry).
    pub fn shed(&self) -> u64 {
        self.per_model.iter().map(|m| m.shed).sum()
    }

    /// Total requests answered with an inference error.
    pub fn failed(&self) -> u64 {
        self.per_model.iter().map(|m| m.failed).sum()
    }

    /// Batches served via work stealing, across all models and
    /// replicas (0 under [`Dispatch::Fixed`]).
    pub fn stolen_batches(&self) -> u64 {
        self.per_model.iter().map(|m| m.metrics.stolen_batches).sum()
    }

    /// Jain's fairness index over weight-normalized served rows
    /// (`rows / weight` per model with any submissions): 1.0 means every
    /// tenant got service in proportion to its weight, `1/n` means one
    /// tenant monopolized the fleet.
    ///
    /// This is a *service-share* index: it is meaningful when tenants
    /// are contending (backlogged), where shares are the scheduler's
    /// doing. Below saturation — or when a tenant's offered load is
    /// under its weighted share — served rows simply mirror the arrival
    /// mix, so a skewed mix reads as a low index without any tenant
    /// being starved. The dispatch experiments therefore report it
    /// alongside the per-tenant p95 *queueing* delay
    /// ([`Metrics::queue_latency`]), which is the direct starvation
    /// metric and the one the acceptance criteria gate on.
    pub fn fairness_index(&self) -> f64 {
        jain_fairness(
            self.per_model
                .iter()
                .filter(|m| m.submitted > 0)
                .map(|m| m.metrics.batch_rows as f64 / m.weight.max(1) as f64),
        )
    }

    /// True when every model's counters balance.
    pub fn conserved(&self) -> bool {
        self.per_model.iter().all(ModelStats::conserved)
    }
}

/// Registers models (each with a service weight), then
/// [`GatewayBuilder::start`]s the fleet.
///
/// # Examples
///
/// Two tenants over one fleet, the minority tenant weighted 4x so a
/// majority-tenant burst cannot starve it:
///
/// ```
/// use kan_sas::coordinator::{GatewayBuilder, GatewayConfig};
/// use kan_sas::kan::{Engine, QuantizedModel};
///
/// let mut builder = GatewayBuilder::with_config(GatewayConfig {
///     replicas: 1,
///     ..Default::default()
/// });
/// let mnist = builder.register(
///     "mnist",
///     Engine::new(QuantizedModel::synthetic("mnist", &[8, 12, 10], 5, 3, 1)),
/// );
/// let har = builder.register_weighted(
///     "har",
///     Engine::new(QuantizedModel::synthetic("har", &[6, 8, 4], 5, 3, 2)),
///     4,
/// );
/// let gateway = builder.start();
///
/// let response = gateway.handle(har).infer_q(vec![0, 50, 100, 150, 200, 250])?;
/// assert_eq!(response.t.len(), 4);
/// let _ = gateway.handle(mnist).infer_q(vec![7; 8])?;
///
/// let stats = gateway.shutdown();
/// assert!(stats.conserved());
/// assert_eq!(stats.per_model[har.index()].weight, 4);
/// # Ok::<(), kan_sas::coordinator::ServeError>(())
/// ```
pub struct GatewayBuilder {
    cfg: GatewayConfig,
    models: Vec<(String, Engine, u32)>,
}

impl Default for GatewayBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl GatewayBuilder {
    /// A builder over the default [`GatewayConfig`].
    pub fn new() -> Self {
        Self { cfg: GatewayConfig::default(), models: Vec::new() }
    }

    /// A builder over an explicit [`GatewayConfig`].
    pub fn with_config(cfg: GatewayConfig) -> Self {
        Self { cfg, models: Vec::new() }
    }

    /// Register a model under `name` with service weight 1. The returned
    /// [`ModelId`] indexes [`GatewayStats::per_model`] and resolves to a
    /// [`ModelHandle`] once the gateway starts. Names must be unique.
    pub fn register(&mut self, name: &str, engine: Engine) -> ModelId {
        self.register_weighted(name, engine, 1)
    }

    /// Register a model under `name` with an explicit service `weight`
    /// (>= 1). Under [`Dispatch::FairSteal`] contention, tenants are
    /// served rows in proportion to their weights: a weight-4 tenant
    /// saturating the fleet alongside a weight-1 tenant gets ~4x the
    /// rows, and a *starved* high-weight tenant's backlog is dispatched
    /// before a saturated low-weight one's. Weights are ignored by
    /// [`Dispatch::Fixed`].
    pub fn register_weighted(&mut self, name: &str, engine: Engine, weight: u32) -> ModelId {
        assert!(weight >= 1, "model '{name}' needs weight >= 1 (got {weight})");
        assert!(
            self.models.iter().all(|(n, _, _)| n != name),
            "model '{name}' registered twice"
        );
        self.models.push((name.to_string(), engine, weight));
        ModelId(self.models.len() - 1)
    }

    /// Spawn the worker fleet and return the running [`Gateway`].
    pub fn start(self) -> Gateway {
        Gateway::start(self.cfg, self.models)
    }
}

/// One worker's mutable metrics slot for one model.
type MetricsCell = Arc<Mutex<Metrics>>;

/// A running multi-model serving gateway; [`Gateway::shutdown`] drains
/// and joins.
pub struct Gateway {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    /// `[replica][model]` metrics cells.
    per_worker: Vec<Vec<MetricsCell>>,
    handles: Vec<ModelHandle>,
}

impl Gateway {
    /// A [`GatewayBuilder`] over the default config.
    pub fn builder() -> GatewayBuilder {
        GatewayBuilder::new()
    }

    fn start(cfg: GatewayConfig, models: Vec<(String, Engine, u32)>) -> Self {
        assert!(cfg.replicas >= 1, "gateway needs at least one replica");
        assert!(cfg.queue_cap >= 1, "admission queue needs capacity");
        assert!(!models.is_empty(), "gateway needs at least one registered model");
        let n_models = models.len();
        let buffers: Vec<Arc<BufferPool>> = models
            .iter()
            .map(|(_, e, _)| {
                // retain enough for a full queue of this model plus every
                // replica's in-flight batch
                let retain = cfg.queue_cap + cfg.replicas * cfg.policy.max_batch;
                Arc::new(BufferPool::new(e.out_dim(), retain))
            })
            .collect();
        let shards = (0..cfg.replicas)
            .map(|_| Shard {
                queues: Mutex::new(ShardQueues::new(n_models, cfg.policy)),
                backlog: AtomicUsize::new(0),
            })
            .collect();
        let shared = Arc::new(Shared {
            state: Mutex::new(GwState {
                items: VecDeque::new(),
                open: true,
                submitted: vec![0; n_models],
                shed: vec![0; n_models],
                peak_depth: 0,
            }),
            nonempty: Condvar::new(),
            space: Condvar::new(),
            cap: cfg.queue_cap,
            shed_policy: cfg.shed,
            dispatch: cfg.dispatch,
            weights: models.iter().map(|(_, _, w)| *w).collect(),
            counters: (0..n_models).map(|_| ModelCounters::default()).collect(),
            buffers,
            shards,
        });
        let mut workers = Vec::with_capacity(cfg.replicas);
        let mut per_worker = Vec::with_capacity(cfg.replicas);
        for i in 0..cfg.replicas {
            let cells: Vec<MetricsCell> =
                (0..n_models).map(|_| Arc::new(Mutex::new(Metrics::default()))).collect();
            per_worker.push(cells.clone());
            // replica set: clones alias weights + compiled plans, ~1x memory
            let engines: Vec<Engine> = models.iter().map(|(_, e, _)| e.clone()).collect();
            let shared_w = Arc::clone(&shared);
            let policy = cfg.policy;
            let sim_array = cfg.sim_array;
            let w = std::thread::Builder::new()
                .name(format!("kansas-gw-{i}"))
                .spawn(move || worker_loop(i, engines, policy, sim_array, shared_w, cells))
                .expect("spawn gateway worker");
            workers.push(w);
        }
        let handles = models
            .iter()
            .enumerate()
            .map(|(m, (name, e, _))| ModelHandle {
                shared: Arc::clone(&shared),
                model: ModelId(m),
                name: Arc::from(name.as_str()),
                in_dim: e.in_dim(),
                out_dim: e.out_dim(),
            })
            .collect();
        Self { shared, workers, per_worker, handles }
    }

    /// Number of registered models.
    pub fn n_models(&self) -> usize {
        self.handles.len()
    }

    /// The typed handle for a registered model.
    pub fn handle(&self, id: ModelId) -> ModelHandle {
        self.handles[id.0].clone()
    }

    /// Resolve a handle by registered name.
    pub fn handle_by_name(&self, name: &str) -> Result<ModelHandle, ServeError> {
        self.handles
            .iter()
            .find(|h| &*h.name == name)
            .cloned()
            .ok_or_else(|| ServeError::UnknownModel(name.to_string()))
    }

    /// All handles, in registration order.
    pub fn handles(&self) -> Vec<ModelHandle> {
        self.handles.clone()
    }

    /// Live snapshot (the gateway keeps serving).
    pub fn stats(&self) -> GatewayStats {
        self.snapshot()
    }

    /// Stop admitting, serve everything already queued, join all
    /// workers, and return the final stats.
    pub fn shutdown(mut self) -> GatewayStats {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.open = false;
        }
        self.shared.nonempty.notify_all();
        self.shared.space.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.snapshot()
    }

    fn snapshot(&self) -> GatewayStats {
        let n_models = self.handles.len();
        let mut merged = Metrics::default();
        let mut per_replica = Vec::with_capacity(self.per_worker.len());
        let mut model_metrics = vec![Metrics::default(); n_models];
        for cells in &self.per_worker {
            let mut replica = Metrics::default();
            for (m, cell) in cells.iter().enumerate() {
                let mm = cell.lock().unwrap().clone();
                merged.merge(&mm);
                replica.merge(&mm);
                model_metrics[m].merge(&mm);
            }
            per_replica.push(replica);
        }
        let st = self.shared.state.lock().unwrap();
        let per_model = (0..n_models)
            .map(|m| {
                let c = &self.shared.counters[m];
                let expired = c.expired.load(Ordering::Relaxed);
                let (created, recycled, _) = self.shared.buffers[m].counts();
                ModelStats {
                    name: self.handles[m].name.to_string(),
                    weight: self.shared.weights[m],
                    submitted: st.submitted[m],
                    completed: c.completed.load(Ordering::Relaxed),
                    // expired requests are shed too: they were answered
                    // without inference
                    shed: st.shed[m] + expired,
                    expired,
                    failed: c.failed.load(Ordering::Relaxed),
                    metrics: std::mem::take(&mut model_metrics[m]),
                    buffers_created: created,
                    buffers_recycled: recycled,
                }
            })
            .collect();
        GatewayStats {
            merged,
            per_replica,
            per_model,
            peak_depth: st.peak_depth,
            queue_depth: st.items.len(),
            replicas: self.per_worker.len(),
        }
    }
}

/// One fleet worker: replicas of every model, a fleet-visible shard of
/// per-model batchers, one scratch arena sized to the widest model, two
/// reusable batch Vecs. Each turn of the loop: pull admissions into the
/// own shard, dispatch ONE batch (own shard by the configured
/// [`Dispatch`] policy, else steal a due batch from the most backlogged
/// peer), serve it, repeat. The worker sleeps only when nothing is due
/// anywhere it can reach, and exits only when the gateway is closed and
/// fully drained.
fn worker_loop(
    me: usize,
    engines: Vec<Engine>,
    policy: BatchPolicy,
    sim_array: ArrayConfig,
    shared: Arc<Shared>,
    metrics: Vec<MetricsCell>,
) {
    // Worker-owned execution state, allocated once per replica: one
    // scratch arena grown to fit every registered model's plan at the
    // peak batch size, plus the two batch Vecs every dispatch reuses
    // (drained batch, then deadline-surviving subset). Batchers live in
    // the fleet-shared shard, not here — peers steal out of them.
    let mut scratch = Scratch::new();
    for e in &engines {
        scratch.fit(e.plan(), policy.max_batch);
    }
    let mut batch: Vec<GwRequest> = Vec::with_capacity(policy.max_batch);
    let mut live: Vec<GwRequest> = Vec::with_capacity(policy.max_batch);
    loop {
        // Phase 1: move admitted requests into this worker's shard.
        let closed;
        {
            let mut st = shared.state.lock().unwrap();
            closed = !st.open;
            let admitted = pull_into(&mut st, &shared, me, policy.max_batch);
            let more_queued = !st.items.is_empty();
            drop(st);
            if admitted {
                shared.space.notify_all();
                if more_queued {
                    // this shard can't hold the remainder (those models'
                    // batchers are full); wake a peer to pull it
                    shared.nonempty.notify_one();
                }
            }
        }
        // Phase 2: dispatch one batch — own shard first, then steal.
        // Batches never mix models: each drain comes from one model's
        // batcher and runs on that model's replica (every worker holds
        // replicas of every model, so stolen batches serve anywhere).
        let mut picked: Option<(usize, bool)> = None;
        {
            let shard = &shared.shards[me];
            let mut q = shard.queues.lock().unwrap();
            let pick = match shared.dispatch {
                Dispatch::FairSteal => q.next_drr(&shared.weights, policy.max_batch, closed),
                Dispatch::Fixed => q.next_fixed(closed),
            };
            if let Some(m) = pick {
                let took = q.batchers[m].drain_into(&mut batch);
                shard.backlog.fetch_sub(took, Ordering::Relaxed);
                picked = Some((m, false));
            }
        }
        if picked.is_none() && shared.dispatch == Dispatch::FairSteal {
            picked =
                steal_batch(&shared, me, policy.max_batch, closed, &mut batch).map(|m| (m, true));
        }
        if let Some((m, stolen)) = picked {
            serve_batch(
                &engines[m],
                &sim_array,
                &mut batch,
                &mut live,
                &mut scratch,
                &shared,
                &shared.counters[m],
                &metrics[m],
                stolen,
            );
            continue;
        }
        // Phase 3: nothing due anywhere. Exit when closed and fully
        // drained; otherwise sleep, bounded by the soonest moment a
        // batch this worker could serve comes due (its own shard's
        // always, a backlogged peer's too when stealing is on) so
        // straggler windows and steal opportunities are never overslept.
        let st = shared.state.lock().unwrap();
        if !st.items.is_empty() {
            continue; // arrivals raced in between phases
        }
        if !st.open {
            let drained = match shared.dispatch {
                Dispatch::Fixed => shared.shards[me].backlog.load(Ordering::Relaxed) == 0,
                Dispatch::FairSteal => {
                    shared.shards.iter().all(|s| s.backlog.load(Ordering::Relaxed) == 0)
                }
            };
            if drained {
                return;
            }
            // a peer's shard still holds work this worker can steal on
            // the next spin (its owner may be mid-serve); don't sleep on
            // a condvar nobody will signal again
            drop(st);
            std::thread::yield_now();
            continue;
        }
        match wait_hint(&shared, me) {
            Some(d) if d.is_zero() => { /* something just came due; spin again */ }
            Some(d) => {
                let _ = shared.nonempty.wait_timeout(st, d).unwrap();
            }
            None => {
                let _ = shared.nonempty.wait(st).unwrap();
            }
        }
    }
}

/// Move queued requests into worker `me`'s shard. [`Dispatch::Fixed`]
/// preserves the pre-fair behaviour: strict FIFO that stops at the
/// first request whose batcher is full, so a one-tenant burst
/// head-of-line blocks every other tenant. [`Dispatch::FairSteal`]
/// scans past such requests — a saturated tenant's overflow stays
/// queued while other tenants' arrivals keep flowing (per-model FIFO
/// order is preserved; only *other* models' requests are overtaken).
/// Returns whether anything entered the shard. Runs under the
/// admission-queue lock, and updates the shard's backlog index there
/// too, so "queue empty + all backlogs zero" is an exact drained check.
fn pull_into(st: &mut GwState, shared: &Shared, me: usize, max_batch: usize) -> bool {
    let shard = &shared.shards[me];
    let mut q = shard.queues.lock().unwrap();
    let mut admitted = 0usize;
    match shared.dispatch {
        Dispatch::Fixed => {
            while let Some(front) = st.items.front() {
                let b = &mut q.batchers[front.model.0];
                if b.len() >= max_batch {
                    break;
                }
                let r = st.items.pop_front().expect("front just observed");
                b.push_arrived(r.submitted, r);
                admitted += 1;
            }
        }
        Dispatch::FairSteal => {
            // Read-only pre-scan: under a saturated burst the queue is
            // mostly one tenant's overflow with no batcher room, and
            // this runs under the hottest lock in the system — don't
            // pay the rotation's writes unless something will admit.
            let admissible = q.batchers.iter().any(|b| b.len() < max_batch)
                && st.items.iter().any(|r| q.batchers[r.model.0].len() < max_batch);
            if admissible {
                // One O(n) rotation: route each request into its
                // batcher if there's room, else re-queue it at the back
                // — processing in order and appending in order
                // preserves the queue's relative (per-model FIFO) order
                // for the skipped remainder. The pass must run to
                // completion: stopping mid-cycle would leave the queue
                // rotated and break per-model FIFO.
                let scan = st.items.len();
                for _ in 0..scan {
                    let r = st.items.pop_front().expect("count just observed");
                    let b = &mut q.batchers[r.model.0];
                    if b.len() >= max_batch {
                        st.items.push_back(r);
                    } else {
                        b.push_arrived(r.submitted, r);
                        admitted += 1;
                    }
                }
            }
        }
    }
    if admitted > 0 {
        shard.backlog.fetch_add(admitted, Ordering::Relaxed);
    }
    admitted > 0
}

/// Steal one due batch from a backlogged peer's shard, trying peers in
/// descending-backlog order (the index reads are lock-free atomics;
/// only probed shards are locked). A heavily backlogged peer whose
/// batches are all still coalescing must not mask a lighter peer with a
/// batch due *now* — the thief keeps probing until it finds due work or
/// runs out of backlogged peers. Within the victim shard the longest
/// due batcher is drained (up to one batch — the drain is splittable,
/// so leftover items keep their arrival clocks). Returns the model
/// stolen, or `None` when no peer has a due batch.
fn steal_batch(
    shared: &Shared,
    me: usize,
    max_batch: usize,
    flush: bool,
    batch: &mut Vec<GwRequest>,
) -> Option<usize> {
    // Victim preference order, allocation-free: the most backlogged
    // peer first (atomic reads only), then every other backlogged peer
    // in index order — a heavy peer whose batches are all still
    // coalescing must not mask a lighter peer with a batch due now.
    let heaviest = shared
        .shards
        .iter()
        .enumerate()
        .filter(|&(i, _)| i != me)
        .map(|(i, s)| (i, s.backlog.load(Ordering::Relaxed)))
        .filter(|&(_, backlog)| backlog > 0)
        .max_by_key(|&(_, backlog)| backlog)
        .map(|(i, _)| i)?;
    if let Some(m) = try_steal_from(shared, heaviest, max_batch, flush, batch) {
        return Some(m);
    }
    for (i, shard) in shared.shards.iter().enumerate() {
        if i == me || i == heaviest || shard.backlog.load(Ordering::Relaxed) == 0 {
            continue;
        }
        if let Some(m) = try_steal_from(shared, i, max_batch, flush, batch) {
            return Some(m);
        }
    }
    None
}

/// Probe one victim shard: drain its longest due batcher (up to one
/// batch) into `batch`, or `None` when nothing in it is due.
fn try_steal_from(
    shared: &Shared,
    victim: usize,
    max_batch: usize,
    flush: bool,
    batch: &mut Vec<GwRequest>,
) -> Option<usize> {
    let shard = &shared.shards[victim];
    let mut q = shard.queues.lock().unwrap();
    let m = (0..q.batchers.len())
        .filter(|&i| q.due(i, flush))
        .max_by_key(|&i| q.batchers[i].len())?;
    let took = q.batchers[m].drain_upto(batch, max_batch);
    shard.backlog.fetch_sub(took, Ordering::Relaxed);
    Some(m)
}

/// Upper bound on how long an idle worker may sleep: the soonest
/// time-to-due across every batch it could serve — its own shard's
/// batchers always, plus any backlogged peer's under
/// [`Dispatch::FairSteal`] (it would steal those). `None` means nothing
/// is queued anywhere reachable; sleep until an admission signal.
fn wait_hint(shared: &Shared, me: usize) -> Option<Duration> {
    let mut hint: Option<Duration> = None;
    for (i, shard) in shared.shards.iter().enumerate() {
        if i != me
            && (shared.dispatch != Dispatch::FairSteal
                || shard.backlog.load(Ordering::Relaxed) == 0)
        {
            continue;
        }
        if let Some(d) = shard.queues.lock().unwrap().soonest_due() {
            hint = Some(match hint {
                Some(h) => h.min(d),
                None => d,
            });
        }
    }
    hint
}

/// Serve one single-model batch on this worker's replica of that model.
/// Deadline-lapsed requests are answered `DeadlineExceeded` before any
/// compute; survivors' rows are gathered straight into the scratch's
/// staging buffer and outputs scattered as slices into each request's
/// pooled, pre-sized response buffer — the gather/forward/scatter core
/// allocates nothing per request (the mpsc response send and latency
/// recording still do). `stolen` marks a batch taken from a peer's
/// shard; it is recorded in the serving worker's metrics cell for the
/// model, so steal traffic shows up per replica and per model.
#[allow(clippy::too_many_arguments)]
fn serve_batch(
    engine: &Engine,
    sim_array: &ArrayConfig,
    batch: &mut Vec<GwRequest>,
    live: &mut Vec<GwRequest>,
    scratch: &mut Scratch,
    shared: &Shared,
    counters: &ModelCounters,
    metrics: &Mutex<Metrics>,
    stolen: bool,
) {
    let in_dim = engine.in_dim();
    let out_dim = engine.out_dim();
    let serve_start = Instant::now();
    live.clear();
    {
        let staging = scratch.stage_input(batch.len() * in_dim);
        for req in batch.drain(..) {
            match req.deadline {
                Some(d) if d <= serve_start => {
                    counters.expired.fetch_add(1, Ordering::Relaxed);
                    shared.buffers[req.model.0].release(req.out);
                    let _ = req.resp.send(Err(ServeError::DeadlineExceeded));
                }
                _ => {
                    staging.extend_from_slice(&req.x_q);
                    live.push(req);
                }
            }
        }
    }
    let bs = live.len();
    if bs == 0 {
        return;
    }
    let result = engine.forward_staged(bs, scratch);
    let sim = engine.simulate_batch(sim_array, bs);
    let mut m = metrics.lock().unwrap();
    m.record_batch_sim(bs, &sim);
    if stolen {
        m.record_steal();
    }
    match result {
        Ok(t) => {
            for (i, mut req) in live.drain(..).enumerate() {
                let queue = serve_start.duration_since(req.submitted);
                let service = serve_start.elapsed();
                m.record_request_split(queue, service);
                counters.completed.fetch_add(1, Ordering::Relaxed);
                req.out.extend_from_slice(&t[i * out_dim..(i + 1) * out_dim]);
                let _ = req.resp.send(Ok(Response {
                    t: req.out,
                    queue_us: queue.as_micros() as u64,
                    service_us: service.as_micros() as u64,
                    pool: Some(Arc::clone(&shared.buffers[req.model.0])),
                }));
            }
        }
        Err(e) => {
            let msg = format!("inference failed: {e}");
            for req in live.drain(..) {
                counters.failed.fetch_add(1, Ordering::Relaxed);
                shared.buffers[req.model.0].release(req.out);
                let _ = req.resp.send(Err(ServeError::Inference(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kan::QuantizedModel;

    fn two_model_gateway(replicas: usize, queue_cap: usize, shed: ShedPolicy) -> Gateway {
        let mut b = GatewayBuilder::with_config(GatewayConfig {
            replicas,
            queue_cap,
            shed,
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
            dispatch: Dispatch::FairSteal,
        });
        let ea = Engine::new(QuantizedModel::synthetic("a", &[4, 6, 3], 5, 3, 5));
        let eb = Engine::new(QuantizedModel::synthetic("b", &[6, 8, 5], 5, 3, 9));
        let a = b.register("alpha", ea);
        let c = b.register("beta", eb);
        assert_eq!(a, ModelId(0));
        assert_eq!(c, ModelId(1));
        b.start()
    }

    /// A handle fleet over a worker-less shared queue: admission control
    /// in isolation, fully deterministic (no racing consumers).
    fn bare_handles(n_models: usize, cap: usize, shed: ShedPolicy) -> Vec<ModelHandle> {
        let shared = Arc::new(Shared {
            state: Mutex::new(GwState {
                items: VecDeque::new(),
                open: true,
                submitted: vec![0; n_models],
                shed: vec![0; n_models],
                peak_depth: 0,
            }),
            nonempty: Condvar::new(),
            space: Condvar::new(),
            cap,
            shed_policy: shed,
            dispatch: Dispatch::FairSteal,
            weights: vec![1; n_models],
            counters: (0..n_models).map(|_| ModelCounters::default()).collect(),
            buffers: (0..n_models).map(|_| Arc::new(BufferPool::new(3, 16))).collect(),
            shards: Vec::new(),
        });
        (0..n_models)
            .map(|m| ModelHandle {
                shared: Arc::clone(&shared),
                model: ModelId(m),
                name: Arc::from(format!("m{m}").as_str()),
                in_dim: 4,
                out_dim: 3,
            })
            .collect()
    }

    #[test]
    fn routes_and_counts_per_model() {
        let gw = two_model_gateway(2, 64, ShedPolicy::RejectNew);
        let ha = gw.handle(ModelId(0));
        let hb = gw.handle_by_name("beta").unwrap();
        assert_eq!(ha.name(), "alpha");
        assert_eq!(hb.in_dim(), 6);
        assert!(gw.handle_by_name("nope").is_err());
        for _ in 0..12 {
            let r = ha.infer_q(vec![1, 2, 3, 4]).unwrap();
            assert_eq!(r.t.len(), 3);
        }
        for _ in 0..7 {
            let r = hb.infer_q(vec![9, 8, 7, 6, 5, 4]).unwrap();
            assert_eq!(r.t.len(), 5);
            let _ = r.prediction();
        }
        let stats = gw.shutdown();
        assert_eq!(stats.per_model.len(), 2);
        let (a, b) = (&stats.per_model[0], &stats.per_model[1]);
        assert_eq!((a.submitted, a.completed, a.shed, a.failed), (12, 12, 0, 0));
        assert_eq!((b.submitted, b.completed, b.shed, b.failed), (7, 7, 0, 0));
        assert!(a.conserved() && b.conserved());
        assert_eq!(a.metrics.batch_rows, 12);
        assert_eq!(b.metrics.batch_rows, 7);
        assert_eq!(stats.merged.batch_rows, 19);
        assert_eq!(stats.per_replica.len(), 2);
        let per_replica_rows: u64 = stats.per_replica.iter().map(|m| m.batch_rows).sum();
        assert_eq!(per_replica_rows, 19);
        assert!(stats.conserved());
        assert_eq!(stats.submitted(), 19);
    }

    #[test]
    fn wrong_model_dim_rejected_before_admission() {
        let gw = two_model_gateway(1, 8, ShedPolicy::RejectNew);
        // a row sized for beta must not pass alpha's validation
        let err = gw.handle(ModelId(0)).infer_q(vec![1, 2, 3, 4, 5, 6]).unwrap_err();
        assert!(matches!(err, ServeError::InvalidInput(_)));
        let stats = gw.shutdown();
        assert_eq!(stats.submitted(), 0);
    }

    #[test]
    fn closed_gateway_rejects_submissions() {
        let gw = two_model_gateway(1, 8, ShedPolicy::RejectNew);
        let h = gw.handle(ModelId(0));
        let stats = gw.shutdown();
        assert_eq!(stats.submitted(), 0);
        assert_eq!(h.infer_q(vec![1, 2, 3, 4]).unwrap_err(), ServeError::Closed);
    }

    #[test]
    fn reject_new_sheds_at_capacity() {
        let hs = bare_handles(2, 2, ShedPolicy::RejectNew);
        let _t1 = hs[0].submit_q(vec![1, 1, 1, 1]).unwrap();
        let _t2 = hs[1].submit_q(vec![2, 2, 2, 2]).unwrap();
        assert_eq!(hs[0].queue_depth(), 2);
        assert_eq!(hs[0].submit_q(vec![3, 3, 3, 3]).unwrap_err(), ServeError::QueueFull);
        assert_eq!(hs[0].queue_depth(), 2, "rejected arrival never enters the queue");
        let st = hs[0].shared.state.lock().unwrap();
        assert_eq!(st.submitted, vec![2, 1]);
        assert_eq!(st.shed, vec![1, 0]);
        assert_eq!(st.peak_depth, 2);
    }

    #[test]
    fn drop_oldest_evicts_stalest_and_admits() {
        let hs = bare_handles(2, 2, ShedPolicy::DropOldest);
        let t1 = hs[0].submit_q(vec![1, 1, 1, 1]).unwrap();
        let t2 = hs[1].submit_q(vec![2, 2, 2, 2]).unwrap();
        // queue full: #3 evicts #1, #4 evicts #2 — the newcomer always
        // wins among equals, and the shed is charged to the VICTIM's model
        let t3 = hs[0].submit_q(vec![3, 3, 3, 3]).unwrap();
        assert_eq!(t1.wait(), Err(ServeError::QueueFull), "oldest answered on eviction");
        let t4 = hs[0].submit_q(vec![4, 4, 4, 4]).unwrap();
        assert_eq!(t2.wait(), Err(ServeError::QueueFull));
        assert_eq!(hs[0].queue_depth(), 2);
        assert!(t3.try_wait().is_none(), "survivors stay in flight");
        assert!(t4.try_wait().is_none());
        let st = hs[0].shared.state.lock().unwrap();
        assert_eq!(st.submitted, vec![3, 1]);
        assert_eq!(st.shed, vec![1, 1], "each model shed its own evicted request");
        drop(st);
        // eviction must recycle the victim's buffer, not drop it: #3's
        // acquire reuses #1's released buffer (model 0); #2's buffer sits
        // on model 1's free-list
        let (c0, r0, f0) = hs[0].shared.buffers[0].counts();
        assert_eq!((c0, r0, f0), (2, 1, 0), "evicted model-0 buffer was reacquired");
        let (c1, _r1, f1) = hs[0].shared.buffers[1].counts();
        assert_eq!((c1, f1), (1, 1), "evicted model-1 buffer returned to its free-list");
    }

    #[test]
    fn drop_oldest_evicts_lowest_priority_first() {
        let hs = bare_handles(1, 2, ShedPolicy::DropOldest);
        let h = &hs[0];
        let t_high = h.submit(Request::from_q(vec![1; 4]).with_priority(Priority::High)).unwrap();
        let t_low = h.submit(Request::from_q(vec![2; 4]).with_priority(Priority::Low)).unwrap();
        // normal newcomer: the LOW request is the victim even though the
        // high one is older
        let t_norm = h.submit(Request::from_q(vec![3; 4])).unwrap();
        assert_eq!(t_low.wait(), Err(ServeError::QueueFull));
        assert!(t_high.try_wait().is_none(), "higher class survives eviction");
        assert!(t_norm.try_wait().is_none());
        // a LOW newcomer against a {High, Normal} queue sheds itself
        let err =
            h.submit(Request::from_q(vec![4; 4]).with_priority(Priority::Low)).unwrap_err();
        assert_eq!(err, ServeError::QueueFull);
        assert_eq!(h.queue_depth(), 2, "queue untouched by the self-shed newcomer");
        assert!(t_high.try_wait().is_none());
    }

    #[test]
    fn priority_orders() {
        assert!(Priority::Low < Priority::Normal && Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
    }

    /// A request shell for exercising the dispatch machinery without a
    /// running fleet (the response channel's receiver is dropped, so
    /// sends are harmless no-ops).
    fn dummy_req(m: usize) -> GwRequest {
        let (tx, _rx) = channel();
        GwRequest {
            model: ModelId(m),
            x_q: Vec::new(),
            out: Vec::new(),
            submitted: Instant::now(),
            deadline: None,
            priority: Priority::Normal,
            resp: tx,
        }
    }

    #[test]
    fn drr_dispatch_tracks_weights_under_saturation() {
        // two tenants kept saturated (batchers refilled after every
        // dispatch): rows served must track the 4:1 weights
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) };
        let mut q = ShardQueues::new(2, policy);
        let weights = [4u32, 1];
        let backdated = Instant::now() - Duration::from_secs(60);
        let mut rows = [0usize; 2];
        let mut out = Vec::new();
        for _ in 0..100 {
            for m in 0..2 {
                while q.batchers[m].len() < policy.max_batch {
                    q.batchers[m].push_arrived(backdated, dummy_req(m));
                }
            }
            let pick = q.next_drr(&weights, policy.max_batch, false).expect("both tenants due");
            rows[pick] += q.batchers[pick].drain_into(&mut out);
        }
        assert_eq!(rows[0] + rows[1], 400, "every dispatch drains a full batch");
        let ratio = rows[0] as f64 / rows[1] as f64;
        assert!((3.0..=5.0).contains(&ratio), "rows {rows:?} — want ~4:1, got {ratio:.2}");
    }

    #[test]
    fn drr_starved_high_weight_tenant_overtakes() {
        // cursor parked past tenant 1; a lone due item of the
        // high-weight tenant must still be dispatched before the
        // saturated low-weight tenant
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) };
        let mut q = ShardQueues::new(2, policy);
        let weights = [1u32, 8];
        let backdated = Instant::now() - Duration::from_secs(60);
        for _ in 0..4 {
            q.batchers[0].push_arrived(backdated, dummy_req(0));
        }
        q.batchers[1].push_arrived(backdated, dummy_req(1));
        let pick = q.next_drr(&weights, policy.max_batch, false);
        assert_eq!(pick, Some(1), "starved weight-8 tenant beats the saturated weight-1 one");
    }

    #[test]
    fn drr_single_tenant_is_work_conserving() {
        // a weight-1 tenant alone must be dispatched even though its
        // batch cost exceeds one round's quantum (credit accrues over
        // rounds within the pick)
        let policy = BatchPolicy { max_batch: 32, max_wait: Duration::from_secs(10) };
        let mut q = ShardQueues::new(3, policy);
        let weights = [1u32, 1, 1];
        let backdated = Instant::now() - Duration::from_secs(60);
        for _ in 0..32 {
            q.batchers[2].push_arrived(backdated, dummy_req(2));
        }
        assert_eq!(q.next_drr(&weights, policy.max_batch, false), Some(2));
        let mut out = Vec::new();
        q.batchers[2].drain_into(&mut out);
        assert_eq!(q.next_drr(&weights, policy.max_batch, false), None, "nothing due");
        // not-yet-due items are not dispatched without flush, but are on flush
        q.batchers[0].push(dummy_req(0));
        assert_eq!(q.next_drr(&weights, policy.max_batch, false), None);
        assert_eq!(q.next_drr(&weights, policy.max_batch, true), Some(0));
    }

    #[test]
    fn fixed_dispatch_still_serves_and_conserves() {
        let mut b = GatewayBuilder::with_config(GatewayConfig {
            replicas: 2,
            queue_cap: 64,
            shed: ShedPolicy::Block,
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
            dispatch: Dispatch::Fixed,
        });
        let ea = Engine::new(QuantizedModel::synthetic("a", &[4, 6, 3], 5, 3, 5));
        let eb = Engine::new(QuantizedModel::synthetic("b", &[6, 8, 5], 5, 3, 9));
        let a = b.register("alpha", ea);
        let c = b.register("beta", eb);
        let gw = b.start();
        for i in 0..20u8 {
            assert_eq!(gw.handle(a).infer_q(vec![i; 4]).unwrap().t.len(), 3);
            assert_eq!(gw.handle(c).infer_q(vec![i; 6]).unwrap().t.len(), 5);
        }
        let stats = gw.shutdown();
        assert!(stats.conserved());
        assert_eq!(stats.completed(), 40);
        assert_eq!(stats.stolen_batches(), 0, "fixed dispatch never steals");
    }

    #[test]
    fn weights_surface_in_stats_and_fairness_index() {
        let mut b = GatewayBuilder::with_config(GatewayConfig {
            replicas: 1,
            queue_cap: 16,
            shed: ShedPolicy::Block,
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
            dispatch: Dispatch::FairSteal,
        });
        let ea = Engine::new(QuantizedModel::synthetic("a", &[4, 6, 3], 5, 3, 5));
        let eb = Engine::new(QuantizedModel::synthetic("b", &[6, 8, 5], 5, 3, 9));
        let a = b.register("alpha", ea);
        let _ = b.register_weighted("beta", eb, 5);
        let gw = b.start();
        gw.handle(a).infer_q(vec![1, 2, 3, 4]).unwrap();
        let stats = gw.shutdown();
        assert_eq!(stats.per_model[0].weight, 1);
        assert_eq!(stats.per_model[1].weight, 5);
        // only alpha submitted, so the index covers alpha alone: fair
        assert!((stats.fairness_index() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn expired_deadline_resolves_and_counts_as_shed() {
        let gw = two_model_gateway(1, 64, ShedPolicy::RejectNew);
        let h = gw.handle(ModelId(0));
        // an already-lapsed deadline: the worker must answer (not hang)
        // with DeadlineExceeded before spending compute
        let t = h.submit(Request::from_q(vec![1, 2, 3, 4]).with_deadline(Duration::ZERO)).unwrap();
        assert_eq!(t.wait(), Err(ServeError::DeadlineExceeded));
        // generous deadline: served normally
        let r = h
            .submit(Request::from_q(vec![1, 2, 3, 4]).with_deadline(Duration::from_secs(60)))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.t.len(), 3);
        let stats = gw.shutdown();
        let a = &stats.per_model[0];
        assert_eq!(a.submitted, 2);
        assert_eq!(a.completed, 1);
        assert_eq!(a.expired, 1);
        assert_eq!(a.shed, 1, "expired requests count inside shed");
        assert!(a.conserved());
    }

    #[test]
    fn responses_carry_split_latency() {
        let gw = two_model_gateway(1, 16, ShedPolicy::Block);
        let h = gw.handle(ModelId(1));
        let r = h.infer_q(vec![0, 50, 100, 150, 200, 250]).unwrap();
        assert_eq!(r.latency_us(), r.queue_us + r.service_us);
        let clone = r.clone();
        assert_eq!(clone.t, r.t);
        drop(r);
        drop(clone);
        gw.shutdown();
    }

    #[test]
    fn buffer_pool_recycles() {
        let pool = BufferPool::new(4, 8);
        let a = pool.acquire();
        assert!(a.capacity() >= 4);
        pool.release(a);
        let b = pool.acquire();
        let (created, recycled, free) = pool.counts();
        assert_eq!((created, recycled, free), (1, 1, 0));
        pool.release(b);
        // oversized strays are dropped, not retained
        pool.release(Vec::with_capacity(1024));
        let (_, _, free) = pool.counts();
        assert_eq!(free, 1);
        // undersized strays too
        pool.release(Vec::new());
        let (_, _, free) = pool.counts();
        assert_eq!(free, 1);
    }

    #[test]
    fn response_drop_returns_buffer_to_pool() {
        let gw = two_model_gateway(1, 16, ShedPolicy::Block);
        let h = gw.handle(ModelId(0));
        for _ in 0..20 {
            let r = h.infer_q(vec![5, 6, 7, 8]).unwrap();
            drop(r); // recycle before the next submit
        }
        let stats = gw.shutdown();
        let a = &stats.per_model[0];
        assert_eq!(a.completed, 20);
        assert!(
            a.buffers_created <= 2,
            "serial traffic needs at most a couple of live buffers, created {}",
            a.buffers_created
        );
        assert!(a.buffers_recycled >= 18, "recycled only {}", a.buffers_recycled);
    }

    #[test]
    fn batches_never_mix_models() {
        // one replica, both models loaded concurrently: every batch must
        // be single-model (otherwise dims would mismatch and inference
        // would fail — completed counts prove correctness)
        let gw = two_model_gateway(1, 256, ShedPolicy::Block);
        let ha = gw.handle(ModelId(0));
        let hb = gw.handle(ModelId(1));
        let mut tickets = Vec::new();
        for i in 0..40u8 {
            tickets.push((3usize, ha.submit_q(vec![i, i, i, i]).unwrap()));
            tickets.push((5usize, hb.submit_q(vec![i, i, i, i, i, i]).unwrap()));
        }
        for (want_dim, t) in tickets {
            assert_eq!(t.wait().unwrap().t.len(), want_dim);
        }
        let stats = gw.shutdown();
        assert_eq!(stats.per_model[0].completed, 40);
        assert_eq!(stats.per_model[1].completed, 40);
        assert_eq!(stats.per_model[0].failed + stats.per_model[1].failed, 0);
    }
}
