//! Dynamic batching policy and queue draining.
//!
//! A [`Batcher`] accumulates same-model requests until the batch is
//! *due* (size or deadline, see [`Batcher::ready`]). In the gateway the
//! batchers live in per-worker **shards** that the whole fleet can
//! reach: the owning worker drains them by weighted deficit-round-robin,
//! and an idle peer may steal through the same [`Batcher::drain_upto`]
//! path (the drain is splittable — a thief takes roughly half of an
//! over-full backlog, leaving the rest with their original arrival
//! times, so owner and thief serve the remainder concurrently).

use std::time::{Duration, Instant};

/// When to close a batch.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum requests per batch (bounded by the compiled HLO's static
    /// batch dimension on the fp32 path).
    pub max_batch: usize,
    /// Maximum time the oldest queued request may wait before the batch
    /// is dispatched anyway.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

/// Accumulates items with arrival timestamps and decides dispatch.
///
/// Every item keeps its *true* arrival time: when a full drain leaves
/// items queued, their `max_wait` window keeps counting from arrival
/// instead of restarting (the deadline-reset bug would silently double
/// the tail latency of every overflow request). The pool's workers also
/// backdate arrivals to the admission-queue submit time via
/// [`Batcher::push_arrived`], so the deadline covers shared-queue wait.
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    items: Vec<(Instant, T)>,
    /// Earliest arrival among queued items (cached; recomputed on drain).
    oldest: Option<Instant>,
}

impl<T> Batcher<T> {
    /// An empty batcher governed by `policy`.
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1);
        Self { policy, items: Vec::new(), oldest: None }
    }

    /// Push an item that arrives now.
    pub fn push(&mut self, item: T) {
        self.push_arrived(Instant::now(), item);
    }

    /// Push an item that arrived at `at` (possibly before now: requests
    /// that waited in an upstream admission queue keep that wait on
    /// their deadline clock).
    pub fn push_arrived(&mut self, at: Instant, item: T) {
        self.oldest = Some(match self.oldest {
            Some(t0) => t0.min(at),
            None => at,
        });
        self.items.push((at, item));
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// The batch-size cap this batcher dispatches at (its policy's
    /// `max_batch`). Batchers carry per-tenant policies in the gateway,
    /// so callers must ask the batcher rather than assume a fleet-wide
    /// constant.
    pub fn max_batch(&self) -> usize {
        self.policy.max_batch
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Should the current batch be dispatched now?
    pub fn ready(&self) -> bool {
        if self.items.len() >= self.policy.max_batch {
            return true;
        }
        match self.oldest {
            Some(t0) => !self.items.is_empty() && t0.elapsed() >= self.policy.max_wait,
            None => false,
        }
    }

    /// Time until this batch becomes due (for recv/steal wait timeouts):
    /// zero when already dispatchable — full to `max_batch` or past the
    /// deadline — else the deadline remainder.
    pub fn time_left(&self) -> Duration {
        if self.items.len() >= self.policy.max_batch {
            return Duration::ZERO;
        }
        match self.oldest {
            Some(t0) => self.policy.max_wait.saturating_sub(t0.elapsed()),
            None => self.policy.max_wait,
        }
    }

    /// Age of the oldest queued item (`None` when empty) — how long the
    /// head of this batch has been coalescing. The telemetry spine
    /// stamps this on every batch-formed event.
    pub fn oldest_age(&self) -> Option<Duration> {
        self.oldest.map(|t0| t0.elapsed())
    }

    /// Take up to `max_batch` items (FIFO), leaving the rest queued with
    /// their original arrival times.
    pub fn drain(&mut self) -> Vec<T> {
        let mut batch = Vec::new();
        self.drain_into(&mut batch);
        batch
    }

    /// Like [`Batcher::drain`], but into a caller-owned `Vec` (cleared
    /// first) so a long-lived worker reuses one batch allocation across
    /// every dispatch instead of allocating per drain. Returns the number
    /// of items drained.
    pub fn drain_into(&mut self, out: &mut Vec<T>) -> usize {
        self.drain_upto(out, self.policy.max_batch)
    }

    /// Splittable drain: take up to `limit` of the oldest items (still
    /// capped at `max_batch`) into a caller-owned `Vec` (cleared first),
    /// leaving the remainder queued with their original arrival times.
    /// This is the steal protocol's entry point — a thief draining a
    /// peer's batcher takes one batch worth and the leftover items keep
    /// their deadline clocks. Returns the number of items drained.
    pub fn drain_upto(&mut self, out: &mut Vec<T>, limit: usize) -> usize {
        let take = self.items.len().min(self.policy.max_batch).min(limit);
        out.clear();
        out.extend(self.items.drain(..take).map(|(_, item)| item));
        self.oldest = self.items.iter().map(|&(at, _)| at).min();
        take
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatches_on_size() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(9) });
        b.push(1);
        b.push(2);
        assert!(!b.ready());
        assert!(b.time_left() > Duration::ZERO);
        b.push(3);
        assert!(b.ready());
        assert_eq!(b.time_left(), Duration::ZERO, "size-due batch waits for nothing");
        assert_eq!(b.drain(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn dispatches_on_deadline() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(1) });
        b.push(7);
        std::thread::sleep(Duration::from_millis(3));
        assert!(b.ready());
        assert_eq!(b.drain(), vec![7]);
    }

    #[test]
    fn drain_respects_max_batch_fifo() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(1) });
        for i in 0..5 {
            b.push(i);
        }
        assert_eq!(b.drain(), vec![0, 1]);
        assert_eq!(b.drain(), vec![2, 3]);
        assert_eq!(b.drain(), vec![4]);
    }

    #[test]
    fn empty_never_ready() {
        let b: Batcher<i32> = Batcher::new(BatchPolicy::default());
        assert!(!b.ready());
    }

    #[test]
    fn drain_preserves_leftover_deadline() {
        // regression: drain() used to stamp leftover items with a fresh
        // Instant::now(), restarting their max_wait window on every drain
        let mut b = Batcher::new(BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(40) });
        b.push(1);
        b.push(2);
        std::thread::sleep(Duration::from_millis(50));
        assert!(b.ready());
        assert_eq!(b.drain(), vec![1]);
        // item 2 arrived >40ms ago: already past its deadline
        assert!(b.ready(), "leftover deadline was reset by drain");
        assert_eq!(b.time_left(), Duration::ZERO);
        assert_eq!(b.drain(), vec![2]);
        assert!(b.is_empty());
        assert_eq!(b.time_left(), Duration::from_millis(40));
    }

    #[test]
    fn drain_into_reuses_one_vec() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(1) });
        for i in 0..5 {
            b.push(i);
        }
        let mut batch = Vec::new();
        assert_eq!(b.drain_into(&mut batch), 2);
        assert_eq!(batch, vec![0, 1]);
        let cap = batch.capacity();
        assert_eq!(b.drain_into(&mut batch), 2);
        assert_eq!(batch, vec![2, 3], "drain_into clears, not appends");
        assert_eq!(batch.capacity(), cap, "no reallocation across drains");
        assert_eq!(b.drain_into(&mut batch), 1);
        assert_eq!(batch, vec![4]);
        assert_eq!(b.drain_into(&mut batch), 0);
        assert!(batch.is_empty());
    }

    #[test]
    fn drain_upto_splits_and_preserves_leftover_arrivals() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(40) });
        let t0 = Instant::now() - Duration::from_millis(200);
        for i in 0..6 {
            b.push_arrived(t0 + Duration::from_millis(i), i);
        }
        let mut out = Vec::new();
        // a thief takes a split batch; the leftover keeps its clock
        assert_eq!(b.drain_upto(&mut out, 4), 4);
        assert_eq!(out, vec![0, 1, 2, 3], "oldest items stolen first (FIFO)");
        assert_eq!(b.len(), 2);
        assert!(b.ready(), "leftover arrivals still past their deadline");
        assert_eq!(b.time_left(), Duration::ZERO);
        // limit above max_batch still caps at max_batch
        assert_eq!(b.drain_upto(&mut out, 99), 2);
        assert!(b.is_empty());
    }

    #[test]
    fn push_arrived_backdates_deadline() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) });
        b.push_arrived(Instant::now() - Duration::from_millis(200), 1);
        assert!(b.ready(), "backdated arrival must count toward max_wait");
        assert_eq!(b.time_left(), Duration::ZERO);
    }
}
