//! Dynamic batching policy and queue draining.
//!
//! A [`Batcher`] accumulates same-model requests until the batch is
//! *due* (size or deadline, see [`Batcher::ready`]). In the gateway the
//! batchers live in per-worker **shards** that the whole fleet can
//! reach: the owning worker drains them by weighted deficit-round-robin,
//! and an idle peer may steal through the same [`Batcher::drain_upto`]
//! path (the drain is splittable — a thief takes roughly half of an
//! over-full backlog, leaving the rest with their original arrival
//! times, so owner and thief serve the remainder concurrently).
//!
//! The batcher never reads the wall clock itself: arrivals are stamped
//! in `u64` microseconds on the caller's [`Clock`](super::Clock) and
//! every time-dependent query ([`Batcher::ready`],
//! [`Batcher::time_left`], [`Batcher::oldest_age`]) takes the current
//! `now_us` explicitly. That makes batching deadlines a pure function
//! of (arrivals, now) — deterministic under a manual test clock.

use std::time::Duration;

/// When to close a batch.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Maximum requests per batch (bounded by the compiled HLO's static
    /// batch dimension on the fp32 path).
    pub max_batch: usize,
    /// Maximum time the oldest queued request may wait before the batch
    /// is dispatched anyway.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_batch: 32, max_wait: Duration::from_millis(2) }
    }
}

impl BatchPolicy {
    /// `max_wait` in the µs unit the batcher computes in.
    fn max_wait_us(&self) -> u64 {
        self.max_wait.as_micros() as u64
    }
}

/// Accumulates items with arrival timestamps and decides dispatch.
///
/// Every item keeps its *true* arrival time: when a full drain leaves
/// items queued, their `max_wait` window keeps counting from arrival
/// instead of restarting (the deadline-reset bug would silently double
/// the tail latency of every overflow request). The pool's workers also
/// backdate arrivals to the admission-queue submit time via
/// [`Batcher::push_arrived`], so the deadline covers shared-queue wait.
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    items: Vec<(u64, T)>,
    /// Earliest arrival (µs) among queued items (cached; recomputed on
    /// drain).
    oldest: Option<u64>,
}

impl<T> Batcher<T> {
    /// An empty batcher governed by `policy`.
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1);
        Self { policy, items: Vec::new(), oldest: None }
    }

    /// Push an item that arrived at `at_us` on the owning gateway's
    /// clock (possibly before now: requests that waited in an upstream
    /// admission queue keep that wait on their deadline clock).
    pub fn push_arrived(&mut self, at_us: u64, item: T) {
        self.oldest = Some(match self.oldest {
            Some(t0) => t0.min(at_us),
            None => at_us,
        });
        self.items.push((at_us, item));
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// The batch-size cap this batcher dispatches at (its policy's
    /// `max_batch`). Batchers carry per-tenant policies in the gateway,
    /// so callers must ask the batcher rather than assume a fleet-wide
    /// constant.
    pub fn max_batch(&self) -> usize {
        self.policy.max_batch
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Should the current batch be dispatched at `now_us`?
    pub fn ready(&self, now_us: u64) -> bool {
        if self.items.len() >= self.policy.max_batch {
            return true;
        }
        match self.oldest {
            Some(t0) => {
                !self.items.is_empty() && now_us.saturating_sub(t0) >= self.policy.max_wait_us()
            }
            None => false,
        }
    }

    /// Time until this batch becomes due (for recv/steal wait timeouts):
    /// zero when already dispatchable — full to `max_batch` or past the
    /// deadline — else the deadline remainder as of `now_us`.
    pub fn time_left(&self, now_us: u64) -> Duration {
        if self.items.len() >= self.policy.max_batch {
            return Duration::ZERO;
        }
        match self.oldest {
            Some(t0) => Duration::from_micros(
                self.policy.max_wait_us().saturating_sub(now_us.saturating_sub(t0)),
            ),
            None => self.policy.max_wait,
        }
    }

    /// Age of the oldest queued item as of `now_us` (`None` when
    /// empty) — how long the head of this batch has been coalescing.
    /// The telemetry spine stamps this on every batch-formed event.
    pub fn oldest_age(&self, now_us: u64) -> Option<Duration> {
        self.oldest.map(|t0| Duration::from_micros(now_us.saturating_sub(t0)))
    }

    /// Take up to `max_batch` items (FIFO), leaving the rest queued with
    /// their original arrival times.
    pub fn drain(&mut self) -> Vec<T> {
        let mut batch = Vec::new();
        self.drain_into(&mut batch);
        batch
    }

    /// Like [`Batcher::drain`], but into a caller-owned `Vec` (cleared
    /// first) so a long-lived worker reuses one batch allocation across
    /// every dispatch instead of allocating per drain. Returns the number
    /// of items drained.
    pub fn drain_into(&mut self, out: &mut Vec<T>) -> usize {
        self.drain_upto(out, self.policy.max_batch)
    }

    /// Splittable drain: take up to `limit` of the oldest items (still
    /// capped at `max_batch`) into a caller-owned `Vec` (cleared first),
    /// leaving the remainder queued with their original arrival times.
    /// This is the steal protocol's entry point — a thief draining a
    /// peer's batcher takes one batch worth and the leftover items keep
    /// their deadline clocks. Returns the number of items drained.
    pub fn drain_upto(&mut self, out: &mut Vec<T>, limit: usize) -> usize {
        let take = self.items.len().min(self.policy.max_batch).min(limit);
        out.clear();
        out.extend(self.items.drain(..take).map(|(_, item)| item));
        self.oldest = self.items.iter().map(|&(at, _)| at).min();
        take
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MS: u64 = 1_000;

    #[test]
    fn dispatches_on_size() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(9) });
        b.push_arrived(0, 1);
        b.push_arrived(0, 2);
        assert!(!b.ready(0));
        assert!(b.time_left(0) > Duration::ZERO);
        b.push_arrived(0, 3);
        assert!(b.ready(0));
        assert_eq!(b.time_left(0), Duration::ZERO, "size-due batch waits for nothing");
        assert_eq!(b.drain(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn dispatches_on_deadline() {
        // pure virtual time: no thread::sleep, the deadline fires when
        // the caller's clock passes arrival + max_wait
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(1) });
        b.push_arrived(0, 7);
        assert!(!b.ready(MS - 1));
        assert!(b.ready(MS));
        assert_eq!(b.drain(), vec![7]);
    }

    #[test]
    fn drain_respects_max_batch_fifo() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(1) });
        for i in 0..5 {
            b.push_arrived(0, i);
        }
        assert_eq!(b.drain(), vec![0, 1]);
        assert_eq!(b.drain(), vec![2, 3]);
        assert_eq!(b.drain(), vec![4]);
    }

    #[test]
    fn empty_never_ready() {
        let b: Batcher<i32> = Batcher::new(BatchPolicy::default());
        assert!(!b.ready(u64::MAX));
    }

    #[test]
    fn drain_preserves_leftover_deadline() {
        // regression: drain() used to restamp leftover items with the
        // drain time, restarting their max_wait window on every drain
        let mut b = Batcher::new(BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(40) });
        b.push_arrived(0, 1);
        b.push_arrived(0, 2);
        let now = 50 * MS;
        assert!(b.ready(now));
        assert_eq!(b.drain(), vec![1]);
        // item 2 arrived >40ms ago: already past its deadline
        assert!(b.ready(now), "leftover deadline was reset by drain");
        assert_eq!(b.time_left(now), Duration::ZERO);
        assert_eq!(b.oldest_age(now), Some(Duration::from_millis(50)));
        assert_eq!(b.drain(), vec![2]);
        assert!(b.is_empty());
        assert_eq!(b.time_left(now), Duration::from_millis(40));
        assert_eq!(b.oldest_age(now), None);
    }

    #[test]
    fn drain_into_reuses_one_vec() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(1) });
        for i in 0..5 {
            b.push_arrived(0, i);
        }
        let mut batch = Vec::new();
        assert_eq!(b.drain_into(&mut batch), 2);
        assert_eq!(batch, vec![0, 1]);
        let cap = batch.capacity();
        assert_eq!(b.drain_into(&mut batch), 2);
        assert_eq!(batch, vec![2, 3], "drain_into clears, not appends");
        assert_eq!(batch.capacity(), cap, "no reallocation across drains");
        assert_eq!(b.drain_into(&mut batch), 1);
        assert_eq!(batch, vec![4]);
        assert_eq!(b.drain_into(&mut batch), 0);
        assert!(batch.is_empty());
    }

    #[test]
    fn drain_upto_splits_and_preserves_leftover_arrivals() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(40) });
        for i in 0..6u64 {
            b.push_arrived(i * MS, i);
        }
        let now = 200 * MS;
        let mut out = Vec::new();
        // a thief takes a split batch; the leftover keeps its clock
        assert_eq!(b.drain_upto(&mut out, 4), 4);
        assert_eq!(out, vec![0, 1, 2, 3], "oldest items stolen first (FIFO)");
        assert_eq!(b.len(), 2);
        assert!(b.ready(now), "leftover arrivals still past their deadline");
        assert_eq!(b.time_left(now), Duration::ZERO);
        assert_eq!(b.oldest_age(now), Some(Duration::from_micros(196 * MS)));
        // limit above max_batch still caps at max_batch
        assert_eq!(b.drain_upto(&mut out, 99), 2);
        assert!(b.is_empty());
    }

    #[test]
    fn push_arrived_backdates_deadline() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) });
        // arrival 200ms before the caller's now
        b.push_arrived(0, 1);
        assert!(b.ready(200 * MS), "backdated arrival must count toward max_wait");
        assert_eq!(b.time_left(200 * MS), Duration::ZERO);
    }

    #[test]
    fn now_before_arrival_saturates() {
        // a thief's clock read can race an arrival stamped slightly
        // later; age/deadline math must saturate, not underflow
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) });
        b.push_arrived(5 * MS, 1);
        assert!(!b.ready(0));
        assert_eq!(b.oldest_age(0), Some(Duration::ZERO));
        assert_eq!(b.time_left(0), Duration::from_millis(10));
    }
}
