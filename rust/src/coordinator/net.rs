//! Network front door: a framed binary wire protocol over TCP.
//!
//! Everything below the gateway is reachable in-process only; this
//! module is the socket. A [`NetServer`] accepts connections on a
//! `std::net` listener (tokio is not available offline — the design is
//! thread-per-connection: one reader + one writer thread each), speaks a
//! length-prefixed framed protocol, and decodes request rows *straight
//! into gateway admission slots*: the reader acquires a pooled row
//! buffer from the target model's row pool
//! ([`ModelHandle::acquire_row`]), reads the quantized payload into it,
//! and submits — after warmup the decode path performs zero heap
//! allocations (`tests/net_alloc.rs` gates the codec with the counting
//! allocator). A pipelined [`NetClient`] multiplexes many logical
//! requests over one connection via correlation ids.
//!
//! # Frame layout
//!
//! Every frame starts with a fixed 32-byte header (all integers
//! little-endian):
//!
//! | offset | size | field |
//! |-------:|-----:|-------|
//! | 0      | 4    | magic `"KSN1"` |
//! | 4      | 1    | protocol version (1) |
//! | 5      | 1    | frame type |
//! | 6      | 1    | request priority / error code |
//! | 7      | 1    | reserved (0) |
//! | 8      | 8    | correlation id |
//! | 16     | 4    | model id |
//! | 20     | 8    | relative deadline in microseconds (0 = none) |
//! | 28     | 4    | payload length |
//!
//! Frame types: `1` InferRequest (payload = one quantized u8 row of the
//! model's `in_dim`), `2` InferOk (payload = `queue_us` u64 +
//! `service_us` u64 + `out_dim` i64 logits), `3` Error (payload = UTF-8
//! message, typed by the header code byte), `4`/`5` StatsRequest /
//! StatsResponse (payload = [`crate::coordinator::Telemetry::snapshot`]
//! JSON), `6`/`7` ModelsRequest / ModelsResponse (payload = the model
//! directory as JSON, so remote clients resolve names to wire ids and
//! row widths).
//!
//! # Connection lifecycle and conservation
//!
//! The reader thread owns admission; the writer thread owns ticket
//! resolution (in submission order per connection — correlation ids let
//! the client match replies to requests). A malformed header (bad
//! magic/version/type) with a sane length is answered with a typed
//! `Malformed` error frame and the connection survives; an oversized
//! length closes the connection after the error frame (framing can no
//! longer be trusted). When a client disconnects mid-flight the reader
//! exits and the writer *drains* every in-flight [`Ticket`] — the
//! gateway still serves and counts each admitted request, so per-model
//! `submitted == completed + shed + failed` holds across drops.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use super::gateway::{Gateway, ModelHandle, Priority, Request, ServeError, Ticket};
use super::telemetry::Telemetry;
use crate::util::json::Value;

/// First four bytes of every frame.
pub const MAGIC: [u8; 4] = *b"KSN1";
/// Wire protocol version carried in byte 4 of the header.
pub const VERSION: u8 = 1;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 32;

/// Frame type tags (header byte 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum FrameType {
    /// Client → server: one quantized input row for one model.
    InferRequest = 1,
    /// Server → client: logits + split timing for a served request.
    InferOk = 2,
    /// Server → client: a typed [`ServeError`] (code in header byte 6).
    Error = 3,
    /// Client → server: ask for a live telemetry snapshot.
    StatsRequest = 4,
    /// Server → client: `Telemetry::snapshot()` rendered as JSON.
    StatsResponse = 5,
    /// Client → server: ask for the model directory.
    ModelsRequest = 6,
    /// Server → client: registered models as JSON (`id`, `name`,
    /// `in_dim`, `out_dim`).
    ModelsResponse = 7,
}

impl FrameType {
    fn from_u8(b: u8) -> Option<FrameType> {
        Some(match b {
            1 => FrameType::InferRequest,
            2 => FrameType::InferOk,
            3 => FrameType::Error,
            4 => FrameType::StatsRequest,
            5 => FrameType::StatsResponse,
            6 => FrameType::ModelsRequest,
            7 => FrameType::ModelsResponse,
            _ => return None,
        })
    }
}

/// Wire error codes (header byte 6 of an [`FrameType::Error`] frame).
/// Codes 1–6 map one-to-one onto [`ServeError`]; 7 is a protocol-level
/// framing error the in-process API has no equivalent for.
pub mod code {
    /// Admission queue full ([`super::ServeError::QueueFull`]).
    pub const QUEUE_FULL: u8 = 1;
    /// Deadline lapsed ([`super::ServeError::DeadlineExceeded`]).
    pub const DEADLINE: u8 = 2;
    /// Gateway stopped ([`super::ServeError::Closed`]).
    pub const CLOSED: u8 = 3;
    /// Row validation failed ([`super::ServeError::InvalidInput`]).
    pub const INVALID_INPUT: u8 = 4;
    /// No such model ([`super::ServeError::UnknownModel`]).
    pub const UNKNOWN_MODEL: u8 = 5;
    /// Engine failure ([`super::ServeError::Inference`]).
    pub const INFERENCE: u8 = 6;
    /// Malformed frame (bad magic, version, type, or length).
    pub const MALFORMED: u8 = 7;
}

/// The wire code for a [`ServeError`].
pub fn error_to_code(e: &ServeError) -> u8 {
    match e {
        ServeError::QueueFull => code::QUEUE_FULL,
        ServeError::DeadlineExceeded => code::DEADLINE,
        ServeError::Closed => code::CLOSED,
        ServeError::InvalidInput(_) => code::INVALID_INPUT,
        ServeError::UnknownModel(_) => code::UNKNOWN_MODEL,
        ServeError::Inference(_) => code::INFERENCE,
    }
}

/// Reconstruct a typed [`ServeError`] from a wire error frame. The
/// protocol-only `MALFORMED` code (and any unknown code) maps to
/// [`ServeError::InvalidInput`] with the server's message.
pub fn error_from_wire(c: u8, msg: &str) -> ServeError {
    match c {
        code::QUEUE_FULL => ServeError::QueueFull,
        code::DEADLINE => ServeError::DeadlineExceeded,
        code::CLOSED => ServeError::Closed,
        code::INVALID_INPUT => ServeError::InvalidInput(msg.to_string()),
        code::UNKNOWN_MODEL => ServeError::UnknownModel(msg.to_string()),
        code::INFERENCE => ServeError::Inference(msg.to_string()),
        _ => ServeError::InvalidInput(format!("protocol: {msg}")),
    }
}

/// A decoded frame header.
///
/// ```
/// use kan_sas::coordinator::net::{FrameHeader, FrameType, HEADER_LEN};
///
/// let h = FrameHeader {
///     ty: FrameType::InferRequest,
///     code: 0,
///     corr: 42,
///     model: 1,
///     deadline_us: 2_000,
///     len: 64,
/// };
/// let mut buf = [0u8; HEADER_LEN];
/// h.encode(&mut buf);
/// assert_eq!(FrameHeader::decode(&buf).unwrap(), h);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    /// Frame type tag.
    pub ty: FrameType,
    /// Request priority class (0 = tenant default, 1 = low, 2 = normal,
    /// 3 = high) on requests; the error code on error frames; 0
    /// otherwise.
    pub code: u8,
    /// Correlation id echoed on the matching response frame.
    pub corr: u64,
    /// Wire model id (the gateway registration slot).
    pub model: u32,
    /// Relative deadline in microseconds from admission (0 = none).
    pub deadline_us: u64,
    /// Payload length in bytes.
    pub len: u32,
}

/// Why a frame header failed to decode.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// The first four bytes are not [`MAGIC`].
    BadMagic([u8; 4]),
    /// Unsupported protocol version.
    BadVersion(u8),
    /// Unknown frame type tag.
    BadType(u8),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::BadMagic(m) => write!(f, "bad magic {m:02x?} (want \"KSN1\")"),
            FrameError::BadVersion(v) => write!(f, "unsupported protocol version {v} (want 1)"),
            FrameError::BadType(t) => write!(f, "unknown frame type {t}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl FrameHeader {
    /// Serialize into a fixed header buffer (no allocation).
    pub fn encode(&self, out: &mut [u8; HEADER_LEN]) {
        out[0..4].copy_from_slice(&MAGIC);
        out[4] = VERSION;
        out[5] = self.ty as u8;
        out[6] = self.code;
        out[7] = 0;
        out[8..16].copy_from_slice(&self.corr.to_le_bytes());
        out[16..20].copy_from_slice(&self.model.to_le_bytes());
        out[20..28].copy_from_slice(&self.deadline_us.to_le_bytes());
        out[28..32].copy_from_slice(&self.len.to_le_bytes());
    }

    /// Parse a fixed header buffer. The payload length is returned as
    /// read — the caller enforces its own `max_frame` bound, because
    /// whether an oversized frame is survivable depends on whether the
    /// header itself was trusted.
    pub fn decode(buf: &[u8; HEADER_LEN]) -> Result<FrameHeader, FrameError> {
        if buf[0..4] != MAGIC {
            return Err(FrameError::BadMagic([buf[0], buf[1], buf[2], buf[3]]));
        }
        if buf[4] != VERSION {
            return Err(FrameError::BadVersion(buf[4]));
        }
        let ty = FrameType::from_u8(buf[5]).ok_or(FrameError::BadType(buf[5]))?;
        Ok(FrameHeader {
            ty,
            code: buf[6],
            corr: u64::from_le_bytes(buf[8..16].try_into().expect("8 bytes")),
            model: u32::from_le_bytes(buf[16..20].try_into().expect("4 bytes")),
            deadline_us: u64::from_le_bytes(buf[20..28].try_into().expect("8 bytes")),
            len: u32::from_le_bytes(buf[28..32].try_into().expect("4 bytes")),
        })
    }
}

fn put_header(buf: &mut Vec<u8>, h: &FrameHeader) {
    let mut hdr = [0u8; HEADER_LEN];
    h.encode(&mut hdr);
    buf.extend_from_slice(&hdr);
}

/// Encode an infer request into `buf` (cleared first). With a
/// warmed-up `buf` the encode performs no allocations.
pub fn encode_request(
    buf: &mut Vec<u8>,
    corr: u64,
    model: u32,
    row: &[u8],
    deadline_us: u64,
    priority: u8,
) {
    buf.clear();
    put_header(
        buf,
        &FrameHeader {
            ty: FrameType::InferRequest,
            code: priority,
            corr,
            model,
            deadline_us,
            len: row.len() as u32,
        },
    );
    buf.extend_from_slice(row);
}

/// Encode an [`FrameType::InferOk`] response into `buf` (cleared
/// first): split timing followed by the logits row.
pub fn encode_response(buf: &mut Vec<u8>, corr: u64, queue_us: u64, service_us: u64, t: &[i64]) {
    buf.clear();
    put_header(
        buf,
        &FrameHeader {
            ty: FrameType::InferOk,
            code: 0,
            corr,
            model: 0,
            deadline_us: 0,
            len: (16 + 8 * t.len()) as u32,
        },
    );
    buf.extend_from_slice(&queue_us.to_le_bytes());
    buf.extend_from_slice(&service_us.to_le_bytes());
    for v in t {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode a typed error frame into `buf` (cleared first).
pub fn encode_error(buf: &mut Vec<u8>, corr: u64, c: u8, msg: &str) {
    buf.clear();
    put_header(
        buf,
        &FrameHeader {
            ty: FrameType::Error,
            code: c,
            corr,
            model: 0,
            deadline_us: 0,
            len: msg.len() as u32,
        },
    );
    buf.extend_from_slice(msg.as_bytes());
}

/// Encode a payload-free control frame (stats / models request).
pub fn encode_control(buf: &mut Vec<u8>, ty: FrameType, corr: u64) {
    buf.clear();
    put_header(buf, &FrameHeader { ty, code: 0, corr, model: 0, deadline_us: 0, len: 0 });
}

/// Encode a JSON-payload response frame (stats / models response).
pub fn encode_json(buf: &mut Vec<u8>, ty: FrameType, corr: u64, json: &str) {
    buf.clear();
    put_header(
        buf,
        &FrameHeader { ty, code: 0, corr, model: 0, deadline_us: 0, len: json.len() as u32 },
    );
    buf.extend_from_slice(json.as_bytes());
}

/// Decode an [`FrameType::InferOk`] payload into a logits buffer
/// (cleared first; with sufficient capacity the decode performs no
/// allocations). Returns `(queue_us, service_us)`.
pub fn decode_ok_payload(payload: &[u8], t: &mut Vec<i64>) -> Result<(u64, u64), ServeError> {
    if payload.len() < 16 || (payload.len() - 16) % 8 != 0 {
        return Err(ServeError::InvalidInput(format!(
            "protocol: InferOk payload of {} bytes (want 16 + 8*out_dim)",
            payload.len()
        )));
    }
    let queue_us = u64::from_le_bytes(payload[0..8].try_into().expect("8 bytes"));
    let service_us = u64::from_le_bytes(payload[8..16].try_into().expect("8 bytes"));
    t.clear();
    for chunk in payload[16..].chunks_exact(8) {
        t.push(i64::from_le_bytes(chunk.try_into().expect("8 bytes")));
    }
    Ok((queue_us, service_us))
}

/// Tuning for both ends of the wire (the config file's `net` stanza).
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Listen address for `kansas serve --listen` when the flag carries
    /// no explicit address (`None` = the flag must name one).
    pub listen: Option<String>,
    /// Maximum accepted payload length; a header announcing more closes
    /// the connection after a typed error frame.
    pub max_frame: usize,
    /// Maximum concurrently served connections; further accepts are
    /// answered with an error frame and closed.
    pub max_conns: usize,
    /// Set `TCP_NODELAY` on every connection (both ends). On by
    /// default: the protocol is request/response over small frames,
    /// where Nagle-delayed acks dominate measured latency.
    pub nodelay: bool,
}

impl Default for NetConfig {
    fn default() -> Self {
        Self { listen: None, max_frame: 1 << 20, max_conns: 1024, nodelay: true }
    }
}

/// Live counters for a [`NetServer`].
#[derive(Clone, Copy, Debug, Default)]
pub struct NetStats {
    /// Connections accepted over the server's lifetime.
    pub accepted: u64,
    /// Connections currently open.
    pub active: usize,
    /// Request/control frames fully decoded.
    pub frames_in: u64,
    /// Response/error frames written.
    pub frames_out: u64,
    /// Malformed frames answered with a `MALFORMED` error.
    pub malformed: u64,
}

struct ServerShared {
    /// Registered models indexed by wire id (the registration slot; a
    /// removed tenant's slot is `None` and answers `UnknownModel`).
    by_slot: Vec<Option<ModelHandle>>,
    telemetry: Arc<Telemetry>,
    stop: AtomicBool,
    cfg: NetConfig,
    accepted: AtomicU64,
    active: AtomicUsize,
    frames_in: AtomicU64,
    frames_out: AtomicU64,
    malformed: AtomicU64,
}

impl ServerShared {
    fn handle(&self, wire_id: u32) -> Option<&ModelHandle> {
        self.by_slot.get(wire_id as usize).and_then(|h| h.as_ref())
    }

    fn models_json(&self) -> String {
        let models: Vec<Value> = self
            .by_slot
            .iter()
            .flatten()
            .map(|h| {
                Value::obj([
                    ("id", Value::num(h.model_id().0 as f64)),
                    ("name", Value::str(h.name())),
                    ("in_dim", Value::num(h.in_dim() as f64)),
                    ("out_dim", Value::num(h.out_dim() as f64)),
                ])
            })
            .collect();
        Value::obj([("models", Value::Arr(models))]).render()
    }
}

/// What the reader hands the writer thread, in submission order.
enum Reply {
    /// An admitted request: resolve the ticket, then answer.
    Flight(u64, Ticket),
    /// An immediate typed error (admission failure or protocol error).
    Reject(u64, u8, String),
    /// A JSON control response.
    Json(u64, FrameType, String),
}

/// The TCP front door for a running [`Gateway`].
///
/// Start one with [`NetServer::start`]; it accepts connections until
/// [`NetServer::shutdown`], which stops accepting, lets every open
/// connection drain its in-flight requests, and joins all threads.
/// Shut the server down *before* the gateway so drains can complete.
///
/// # Examples
///
/// ```
/// use kan_sas::coordinator::net::{NetClient, NetConfig, NetServer};
/// use kan_sas::coordinator::{GatewayBuilder, GatewayConfig};
/// use kan_sas::kan::{Engine, QuantizedModel};
///
/// let mut b = GatewayBuilder::with_config(GatewayConfig {
///     replicas: 1,
///     ..Default::default()
/// });
/// b.register("demo", Engine::new(QuantizedModel::synthetic("demo", &[4, 6, 3], 5, 3, 9)));
/// let gateway = b.start();
///
/// let server = NetServer::start("127.0.0.1:0", &gateway, NetConfig::default())?;
/// let client = NetClient::connect(&server.local_addr().to_string())?;
/// let demo = client.handle("demo")?;
/// let resp = demo.infer_q(vec![10, 20, 30, 40])?;
/// assert_eq!(resp.t.len(), 3);
/// drop(client);
/// server.shutdown();
/// gateway.shutdown();
/// # Ok::<(), Box<dyn std::error::Error>>(())
/// ```
pub struct NetServer {
    shared: Arc<ServerShared>,
    local: SocketAddr,
    accept: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving every model registered on `gateway` at call time.
    /// Models hot-added later are not reachable over this server.
    pub fn start(addr: &str, gateway: &Gateway, cfg: NetConfig) -> io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let mut by_slot: Vec<Option<ModelHandle>> = Vec::new();
        for h in gateway.handles() {
            let slot = h.model_id().0;
            // keep wire id == registration slot; a removed tenant's
            // hole stays `None` and answers UnknownModel
            if by_slot.len() <= slot {
                by_slot.resize_with(slot + 1, || None);
            }
            by_slot[slot] = Some(h);
        }
        let shared = Arc::new(ServerShared {
            by_slot,
            telemetry: gateway.telemetry(),
            stop: AtomicBool::new(false),
            cfg,
            accepted: AtomicU64::new(0),
            active: AtomicUsize::new(0),
            frames_in: AtomicU64::new(0),
            frames_out: AtomicU64::new(0),
            malformed: AtomicU64::new(0),
        });
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = Arc::clone(&shared);
            let conns = Arc::clone(&conns);
            thread::Builder::new().name("net-accept".into()).spawn(move || {
                accept_loop(listener, shared, conns);
            })?
        };
        Ok(NetServer { shared, local, accept: Some(accept), conns })
    }

    /// The actually bound address (resolves an ephemeral `:0` port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Connections currently open.
    pub fn connections(&self) -> usize {
        self.shared.active.load(Ordering::Relaxed)
    }

    /// Live server counters.
    pub fn stats(&self) -> NetStats {
        NetStats {
            accepted: self.shared.accepted.load(Ordering::Relaxed),
            active: self.shared.active.load(Ordering::Relaxed),
            frames_in: self.shared.frames_in.load(Ordering::Relaxed),
            frames_out: self.shared.frames_out.load(Ordering::Relaxed),
            malformed: self.shared.malformed.load(Ordering::Relaxed),
        }
    }

    /// Stop accepting, drain every open connection (in-flight requests
    /// are still answered), and join all threads. Returns the final
    /// counters.
    pub fn shutdown(mut self) -> NetStats {
        self.stop_and_join();
        self.stats()
    }

    fn stop_and_join(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<_> = self.conns.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(
    listener: TcpListener,
    shared: Arc<ServerShared>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    while !shared.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.accepted.fetch_add(1, Ordering::Relaxed);
                if shared.active.load(Ordering::Relaxed) >= shared.cfg.max_conns {
                    refuse(stream, "connection limit reached");
                    continue;
                }
                shared.active.fetch_add(1, Ordering::Relaxed);
                let sh = Arc::clone(&shared);
                let conn = thread::Builder::new()
                    .name("net-conn".into())
                    .spawn(move || {
                        serve_connection(stream, &sh);
                        sh.active.fetch_sub(1, Ordering::Relaxed);
                    })
                    .expect("spawn connection thread");
                let mut cs = conns.lock().unwrap();
                cs.retain(|h| !h.is_finished());
                cs.push(conn);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(10));
            }
            Err(_) => thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Answer a refused connection with a single error frame, best-effort.
fn refuse(mut stream: TcpStream, msg: &str) {
    let mut buf = Vec::with_capacity(HEADER_LEN + msg.len());
    encode_error(&mut buf, 0, code::CLOSED, msg);
    let _ = stream.write_all(&buf);
    let _ = stream.shutdown(Shutdown::Both);
}

/// `read_exact` against a read-timeout socket: keeps the fill offset
/// across `WouldBlock`/`TimedOut` so a stop-flag poll never tears a
/// frame. Returns `false` on EOF/error or when `stop` was raised before
/// any byte of this read arrived (mid-frame reads keep going so a drain
/// finishes cleanly).
fn read_full(stream: &mut TcpStream, buf: &mut [u8], stop: &AtomicBool) -> bool {
    let mut filled = 0usize;
    while filled < buf.len() {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => return false,
            Ok(n) => filled += n,
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                if filled == 0 && stop.load(Ordering::SeqCst) {
                    return false;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return false,
        }
    }
    true
}

/// Skip `len` payload bytes after a frame whose payload is not wanted
/// (malformed or rejected before admission).
fn skip_payload(stream: &mut TcpStream, len: usize, scratch: &mut Vec<u8>, stop: &AtomicBool) -> bool {
    let mut left = len;
    while left > 0 {
        let take = left.min(4096);
        scratch.resize(take, 0);
        if !read_full(stream, &mut scratch[..take], stop) {
            return false;
        }
        left -= take;
    }
    true
}

/// One connection: this (reader) thread decodes frames into gateway
/// admission; a paired writer thread resolves tickets and writes
/// responses. Exits on EOF, socket error, protocol loss of sync, or
/// server stop — then joins the writer, which drains all in-flight
/// tickets first.
fn serve_connection(mut stream: TcpStream, shared: &ServerShared) {
    let _ = stream.set_nodelay(shared.cfg.nodelay);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (tx, rx) = channel::<Reply>();
    let writer = match thread::Builder::new()
        .name("net-write".into())
        .spawn(move || write_loop(write_half, rx))
    {
        Ok(w) => w,
        Err(_) => return,
    };

    let mut hdr = [0u8; HEADER_LEN];
    let mut scratch: Vec<u8> = Vec::new();
    let stop = &shared.stop;
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        if !read_full(&mut stream, &mut hdr, stop) {
            break;
        }
        let h = match FrameHeader::decode(&hdr) {
            Ok(h) => h,
            Err(e) => {
                // the length field sits at a fixed offset, so even a
                // bad-magic header tells us how much to skip — if it is
                // believable. Past max_frame the stream cannot be
                // resynced; answer and close.
                shared.malformed.fetch_add(1, Ordering::Relaxed);
                let len = u32::from_le_bytes(hdr[28..32].try_into().expect("4 bytes")) as usize;
                let corr = u64::from_le_bytes(hdr[8..16].try_into().expect("8 bytes"));
                let survivable = len <= shared.cfg.max_frame;
                let _ = tx.send(Reply::Reject(corr, code::MALFORMED, e.to_string()));
                if !survivable || !skip_payload(&mut stream, len, &mut scratch, stop) {
                    break;
                }
                continue;
            }
        };
        let len = h.len as usize;
        if len > shared.cfg.max_frame {
            shared.malformed.fetch_add(1, Ordering::Relaxed);
            let msg = format!("frame of {len} bytes exceeds max_frame {}", shared.cfg.max_frame);
            let _ = tx.send(Reply::Reject(h.corr, code::MALFORMED, msg));
            break;
        }
        shared.frames_in.fetch_add(1, Ordering::Relaxed);
        match h.ty {
            FrameType::InferRequest => {
                let Some(handle) = shared.handle(h.model) else {
                    let _ = tx.send(Reply::Reject(
                        h.corr,
                        code::UNKNOWN_MODEL,
                        format!("unknown model id {}", h.model),
                    ));
                    if !skip_payload(&mut stream, len, &mut scratch, stop) {
                        break;
                    }
                    continue;
                };
                if len != handle.in_dim() {
                    let msg = format!(
                        "input dim {len} != model '{}' dim {}",
                        handle.name(),
                        handle.in_dim()
                    );
                    let _ = tx.send(Reply::Reject(h.corr, code::INVALID_INPUT, msg));
                    if !skip_payload(&mut stream, len, &mut scratch, stop) {
                        break;
                    }
                    continue;
                }
                // decode straight into an admission slot: the payload
                // lands in a pooled row buffer that `submit` hands to
                // the gateway, and the serving worker recycles
                let mut row = handle.acquire_row();
                row.resize(len, 0);
                if !read_full(&mut stream, &mut row, stop) {
                    break;
                }
                let mut req = Request::from_q(row);
                if h.deadline_us > 0 {
                    req = req.with_deadline(Duration::from_micros(h.deadline_us));
                }
                req = match h.code {
                    1 => req.with_priority(Priority::Low),
                    2 => req.with_priority(Priority::Normal),
                    3 => req.with_priority(Priority::High),
                    _ => req,
                };
                match handle.submit(req) {
                    Ok(t) => {
                        let _ = tx.send(Reply::Flight(h.corr, t));
                    }
                    Err(e) => {
                        let _ = tx.send(Reply::Reject(h.corr, error_to_code(&e), e.to_string()));
                    }
                }
            }
            FrameType::StatsRequest => {
                let json = shared.telemetry.snapshot().to_value().render();
                let _ = tx.send(Reply::Json(h.corr, FrameType::StatsResponse, json));
            }
            FrameType::ModelsRequest => {
                let _ = tx.send(Reply::Json(
                    h.corr,
                    FrameType::ModelsResponse,
                    shared.models_json(),
                ));
            }
            FrameType::InferOk
            | FrameType::Error
            | FrameType::StatsResponse
            | FrameType::ModelsResponse => {
                // response types are server → client only
                shared.malformed.fetch_add(1, Ordering::Relaxed);
                let msg = format!("unexpected {:?} frame from a client", h.ty);
                let _ = tx.send(Reply::Reject(h.corr, code::MALFORMED, msg));
                if !skip_payload(&mut stream, len, &mut scratch, stop) {
                    break;
                }
            }
        }
    }
    // Reader is done: close the submit side. The writer drains every
    // queued reply (waiting in-flight tickets out — the gateway counts
    // them whether or not the peer still reads), then exits.
    drop(tx);
    let frames = writer.join().unwrap_or(0);
    shared.frames_out.fetch_add(frames, Ordering::Relaxed);
    let _ = stream.shutdown(Shutdown::Both);
}

/// Writer half of a connection: resolves replies in submission order
/// into one reusable encode buffer. Write errors flip the connection to
/// drain-only — remaining tickets are still waited (conservation), the
/// bytes just go nowhere. Returns the frame count it wrote.
fn write_loop(mut stream: TcpStream, rx: Receiver<Reply>) -> u64 {
    let mut buf: Vec<u8> = Vec::with_capacity(4096);
    let mut dead = false;
    let mut frames = 0u64;
    while let Ok(reply) = rx.recv() {
        match reply {
            Reply::Flight(corr, ticket) => match ticket.wait() {
                Ok(resp) => {
                    encode_response(&mut buf, corr, resp.queue_us, resp.service_us, &resp.t);
                }
                Err(e) => encode_error(&mut buf, corr, error_to_code(&e), &e.to_string()),
            },
            Reply::Reject(corr, c, msg) => encode_error(&mut buf, corr, c, &msg),
            Reply::Json(corr, ty, json) => encode_json(&mut buf, ty, corr, &json),
        }
        if !dead {
            if stream.write_all(&buf).is_err() {
                dead = true;
            } else {
                frames += 1;
            }
        }
    }
    let _ = stream.flush();
    frames
}

// ---------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------

/// A response received over the wire.
#[derive(Clone, Debug)]
pub struct RemoteResponse {
    /// Final-layer i64 accumulators for the row (argmax = class).
    pub t: Vec<i64>,
    /// Server-side queueing + batching delay in microseconds.
    pub queue_us: u64,
    /// Server-side compute + scatter time in microseconds.
    pub service_us: u64,
    /// Client-observed submit→receive latency in microseconds (wire
    /// time included; stamped by the client's reader thread).
    pub e2e_us: u64,
}

enum ClientReply {
    Infer(RemoteResponse),
    Json(String),
}

type PendingSlot = (Instant, Sender<Result<ClientReply, ServeError>>);

struct ClientShared {
    /// Write half + its reusable encode buffer, serialized under one
    /// lock so frames never interleave.
    writer: Mutex<(TcpStream, Vec<u8>)>,
    pending: Mutex<HashMap<u64, PendingSlot>>,
    next_corr: AtomicU64,
    closed: AtomicBool,
}

impl ClientShared {
    fn send_frame(&self, encode: impl FnOnce(&mut Vec<u8>)) -> Result<(), ServeError> {
        if self.closed.load(Ordering::SeqCst) {
            return Err(ServeError::Closed);
        }
        let mut w = self.writer.lock().unwrap();
        let (stream, buf) = &mut *w;
        encode(buf);
        stream.write_all(buf).map_err(|_| {
            self.closed.store(true, Ordering::SeqCst);
            ServeError::Closed
        })
    }

    fn register(&self) -> (u64, Receiver<Result<ClientReply, ServeError>>) {
        let corr = self.next_corr.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        self.pending.lock().unwrap().insert(corr, (Instant::now(), tx));
        (corr, rx)
    }

    fn unregister(&self, corr: u64) {
        self.pending.lock().unwrap().remove(&corr);
    }
}

/// A pipelined client for a [`NetServer`]: many logical requests share
/// one TCP connection, matched to their replies by correlation id. All
/// methods are callable from any thread; submissions from different
/// threads interleave at frame granularity.
///
/// Clone [`RemoteHandle`]s (one per model, from [`NetClient::handle`] /
/// [`NetClient::handles`]) to drive load; they stay valid for the
/// client's lifetime. Dropping the client closes the connection — any
/// unresolved tickets then answer [`ServeError::Closed`].
pub struct NetClient {
    shared: Arc<ClientShared>,
    reader: Option<JoinHandle<()>>,
    max_frame: usize,
}

impl NetClient {
    /// Connect with default [`NetConfig`] tuning.
    pub fn connect(addr: &str) -> io::Result<NetClient> {
        Self::connect_with(addr, NetConfig::default())
    }

    /// Connect to a listening server.
    pub fn connect_with(addr: &str, cfg: NetConfig) -> io::Result<NetClient> {
        let mut last = io::Error::new(io::ErrorKind::AddrNotAvailable, "no address resolved");
        let mut stream = None;
        for a in addr.to_socket_addrs()? {
            match TcpStream::connect(a) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => last = e,
            }
        }
        let stream = stream.ok_or(last)?;
        let _ = stream.set_nodelay(cfg.nodelay);
        let read_half = stream.try_clone()?;
        let shared = Arc::new(ClientShared {
            writer: Mutex::new((stream, Vec::with_capacity(4096))),
            pending: Mutex::new(HashMap::new()),
            next_corr: AtomicU64::new(1),
            closed: AtomicBool::new(false),
        });
        let reader = {
            let shared = Arc::clone(&shared);
            let max_frame = cfg.max_frame;
            thread::Builder::new()
                .name("net-client-read".into())
                .spawn(move || client_read_loop(read_half, &shared, max_frame))?
        };
        Ok(NetClient { shared, reader: Some(reader), max_frame: cfg.max_frame })
    }

    /// The server's model directory (a `ModelsRequest` round trip).
    pub fn models(&self) -> Result<Vec<RemoteModel>, ServeError> {
        let (corr, rx) = self.shared.register();
        if let Err(e) =
            self.shared.send_frame(|buf| encode_control(buf, FrameType::ModelsRequest, corr))
        {
            self.shared.unregister(corr);
            return Err(e);
        }
        let json = match rx.recv().map_err(|_| ServeError::Closed)?? {
            ClientReply::Json(j) => j,
            ClientReply::Infer(_) => {
                return Err(ServeError::InvalidInput("protocol: infer reply to models".into()))
            }
        };
        let v = Value::parse(&json)
            .map_err(|e| ServeError::InvalidInput(format!("protocol: models JSON: {e}")))?;
        let arr = v
            .get("models")
            .and_then(Value::as_arr)
            .ok_or_else(|| ServeError::InvalidInput("protocol: models JSON shape".into()))?;
        let mut out = Vec::with_capacity(arr.len());
        for m in arr {
            out.push(RemoteModel {
                id: m.get("id").and_then(Value::as_usize).unwrap_or(0) as u32,
                name: m.get("name").and_then(Value::as_str).unwrap_or("").to_string(),
                in_dim: m.get("in_dim").and_then(Value::as_usize).unwrap_or(0),
                out_dim: m.get("out_dim").and_then(Value::as_usize).unwrap_or(0),
            });
        }
        Ok(out)
    }

    /// A submission handle for every registered model, in wire-id order.
    pub fn handles(&self) -> Result<Vec<RemoteHandle>, ServeError> {
        Ok(self.models()?.into_iter().map(|m| self.handle_for(&m)).collect())
    }

    /// A submission handle for the model registered as `name`.
    pub fn handle(&self, name: &str) -> Result<RemoteHandle, ServeError> {
        self.models()?
            .into_iter()
            .find(|m| m.name == name)
            .map(|m| self.handle_for(&m))
            .ok_or_else(|| ServeError::UnknownModel(name.to_string()))
    }

    /// A submission handle for an already-fetched directory entry.
    pub fn handle_for(&self, model: &RemoteModel) -> RemoteHandle {
        RemoteHandle {
            shared: Arc::clone(&self.shared),
            id: model.id,
            name: Arc::from(model.name.as_str()),
            in_dim: model.in_dim,
            out_dim: model.out_dim,
            rows: Arc::new(Mutex::new(Vec::new())),
        }
    }

    /// A live [`Telemetry::snapshot`] from the server, as rendered JSON
    /// (a `StatsRequest` round trip). Sampled trace spans are *moved*
    /// into whichever snapshot claims them first, so a polling remote
    /// client drains spans the serving process would otherwise print.
    pub fn stats_json(&self) -> Result<String, ServeError> {
        let (corr, rx) = self.shared.register();
        if let Err(e) =
            self.shared.send_frame(|buf| encode_control(buf, FrameType::StatsRequest, corr))
        {
            self.shared.unregister(corr);
            return Err(e);
        }
        match rx.recv().map_err(|_| ServeError::Closed)?? {
            ClientReply::Json(j) => Ok(j),
            ClientReply::Infer(_) => {
                Err(ServeError::InvalidInput("protocol: infer reply to stats".into()))
            }
        }
    }

    /// Maximum payload this client will accept on a response frame.
    pub fn max_frame(&self) -> usize {
        self.max_frame
    }

    /// Close the connection and join the reader thread. Outstanding
    /// tickets resolve [`ServeError::Closed`].
    pub fn close(mut self) {
        self.close_inner();
    }

    fn close_inner(&mut self) {
        self.shared.closed.store(true, Ordering::SeqCst);
        if let Ok(w) = self.shared.writer.lock() {
            let _ = w.0.shutdown(Shutdown::Both);
        }
        if let Some(r) = self.reader.take() {
            let _ = r.join();
        }
    }
}

impl Drop for NetClient {
    fn drop(&mut self) {
        self.close_inner();
    }
}

/// One entry of the server's model directory.
#[derive(Clone, Debug)]
pub struct RemoteModel {
    /// Wire model id (the gateway registration slot).
    pub id: u32,
    /// Registered model name.
    pub name: String,
    /// Input row width in bytes.
    pub in_dim: usize,
    /// Logits row width.
    pub out_dim: usize,
}

/// A cloneable, typed submission handle for one remote model — the
/// wire twin of [`ModelHandle`]. Submissions multiplex over the owning
/// [`NetClient`]'s connection.
#[derive(Clone)]
pub struct RemoteHandle {
    shared: Arc<ClientShared>,
    id: u32,
    name: Arc<str>,
    in_dim: usize,
    out_dim: usize,
    /// Client-side free-list of row buffers: a row is recycled as soon
    /// as its bytes hit the socket, so a steady-state driver reuses the
    /// same buffers instead of allocating per request.
    rows: Arc<Mutex<Vec<Vec<u8>>>>,
}

impl RemoteHandle {
    /// The registered model name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Wire model id.
    pub fn id(&self) -> u32 {
        self.id
    }

    /// Input row width (quantized activations).
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    /// Logits row width.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// An empty row buffer with `in_dim` capacity — recycled from this
    /// handle's free-list when available.
    pub fn acquire_row(&self) -> Vec<u8> {
        self.rows.lock().unwrap().pop().unwrap_or_else(|| Vec::with_capacity(self.in_dim))
    }

    /// Submit one quantized row with optional deadline and priority;
    /// returns a [`RemoteTicket`] without waiting. The row buffer is
    /// recycled onto this handle's free-list once written to the wire.
    pub fn submit(
        &self,
        mut row: Vec<u8>,
        deadline: Option<Duration>,
        priority: Option<Priority>,
    ) -> Result<RemoteTicket, ServeError> {
        if row.len() != self.in_dim {
            return Err(ServeError::InvalidInput(format!(
                "input dim {} != model '{}' dim {}",
                row.len(),
                self.name,
                self.in_dim
            )));
        }
        let deadline_us = deadline.map(|d| d.as_micros() as u64).unwrap_or(0);
        let pri = match priority {
            None => 0,
            Some(Priority::Low) => 1,
            Some(Priority::Normal) => 2,
            Some(Priority::High) => 3,
        };
        let (corr, rx) = self.shared.register();
        let submitted = Instant::now();
        let sent = self
            .shared
            .send_frame(|buf| encode_request(buf, corr, self.id, &row, deadline_us, pri));
        if let Err(e) = sent {
            self.shared.unregister(corr);
            return Err(e);
        }
        row.clear();
        let mut rows = self.rows.lock().unwrap();
        if rows.len() < 64 && row.capacity() >= self.in_dim {
            rows.push(row);
        }
        drop(rows);
        Ok(RemoteTicket { rx, submitted })
    }

    /// Submit with default options (no deadline, tenant-default
    /// priority).
    pub fn submit_q(&self, row: Vec<u8>) -> Result<RemoteTicket, ServeError> {
        self.submit(row, None, None)
    }

    /// Blocking convenience: submit one row and wait for its response.
    pub fn infer_q(&self, row: Vec<u8>) -> Result<RemoteResponse, ServeError> {
        self.submit_q(row)?.wait()
    }
}

/// A claim on one in-flight remote request. Dropping it abandons the
/// answer client-side (the server still serves and counts it).
pub struct RemoteTicket {
    rx: Receiver<Result<ClientReply, ServeError>>,
    /// When the request frame was written.
    pub submitted: Instant,
}

impl RemoteTicket {
    /// Block until the response frame arrives (or the connection dies,
    /// which resolves [`ServeError::Closed`]).
    pub fn wait(self) -> Result<RemoteResponse, ServeError> {
        match self.rx.recv().map_err(|_| ServeError::Closed)?? {
            ClientReply::Infer(r) => Ok(r),
            ClientReply::Json(_) => {
                Err(ServeError::InvalidInput("protocol: json reply to infer".into()))
            }
        }
    }

    /// Non-blocking poll; `None` while the response is still in flight.
    pub fn try_wait(&self) -> Option<Result<RemoteResponse, ServeError>> {
        match self.rx.try_recv() {
            Ok(Ok(ClientReply::Infer(r))) => Some(Ok(r)),
            Ok(Ok(ClientReply::Json(_))) => {
                Some(Err(ServeError::InvalidInput("protocol: json reply to infer".into())))
            }
            Ok(Err(e)) => Some(Err(e)),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => Some(Err(ServeError::Closed)),
        }
    }
}

/// Client reader: match response frames to pending correlation ids. On
/// EOF or a framing error, fail every pending request with `Closed`.
fn client_read_loop(mut stream: TcpStream, shared: &ClientShared, max_frame: usize) {
    let mut hdr = [0u8; HEADER_LEN];
    let mut payload: Vec<u8> = Vec::new();
    let never = AtomicBool::new(false);
    loop {
        if !read_full(&mut stream, &mut hdr, &never) {
            break;
        }
        let h = match FrameHeader::decode(&hdr) {
            Ok(h) => h,
            Err(_) => break, // server never sends garbage; lost sync
        };
        let len = h.len as usize;
        if len > max_frame {
            break;
        }
        payload.resize(len, 0);
        if !read_full(&mut stream, &mut payload, &never) {
            break;
        }
        let slot = shared.pending.lock().unwrap().remove(&h.corr);
        let Some((submitted, tx)) = slot else { continue };
        let reply = match h.ty {
            FrameType::InferOk => {
                let mut t = Vec::new();
                match decode_ok_payload(&payload, &mut t) {
                    Ok((queue_us, service_us)) => Ok(ClientReply::Infer(RemoteResponse {
                        t,
                        queue_us,
                        service_us,
                        e2e_us: submitted.elapsed().as_micros() as u64,
                    })),
                    Err(e) => Err(e),
                }
            }
            FrameType::Error => {
                let msg = String::from_utf8_lossy(&payload);
                Err(error_from_wire(h.code, &msg))
            }
            FrameType::StatsResponse | FrameType::ModelsResponse => {
                Ok(ClientReply::Json(String::from_utf8_lossy(&payload).into_owned()))
            }
            _ => Err(ServeError::InvalidInput(format!(
                "protocol: unexpected {:?} frame from server",
                h.ty
            ))),
        };
        let _ = tx.send(reply);
    }
    shared.closed.store(true, Ordering::SeqCst);
    let pending: Vec<PendingSlot> =
        shared.pending.lock().unwrap().drain().map(|(_, slot)| slot).collect();
    for (_, tx) in pending {
        let _ = tx.send(Err(ServeError::Closed));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_round_trip_all_types() {
        for (ty, c) in [
            (FrameType::InferRequest, 3),
            (FrameType::InferOk, 0),
            (FrameType::Error, code::MALFORMED),
            (FrameType::StatsRequest, 0),
            (FrameType::StatsResponse, 0),
            (FrameType::ModelsRequest, 0),
            (FrameType::ModelsResponse, 0),
        ] {
            let h = FrameHeader {
                ty,
                code: c,
                corr: 0xDEAD_BEEF_0BAD_CAFE,
                model: 7,
                deadline_us: 123_456,
                len: 99,
            };
            let mut buf = [0u8; HEADER_LEN];
            h.encode(&mut buf);
            assert_eq!(FrameHeader::decode(&buf).unwrap(), h);
        }
    }

    #[test]
    fn decode_rejects_bad_magic_version_type() {
        let h = FrameHeader {
            ty: FrameType::InferRequest,
            code: 0,
            corr: 1,
            model: 0,
            deadline_us: 0,
            len: 4,
        };
        let mut buf = [0u8; HEADER_LEN];
        h.encode(&mut buf);
        let mut bad = buf;
        bad[0] = b'X';
        assert!(matches!(FrameHeader::decode(&bad), Err(FrameError::BadMagic(_))));
        let mut bad = buf;
        bad[4] = 9;
        assert_eq!(FrameHeader::decode(&bad), Err(FrameError::BadVersion(9)));
        let mut bad = buf;
        bad[5] = 200;
        assert_eq!(FrameHeader::decode(&bad), Err(FrameError::BadType(200)));
    }

    #[test]
    fn response_payload_round_trip() {
        let logits = [5i64, -3, 1 << 40];
        let mut buf = Vec::new();
        encode_response(&mut buf, 9, 100, 250, &logits);
        let h = FrameHeader::decode(buf[..HEADER_LEN].try_into().unwrap()).unwrap();
        assert_eq!(h.ty, FrameType::InferOk);
        assert_eq!(h.corr, 9);
        assert_eq!(h.len as usize, buf.len() - HEADER_LEN);
        let mut t = Vec::new();
        let (q, s) = decode_ok_payload(&buf[HEADER_LEN..], &mut t).unwrap();
        assert_eq!((q, s), (100, 250));
        assert_eq!(t, logits);
    }

    #[test]
    fn error_code_round_trip() {
        let cases = [
            ServeError::QueueFull,
            ServeError::DeadlineExceeded,
            ServeError::Closed,
            ServeError::InvalidInput("dim".into()),
            ServeError::UnknownModel("m".into()),
            ServeError::Inference("boom".into()),
        ];
        for e in cases {
            let c = error_to_code(&e);
            let back = error_from_wire(c, &e.to_string());
            assert_eq!(std::mem::discriminant(&back), std::mem::discriminant(&e));
        }
        assert!(matches!(
            error_from_wire(code::MALFORMED, "bad magic"),
            ServeError::InvalidInput(_)
        ));
    }

    #[test]
    fn truncated_ok_payload_is_typed() {
        let mut t = Vec::new();
        assert!(decode_ok_payload(&[0u8; 10], &mut t).is_err());
        assert!(decode_ok_payload(&[0u8; 21], &mut t).is_err());
    }
}
