//! Injectable time source for the serving stack.
//!
//! Every control decision in the coordinator is a function of time:
//! batcher `max_wait` deadlines, telemetry window rolls, and the
//! autoscaler's SLO evaluation all ask "what time is it / how long has
//! this waited". [`Clock`] abstracts that question so production runs
//! on the monotonic wall clock while tests inject a manually-advanced
//! clock ([`Clock::manual`]) and step virtual time deterministically —
//! no `sleep(...); hope the race resolved` in the assertions.
//!
//! Timestamps are plain `u64` microseconds since the clock's origin
//! (process start for [`Clock::real`], zero for [`Clock::manual`]).
//! A `u64` µs stamp is POD, atomically storable, and costs nothing to
//! copy through the request hot path — reading the real clock is one
//! `Instant::elapsed`, with no lock and no allocation.
//!
//! Sleeping threads (the telemetry collector, the autoscaler) park on
//! [`Clock::sleep`]. On the real clock that is a plain timed wait that
//! [`Clock::wake_all`] can cut short (prompt shutdown); on a manual
//! clock it blocks until [`Clock::advance`] moves virtual time or
//! `wake_all` fires, so a test drives every tick explicitly.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Waiter state shared by every clone of a [`Clock`]: a generation
/// counter bumped by [`Clock::advance`] / [`Clock::wake_all`] plus (for
/// manual clocks) the virtual now.
#[derive(Debug)]
struct Waiters {
    state: Mutex<WaitState>,
    cv: Condvar,
}

#[derive(Debug)]
struct WaitState {
    /// Virtual microseconds (manual clocks only; unused on real clocks).
    now_us: u64,
    /// Bumped on every `advance`/`wake_all`; sleepers return when it
    /// moves so shutdown never waits out a full tick.
    generation: u64,
}

impl Waiters {
    fn new() -> Arc<Self> {
        let state = Mutex::new(WaitState { now_us: 0, generation: 0 });
        Arc::new(Self { state, cv: Condvar::new() })
    }
}

#[derive(Clone, Debug)]
enum Inner {
    /// Monotonic wall clock; stamps are µs since `origin`.
    Real { origin: Instant, waiters: Arc<Waiters> },
    /// Manually-advanced virtual clock; stamps are µs since creation.
    Manual(Arc<Waiters>),
}

/// A cloneable time source: monotonic wall clock in production, a
/// manually-advanced virtual clock in tests. Clones share one origin
/// and one waiter set, so a component holding a clone observes the
/// same timeline (and the same [`Clock::advance`] calls) as every
/// other holder.
///
/// ```
/// use std::time::Duration;
/// use kan_sas::coordinator::Clock;
///
/// let clock = Clock::manual();
/// assert_eq!(clock.now_us(), 0);
/// clock.advance(Duration::from_millis(5));
/// assert_eq!(clock.now_us(), 5_000);
/// let real = Clock::real();
/// assert!(!real.is_manual());
/// ```
#[derive(Clone, Debug)]
pub struct Clock(Inner);

impl Default for Clock {
    fn default() -> Self {
        Self::real()
    }
}

impl Clock {
    /// The monotonic wall clock, with its origin at the call.
    pub fn real() -> Self {
        Clock(Inner::Real { origin: Instant::now(), waiters: Waiters::new() })
    }

    /// A manually-advanced virtual clock starting at 0 µs. Time moves
    /// only through [`Clock::advance`].
    pub fn manual() -> Self {
        Clock(Inner::Manual(Waiters::new()))
    }

    /// True for [`Clock::manual`] clocks.
    pub fn is_manual(&self) -> bool {
        matches!(self.0, Inner::Manual(_))
    }

    /// Microseconds since the clock's origin.
    pub fn now_us(&self) -> u64 {
        match &self.0 {
            Inner::Real { origin, .. } => origin.elapsed().as_micros() as u64,
            Inner::Manual(w) => w.state.lock().unwrap().now_us,
        }
    }

    /// Advance a manual clock by `d` and wake every sleeper. Panics on
    /// a real clock — advancing wall time is a test-harness bug.
    pub fn advance(&self, d: Duration) {
        match &self.0 {
            Inner::Real { .. } => panic!("Clock::advance on a real clock"),
            Inner::Manual(w) => {
                let mut st = w.state.lock().unwrap();
                st.now_us = st.now_us.saturating_add(d.as_micros() as u64);
                st.generation += 1;
                w.cv.notify_all();
            }
        }
    }

    /// Park the calling thread for `d`. Returns early when
    /// [`Clock::advance`] or [`Clock::wake_all`] fires, so periodic
    /// loops must re-check their own stop/ready condition after every
    /// return (a spurious early return is harmless by design). On a
    /// manual clock with no concurrent `advance` this blocks
    /// indefinitely — virtual time only moves when the test moves it.
    pub fn sleep(&self, d: Duration) {
        match &self.0 {
            Inner::Real { waiters, .. } => {
                let st = waiters.state.lock().unwrap();
                let gen0 = st.generation;
                // timed wait instead of thread::sleep so wake_all gives
                // prompt shutdown; ignore the timeout/wake distinction
                let _unused = waiters
                    .cv
                    .wait_timeout_while(st, d, |s| s.generation == gen0)
                    .unwrap();
            }
            Inner::Manual(w) => {
                let mut st = w.state.lock().unwrap();
                let target = st.now_us.saturating_add(d.as_micros() as u64);
                let gen0 = st.generation;
                while st.now_us < target && st.generation == gen0 {
                    st = w.cv.wait(st).unwrap();
                }
            }
        }
    }

    /// Wake every thread parked in [`Clock::sleep`] without moving
    /// time. Shutdown paths call this after setting their stop flags so
    /// collector/controller threads exit promptly instead of waiting
    /// out their tick.
    pub fn wake_all(&self) {
        let w = match &self.0 {
            Inner::Real { waiters, .. } => waiters,
            Inner::Manual(w) => w,
        };
        let mut st = w.state.lock().unwrap();
        st.generation += 1;
        w.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};

    #[test]
    fn real_clock_moves_forward() {
        let c = Clock::real();
        assert!(!c.is_manual());
        let a = c.now_us();
        let b = c.now_us();
        assert!(b >= a);
    }

    #[test]
    fn manual_clock_only_moves_on_advance() {
        let c = Clock::manual();
        assert!(c.is_manual());
        assert_eq!(c.now_us(), 0);
        let c2 = c.clone();
        c.advance(Duration::from_micros(250));
        assert_eq!(c2.now_us(), 250, "clones share the timeline");
        c2.advance(Duration::from_millis(1));
        assert_eq!(c.now_us(), 1_250);
    }

    #[test]
    fn manual_sleep_blocks_until_advance() {
        let c = Clock::manual();
        let woke = Arc::new(AtomicBool::new(false));
        let (c2, woke2) = (c.clone(), woke.clone());
        let h = std::thread::spawn(move || {
            c2.sleep(Duration::from_millis(10));
            woke2.store(true, Ordering::SeqCst);
        });
        // the sleeper must not return while virtual time is short of
        // the target (bounded real-time check, no virtual advance yet)
        std::thread::sleep(Duration::from_millis(20));
        assert!(!woke.load(Ordering::SeqCst), "slept past virtual target without advance");
        c.advance(Duration::from_millis(10));
        h.join().unwrap();
        assert!(woke.load(Ordering::SeqCst));
    }

    #[test]
    fn wake_all_releases_manual_sleepers() {
        let c = Clock::manual();
        let c2 = c.clone();
        let h = std::thread::spawn(move || c2.sleep(Duration::from_secs(3600)));
        std::thread::sleep(Duration::from_millis(5));
        c.wake_all();
        h.join().unwrap();
        assert_eq!(c.now_us(), 0, "wake_all moves no time");
    }

    #[test]
    fn real_sleep_cut_short_by_wake() {
        let c = Clock::real();
        let c2 = c.clone();
        let t0 = Instant::now();
        let h = std::thread::spawn(move || c2.sleep(Duration::from_secs(30)));
        std::thread::sleep(Duration::from_millis(5));
        c.wake_all();
        h.join().unwrap();
        assert!(t0.elapsed() < Duration::from_secs(5), "wake_all must not wait out the sleep");
    }

    #[test]
    #[should_panic(expected = "advance on a real clock")]
    fn advancing_real_clock_panics() {
        Clock::real().advance(Duration::from_secs(1));
    }
}
