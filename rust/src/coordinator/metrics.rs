//! Request-level metrics: latency percentiles and throughput.

use std::time::Duration;

/// Online latency collector (stores all samples; serving runs here are
/// bounded, so memory is a non-issue and exact percentiles beat sketches).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    latencies_us: Vec<u64>,
    pub batches: u64,
    pub batch_rows: u64,
    pub sim_cycles: u64,
}

#[derive(Clone, Copy, Debug)]
pub struct LatencyStats {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

impl Metrics {
    pub fn record_request(&mut self, latency: Duration) {
        self.latencies_us.push(latency.as_micros() as u64);
    }

    pub fn record_batch(&mut self, rows: usize, sim_cycles: u64) {
        self.batches += 1;
        self.batch_rows += rows as u64;
        self.sim_cycles += sim_cycles;
    }

    pub fn merge(&mut self, other: &Metrics) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.batches += other.batches;
        self.batch_rows += other.batch_rows;
        self.sim_cycles += other.sim_cycles;
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.batch_rows as f64 / self.batches as f64
    }

    pub fn latency(&self) -> Option<LatencyStats> {
        if self.latencies_us.is_empty() {
            return None;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let pct = |p: f64| v[((v.len() as f64 * p) as usize).min(v.len() - 1)];
        Some(LatencyStats {
            count: v.len(),
            mean_us: v.iter().sum::<u64>() as f64 / v.len() as f64,
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            max_us: *v.last().unwrap(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_sorted() {
        let mut m = Metrics::default();
        for us in [5u64, 1, 9, 3, 7, 2, 8, 4, 6, 10] {
            m.record_request(Duration::from_micros(us));
        }
        let s = m.latency().unwrap();
        assert_eq!(s.count, 10);
        assert_eq!(s.p50_us, 6);
        assert_eq!(s.max_us, 10);
        assert!((s.mean_us - 5.5).abs() < 1e-9);
    }

    #[test]
    fn empty_latency_none() {
        assert!(Metrics::default().latency().is_none());
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Metrics::default();
        a.record_batch(4, 100);
        let mut b = Metrics::default();
        b.record_batch(8, 200);
        b.record_request(Duration::from_micros(5));
        a.merge(&b);
        assert_eq!(a.batches, 2);
        assert_eq!(a.batch_rows, 12);
        assert_eq!(a.sim_cycles, 300);
        assert!((a.mean_batch_size() - 6.0).abs() < 1e-9);
        assert_eq!(a.latency().unwrap().count, 1);
    }
}
