//! Request-level metrics: latency percentiles (end-to-end and
//! queueing-only), throughput, steal accounting, and attached
//! accelerator-simulation counters. One `Metrics` cell exists per
//! (replica, model); cells merge into per-model, per-replica, and
//! gateway-level stats. [`jain_fairness`] condenses per-model service
//! into the raw fairness index the dispatch experiments track, and
//! [`jain_fairness_normalized`] is its demand-normalized companion:
//! Jain over `served / min(demand, weighted share)`, which isolates
//! *scheduler* fairness from the arrival mix below saturation.
//!
//! Latency distributions default to a bounded [`LogHistogram`] (fixed
//! 7.8 KiB per stream, ≤ ~3.2% relative quantile error), so a serving
//! cell's memory no longer grows with the request count. Benches that
//! want exact percentiles opt back into sample retention with
//! [`Metrics::exact`].

use std::time::Duration;

use crate::sim::SimStats;

/// Values below this record into exact unit-width buckets.
const LINEAR_CUTOFF: u64 = 32;
/// Log-spaced sub-buckets per power of two above the cutoff.
const SUBBUCKETS: usize = 16;
/// Octaves covered above the cutoff (exponents 5..=63 inclusive).
const OCTAVES: usize = 59;
/// Total bucket count of a [`LogHistogram`].
pub const HIST_BUCKETS: usize = LINEAR_CUTOFF as usize + OCTAVES * SUBBUCKETS;

/// Bucket index for a value: identity below [`LINEAR_CUTOFF`], then the
/// top five significant bits select one of [`SUBBUCKETS`] sub-buckets
/// inside the value's octave. Width of a bucket is `2^(l-4)` for a value
/// with leading bit `l`, so the representative midpoint is at most
/// `~1/32` (3.2%) away from any member in relative terms.
fn bucket_index(v: u64) -> usize {
    if v < LINEAR_CUTOFF {
        return v as usize;
    }
    let l = 63 - v.leading_zeros() as usize; // 5..=63
    let sub = ((v >> (l - 4)) as usize) & (SUBBUCKETS - 1);
    LINEAR_CUTOFF as usize + (l - 5) * SUBBUCKETS + sub
}

/// Midpoint of a bucket's value range (inverse of [`bucket_index`]).
fn representative(idx: usize) -> u64 {
    if idx < LINEAR_CUTOFF as usize {
        return idx as u64;
    }
    let r = idx - LINEAR_CUTOFF as usize;
    let l = 5 + r / SUBBUCKETS;
    let sub = (r % SUBBUCKETS) as u64;
    let lo = (SUBBUCKETS as u64 + sub) << (l - 4);
    let width = 1u64 << (l - 4);
    lo + (width - 1) / 2
}

/// Bounded log-bucketed histogram over `u64` samples (microseconds in
/// every current use). Fixed memory ([`HIST_BUCKETS`] counters),
/// O(1) record with no allocation, quantiles within ~3.2% relative
/// error (exact below [`LINEAR_CUTOFF`]). The min/max extremes are
/// tracked exactly, and quantiles clamp to them, so tiny sample sets
/// behave like the exact path. Shared by serving [`Metrics`] cells and
/// the telemetry collector's per-window latency series.
#[derive(Clone, Debug)]
pub struct LogHistogram {
    counts: Box<[u64]>,
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    /// An empty histogram (allocates its fixed bucket array once).
    pub fn new() -> Self {
        Self {
            counts: vec![0u64; HIST_BUCKETS].into_boxed_slice(),
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Record one sample. Never allocates.
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact largest recorded sample (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Arithmetic mean of recorded samples (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum as f64 / self.count as f64
    }

    /// Reset to empty without releasing the bucket array (the telemetry
    /// collector rolls windows allocation-free through this).
    pub fn clear(&mut self) {
        self.counts.fill(0);
        self.count = 0;
        self.sum = 0;
        self.min = u64::MAX;
        self.max = 0;
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (c, o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += o;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// The `p`-quantile (p in [0, 1]) with the same rank convention as
    /// the exact path: the sample at index `min(floor(count*p), count-1)`
    /// of the sorted stream, reported as its bucket's midpoint.
    pub fn quantile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((self.count as f64 * p) as u64).min(self.count - 1);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return representative(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fraction of recorded samples `<= v` (1.0 when empty — a stream
    /// with no samples violates no bound). Resolution is one bucket:
    /// samples sharing `v`'s bucket all count as within, so the answer
    /// carries the same ~3.2% relative-value error as the quantiles.
    /// This is the SLO-attainment lens the autoscale bench reads.
    pub fn fraction_le(&self, v: u64) -> f64 {
        if self.count == 0 {
            return 1.0;
        }
        let idx = bucket_index(v);
        let within: u64 = self.counts[..=idx].iter().sum();
        within as f64 / self.count as f64
    }

    /// Percentile summary in [`LatencyStats`] form; `None` when empty.
    pub fn stats(&self) -> Option<LatencyStats> {
        if self.count == 0 {
            return None;
        }
        Some(LatencyStats {
            count: self.count as usize,
            mean_us: self.mean(),
            p50_us: self.quantile(0.50),
            p95_us: self.quantile(0.95),
            p99_us: self.quantile(0.99),
            max_us: self.max,
        })
    }
}

/// Request-latency collector. The default mode records into bounded
/// [`LogHistogram`]s (fixed memory per cell no matter how long the
/// gateway serves); [`Metrics::exact`] cells additionally retain every
/// sample for exact percentiles (benches and short analysis runs).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Retain raw samples for exact percentiles (bench mode).
    exact: bool,
    /// Raw end-to-end samples (exact mode only).
    latencies_us: Vec<u64>,
    /// Raw queueing samples, parallel to `latencies_us` (exact mode only).
    queue_samples_us: Vec<u64>,
    /// Bounded end-to-end latency distribution (always maintained).
    latency_hist: LogHistogram,
    /// Bounded queueing-delay distribution (always maintained); the
    /// fairness experiments read its percentiles through
    /// [`Metrics::queue_latency`] because starvation shows up in queue
    /// time, not service time.
    queue_hist: LogHistogram,
    /// Requests recorded (the divisor for the mean splits).
    requests: u64,
    /// Sum of per-request *queueing* microseconds (admission → batch
    /// serve start); with `service_us_sum` this splits the end-to-end
    /// latency so shed-policy experiments can separate waiting from
    /// compute.
    pub queue_us_sum: u64,
    /// Sum of per-request *service* microseconds (batch serve start →
    /// response sent).
    pub service_us_sum: u64,
    /// Batches served.
    pub batches: u64,
    /// Rows served across all batches.
    pub batch_rows: u64,
    /// Of `batches`, how many this worker *stole* from a backlogged
    /// peer's batcher shard instead of draining its own (always 0 under
    /// fixed dispatch).
    pub stolen_batches: u64,
    /// Simulated accelerator cycles attached to the served batches.
    pub sim_cycles: u64,
    /// Lane-slot denominator of the simulated utilization (Figs. 7a/8).
    pub sim_active_slots: u64,
    /// Useful-MAC numerator of the simulated utilization.
    pub sim_useful_macs: u64,
}

/// Summary of one latency distribution. Percentiles are exact in
/// [`Metrics::exact`] mode and bucket midpoints (≤ ~3.2% relative
/// error) in the default histogram mode; `max_us` is exact in both.
#[derive(Clone, Copy, Debug)]
pub struct LatencyStats {
    /// Number of samples.
    pub count: usize,
    /// Arithmetic mean, microseconds.
    pub mean_us: f64,
    /// Median, microseconds.
    pub p50_us: u64,
    /// 95th percentile, microseconds.
    pub p95_us: u64,
    /// 99th percentile, microseconds.
    pub p99_us: u64,
    /// Largest sample, microseconds.
    pub max_us: u64,
}

/// Exact percentile summary of a sample set; `None` when empty.
fn stats_of(samples: &[u64]) -> Option<LatencyStats> {
    if samples.is_empty() {
        return None;
    }
    let mut v = samples.to_vec();
    v.sort_unstable();
    let pct = |p: f64| v[((v.len() as f64 * p) as usize).min(v.len() - 1)];
    Some(LatencyStats {
        count: v.len(),
        mean_us: v.iter().sum::<u64>() as f64 / v.len() as f64,
        p50_us: pct(0.50),
        p95_us: pct(0.95),
        p99_us: pct(0.99),
        max_us: *v.last().unwrap(),
    })
}

/// Jain's fairness index over per-tenant service shares:
/// `(Σx)² / (n · Σx²)`. 1.0 means perfectly even shares; `1/n` means one
/// tenant got everything. The gateway feeds it weight-normalized served
/// rows, so a high-weight tenant consuming its larger share still scores
/// 1.0. Degenerate inputs (empty, or all-zero shares) score 1.0 — an
/// idle system starves nobody.
pub fn jain_fairness<I: IntoIterator<Item = f64>>(xs: I) -> f64 {
    let (mut n, mut sum, mut sum_sq) = (0usize, 0.0f64, 0.0f64);
    for x in xs {
        n += 1;
        sum += x;
        sum_sq += x * x;
    }
    if n == 0 || sum_sq == 0.0 {
        return 1.0;
    }
    (sum * sum) / (n as f64 * sum_sq)
}

/// Demand-normalized Jain fairness over `(served, demand, weight)`
/// tenant rows.
///
/// The raw index over `served / weight` reads the *arrival mix* as
/// unfairness below saturation: a tenant that offered little traffic
/// and had all of it served drags the index down exactly like a starved
/// one. Here each tenant is scored against its *entitlement*
/// `min(demand, weighted share of total service)` — a tenant that got
/// everything it asked for scores 1 regardless of how small its share
/// of the mix was, while a tenant held below both its demand and its
/// weighted share (true scheduler unfairness) scores < 1. Scores are
/// capped at 1: serving *beyond* entitlement — work conservation when
/// another tenant under-demands — is not unfairness either. Targets are
/// floored at one row so the ratio stays finite. Degenerate inputs
/// (no rows, zero service, zero weights) score 1.0 — an idle system
/// starves nobody.
pub fn jain_fairness_normalized(rows: &[(f64, f64, f64)]) -> f64 {
    let total_served: f64 = rows.iter().map(|r| r.0).sum();
    let total_w: f64 = rows.iter().map(|r| r.2).sum();
    if rows.is_empty() || total_served <= 0.0 || total_w <= 0.0 {
        return 1.0;
    }
    jain_fairness(rows.iter().map(|&(served, demand, w)| {
        let share = total_served * w / total_w;
        (served / demand.min(share).max(1.0)).min(1.0)
    }))
}

impl Metrics {
    /// A cell that retains every raw sample for exact percentiles, at
    /// the cost of memory growing with the request count. Benches and
    /// bounded analysis runs use this; serving defaults to the bounded
    /// histogram cell.
    pub fn exact() -> Self {
        Self { exact: true, ..Self::default() }
    }

    /// True when this cell retains raw samples (exact percentiles).
    pub fn is_exact(&self) -> bool {
        self.exact
    }

    /// Record one answered request by its end-to-end latency (no
    /// queue/service split — the split-aware path is
    /// [`Metrics::record_request_split`]).
    pub fn record_request(&mut self, latency: Duration) {
        let us = latency.as_micros() as u64;
        self.requests += 1;
        self.latency_hist.record(us);
        if self.exact {
            self.latencies_us.push(us);
        }
    }

    /// Record one answered request with its latency split into queueing
    /// (admission → serve start) and service (serve start → response).
    /// The end-to-end percentile distribution tracks the sum; the
    /// queueing-only distribution is kept alongside for
    /// [`Metrics::queue_latency`].
    pub fn record_request_split(&mut self, queue: Duration, service: Duration) {
        let q = queue.as_micros() as u64;
        let s = service.as_micros() as u64;
        self.queue_us_sum += q;
        self.service_us_sum += s;
        self.requests += 1;
        self.queue_hist.record(q);
        self.latency_hist.record(q + s);
        if self.exact {
            self.queue_samples_us.push(q);
            self.latencies_us.push(q + s);
        }
    }

    /// Mean queueing delay per recorded request, in microseconds.
    pub fn mean_queue_us(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.queue_us_sum as f64 / self.requests as f64
    }

    /// Mean service time per recorded request, in microseconds.
    pub fn mean_service_us(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.service_us_sum as f64 / self.requests as f64
    }

    /// Record a served batch and its simulated cycle count.
    pub fn record_batch(&mut self, rows: usize, sim_cycles: u64) {
        self.batches += 1;
        self.batch_rows += rows as u64;
        self.sim_cycles += sim_cycles;
    }

    /// Record a served batch with its full simulated accelerator stats.
    pub fn record_batch_sim(&mut self, rows: usize, sim: &SimStats) {
        self.record_batch(rows, sim.cycles);
        self.sim_active_slots += sim.active_slots;
        self.sim_useful_macs += sim.useful_macs;
    }

    /// Mark the most recently recorded batch as stolen from a peer's
    /// shard (the thief records the batch in its *own* cell, so
    /// per-replica stats show who did the stealing and per-model stats
    /// show how much of a tenant's service arrived via steals).
    pub fn record_steal(&mut self) {
        self.stolen_batches += 1;
    }

    /// Fold another cell's counters and samples into this one. An empty
    /// cell adopts the other's exactness (so a freshly defaulted merge
    /// base inherits the mode of the cells folded into it); otherwise
    /// the merge is exact only if both sides are.
    pub fn merge(&mut self, other: &Metrics) {
        if self.requests == 0 {
            self.exact = other.exact;
        } else if other.requests > 0 {
            self.exact = self.exact && other.exact;
        }
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.queue_samples_us.extend_from_slice(&other.queue_samples_us);
        self.latency_hist.merge(&other.latency_hist);
        self.queue_hist.merge(&other.queue_hist);
        self.requests += other.requests;
        self.queue_us_sum += other.queue_us_sum;
        self.service_us_sum += other.service_us_sum;
        self.batches += other.batches;
        self.batch_rows += other.batch_rows;
        self.stolen_batches += other.stolen_batches;
        self.sim_cycles += other.sim_cycles;
        self.sim_active_slots += other.sim_active_slots;
        self.sim_useful_macs += other.sim_useful_macs;
    }

    /// Rows per served batch, averaged.
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.batch_rows as f64 / self.batches as f64
    }

    /// Simulated PE utilization across everything this replica served
    /// (useful MACs over active lane-slots, the paper's metric).
    pub fn sim_utilization(&self) -> f64 {
        if self.sim_active_slots == 0 {
            return 0.0;
        }
        self.sim_useful_macs as f64 / self.sim_active_slots as f64
    }

    /// End-to-end latency percentiles (`None` before any request):
    /// exact in [`Metrics::exact`] mode, histogram-derived otherwise.
    pub fn latency(&self) -> Option<LatencyStats> {
        if self.exact {
            stats_of(&self.latencies_us)
        } else {
            self.latency_hist.stats()
        }
    }

    /// Queueing-delay percentiles (admission → batch serve start) over
    /// split-recorded requests; `None` before any. This is the
    /// starvation metric: a tenant stuck behind another tenant's burst
    /// shows it here even when its service time is tiny.
    pub fn queue_latency(&self) -> Option<LatencyStats> {
        if self.exact {
            stats_of(&self.queue_samples_us)
        } else {
            self.queue_hist.stats()
        }
    }

    /// SLO attainment: the fraction of answered requests whose
    /// end-to-end latency was `<= us` (1.0 before any request). Exact
    /// in [`Metrics::exact`] mode, bucket-resolution otherwise.
    pub fn latency_within_us(&self, us: u64) -> f64 {
        if self.exact {
            if self.latencies_us.is_empty() {
                return 1.0;
            }
            let within = self.latencies_us.iter().filter(|&&v| v <= us).count();
            within as f64 / self.latencies_us.len() as f64
        } else {
            self.latency_hist.fraction_le(us)
        }
    }

    /// SLO attainment on the queueing-delay stream: the fraction of
    /// split-recorded requests that waited `<= us` before service
    /// (1.0 before any). The autoscale bench scores fleets on this —
    /// queueing is what a too-small fleet inflates.
    pub fn queue_within_us(&self, us: u64) -> f64 {
        if self.exact {
            if self.queue_samples_us.is_empty() {
                return 1.0;
            }
            let within = self.queue_samples_us.iter().filter(|&&v| v <= us).count();
            within as f64 / self.queue_samples_us.len() as f64
        } else {
            self.queue_hist.fraction_le(us)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_sorted() {
        let mut m = Metrics::default();
        for us in [5u64, 1, 9, 3, 7, 2, 8, 4, 6, 10] {
            m.record_request(Duration::from_micros(us));
        }
        let s = m.latency().unwrap();
        assert_eq!(s.count, 10);
        assert_eq!(s.p50_us, 6);
        assert_eq!(s.max_us, 10);
        assert!((s.mean_us - 5.5).abs() < 1e-9);
    }

    #[test]
    fn empty_latency_none() {
        assert!(Metrics::default().latency().is_none());
        assert!(Metrics::default().queue_latency().is_none());
        assert!(Metrics::exact().latency().is_none());
    }

    #[test]
    fn split_sums_and_total_distribution() {
        let mut m = Metrics::default();
        m.record_request_split(Duration::from_micros(30), Duration::from_micros(10));
        m.record_request_split(Duration::from_micros(50), Duration::from_micros(30));
        assert_eq!(m.queue_us_sum, 80);
        assert_eq!(m.service_us_sum, 40);
        assert!((m.mean_queue_us() - 40.0).abs() < 1e-9);
        assert!((m.mean_service_us() - 20.0).abs() < 1e-9);
        // percentile stream sees the end-to-end sum; the queue-only
        // stream sees just the waiting component
        assert_eq!(m.latency().unwrap().max_us, 80);
        assert_eq!(m.queue_latency().unwrap().max_us, 50);
        assert_eq!(m.queue_latency().unwrap().p50_us, 50);
        let mut other = Metrics::default();
        other.record_request_split(Duration::from_micros(1), Duration::from_micros(2));
        m.merge(&other);
        assert_eq!(m.queue_us_sum, 81);
        assert_eq!(m.service_us_sum, 42);
        assert_eq!(m.latency().unwrap().count, 3);
        assert_eq!(m.queue_latency().unwrap().count, 3);
        assert_eq!(Metrics::default().mean_queue_us(), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Metrics::default();
        a.record_batch(4, 100);
        let mut b = Metrics::default();
        b.record_batch(8, 200);
        b.record_steal();
        b.record_request(Duration::from_micros(5));
        a.merge(&b);
        assert_eq!(a.batches, 2);
        assert_eq!(a.batch_rows, 12);
        assert_eq!(a.stolen_batches, 1, "steal counts merge");
        assert_eq!(a.sim_cycles, 300);
        assert!((a.mean_batch_size() - 6.0).abs() < 1e-9);
        assert_eq!(a.latency().unwrap().count, 1);
    }

    #[test]
    fn sim_stats_flow_through() {
        let mut a = Metrics::default();
        a.record_batch_sim(4, &SimStats { cycles: 10, active_slots: 100, useful_macs: 30, tiles: 1 });
        assert_eq!(a.sim_cycles, 10);
        assert!((a.sim_utilization() - 0.3).abs() < 1e-12);
        let mut b = Metrics::default();
        b.record_batch_sim(2, &SimStats { cycles: 5, active_slots: 100, useful_macs: 70, tiles: 1 });
        a.merge(&b);
        assert_eq!(a.sim_active_slots, 200);
        assert!((a.sim_utilization() - 0.5).abs() < 1e-12);
        assert_eq!(Metrics::default().sim_utilization(), 0.0);
    }

    #[test]
    fn exact_mode_keeps_samples_and_merge_adopts_mode() {
        let mut e = Metrics::exact();
        assert!(e.is_exact() && !Metrics::default().is_exact());
        for us in [10u64, 20, 30] {
            e.record_request(Duration::from_micros(us));
        }
        assert_eq!(e.latency().unwrap().p50_us, 20);
        // an empty default-mode merge base adopts exactness from its
        // first non-empty contribution (the loadgen merge pattern)
        let mut base = Metrics::default();
        base.merge(&e);
        assert!(base.is_exact());
        assert_eq!(base.latency().unwrap().count, 3);
        // merging a histogram-mode cell into an exact one demotes it
        let mut h = Metrics::default();
        h.record_request(Duration::from_micros(40));
        base.merge(&h);
        assert!(!base.is_exact());
        assert_eq!(base.latency().unwrap().count, 4);
    }

    #[test]
    fn histogram_bucket_roundtrip() {
        // every value maps to a bucket whose representative is within
        // 3.2% (exact below the linear cutoff)
        let mut v = 1u64;
        while v < u64::MAX / 3 {
            for x in [v, v + v / 3, v.saturating_mul(2) - 1] {
                let idx = bucket_index(x);
                let rep = representative(idx);
                assert_eq!(bucket_index(rep), idx, "representative stays in its bucket");
                if x < LINEAR_CUTOFF {
                    assert_eq!(rep, x);
                } else {
                    let err = (rep as f64 - x as f64).abs() / x as f64;
                    assert!(err <= 1.0 / 32.0, "value {x}: rep {rep}, err {err}");
                }
            }
            v = v.saturating_mul(2);
        }
        assert_eq!(bucket_index(u64::MAX), HIST_BUCKETS - 1);
    }

    /// Satellite acceptance: histogram quantiles track exact quantiles
    /// within 5% relative error across random distributions.
    #[test]
    fn histogram_quantile_error_bounded() {
        // deterministic xorshift64* — no rand dependency
        let mut state = 0x9E37_79B9_7F4A_7C15u64;
        let mut next = move || {
            state ^= state >> 12;
            state ^= state << 25;
            state ^= state >> 27;
            state.wrapping_mul(0x2545_F491_4F6C_DD1D)
        };
        let mut check = |samples: &[u64], label: &str| {
            let mut h = LogHistogram::new();
            for &s in samples {
                h.record(s);
            }
            let exact = stats_of(samples).unwrap();
            let approx = h.stats().unwrap();
            assert_eq!(approx.count, exact.count);
            assert_eq!(approx.max_us, exact.max_us, "{label}: max is exact");
            for (a, e, q) in [
                (approx.p50_us, exact.p50_us, "p50"),
                (approx.p95_us, exact.p95_us, "p95"),
                (approx.p99_us, exact.p99_us, "p99"),
            ] {
                let err = (a as f64 - e as f64).abs() / (e as f64).max(1.0);
                assert!(err <= 0.05, "{label} {q}: approx {a} vs exact {e} (err {err:.4})");
            }
            let mean_err = (approx.mean_us - exact.mean_us).abs() / exact.mean_us.max(1.0);
            assert!(mean_err <= 0.05, "{label} mean: {} vs {}", approx.mean_us, exact.mean_us);
        };
        // uniform [1, 1e6)
        let uniform: Vec<u64> = (0..4096).map(|_| 1 + next() % 1_000_000).collect();
        check(&uniform, "uniform");
        // exponential-ish tail: u ~ U(0,1), -ln(u) * 10_000
        let expo: Vec<u64> = (0..4096)
            .map(|_| {
                let u = (next() >> 11) as f64 / (1u64 << 53) as f64;
                (-(u.max(1e-12)).ln() * 10_000.0) as u64
            })
            .collect();
        check(&expo, "exponential");
        // bimodal: tight service mode + rare slow mode
        let bimodal: Vec<u64> = (0..4096)
            .map(|_| if next() % 10 == 0 { 500_000 + next() % 50_000 } else { 800 + next() % 100 })
            .collect();
        check(&bimodal, "bimodal");
        // tiny sets stay exact-equivalent via min/max clamping
        check(&[7, 9], "pair");
        check(&[1_000_000], "singleton");
    }

    #[test]
    fn histogram_clear_and_merge() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for v in [100u64, 200, 300] {
            a.record(v);
        }
        for v in [400u64, 500] {
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 5);
        assert_eq!(a.max(), 500);
        a.clear();
        assert!(a.is_empty());
        assert_eq!(a.quantile(0.5), 0);
        assert!(a.stats().is_none());
        a.record(42);
        assert_eq!(a.stats().unwrap().p50_us, 42);
    }

    #[test]
    fn attainment_fractions() {
        // empty streams violate no bound
        assert_eq!(LogHistogram::new().fraction_le(0), 1.0);
        assert_eq!(Metrics::default().latency_within_us(0), 1.0);
        assert_eq!(Metrics::exact().queue_within_us(0), 1.0);
        // exact mode: precise counting
        let mut e = Metrics::exact();
        for us in [10u64, 20, 30, 40] {
            e.record_request_split(Duration::from_micros(us), Duration::ZERO);
        }
        assert!((e.latency_within_us(25) - 0.5).abs() < 1e-12);
        assert!((e.queue_within_us(10) - 0.25).abs() < 1e-12);
        assert_eq!(e.latency_within_us(1_000), 1.0);
        assert_eq!(e.latency_within_us(5), 0.0);
        // histogram mode: exact below the linear cutoff, monotone and
        // saturating above it
        let mut h = LogHistogram::new();
        for v in [1u64, 2, 3, 10_000, 20_000] {
            h.record(v);
        }
        assert!((h.fraction_le(3) - 0.6).abs() < 1e-12);
        assert_eq!(h.fraction_le(u64::MAX / 2), 1.0);
        assert!(h.fraction_le(5_000) >= 0.6);
        assert!(h.fraction_le(5_000) < 1.0);
    }

    #[test]
    fn jain_index_shapes() {
        // perfectly even shares
        assert!((jain_fairness([3.0, 3.0, 3.0]) - 1.0).abs() < 1e-12);
        // one tenant starved to zero among two -> 0.5
        assert!((jain_fairness([10.0, 0.0]) - 0.5).abs() < 1e-12);
        // one of n gets everything -> 1/n
        assert!((jain_fairness([7.0, 0.0, 0.0, 0.0]) - 0.25).abs() < 1e-12);
        // degenerate inputs read as fair
        assert_eq!(jain_fairness([]), 1.0);
        assert_eq!(jain_fairness([0.0, 0.0]), 1.0);
        // mild skew lands strictly between 1/n and 1
        let j = jain_fairness([4.0, 2.0]);
        assert!(j > 0.5 && j < 1.0, "got {j}");
    }

    #[test]
    fn normalized_jain_discounts_the_arrival_mix() {
        // a 9:1 arrival mix, both tenants fully served: the RAW index
        // reads the skew as unfairness, the normalized one does not
        let rows = [(900.0, 900.0, 1.0), (100.0, 100.0, 1.0)];
        let raw = jain_fairness(rows.iter().map(|r| r.0 / r.2));
        assert!(raw < 0.7, "raw index penalizes the mix: {raw}");
        assert!(
            (jain_fairness_normalized(&rows) - 1.0).abs() < 1e-12,
            "every tenant got min(demand, share): perfectly fair"
        );
        // a genuinely starved tenant still reads as unfair: it demanded
        // far more than it was served and its weighted share would have
        // allowed more
        let rows = [(990.0, 1000.0, 1.0), (10.0, 1000.0, 1.0)];
        let norm = jain_fairness_normalized(&rows);
        assert!(norm < 0.7, "starvation must survive normalization: {norm}");
        // a high-weight tenant consuming its larger share is fair under
        // both lenses
        let rows = [(800.0, 2000.0, 4.0), (200.0, 2000.0, 1.0)];
        let norm = jain_fairness_normalized(&rows);
        assert!((norm - 1.0).abs() < 1e-12, "4:1 weights, 4:1 service: {norm}");
        // degenerate inputs read as fair
        assert_eq!(jain_fairness_normalized(&[]), 1.0);
        assert_eq!(jain_fairness_normalized(&[(0.0, 5.0, 1.0)]), 1.0);
    }
}
