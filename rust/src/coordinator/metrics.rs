//! Request-level metrics: latency percentiles, throughput, and attached
//! accelerator-simulation counters (one `Metrics` per pool replica;
//! replicas merge into pool-level stats).

use std::time::Duration;

use crate::sim::SimStats;

/// Online latency collector (stores all samples; serving runs here are
/// bounded, so memory is a non-issue and exact percentiles beat sketches).
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    latencies_us: Vec<u64>,
    /// Sum of per-request *queueing* microseconds (admission → batch
    /// serve start); with `service_us_sum` this splits the end-to-end
    /// latency so shed-policy experiments can separate waiting from
    /// compute.
    pub queue_us_sum: u64,
    /// Sum of per-request *service* microseconds (batch serve start →
    /// response sent).
    pub service_us_sum: u64,
    pub batches: u64,
    pub batch_rows: u64,
    pub sim_cycles: u64,
    /// Lane-slot denominator of the simulated utilization (Figs. 7a/8).
    pub sim_active_slots: u64,
    /// Useful-MAC numerator of the simulated utilization.
    pub sim_useful_macs: u64,
}

#[derive(Clone, Copy, Debug)]
pub struct LatencyStats {
    pub count: usize,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

impl Metrics {
    pub fn record_request(&mut self, latency: Duration) {
        self.latencies_us.push(latency.as_micros() as u64);
    }

    /// Record one answered request with its latency split into queueing
    /// (admission → serve start) and service (serve start → response).
    /// The percentile distribution tracks the end-to-end sum.
    pub fn record_request_split(&mut self, queue: Duration, service: Duration) {
        let q = queue.as_micros() as u64;
        let s = service.as_micros() as u64;
        self.queue_us_sum += q;
        self.service_us_sum += s;
        self.latencies_us.push(q + s);
    }

    /// Mean queueing delay per recorded request, in microseconds.
    pub fn mean_queue_us(&self) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        self.queue_us_sum as f64 / self.latencies_us.len() as f64
    }

    /// Mean service time per recorded request, in microseconds.
    pub fn mean_service_us(&self) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        self.service_us_sum as f64 / self.latencies_us.len() as f64
    }

    pub fn record_batch(&mut self, rows: usize, sim_cycles: u64) {
        self.batches += 1;
        self.batch_rows += rows as u64;
        self.sim_cycles += sim_cycles;
    }

    /// Record a served batch with its full simulated accelerator stats.
    pub fn record_batch_sim(&mut self, rows: usize, sim: &SimStats) {
        self.record_batch(rows, sim.cycles);
        self.sim_active_slots += sim.active_slots;
        self.sim_useful_macs += sim.useful_macs;
    }

    pub fn merge(&mut self, other: &Metrics) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.queue_us_sum += other.queue_us_sum;
        self.service_us_sum += other.service_us_sum;
        self.batches += other.batches;
        self.batch_rows += other.batch_rows;
        self.sim_cycles += other.sim_cycles;
        self.sim_active_slots += other.sim_active_slots;
        self.sim_useful_macs += other.sim_useful_macs;
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.batch_rows as f64 / self.batches as f64
    }

    /// Simulated PE utilization across everything this replica served
    /// (useful MACs over active lane-slots, the paper's metric).
    pub fn sim_utilization(&self) -> f64 {
        if self.sim_active_slots == 0 {
            return 0.0;
        }
        self.sim_useful_macs as f64 / self.sim_active_slots as f64
    }

    pub fn latency(&self) -> Option<LatencyStats> {
        if self.latencies_us.is_empty() {
            return None;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let pct = |p: f64| v[((v.len() as f64 * p) as usize).min(v.len() - 1)];
        Some(LatencyStats {
            count: v.len(),
            mean_us: v.iter().sum::<u64>() as f64 / v.len() as f64,
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            max_us: *v.last().unwrap(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_sorted() {
        let mut m = Metrics::default();
        for us in [5u64, 1, 9, 3, 7, 2, 8, 4, 6, 10] {
            m.record_request(Duration::from_micros(us));
        }
        let s = m.latency().unwrap();
        assert_eq!(s.count, 10);
        assert_eq!(s.p50_us, 6);
        assert_eq!(s.max_us, 10);
        assert!((s.mean_us - 5.5).abs() < 1e-9);
    }

    #[test]
    fn empty_latency_none() {
        assert!(Metrics::default().latency().is_none());
    }

    #[test]
    fn split_sums_and_total_distribution() {
        let mut m = Metrics::default();
        m.record_request_split(Duration::from_micros(30), Duration::from_micros(10));
        m.record_request_split(Duration::from_micros(50), Duration::from_micros(30));
        assert_eq!(m.queue_us_sum, 80);
        assert_eq!(m.service_us_sum, 40);
        assert!((m.mean_queue_us() - 40.0).abs() < 1e-9);
        assert!((m.mean_service_us() - 20.0).abs() < 1e-9);
        // percentile stream sees the end-to-end sum
        assert_eq!(m.latency().unwrap().max_us, 80);
        let mut other = Metrics::default();
        other.record_request_split(Duration::from_micros(1), Duration::from_micros(2));
        m.merge(&other);
        assert_eq!(m.queue_us_sum, 81);
        assert_eq!(m.service_us_sum, 42);
        assert_eq!(m.latency().unwrap().count, 3);
        assert_eq!(Metrics::default().mean_queue_us(), 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = Metrics::default();
        a.record_batch(4, 100);
        let mut b = Metrics::default();
        b.record_batch(8, 200);
        b.record_request(Duration::from_micros(5));
        a.merge(&b);
        assert_eq!(a.batches, 2);
        assert_eq!(a.batch_rows, 12);
        assert_eq!(a.sim_cycles, 300);
        assert!((a.mean_batch_size() - 6.0).abs() < 1e-9);
        assert_eq!(a.latency().unwrap().count, 1);
    }

    #[test]
    fn sim_stats_flow_through() {
        let mut a = Metrics::default();
        a.record_batch_sim(4, &SimStats { cycles: 10, active_slots: 100, useful_macs: 30, tiles: 1 });
        assert_eq!(a.sim_cycles, 10);
        assert!((a.sim_utilization() - 0.3).abs() < 1e-12);
        let mut b = Metrics::default();
        b.record_batch_sim(2, &SimStats { cycles: 5, active_slots: 100, useful_macs: 70, tiles: 1 });
        a.merge(&b);
        assert_eq!(a.sim_active_slots, 200);
        assert!((a.sim_utilization() - 0.5).abs() < 1e-12);
        assert_eq!(Metrics::default().sim_utilization(), 0.0);
    }
}
