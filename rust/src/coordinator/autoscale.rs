//! SLO-driven worker-fleet autoscaling.
//!
//! The paper's core argument is that *utilization*, not peak
//! capability, decides efficiency — KAN-SAs wins by keeping the array
//! busy. The serving tier has the same gap one level up: a fleet sized
//! for peak traffic idles through the trough of a `diurnal` day, and a
//! fleet sized for the trough sheds through a `flash-crowd`. This
//! module closes it with a small control loop:
//!
//! - **Signals** ([`FleetSignals`]): the telemetry spine's windowed
//!   per-tenant stats ([`Telemetry::snapshot`]) reduced to the
//!   worst-tenant p95 queueing delay, shed rate, and queue depth. The
//!   SLO is judged on *queueing* delay because that is the component
//!   adding workers can fix — service time is the model's own cost.
//! - **Policy** ([`Controller`]): a pure `(active, signals) →`
//!   [`ScaleDecision`] function. Scale-up is fast (double, clamped to
//!   `max_workers`) on any SLO breach; scale-down is slow — one worker
//!   at a time, only after [`AutoscaleConfig::calm_windows`]
//!   *consecutive* calm windows (hysteresis, so a breach→calm→breach
//!   oscillation never thrashes the fleet).
//! - **Actuation** (in [`gateway`](super::gateway)): scale-up spawns a
//!   worker on a pre-sized shard slot; scale-down generalizes the
//!   `remove_model` drain contract to replicas — stop dispatching to
//!   the victim, let it (and stealing peers) flush its shard backlog,
//!   then join the thread once nothing is left. No request is ever
//!   dropped by a scaling action, so the per-model conservation
//!   invariant (`submitted == completed + shed + failed`) holds
//!   through arbitrary churn.
//!
//! Because every decision is a function of windowed time, the
//! controller is driven by the gateway's injected
//! [`Clock`](super::Clock): in production a thread evaluates every
//! [`AutoscaleConfig::interval`]; under a manual test clock the same
//! evaluation runs synchronously via `Gateway::autoscale_tick`, making
//! scale-up latency and hysteresis exactly testable.
//!
//! [`Telemetry::snapshot`]: super::telemetry::Telemetry::snapshot

use std::time::Duration;

use super::telemetry::TelemetrySnapshot;

/// Autoscaler policy knobs, carried in
/// [`GatewayConfig::autoscale`](super::gateway::GatewayConfig).
#[derive(Clone, Copy, Debug)]
pub struct AutoscaleConfig {
    /// Floor of the active fleet (the controller never drains below
    /// this; also the initial fleet size).
    pub min_workers: usize,
    /// Ceiling of the active fleet. Shards, telemetry rings, and
    /// per-replica metrics cells are pre-sized to this at gateway
    /// start, so scale-up never reallocates shared state.
    pub max_workers: usize,
    /// The SLO: windowed p95 queueing delay (admission → serve start)
    /// must stay at or below this many microseconds.
    pub slo_p95_us: u64,
    /// Shed rate above which a window counts as an SLO breach even if
    /// the survivors' p95 looks healthy (shedding hides queue delay:
    /// dropped requests never report latency).
    pub max_shed_rate: f64,
    /// Consecutive calm windows required before one worker is drained
    /// (the hysteresis constant K).
    pub calm_windows: u32,
    /// A window only counts as calm when p95 queueing delay is below
    /// `slo_p95_us * calm_fraction` and nothing was shed — the dead
    /// band between the scale-up and scale-down thresholds.
    pub calm_fraction: f64,
    /// Evaluation period of the controller loop.
    pub interval: Duration,
    /// Pin each worker thread to a CPU core (slot index modulo the
    /// core count) so scratch arenas and MAC tables stay core-local.
    pub pin_cores: bool,
}

impl Default for AutoscaleConfig {
    fn default() -> Self {
        Self {
            min_workers: 1,
            max_workers: super::pool::default_replicas(),
            slo_p95_us: 10_000,
            max_shed_rate: 0.01,
            calm_windows: 3,
            calm_fraction: 0.5,
            interval: Duration::from_millis(250),
            pin_cores: false,
        }
    }
}

impl AutoscaleConfig {
    /// Parse a `min:max` fleet-bounds spec (the `--autoscale` CLI
    /// argument) onto the default policy.
    pub fn from_bounds_spec(spec: &str) -> Result<Self, String> {
        let (lo, hi) = spec
            .split_once(':')
            .ok_or_else(|| format!("autoscale spec `{spec}`: expected min:max"))?;
        let min_workers: usize =
            lo.parse().map_err(|_| format!("autoscale min `{lo}`: not a number"))?;
        let max_workers: usize =
            hi.parse().map_err(|_| format!("autoscale max `{hi}`: not a number"))?;
        if min_workers == 0 || max_workers < min_workers {
            return Err(format!(
                "autoscale bounds {min_workers}:{max_workers}: want 1 <= min <= max"
            ));
        }
        Ok(Self { min_workers, max_workers, ..Self::default() })
    }
}

/// The fleet-level control signals one evaluation reads: the telemetry
/// snapshot's per-tenant windows reduced to worst-case scalars (the
/// SLO is per-tenant, so the worst tenant governs).
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetSignals {
    /// Worst per-tenant windowed p95 queueing delay, µs (0 when no
    /// tenant reported a queue distribution — an idle fleet is calm).
    pub p95_queue_us: u64,
    /// Worst per-tenant windowed shed rate.
    pub shed_rate: f64,
    /// Worst per-tenant queue depth after the window's last admission.
    pub depth_last: u64,
    /// Tenants that contributed a window to this evaluation.
    pub windows: usize,
}

impl FleetSignals {
    /// Reduce a telemetry snapshot to fleet-level signals.
    pub fn from_snapshot(snap: &TelemetrySnapshot) -> Self {
        let mut sig = FleetSignals::default();
        for t in &snap.tenants {
            let Some(w) = &t.window else { continue };
            sig.windows += 1;
            if let Some(q) = &w.queue {
                sig.p95_queue_us = sig.p95_queue_us.max(q.p95_us);
            }
            if w.shed_rate > sig.shed_rate {
                sig.shed_rate = w.shed_rate;
            }
            sig.depth_last = sig.depth_last.max(w.depth_last);
        }
        sig
    }
}

/// One scaling verdict. `Up`/`Down` carry worker *deltas*.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Fleet stays as it is.
    Hold,
    /// Add this many workers (scale-up is fast: double, clamped).
    Up(usize),
    /// Drain this many workers (scale-down is slow: one per decision).
    Down(usize),
}

/// The pure scaling policy: feed it the active worker count and the
/// current [`FleetSignals`], get a [`ScaleDecision`]. It owns only the
/// calm-streak counter, so deterministic tests drive it window by
/// window with synthetic signals and no clock at all.
///
/// ```
/// use kan_sas::coordinator::autoscale::{
///     AutoscaleConfig, Controller, FleetSignals, ScaleDecision,
/// };
///
/// let cfg = AutoscaleConfig { min_workers: 1, max_workers: 8, slo_p95_us: 1_000,
///     calm_windows: 2, ..AutoscaleConfig::default() };
/// let mut c = Controller::new(cfg);
/// let breach = FleetSignals { p95_queue_us: 5_000, windows: 1, ..Default::default() };
/// assert_eq!(c.evaluate(2, &breach), ScaleDecision::Up(2), "breach doubles the fleet");
/// let calm = FleetSignals { p95_queue_us: 100, windows: 1, ..Default::default() };
/// assert_eq!(c.evaluate(4, &calm), ScaleDecision::Hold, "one calm window is not enough");
/// assert_eq!(c.evaluate(4, &calm), ScaleDecision::Down(1), "K consecutive calm windows drain one");
/// ```
#[derive(Debug)]
pub struct Controller {
    cfg: AutoscaleConfig,
    calm: u32,
}

impl Controller {
    /// A controller with zero calm-streak history.
    pub fn new(cfg: AutoscaleConfig) -> Self {
        Self { cfg, calm: 0 }
    }

    /// The policy this controller enforces.
    pub fn config(&self) -> &AutoscaleConfig {
        &self.cfg
    }

    /// Consecutive calm windows observed so far (resets on breach, on
    /// any non-calm window, and after every scale-down).
    pub fn calm_streak(&self) -> u32 {
        self.calm
    }

    /// Evaluate one control window. Pure in (self.calm, active, sig).
    pub fn evaluate(&mut self, active: usize, sig: &FleetSignals) -> ScaleDecision {
        let breach =
            sig.p95_queue_us > self.cfg.slo_p95_us || sig.shed_rate > self.cfg.max_shed_rate;
        if breach {
            self.calm = 0;
            if active < self.cfg.max_workers {
                // scale up fast: double the fleet, clamped to the
                // ceiling (a flash crowd reaches max in O(log) windows)
                let target = (active * 2).clamp(active + 1, self.cfg.max_workers);
                return ScaleDecision::Up(target - active);
            }
            return ScaleDecision::Hold;
        }
        let calm_bar = (self.cfg.slo_p95_us as f64 * self.cfg.calm_fraction) as u64;
        let calm = sig.p95_queue_us <= calm_bar && sig.shed_rate == 0.0;
        if calm {
            self.calm = self.calm.saturating_add(1);
        } else {
            // inside the dead band (above calm_bar, at or below the
            // SLO): hold and restart the streak
            self.calm = 0;
        }
        if self.calm >= self.cfg.calm_windows && active > self.cfg.min_workers {
            self.calm = 0;
            return ScaleDecision::Down(1);
        }
        ScaleDecision::Hold
    }
}

/// One applied scaling action, recorded by the gateway's actuator (the
/// log is bounded at [`SCALE_EVENT_CAP`]; older events are dropped).
#[derive(Clone, Copy, Debug)]
pub struct ScaleEvent {
    /// When the action was applied, µs on the gateway clock.
    pub at_us: u64,
    /// Active workers before.
    pub from: usize,
    /// Active workers after.
    pub to: usize,
    /// The worst-tenant p95 queueing delay that drove the decision.
    pub p95_queue_us: u64,
    /// The worst-tenant shed rate that drove the decision.
    pub shed_rate: f64,
}

/// Retention bound of the gateway's scale-event log.
pub const SCALE_EVENT_CAP: usize = 256;

/// Pin the calling thread to `core` (modulo the machine's core count)
/// via `sched_setaffinity`. Best-effort: failures are ignored, and the
/// call is a no-op off Linux. No external crate — the raw syscall
/// binding is all we need.
pub(crate) fn pin_current_thread(core: usize) {
    #[cfg(target_os = "linux")]
    {
        let ncores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let core = core % ncores;
        // 1024-bit CPU set, the kernel's default mask width
        let mut mask = [0u64; 16];
        mask[(core / 64) % mask.len()] |= 1u64 << (core % 64);
        extern "C" {
            fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
        }
        // pid 0 = the calling thread; best-effort, ignore EINVAL/EPERM
        unsafe {
            let _unused = sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr());
        }
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _unused = core;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig {
            min_workers: 1,
            max_workers: 8,
            slo_p95_us: 1_000,
            max_shed_rate: 0.0,
            calm_windows: 3,
            calm_fraction: 0.5,
            interval: Duration::from_millis(10),
            pin_cores: false,
        }
    }

    fn sig(p95: u64, shed: f64) -> FleetSignals {
        FleetSignals { p95_queue_us: p95, shed_rate: shed, depth_last: 0, windows: 1 }
    }

    #[test]
    fn breach_doubles_until_max() {
        let mut c = Controller::new(cfg());
        assert_eq!(c.evaluate(1, &sig(5_000, 0.0)), ScaleDecision::Up(1));
        assert_eq!(c.evaluate(2, &sig(5_000, 0.0)), ScaleDecision::Up(2));
        assert_eq!(c.evaluate(4, &sig(5_000, 0.0)), ScaleDecision::Up(4));
        assert_eq!(c.evaluate(8, &sig(5_000, 0.0)), ScaleDecision::Hold, "already at max");
    }

    #[test]
    fn shed_rate_alone_is_a_breach() {
        let mut c = Controller::new(cfg());
        assert_eq!(c.evaluate(2, &sig(0, 0.25)), ScaleDecision::Up(2));
    }

    #[test]
    fn hysteresis_requires_k_consecutive_calm_windows() {
        let mut c = Controller::new(cfg());
        assert_eq!(c.evaluate(4, &sig(100, 0.0)), ScaleDecision::Hold);
        assert_eq!(c.evaluate(4, &sig(100, 0.0)), ScaleDecision::Hold);
        assert_eq!(c.calm_streak(), 2);
        // a breach in the middle resets the streak
        assert_eq!(c.evaluate(4, &sig(5_000, 0.0)), ScaleDecision::Up(4));
        assert_eq!(c.calm_streak(), 0);
        assert_eq!(c.evaluate(8, &sig(100, 0.0)), ScaleDecision::Hold);
        assert_eq!(c.evaluate(8, &sig(100, 0.0)), ScaleDecision::Hold);
        assert_eq!(c.evaluate(8, &sig(100, 0.0)), ScaleDecision::Down(1));
        // the streak restarts after a drain: no double-dip
        assert_eq!(c.evaluate(7, &sig(100, 0.0)), ScaleDecision::Hold);
    }

    #[test]
    fn dead_band_neither_scales_nor_counts_calm() {
        let mut c = Controller::new(cfg());
        // 800µs is under the SLO (1000) but above the calm bar (500)
        for _ in 0..10 {
            assert_eq!(c.evaluate(4, &sig(800, 0.0)), ScaleDecision::Hold);
        }
        assert_eq!(c.calm_streak(), 0);
    }

    #[test]
    fn never_drains_below_min() {
        let mut c = Controller::new(cfg());
        for _ in 0..10 {
            assert_ne!(c.evaluate(1, &sig(0, 0.0)), ScaleDecision::Down(1));
        }
    }

    #[test]
    fn idle_windows_count_as_calm() {
        // no tenant reported a window: p95 0, shed 0 — calm by design,
        // so a fleet scaled up for a flash crowd shrinks after it ends
        let mut c = Controller::new(cfg());
        let idle = FleetSignals::default();
        assert_eq!(c.evaluate(4, &idle), ScaleDecision::Hold);
        assert_eq!(c.evaluate(4, &idle), ScaleDecision::Hold);
        assert_eq!(c.evaluate(4, &idle), ScaleDecision::Down(1));
    }

    #[test]
    fn bounds_spec_parses() {
        let a = AutoscaleConfig::from_bounds_spec("2:12").unwrap();
        assert_eq!((a.min_workers, a.max_workers), (2, 12));
        assert!(AutoscaleConfig::from_bounds_spec("12").is_err());
        assert!(AutoscaleConfig::from_bounds_spec("0:4").is_err());
        assert!(AutoscaleConfig::from_bounds_spec("5:4").is_err());
        assert!(AutoscaleConfig::from_bounds_spec("a:b").is_err());
    }

    #[test]
    fn signals_take_the_worst_tenant() {
        use crate::coordinator::telemetry::{TelemetrySnapshot, TenantSnapshot};
        let snap = TelemetrySnapshot {
            at_us: 0,
            dropped_events: 0,
            tenants: vec![TenantSnapshot {
                name: "calm".into(),
                live: true,
                window: None,
                totals: Default::default(),
            }],
            spans: Vec::new(),
        };
        let sig = FleetSignals::from_snapshot(&snap);
        assert_eq!(sig.windows, 0);
        assert_eq!(sig.p95_queue_us, 0);
    }
}
