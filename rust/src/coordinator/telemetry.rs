//! The telemetry spine: lock-free request tracing, windowed per-tenant
//! stats, and a bounded flight recorder for the serving gateway.
//!
//! Three layers, matching the zero-allocation discipline of the hot
//! path it observes:
//!
//! 1. **Event layer** — one fixed-capacity SPSC [`EventRing`] per
//!    worker, plus one *admission ring* whose single producer is
//!    "whoever holds the gateway state lock" (submitters and
//!    control-plane flushes are serialized by that lock, so the SPSC
//!    contract holds). Hot-path emission builds a compact POD
//!    [`Event`] and publishes it with one `Acquire` load and one
//!    `Release` store; a full ring **drops and counts**
//!    ([`Telemetry::dropped_events`]) — a slow collector can never
//!    block a worker or a submitter.
//! 2. **Aggregation layer** — a collector thread drains the rings into
//!    per-tenant *windowed* series: bounded
//!    [`LogHistogram`](super::metrics::LogHistogram)s for queue/service
//!    latency plus rolling throughput, shed-rate, steal-rate,
//!    queue-depth, and `sim_utilization` gauges over a configurable
//!    window — and into a bounded **flight recorder**: the last N
//!    lifecycle events per tenant and every registry churn record
//!    (add / re-weight / remove transitions), dumpable on demand.
//!    Steady-state collection is allocation-free: histograms clear in
//!    place, flight rings pop before they push, and window summaries
//!    are plain `Copy` structs.
//! 3. **Export layer** — [`Telemetry::snapshot`] summarizes the last
//!    completed window per tenant; [`TelemetrySnapshot::to_value`] /
//!    [`FlightDump::to_value`] / [`Span::to_value`] render deterministic
//!    [`util::json`](crate::util::json) lines for `TELEMETRY.jsonl`,
//!    the live `--stats-every` console table, and `--trace-sample`
//!    request span timelines (admission → enqueue → batch/steal →
//!    serve → respond).

use std::cell::UnsafeCell;
use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::json::Value;

use super::clock::Clock;
use super::metrics::{LatencyStats, LogHistogram};

/// Telemetry spine configuration, carried inside
/// [`GatewayConfig`](super::gateway::GatewayConfig).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TelemetryConfig {
    /// Master switch. Off = no rings, no collector thread, every emit
    /// is a single branch.
    pub enabled: bool,
    /// Slots per event ring (rounded up to a power of two). One ring
    /// per worker plus the admission ring.
    pub ring_capacity: usize,
    /// Width of the rolling stats window.
    pub window: Duration,
    /// Lifecycle events retained per tenant in the flight recorder.
    pub flight_capacity: usize,
    /// Trace 1-in-N admitted requests end to end (0 = tracing off).
    pub trace_sample: u64,
    /// Retain exact latency samples in the serving `Metrics` cells
    /// (bench mode) instead of the bounded histograms.
    pub exact_samples: bool,
    /// Interval between periodic flight-recorder dumps on the
    /// TELEMETRY.jsonl stream (`kansas serve --telemetry`), so the
    /// registry-churn record survives a crash instead of existing only
    /// in the single shutdown dump. `Duration::ZERO` disables the
    /// periodic dumps (the shutdown dump is always written).
    pub flight_every: Duration,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            ring_capacity: 8192,
            window: Duration::from_secs(1),
            flight_capacity: 64,
            trace_sample: 0,
            exact_samples: false,
            flight_every: Duration::from_secs(5),
        }
    }
}

impl TelemetryConfig {
    /// Telemetry fully disabled (the A-side of the overhead experiment).
    pub fn off() -> Self {
        Self { enabled: false, ..Self::default() }
    }
}

/// Lifecycle stage of an [`Event`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// Request admitted to the shared queue (`a` = queue depth after).
    Admitted = 0,
    /// Request pulled from the shared queue into a shard batcher.
    Enqueued = 1,
    /// Batch drained from its owner's batcher (`rows` > 0; `a` = age of
    /// the oldest request in µs). `rows == 0` marks a per-request trace
    /// echo.
    BatchFormed = 2,
    /// Batch stolen from a peer's shard (`rows` > 0; `a` = victim
    /// worker). `rows == 0` marks a per-request trace echo.
    Stolen = 3,
    /// Batch entered service (`rows` = live batch size).
    ServeStart = 4,
    /// Batch finished service (`a` = useful MACs, `b` = active lane
    /// slots from the attached accelerator simulation).
    ServeEnd = 5,
    /// One request answered (`a` = queue µs, `b` = service µs).
    Responded = 6,
    /// One request shed (rejected, evicted, or flushed by a removal).
    Shed = 7,
    /// One request expired past its deadline before service.
    Expired = 8,
    /// A worker adopted a new registry snapshot (`a` = epoch).
    EpochAdopted = 9,
}

impl EventKind {
    /// Stable lowercase name (the JSONL / flight-recorder vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Admitted => "admitted",
            EventKind::Enqueued => "enqueued",
            EventKind::BatchFormed => "batch_formed",
            EventKind::Stolen => "stolen",
            EventKind::ServeStart => "serve_start",
            EventKind::ServeEnd => "serve_end",
            EventKind::Responded => "responded",
            EventKind::Shed => "shed",
            EventKind::Expired => "expired",
            EventKind::EpochAdopted => "epoch_adopted",
        }
    }
}

/// Compact POD event record (48 bytes, `Copy`): what a ring slot holds.
/// Field meaning varies by [`EventKind`]; `trace` is the nonzero span id
/// for sampled requests (0 = untraced).
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Microseconds since the telemetry origin (monotonic).
    pub t_us: u64,
    /// Kind-specific argument (queue depth, queue µs, useful MACs, …).
    pub a: u64,
    /// Kind-specific argument (service µs, active slots, …).
    pub b: u64,
    /// Span id for sampled requests; 0 when untraced.
    pub trace: u64,
    /// Tenant slot index ([`u32::MAX`] for fleet-wide events).
    pub tenant: u32,
    /// Rows involved (1 for per-request events, batch size for batch
    /// events, 0 for per-request trace echoes of batch events).
    pub rows: u32,
    /// Worker index (the admission ring reports the worker count).
    pub worker: u16,
    /// Lifecycle stage.
    pub kind: EventKind,
}

impl Event {
    const ZERO: Event = Event {
        t_us: 0,
        a: 0,
        b: 0,
        trace: 0,
        tenant: 0,
        rows: 0,
        worker: 0,
        kind: EventKind::Admitted,
    };
}

/// Tenant id used for events not attributable to one tenant
/// (epoch adoptions).
pub const NO_TENANT: u32 = u32::MAX;

/// Fixed-capacity single-producer single-consumer ring of [`Event`]s.
///
/// The producer publishes with a `Relaxed` tail read (producer-owned),
/// an `Acquire` head read, a plain slot write, and a `Release` tail
/// store; the consumer mirrors it. A full ring drops the event and
/// bumps `dropped` — emission never blocks and never allocates.
pub struct EventRing {
    slots: Box<[UnsafeCell<Event>]>,
    mask: usize,
    head: AtomicUsize,
    tail: AtomicUsize,
    dropped: AtomicU64,
}

// SAFETY: slot i is written only by the single producer while
// `head <= i < head + capacity` excludes it from the consumer's range,
// and read only by the single consumer after the producer's Release
// store of `tail` made the write visible.
unsafe impl Sync for EventRing {}
unsafe impl Send for EventRing {}

impl EventRing {
    /// A ring with at least `capacity` slots (rounded up to a power of
    /// two, minimum 2).
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(2).next_power_of_two();
        Self {
            slots: (0..cap).map(|_| UnsafeCell::new(Event::ZERO)).collect(),
            mask: cap - 1,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Slot count.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Producer side: publish one event, or drop-and-count when full.
    /// Returns whether the event was stored. Never blocks or allocates.
    pub fn push(&self, ev: Event) -> bool {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Acquire);
        if tail.wrapping_sub(head) >= self.slots.len() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        // SAFETY: single producer; this slot is outside the consumer's
        // published range until the Release store below.
        unsafe { *self.slots[tail & self.mask].get() = ev };
        self.tail.store(tail.wrapping_add(1), Ordering::Release);
        true
    }

    /// Consumer side: drain every published event through `f` (oldest
    /// first). Returns the number consumed.
    pub fn drain(&self, mut f: impl FnMut(Event)) -> usize {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        let n = tail.wrapping_sub(head);
        for i in 0..n {
            // SAFETY: single consumer; the producer's Release store of
            // `tail` ordered these slot writes before our Acquire load.
            let ev = unsafe { *self.slots[head.wrapping_add(i) & self.mask].get() };
            f(ev);
        }
        self.head.store(tail, Ordering::Release);
        n
    }

    /// Events dropped on overflow since creation.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// What a registry churn record describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChurnKind {
    /// Tenant registered at gateway start.
    Registered,
    /// Tenant hot-added on the live gateway.
    Added,
    /// Tenant re-weighted.
    Reweighted,
    /// Tenant removal began (stopped accepting; backlog draining).
    RemoveBegin,
    /// Tenant removal completed (engine and buffers retired).
    Removed,
}

impl ChurnKind {
    /// Stable lowercase name.
    pub fn name(self) -> &'static str {
        match self {
            ChurnKind::Registered => "registered",
            ChurnKind::Added => "added",
            ChurnKind::Reweighted => "reweighted",
            ChurnKind::RemoveBegin => "remove_begin",
            ChurnKind::Removed => "removed",
        }
    }
}

/// One registry transition, kept in arrival order by the flight
/// recorder (control-plane calls are serialized by the gateway's admin
/// lock, so arrival order is transition order).
#[derive(Clone, Debug)]
pub struct ChurnRecord {
    /// Microseconds since the telemetry origin.
    pub t_us: u64,
    /// Transition.
    pub kind: ChurnKind,
    /// Tenant slot index.
    pub tenant: u32,
    /// Tenant name.
    pub name: String,
    /// Service weight after the transition.
    pub weight: u32,
    /// Registry epoch after the transition.
    pub epoch: u64,
}

/// Rolling accumulators for the current window of one tenant.
struct WindowAccum {
    admitted: u64,
    completed: u64,
    rows: u64,
    shed: u64,
    expired: u64,
    batches: u64,
    stolen: u64,
    useful_macs: u64,
    active_slots: u64,
    depth_last: u64,
    depth_max: u64,
    queue: LogHistogram,
    service: LogHistogram,
}

impl WindowAccum {
    fn new() -> Self {
        Self {
            admitted: 0,
            completed: 0,
            rows: 0,
            shed: 0,
            expired: 0,
            batches: 0,
            stolen: 0,
            useful_macs: 0,
            active_slots: 0,
            depth_last: 0,
            depth_max: 0,
            queue: LogHistogram::new(),
            service: LogHistogram::new(),
        }
    }

    /// Reset for the next window in place (no allocation: the
    /// histograms clear their existing storage). The `depth_last` gauge
    /// carries over — depth is a level, not a rate.
    fn clear(&mut self) {
        self.admitted = 0;
        self.completed = 0;
        self.rows = 0;
        self.shed = 0;
        self.expired = 0;
        self.batches = 0;
        self.stolen = 0;
        self.useful_macs = 0;
        self.active_slots = 0;
        self.depth_max = self.depth_last;
        self.queue.clear();
        self.service.clear();
    }

    fn summarize(&self, start_us: u64, end_us: u64) -> WindowStats {
        let secs = ((end_us - start_us) as f64 / 1e6).max(1e-9);
        let denom = (self.admitted + self.shed) as f64;
        WindowStats {
            start_us,
            end_us,
            admitted: self.admitted,
            completed: self.completed,
            rows: self.rows,
            shed: self.shed,
            expired: self.expired,
            batches: self.batches,
            stolen: self.stolen,
            throughput_rps: self.completed as f64 / secs,
            shed_rate: if denom > 0.0 { self.shed as f64 / denom } else { 0.0 },
            steal_rate: if self.batches > 0 {
                self.stolen as f64 / self.batches as f64
            } else {
                0.0
            },
            sim_utilization: if self.active_slots > 0 {
                self.useful_macs as f64 / self.active_slots as f64
            } else {
                0.0
            },
            depth_last: self.depth_last,
            depth_max: self.depth_max,
            queue: self.queue.stats(),
            service: self.service.stats(),
        }
    }
}

/// Summary of one completed stats window for one tenant.
#[derive(Clone, Copy, Debug)]
pub struct WindowStats {
    /// Window start, µs since the telemetry origin.
    pub start_us: u64,
    /// Window end, µs since the telemetry origin.
    pub end_us: u64,
    /// Requests admitted in the window.
    pub admitted: u64,
    /// Requests answered in the window.
    pub completed: u64,
    /// Rows served in the window.
    pub rows: u64,
    /// Requests shed in the window.
    pub shed: u64,
    /// Requests expired past their deadline in the window.
    pub expired: u64,
    /// Batches served in the window.
    pub batches: u64,
    /// Of `batches`, how many arrived by work stealing.
    pub stolen: u64,
    /// Completed requests per second.
    pub throughput_rps: f64,
    /// `shed / (admitted + shed)` over the window.
    pub shed_rate: f64,
    /// `stolen / batches` over the window.
    pub steal_rate: f64,
    /// Simulated accelerator utilization over the window's batches.
    pub sim_utilization: f64,
    /// Queue depth after the window's last admission.
    pub depth_last: u64,
    /// Peak observed queue depth in the window.
    pub depth_max: u64,
    /// Queueing-delay distribution (admission → serve start).
    pub queue: Option<LatencyStats>,
    /// Service-time distribution (serve start → response).
    pub service: Option<LatencyStats>,
}

/// Cumulative per-tenant counters since gateway start (collector's
/// view; the authoritative conservation counters live in
/// [`GatewayStats`](super::gateway::GatewayStats)).
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantTotals {
    /// Requests admitted.
    pub admitted: u64,
    /// Requests answered.
    pub completed: u64,
    /// Requests shed.
    pub shed: u64,
    /// Requests expired.
    pub expired: u64,
    /// Batches served.
    pub batches: u64,
    /// Stolen batches.
    pub stolen: u64,
}

/// Collector-side state for one tenant slot.
struct TenantAgg {
    name: String,
    live: bool,
    cur: WindowAccum,
    last: Option<WindowStats>,
    totals: TenantTotals,
    flight: VecDeque<Event>,
}

impl TenantAgg {
    fn new(name: String, flight_cap: usize) -> Self {
        Self {
            name,
            live: true,
            cur: WindowAccum::new(),
            last: None,
            totals: TenantTotals::default(),
            flight: VecDeque::with_capacity(flight_cap.max(1)),
        }
    }

    fn remember(&mut self, ev: Event, cap: usize) {
        if self.flight.len() >= cap.max(1) {
            self.flight.pop_front();
        }
        self.flight.push_back(ev);
    }
}

/// In-flight span assembly for one traced request.
#[derive(Clone, Copy, Debug, Default)]
struct SpanBuild {
    tenant: u32,
    admitted_us: Option<u64>,
    enqueued_us: Option<u64>,
    batch_us: Option<u64>,
    stolen: bool,
    serve_us: Option<u64>,
    responded_us: Option<u64>,
    queue_us: u64,
    service_us: u64,
    worker: u16,
    dead: bool,
}

/// A completed request timeline from `--trace-sample` sampling:
/// admission → enqueue → batch (possibly stolen) → serve → respond.
#[derive(Clone, Debug)]
pub struct Span {
    /// Span id (the admission sequence number + 1).
    pub trace: u64,
    /// Tenant name.
    pub tenant: String,
    /// Admission time, µs since the telemetry origin.
    pub admitted_us: u64,
    /// Pull into a shard batcher, µs since origin.
    pub enqueued_us: Option<u64>,
    /// Batch formation, µs since origin.
    pub batch_us: Option<u64>,
    /// Whether the batch was work-stolen to another worker.
    pub stolen: bool,
    /// Service start, µs since origin.
    pub serve_us: Option<u64>,
    /// Response, µs since origin.
    pub responded_us: u64,
    /// Queueing delay, µs.
    pub queue_us: u64,
    /// Service time, µs.
    pub service_us: u64,
    /// Worker that served the request.
    pub worker: u16,
}

impl Span {
    /// Deterministic JSON line (`kind: "span"`).
    pub fn to_value(&self) -> Value {
        let opt = |v: Option<u64>| match v {
            Some(x) => Value::num(x as f64),
            None => Value::Null,
        };
        Value::obj([
            ("kind", Value::str("span")),
            ("trace", Value::num(self.trace as f64)),
            ("tenant", Value::str(self.tenant.clone())),
            ("admitted_us", Value::num(self.admitted_us as f64)),
            ("enqueued_us", opt(self.enqueued_us)),
            ("batch_us", opt(self.batch_us)),
            ("stolen", Value::Bool(self.stolen)),
            ("serve_us", opt(self.serve_us)),
            ("responded_us", Value::num(self.responded_us as f64)),
            ("queue_us", Value::num(self.queue_us as f64)),
            ("service_us", Value::num(self.service_us as f64)),
            ("worker", Value::num(self.worker as f64)),
        ])
    }

    /// One-line console rendering of the stage timeline.
    pub fn timeline(&self) -> String {
        let mut s =
            format!("trace {} [{}] t={}us admitted", self.trace, self.tenant, self.admitted_us);
        if let Some(t) = self.enqueued_us {
            s += &format!(" → +{}us enqueued", t.saturating_sub(self.admitted_us));
        }
        if let Some(t) = self.batch_us {
            let stage = if self.stolen { "batched(stolen)" } else { "batched" };
            s += &format!(" → +{}us {stage}", t.saturating_sub(self.admitted_us));
        }
        if let Some(t) = self.serve_us {
            s += &format!(" → +{}us serve[w{}]", t.saturating_sub(self.admitted_us), self.worker);
        }
        s += &format!(
            " → +{}us responded (queue {}us + service {}us)",
            self.responded_us.saturating_sub(self.admitted_us),
            self.queue_us,
            self.service_us
        );
        s
    }
}

const CHURN_CAP: usize = 1024;
const SPAN_BUFFER: usize = 256;
const GLOBAL_FLIGHT_CAP: usize = 64;

/// Collector-owned aggregation state (behind one mutex, touched only by
/// the collector thread, control-plane calls, and snapshot readers).
struct Aggregator {
    tenants: Vec<TenantAgg>,
    churn: VecDeque<ChurnRecord>,
    churn_dropped: u64,
    /// Fleet-wide events (epoch adoptions) — the global flight ring.
    global_flight: VecDeque<Event>,
    spans: HashMap<u64, SpanBuild>,
    done_spans: VecDeque<Span>,
    window_us: u64,
    window_start_us: u64,
    flight_cap: usize,
}

impl Aggregator {
    fn ensure_tenant(&mut self, tenant: u32) {
        let idx = tenant as usize;
        while self.tenants.len() <= idx {
            let name = format!("tenant{}", self.tenants.len());
            self.tenants.push(TenantAgg::new(name, self.flight_cap));
        }
    }

    fn apply(&mut self, ev: Event) {
        if ev.trace != 0 {
            self.apply_trace(ev);
        }
        if ev.tenant == NO_TENANT {
            if self.global_flight.len() >= GLOBAL_FLIGHT_CAP {
                self.global_flight.pop_front();
            }
            self.global_flight.push_back(ev);
            return;
        }
        self.ensure_tenant(ev.tenant);
        let cap = self.flight_cap;
        let t = &mut self.tenants[ev.tenant as usize];
        match ev.kind {
            EventKind::Admitted => {
                t.cur.admitted += 1;
                t.totals.admitted += 1;
                t.cur.depth_last = ev.a;
                t.cur.depth_max = t.cur.depth_max.max(ev.a);
            }
            EventKind::Enqueued => {}
            EventKind::BatchFormed => {
                if ev.rows == 0 {
                    return; // per-request trace echo: span-only
                }
            }
            EventKind::Stolen => {
                if ev.rows == 0 {
                    return; // per-request trace echo: span-only
                }
                t.cur.stolen += 1;
                t.totals.stolen += 1;
            }
            EventKind::ServeStart => {
                if ev.rows == 0 {
                    return; // per-request trace echo: span-only
                }
            }
            EventKind::ServeEnd => {
                t.cur.batches += 1;
                t.totals.batches += 1;
                t.cur.rows += ev.rows as u64;
                t.cur.useful_macs += ev.a;
                t.cur.active_slots += ev.b;
            }
            EventKind::Responded => {
                t.cur.completed += 1;
                t.totals.completed += 1;
                t.cur.queue.record(ev.a);
                t.cur.service.record(ev.b);
            }
            EventKind::Shed => {
                t.cur.shed += 1;
                t.totals.shed += 1;
            }
            EventKind::Expired => {
                t.cur.expired += 1;
                t.totals.expired += 1;
            }
            EventKind::EpochAdopted => {}
        }
        t.remember(ev, cap);
    }

    fn apply_trace(&mut self, ev: Event) {
        let s = self.spans.entry(ev.trace).or_default();
        s.tenant = ev.tenant;
        match ev.kind {
            EventKind::Admitted => s.admitted_us = Some(ev.t_us),
            EventKind::Enqueued => s.enqueued_us = Some(ev.t_us),
            EventKind::BatchFormed => s.batch_us = Some(ev.t_us),
            EventKind::Stolen => {
                s.batch_us = s.batch_us.or(Some(ev.t_us));
                s.stolen = true;
            }
            EventKind::ServeStart => {
                s.serve_us = Some(ev.t_us);
                s.worker = ev.worker;
            }
            EventKind::Responded => {
                s.responded_us = Some(ev.t_us);
                s.queue_us = ev.a;
                s.service_us = ev.b;
                s.worker = ev.worker;
            }
            EventKind::Shed | EventKind::Expired => s.dead = true,
            _ => {}
        }
    }

    /// Move finished span builds to the bounded output buffer and drop
    /// dead or stale ones.
    fn reap_spans(&mut self, now_us: u64) {
        if self.spans.is_empty() {
            return;
        }
        let mut done: Vec<(u64, SpanBuild)> = Vec::new();
        self.spans.retain(|&trace, s| {
            if s.dead {
                return false;
            }
            if s.responded_us.is_some() && s.admitted_us.is_some() {
                done.push((trace, *s));
                return false;
            }
            // stale guard: an incomplete span whose newest stage is
            // over 30s old will never finish (its terminal event was
            // dropped on ring overflow)
            let newest = s
                .responded_us
                .or(s.serve_us)
                .or(s.batch_us)
                .or(s.enqueued_us)
                .or(s.admitted_us)
                .unwrap_or(now_us);
            now_us.saturating_sub(newest) < 30_000_000
        });
        done.sort_by_key(|(trace, _)| *trace);
        for (trace, s) in done {
            let tenant = self
                .tenants
                .get(s.tenant as usize)
                .map(|t| t.name.clone())
                .unwrap_or_else(|| format!("tenant{}", s.tenant));
            if self.done_spans.len() >= SPAN_BUFFER {
                self.done_spans.pop_front();
            }
            self.done_spans.push_back(Span {
                trace,
                tenant,
                admitted_us: s.admitted_us.unwrap_or(0),
                enqueued_us: s.enqueued_us,
                batch_us: s.batch_us,
                stolen: s.stolen,
                serve_us: s.serve_us,
                responded_us: s.responded_us.unwrap_or(0),
                queue_us: s.queue_us,
                service_us: s.service_us,
                worker: s.worker,
            });
        }
    }

    fn maybe_roll(&mut self, now_us: u64) {
        if now_us.saturating_sub(self.window_start_us) < self.window_us {
            return;
        }
        for t in &mut self.tenants {
            t.last = Some(t.cur.summarize(self.window_start_us, now_us));
            t.cur.clear();
        }
        self.window_start_us = now_us;
    }

    fn record_churn(&mut self, rec: ChurnRecord) {
        self.ensure_tenant(rec.tenant);
        let t = &mut self.tenants[rec.tenant as usize];
        t.name = rec.name.clone();
        match rec.kind {
            ChurnKind::Registered | ChurnKind::Added => t.live = true,
            ChurnKind::Removed | ChurnKind::RemoveBegin => t.live = false,
            ChurnKind::Reweighted => {}
        }
        if self.churn.len() >= CHURN_CAP {
            self.churn.pop_front();
            self.churn_dropped += 1;
        }
        self.churn.push_back(rec);
    }
}

/// Point-in-time view of one tenant's telemetry.
#[derive(Clone, Debug)]
pub struct TenantSnapshot {
    /// Tenant name.
    pub name: String,
    /// Whether the tenant is still registered and accepting.
    pub live: bool,
    /// Last completed window (or the partial current window before the
    /// first roll).
    pub window: Option<WindowStats>,
    /// Cumulative collector-side totals.
    pub totals: TenantTotals,
}

/// Point-in-time view of the whole telemetry spine
/// ([`Telemetry::snapshot`]). Completed trace spans are *moved* into
/// the snapshot that observes them, so streamed JSONL lines never
/// repeat a span.
#[derive(Clone, Debug)]
pub struct TelemetrySnapshot {
    /// Snapshot time, µs since the telemetry origin.
    pub at_us: u64,
    /// Events dropped on ring overflow since start (all rings).
    pub dropped_events: u64,
    /// Per-tenant windowed stats.
    pub tenants: Vec<TenantSnapshot>,
    /// Trace spans completed since the previous snapshot.
    pub spans: Vec<Span>,
}

impl TelemetrySnapshot {
    /// Deterministic JSON object (`kind: "window"`) for
    /// `TELEMETRY.jsonl` streaming.
    pub fn to_value(&self) -> Value {
        let lat = |l: &Option<LatencyStats>| match l {
            None => Value::Null,
            Some(s) => Value::obj([
                ("count", Value::num(s.count as f64)),
                ("mean_us", Value::num(s.mean_us)),
                ("p50_us", Value::num(s.p50_us as f64)),
                ("p95_us", Value::num(s.p95_us as f64)),
                ("p99_us", Value::num(s.p99_us as f64)),
                ("max_us", Value::num(s.max_us as f64)),
            ]),
        };
        let tenants = self.tenants.iter().map(|t| {
            let window = match &t.window {
                None => Value::Null,
                Some(w) => Value::obj([
                    ("start_us", Value::num(w.start_us as f64)),
                    ("end_us", Value::num(w.end_us as f64)),
                    ("admitted", Value::num(w.admitted as f64)),
                    ("completed", Value::num(w.completed as f64)),
                    ("rows", Value::num(w.rows as f64)),
                    ("shed", Value::num(w.shed as f64)),
                    ("expired", Value::num(w.expired as f64)),
                    ("batches", Value::num(w.batches as f64)),
                    ("stolen", Value::num(w.stolen as f64)),
                    ("throughput_rps", Value::num(w.throughput_rps)),
                    ("shed_rate", Value::num(w.shed_rate)),
                    ("steal_rate", Value::num(w.steal_rate)),
                    ("sim_utilization", Value::num(w.sim_utilization)),
                    ("depth_last", Value::num(w.depth_last as f64)),
                    ("depth_max", Value::num(w.depth_max as f64)),
                    ("queue", lat(&w.queue)),
                    ("service", lat(&w.service)),
                ]),
            };
            Value::obj([
                ("name", Value::str(t.name.clone())),
                ("live", Value::Bool(t.live)),
                ("window", window),
                (
                    "totals",
                    Value::obj([
                        ("admitted", Value::num(t.totals.admitted as f64)),
                        ("completed", Value::num(t.totals.completed as f64)),
                        ("shed", Value::num(t.totals.shed as f64)),
                        ("expired", Value::num(t.totals.expired as f64)),
                        ("batches", Value::num(t.totals.batches as f64)),
                        ("stolen", Value::num(t.totals.stolen as f64)),
                    ]),
                ),
            ])
        });
        Value::obj([
            ("kind", Value::str("window")),
            ("at_us", Value::num(self.at_us as f64)),
            ("dropped_events", Value::num(self.dropped_events as f64)),
            ("tenants", Value::arr(tenants)),
        ])
    }
}

/// On-demand dump of the flight recorder: every retained churn record
/// (in transition order) plus the last N lifecycle events per tenant.
#[derive(Clone, Debug)]
pub struct FlightDump {
    /// Dump time, µs since the telemetry origin.
    pub at_us: u64,
    /// Registry transitions, oldest first.
    pub churn: Vec<ChurnRecord>,
    /// Older churn records evicted from the bounded recorder.
    pub churn_dropped: u64,
    /// `(tenant name, last N lifecycle events)` per tenant slot.
    pub tenants: Vec<(String, Vec<Event>)>,
    /// Fleet-wide events (epoch adoptions), oldest first.
    pub global: Vec<Event>,
}

impl FlightDump {
    /// Deterministic JSON object (`kind: "flight"`).
    pub fn to_value(&self) -> Value {
        let ev = |e: &Event| {
            Value::obj([
                ("t_us", Value::num(e.t_us as f64)),
                ("event", Value::str(e.kind.name())),
                ("rows", Value::num(e.rows as f64)),
                ("worker", Value::num(e.worker as f64)),
                ("a", Value::num(e.a as f64)),
                ("b", Value::num(e.b as f64)),
            ])
        };
        Value::obj([
            ("kind", Value::str("flight")),
            ("at_us", Value::num(self.at_us as f64)),
            ("churn_dropped", Value::num(self.churn_dropped as f64)),
            (
                "churn",
                Value::arr(self.churn.iter().map(|c| {
                    Value::obj([
                        ("t_us", Value::num(c.t_us as f64)),
                        ("action", Value::str(c.kind.name())),
                        ("tenant", Value::str(c.name.clone())),
                        ("weight", Value::num(c.weight as f64)),
                        ("epoch", Value::num(c.epoch as f64)),
                    ])
                })),
            ),
            (
                "tenants",
                Value::arr(self.tenants.iter().map(|(name, evs)| {
                    Value::obj([
                        ("name", Value::str(name.clone())),
                        ("events", Value::arr(evs.iter().map(ev))),
                    ])
                })),
            ),
            ("global", Value::arr(self.global.iter().map(ev))),
        ])
    }
}

/// The telemetry spine owned by a gateway: rings, aggregator, trace
/// sampler, and the collector's control surface.
pub struct Telemetry {
    cfg: TelemetryConfig,
    clock: Clock,
    /// One ring per worker, plus the admission ring at index
    /// `workers` (its producer is the state-lock holder).
    rings: Vec<EventRing>,
    workers: usize,
    seq: AtomicU64,
    agg: Mutex<Aggregator>,
    stop: AtomicBool,
}

impl Telemetry {
    /// Build the spine for `workers` worker threads and the given
    /// initial tenants, on the real wall clock. When `cfg.enabled` is
    /// false no rings are allocated and every emit reduces to one
    /// branch.
    pub fn new(cfg: TelemetryConfig, workers: usize, tenants: &[&str]) -> Self {
        Self::new_with_clock(cfg, workers, tenants, Clock::real())
    }

    /// Like [`Telemetry::new`], but timestamping events and rolling
    /// windows on an injected [`Clock`]. The gateway passes its own
    /// clock here so that under a manual test clock the telemetry
    /// windows (and everything the autoscaler reads from them) advance
    /// only when the test advances time.
    pub fn new_with_clock(
        cfg: TelemetryConfig,
        workers: usize,
        tenants: &[&str],
        clock: Clock,
    ) -> Self {
        let rings = if cfg.enabled {
            (0..workers + 1).map(|_| EventRing::new(cfg.ring_capacity)).collect()
        } else {
            Vec::new()
        };
        let window_us = cfg.window.as_micros().max(1) as u64;
        let agg = Aggregator {
            tenants: tenants
                .iter()
                .map(|n| TenantAgg::new((*n).to_string(), cfg.flight_capacity))
                .collect(),
            churn: VecDeque::with_capacity(64),
            churn_dropped: 0,
            global_flight: VecDeque::with_capacity(GLOBAL_FLIGHT_CAP),
            spans: HashMap::new(),
            done_spans: VecDeque::new(),
            window_us,
            window_start_us: 0,
            flight_cap: cfg.flight_capacity,
        };
        Self {
            cfg,
            clock,
            rings,
            workers,
            seq: AtomicU64::new(0),
            agg: Mutex::new(agg),
            stop: AtomicBool::new(false),
        }
    }

    /// Whether the spine is recording.
    #[inline]
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// The configuration this spine was built with.
    pub fn config(&self) -> &TelemetryConfig {
        &self.cfg
    }

    /// Microseconds on the spine's clock (monotonic; since process
    /// start on the real clock, since 0 on a manual test clock).
    #[inline]
    pub fn clock_us(&self) -> u64 {
        self.clock.now_us()
    }

    /// Emit from worker `worker`'s ring (single producer: that worker's
    /// thread, whether or not it holds the state lock).
    #[inline]
    pub(crate) fn emit_worker(
        &self,
        worker: usize,
        kind: EventKind,
        tenant: u32,
        rows: u32,
        a: u64,
        b: u64,
        trace: u64,
    ) {
        if !self.cfg.enabled {
            return;
        }
        self.rings[worker].push(Event {
            t_us: self.clock_us(),
            a,
            b,
            trace,
            tenant,
            rows,
            worker: worker as u16,
            kind,
        });
    }

    /// Emit from the admission ring. The caller MUST hold the gateway
    /// state lock — that lock is what makes this ring single-producer.
    #[inline]
    pub(crate) fn emit_admission(
        &self,
        kind: EventKind,
        tenant: u32,
        rows: u32,
        a: u64,
        b: u64,
        trace: u64,
    ) {
        if !self.cfg.enabled {
            return;
        }
        self.rings[self.workers].push(Event {
            t_us: self.clock_us(),
            a,
            b,
            trace,
            tenant,
            rows,
            worker: self.workers as u16,
            kind,
        });
    }

    /// Allocate a span id for a newly admitted request: nonzero for
    /// 1-in-N sampled requests, 0 (untraced) otherwise.
    #[inline]
    pub(crate) fn next_trace(&self) -> u64 {
        let n = self.cfg.trace_sample;
        if !self.cfg.enabled || n == 0 {
            return 0;
        }
        let s = self.seq.fetch_add(1, Ordering::Relaxed);
        if s % n == 0 {
            s + 1
        } else {
            0
        }
    }

    /// Events dropped on ring overflow since start.
    pub fn dropped_events(&self) -> u64 {
        self.rings.iter().map(EventRing::dropped).sum()
    }

    /// Record a registry transition in the flight recorder. Called from
    /// the gateway's admin-serialized control plane, so arrival order is
    /// transition order.
    pub(crate) fn record_churn(
        &self,
        kind: ChurnKind,
        tenant: u32,
        name: &str,
        weight: u32,
        epoch: u64,
    ) {
        if !self.cfg.enabled {
            return;
        }
        let rec = ChurnRecord {
            t_us: self.clock_us(),
            kind,
            tenant,
            name: name.to_string(),
            weight,
            epoch,
        };
        self.agg.lock().unwrap().record_churn(rec);
    }

    /// One drain-and-aggregate pass over every ring. The collector
    /// thread calls this in a loop; tests and snapshotting call it
    /// directly. Steady-state passes allocate nothing (histograms and
    /// flight rings are pre-sized; spans only exist under
    /// `trace_sample`).
    pub fn collect(&self) {
        if !self.cfg.enabled {
            return;
        }
        let mut agg = self.agg.lock().unwrap();
        for ring in &self.rings {
            ring.drain(|ev| agg.apply(ev));
        }
        let now = self.clock_us();
        agg.reap_spans(now);
        agg.maybe_roll(now);
    }

    /// Drain the rings and summarize: per-tenant windowed stats (last
    /// completed window, or the partial current one before the first
    /// roll), cumulative totals, and any trace spans completed since
    /// the previous snapshot (moved out, not copied).
    pub fn snapshot(&self) -> TelemetrySnapshot {
        self.collect();
        let now = self.clock_us();
        let mut agg = self.agg.lock().unwrap();
        let window_start = agg.window_start_us;
        let tenants = agg
            .tenants
            .iter()
            .map(|t| TenantSnapshot {
                name: t.name.clone(),
                live: t.live,
                window: t.last.or_else(|| {
                    if t.cur.admitted + t.cur.completed + t.cur.shed > 0 {
                        Some(t.cur.summarize(window_start, now))
                    } else {
                        None
                    }
                }),
                totals: t.totals,
            })
            .collect();
        let spans = agg.done_spans.drain(..).collect();
        TelemetrySnapshot {
            at_us: now,
            dropped_events: self.dropped_events(),
            tenants,
            spans,
        }
    }

    /// Dump the flight recorder: all retained churn records in order
    /// plus the last N lifecycle events per tenant.
    pub fn flight_dump(&self) -> FlightDump {
        self.collect();
        let agg = self.agg.lock().unwrap();
        FlightDump {
            at_us: self.clock_us(),
            churn: agg.churn.iter().cloned().collect(),
            churn_dropped: agg.churn_dropped,
            tenants: agg
                .tenants
                .iter()
                .map(|t| (t.name.clone(), t.flight.iter().copied().collect()))
                .collect(),
            global: agg.global_flight.iter().copied().collect(),
        }
    }

    /// Ask the collector loop to exit after a final drain. Wakes any
    /// thread parked in the clock (the collector's tick sleep) so
    /// shutdown is prompt on the real clock and doesn't deadlock on a
    /// manual one.
    pub(crate) fn stop(&self) {
        self.stop.store(true, Ordering::Release);
        self.clock.wake_all();
    }

    /// The collector thread body: drain the rings at roughly a quarter
    /// of the window period (clamped to [1ms, 100ms]) until stopped,
    /// then run one final pass so shutdown snapshots see every event.
    /// The tick sleeps on the spine's [`Clock`], so under a manual
    /// clock the collector runs a pass per `advance` instead of
    /// free-running.
    pub(crate) fn run_collector(&self) {
        let tick =
            (self.cfg.window / 4).clamp(Duration::from_millis(1), Duration::from_millis(100));
        while !self.stop.load(Ordering::Acquire) {
            self.collect();
            self.clock.sleep(tick);
        }
        self.collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, tenant: u32, rows: u32, a: u64, b: u64) -> Event {
        Event { t_us: 1, a, b, trace: 0, tenant, rows, worker: 0, kind }
    }

    #[test]
    fn ring_push_drain_fifo() {
        let r = EventRing::new(8);
        assert_eq!(r.capacity(), 8);
        for i in 0..5u64 {
            assert!(r.push(ev(EventKind::Admitted, 0, 1, i, 0)));
        }
        let mut seen = Vec::new();
        assert_eq!(r.drain(|e| seen.push(e.a)), 5);
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
        assert_eq!(r.drain(|_| panic!("empty")), 0);
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn ring_overflow_drops_and_counts() {
        let r = EventRing::new(4);
        for i in 0..10u64 {
            r.push(ev(EventKind::Admitted, 0, 1, i, 0));
        }
        assert_eq!(r.dropped(), 6, "capacity 4, 10 pushes: 6 dropped");
        let mut seen = Vec::new();
        r.drain(|e| seen.push(e.a));
        assert_eq!(seen, vec![0, 1, 2, 3], "oldest events survive, newest drop");
        // after a drain the ring accepts events again
        assert!(r.push(ev(EventKind::Admitted, 0, 1, 99, 0)));
        assert_eq!(r.dropped(), 6);
    }

    #[test]
    fn ring_capacity_rounds_up() {
        assert_eq!(EventRing::new(0).capacity(), 2);
        assert_eq!(EventRing::new(5).capacity(), 8);
        assert_eq!(EventRing::new(8).capacity(), 8);
    }

    #[test]
    fn ring_spsc_stress() {
        let r = std::sync::Arc::new(EventRing::new(64));
        let p = std::sync::Arc::clone(&r);
        let producer = std::thread::spawn(move || {
            for i in 0..10_000u64 {
                p.push(ev(EventKind::Responded, 0, 1, i, 0));
            }
        });
        let mut last = None::<u64>;
        let mut consumed = 0u64;
        loop {
            let done = producer.is_finished();
            let n = r.drain(|e| {
                if let Some(l) = last {
                    assert!(e.a > l, "monotone sequence per producer");
                }
                last = Some(e.a);
            });
            consumed += n as u64;
            // check `done` from BEFORE the drain so the producer can't
            // finish between our last drain and the exit test
            if done && n == 0 {
                break;
            }
            std::hint::spin_loop();
        }
        producer.join().unwrap();
        assert_eq!(consumed + r.dropped(), 10_000, "every event consumed or counted");
        assert!(consumed > 0);
    }

    fn spine(cfg: TelemetryConfig) -> Telemetry {
        Telemetry::new(cfg, 2, &["alpha", "beta"])
    }

    #[test]
    fn windowed_aggregation_and_snapshot() {
        let tel = spine(TelemetryConfig {
            window: Duration::from_micros(1), // every collect rolls
            ..TelemetryConfig::default()
        });
        tel.emit_admission(EventKind::Admitted, 0, 1, 3, 0, 0);
        tel.emit_worker(0, EventKind::Enqueued, 0, 1, 0, 0, 0);
        tel.emit_worker(0, EventKind::BatchFormed, 0, 4, 120, 0, 0);
        tel.emit_worker(0, EventKind::ServeStart, 0, 4, 0, 0, 0);
        tel.emit_worker(0, EventKind::ServeEnd, 0, 4, 300, 1000, 0);
        tel.emit_worker(0, EventKind::Responded, 0, 1, 250, 90, 0);
        tel.emit_worker(1, EventKind::Stolen, 1, 2, 0, 0, 0);
        tel.emit_worker(1, EventKind::ServeEnd, 1, 2, 50, 100, 0);
        tel.emit_admission(EventKind::Shed, 1, 1, 0, 0, 0);
        std::thread::sleep(Duration::from_millis(1));
        let snap = tel.snapshot();
        assert_eq!(snap.dropped_events, 0);
        assert_eq!(snap.tenants.len(), 2);
        let a = &snap.tenants[0];
        assert_eq!(a.name, "alpha");
        assert_eq!(a.totals.admitted, 1);
        assert_eq!(a.totals.completed, 1);
        assert_eq!(a.totals.batches, 1);
        let w = a.window.expect("window summarized");
        assert_eq!(w.completed, 1);
        assert_eq!(w.rows, 4);
        assert!((w.sim_utilization - 0.3).abs() < 1e-12);
        assert_eq!(w.queue.unwrap().p50_us, 250);
        assert_eq!(w.service.unwrap().max_us, 90);
        assert_eq!(w.depth_last, 3);
        let b = &snap.tenants[1];
        assert_eq!(b.totals.stolen, 1);
        assert_eq!(b.totals.shed, 1);
        let wb = b.window.unwrap();
        assert!((wb.steal_rate - 1.0).abs() < 1e-12);
        assert!((wb.shed_rate - 1.0).abs() < 1e-12, "1 shed, 0 admitted");
    }

    #[test]
    fn flight_recorder_bounds_and_churn_order() {
        let tel = Telemetry::new(
            TelemetryConfig { flight_capacity: 4, ..TelemetryConfig::default() },
            1,
            &["only"],
        );
        for i in 0..10u64 {
            tel.emit_worker(0, EventKind::Responded, 0, 1, i, 1, 0);
        }
        tel.record_churn(ChurnKind::Registered, 0, "only", 1, 1);
        tel.record_churn(ChurnKind::Added, 1, "hot", 2, 2);
        tel.record_churn(ChurnKind::Reweighted, 1, "hot", 6, 3);
        tel.record_churn(ChurnKind::RemoveBegin, 1, "hot", 6, 3);
        tel.record_churn(ChurnKind::Removed, 1, "hot", 6, 5);
        let dump = tel.flight_dump();
        assert_eq!(dump.tenants[0].1.len(), 4, "flight ring bounded");
        assert_eq!(dump.tenants[0].1.last().unwrap().a, 9, "newest retained");
        let kinds: Vec<ChurnKind> = dump.churn.iter().map(|c| c.kind).collect();
        assert_eq!(
            kinds,
            vec![
                ChurnKind::Registered,
                ChurnKind::Added,
                ChurnKind::Reweighted,
                ChurnKind::RemoveBegin,
                ChurnKind::Removed
            ],
            "churn records keep transition order"
        );
        assert_eq!(dump.tenants[1].0, "hot", "churn labels the hot-added tenant slot");
        let snap = tel.snapshot();
        assert!(!snap.tenants[1].live, "removed tenant reads dead");
    }

    #[test]
    fn trace_sampling_assembles_spans() {
        let tel = Telemetry::new(
            TelemetryConfig { trace_sample: 1, ..TelemetryConfig::default() },
            1,
            &["t"],
        );
        let trace = tel.next_trace();
        assert_ne!(trace, 0, "1-in-1 sampling traces everything");
        tel.emit_admission(EventKind::Admitted, 0, 1, 1, 0, trace);
        tel.emit_worker(0, EventKind::Enqueued, 0, 1, 0, 0, trace);
        tel.emit_worker(0, EventKind::Stolen, 0, 0, 0, 0, trace);
        tel.emit_worker(0, EventKind::ServeStart, 0, 0, 0, 0, trace);
        tel.emit_worker(0, EventKind::Responded, 0, 1, 120, 40, trace);
        let snap = tel.snapshot();
        assert_eq!(snap.spans.len(), 1);
        let s = &snap.spans[0];
        assert_eq!(s.trace, trace);
        assert_eq!(s.tenant, "t");
        assert!(s.stolen);
        assert_eq!((s.queue_us, s.service_us), (120, 40));
        assert!(s.timeline().contains("stolen"));
        // spans are moved out: a second snapshot repeats nothing
        assert!(tel.snapshot().spans.is_empty());
        // 1-in-4 sampling traces every 4th admission
        let tel = Telemetry::new(
            TelemetryConfig { trace_sample: 4, ..TelemetryConfig::default() },
            1,
            &["t"],
        );
        let traced = (0..16).filter(|_| tel.next_trace() != 0).count();
        assert_eq!(traced, 4);
    }

    #[test]
    fn disabled_spine_is_inert() {
        let tel = Telemetry::new(TelemetryConfig::off(), 4, &["x"]);
        assert!(!tel.enabled());
        tel.emit_worker(0, EventKind::Responded, 0, 1, 1, 1, 0);
        tel.emit_admission(EventKind::Admitted, 0, 1, 1, 0, 0);
        assert_eq!(tel.next_trace(), 0);
        assert_eq!(tel.dropped_events(), 0);
        let snap = tel.snapshot();
        assert!(snap.tenants[0].window.is_none());
    }

    #[test]
    fn jsonl_rendering_fixpoint() {
        let tel = spine(TelemetryConfig {
            window: Duration::from_micros(1),
            trace_sample: 1,
            ..TelemetryConfig::default()
        });
        let trace = tel.next_trace();
        tel.emit_admission(EventKind::Admitted, 0, 1, 1, 0, trace);
        tel.emit_worker(0, EventKind::Responded, 0, 1, 100, 20, trace);
        tel.record_churn(ChurnKind::Registered, 0, "alpha", 1, 1);
        std::thread::sleep(Duration::from_millis(1));
        let snap = tel.snapshot();
        for v in [snap.to_value(), tel.flight_dump().to_value()]
            .into_iter()
            .chain(snap.spans.iter().map(Span::to_value))
        {
            let line = v.render();
            let reparsed = Value::parse(&line).expect("snapshot json parses");
            assert_eq!(reparsed.render(), line, "render→parse→render fixpoint");
        }
    }
}
