//! The serving loop: request queue -> dynamic batcher -> engine worker.
//!
//! One dispatcher thread owns the integer engine and the batcher; clients
//! hold a cloneable [`Handle`] that submits requests and blocks on a
//! per-request response channel. Every request is answered exactly once
//! (conservation is property-tested in the integration suite).

use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::arch::ArrayConfig;
use crate::kan::Engine;

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub policy: BatchPolicy,
    /// Accelerator config used to attach simulated cycle counts to each
    /// served batch (a scalar config is always compatible; vector configs
    /// are re-instantiated per layer as needed).
    pub sim_array: ArrayConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { policy: BatchPolicy::default(), sim_array: ArrayConfig::kan_sas(16, 16, 4, 8) }
    }
}

/// One inference request: quantized input row + response channel.
struct Request {
    x_q: Vec<u8>,
    submitted: Instant,
    resp: Sender<Result<Response, String>>,
}

/// Response: i64 accumulators for the row (argmax = class) + timing.
#[derive(Clone, Debug)]
pub struct Response {
    pub t: Vec<i64>,
    pub latency_us: u64,
}

impl Response {
    pub fn prediction(&self) -> usize {
        self.t
            .iter()
            .enumerate()
            .max_by_key(|&(_, v)| *v)
            .map(|(i, _)| i)
            .unwrap()
    }
}

/// Cloneable client handle.
#[derive(Clone)]
pub struct Handle {
    tx: Sender<Request>,
    in_dim: usize,
}

impl Handle {
    /// Submit one quantized row and wait for its logits.
    pub fn infer_q(&self, x_q: Vec<u8>) -> Result<Response> {
        if x_q.len() != self.in_dim {
            return Err(anyhow!("input dim {} != model {}", x_q.len(), self.in_dim));
        }
        let (tx, rx) = channel();
        self.tx
            .send(Request { x_q, submitted: Instant::now(), resp: tx })
            .map_err(|_| anyhow!("server stopped"))?;
        rx.recv().map_err(|_| anyhow!("server dropped request"))?.map_err(|e| anyhow!(e))
    }

    /// Submit a float (spline-domain) row.
    pub fn infer(&self, x: &[f32]) -> Result<Response> {
        self.infer_q(crate::quant::quantize_activations(x))
    }
}

/// A running server; dropping it (after `shutdown`) joins the worker.
pub struct Server {
    handle: Handle,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Mutex<Metrics>>,
    stop_tx: Sender<()>,
}

impl Server {
    pub fn start(engine: Engine, cfg: ServerConfig) -> Self {
        let (req_tx, req_rx) = channel::<Request>();
        let (stop_tx, stop_rx) = channel::<()>();
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let metrics_worker = Arc::clone(&metrics);
        let in_dim = engine.model.in_dim();
        let worker = std::thread::Builder::new()
            .name("kansas-dispatch".into())
            .spawn(move || dispatch_loop(engine, cfg, req_rx, stop_rx, metrics_worker))
            .expect("spawn dispatcher");
        Self { handle: Handle { tx: req_tx, in_dim }, worker: Some(worker), metrics, stop_tx }
    }

    pub fn handle(&self) -> Handle {
        self.handle.clone()
    }

    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Stop accepting work and join the dispatcher (queued requests are
    /// drained first).
    pub fn shutdown(mut self) -> Metrics {
        let _ = self.stop_tx.send(());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.metrics.lock().unwrap().clone()
    }
}

fn dispatch_loop(
    engine: Engine,
    cfg: ServerConfig,
    req_rx: Receiver<Request>,
    stop_rx: Receiver<()>,
    metrics: Arc<Mutex<Metrics>>,
) {
    let mut batcher: Batcher<Request> = Batcher::new(cfg.policy);
    let mut stopping = false;
    loop {
        if !stopping && matches!(stop_rx.try_recv(), Ok(()) | Err(TryRecvError::Disconnected)) {
            stopping = true;
        }
        // pull requests until the batch closes or the queue stalls
        match req_rx.recv_timeout(batcher.time_left()) {
            Ok(req) => batcher.push(req),
            Err(_) => {
                if stopping && batcher.is_empty() {
                    // drain anything that raced in, then exit
                    while let Ok(req) = req_rx.try_recv() {
                        batcher.push(req);
                    }
                    if batcher.is_empty() {
                        break;
                    }
                }
            }
        }
        if !(batcher.ready() || (stopping && !batcher.is_empty())) {
            continue;
        }
        let batch = batcher.drain();
        let bs = batch.len();
        let in_dim = engine.model.in_dim();
        let out_dim = engine.model.out_dim();
        let mut x_q = Vec::with_capacity(bs * in_dim);
        for r in &batch {
            x_q.extend_from_slice(&r.x_q);
        }
        let result = engine.forward_from_q(&x_q, bs);
        let sim = engine.simulate_batch(&cfg.sim_array, bs);
        let mut m = metrics.lock().unwrap();
        m.record_batch(bs, sim.cycles);
        match result {
            Ok(fwd) => {
                for (i, req) in batch.into_iter().enumerate() {
                    let latency = req.submitted.elapsed();
                    m.record_request(latency);
                    let _ = req.resp.send(Ok(Response {
                        t: fwd.t[i * out_dim..(i + 1) * out_dim].to_vec(),
                        latency_us: latency.as_micros() as u64,
                    }));
                }
            }
            Err(e) => {
                let msg = format!("inference failed: {e}");
                for req in batch {
                    let _ = req.resp.send(Err(msg.clone()));
                }
            }
        }
    }
}
