//! The single-replica serving facade: `Server` is the 1-model,
//! 1-replica special case of the [`Gateway`](super::gateway::Gateway)
//! (by way of [`pool::Pool`](super::pool::Pool)).
//!
//! It keeps the original never-reject semantics by running one worker
//! over a deep admission queue with [`ShedPolicy::Block`] backpressure —
//! so the dispatcher loop, batching, metrics, and shutdown-drain
//! behaviour are the gateway's, tested once. That single worker owns the
//! server's [`Scratch`](crate::kan::Scratch) arena, so `Server` inherits
//! the zero-allocation steady-state dispatch path too.
//!
//! Errors are the unified [`ServeError`] — the old `anyhow::Result`
//! facade is gone, so `Server`, `Pool`, and `Gateway` clients all match
//! on one enum.

use crate::arch::ArrayConfig;
use crate::kan::Engine;

use super::batcher::BatchPolicy;
use super::gateway::{Dispatch, QuotaPolicy, ServeError};
use super::metrics::Metrics;
use super::pool::{Pool, PoolConfig, PoolHandle, ShedPolicy};
use super::telemetry::TelemetryConfig;

pub use super::gateway::Response;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Dynamic batching policy for the single worker.
    pub policy: BatchPolicy,
    /// Accelerator config used to attach simulated cycle counts to each
    /// served batch (a scalar config is always compatible; vector configs
    /// are re-instantiated per layer as needed).
    pub sim_array: ArrayConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self { policy: BatchPolicy::default(), sim_array: ArrayConfig::kan_sas(16, 16, 4, 8) }
    }
}

/// Cloneable client handle.
#[derive(Clone)]
pub struct Handle {
    inner: PoolHandle,
}

impl Handle {
    /// Submit one quantized row and wait for its logits.
    pub fn infer_q(&self, x_q: Vec<u8>) -> Result<Response, ServeError> {
        self.inner.infer_q(x_q)
    }

    /// Submit a float (spline-domain) row.
    pub fn infer(&self, x: &[f32]) -> Result<Response, ServeError> {
        self.inner.infer(x)
    }
}

/// A running server; `shutdown` drains queued requests and joins the
/// worker. Every request is answered exactly once (conservation is
/// property-tested in the integration suite, against the pool).
pub struct Server {
    pool: Pool,
}

impl Server {
    /// Spawn the single worker serving `engine`.
    pub fn start(engine: Engine, cfg: ServerConfig) -> Self {
        Self {
            pool: Pool::start(
                engine,
                PoolConfig {
                    replicas: 1,
                    // deep queue + blocking admission reproduce the old
                    // unbounded-channel semantics: clients wait, nothing
                    // is ever answered QueueFull
                    queue_cap: 65_536,
                    shed: ShedPolicy::Block,
                    policy: cfg.policy,
                    sim_array: cfg.sim_array,
                    // one worker has no peers to steal from; fair
                    // dispatch degenerates to the plain batcher loop
                    dispatch: Dispatch::FairSteal,
                    // a single tenant needs no admission reservations
                    quota: QuotaPolicy::None,
                    telemetry: TelemetryConfig::default(),
                    ..Default::default()
                },
            ),
        }
    }

    /// A cloneable client handle.
    pub fn handle(&self) -> Handle {
        Handle { inner: self.pool.handle() }
    }

    /// Live snapshot of the worker's merged metrics.
    pub fn metrics(&self) -> Metrics {
        self.pool.stats().merged
    }

    /// Stop accepting work and join the worker (queued requests are
    /// drained first).
    pub fn shutdown(self) -> Metrics {
        self.pool.shutdown().merged
    }
}
