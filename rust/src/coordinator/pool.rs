//! Sharded multi-replica serving pool with admission control.
//!
//! The paper's utilization argument, applied to the serving tier: a single
//! dispatcher thread owning a single engine leaves the rest of the host
//! idle the same way a conventional SA idles on B-splines. The pool runs
//! N worker threads, each owning an [`Engine`] *replica* — a clone whose
//! weights, LUT ROMs, and widened MAC tables all alias the original's
//! allocations through `Arc` (see `Engine::shares_weights_with`), so N
//! replicas cost ~1x model memory.
//!
//! Admission is a bounded MPMC queue (mutex + condvars — std-only, like
//! the rest of the crate) with an explicit [`ShedPolicy`]:
//!
//! * [`ShedPolicy::RejectNew`] — overload answers `QueueFull` immediately
//!   (open-loop traffic: shedding beats unbounded queueing);
//! * [`ShedPolicy::DropOldest`] — evict the stalest queued request (its
//!   client gets `QueueFull`) and admit the newcomer;
//! * [`ShedPolicy::Block`] — backpressure the submitter (closed-loop
//!   clients; also how the 1-replica [`super::Server`] keeps its
//!   never-reject semantics).
//!
//! Each worker runs its own dynamic [`Batcher`] whose deadlines are
//! anchored at admission time, serves the batch on its replica, attaches
//! simulated accelerator cycles, and records into a per-replica
//! [`Metrics`]; [`Pool::stats`] merges them into a [`PoolStats`].
//!
//! The dispatch hot path is allocation-light by construction: every
//! worker owns a [`Scratch`](crate::kan::Scratch) arena and one reusable
//! batch `Vec` ([`Batcher::drain_into`]), gathers request rows straight
//! into the scratch's staging buffer, runs the engine's planned
//! zero-allocation `forward_staged`, and scatters output rows into
//! response buffers that were pre-sized at submit time — so the
//! gather/forward/scatter core of dispatch does no per-request
//! allocation. (The response-channel send and latency-sample recording
//! still allocate per request; response-buffer pooling is listed as
//! future work in ROADMAP.md.)
//!
//! Conservation invariant (integration-tested, including shutdown races):
//! every submission the pool *counts* is answered exactly once —
//! `submitted == completed + shed + failed` over the [`PoolStats`]
//! counters.

use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::arch::ArrayConfig;
use crate::kan::{Engine, Scratch};

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;

/// What to do with a new submission when the admission queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Reject the new arrival with [`PoolError::QueueFull`].
    RejectNew,
    /// Answer the *oldest* queued request with `QueueFull` (it has burned
    /// the most deadline budget) and admit the new one.
    DropOldest,
    /// Block the submitting thread until a worker frees space.
    Block,
}

/// Pool sizing and policy.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Engine replicas == worker threads.
    pub replicas: usize,
    /// Admission queue capacity (requests, not batches).
    pub queue_cap: usize,
    pub shed: ShedPolicy,
    /// Per-worker dynamic batching policy.
    pub policy: BatchPolicy,
    /// Accelerator config used to attach simulated cycle counts to each
    /// served batch.
    pub sim_array: ArrayConfig,
}

/// Replica count matched to the host: one per core, clamped to [1, 8].
pub fn default_replicas() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 8)
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self {
            replicas: default_replicas(),
            queue_cap: 1024,
            shed: ShedPolicy::RejectNew,
            policy: BatchPolicy::default(),
            sim_array: ArrayConfig::kan_sas(16, 16, 4, 8),
        }
    }
}

/// Terminal outcomes a submission can observe besides logits.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PoolError {
    /// Shed by admission control (at submit, or evicted under
    /// [`ShedPolicy::DropOldest`]).
    QueueFull,
    /// The pool shut down before the request could be admitted.
    Closed,
    /// Input validation failed (wrong dimension).
    InvalidInput(String),
    /// The engine rejected the whole batch.
    Inference(String),
}

impl fmt::Display for PoolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PoolError::QueueFull => write!(f, "admission queue full (request shed)"),
            PoolError::Closed => write!(f, "pool stopped"),
            PoolError::InvalidInput(m) => write!(f, "{m}"),
            PoolError::Inference(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for PoolError {}

/// Response: i64 accumulators for the row (argmax = class) + timing.
#[derive(Clone, Debug)]
pub struct Response {
    pub t: Vec<i64>,
    pub latency_us: u64,
}

impl Response {
    pub fn prediction(&self) -> usize {
        crate::util::argmax(&self.t)
    }
}

/// One admitted request: quantized input row + response channel. The
/// output buffer is allocated (to exact capacity) by the *submitting*
/// thread, so the worker's scatter is a pure `extend_from_slice` — no
/// allocation on the serving hot path.
struct PoolRequest {
    x_q: Vec<u8>,
    /// Pre-sized (capacity `out_dim`) response buffer the worker fills.
    out: Vec<i64>,
    submitted: Instant,
    resp: Sender<Result<Response, PoolError>>,
}

struct QueueState {
    items: VecDeque<PoolRequest>,
    open: bool,
    /// Valid submissions counted by admission control (admitted or
    /// rejected-new; Block submissions that observe `Closed` are not
    /// counted — they produced no queue entry and no shed).
    submitted: u64,
    /// Requests answered `QueueFull`.
    shed: u64,
    peak_depth: usize,
}

struct Shared {
    state: Mutex<QueueState>,
    /// Signalled when a request is admitted (workers wait here).
    nonempty: Condvar,
    /// Signalled when a worker frees queue space (Block submitters wait).
    space: Condvar,
    cap: usize,
    shed_policy: ShedPolicy,
    /// Requests answered with logits (Ok), across all replicas.
    completed: AtomicU64,
    /// Requests answered with an inference error, across all replicas.
    failed: AtomicU64,
}

/// A pending response. Dropping it abandons the answer (the pool still
/// serves and counts the request).
pub struct Ticket {
    rx: Receiver<Result<Response, PoolError>>,
    pub submitted: Instant,
}

impl Ticket {
    /// Block until the request resolves. A worker failure that loses the
    /// channel maps to [`PoolError::Closed`], so this can never hang.
    pub fn wait(self) -> Result<Response, PoolError> {
        self.rx.recv().unwrap_or(Err(PoolError::Closed))
    }

    /// Non-blocking poll; `None` while still in flight. A lost worker
    /// (disconnected channel) is a terminal [`PoolError::Closed`], not
    /// `None` — pollers must never spin forever on a dead ticket.
    pub fn try_wait(&self) -> Option<Result<Response, PoolError>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => Some(Err(PoolError::Closed)),
        }
    }
}

/// Cloneable client handle.
#[derive(Clone)]
pub struct PoolHandle {
    shared: Arc<Shared>,
    in_dim: usize,
    out_dim: usize,
}

impl PoolHandle {
    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Requests currently waiting for a worker.
    pub fn queue_depth(&self) -> usize {
        self.shared.state.lock().unwrap().items.len()
    }

    /// Submit one quantized row; returns a [`Ticket`] without waiting for
    /// the result (the open-loop load generator's entry point). Admission
    /// control applies here: a full queue sheds per the pool's
    /// [`ShedPolicy`].
    pub fn submit_q(&self, x_q: Vec<u8>) -> Result<Ticket, PoolError> {
        if x_q.len() != self.in_dim {
            return Err(PoolError::InvalidInput(format!(
                "input dim {} != model {}",
                x_q.len(),
                self.in_dim
            )));
        }
        let submitted = Instant::now();
        let mut st = self.shared.state.lock().unwrap();
        if !st.open {
            return Err(PoolError::Closed);
        }
        while st.items.len() >= self.shared.cap {
            match self.shared.shed_policy {
                ShedPolicy::RejectNew => {
                    st.submitted += 1;
                    st.shed += 1;
                    return Err(PoolError::QueueFull);
                }
                ShedPolicy::DropOldest => {
                    if let Some(old) = st.items.pop_front() {
                        st.shed += 1;
                        let _ = old.resp.send(Err(PoolError::QueueFull));
                    }
                }
                ShedPolicy::Block => {
                    st = self.shared.space.wait(st).unwrap();
                    if !st.open {
                        return Err(PoolError::Closed);
                    }
                }
            }
        }
        // admitted: only now pay for the response channel and the
        // pre-sized output buffer, so shed requests (the overload path)
        // cost no heap allocations
        let (tx, rx) = channel();
        st.submitted += 1;
        st.items.push_back(PoolRequest {
            x_q,
            out: Vec::with_capacity(self.out_dim),
            submitted,
            resp: tx,
        });
        st.peak_depth = st.peak_depth.max(st.items.len());
        drop(st);
        self.shared.nonempty.notify_one();
        Ok(Ticket { rx, submitted })
    }

    /// Submit one quantized row and block for its logits.
    pub fn infer_q(&self, x_q: Vec<u8>) -> Result<Response, PoolError> {
        self.submit_q(x_q)?.wait()
    }

    /// Submit a float (spline-domain) row and block for its logits.
    pub fn infer(&self, x: &[f32]) -> Result<Response, PoolError> {
        self.infer_q(crate::quant::quantize_activations(x))
    }
}

/// Pool-level statistics: merged replica metrics + admission counters.
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    /// All replicas' metrics merged.
    pub merged: Metrics,
    /// Per-replica metrics (rows served, batches, latency samples,
    /// simulated cycles/utilization) — the load-balance view.
    pub per_replica: Vec<Metrics>,
    pub submitted: u64,
    pub shed: u64,
    pub completed: u64,
    /// Requests answered with an inference error. Conservation:
    /// `submitted == completed + shed + failed` once drained.
    pub failed: u64,
    /// High-water mark of the admission queue.
    pub peak_depth: usize,
    /// Queue depth at snapshot time.
    pub queue_depth: usize,
    pub replicas: usize,
}

impl PoolStats {
    /// Fraction of counted submissions shed by admission control.
    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            return 0.0;
        }
        self.shed as f64 / self.submitted as f64
    }
}

/// A running replica pool; [`Pool::shutdown`] drains and joins.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    per_worker: Vec<Arc<Mutex<Metrics>>>,
    handle: PoolHandle,
}

impl Pool {
    pub fn start(engine: Engine, cfg: PoolConfig) -> Self {
        assert!(cfg.replicas >= 1, "pool needs at least one replica");
        assert!(cfg.queue_cap >= 1, "admission queue needs capacity");
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                open: true,
                submitted: 0,
                shed: 0,
                peak_depth: 0,
            }),
            nonempty: Condvar::new(),
            space: Condvar::new(),
            cap: cfg.queue_cap,
            shed_policy: cfg.shed,
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
        });
        let in_dim = engine.model.in_dim();
        let out_dim = engine.model.out_dim();
        let mut workers = Vec::with_capacity(cfg.replicas);
        let mut per_worker = Vec::with_capacity(cfg.replicas);
        for i in 0..cfg.replicas {
            let metrics = Arc::new(Mutex::new(Metrics::default()));
            per_worker.push(Arc::clone(&metrics));
            let engine = engine.clone(); // aliases weights, ~1x memory
            let shared_w = Arc::clone(&shared);
            let policy = cfg.policy;
            let sim_array = cfg.sim_array;
            let w = std::thread::Builder::new()
                .name(format!("kansas-pool-{i}"))
                .spawn(move || worker_loop(engine, policy, sim_array, shared_w, metrics))
                .expect("spawn pool worker");
            workers.push(w);
        }
        let handle = PoolHandle { shared: Arc::clone(&shared), in_dim, out_dim };
        Self { shared, workers, per_worker, handle }
    }

    pub fn handle(&self) -> PoolHandle {
        self.handle.clone()
    }

    /// Live snapshot (the pool keeps serving).
    pub fn stats(&self) -> PoolStats {
        self.snapshot()
    }

    /// Stop admitting, serve everything already queued, join all workers,
    /// and return the final stats.
    pub fn shutdown(mut self) -> PoolStats {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.open = false;
        }
        self.shared.nonempty.notify_all();
        self.shared.space.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.snapshot()
    }

    fn snapshot(&self) -> PoolStats {
        let mut merged = Metrics::default();
        let mut per_replica = Vec::with_capacity(self.per_worker.len());
        for m in &self.per_worker {
            let mm = m.lock().unwrap().clone();
            merged.merge(&mm);
            per_replica.push(mm);
        }
        let st = self.shared.state.lock().unwrap();
        PoolStats {
            merged,
            replicas: self.per_worker.len(),
            submitted: st.submitted,
            shed: st.shed,
            completed: self.shared.completed.load(Ordering::Relaxed),
            failed: self.shared.failed.load(Ordering::Relaxed),
            peak_depth: st.peak_depth,
            queue_depth: st.items.len(),
            per_replica,
        }
    }
}

fn worker_loop(
    engine: Engine,
    policy: BatchPolicy,
    sim_array: ArrayConfig,
    shared: Arc<Shared>,
    metrics: Arc<Mutex<Metrics>>,
) {
    let mut batcher: Batcher<PoolRequest> = Batcher::new(policy);
    // Worker-owned execution state, allocated once per replica: the
    // engine's scratch arena (zero-allocation steady-state forwards) and
    // the batch Vec every drain reuses.
    let mut scratch = Scratch::for_plan(engine.plan(), policy.max_batch);
    let mut batch: Vec<PoolRequest> = Vec::with_capacity(policy.max_batch);
    loop {
        // Phase 1: block until at least one request is admitted (or the
        // pool is closed and drained — the only exit).
        {
            let mut st = shared.state.lock().unwrap();
            loop {
                let admitted = pull_into(&mut st, &mut batcher, policy.max_batch);
                if !batcher.is_empty() {
                    drop(st);
                    if admitted {
                        shared.space.notify_all();
                    }
                    break;
                }
                if !st.open {
                    return;
                }
                st = shared.nonempty.wait(st).unwrap();
            }
        }
        // Phase 2: wait out the batching window for stragglers. Deadlines
        // are anchored at admission time (push_arrived), so a request's
        // shared-queue wait counts against max_wait.
        while !batcher.ready() {
            let mut st = shared.state.lock().unwrap();
            if !st.open {
                break; // flush immediately on shutdown
            }
            if st.items.is_empty() {
                let wait = batcher.time_left();
                if wait.is_zero() {
                    break;
                }
                let (guard, _) = shared.nonempty.wait_timeout(st, wait).unwrap();
                st = guard;
            }
            let admitted = pull_into(&mut st, &mut batcher, policy.max_batch);
            drop(st);
            if admitted {
                shared.space.notify_all();
            }
        }
        batcher.drain_into(&mut batch);
        serve_batch(&engine, &sim_array, &mut batch, &mut scratch, &shared, &metrics);
    }
}

/// Move queued requests into the worker's batcher, up to `max_batch`.
fn pull_into(
    st: &mut QueueState,
    batcher: &mut Batcher<PoolRequest>,
    max_batch: usize,
) -> bool {
    let mut admitted = false;
    while batcher.len() < max_batch {
        match st.items.pop_front() {
            Some(r) => {
                batcher.push_arrived(r.submitted, r);
                admitted = true;
            }
            None => break,
        }
    }
    admitted
}

/// Serve one drained batch on this worker's replica. Inputs are gathered
/// straight into the scratch's staging buffer and outputs scattered as
/// slices into each request's pre-sized response buffer — the
/// gather/forward/scatter core allocates nothing per request (the mpsc
/// response send and latency recording still do).
fn serve_batch(
    engine: &Engine,
    sim_array: &ArrayConfig,
    batch: &mut Vec<PoolRequest>,
    scratch: &mut Scratch,
    shared: &Shared,
    metrics: &Mutex<Metrics>,
) {
    let bs = batch.len();
    let in_dim = engine.model.in_dim();
    let out_dim = engine.model.out_dim();
    {
        let staging = scratch.stage_input(bs * in_dim);
        for r in batch.iter() {
            staging.extend_from_slice(&r.x_q);
        }
    }
    let result = engine.forward_staged(bs, scratch);
    let sim = engine.simulate_batch(sim_array, bs);
    let mut m = metrics.lock().unwrap();
    m.record_batch_sim(bs, &sim);
    match result {
        Ok(t) => {
            for (i, mut req) in batch.drain(..).enumerate() {
                let latency = req.submitted.elapsed();
                m.record_request(latency);
                shared.completed.fetch_add(1, Ordering::Relaxed);
                req.out.extend_from_slice(&t[i * out_dim..(i + 1) * out_dim]);
                let _ = req.resp.send(Ok(Response {
                    t: req.out,
                    latency_us: latency.as_micros() as u64,
                }));
            }
        }
        Err(e) => {
            let msg = format!("inference failed: {e}");
            for req in batch.drain(..) {
                shared.failed.fetch_add(1, Ordering::Relaxed);
                let _ = req.resp.send(Err(PoolError::Inference(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kan::QuantizedModel;
    use std::time::Duration;

    fn tiny_pool(replicas: usize, queue_cap: usize, shed: ShedPolicy) -> Pool {
        let engine = Engine::new(QuantizedModel::synthetic("pool", &[4, 6, 3], 5, 3, 5));
        Pool::start(
            engine,
            PoolConfig {
                replicas,
                queue_cap,
                shed,
                policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
                sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
            },
        )
    }

    #[test]
    fn serves_and_counts() {
        let pool = tiny_pool(2, 64, ShedPolicy::RejectNew);
        let h = pool.handle();
        for _ in 0..20 {
            let r = h.infer_q(vec![1, 2, 3, 4]).unwrap();
            assert_eq!(r.t.len(), 3);
            let _ = r.prediction();
        }
        let stats = pool.shutdown();
        assert_eq!(stats.submitted, 20);
        assert_eq!(stats.completed, 20);
        assert_eq!(stats.shed, 0);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.submitted, stats.completed + stats.shed + stats.failed);
        assert_eq!(stats.merged.batch_rows, 20);
        assert_eq!(stats.replicas, 2);
        assert_eq!(stats.per_replica.len(), 2);
        let per_sum: u64 = stats.per_replica.iter().map(|m| m.batch_rows).sum();
        assert_eq!(per_sum, 20);
        assert!(stats.merged.sim_cycles > 0);
        assert!(stats.merged.sim_utilization() > 0.0);
    }

    #[test]
    fn wrong_dim_rejected_before_admission() {
        let pool = tiny_pool(1, 8, ShedPolicy::RejectNew);
        let err = pool.handle().infer_q(vec![1, 2]).unwrap_err();
        assert!(matches!(err, PoolError::InvalidInput(_)));
        let stats = pool.shutdown();
        assert_eq!(stats.submitted, 0);
    }

    #[test]
    fn closed_pool_rejects_submissions() {
        let pool = tiny_pool(1, 8, ShedPolicy::RejectNew);
        let h = pool.handle();
        let stats = pool.shutdown();
        assert_eq!(stats.submitted, 0);
        assert_eq!(h.infer_q(vec![1, 2, 3, 4]).unwrap_err(), PoolError::Closed);
    }

    /// A handle over a worker-less queue: admission control in isolation,
    /// fully deterministic (no racing consumers).
    fn bare_handle(cap: usize, shed: ShedPolicy) -> PoolHandle {
        PoolHandle {
            shared: Arc::new(Shared {
                state: Mutex::new(QueueState {
                    items: VecDeque::new(),
                    open: true,
                    submitted: 0,
                    shed: 0,
                    peak_depth: 0,
                }),
                nonempty: Condvar::new(),
                space: Condvar::new(),
                cap,
                shed_policy: shed,
                completed: AtomicU64::new(0),
                failed: AtomicU64::new(0),
            }),
            in_dim: 4,
            out_dim: 3,
        }
    }

    #[test]
    fn reject_new_sheds_at_capacity() {
        let h = bare_handle(2, ShedPolicy::RejectNew);
        let _t1 = h.submit_q(vec![1, 1, 1, 1]).unwrap();
        let _t2 = h.submit_q(vec![2, 2, 2, 2]).unwrap();
        assert_eq!(h.queue_depth(), 2);
        assert_eq!(h.submit_q(vec![3, 3, 3, 3]).unwrap_err(), PoolError::QueueFull);
        assert_eq!(h.queue_depth(), 2, "rejected arrival never enters the queue");
        let st = h.shared.state.lock().unwrap();
        assert_eq!(st.submitted, 3);
        assert_eq!(st.shed, 1);
        assert_eq!(st.peak_depth, 2);
    }

    #[test]
    fn drop_oldest_evicts_stalest_and_admits() {
        let h = bare_handle(2, ShedPolicy::DropOldest);
        let t1 = h.submit_q(vec![1, 1, 1, 1]).unwrap();
        let t2 = h.submit_q(vec![2, 2, 2, 2]).unwrap();
        // queue full: #3 evicts #1, #4 evicts #2 — the newcomer always wins
        let t3 = h.submit_q(vec![3, 3, 3, 3]).unwrap();
        assert_eq!(t1.wait(), Err(PoolError::QueueFull), "oldest answered on eviction");
        let t4 = h.submit_q(vec![4, 4, 4, 4]).unwrap();
        assert_eq!(t2.wait(), Err(PoolError::QueueFull));
        assert_eq!(h.queue_depth(), 2);
        assert!(t3.try_wait().is_none(), "survivors stay in flight");
        assert!(t4.try_wait().is_none());
        let st = h.shared.state.lock().unwrap();
        assert_eq!(st.submitted, 4);
        assert_eq!(st.shed, 2);
    }
}
