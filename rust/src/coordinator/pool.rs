//! `Pool` — the 1-model special case of the multi-tenant
//! [`Gateway`](super::gateway::Gateway).
//!
//! Everything the pool used to own — the bounded admission queue with
//! [`ShedPolicy`] shedding, the replica fleet of `Arc`-aliased engines,
//! per-worker batchers, per-replica metrics, the zero-allocation
//! gather/forward/scatter dispatch core, pooled response buffers — now
//! lives in [`super::gateway`], tested once and shared by every tenant
//! count. `Pool::start` registers a single model on a gateway and
//! re-presents the gateway's stats through the familiar flat
//! [`PoolStats`].
//!
//! The legacy names survive as aliases so single-model callers read
//! naturally: [`PoolHandle`] *is* a [`ModelHandle`] and [`PoolError`]
//! *is* the unified [`ServeError`].
//!
//! Conservation invariant (integration-tested, including shutdown
//! races): every submission the pool *counts* is answered exactly once —
//! `submitted == completed + shed + failed` over the [`PoolStats`]
//! counters.

use std::sync::Arc;

use crate::kan::Engine;

use super::gateway::{Gateway, GatewayBuilder, GatewayStats, ModelHandle, ServeError};
use super::metrics::Metrics;
use super::telemetry::Telemetry;

pub use super::gateway::{Dispatch, GatewayConfig as PoolConfig, Response, ShedPolicy, Ticket};

/// The unified serving error. Kept under its historical name for
/// single-model callers; both spellings are the same type.
pub type PoolError = ServeError;

/// Cloneable client handle — the gateway's typed [`ModelHandle`], bound
/// to the pool's single model.
pub type PoolHandle = ModelHandle;

/// Replica count matched to the host: one per core, clamped to
/// `[1, max]` where `max` comes from the `KANSAS_MAX_REPLICAS`
/// environment variable (default 8; big hosts raise it, CI pins it —
/// the `kansas serve --max-replicas` flag overrides both).
pub fn default_replicas() -> usize {
    let max = std::env::var("KANSAS_MAX_REPLICAS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&m| m >= 1)
        .unwrap_or(8);
    default_replicas_capped(max)
}

/// One replica per core, clamped to `[1, cap]` — the explicit-cap form
/// behind [`default_replicas`].
pub fn default_replicas_capped(cap: usize) -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, cap.max(1))
}

/// Pool-level statistics: merged replica metrics + admission counters
/// (the single-model flattening of [`GatewayStats`]).
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    /// All replicas' metrics merged.
    pub merged: Metrics,
    /// Per-replica metrics (rows served, batches, latency samples,
    /// simulated cycles/utilization) — the load-balance view.
    pub per_replica: Vec<Metrics>,
    /// Valid submissions counted by admission control.
    pub submitted: u64,
    /// Requests answered without inference (`QueueFull` or deadline
    /// expiry).
    pub shed: u64,
    /// Requests answered with logits.
    pub completed: u64,
    /// Requests answered with an inference error. Conservation:
    /// `submitted == completed + shed + failed` once drained.
    pub failed: u64,
    /// High-water mark of the admission queue.
    pub peak_depth: usize,
    /// Queue depth at snapshot time.
    pub queue_depth: usize,
    /// Worker fleet size.
    pub replicas: usize,
}

impl PoolStats {
    /// Fraction of counted submissions shed by admission control.
    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            return 0.0;
        }
        self.shed as f64 / self.submitted as f64
    }

    fn from_gateway(mut stats: GatewayStats) -> Self {
        let m = stats.per_model.remove(0);
        Self {
            merged: stats.merged,
            per_replica: stats.per_replica,
            submitted: m.submitted,
            shed: m.shed,
            completed: m.completed,
            failed: m.failed,
            peak_depth: stats.peak_depth,
            queue_depth: stats.queue_depth,
            replicas: stats.replicas,
        }
    }
}

/// A running single-model replica pool; [`Pool::shutdown`] drains and
/// joins. Internally a one-tenant [`Gateway`].
///
/// # Examples
///
/// ```
/// use kan_sas::coordinator::{Pool, PoolConfig};
/// use kan_sas::kan::{Engine, QuantizedModel};
///
/// let engine = Engine::new(QuantizedModel::synthetic("demo", &[4, 6, 3], 5, 3, 11));
/// let pool = Pool::start(engine, PoolConfig { replicas: 1, ..Default::default() });
/// let handle = pool.handle();
///
/// let response = handle.infer(&[0.25, -0.5, 0.75, 0.1])?;
/// let _class = response.prediction();
///
/// let stats = pool.shutdown();
/// assert_eq!(stats.submitted, stats.completed + stats.shed + stats.failed);
/// # Ok::<(), kan_sas::coordinator::PoolError>(())
/// ```
pub struct Pool {
    gateway: Gateway,
    handle: PoolHandle,
}

impl Pool {
    /// Spawn a replica fleet serving `engine` (registered on an internal
    /// one-tenant gateway under the model's own name).
    pub fn start(engine: Engine, cfg: PoolConfig) -> Self {
        let name = engine.model.name.clone();
        let mut builder = GatewayBuilder::with_config(cfg);
        let id = builder.register(&name, engine);
        let gateway = builder.start();
        let handle = gateway.handle(id);
        Self { gateway, handle }
    }

    /// A cloneable client handle for the pool's single model.
    pub fn handle(&self) -> PoolHandle {
        self.handle.clone()
    }

    /// Live snapshot (the pool keeps serving).
    pub fn stats(&self) -> PoolStats {
        PoolStats::from_gateway(self.gateway.stats())
    }

    /// The pool's telemetry spine (shared with the underlying gateway;
    /// stays valid for snapshots after [`Pool::shutdown`]).
    pub fn telemetry(&self) -> Arc<Telemetry> {
        self.gateway.telemetry()
    }

    /// Stop admitting, serve everything already queued, join all
    /// workers, and return the final stats.
    pub fn shutdown(self) -> PoolStats {
        PoolStats::from_gateway(self.gateway.shutdown())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArrayConfig;
    use crate::coordinator::BatchPolicy;
    use crate::kan::QuantizedModel;
    use std::time::Duration;

    fn tiny_pool(replicas: usize, queue_cap: usize, shed: ShedPolicy) -> Pool {
        let engine = Engine::new(QuantizedModel::synthetic("pool", &[4, 6, 3], 5, 3, 5));
        Pool::start(
            engine,
            PoolConfig {
                replicas,
                queue_cap,
                shed,
                policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
                sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
                dispatch: crate::coordinator::Dispatch::FairSteal,
                quota: crate::coordinator::QuotaPolicy::None,
                telemetry: crate::coordinator::TelemetryConfig::default(),
                ..Default::default()
            },
        )
    }

    #[test]
    fn serves_and_counts() {
        let pool = tiny_pool(2, 64, ShedPolicy::RejectNew);
        let h = pool.handle();
        for _ in 0..20 {
            let r = h.infer_q(vec![1, 2, 3, 4]).unwrap();
            assert_eq!(r.t.len(), 3);
            let _ = r.prediction();
        }
        let stats = pool.shutdown();
        assert_eq!(stats.submitted, 20);
        assert_eq!(stats.completed, 20);
        assert_eq!(stats.shed, 0);
        assert_eq!(stats.failed, 0);
        assert_eq!(stats.submitted, stats.completed + stats.shed + stats.failed);
        assert_eq!(stats.merged.batch_rows, 20);
        assert_eq!(stats.replicas, 2);
        assert_eq!(stats.per_replica.len(), 2);
        let per_sum: u64 = stats.per_replica.iter().map(|m| m.batch_rows).sum();
        assert_eq!(per_sum, 20);
        assert!(stats.merged.sim_cycles > 0);
        assert!(stats.merged.sim_utilization() > 0.0);
    }

    #[test]
    fn wrong_dim_rejected_before_admission() {
        let pool = tiny_pool(1, 8, ShedPolicy::RejectNew);
        let err = pool.handle().infer_q(vec![1, 2]).unwrap_err();
        assert!(matches!(err, PoolError::InvalidInput(_)));
        let stats = pool.shutdown();
        assert_eq!(stats.submitted, 0);
    }

    #[test]
    fn closed_pool_rejects_submissions() {
        let pool = tiny_pool(1, 8, ShedPolicy::RejectNew);
        let h = pool.handle();
        let stats = pool.shutdown();
        assert_eq!(stats.submitted, 0);
        assert_eq!(h.infer_q(vec![1, 2, 3, 4]).unwrap_err(), PoolError::Closed);
    }

    #[test]
    fn default_replicas_within_env_cap() {
        // can't mutate the environment safely under the parallel test
        // harness; assert the invariant against whatever cap is active
        let cap = std::env::var("KANSAS_MAX_REPLICAS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&m| m >= 1)
            .unwrap_or(8);
        let r = default_replicas();
        assert!(r >= 1 && r <= cap.max(1), "default_replicas {r} violates cap {cap}");
    }
}
