//! Serving coordinator: the L3 request path — multi-tenant, weighted,
//! work-stealing, and **live-reconfigurable**.
//!
//! The front door is the [`gateway`]: one [`Gateway`] serves **many
//! registered models over one replica fleet**, mirroring the paper's
//! Fig. 8, where a single KAN-SAs array time-shares a mix of
//! applications (MNIST, CIFAR, HAR, …). A thread-per-worker design over
//! std sync primitives (tokio is not available offline, and the
//! workload — CPU-bound batched inference — doesn't want an async
//! reactor anyway):
//!
//! * the tenant set lives in an epoch-versioned **registry snapshot**
//!   (`Arc`-swapped atomically): models are registered on a
//!   [`GatewayBuilder`] with a **service weight** and optionally their
//!   own [`BatchPolicy`] ([`GatewayBuilder::register`],
//!   [`GatewayBuilder::register_weighted`],
//!   [`GatewayBuilder::register_with_policy`]), and the *running*
//!   gateway can hot-add ([`Gateway::add_model`]), re-weight
//!   ([`Gateway::set_weight`]), and remove ([`Gateway::remove_model`])
//!   tenants under live traffic — removal drains the tenant's backlog
//!   per [`DrainMode`] (serve or shed) and retires its [`BufferPool`]
//!   only after the last in-flight response returns, with per-model
//!   conservation holding across the transition. Workers adopt a new
//!   epoch at their next batch boundary, so the hot path pays one
//!   integer compare per loop;
//! * clients hold a typed [`ModelHandle`] and submit a [`Request`]
//!   (quantized or f32 row, optional deadline, [`Priority`] class),
//!   receiving their logits through a [`Ticket`] or the blocking
//!   `infer` conveniences;
//! * admission is **one bounded queue shared by every model**, with
//!   overload explicit: a full queue sheds per [`ShedPolicy`]
//!   (`QueueFull` rejection, priority-ordered oldest-eviction, or
//!   blocking backpressure), and lapsed deadlines resolve
//!   [`ServeError::DeadlineExceeded`] — every terminal outcome is one
//!   [`ServeError`]. Under [`QuotaPolicy::Weighted`] each tenant gets
//!   **weight-proportional reserved queue slots** plus a shared
//!   overflow region, so one tenant's burst can no longer shed every
//!   tenant's new arrivals, and `DropOldest` evicts from the most
//!   oversubscribed tenant first;
//! * the worker fleet is shared too: each worker serves every
//!   registered model through the registry's `Arc`-shared engines (~1x
//!   total model memory), one [`Scratch`](crate::kan::Scratch) arena
//!   sized to the widest model, and a fleet-visible **shard of
//!   per-model dynamic [`batcher`]s** — batches are never mixed-model,
//!   each tenant's batcher runs that tenant's policy, and deadlines
//!   anchor at admission time so queue wait counts against the batching
//!   window;
//! * dispatch is **weighted-fair with work stealing**
//!   ([`Dispatch::FairSteal`], the default): workers pick the next batch
//!   by deficit-round-robin over their shard (tenants earn credit by
//!   weight, pay in rows served, so a starved high-weight tenant
//!   overtakes a saturated low-weight one), queue pulls skip past
//!   head-of-line requests whose batcher is full, and an idle worker
//!   steals from the most-backlogged peer's shard instead of sleeping —
//!   *splitting* an over-full backlog roughly in half so owner and
//!   thief serve it concurrently ([`Dispatch::Fixed`] keeps the
//!   pre-fair baseline for comparison);
//! * response buffers are pooled per model ([`BufferPool`]): dropping a
//!   [`Response`] recycles its pre-sized output `Vec`, so steady-state
//!   submission pays no buffer allocation;
//! * accounting is per model *and* per replica: [`GatewayStats`] holds a
//!   [`ModelStats`] row per tenant — including removed ones
//!   (`live == false`; slots are never reused) — with conservation per
//!   model (`submitted == completed + shed + failed`, steal-proof and
//!   churn-proof), merged [`Metrics`] per worker, request latency split
//!   into queueing vs service time, per-model steal counts, the
//!   registry epoch, and two fairness lenses: the raw Jain index over
//!   weight-normalized service ([`GatewayStats::fairness_index`]) and
//!   the demand-normalized one
//!   ([`GatewayStats::fairness_index_normalized`]) that isolates
//!   scheduler fairness from the arrival mix;
//! * the whole request path is observable while it runs through the
//!   [`telemetry`] spine: per-worker lock-free SPSC event rings (two
//!   atomic ops per hot-path event, drop-and-count on overflow, never
//!   blocking a worker) drained by a collector thread into per-tenant
//!   **windowed** stats — log-bucketed latency histograms, queue depth,
//!   throughput, shed/steal rates, and the paper-faithful
//!   `sim_utilization` gauge — plus a bounded **flight recorder** (last
//!   N lifecycle events per tenant and every registry churn event) and
//!   sampled full-request **span traces**
//!   (admission→batch→serve→respond timelines);
//! * the fleet is **elastic** under an SLO: [`autoscale`] evaluates the
//!   telemetry spine's windowed signals (worst-tenant p95 queueing
//!   delay, shed rate) against a target and scales workers between
//!   configured bounds — doubling fast on breach, draining one at a
//!   time after K consecutive calm windows (the `remove_model` drain
//!   contract generalized to replicas, so no request is dropped by a
//!   scaling action). Every time-dependent decision (batcher windows,
//!   telemetry ticks, autoscale evaluation) reads an injectable
//!   [`Clock`] — production runs the monotonic wall clock, tests drive
//!   a manually-advanced one through [`GatewayConfig`] and step
//!   virtual time deterministically;
//! * [`pool`] keeps `Pool` as the 1-model special case (`PoolHandle` =
//!   [`ModelHandle`], `PoolError` = [`ServeError`]) and [`server`] keeps
//!   `Server` as the 1-model, 1-replica special case;
//! * [`net`] is the **network front door**: a [`NetServer`] speaks a
//!   length-prefixed framed binary protocol over TCP
//!   (`kansas serve --listen`), decoding quantized request rows
//!   straight into pooled gateway admission buffers
//!   ([`ModelHandle::acquire_row`]) and answering with logits or typed
//!   [`ServeError`] frames; a pipelined [`NetClient`] multiplexes
//!   logical requests over one connection by correlation id
//!   (`kansas load --connect`), and a `StatsRequest` frame serves
//!   [`Telemetry::snapshot`] JSON to remote pollers.

#![warn(missing_docs)]

pub mod autoscale;
pub mod batcher;
pub mod clock;
pub mod gateway;
pub mod metrics;
pub mod net;
pub mod pool;
pub mod server;
pub mod telemetry;

pub use autoscale::{AutoscaleConfig, Controller, FleetSignals, ScaleDecision, ScaleEvent};
pub use batcher::{BatchPolicy, Batcher};
pub use clock::Clock;
pub use gateway::{
    BufferPool, Dispatch, DrainMode, Gateway, GatewayBuilder, GatewayConfig, GatewayStats,
    ModelHandle, ModelId, ModelStats, Priority, QuotaPolicy, Request, Response, RowPool,
    ServeError, ShedPolicy, TenantDefaults, Ticket,
};
pub use net::{
    NetClient, NetConfig, NetServer, NetStats, RemoteHandle, RemoteModel, RemoteResponse,
    RemoteTicket,
};
pub use metrics::{jain_fairness, jain_fairness_normalized, LatencyStats, LogHistogram, Metrics};
pub use telemetry::{
    ChurnKind, ChurnRecord, Event, EventKind, EventRing, FlightDump, Span, Telemetry,
    TelemetryConfig, TelemetrySnapshot, TenantSnapshot, TenantTotals, WindowStats, NO_TENANT,
};
pub use pool::{
    default_replicas, default_replicas_capped, Pool, PoolConfig, PoolError, PoolHandle, PoolStats,
};
pub use server::{Handle, Server, ServerConfig};
