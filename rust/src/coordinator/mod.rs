//! Serving coordinator: the L3 request path.
//!
//! A thread-per-worker design over std sync primitives (tokio is not
//! available offline, and the workload — CPU-bound batched inference —
//! doesn't want an async reactor anyway):
//!
//! * clients submit requests to a **bounded admission queue** shared by
//!   the whole pool, and receive their logits on a per-request
//!   oneshot-style channel (blocking [`PoolHandle::infer`] or open-loop
//!   [`PoolHandle::submit_q`] + [`Ticket`]);
//! * overload is explicit: a full queue sheds per [`ShedPolicy`]
//!   (`QueueFull` rejection, oldest-eviction, or blocking backpressure);
//! * [`pool`] runs N worker threads, each owning an `Engine` replica
//!   (weights `Arc`-shared: N replicas ≈ 1x model memory) and its own
//!   dynamic [`batcher`] (the classic tradeoff: larger batches amortize
//!   fill/drain, older requests must not starve — deadlines anchored at
//!   admission time);
//! * workers attach simulated accelerator stats to every batch; per-
//!   replica [`metrics`] merge into [`PoolStats`] (latency percentiles,
//!   throughput, shed counts, queue high-water mark, per-replica
//!   simulated utilization);
//! * [`server`] keeps the original single-replica `Server` API as the
//!   1-replica special case of the pool.

pub mod batcher;
pub mod metrics;
pub mod pool;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::{LatencyStats, Metrics};
pub use pool::{
    default_replicas, Pool, PoolConfig, PoolError, PoolHandle, PoolStats, Response, ShedPolicy,
    Ticket,
};
pub use server::{Handle, Server, ServerConfig};
