//! Serving coordinator: the L3 request path — multi-tenant, weighted,
//! and work-stealing.
//!
//! The front door is the [`gateway`]: one [`Gateway`] serves **many
//! registered models over one replica fleet**, mirroring the paper's
//! Fig. 8, where a single KAN-SAs array time-shares a mix of
//! applications (MNIST, CIFAR, HAR, …). A thread-per-worker design over
//! std sync primitives (tokio is not available offline, and the
//! workload — CPU-bound batched inference — doesn't want an async
//! reactor anyway):
//!
//! * models are registered on a [`GatewayBuilder`] with a **service
//!   weight** ([`GatewayBuilder::register`] = weight 1,
//!   [`GatewayBuilder::register_weighted`] for an explicit share);
//!   clients hold a typed [`ModelHandle`] and submit a [`Request`]
//!   (quantized or f32 row, optional deadline, [`Priority`] class),
//!   receiving their logits through a [`Ticket`] or the blocking
//!   `infer` conveniences;
//! * admission is **one bounded queue shared by every model**, with
//!   overload explicit: a full queue sheds per [`ShedPolicy`]
//!   (`QueueFull` rejection, priority-ordered oldest-eviction, or
//!   blocking backpressure), and lapsed deadlines resolve
//!   [`ServeError::DeadlineExceeded`] — every terminal outcome is one
//!   [`ServeError`];
//! * the worker fleet is shared too: each worker owns an `Arc`-aliased
//!   replica of *every* registered model (~1x total model memory), one
//!   [`Scratch`](crate::kan::Scratch) arena sized to the widest model,
//!   and a fleet-visible **shard of per-model dynamic [`batcher`]s** —
//!   batches are never mixed-model, and deadlines anchor at admission
//!   time so queue wait counts against the batching window;
//! * dispatch is **weighted-fair with work stealing**
//!   ([`Dispatch::FairSteal`], the default): workers pick the next batch
//!   by deficit-round-robin over their shard (tenants earn credit by
//!   weight, pay in rows served, so a starved high-weight tenant
//!   overtakes a saturated low-weight one), queue pulls skip past
//!   head-of-line requests whose batcher is full, and an idle worker
//!   steals a due batch from the most-backlogged peer's shard instead
//!   of sleeping ([`Dispatch::Fixed`] keeps the pre-fair baseline for
//!   comparison);
//! * response buffers are pooled per model ([`BufferPool`]): dropping a
//!   [`Response`] recycles its pre-sized output `Vec`, so steady-state
//!   submission pays no buffer allocation;
//! * accounting is per model *and* per replica: [`GatewayStats`] holds a
//!   [`ModelStats`] row per tenant (conservation per model:
//!   `submitted == completed + shed + failed`, steal-proof — the
//!   invariant never cares which worker served a batch) and merged
//!   [`Metrics`] per worker, with request latency split into queueing vs
//!   service time (`Response::queue_us` / `Response::service_us`),
//!   per-model steal counts ([`Metrics::stolen_batches`]), and a Jain
//!   fairness index over weight-normalized service
//!   ([`GatewayStats::fairness_index`]);
//! * [`pool`] keeps `Pool` as the 1-model special case (`PoolHandle` =
//!   [`ModelHandle`], `PoolError` = [`ServeError`]) and [`server`] keeps
//!   `Server` as the 1-model, 1-replica special case.

#![warn(missing_docs)]

pub mod batcher;
pub mod gateway;
pub mod metrics;
pub mod pool;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use gateway::{
    BufferPool, Dispatch, Gateway, GatewayBuilder, GatewayConfig, GatewayStats, ModelHandle,
    ModelId, ModelStats, Priority, Request, Response, ServeError, ShedPolicy, Ticket,
};
pub use metrics::{jain_fairness, LatencyStats, Metrics};
pub use pool::{
    default_replicas, default_replicas_capped, Pool, PoolConfig, PoolError, PoolHandle, PoolStats,
};
pub use server::{Handle, Server, ServerConfig};
