//! Serving coordinator: the L3 request path.
//!
//! A thread-per-worker design over std mpsc channels (tokio is not
//! available offline, and the workload — CPU-bound batched inference —
//! doesn't want an async reactor anyway):
//!
//! * clients submit [`Request`]s to a bounded queue and receive their
//!   logits on a per-request oneshot-style channel;
//! * the [`batcher`] collects requests into batches under a size/deadline
//!   policy (the classic dynamic-batching tradeoff: larger batches
//!   amortize fill/drain, older requests must not starve);
//! * worker threads run the integer engine (and optionally the PJRT fp32
//!   engine) per batch and attach simulated accelerator stats;
//! * [`metrics`] aggregates latency percentiles and throughput.

pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::{LatencyStats, Metrics};
pub use server::{Server, ServerConfig};
