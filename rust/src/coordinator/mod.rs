//! Serving coordinator: the L3 request path, now multi-tenant.
//!
//! The front door is the [`gateway`]: one [`Gateway`] serves **many
//! registered models over one replica fleet**, mirroring the paper's
//! Fig. 8, where a single KAN-SAs array time-shares a mix of
//! applications (MNIST, CIFAR, HAR, …). A thread-per-worker design over
//! std sync primitives (tokio is not available offline, and the
//! workload — CPU-bound batched inference — doesn't want an async
//! reactor anyway):
//!
//! * models are registered on a [`GatewayBuilder`]
//!   ([`GatewayBuilder::register`] → [`ModelId`]); clients hold a typed
//!   [`ModelHandle`] and submit a [`Request`] (quantized or f32 row,
//!   optional deadline, [`Priority`] class), receiving their logits
//!   through a [`Ticket`] or the blocking `infer` conveniences;
//! * admission is **one bounded queue shared by every model**, with
//!   overload explicit: a full queue sheds per [`ShedPolicy`]
//!   (`QueueFull` rejection, priority-ordered oldest-eviction, or
//!   blocking backpressure), and lapsed deadlines resolve
//!   [`ServeError::DeadlineExceeded`] — every terminal outcome is one
//!   [`ServeError`];
//! * the worker fleet is shared too: each worker owns an `Arc`-aliased
//!   replica of *every* registered model (~1x total model memory), one
//!   [`Scratch`](crate::kan::Scratch) arena sized to the widest model,
//!   and **per-model dynamic [`batcher`]s** — batches are never
//!   mixed-model, and deadlines anchor at admission time so queue wait
//!   counts against the batching window;
//! * response buffers are pooled per model ([`BufferPool`]): dropping a
//!   [`Response`] recycles its pre-sized output `Vec`, so steady-state
//!   submission pays no buffer allocation;
//! * accounting is per model *and* per replica: [`GatewayStats`] holds a
//!   [`ModelStats`] row per tenant (conservation per model:
//!   `submitted == completed + shed + failed`) and merged [`Metrics`]
//!   per worker, with request latency split into queueing vs service
//!   time (`Response::queue_us` / `Response::service_us`);
//! * [`pool`] keeps `Pool` as the 1-model special case (`PoolHandle` =
//!   [`ModelHandle`], `PoolError` = [`ServeError`]) and [`server`] keeps
//!   `Server` as the 1-model, 1-replica special case.

pub mod batcher;
pub mod gateway;
pub mod metrics;
pub mod pool;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use gateway::{
    BufferPool, Gateway, GatewayBuilder, GatewayConfig, GatewayStats, ModelHandle, ModelId,
    ModelStats, Priority, Request, Response, ServeError, ShedPolicy, Ticket,
};
pub use metrics::{LatencyStats, Metrics};
pub use pool::{
    default_replicas, default_replicas_capped, Pool, PoolConfig, PoolError, PoolHandle, PoolStats,
};
pub use server::{Handle, Server, ServerConfig};
