//! `kansas` — the KAN-SAs leader binary.
//!
//! Subcommands map one-to-one onto the paper's evaluation artifacts (see
//! DESIGN.md's experiment index) plus the serving/simulation entrypoints:
//!
//! ```text
//! kansas table1                    # Table I  — PE cost model
//! kansas table2                    # Table II — workload registry
//! kansas fig7 [--csv DIR]          # Fig. 7a/7b — design-space sweep
//! kansas fig8                      # Fig. 8 — per-app utilization
//! kansas arkane                    # Sec. V-B — B-spline vs ArKANe
//! kansas accuracy [--model NAME]   # int8 vs fp32 accuracy (golden batch)
//! kansas simulate [--rows R --cols C --pe N:M --bs B]   # one config
//! kansas serve [--models a.kanq,b.kanq --mix 3,1 --replicas R] # gateway
//! kansas serve --listen ADDR [...] # network front door (TCP)
//! kansas load --connect ADDR [...] # remote load generator
//! kansas quickstart                # minimal end-to-end smoke
//! ```
//!
//! `serve` runs the multi-tenant Gateway: every `--models` entry is
//! registered on one shared worker fleet and admission queue (with a
//! per-model service `--weights` share), traffic is a weighted `--mix`,
//! dispatch is weighted-fair with work stealing (`--dispatch fixed`
//! keeps the pre-fair baseline), `--quota` reserves weight-proportional
//! admission slots per tenant, `--scenario churn` hot-adds/re-weights/
//! removes a tenant on the live gateway mid-run (scriptable via the
//! config `admin` stanza), and the report breaks counters down per
//! model and per replica (conservation: submitted == ok + shed +
//! failed, per model — including removed tenants) with steal counts,
//! both fairness indices, and the registry epoch. The telemetry spine
//! surfaces through `--stats-every S` (live windowed per-tenant stats
//! table), `--telemetry FILE` (streamed TELEMETRY.jsonl: window
//! snapshots, trace spans, periodic + final flight-recorder dumps —
//! `--flight-every S` tunes the dump interval), `--trace-sample N`
//! (1-in-N full request timelines), and `--no-telemetry` (the overhead
//! experiment's A-side). Startup also reports the dispatched SIMD MAC
//! kernel and each model's autotuned batch blocks (see `kan::kernel`),
//! so serving numbers are attributable to a dispatch path.
//! `--autoscale MIN:MAX` (with `--slo-p95-us`) makes the worker fleet
//! elastic against a p95 queueing-delay SLO — see
//! `coordinator::autoscale`.

use std::fs::File;
use std::io::Write;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use kan_sas::arch::{ArrayConfig, WeightLoad};
use kan_sas::config::{parse_dispatch, parse_pe, parse_shed, parse_synth_spec, RunConfig};
use kan_sas::coordinator::{
    AutoscaleConfig, BatchPolicy, GatewayBuilder, NetClient, NetServer, QuotaPolicy, RemoteHandle,
    Span, Telemetry, TelemetrySnapshot,
};
use kan_sas::cost::array_area_mm2;
use kan_sas::experiments;
use kan_sas::kan::{Engine, Kernel, Precision, QuantizedModel};
use kan_sas::loadgen::{self, LoadReport, MixEntry, Scenario};
use kan_sas::report::Table;
use kan_sas::sim::analytic;
use kan_sas::util::container::Container;
use kan_sas::workloads;

fn artifacts_dir() -> PathBuf {
    std::env::var("KANSAS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// Tiny argv reader: `--key value` pairs after the subcommand.
struct Args {
    rest: Vec<String>,
}

impl Args {
    fn get(&self, key: &str) -> Option<&str> {
        self.rest
            .iter()
            .position(|a| a == key)
            .and_then(|i| self.rest.get(i + 1))
            .map(|s| s.as_str())
    }

    fn flag(&self, key: &str) -> bool {
        self.rest.iter().any(|a| a == key)
    }

    fn parsed<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("bad value for {key}: '{v}'")),
        }
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(|s| s.as_str()) else {
        print_help();
        return Ok(());
    };
    let args = Args { rest: argv[1..].to_vec() };
    match cmd {
        "table1" => print!("{}", experiments::table1().render()),
        "table2" => print!("{}", experiments::table2().render()),
        "fig7" => cmd_fig7(&args)?,
        "fig8" => {
            let (t, avg, _) = experiments::fig8();
            print!("{}", t.render());
            println!("average absolute utilization improvement: {avg:.1} pp (paper: 39.9)");
            println!(
                "equal-area cycle ratio (conv 32x32 / KAN-SAs 16x16): {:.2}x (paper: ~2x)",
                experiments::equal_area_cycle_ratio()
            );
        }
        "arkane" => print!("{}", experiments::arkane_comparison().render()),
        "accuracy" => cmd_accuracy(&args)?,
        "simulate" => cmd_simulate(&args)?,
        "serve" => cmd_serve(&args)?,
        "load" => cmd_load(&args)?,
        "quickstart" => cmd_quickstart()?,
        "help" | "--help" | "-h" => print_help(),
        other => {
            print_help();
            bail!("unknown subcommand '{other}'");
        }
    }
    Ok(())
}

fn print_help() {
    println!(
        "kansas — KAN-SAs: Kolmogorov-Arnold Networks on systolic arrays\n\
         \n\
         experiments:   table1 | table2 | fig7 [--csv DIR] | fig8 | arkane\n\
         validation:    accuracy [--model mnist_kan]\n\
         simulation:    simulate [--rows R --cols C --pe N:M|scalar --bs B --counted-loads]\n\
         serving:       serve [--model NAME | --models SPEC,SPEC,...]\n\
                              [--mix W1,W2,...] [--weights W1,W2,...]\n\
                              [--dispatch fair|fixed] [--quota [FRAC]]\n\
                              [--synthetic --replicas R --max-replicas CAP --queue-cap Q\n\
                               --shed reject|drop-oldest|block --max-batch B\n\
                               --requests N --clients C\n\
                               --scenario steady|diurnal|flash-crowd|skewed-burst|churn\n\
                               --rate RPS --duration-ms MS]\n\
                              [--autoscale MIN:MAX --slo-p95-us US --pin-cores]\n\
                              [--stats-every S] [--telemetry FILE]\n\
                              [--flight-every S] [--trace-sample N] [--no-telemetry]\n\
                              [--listen ADDR]\n\
         remote load:   load --connect ADDR [--model NAME] [--mix W1,W2,...]\n\
                             [--scenario steady|diurnal|flash-crowd|skewed-burst\n\
                              --rate RPS --duration-ms MS]\n\
                             [--requests N --clients C] [--seed S] [--stats]\n\
         smoke:         quickstart\n\
         \n\
         serve runs the multi-tenant Gateway: one worker fleet + one bounded\n\
         admission queue serving every registered model, per-model batchers\n\
         (batches never mix models), per-model + per-replica accounting.\n\
         Each --models SPEC is a .kanq path (model name = file stem) or a\n\
         synthetic spec name:DIMxDIMx..DIM (e.g. mnist:64x32x10), with an\n\
         optional @int8|@int4|@mixed precision suffix: int4 packs two\n\
         coefficients per byte (half the table memory per tenant — .kanq\n\
         weights are demoted, synthetic models draw native int4; mixed\n\
         alternates per layer). KANSAS_FORCE_PRECISION=int4 forces every\n\
         synthetic model; startup prints per-model precisions and table\n\
         bytes.\n\
         --mix weights the open-loop ARRIVAL split (default equal);\n\
         --weights sets each model's SERVICE share (integers >= 1, default\n\
         1) for the weighted fair scheduler: under contention, tenants are\n\
         served rows in proportion to their weights, and an idle worker\n\
         steals a ready batch from the most backlogged peer instead of\n\
         sleeping. --dispatch fixed restores the pre-fair baseline (FIFO\n\
         pulls, no weights, no stealing) for A/B comparison; the scenario\n\
         skewed-burst concentrates a 4x burst on the FIRST model (~10:1)\n\
         to stress exactly that difference. --quota [FRAC] reserves\n\
         FRAC (default 0.5) of the queue per tenant in proportion to\n\
         --weights, so one tenant's burst can't shed everyone's new\n\
         arrivals; --scenario churn drives live registry churn (hot-add\n\
         at 25%, re-weight at 50%, remove at 75% — or the config file's\n\
         \"admin\" event script) while traffic flows.\n\
         The telemetry spine is on by default (lock-free event rings +\n\
         a collector thread): --stats-every S prints a live windowed\n\
         per-tenant stats table every S seconds, --telemetry FILE\n\
         streams TELEMETRY.jsonl (window snapshots, sampled spans, and\n\
         flight-recorder dumps — periodic every --flight-every S,\n\
         default 5, 0 keeps only the shutdown dump), --trace-sample N\n\
         records a full admission→batch→serve→respond timeline for\n\
         1-in-N requests, and --no-telemetry turns the spine off (the\n\
         A-side of the overhead experiment in EXPERIMENTS.md).\n\
         The MAC hot path dispatches to SIMD kernels at startup (the\n\
         chosen path and autotuned batch blocks are printed); pin with\n\
         KANSAS_FORCE_KERNEL=scalar|avx2|avx512|neon, KANSAS_BB=N, or\n\
         KANSAS_AUTOTUNE=0.\n\
         One model defaults to closed-loop clients; several models (or\n\
         --scenario) drive the open-loop Poisson generator. Replica\n\
         autosizing clamps cores to 8; raise with --max-replicas or\n\
         KANSAS_MAX_REPLICAS (explicit --replicas wins).\n\
         --autoscale MIN:MAX makes the fleet elastic: an SLO controller\n\
         watches the telemetry spine's windowed signals (worst-tenant\n\
         p95 queueing delay vs --slo-p95-us, default 10000; shed rate)\n\
         and doubles the fleet on breach, draining one worker at a time\n\
         after consecutive calm windows — no request is dropped by a\n\
         scale-down. --pin-cores pins each worker to a core. The final\n\
         report lists every scale event and the worker-seconds consumed\n\
         vs a fixed MAX-worker fleet.\n\
         --listen ADDR turns serve into the network front door: a TCP\n\
         server speaking the framed binary protocol (see\n\
         ARCHITECTURE.md), running until SIGINT (graceful drain + final\n\
         report) or --duration-ms. ADDR like 127.0.0.1:0 picks an\n\
         ephemeral port, printed as 'listening on ...'. Drive it from\n\
         another process with kansas load --connect ADDR: closed-loop\n\
         by default (--requests/--clients), open-loop with --scenario/\n\
         --rate, --stats polls the server's telemetry snapshot JSON\n\
         over the wire.\n\
         --config FILE (json) applies to simulate/serve; artifacts are read\n\
         from ./artifacts (override with KANSAS_ARTIFACTS).\n\
         \n\
         example — two tenants, minority weighted 4x against a 10:1 skewed\n\
         burst (fair dispatch keeps its p95 queue time flat; rerun with\n\
         --dispatch fixed to watch it starve):\n\
           kansas serve --models mnist:64x32x10,har:16x32x6 \\\n\
                        --mix 10,1 --weights 1,4 \\\n\
                        --scenario skewed-burst --rate 4000 --duration-ms 2000"
    );
}

fn cmd_fig7(args: &Args) -> Result<()> {
    let csv = args.get("--csv").map(PathBuf::from);
    let (a, b) = experiments::fig7(csv.as_deref());
    println!("{a}");
    println!("{b}");
    if let Some(dir) = csv {
        println!("wrote {}", dir.join("fig7.csv").display());
    }
    Ok(())
}

fn load_run_config(args: &Args) -> Result<RunConfig> {
    match args.get("--config") {
        Some(p) => RunConfig::load(std::path::Path::new(p)),
        None => Ok(RunConfig::default()),
    }
}

fn cmd_accuracy(args: &Args) -> Result<()> {
    let model = args.get("--model").unwrap_or("mnist_kan");
    let dir = artifacts_dir();
    let qm = QuantizedModel::load(&dir.join(format!("{model}.kanq")))
        .context("run `make artifacts` first")?;
    let engine = Engine::new(qm);
    let golden = Container::open(&dir.join(format!("{model}_golden.kgld")))?;
    let (x_q, xs) = golden.u8("x_q")?;
    let (labels, _) = golden.i32("labels")?;
    let fwd = engine.forward_from_q(&x_q, xs[0])?;
    let correct = fwd
        .predictions()
        .iter()
        .zip(&labels)
        .filter(|&(&p, &l)| p as i32 == l)
        .count();
    println!(
        "{model}: int8 accuracy on the golden batch: {}/{} = {:.2}%",
        correct,
        labels.len(),
        100.0 * correct as f64 / labels.len() as f64
    );
    // full quant metrics from the python export, if present
    if let Ok(text) = std::fs::read_to_string(dir.join("quant_metrics.json")) {
        if let Ok(v) = kan_sas::util::json::Value::parse(&text) {
            if let Some(m) = v.get(model) {
                let fp = m.get("fp32_test_acc").and_then(|x| x.as_f64()).unwrap_or(0.0);
                let i8a = m.get("int8_test_acc").and_then(|x| x.as_f64()).unwrap_or(0.0);
                println!(
                    "full test set (from build): fp32 {:.2}%  int8 {:.2}%  drop {:.2}pp (paper target: <1pp)",
                    fp * 100.0,
                    i8a * 100.0,
                    (fp - i8a) * 100.0
                );
            }
        }
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let base = load_run_config(args)?;
    let rows = args.parsed("--rows", base.array.rows)?;
    let cols = args.parsed("--cols", base.array.cols)?;
    let pe = match args.get("--pe") {
        Some(s) => parse_pe(s)?,
        None => base.array.pe,
    };
    let bs = args.parsed("--bs", base.batch_size)?;
    let weight_load =
        if args.flag("--counted-loads") { WeightLoad::Counted } else { base.array.weight_load };
    let cfg = ArrayConfig { rows, cols, pe, weight_load };

    let mut t = Table::new(&[
        "Application", "GEMMs", "cycles", "util %", "useful MACs",
    ])
    .with_title(format!(
        "simulate — {} ({:.3} mm^2), BS={bs}",
        cfg.label(),
        array_area_mm2(&cfg)
    )
    .as_str());
    for app in workloads::table2() {
        let wls = workloads::app_workloads(&app, bs, None);
        let compatible = wls.iter().all(|w| analytic::compatible(&cfg, w));
        if !compatible {
            t.row(vec![
                app.name.to_string(),
                wls.len().to_string(),
                "-".into(),
                "needs matching N:M".into(),
                "-".into(),
            ]);
            continue;
        }
        let s = analytic::simulate_app(&cfg, &wls);
        t.row(vec![
            app.name.to_string(),
            wls.len().to_string(),
            s.cycles.to_string(),
            format!("{:.1}", s.utilization() * 100.0),
            s.useful_macs.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

/// One `--models` entry: `path/to/model.kanq` (name = file stem) or a
/// synthetic spec `name:IN x HIDDEN x .. x OUT` (dims separated by `x`),
/// optionally suffixed `@int8|@int4|@mixed` to pick the coefficient
/// storage precision. Synthetic specs draw native int4 weights; `.kanq`
/// artifacts are demoted layer-wise (`QuantizedModel::with_precisions`);
/// `@mixed` alternates int4/int8 starting at the first layer.
fn load_model_spec(spec: &str, seed: u64) -> Result<(String, Engine)> {
    let (body, prec) = match spec.rsplit_once('@') {
        Some((b, p)) => (b, Some(p.trim().to_ascii_lowercase())),
        None => (spec, None),
    };
    let layer_precisions = |n_layers: usize| -> Result<Vec<Precision>> {
        match prec.as_deref() {
            None | Some("int8") => Ok(vec![Precision::Int8; n_layers]),
            Some("int4") => Ok(vec![Precision::Int4; n_layers]),
            Some("mixed") => Ok((0..n_layers)
                .map(|i| if i % 2 == 0 { Precision::Int4 } else { Precision::Int8 })
                .collect()),
            Some(other) => bail!("bad precision suffix '@{other}' (want int8|int4|mixed)"),
        }
    };
    if body.contains(':') {
        let (name, dims) = parse_synth_spec(body)?;
        let qm = match &prec {
            // no suffix: the plain synthetic path (honors
            // KANSAS_FORCE_PRECISION for whole-process overrides)
            None => QuantizedModel::synthetic(&name, &dims, 5, 3, seed),
            Some(_) => {
                let p = layer_precisions(dims.len() - 1)?;
                QuantizedModel::synthetic_mixed(&name, &dims, 5, 3, seed, &p)
            }
        };
        return Ok((name, Engine::new(qm)));
    }
    let mut path = PathBuf::from(body);
    if !path.exists() {
        path = artifacts_dir().join(body);
    }
    let name = path
        .file_stem()
        .and_then(|s| s.to_str())
        .with_context(|| format!("model spec '{spec}' has no file stem"))?
        .to_string();
    let mut qm = QuantizedModel::load(&path).with_context(|| {
        format!("loading '{spec}' (run `make artifacts`, or use name:DIMxDIM syntax)")
    })?;
    if prec.is_some() {
        let p = layer_precisions(qm.layers.len())?;
        qm = qm.with_precisions(&p);
    }
    Ok((name, Engine::new(qm)))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let base = load_run_config(args)?;
    let requests: usize = args.parsed("--requests", 256)?;
    let clients: usize = args.parsed("--clients", 4)?;
    let max_batch: usize = args.parsed("--max-batch", base.policy.max_batch)?;
    let mut cfg = base.to_pool_config();
    cfg.policy = BatchPolicy { max_batch, ..base.policy };
    // --replicas pins the fleet size; otherwise autosize to the host,
    // with --max-replicas (or KANSAS_MAX_REPLICAS) lifting the clamp
    if let Some(cap) = args.get("--max-replicas") {
        let cap: usize = cap.parse().map_err(|_| anyhow::anyhow!("bad --max-replicas '{cap}'"))?;
        cfg.replicas = kan_sas::coordinator::default_replicas_capped(cap);
    }
    cfg.replicas = args.parsed("--replicas", cfg.replicas)?;
    cfg.queue_cap = args.parsed("--queue-cap", cfg.queue_cap)?;
    if let Some(s) = args.get("--shed") {
        cfg.shed = parse_shed(s)?;
    }
    if let Some(s) = args.get("--dispatch") {
        cfg.dispatch = parse_dispatch(s)?;
    }
    // --quota [FRAC]: weighted per-tenant admission quotas. Bare flag
    // reserves half the queue; an explicit fraction tunes the split
    // (0 disables, matching the config file's pool.quota).
    if args.flag("--quota") {
        cfg.quota = match args.get("--quota") {
            Some(v) if !v.starts_with("--") => {
                let f: f64 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad --quota '{v}' (want a fraction in [0,1])"))?;
                if !(0.0..=1.0).contains(&f) {
                    bail!("--quota must be in [0, 1], got {f}");
                }
                if f == 0.0 {
                    QuotaPolicy::None
                } else {
                    QuotaPolicy::Weighted { reserve: f }
                }
            }
            _ => QuotaPolicy::weighted(),
        };
    }
    // --autoscale MIN:MAX makes the worker fleet elastic against a p95
    // queueing-delay SLO (--slo-p95-us, default 10000); layered over
    // the config file's autoscale stanza (CLI bounds win)
    if let Some(spec) = args.get("--autoscale") {
        let bounds = AutoscaleConfig::from_bounds_spec(spec).map_err(|e| anyhow::anyhow!(e))?;
        cfg.autoscale = Some(match cfg.autoscale {
            Some(prev) => AutoscaleConfig {
                min_workers: bounds.min_workers,
                max_workers: bounds.max_workers,
                ..prev
            },
            None => bounds,
        });
    }
    if let Some(a) = cfg.autoscale.as_mut() {
        a.slo_p95_us = args.parsed("--slo-p95-us", a.slo_p95_us)?;
        if a.slo_p95_us == 0 {
            bail!("--slo-p95-us must be positive");
        }
        if args.flag("--pin-cores") {
            a.pin_cores = true;
        }
    } else if args.get("--slo-p95-us").is_some() {
        bail!("--slo-p95-us needs --autoscale MIN:MAX (or a config autoscale stanza)");
    }
    let autoscale_cfg = cfg.autoscale;
    // telemetry spine controls: --no-telemetry is the overhead
    // experiment's A-side; any observability flag implies the spine on
    let stats_every: f64 = args.parsed("--stats-every", 0.0)?;
    if !stats_every.is_finite() || stats_every < 0.0 {
        bail!("--stats-every must be a non-negative number of seconds");
    }
    let telemetry_path = args.get("--telemetry").map(PathBuf::from);
    cfg.telemetry.trace_sample = args.parsed("--trace-sample", cfg.telemetry.trace_sample)?;
    // --flight-every S: interval between flight-recorder dumps on the
    // JSONL stream (0 disables the periodic dumps; the shutdown dump is
    // always written). Layered over the config file's flight_every_s.
    let flight_every: f64 =
        args.parsed("--flight-every", cfg.telemetry.flight_every.as_secs_f64())?;
    if !flight_every.is_finite() || flight_every < 0.0 {
        bail!("--flight-every must be a non-negative number of seconds");
    }
    cfg.telemetry.flight_every = Duration::from_micros((flight_every * 1e6) as u64);
    if args.flag("--no-telemetry") {
        cfg.telemetry.enabled = false;
    } else if stats_every > 0.0 || telemetry_path.is_some() || cfg.telemetry.trace_sample > 0 {
        cfg.telemetry.enabled = true;
    }

    // registered models: --models SPEC,SPEC,... or the single-model flags
    let specs: Vec<(String, Engine)> = if let Some(list) = args.get("--models") {
        list.split(',')
            .enumerate()
            .map(|(i, s)| load_model_spec(s.trim(), 17 + i as u64))
            .collect::<Result<_>>()?
    } else if args.flag("--synthetic") {
        vec![(
            "synthetic_kan".to_string(),
            Engine::new(QuantizedModel::synthetic("synthetic_kan", &[64, 64, 10], 5, 3, 17)),
        )]
    } else {
        let model = args.get("--model").unwrap_or("mnist_kan");
        let dir = artifacts_dir();
        let qm = QuantizedModel::load(&dir.join(format!("{model}.kanq")))
            .context("run `make artifacts` first (or pass --synthetic / --models)")?;
        vec![(model.to_string(), Engine::new(qm))]
    };
    for (i, (name, _)) in specs.iter().enumerate() {
        if specs[..i].iter().any(|(earlier, _)| earlier == name) {
            bail!("duplicate model name '{name}' in --models (names must be unique)");
        }
    }
    let weights: Vec<f64> = match args.get("--mix") {
        Some(w) => {
            let ws: Vec<f64> = w
                .split(',')
                .map(|s| s.trim().parse().with_context(|| format!("bad --mix weight '{s}'")))
                .collect::<Result<_>>()?;
            if ws.len() != specs.len() {
                bail!("--mix has {} weights for {} models", ws.len(), specs.len());
            }
            if ws.iter().any(|w| !w.is_finite() || *w < 0.0) {
                bail!("--mix weights must be finite and >= 0");
            }
            if ws.iter().sum::<f64>() <= 0.0 {
                bail!("--mix needs a positive total weight");
            }
            ws
        }
        None => vec![1.0; specs.len()],
    };
    // --weights: per-model SERVICE shares for the fair scheduler
    // (distinct from --mix, which splits the offered ARRIVALS)
    let service_weights: Vec<u32> = match args.get("--weights") {
        Some(w) => {
            let ws: Vec<u32> = w
                .split(',')
                .map(|s| s.trim().parse().with_context(|| format!("bad --weights value '{s}'")))
                .collect::<Result<_>>()?;
            if ws.len() != specs.len() {
                bail!("--weights has {} values for {} models", ws.len(), specs.len());
            }
            if ws.iter().any(|&w| w == 0) {
                bail!("--weights values must be >= 1");
            }
            ws
        }
        None => vec![1; specs.len()],
    };

    let total_kib: usize = specs.iter().map(|(_, e)| e.param_bytes()).sum::<usize>() / 1024;
    let names: Vec<String> = specs
        .iter()
        .zip(&service_weights)
        .map(|((n, _), w)| format!("{n}(w{w})"))
        .collect();
    println!(
        "serve — {} replicas x [{}] (queue {} / {:?} / {:?} / quota {:?}), weights shared: {} KiB total",
        cfg.replicas,
        names.join(", "),
        cfg.queue_cap,
        cfg.shed,
        cfg.dispatch,
        cfg.quota,
        total_kib
    );
    if let Some(a) = &autoscale_cfg {
        println!(
            "autoscale: {}..{} workers, SLO p95 queue <= {} us, shed <= {:.2}%, \
             scale-down after {} calm windows @ {:?}{}",
            a.min_workers,
            a.max_workers,
            a.slo_p95_us,
            100.0 * a.max_shed_rate,
            a.calm_windows,
            a.interval,
            if a.pin_cores { ", cores pinned" } else { "" }
        );
    }
    // attribute every serving number to a MAC dispatch path: the
    // resolved kernel (all plans in one process dispatch identically)
    // and each model's autotuned per-layer batch blocks
    let blocks: Vec<String> = specs
        .iter()
        .map(|(n, e)| {
            let bb: Vec<String> =
                e.plan().batch_blocks().iter().map(|b| b.to_string()).collect();
            format!("{n}=[{}]", bb.join(","))
        })
        .collect();
    println!(
        "mac kernel: {} (available: {}); autotuned batch blocks: {}",
        specs[0].1.plan().kernel_kind(),
        Kernel::available().iter().map(|k| k.name()).collect::<Vec<_>>().join("|"),
        blocks.join("  ")
    );
    // per-model storage precisions and compiled coefficient-table bytes
    // (ExecutionPlan::derived_bytes) — the memory the int4 packing saves
    let precs: Vec<String> = specs
        .iter()
        .map(|(n, e)| {
            let p: Vec<&str> = e.plan().precisions().iter().map(|p| p.name()).collect();
            format!("{n}=[{}] {:.1} KiB", p.join(","), e.plan().derived_bytes() as f64 / 1024.0)
        })
        .collect();
    println!("precision (coefficient tables): {}", precs.join("  "));
    let mut builder = GatewayBuilder::with_config(cfg);
    for ((name, engine), &w) in specs.into_iter().zip(&service_weights) {
        builder.register_weighted(&name, engine, w);
    }
    let gateway = builder.start();
    let handles = gateway.handles();
    let tel = gateway.telemetry();
    let jsonl_out = match &telemetry_path {
        Some(p) if tel.enabled() => {
            let f = File::create(p)
                .with_context(|| format!("creating telemetry stream {}", p.display()))?;
            Some(f)
        }
        Some(p) => {
            println!("--telemetry {} ignored: spine disabled by --no-telemetry", p.display());
            None
        }
        None => None,
    };
    let monitor = (tel.enabled() && (stats_every > 0.0 || jsonl_out.is_some())).then(|| {
        let every = if stats_every > 0.0 {
            Duration::from_secs_f64(stats_every)
        } else {
            Duration::from_secs(1)
        };
        let flight_every = tel.config().flight_every;
        spawn_monitor(Arc::clone(&tel), every, stats_every > 0.0, jsonl_out, flight_every)
    });

    let multi = handles.len() > 1;
    let listen = args.get("--listen").map(str::to_string).or_else(|| base.net.listen.clone());
    let report = if let Some(addr) = listen {
        // network front door: serve remote `kansas load --connect`
        // clients instead of generating local traffic; SIGINT (graceful
        // drain) or a nonzero --duration-ms ends the run
        let mut net_cfg = base.net.clone();
        net_cfg.listen = Some(addr.clone());
        let server = NetServer::start(&addr, &gateway, net_cfg)
            .with_context(|| format!("binding {addr}"))?;
        println!("listening on {}", server.local_addr());
        install_sigint();
        let dur_ms: u64 = args.parsed("--duration-ms", 0)?;
        let t0 = Instant::now();
        let until = (dur_ms > 0).then(|| t0 + Duration::from_millis(dur_ms));
        loop {
            if SIGINT_FLAG.load(Ordering::SeqCst) {
                break;
            }
            if let Some(u) = until {
                if Instant::now() >= u {
                    break;
                }
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        if SIGINT_FLAG.load(Ordering::SeqCst) {
            println!("SIGINT: draining connections, flushing telemetry");
        }
        let net_stats = server.shutdown();
        let wall = t0.elapsed();
        println!(
            "net: {} conns accepted, {} frames in, {} frames out, {} malformed",
            net_stats.accepted, net_stats.frames_in, net_stats.frames_out, net_stats.malformed
        );
        // synthesize the run report from the gateway's own counters so
        // the shared report block below applies unchanged
        let stats = gateway.stats();
        let (mut sub, mut ok, mut shed, mut failed) = (0u64, 0u64, 0u64, 0u64);
        for m in &stats.per_model {
            sub += m.submitted;
            ok += m.completed;
            shed += m.shed;
            failed += m.failed;
        }
        let secs = wall.as_secs_f64().max(1e-9);
        LoadReport {
            scenario: "listen".to_string(),
            submitted: sub,
            ok,
            shed,
            failed,
            wall,
            offered_rps: sub as f64 / secs,
            achieved_rps: ok as f64 / secs,
            latency: stats.merged.latency(),
        }
    } else if args.get("--scenario") == Some("churn") {
        // registry churn demo: open-loop traffic while a scripted event
        // timeline (config `admin` stanza, or the default add → reweight
        // → remove cycle) mutates the live gateway
        let rate: f64 = args.parsed("--rate", 2000.0)?;
        let dur_ms: u64 = args.parsed("--duration-ms", 2000)?;
        let duration = Duration::from_millis(dur_ms);
        let sc = Scenario::steady(rate, duration);
        let events = if base.admin_events.is_empty() {
            loadgen::default_churn_events(duration)
        } else {
            base.admin_events.clone()
        };
        println!("churn script: {} events over {dur_ms} ms", events.len());
        let entries: Vec<MixEntry> = handles
            .iter()
            .zip(&weights)
            .map(|(h, &w)| MixEntry { handle: h.clone(), weight: w })
            .collect();
        let mix = loadgen::run_churn(&gateway, entries, &sc, &events, 12345);
        for rep in &mix.per_model {
            println!("  {}", rep.summary());
        }
        mix.total
    } else if multi || args.get("--scenario").is_some() {
        let name = args.get("--scenario").unwrap_or("steady");
        let rate: f64 = args.parsed("--rate", 2000.0)?;
        let dur_ms: u64 = args.parsed("--duration-ms", 2000)?;
        let sc = Scenario::by_name(name, rate, Duration::from_millis(dur_ms)).with_context(|| {
            format!("unknown scenario '{name}' (steady|diurnal|flash-crowd|skewed-burst|churn)")
        })?;
        let entries: Vec<MixEntry> = handles
            .iter()
            .zip(&weights)
            .map(|(h, &w)| MixEntry { handle: h.clone(), weight: w })
            .collect();
        let mix = loadgen::run_mix(&entries, &sc, 12345);
        for rep in &mix.per_model {
            println!("  {}", rep.summary());
        }
        mix.total
    } else {
        // legacy closed-loop mode, sized by --requests/--clients
        let per_client = requests / clients.max(1);
        let budget = Some(per_client);
        loadgen::closed_loop(&handles[0], clients, Duration::from_secs(3600), budget, 12345)
    };

    // stop the live monitor before the final report so its table stops
    // interleaving; the post-shutdown snapshot below catches the tail
    let (mut spans, mut jsonl_out) = match monitor {
        Some(m) => {
            m.stop.store(true, Ordering::Release);
            m.handle.join().expect("join telemetry monitor")
        }
        None => (Vec::new(), None),
    };
    let scale_events = gateway.scale_events();
    let fleet_final = gateway.active_workers();
    let worker_us = gateway.worker_time_us();
    let stats = gateway.shutdown();
    if tel.enabled() {
        let final_snap = tel.snapshot();
        if let Some(f) = jsonl_out.as_mut() {
            let _ = writeln!(f, "{}", final_snap.to_value().render());
            for s in &final_snap.spans {
                let _ = writeln!(f, "{}", s.to_value().render());
            }
            let _ = writeln!(f, "{}", tel.flight_dump().to_value().render());
        }
        spans.extend(final_snap.spans);
    }
    println!("{}", report.summary());
    println!(
        "throughput: {:.0} rows/s over {:.2}s   mean batch {:.1}   batches {}   peak queue {}",
        stats.merged.batch_rows as f64 / report.wall.as_secs_f64(),
        report.wall.as_secs_f64(),
        stats.merged.mean_batch_size(),
        stats.merged.batches,
        stats.peak_depth
    );
    if let Some(lat) = stats.merged.latency() {
        println!(
            "latency us: mean {:.0} (queue {:.0} + service {:.0})  p50 {}  p95 {}  p99 {}  max {}",
            lat.mean_us,
            stats.merged.mean_queue_us(),
            stats.merged.mean_service_us(),
            lat.p50_us,
            lat.p95_us,
            lat.p99_us,
            lat.max_us
        );
    }
    println!(
        "simulated accelerator: {} cycles total on {} ({:.3} mm^2), utilization {:.1}%",
        stats.merged.sim_cycles,
        base.array.label(),
        array_area_mm2(&base.array),
        100.0 * stats.merged.sim_utilization()
    );
    let mut t = Table::new(&[
        "model", "wt", "rsvd", "submitted", "ok", "shed", "failed", "rows", "stolen", "p50 us",
        "p99 us", "q p95 us", "conserved",
    ])
    .with_title(
        format!(
            "per-model accounting ({} live / {} registered)",
            stats.live_models(),
            stats.per_model.len()
        )
        .as_str(),
    );
    for m in &stats.per_model {
        let (p50, p99) = m.metrics.latency().map(|l| (l.p50_us, l.p99_us)).unwrap_or((0, 0));
        let q95 = m.metrics.queue_latency().map(|l| l.p95_us).unwrap_or(0);
        let name = if m.live { m.name.clone() } else { format!("{} (removed)", m.name) };
        t.row(vec![
            name,
            m.weight.to_string(),
            m.reserved.to_string(),
            m.submitted.to_string(),
            m.completed.to_string(),
            m.shed.to_string(),
            m.failed.to_string(),
            m.metrics.batch_rows.to_string(),
            m.metrics.stolen_batches.to_string(),
            p50.to_string(),
            p99.to_string(),
            q95.to_string(),
            if m.conserved() { "yes".into() } else { "NO".into() },
        ]);
    }
    print!("{}", t.render());
    println!(
        "fairness (Jain): raw {:.3}   demand-normalized {:.3}   stolen batches: {}",
        stats.fairness_index(),
        stats.fairness_index_normalized(),
        stats.stolen_batches()
    );
    println!(
        "registry: epoch {}   {} live / {} registered tenants",
        stats.epoch,
        stats.live_models(),
        stats.per_model.len()
    );
    let mut t = Table::new(&["replica", "rows", "batches", "stolen", "sim cycles", "sim util %"])
        .with_title(
            format!("per-replica load balance ({} worker slots)", stats.per_replica.len()).as_str(),
        );
    for (i, m) in stats.per_replica.iter().enumerate() {
        t.row(vec![
            i.to_string(),
            m.batch_rows.to_string(),
            m.batches.to_string(),
            m.stolen_batches.to_string(),
            m.sim_cycles.to_string(),
            format!("{:.1}", 100.0 * m.sim_utilization()),
        ]);
    }
    print!("{}", t.render());
    if let Some(a) = &autoscale_cfg {
        let wall_s = report.wall.as_secs_f64().max(1e-9);
        println!(
            "autoscale: {} scale events, final fleet {} workers, worker-time {:.2}s \
             (a fixed {}-worker fleet costs {:.2}s)",
            scale_events.len(),
            fleet_final,
            worker_us as f64 / 1e6,
            a.max_workers,
            a.max_workers as f64 * wall_s
        );
        for e in scale_events.iter().take(16) {
            println!(
                "  t={}us workers {} -> {} (p95 queue {} us, shed {:.2}%)",
                e.at_us,
                e.from,
                e.to,
                e.p95_queue_us,
                100.0 * e.shed_rate
            );
        }
        if scale_events.len() > 16 {
            println!("  ... {} more scale events", scale_events.len() - 16);
        }
    }
    if tel.enabled() {
        if tel.config().trace_sample > 0 && !spans.is_empty() {
            println!("trace spans: {} sampled (showing up to 10)", spans.len());
            for s in spans.iter().take(10) {
                println!("  {}", s.timeline());
            }
        }
        let dump = tel.flight_dump();
        if !dump.churn.is_empty() {
            println!("flight recorder — {} registry transitions (in order):", dump.churn.len());
            for c in &dump.churn {
                println!(
                    "  t={}us {} '{}' (weight {}, epoch {})",
                    c.t_us,
                    c.kind.name(),
                    c.name,
                    c.weight,
                    c.epoch
                );
            }
        }
        let dropped = tel.dropped_events();
        if dropped > 0 {
            println!("telemetry: {dropped} events dropped on ring overflow (raise ring_capacity)");
        }
        if let Some(p) = &telemetry_path {
            println!("telemetry stream written to {}", p.display());
        }
    }
    Ok(())
}

/// Set by the SIGINT handler installed for `kansas serve --listen`.
static SIGINT_FLAG: AtomicBool = AtomicBool::new(false);

/// Install a minimal SIGINT handler (libc `signal`, already linked by
/// std) so a listening server stops accepting, drains its connections,
/// and prints the final report on ctrl-c instead of dying mid-flight.
#[cfg(unix)]
fn install_sigint() {
    extern "C" fn on_sigint(_sig: i32) {
        // only an atomic store: async-signal-safe
        SIGINT_FLAG.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    unsafe {
        signal(SIGINT, on_sigint as extern "C" fn(i32) as usize);
    }
}

#[cfg(not(unix))]
fn install_sigint() {
    // no portable handler without a signal API; --duration-ms still
    // bounds the run
}

/// `kansas load --connect ADDR`: drive a remote `kansas serve --listen`
/// server through the framed wire protocol. Closed-loop by default
/// (`--requests`/`--clients` like in-process serve), open-loop Poisson
/// with `--scenario`/`--rate`/`--duration-ms`; `--stats` polls the
/// server's telemetry snapshot over the wire at the end.
fn cmd_load(args: &Args) -> Result<()> {
    let Some(addr) = args.get("--connect") else {
        bail!("load needs --connect ADDR (start a server with `kansas serve --listen ADDR`)");
    };
    let client = NetClient::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    let mut handles = client.handles().map_err(|e| anyhow::anyhow!("listing models: {e}"))?;
    if handles.is_empty() {
        bail!("server at {addr} has no models registered");
    }
    if let Some(name) = args.get("--model") {
        handles.retain(|h| h.name() == name);
        if handles.is_empty() {
            bail!("server has no model named '{name}'");
        }
    }
    let names: Vec<String> =
        handles.iter().map(|h| format!("{}:{}x{}", h.name(), h.in_dim(), h.out_dim())).collect();
    println!("connected to {addr}: {} models [{}]", handles.len(), names.join(", "));
    let weights: Vec<f64> = match args.get("--mix") {
        Some(w) => {
            let ws: Vec<f64> = w
                .split(',')
                .map(|s| s.trim().parse().with_context(|| format!("bad --mix weight '{s}'")))
                .collect::<Result<_>>()?;
            if ws.len() != handles.len() {
                bail!("--mix has {} weights for {} models", ws.len(), handles.len());
            }
            if ws.iter().any(|w| !w.is_finite() || *w < 0.0) || ws.iter().sum::<f64>() <= 0.0 {
                bail!("--mix weights must be finite, >= 0, with a positive total");
            }
            ws
        }
        None => vec![1.0; handles.len()],
    };
    let seed: u64 = args.parsed("--seed", 12345)?;
    let open_loop =
        args.get("--scenario").is_some() || args.get("--rate").is_some() || handles.len() > 1;
    let report = if open_loop {
        let name = args.get("--scenario").unwrap_or("steady");
        let rate: f64 = args.parsed("--rate", 2000.0)?;
        let dur_ms: u64 = args.parsed("--duration-ms", 2000)?;
        let sc = Scenario::by_name(name, rate, Duration::from_millis(dur_ms)).with_context(
            || format!("unknown scenario '{name}' (steady|diurnal|flash-crowd|skewed-burst)"),
        )?;
        let entries: Vec<MixEntry<RemoteHandle>> = handles
            .iter()
            .zip(&weights)
            .map(|(h, &w)| MixEntry { handle: h.clone(), weight: w })
            .collect();
        let mix = loadgen::run_mix(&entries, &sc, seed);
        for rep in &mix.per_model {
            println!("  {}", rep.summary());
        }
        mix.total
    } else {
        let requests: usize = args.parsed("--requests", 256)?;
        let clients: usize = args.parsed("--clients", 4)?;
        let per_client = requests / clients.max(1);
        loadgen::closed_loop(
            &handles[0],
            clients,
            Duration::from_secs(3600),
            Some(per_client),
            seed,
        )
    };
    println!("{}", report.summary());
    let conserved = report.submitted == report.ok + report.shed + report.failed;
    println!(
        "client conservation: submitted {} == ok {} + shed {} + failed {} -> {}",
        report.submitted,
        report.ok,
        report.shed,
        report.failed,
        if conserved { "yes" } else { "NO" }
    );
    if args.flag("--stats") {
        match client.stats_json() {
            Ok(s) => println!("server stats: {s}"),
            Err(e) => println!("server stats unavailable: {e}"),
        }
    }
    client.close();
    if !conserved {
        bail!("client-side conservation violated");
    }
    Ok(())
}

/// Background telemetry monitor spawned by `kansas serve`: snapshots the
/// spine every `tick`, optionally printing the live per-tenant table and
/// streaming JSONL lines (with a flight-recorder dump every
/// `flight_every` so the churn record survives a crash); returns the
/// accumulated trace spans and the stream file on join.
struct Monitor {
    stop: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<(Vec<Span>, Option<File>)>,
}

fn spawn_monitor(
    tel: Arc<Telemetry>,
    tick: Duration,
    print: bool,
    mut out: Option<File>,
    flight_every: Duration,
) -> Monitor {
    let stop = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&stop);
    let handle = std::thread::Builder::new()
        .name("kansas-monitor".into())
        .spawn(move || {
            let mut spans = Vec::new();
            let mut last_flight = Instant::now();
            loop {
                // sleep in short slices so shutdown is responsive even
                // with multi-second --stats-every intervals
                let mut slept = Duration::ZERO;
                while slept < tick && !flag.load(Ordering::Acquire) {
                    let slice = (tick - slept).min(Duration::from_millis(50));
                    std::thread::sleep(slice);
                    slept += slice;
                }
                if flag.load(Ordering::Acquire) {
                    break;
                }
                let snap = tel.snapshot();
                if let Some(f) = out.as_mut() {
                    let _ = writeln!(f, "{}", snap.to_value().render());
                    for s in &snap.spans {
                        let _ = writeln!(f, "{}", s.to_value().render());
                    }
                    // periodic flight dump (kind="flight"): the registry
                    // churn record streams on an interval instead of
                    // existing only in the single shutdown dump
                    if !flight_every.is_zero() && last_flight.elapsed() >= flight_every {
                        let _ = writeln!(f, "{}", tel.flight_dump().to_value().render());
                        last_flight = Instant::now();
                    }
                }
                if print {
                    print!("{}", live_table(&snap).render());
                }
                spans.extend(snap.spans);
            }
            (spans, out)
        })
        .expect("spawn telemetry monitor");
    Monitor { stop, handle }
}

/// The `--stats-every` console table: one row per tenant over the last
/// completed stats window.
fn live_table(snap: &TelemetrySnapshot) -> Table {
    let mut t = Table::new(&[
        "tenant", "rps", "shed %", "steal %", "depth", "q p95 us", "svc p95 us", "util %",
    ])
    .with_title(
        format!(
            "telemetry @ {:.1}s (dropped events: {})",
            snap.at_us as f64 / 1e6,
            snap.dropped_events
        )
        .as_str(),
    );
    for ten in &snap.tenants {
        let name = if ten.live { ten.name.clone() } else { format!("{} (removed)", ten.name) };
        let Some(w) = &ten.window else {
            let dash = || "-".to_string();
            t.row(vec![name, dash(), dash(), dash(), dash(), dash(), dash(), dash()]);
            continue;
        };
        t.row(vec![
            name,
            format!("{:.0}", w.throughput_rps),
            format!("{:.1}", 100.0 * w.shed_rate),
            format!("{:.1}", 100.0 * w.steal_rate),
            w.depth_last.to_string(),
            w.queue.map(|l| l.p95_us.to_string()).unwrap_or_else(|| "-".into()),
            w.service.map(|l| l.p95_us.to_string()).unwrap_or_else(|| "-".into()),
            format!("{:.1}", 100.0 * w.sim_utilization),
        ]);
    }
    t
}

fn cmd_quickstart() -> Result<()> {
    let dir = artifacts_dir();
    let qm = QuantizedModel::load(&dir.join("quickstart_kan.kanq"))
        .context("run `make artifacts` first")?;
    let engine = Engine::new(qm);
    let x = vec![0.25f32, -0.5, 0.75, 0.1];
    // drive the compiled-plan path the serving pool runs: quantize into
    // the scratch's staging buffer, execute, argmax the returned slice
    let mut scratch = kan_sas::kan::Scratch::new();
    kan_sas::quant::quantize_activations_into(&x, scratch.stage_input(x.len()));
    let t = engine.forward_staged(1, &mut scratch)?;
    println!("int8 engine prediction: class {}", kan_sas::util::argmax(t));

    #[cfg(feature = "xla")]
    {
        use kan_sas::runtime::{FloatEngine, ModelArtifacts};
        let client = xla::PjRtClient::cpu()?;
        let art = ModelArtifacts::new(&dir, "quickstart_kan");
        let fe = FloatEngine::load(&client, &art, 1)?;
        let logits = fe.execute(&x)?;
        println!("pjrt fp32 logits: {logits:?}");
        println!("pjrt fp32 prediction: class {}", fe.predictions(&logits)[0]);
    }
    #[cfg(not(feature = "xla"))]
    println!("pjrt fp32 cross-check skipped (rebuild with --features xla)");
    println!("quickstart OK");
    Ok(())
}
