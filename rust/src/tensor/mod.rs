//! Minimal dense tensor used across the integer engine and simulator.
//!
//! Row-major, owned storage, shape-checked ops. Deliberately small: the
//! heavy lifting happens either in the PJRT runtime (fp32 path) or in the
//! hand-written integer kernels in `kan::engine` (int8 path); this type
//! mostly carries data between them with explicit shapes.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor<T> {
    data: Vec<T>,
    shape: Vec<usize>,
}

impl<T: fmt::Debug> fmt::Debug for Tensor<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}(len={})", self.shape, self.data.len())
    }
}

impl<T: Clone + Default> Tensor<T> {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { data: vec![T::default(); n], shape: shape.to_vec() }
    }
}

impl<T> Tensor<T> {
    pub fn from_vec(data: Vec<T>, shape: &[usize]) -> Self {
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "data length {} != shape {:?}",
            data.len(),
            shape
        );
        Self { data, shape: shape.to_vec() }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Flat offset of a multi-index (row-major).
    pub fn offset(&self, idx: &[usize]) -> usize {
        assert_eq!(idx.len(), self.shape.len(), "index rank mismatch");
        let mut off = 0;
        for (i, (&ix, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            assert!(ix < dim, "index {ix} out of bounds for dim {i} (size {dim})");
            off = off * dim + ix;
        }
        off
    }

    pub fn at(&self, idx: &[usize]) -> &T {
        &self.data[self.offset(idx)]
    }

    pub fn at_mut(&mut self, idx: &[usize]) -> &mut T {
        let off = self.offset(idx);
        &mut self.data[off]
    }

    /// Contiguous row `r` of a rank-2 tensor.
    pub fn row(&self, r: usize) -> &[T] {
        assert_eq!(self.rank(), 2);
        let cols = self.shape[1];
        &self.data[r * cols..(r + 1) * cols]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(self.data.len(), shape.iter().product::<usize>());
        self.shape = shape.to_vec();
        self
    }
}

/// f32 GEMM: `out[m,n] = sum_k a[m,k] * b[k,n]` (reference/test helper; the
/// serving fp32 path goes through PJRT instead).
pub fn matmul_f32(a: &Tensor<f32>, b: &Tensor<f32>) -> Tensor<f32> {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "inner dims {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let arow = a.row(i);
        let orow = &mut out.data[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Integer GEMM with i32 accumulation (u8 activations x i8 weights), the
/// arithmetic of the paper's PE datapath (8-bit in, 32-bit out).
pub fn matmul_u8_i8(a: &Tensor<u8>, b: &Tensor<i8>) -> Tensor<i32> {
    assert_eq!(a.rank(), 2);
    assert_eq!(b.rank(), 2);
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "inner dims {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        let arow = a.row(i);
        let orow = &mut out.data[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0 {
                continue;
            }
            let av = av as i32;
            let brow = &b.data[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv as i32;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{check, Rng};

    #[test]
    fn indexing_row_major() {
        let t = Tensor::from_vec((0..24).collect::<Vec<i32>>(), &[2, 3, 4]);
        assert_eq!(*t.at(&[0, 0, 0]), 0);
        assert_eq!(*t.at(&[1, 2, 3]), 23);
        assert_eq!(*t.at(&[1, 0, 2]), 14);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn oob_panics() {
        let t = Tensor::from_vec(vec![1, 2], &[2]);
        t.at(&[2]);
    }

    #[test]
    #[should_panic(expected = "data length")]
    fn bad_shape_panics() {
        Tensor::from_vec(vec![1, 2, 3], &[2, 2]);
    }

    #[test]
    fn matmul_small() {
        let a = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let b = Tensor::from_vec(vec![1.0, 1.0, 1.0, 1.0], &[2, 2]);
        let c = matmul_f32(&a, &b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_int_matches_float() {
        check(20, 11, |rng: &mut Rng| {
            let (m, k, n) = (1 + rng.below(6), 1 + rng.below(6), 1 + rng.below(6));
            let a: Vec<u8> = (0..m * k).map(|_| rng.below(256) as u8).collect();
            let b: Vec<i8> = (0..k * n).map(|_| rng.range_i64(-127, 127) as i8).collect();
            let ai = Tensor::from_vec(a.clone(), &[m, k]);
            let bi = Tensor::from_vec(b.clone(), &[k, n]);
            let got = matmul_u8_i8(&ai, &bi);
            let af = Tensor::from_vec(a.iter().map(|&x| x as f32).collect(), &[m, k]);
            let bf = Tensor::from_vec(b.iter().map(|&x| x as f32).collect(), &[k, n]);
            let want = matmul_f32(&af, &bf);
            for (g, w) in got.data().iter().zip(want.data()) {
                assert_eq!(*g as f32, *w);
            }
        });
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec((0..6).collect::<Vec<i32>>(), &[2, 3]).reshape(&[3, 2]);
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(*t.at(&[2, 1]), 5);
    }
}
