//! Micro-benchmark harness (criterion replacement for the offline image).
//!
//! Each bench target is a plain `harness = false` binary that calls
//! [`bench`] / [`bench_with_result`]: warm up, run timed samples, report
//! median/mean/p95 and derived throughput. Deterministic sample counts
//! keep runs comparable across the perf-iteration log in EXPERIMENTS.md.

use std::hint::black_box;
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub samples: usize,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl BenchStats {
    pub fn per_second(&self, items: u64) -> f64 {
        items as f64 / self.median.as_secs_f64()
    }
}

/// Time `f` (re-run until ~`target_time` or `max_samples`); prints a
/// criterion-style line and returns the stats.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchStats {
    bench_with(name, Duration::from_millis(700), 200, &mut f)
}

/// Like [`bench`] but keeps the closure's result out of the optimizer.
pub fn bench_val<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchStats {
    bench_with(name, Duration::from_millis(700), 200, &mut || {
        black_box(f());
    })
}

fn bench_with<F: FnMut()>(name: &str, target: Duration, max_samples: usize, f: &mut F) -> BenchStats {
    // warmup
    for _ in 0..2 {
        f();
    }
    let mut times = Vec::new();
    let t0 = Instant::now();
    while times.len() < max_samples && (t0.elapsed() < target || times.len() < 5) {
        let s = Instant::now();
        f();
        times.push(s.elapsed());
    }
    times.sort();
    let pct = |p: f64| times[((times.len() as f64 * p) as usize).min(times.len() - 1)];
    let stats = BenchStats {
        samples: times.len(),
        median: times[times.len() / 2],
        mean: times.iter().sum::<Duration>() / times.len() as u32,
        p95: pct(0.95),
        p99: pct(0.99),
        min: times[0],
    };
    println!(
        "{name:<52} median {:>10.3?}  mean {:>10.3?}  p95 {:>10.3?}  ({} samples)",
        stats.median, stats.mean, stats.p95, stats.samples
    );
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut n = 0u64;
        let s = bench("noop", || {
            n += 1;
        });
        assert!(s.samples >= 5);
        assert!(n as usize >= s.samples);
        assert!(s.min <= s.median && s.median <= s.p95 && s.p95 <= s.p99);
    }

    #[test]
    fn per_second_positive() {
        let s = bench_val("spin", || std::hint::black_box((0..100).sum::<u64>()));
        assert!(s.per_second(100) > 0.0);
    }
}
