//! Micro-benchmark harness (criterion replacement for the offline image).
//!
//! Each bench target is a plain `harness = false` binary that calls
//! [`bench`] / [`bench_with_result`]: warm up, run timed samples, report
//! median/mean/p95 and derived throughput. Deterministic sample counts
//! keep runs comparable across the perf-iteration log in EXPERIMENTS.md.
//!
//! Bench binaries that track a machine-readable artifact
//! (`BENCH_serving.json`, `BENCH_engine.json`) write it through
//! [`write_artifact`], which merge-appends top-level sections into the
//! existing file instead of clobbering it — so a partial rerun (e.g.
//! `KANSAS_BENCH_SECTIONS=net cargo bench --bench serving_scale`)
//! refreshes just its own sections and the rest of the perf trail
//! survives.

use std::hint::black_box;
use std::time::{Duration, Instant};

use crate::util::json::Value;

#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    pub samples: usize,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
}

impl BenchStats {
    pub fn per_second(&self, items: u64) -> f64 {
        items as f64 / self.median.as_secs_f64()
    }
}

/// Time `f` (re-run until ~`target_time` or `max_samples`); prints a
/// criterion-style line and returns the stats.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchStats {
    bench_with(name, Duration::from_millis(700), 200, &mut f)
}

/// Like [`bench`] but keeps the closure's result out of the optimizer.
pub fn bench_val<T, F: FnMut() -> T>(name: &str, mut f: F) -> BenchStats {
    bench_with(name, Duration::from_millis(700), 200, &mut || {
        black_box(f());
    })
}

fn bench_with<F: FnMut()>(name: &str, target: Duration, max_samples: usize, f: &mut F) -> BenchStats {
    // warmup
    for _ in 0..2 {
        f();
    }
    let mut times = Vec::new();
    let t0 = Instant::now();
    while times.len() < max_samples && (t0.elapsed() < target || times.len() < 5) {
        let s = Instant::now();
        f();
        times.push(s.elapsed());
    }
    times.sort();
    let pct = |p: f64| times[((times.len() as f64 * p) as usize).min(times.len() - 1)];
    let stats = BenchStats {
        samples: times.len(),
        median: times[times.len() / 2],
        mean: times.iter().sum::<Duration>() / times.len() as u32,
        p95: pct(0.95),
        p99: pct(0.99),
        min: times[0],
    };
    println!(
        "{name:<52} median {:>10.3?}  mean {:>10.3?}  p95 {:>10.3?}  ({} samples)",
        stats.median, stats.mean, stats.p95, stats.samples
    );
    stats
}

/// Version stamped as a top-level `schema_version` into every artifact
/// [`write_artifact`] touches, so downstream parsers of the
/// merge-append trail can detect section-layout changes. Bump when a
/// section's row shape changes incompatibly.
pub const SCHEMA_VERSION: u64 = 1;

/// Merge `doc`'s top-level sections over `existing`: sections present
/// in `doc` replace same-named ones, sections only in `existing`
/// survive. A non-object (or absent / unparseable) `existing` is
/// discarded; a non-object `doc` wins outright.
pub fn merge_artifact(existing: Option<Value>, doc: Value) -> Value {
    let fresh = match doc {
        Value::Obj(m) => m,
        other => return other,
    };
    let mut merged = match existing {
        Some(Value::Obj(m)) => m,
        _ => std::collections::BTreeMap::new(),
    };
    for (k, v) in fresh {
        merged.insert(k, v);
    }
    Value::Obj(merged)
}

/// Write a bench artifact, merge-appending `doc`'s top-level sections
/// into whatever valid JSON object is already at `path` (see
/// [`merge_artifact`]) and stamping the current [`SCHEMA_VERSION`]. A
/// missing or corrupt file degrades to a plain write of `doc`.
pub fn write_artifact(path: &str, doc: Value) -> std::io::Result<()> {
    let existing = std::fs::read_to_string(path).ok().and_then(|t| Value::parse(&t).ok());
    let mut merged = merge_artifact(existing, doc);
    if let Value::Obj(m) = &mut merged {
        m.insert("schema_version".to_string(), Value::num(SCHEMA_VERSION as f64));
    }
    std::fs::write(path, merged.render() + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut n = 0u64;
        let s = bench("noop", || {
            n += 1;
        });
        assert!(s.samples >= 5);
        assert!(n as usize >= s.samples);
        assert!(s.min <= s.median && s.median <= s.p95 && s.p95 <= s.p99);
    }

    #[test]
    fn per_second_positive() {
        let s = bench_val("spin", || std::hint::black_box((0..100).sum::<u64>()));
        assert!(s.per_second(100) > 0.0);
    }

    #[test]
    fn merge_artifact_unions_sections_new_wins() {
        let existing = Value::obj([
            ("bench", Value::str("serving_scale")),
            ("closed_loop", Value::arr([Value::num(1.0)])),
            ("fairness", Value::arr([Value::num(2.0)])),
        ]);
        let doc = Value::obj([
            ("bench", Value::str("serving_scale")),
            ("closed_loop", Value::arr([Value::num(9.0)])),
            ("net", Value::arr([Value::num(3.0)])),
        ]);
        let merged = merge_artifact(Some(existing), doc);
        // refreshed section replaced, untouched section survived, new
        // section appended
        assert_eq!(merged.path("closed_loop/0").and_then(Value::as_f64), Some(9.0));
        assert_eq!(merged.path("fairness/0").and_then(Value::as_f64), Some(2.0));
        assert_eq!(merged.path("net/0").and_then(Value::as_f64), Some(3.0));
        assert_eq!(merged.get("bench").and_then(Value::as_str), Some("serving_scale"));
    }

    #[test]
    fn merge_artifact_discards_non_object_existing() {
        let doc = Value::obj([("bench", Value::str("b"))]);
        let merged = merge_artifact(Some(Value::str("corrupt")), doc.clone());
        assert_eq!(merged, doc);
        assert_eq!(merge_artifact(None, doc.clone()), doc);
    }

    #[test]
    fn write_artifact_merges_on_disk() {
        let path = std::env::temp_dir()
            .join(format!("kan_sas_bench_artifact_{}.json", std::process::id()));
        let path = path.to_str().expect("utf-8 temp path").to_string();
        let _ = std::fs::remove_file(&path);

        write_artifact(&path, Value::obj([("a", Value::num(1.0)), ("b", Value::num(2.0))]))
            .expect("first write");
        write_artifact(&path, Value::obj([("b", Value::num(7.0)), ("c", Value::num(3.0))]))
            .expect("merge write");
        let text = std::fs::read_to_string(&path).expect("artifact readable");
        std::fs::remove_file(&path).ok();

        let v = Value::parse(&text).expect("artifact is valid JSON");
        assert_eq!(v.get("a").and_then(Value::as_f64), Some(1.0), "untouched section kept");
        assert_eq!(v.get("b").and_then(Value::as_f64), Some(7.0), "rerun section refreshed");
        assert_eq!(v.get("c").and_then(Value::as_f64), Some(3.0), "new section appended");
        assert_eq!(
            v.get("schema_version").and_then(Value::as_f64),
            Some(SCHEMA_VERSION as f64),
            "every written artifact carries the schema version"
        );
        assert!(text.ends_with('\n'));
    }
}
