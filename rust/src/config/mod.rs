//! Run configuration: accelerator + serving settings, loadable from a
//! JSON file (`--config path.json`) with CLI-friendly defaults.
//!
//! Example:
//! ```json
//! {
//!   "array": {"rows": 16, "cols": 16, "pe": "4:8", "weight_load": "amortized"},
//!   "serve": {"max_batch": 32, "max_wait_ms": 2},
//!   "pool": {"replicas": 4, "queue_cap": 1024, "shed": "reject", "quota": 0.5},
//!   "admin": {"events": [
//!     {"at_ms": 500, "add": "hot:16x32x6", "weight": 2},
//!     {"at_ms": 1000, "set_weight": "hot", "weight": 6},
//!     {"at_ms": 1500, "remove": "hot", "mode": "serve"}
//!   ]},
//!   "batch_size": 32
//! }
//! ```
//!
//! `pool.quota` enables weighted per-tenant admission quotas (`true` =
//! reserve half the queue, or a fraction in `[0, 1]`). The `admin`
//! stanza scripts registry churn for `kansas serve --scenario churn`:
//! each event hot-adds (`add` takes a synthetic `name:DIMxDIM..` spec),
//! re-weights, or removes a tenant on the live gateway at `at_ms`.
//!
//! A `telemetry` stanza tunes the observability spine:
//! ```json
//! {
//!   "telemetry": {"enabled": true, "ring_capacity": 8192,
//!                 "window_ms": 1000, "flight_capacity": 64,
//!                 "trace_sample": 0, "exact_samples": false,
//!                 "flight_every_s": 5}
//! }
//! ```
//!
//! A `net` stanza tunes the network front door (`kansas serve
//! --listen` / `kansas load --connect`):
//! ```json
//! {
//!   "net": {"listen": "127.0.0.1:7171", "max_frame": 1048576,
//!           "max_conns": 1024, "nodelay": true}
//! }
//! ```
//!
//! An `autoscale` stanza makes the worker fleet elastic between `min`
//! and `max` replicas, scaling against a p95 queueing-delay SLO (the
//! CLI `kansas serve --autoscale min:max --slo-p95-us N` flags layer
//! on top):
//! ```json
//! {
//!   "autoscale": {"min": 1, "max": 8, "slo_p95_us": 10000,
//!                 "max_shed_rate": 0.01, "calm_windows": 3,
//!                 "interval_ms": 250, "pin_cores": false}
//! }
//! ```

use std::path::Path;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::arch::{ArrayConfig, PeKind, WeightLoad};
use crate::coordinator::{
    AutoscaleConfig, BatchPolicy, Dispatch, DrainMode, NetConfig, PoolConfig, QuotaPolicy,
    ShedPolicy, TelemetryConfig,
};
use crate::loadgen::{ChurnAction, ChurnEvent};
use crate::util::json::Value;

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub array: ArrayConfig,
    pub policy: BatchPolicy,
    /// Default workload batch rows for simulations.
    pub batch_size: usize,
    /// Serving-pool replicas (worker threads, each an Arc-shared engine).
    pub replicas: usize,
    /// Admission queue capacity.
    pub queue_cap: usize,
    /// Load-shedding policy when the admission queue is full.
    pub shed: ShedPolicy,
    /// Worker dispatch policy (weighted fair + stealing, or the fixed
    /// baseline).
    pub dispatch: Dispatch,
    /// Per-tenant admission quotas over the shared queue.
    pub quota: QuotaPolicy,
    /// Scripted registry churn (the `admin` stanza), applied by
    /// `kansas serve --scenario churn`.
    pub admin_events: Vec<ChurnEvent>,
    /// Telemetry spine settings (the `telemetry` stanza; CLI
    /// `--telemetry`/`--stats-every`/`--trace-sample` flags layer on
    /// top).
    pub telemetry: TelemetryConfig,
    /// Network front door settings (the `net` stanza; `kansas serve
    /// --listen` / `kansas load --connect` use them on their ends).
    pub net: NetConfig,
    /// SLO-driven worker autoscaling (the `autoscale` stanza; `None`
    /// keeps a fixed fleet of `replicas` workers).
    pub autoscale: Option<AutoscaleConfig>,
}

impl Default for RunConfig {
    fn default() -> Self {
        let pool = PoolConfig::default();
        Self {
            array: ArrayConfig::kan_sas(16, 16, 4, 8),
            policy: BatchPolicy::default(),
            batch_size: crate::workloads::DEFAULT_BS,
            replicas: pool.replicas,
            queue_cap: pool.queue_cap,
            shed: pool.shed,
            dispatch: pool.dispatch,
            quota: pool.quota,
            admin_events: Vec::new(),
            telemetry: pool.telemetry,
            net: NetConfig::default(),
            autoscale: None,
        }
    }
}

/// Parse a shed policy: "reject", "drop-oldest", or "block".
pub fn parse_shed(s: &str) -> Result<ShedPolicy> {
    match s {
        "reject" | "reject-new" => Ok(ShedPolicy::RejectNew),
        "drop-oldest" | "drop_oldest" => Ok(ShedPolicy::DropOldest),
        "block" => Ok(ShedPolicy::Block),
        other => bail!("shed policy '{other}' (want reject|drop-oldest|block)"),
    }
}

/// Parse a dispatch policy: "fair" (weighted DRR + stealing, default)
/// or "fixed" (the pre-fair baseline).
pub fn parse_dispatch(s: &str) -> Result<Dispatch> {
    match s {
        "fair" | "fair-steal" | "fair_steal" => Ok(Dispatch::FairSteal),
        "fixed" => Ok(Dispatch::Fixed),
        other => bail!("dispatch policy '{other}' (want fair|fixed)"),
    }
}

/// Parse a quota setting: `true`/`false`, or a reserve fraction in
/// `[0, 1]` (0 disables).
pub fn parse_quota(v: &Value) -> Result<QuotaPolicy> {
    if let Some(b) = v.as_bool() {
        return Ok(if b { QuotaPolicy::weighted() } else { QuotaPolicy::None });
    }
    match v.as_f64() {
        Some(f) if f == 0.0 => Ok(QuotaPolicy::None),
        Some(f) if (0.0..=1.0).contains(&f) => Ok(QuotaPolicy::Weighted { reserve: f }),
        _ => bail!("pool.quota must be true/false or a reserve fraction in [0, 1]"),
    }
}

/// Parse a synthetic model spec `name:IN x HIDDEN x .. x OUT` (dims
/// separated by `x`), as used by `--models` and the admin stanza's
/// `add` events.
pub fn parse_synth_spec(spec: &str) -> Result<(String, Vec<usize>)> {
    let (name, dims) = spec
        .split_once(':')
        .with_context(|| format!("synthetic spec '{spec}' needs name:DIMxDIM form"))?;
    let dims: Vec<usize> = dims
        .split('x')
        .map(|d| d.trim().parse().with_context(|| format!("bad dim '{d}' in '{spec}'")))
        .collect::<Result<_>>()?;
    if dims.len() < 2 {
        bail!("synthetic spec '{spec}' needs at least IN x OUT dims");
    }
    Ok((name.to_string(), dims))
}

/// Parse one `admin.events` entry into a [`ChurnEvent`].
fn parse_admin_event(e: &Value) -> Result<ChurnEvent> {
    let at_ms = e
        .get("at_ms")
        .and_then(Value::as_f64)
        .context("admin event needs an at_ms offset")?;
    if !at_ms.is_finite() || at_ms < 0.0 {
        bail!("admin event at_ms must be >= 0");
    }
    let at = Duration::from_micros((at_ms * 1000.0) as u64);
    let action = if let Some(spec) = e.get("add").and_then(Value::as_str) {
        let (name, dims) = parse_synth_spec(spec)?;
        let weight = e.get("weight").and_then(Value::as_usize).unwrap_or(1) as u32;
        if weight == 0 {
            bail!("admin add '{name}' needs weight >= 1");
        }
        let mix_weight = e.get("mix").and_then(Value::as_f64).unwrap_or(1.0);
        if !mix_weight.is_finite() || mix_weight <= 0.0 {
            bail!("admin add '{name}' needs a positive mix weight");
        }
        ChurnAction::Add { name, dims, weight, mix_weight }
    } else if let Some(name) = e.get("set_weight").and_then(Value::as_str) {
        let weight = e
            .get("weight")
            .and_then(Value::as_usize)
            .context("admin set_weight needs a weight")? as u32;
        if weight == 0 {
            bail!("admin set_weight '{name}' needs weight >= 1");
        }
        ChurnAction::SetWeight { name: name.to_string(), weight }
    } else if let Some(name) = e.get("remove").and_then(Value::as_str) {
        let mode = match e.get("mode").and_then(Value::as_str) {
            Some("serve") | None => DrainMode::Serve,
            Some("shed") => DrainMode::Shed,
            Some(other) => bail!("admin remove mode '{other}' (want serve|shed)"),
        };
        ChurnAction::Remove { name: name.to_string(), mode }
    } else {
        bail!("admin event needs one of add/set_weight/remove");
    };
    Ok(ChurnEvent { at, action })
}

/// Parse a PE spec: "scalar", "1:1", or "N:M".
pub fn parse_pe(s: &str) -> Result<PeKind> {
    if s.eq_ignore_ascii_case("scalar") || s == "1:1" {
        return Ok(PeKind::Scalar);
    }
    let (n, m) = s.split_once(':').with_context(|| format!("bad PE spec '{s}'"))?;
    let n: usize = n.trim().parse().with_context(|| format!("bad N in '{s}'"))?;
    let m: usize = m.trim().parse().with_context(|| format!("bad M in '{s}'"))?;
    if n < 1 || m < n {
        bail!("PE spec '{s}' needs M >= N >= 1");
    }
    Ok(PeKind::Vector { n, m })
}

impl RunConfig {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Value::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = Self::default();

        if let Some(arr) = v.get("array") {
            let rows = arr.get("rows").and_then(Value::as_usize).unwrap_or(cfg.array.rows);
            let cols = arr.get("cols").and_then(Value::as_usize).unwrap_or(cfg.array.cols);
            let pe = match arr.get("pe").and_then(Value::as_str) {
                Some(s) => parse_pe(s)?,
                None => cfg.array.pe,
            };
            let weight_load = match arr.get("weight_load").and_then(Value::as_str) {
                Some("amortized") | None => WeightLoad::Amortized,
                Some("counted") => WeightLoad::Counted,
                Some(other) => bail!("weight_load '{other}' (want amortized|counted)"),
            };
            if rows == 0 || cols == 0 {
                bail!("array dims must be positive");
            }
            cfg.array = ArrayConfig { rows, cols, pe, weight_load };
        }
        if let Some(s) = v.get("serve") {
            if let Some(b) = s.get("max_batch").and_then(Value::as_usize) {
                if b == 0 {
                    bail!("max_batch must be positive");
                }
                cfg.policy.max_batch = b;
            }
            if let Some(ms) = s.get("max_wait_ms").and_then(Value::as_f64) {
                cfg.policy.max_wait = Duration::from_micros((ms * 1000.0) as u64);
            }
        }
        if let Some(p) = v.get("pool") {
            if let Some(r) = p.get("replicas").and_then(Value::as_usize) {
                if r == 0 {
                    bail!("replicas must be positive");
                }
                cfg.replicas = r;
            }
            if let Some(q) = p.get("queue_cap").and_then(Value::as_usize) {
                if q == 0 {
                    bail!("queue_cap must be positive");
                }
                cfg.queue_cap = q;
            }
            if let Some(s) = p.get("shed").and_then(Value::as_str) {
                cfg.shed = parse_shed(s)?;
            }
            if let Some(s) = p.get("dispatch").and_then(Value::as_str) {
                cfg.dispatch = parse_dispatch(s)?;
            }
            if let Some(q) = p.get("quota") {
                cfg.quota = parse_quota(q)?;
            }
        }
        if let Some(t) = v.get("telemetry") {
            if let Some(b) = t.get("enabled").and_then(Value::as_bool) {
                cfg.telemetry.enabled = b;
            }
            if let Some(c) = t.get("ring_capacity").and_then(Value::as_usize) {
                if c < 2 {
                    bail!("telemetry.ring_capacity must be >= 2");
                }
                cfg.telemetry.ring_capacity = c;
            }
            if let Some(ms) = t.get("window_ms").and_then(Value::as_f64) {
                if !ms.is_finite() || ms <= 0.0 {
                    bail!("telemetry.window_ms must be positive");
                }
                cfg.telemetry.window = Duration::from_micros((ms * 1000.0) as u64);
            }
            if let Some(c) = t.get("flight_capacity").and_then(Value::as_usize) {
                if c == 0 {
                    bail!("telemetry.flight_capacity must be positive");
                }
                cfg.telemetry.flight_capacity = c;
            }
            if let Some(n) = t.get("trace_sample").and_then(Value::as_usize) {
                cfg.telemetry.trace_sample = n as u64;
            }
            if let Some(b) = t.get("exact_samples").and_then(Value::as_bool) {
                cfg.telemetry.exact_samples = b;
            }
            if let Some(s) = t.get("flight_every_s").and_then(Value::as_f64) {
                if !s.is_finite() || s < 0.0 {
                    bail!("telemetry.flight_every_s must be >= 0 (0 disables periodic dumps)");
                }
                cfg.telemetry.flight_every = Duration::from_micros((s * 1e6) as u64);
            }
        }
        if let Some(n) = v.get("net") {
            if let Some(l) = n.get("listen").and_then(Value::as_str) {
                cfg.net.listen = Some(l.to_string());
            }
            if let Some(m) = n.get("max_frame").and_then(Value::as_usize) {
                if m < crate::coordinator::net::HEADER_LEN {
                    bail!("net.max_frame must be at least one frame header");
                }
                cfg.net.max_frame = m;
            }
            if let Some(c) = n.get("max_conns").and_then(Value::as_usize) {
                if c == 0 {
                    bail!("net.max_conns must be positive");
                }
                cfg.net.max_conns = c;
            }
            if let Some(b) = n.get("nodelay").and_then(Value::as_bool) {
                cfg.net.nodelay = b;
            }
        }
        if let Some(a) = v.get("autoscale") {
            let mut auto = AutoscaleConfig::default();
            if let Some(m) = a.get("min").and_then(Value::as_usize) {
                auto.min_workers = m;
            }
            if let Some(m) = a.get("max").and_then(Value::as_usize) {
                auto.max_workers = m;
            }
            if auto.min_workers == 0 || auto.max_workers < auto.min_workers {
                bail!("autoscale needs 1 <= min <= max");
            }
            if let Some(us) = a.get("slo_p95_us").and_then(Value::as_usize) {
                if us == 0 {
                    bail!("autoscale.slo_p95_us must be positive");
                }
                auto.slo_p95_us = us as u64;
            }
            if let Some(r) = a.get("max_shed_rate").and_then(Value::as_f64) {
                if !r.is_finite() || !(0.0..=1.0).contains(&r) {
                    bail!("autoscale.max_shed_rate must be in [0, 1]");
                }
                auto.max_shed_rate = r;
            }
            if let Some(k) = a.get("calm_windows").and_then(Value::as_usize) {
                if k == 0 {
                    bail!("autoscale.calm_windows must be positive");
                }
                auto.calm_windows = k;
            }
            if let Some(ms) = a.get("interval_ms").and_then(Value::as_f64) {
                if !ms.is_finite() || ms <= 0.0 {
                    bail!("autoscale.interval_ms must be positive");
                }
                auto.interval = Duration::from_micros((ms * 1000.0) as u64);
            }
            if let Some(b) = a.get("pin_cores").and_then(Value::as_bool) {
                auto.pin_cores = b;
            }
            cfg.autoscale = Some(auto);
        }
        if let Some(a) = v.get("admin") {
            let events = a
                .get("events")
                .and_then(Value::as_arr)
                .context("admin stanza needs an events array")?;
            cfg.admin_events = events.iter().map(parse_admin_event).collect::<Result<_>>()?;
        }
        if let Some(b) = v.get("batch_size").and_then(Value::as_usize) {
            cfg.batch_size = b;
        }
        Ok(cfg)
    }

    /// The serving-pool configuration this run config describes.
    pub fn to_pool_config(&self) -> PoolConfig {
        PoolConfig {
            replicas: self.replicas,
            queue_cap: self.queue_cap,
            shed: self.shed,
            policy: self.policy,
            sim_array: self.array,
            dispatch: self.dispatch,
            quota: self.quota,
            telemetry: self.telemetry,
            autoscale: self.autoscale,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn parse_pe_specs() {
        assert_eq!(parse_pe("scalar").unwrap(), PeKind::Scalar);
        assert_eq!(parse_pe("1:1").unwrap(), PeKind::Scalar);
        assert_eq!(parse_pe("4:8").unwrap(), PeKind::Vector { n: 4, m: 8 });
        assert!(parse_pe("8:4").is_err());
        assert!(parse_pe("x").is_err());
        assert!(parse_pe("0:3").is_err());
    }

    #[test]
    fn load_full_config() {
        let mut f = tempfile("cfg1.json");
        write!(
            f,
            r#"{{"array": {{"rows": 8, "cols": 4, "pe": "2:6", "weight_load": "counted"}},
                "serve": {{"max_batch": 64, "max_wait_ms": 5}},
                "batch_size": 16}}"#
        )
        .unwrap();
        let cfg = RunConfig::load(&path("cfg1.json")).unwrap();
        assert_eq!(cfg.array.rows, 8);
        assert_eq!(cfg.array.cols, 4);
        assert_eq!(cfg.array.pe, PeKind::Vector { n: 2, m: 6 });
        assert_eq!(cfg.array.weight_load, WeightLoad::Counted);
        assert_eq!(cfg.policy.max_batch, 64);
        assert_eq!(cfg.policy.max_wait, Duration::from_millis(5));
        assert_eq!(cfg.batch_size, 16);
    }

    #[test]
    fn defaults_fill_missing() {
        let mut f = tempfile("cfg2.json");
        write!(f, "{{}}").unwrap();
        let cfg = RunConfig::load(&path("cfg2.json")).unwrap();
        assert_eq!(cfg.array.rows, 16);
        assert_eq!(cfg.batch_size, crate::workloads::DEFAULT_BS);
    }

    #[test]
    fn parse_shed_policies() {
        assert_eq!(parse_shed("reject").unwrap(), ShedPolicy::RejectNew);
        assert_eq!(parse_shed("drop-oldest").unwrap(), ShedPolicy::DropOldest);
        assert_eq!(parse_shed("block").unwrap(), ShedPolicy::Block);
        assert!(parse_shed("yolo").is_err());
    }

    #[test]
    fn load_pool_section() {
        let mut f = tempfile("cfg5.json");
        write!(
            f,
            r#"{{"pool": {{"replicas": 3, "queue_cap": 77, "shed": "drop-oldest", "dispatch": "fixed"}}}}"#
        )
        .unwrap();
        let cfg = RunConfig::load(&path("cfg5.json")).unwrap();
        assert_eq!(cfg.replicas, 3);
        assert_eq!(cfg.queue_cap, 77);
        assert_eq!(cfg.shed, ShedPolicy::DropOldest);
        assert_eq!(cfg.dispatch, Dispatch::Fixed);
        let pc = cfg.to_pool_config();
        assert_eq!(pc.replicas, 3);
        assert_eq!(pc.queue_cap, 77);
        assert_eq!(pc.dispatch, Dispatch::Fixed);
        let mut f = tempfile("cfg6.json");
        write!(f, r#"{{"pool": {{"replicas": 0}}}}"#).unwrap();
        assert!(RunConfig::load(&path("cfg6.json")).is_err());
    }

    #[test]
    fn parse_dispatch_policies() {
        assert_eq!(parse_dispatch("fair").unwrap(), Dispatch::FairSteal);
        assert_eq!(parse_dispatch("fair-steal").unwrap(), Dispatch::FairSteal);
        assert_eq!(parse_dispatch("fixed").unwrap(), Dispatch::Fixed);
        assert!(parse_dispatch("random").is_err());
        assert_eq!(RunConfig::default().dispatch, Dispatch::FairSteal);
    }

    #[test]
    fn load_quota_and_admin_stanzas() {
        let mut f = tempfile("cfg7.json");
        write!(
            f,
            r#"{{"pool": {{"quota": 0.4}},
                "admin": {{"events": [
                  {{"at_ms": 250, "add": "hot:16x32x6", "weight": 2, "mix": 0.5}},
                  {{"at_ms": 500, "set_weight": "hot", "weight": 6}},
                  {{"at_ms": 750, "remove": "hot", "mode": "shed"}}
                ]}}}}"#
        )
        .unwrap();
        let cfg = RunConfig::load(&path("cfg7.json")).unwrap();
        assert_eq!(cfg.quota, QuotaPolicy::Weighted { reserve: 0.4 });
        assert_eq!(cfg.to_pool_config().quota, cfg.quota);
        assert_eq!(cfg.admin_events.len(), 3);
        assert_eq!(cfg.admin_events[0].at, Duration::from_millis(250));
        match &cfg.admin_events[0].action {
            ChurnAction::Add { name, dims, weight, mix_weight } => {
                assert_eq!(name, "hot");
                assert_eq!(dims, &[16, 32, 6]);
                assert_eq!(*weight, 2);
                assert!((mix_weight - 0.5).abs() < 1e-12);
            }
            other => panic!("expected Add, got {other:?}"),
        }
        match &cfg.admin_events[1].action {
            ChurnAction::SetWeight { name, weight } => {
                assert_eq!((name.as_str(), *weight), ("hot", 6));
            }
            other => panic!("expected SetWeight, got {other:?}"),
        }
        match &cfg.admin_events[2].action {
            ChurnAction::Remove { name, mode } => {
                assert_eq!((name.as_str(), *mode), ("hot", DrainMode::Shed));
            }
            other => panic!("expected Remove, got {other:?}"),
        }
        // booleans toggle the default reserve
        let mut f = tempfile("cfg8.json");
        write!(f, r#"{{"pool": {{"quota": true}}}}"#).unwrap();
        let cfg = RunConfig::load(&path("cfg8.json")).unwrap();
        assert_eq!(cfg.quota, QuotaPolicy::weighted());
        // defaults: quota off, no admin script
        assert_eq!(RunConfig::default().quota, QuotaPolicy::None);
        assert!(RunConfig::default().admin_events.is_empty());
        // bad values rejected
        let mut f = tempfile("cfg9.json");
        write!(f, r#"{{"pool": {{"quota": 1.5}}}}"#).unwrap();
        assert!(RunConfig::load(&path("cfg9.json")).is_err());
        let mut f = tempfile("cfg10.json");
        write!(f, r#"{{"admin": {{"events": [{{"at_ms": 10}}]}}}}"#).unwrap();
        assert!(RunConfig::load(&path("cfg10.json")).is_err());
    }

    #[test]
    fn load_telemetry_flight_interval() {
        let mut f = tempfile("cfg11.json");
        write!(f, r#"{{"telemetry": {{"flight_every_s": 2.5, "trace_sample": 8}}}}"#).unwrap();
        let cfg = RunConfig::load(&path("cfg11.json")).unwrap();
        assert_eq!(cfg.telemetry.flight_every, Duration::from_micros(2_500_000));
        assert_eq!(cfg.telemetry.trace_sample, 8);
        // 0 disables periodic dumps; negatives are rejected
        let mut f = tempfile("cfg12.json");
        write!(f, r#"{{"telemetry": {{"flight_every_s": 0}}}}"#).unwrap();
        let cfg = RunConfig::load(&path("cfg12.json")).unwrap();
        assert_eq!(cfg.telemetry.flight_every, Duration::ZERO);
        let mut f = tempfile("cfg13.json");
        write!(f, r#"{{"telemetry": {{"flight_every_s": -1}}}}"#).unwrap();
        assert!(RunConfig::load(&path("cfg13.json")).is_err());
        // default: periodic dumps every 5s
        assert_eq!(RunConfig::default().telemetry.flight_every, Duration::from_secs(5));
    }

    #[test]
    fn load_net_section() {
        let mut f = tempfile("cfg14.json");
        write!(
            f,
            r#"{{"net": {{"listen": "127.0.0.1:7171", "max_frame": 65536,
                          "max_conns": 8, "nodelay": false}}}}"#
        )
        .unwrap();
        let cfg = RunConfig::load(&path("cfg14.json")).unwrap();
        assert_eq!(cfg.net.listen.as_deref(), Some("127.0.0.1:7171"));
        assert_eq!(cfg.net.max_frame, 65536);
        assert_eq!(cfg.net.max_conns, 8);
        assert!(!cfg.net.nodelay);
        // defaults: no listen address, 1 MiB frames, nodelay on
        let d = RunConfig::default();
        assert!(d.net.listen.is_none());
        assert_eq!(d.net.max_frame, 1 << 20);
        assert!(d.net.nodelay);
        // bad values rejected
        let mut f = tempfile("cfg15.json");
        write!(f, r#"{{"net": {{"max_frame": 4}}}}"#).unwrap();
        assert!(RunConfig::load(&path("cfg15.json")).is_err());
        let mut f = tempfile("cfg16.json");
        write!(f, r#"{{"net": {{"max_conns": 0}}}}"#).unwrap();
        assert!(RunConfig::load(&path("cfg16.json")).is_err());
    }

    #[test]
    fn load_autoscale_section() {
        let mut f = tempfile("cfg17.json");
        write!(
            f,
            r#"{{"autoscale": {{"min": 2, "max": 6, "slo_p95_us": 5000,
                               "max_shed_rate": 0.02, "calm_windows": 4,
                               "interval_ms": 100, "pin_cores": true}}}}"#
        )
        .unwrap();
        let cfg = RunConfig::load(&path("cfg17.json")).unwrap();
        let auto = cfg.autoscale.expect("autoscale stanza parsed");
        assert_eq!((auto.min_workers, auto.max_workers), (2, 6));
        assert_eq!(auto.slo_p95_us, 5000);
        assert!((auto.max_shed_rate - 0.02).abs() < 1e-12);
        assert_eq!(auto.calm_windows, 4);
        assert_eq!(auto.interval, Duration::from_millis(100));
        assert!(auto.pin_cores);
        assert_eq!(cfg.to_pool_config().autoscale.map(|a| a.max_workers), Some(6));
        // defaults: fixed fleet, no autoscaler
        assert!(RunConfig::default().autoscale.is_none());
        // inverted bounds rejected
        let mut f = tempfile("cfg18.json");
        write!(f, r#"{{"autoscale": {{"min": 4, "max": 2}}}}"#).unwrap();
        assert!(RunConfig::load(&path("cfg18.json")).is_err());
    }

    #[test]
    fn parse_synth_specs() {
        let (name, dims) = parse_synth_spec("mnist:64x32x10").unwrap();
        assert_eq!((name.as_str(), dims.as_slice()), ("mnist", &[64usize, 32, 10][..]));
        assert!(parse_synth_spec("noname").is_err());
        assert!(parse_synth_spec("m:64").is_err());
        assert!(parse_synth_spec("m:64xbogus").is_err());
    }

    #[test]
    fn rejects_bad_values() {
        let mut f = tempfile("cfg3.json");
        write!(f, r#"{{"array": {{"rows": 0}}}}"#).unwrap();
        assert!(RunConfig::load(&path("cfg3.json")).is_err());
        let mut f = tempfile("cfg4.json");
        write!(f, r#"{{"array": {{"weight_load": "magic"}}}}"#).unwrap();
        assert!(RunConfig::load(&path("cfg4.json")).is_err());
    }

    fn path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("kansas-test-{name}"))
    }

    fn tempfile(name: &str) -> std::fs::File {
        std::fs::File::create(path(name)).unwrap()
    }
}
