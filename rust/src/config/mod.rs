//! Run configuration: accelerator + serving settings, loadable from a
//! JSON file (`--config path.json`) with CLI-friendly defaults.
//!
//! Example:
//! ```json
//! {
//!   "array": {"rows": 16, "cols": 16, "pe": "4:8", "weight_load": "amortized"},
//!   "serve": {"max_batch": 32, "max_wait_ms": 2},
//!   "pool": {"replicas": 4, "queue_cap": 1024, "shed": "reject"},
//!   "batch_size": 32
//! }
//! ```

use std::path::Path;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::arch::{ArrayConfig, PeKind, WeightLoad};
use crate::coordinator::{BatchPolicy, Dispatch, PoolConfig, ShedPolicy};
use crate::util::json::Value;

#[derive(Clone, Debug)]
pub struct RunConfig {
    pub array: ArrayConfig,
    pub policy: BatchPolicy,
    /// Default workload batch rows for simulations.
    pub batch_size: usize,
    /// Serving-pool replicas (worker threads, each an Arc-shared engine).
    pub replicas: usize,
    /// Admission queue capacity.
    pub queue_cap: usize,
    /// Load-shedding policy when the admission queue is full.
    pub shed: ShedPolicy,
    /// Worker dispatch policy (weighted fair + stealing, or the fixed
    /// baseline).
    pub dispatch: Dispatch,
}

impl Default for RunConfig {
    fn default() -> Self {
        let pool = PoolConfig::default();
        Self {
            array: ArrayConfig::kan_sas(16, 16, 4, 8),
            policy: BatchPolicy::default(),
            batch_size: crate::workloads::DEFAULT_BS,
            replicas: pool.replicas,
            queue_cap: pool.queue_cap,
            shed: pool.shed,
            dispatch: pool.dispatch,
        }
    }
}

/// Parse a shed policy: "reject", "drop-oldest", or "block".
pub fn parse_shed(s: &str) -> Result<ShedPolicy> {
    match s {
        "reject" | "reject-new" => Ok(ShedPolicy::RejectNew),
        "drop-oldest" | "drop_oldest" => Ok(ShedPolicy::DropOldest),
        "block" => Ok(ShedPolicy::Block),
        other => bail!("shed policy '{other}' (want reject|drop-oldest|block)"),
    }
}

/// Parse a dispatch policy: "fair" (weighted DRR + stealing, default)
/// or "fixed" (the pre-fair baseline).
pub fn parse_dispatch(s: &str) -> Result<Dispatch> {
    match s {
        "fair" | "fair-steal" | "fair_steal" => Ok(Dispatch::FairSteal),
        "fixed" => Ok(Dispatch::Fixed),
        other => bail!("dispatch policy '{other}' (want fair|fixed)"),
    }
}

/// Parse a PE spec: "scalar", "1:1", or "N:M".
pub fn parse_pe(s: &str) -> Result<PeKind> {
    if s.eq_ignore_ascii_case("scalar") || s == "1:1" {
        return Ok(PeKind::Scalar);
    }
    let (n, m) = s.split_once(':').with_context(|| format!("bad PE spec '{s}'"))?;
    let n: usize = n.trim().parse().with_context(|| format!("bad N in '{s}'"))?;
    let m: usize = m.trim().parse().with_context(|| format!("bad M in '{s}'"))?;
    if n < 1 || m < n {
        bail!("PE spec '{s}' needs M >= N >= 1");
    }
    Ok(PeKind::Vector { n, m })
}

impl RunConfig {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Value::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = Self::default();

        if let Some(arr) = v.get("array") {
            let rows = arr.get("rows").and_then(Value::as_usize).unwrap_or(cfg.array.rows);
            let cols = arr.get("cols").and_then(Value::as_usize).unwrap_or(cfg.array.cols);
            let pe = match arr.get("pe").and_then(Value::as_str) {
                Some(s) => parse_pe(s)?,
                None => cfg.array.pe,
            };
            let weight_load = match arr.get("weight_load").and_then(Value::as_str) {
                Some("amortized") | None => WeightLoad::Amortized,
                Some("counted") => WeightLoad::Counted,
                Some(other) => bail!("weight_load '{other}' (want amortized|counted)"),
            };
            if rows == 0 || cols == 0 {
                bail!("array dims must be positive");
            }
            cfg.array = ArrayConfig { rows, cols, pe, weight_load };
        }
        if let Some(s) = v.get("serve") {
            if let Some(b) = s.get("max_batch").and_then(Value::as_usize) {
                if b == 0 {
                    bail!("max_batch must be positive");
                }
                cfg.policy.max_batch = b;
            }
            if let Some(ms) = s.get("max_wait_ms").and_then(Value::as_f64) {
                cfg.policy.max_wait = Duration::from_micros((ms * 1000.0) as u64);
            }
        }
        if let Some(p) = v.get("pool") {
            if let Some(r) = p.get("replicas").and_then(Value::as_usize) {
                if r == 0 {
                    bail!("replicas must be positive");
                }
                cfg.replicas = r;
            }
            if let Some(q) = p.get("queue_cap").and_then(Value::as_usize) {
                if q == 0 {
                    bail!("queue_cap must be positive");
                }
                cfg.queue_cap = q;
            }
            if let Some(s) = p.get("shed").and_then(Value::as_str) {
                cfg.shed = parse_shed(s)?;
            }
            if let Some(s) = p.get("dispatch").and_then(Value::as_str) {
                cfg.dispatch = parse_dispatch(s)?;
            }
        }
        if let Some(b) = v.get("batch_size").and_then(Value::as_usize) {
            cfg.batch_size = b;
        }
        Ok(cfg)
    }

    /// The serving-pool configuration this run config describes.
    pub fn to_pool_config(&self) -> PoolConfig {
        PoolConfig {
            replicas: self.replicas,
            queue_cap: self.queue_cap,
            shed: self.shed,
            policy: self.policy,
            sim_array: self.array,
            dispatch: self.dispatch,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    #[test]
    fn parse_pe_specs() {
        assert_eq!(parse_pe("scalar").unwrap(), PeKind::Scalar);
        assert_eq!(parse_pe("1:1").unwrap(), PeKind::Scalar);
        assert_eq!(parse_pe("4:8").unwrap(), PeKind::Vector { n: 4, m: 8 });
        assert!(parse_pe("8:4").is_err());
        assert!(parse_pe("x").is_err());
        assert!(parse_pe("0:3").is_err());
    }

    #[test]
    fn load_full_config() {
        let mut f = tempfile("cfg1.json");
        write!(
            f,
            r#"{{"array": {{"rows": 8, "cols": 4, "pe": "2:6", "weight_load": "counted"}},
                "serve": {{"max_batch": 64, "max_wait_ms": 5}},
                "batch_size": 16}}"#
        )
        .unwrap();
        let cfg = RunConfig::load(&path("cfg1.json")).unwrap();
        assert_eq!(cfg.array.rows, 8);
        assert_eq!(cfg.array.cols, 4);
        assert_eq!(cfg.array.pe, PeKind::Vector { n: 2, m: 6 });
        assert_eq!(cfg.array.weight_load, WeightLoad::Counted);
        assert_eq!(cfg.policy.max_batch, 64);
        assert_eq!(cfg.policy.max_wait, Duration::from_millis(5));
        assert_eq!(cfg.batch_size, 16);
    }

    #[test]
    fn defaults_fill_missing() {
        let mut f = tempfile("cfg2.json");
        write!(f, "{{}}").unwrap();
        let cfg = RunConfig::load(&path("cfg2.json")).unwrap();
        assert_eq!(cfg.array.rows, 16);
        assert_eq!(cfg.batch_size, crate::workloads::DEFAULT_BS);
    }

    #[test]
    fn parse_shed_policies() {
        assert_eq!(parse_shed("reject").unwrap(), ShedPolicy::RejectNew);
        assert_eq!(parse_shed("drop-oldest").unwrap(), ShedPolicy::DropOldest);
        assert_eq!(parse_shed("block").unwrap(), ShedPolicy::Block);
        assert!(parse_shed("yolo").is_err());
    }

    #[test]
    fn load_pool_section() {
        let mut f = tempfile("cfg5.json");
        write!(
            f,
            r#"{{"pool": {{"replicas": 3, "queue_cap": 77, "shed": "drop-oldest", "dispatch": "fixed"}}}}"#
        )
        .unwrap();
        let cfg = RunConfig::load(&path("cfg5.json")).unwrap();
        assert_eq!(cfg.replicas, 3);
        assert_eq!(cfg.queue_cap, 77);
        assert_eq!(cfg.shed, ShedPolicy::DropOldest);
        assert_eq!(cfg.dispatch, Dispatch::Fixed);
        let pc = cfg.to_pool_config();
        assert_eq!(pc.replicas, 3);
        assert_eq!(pc.queue_cap, 77);
        assert_eq!(pc.dispatch, Dispatch::Fixed);
        let mut f = tempfile("cfg6.json");
        write!(f, r#"{{"pool": {{"replicas": 0}}}}"#).unwrap();
        assert!(RunConfig::load(&path("cfg6.json")).is_err());
    }

    #[test]
    fn parse_dispatch_policies() {
        assert_eq!(parse_dispatch("fair").unwrap(), Dispatch::FairSteal);
        assert_eq!(parse_dispatch("fair-steal").unwrap(), Dispatch::FairSteal);
        assert_eq!(parse_dispatch("fixed").unwrap(), Dispatch::Fixed);
        assert!(parse_dispatch("random").is_err());
        assert_eq!(RunConfig::default().dispatch, Dispatch::FairSteal);
    }

    #[test]
    fn rejects_bad_values() {
        let mut f = tempfile("cfg3.json");
        write!(f, r#"{{"array": {{"rows": 0}}}}"#).unwrap();
        assert!(RunConfig::load(&path("cfg3.json")).is_err());
        let mut f = tempfile("cfg4.json");
        write!(f, r#"{{"array": {{"weight_load": "magic"}}}}"#).unwrap();
        assert!(RunConfig::load(&path("cfg4.json")).is_err());
    }

    fn path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("kansas-test-{name}"))
    }

    fn tempfile(name: &str) -> std::fs::File {
        std::fs::File::create(path(name)).unwrap()
    }
}
