//! ASCII scatter/line plots for the figure benches (Figs. 7a/7b style:
//! two series over a shared x axis).

pub struct AsciiPlot {
    width: usize,
    height: usize,
    title: String,
    x_label: String,
    y_label: String,
    series: Vec<(String, char, Vec<(f64, f64)>)>,
    log_x: bool,
    log_y: bool,
}

impl AsciiPlot {
    pub fn new(title: &str, x_label: &str, y_label: &str) -> Self {
        Self {
            width: 72,
            height: 20,
            title: title.to_string(),
            x_label: x_label.to_string(),
            y_label: y_label.to_string(),
            series: Vec::new(),
            log_x: false,
            log_y: false,
        }
    }

    pub fn log_axes(mut self, x: bool, y: bool) -> Self {
        self.log_x = x;
        self.log_y = y;
        self
    }

    pub fn series(mut self, name: &str, marker: char, pts: Vec<(f64, f64)>) -> Self {
        self.series.push((name.to_string(), marker, pts));
        self
    }

    fn tx(&self, x: f64) -> f64 {
        if self.log_x { x.max(1e-12).log10() } else { x }
    }

    fn ty(&self, y: f64) -> f64 {
        if self.log_y { y.max(1e-12).log10() } else { y }
    }

    pub fn render(&self) -> String {
        let all: Vec<(f64, f64)> = self
            .series
            .iter()
            .flat_map(|(_, _, pts)| pts.iter().map(|&(x, y)| (self.tx(x), self.ty(y))))
            .collect();
        if all.is_empty() {
            return format!("{} (no data)\n", self.title);
        }
        let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
        let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
        for &(x, y) in &all {
            x0 = x0.min(x);
            x1 = x1.max(x);
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
        if (x1 - x0).abs() < 1e-12 {
            x1 = x0 + 1.0;
        }
        if (y1 - y0).abs() < 1e-12 {
            y1 = y0 + 1.0;
        }
        let mut grid = vec![vec![' '; self.width]; self.height];
        for (_, marker, pts) in &self.series {
            for &(x, y) in pts {
                let (x, y) = (self.tx(x), self.ty(y));
                let cx = ((x - x0) / (x1 - x0) * (self.width - 1) as f64).round() as usize;
                let cy = ((y - y0) / (y1 - y0) * (self.height - 1) as f64).round() as usize;
                grid[self.height - 1 - cy][cx] = *marker;
            }
        }
        let mut out = format!("{}\n", self.title);
        for (i, row) in grid.iter().enumerate() {
            let label = if i == 0 {
                format!("{:>9.3} ", if self.log_y { 10f64.powf(y1) } else { y1 })
            } else if i == self.height - 1 {
                format!("{:>9.3} ", if self.log_y { 10f64.powf(y0) } else { y0 })
            } else {
                " ".repeat(10)
            };
            out.push_str(&label);
            out.push('|');
            out.push_str(&row.iter().collect::<String>());
            out.push('\n');
        }
        out.push_str(&" ".repeat(10));
        out.push('+');
        out.push_str(&"-".repeat(self.width));
        out.push('\n');
        out.push_str(&format!(
            "{:>10} {:<30} {:>38}\n",
            "",
            format!(
                "{} = {:.3}",
                self.x_label,
                if self.log_x { 10f64.powf(x0) } else { x0 }
            ),
            format!("{:.3} ({})", if self.log_x { 10f64.powf(x1) } else { x1 }, self.y_label)
        ));
        for (name, marker, _) in &self.series {
            out.push_str(&format!("{:>12} {} {}\n", "", marker, name));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_both_series() {
        let p = AsciiPlot::new("t", "x", "y")
            .series("a", 'o', vec![(1.0, 1.0), (2.0, 2.0)])
            .series("b", 'x', vec![(1.0, 2.0), (2.0, 4.0)]);
        let s = p.render();
        assert!(s.contains('o') && s.contains('x'));
        assert!(s.contains("o a") && s.contains("x b"));
    }

    #[test]
    fn empty_plot_safe() {
        let s = AsciiPlot::new("t", "x", "y").render();
        assert!(s.contains("no data"));
    }

    #[test]
    fn log_axes_do_not_panic() {
        let s = AsciiPlot::new("t", "x", "y")
            .log_axes(true, true)
            .series("a", '*', vec![(0.1, 10.0), (100.0, 1000.0)])
            .render();
        assert!(s.contains('*'));
    }
}
