//! Minimal ASCII table renderer (right-aligned numeric cells).

#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new(), title: None }
    }

    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                s.push_str(&format!(" {:>width$} |", cells[i], width = widths[i]));
            }
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["a", "bbbb"]).with_title("T");
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["100".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.starts_with("T\n+"));
        assert!(s.contains("| 100 |"));
        let widths: Vec<usize> = s.lines().skip(1).map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        Table::new(&["a"]).row(vec!["1".into(), "2".into()]);
    }
}
