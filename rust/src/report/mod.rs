//! Result rendering: ASCII tables, CSV emission, and terminal scatter
//! plots for the paper's figures.

pub mod plot;
pub mod table;

pub use plot::AsciiPlot;
pub use table::Table;

/// Write CSV rows (header + data) to a file, creating parent dirs.
pub fn write_csv(path: &std::path::Path, header: &[&str], rows: &[Vec<String>]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut out = String::new();
    out.push_str(&header.join(","));
    out.push('\n');
    for row in rows {
        out.push_str(&row.join(","));
        out.push('\n');
    }
    std::fs::write(path, out)
}
