//! # KAN-SAs — Kolmogorov-Arnold Networks on Systolic Arrays
//!
//! Reproduction of *"KAN-SAs: Efficient Acceleration of Kolmogorov-Arnold
//! Networks on Systolic Arrays"* (Errabii, Sentieys, Traiola — CS.AR 2025).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1 (python, build time)** — Pallas kernel implementing the paper's
//!   tabulated, non-recursive B-spline evaluation; checked against a pure-jnp
//!   Cox-de Boor oracle.
//! * **L2 (python, build time)** — JAX KAN model (spline + base term) that
//!   calls the L1 kernel and is AOT-lowered to HLO text in `artifacts/`.
//! * **L3 (this crate, runtime)** — loads the artifacts through PJRT
//!   ([`runtime`]), owns the bit-accurate integer inference engine
//!   ([`kan`]), the cycle-level systolic-array simulator ([`sim`], [`arch`]),
//!   the synthesis-calibrated cost models ([`cost`]), the workload registry
//!   ([`workloads`]) and the serving coordinator ([`coordinator`]).
//!
//! Python never runs on the request path: after `make artifacts` the `kansas`
//! binary and all examples are self-contained.

pub mod bench;
pub mod bspline;
pub mod quant;
pub mod tensor;
pub mod arch;
pub mod sim;
pub mod cost;
pub mod arkane;
pub mod workloads;
pub mod kan;
pub mod runtime;
pub mod coordinator;
pub mod report;
pub mod config;
pub mod experiments;
pub mod util;
