//! # KAN-SAs — Kolmogorov-Arnold Networks on Systolic Arrays
//!
//! Reproduction of *"KAN-SAs: Efficient Acceleration of Kolmogorov-Arnold
//! Networks on Systolic Arrays"* (Errabii, Sentieys, Traiola — CS.AR 2025).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1 (python, build time)** — Pallas kernel implementing the paper's
//!   tabulated, non-recursive B-spline evaluation; checked against a pure-jnp
//!   Cox-de Boor oracle.
//! * **L2 (python, build time)** — JAX KAN model (spline + base term) that
//!   calls the L1 kernel and is AOT-lowered to HLO text in `artifacts/`.
//! * **L3 (this crate, runtime)** — loads the artifacts through PJRT
//!   ([`runtime`], behind the `xla` feature), owns the bit-accurate integer
//!   inference engine ([`kan`]), the cycle-level systolic-array simulator
//!   ([`sim`], [`arch`]), the synthesis-calibrated cost models ([`cost`]),
//!   the workload registry ([`workloads`]) and the serving stack
//!   ([`coordinator`], [`loadgen`]).
//!
//! ## Serving architecture
//!
//! The paper's Fig. 8 runs a *mix* of applications (MNIST, CIFAR, HAR, …)
//! on one accelerator; the request path mirrors that as a **multi-tenant
//! gateway** ([`coordinator::gateway`]): one bounded admission queue and
//! one worker fleet serving every registered model.
//!
//! * Models are registered on a [`coordinator::GatewayBuilder`] — each
//!   with a **service weight** (`register_weighted`) and optionally its
//!   own batch policy — and addressed through typed
//!   [`coordinator::ModelHandle`]s; a [`coordinator::Request`] carries
//!   the row (quantized or f32), an optional deadline, and a
//!   [`coordinator::Priority`] class. Every terminal outcome is one
//!   [`coordinator::ServeError`]. The tenant set is **live**: the
//!   per-tenant tables sit in an epoch-versioned registry snapshot, so
//!   a running gateway can hot-add (`Gateway::add_model`), re-weight
//!   (`Gateway::set_weight`), and remove (`Gateway::remove_model`,
//!   draining per [`coordinator::DrainMode`]) models under traffic.
//! * Each fleet worker owns an [`kan::Engine`] replica of *every* model;
//!   replicas share weights, LUTs, and widened MAC tables through `Arc`,
//!   so the fleet costs ~1x total model memory
//!   (`Engine::shares_weights_with`).
//! * Admission is a **shared bounded queue** with an explicit shed policy
//!   ([`coordinator::ShedPolicy`]): reject new arrivals with `QueueFull`,
//!   evict the oldest lowest-priority request, or block for backpressure;
//!   lapsed deadlines answer `DeadlineExceeded`. Weighted **per-tenant
//!   quotas** ([`coordinator::QuotaPolicy`]) reserve queue slots per
//!   service weight with a shared overflow region, so one tenant's
//!   burst can't shed every tenant's new arrivals.
//! * Dispatch is **weighted-fair with work stealing**
//!   ([`coordinator::Dispatch`]): per-model dynamic
//!   [`coordinator::Batcher`]s (size + deadline policy, deadlines
//!   anchored at true arrival times; batches never mix models) live in
//!   fleet-visible per-worker shards. Workers pick the next batch by
//!   deficit-round-robin — tenants earn credit in proportion to their
//!   weight and pay in rows served, so one tenant's burst can't starve
//!   another — queue pulls skip past head-of-line requests whose
//!   batcher is full, and an idle worker *steals* a due batch from the
//!   most backlogged peer instead of sleeping — splitting an over-full
//!   backlog roughly in half so owner and thief serve it concurrently.
//!   Steal counts and two Jain fairness lenses (raw weight-normalized
//!   service, plus a demand-normalized index that discounts the arrival
//!   mix) surface in [`coordinator::GatewayStats`]; every served batch
//!   carries simulated accelerator cycles.
//! * Inference follows a **compile/execute split** ([`kan::plan`]): the
//!   engine compiles an [`kan::ExecutionPlan`] once (resolved B-spline
//!   units, i16-widened MAC tables, buffer sizing — what the accelerator
//!   wires at configuration time), and each worker owns one
//!   [`kan::Scratch`] arena fitted to the widest registered model, so
//!   steady-state forwards perform zero heap allocations
//!   (`tests/zero_alloc.rs` enforces this with a counting allocator).
//!   Response buffers are pooled per model
//!   ([`coordinator::BufferPool`], `tests/gateway_alloc.rs`).
//! * Accounting is per model *and* per replica
//!   ([`coordinator::GatewayStats`] / [`coordinator::ModelStats`]), with
//!   conservation per model (`submitted == completed + shed + failed`)
//!   and latency split into queueing vs service time.
//!
//! `Pool` survives as the 1-model special case and `Server` as the
//! 1-model/1-replica one. Offered load comes from [`loadgen`]: an
//! open-loop Poisson generator with named scenario mixes (`steady`,
//! `diurnal`, `flash-crowd`, and the fair-dispatch stress
//! `skewed-burst`, which concentrates a burst on one tenant), weighted
//! multi-model mixes (`loadgen::run_mix` — Fig. 8's application mixes
//! at the serving tier), and scripted registry churn
//! (`loadgen::run_churn`: hot-add / re-weight / remove while traffic
//! flows), so throughput/latency/shed-rate/fairness curves are
//! measured, not anecdotal — see the `serving_scale` bench. A top-level
//! `ARCHITECTURE.md` walks the whole crate map and the invariants each
//! test file enforces.
//!
//! Python never runs on the request path: after `make artifacts` the `kansas`
//! binary and all examples are self-contained. Without artifacts, synthetic
//! models ([`kan::QuantizedModel::synthetic`]) keep the serving stack,
//! tests, and benches fully exercisable offline.

pub mod bench;
pub mod bspline;
pub mod quant;
pub mod tensor;
pub mod arch;
pub mod sim;
pub mod cost;
pub mod arkane;
pub mod workloads;
pub mod kan;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod coordinator;
pub mod loadgen;
pub mod report;
pub mod config;
pub mod experiments;
pub mod util;
