//! # KAN-SAs — Kolmogorov-Arnold Networks on Systolic Arrays
//!
//! Reproduction of *"KAN-SAs: Efficient Acceleration of Kolmogorov-Arnold
//! Networks on Systolic Arrays"* (Errabii, Sentieys, Traiola — CS.AR 2025).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L1 (python, build time)** — Pallas kernel implementing the paper's
//!   tabulated, non-recursive B-spline evaluation; checked against a pure-jnp
//!   Cox-de Boor oracle.
//! * **L2 (python, build time)** — JAX KAN model (spline + base term) that
//!   calls the L1 kernel and is AOT-lowered to HLO text in `artifacts/`.
//! * **L3 (this crate, runtime)** — loads the artifacts through PJRT
//!   ([`runtime`], behind the `xla` feature), owns the bit-accurate integer
//!   inference engine ([`kan`]), the cycle-level systolic-array simulator
//!   ([`sim`], [`arch`]), the synthesis-calibrated cost models ([`cost`]),
//!   the workload registry ([`workloads`]) and the serving stack
//!   ([`coordinator`], [`loadgen`]).
//!
//! ## Serving architecture
//!
//! The paper's utilization argument — a conventional SA idles on B-splines,
//! KAN-SAs keeps every PE lane busy — repeats one level up at the serving
//! tier, so the request path is a **sharded multi-replica pool**
//! ([`coordinator::pool`]):
//!
//! * N worker threads each own an [`kan::Engine`] replica; replicas share
//!   the model's weights, LUTs, and widened MAC tables through `Arc`, so N
//!   replicas cost ~1x model memory (`Engine::shares_weights_with`).
//! * Clients submit through a **bounded admission queue** with an explicit
//!   shed policy ([`coordinator::ShedPolicy`]): reject new arrivals with
//!   `QueueFull`, drop the oldest queued request, or block for backpressure.
//! * Each worker runs its own dynamic [`coordinator::Batcher`] (size +
//!   deadline policy, deadlines anchored at true arrival times) and attaches
//!   simulated accelerator cycles to every served batch.
//! * Inference follows a **compile/execute split** ([`kan::plan`]): the
//!   engine compiles an [`kan::ExecutionPlan`] once (resolved B-spline
//!   units, i16-widened MAC tables, buffer sizing — what the accelerator
//!   wires at configuration time), and each worker owns a [`kan::Scratch`]
//!   arena so steady-state forwards perform zero heap allocations
//!   (`tests/zero_alloc.rs` enforces this with a counting allocator).
//! * Per-replica [`coordinator::Metrics`] merge into a pool-level
//!   [`coordinator::PoolStats`] (queue depth, shed count, per-replica rows
//!   and simulated utilization).
//!
//! The single-`Server` API survives as the 1-replica special case of the
//! pool. Offered load comes from [`loadgen`]: an open-loop Poisson
//! generator with named scenario mixes (`steady`, `diurnal`, `flash-crowd`)
//! so throughput/latency/shed-rate curves are measured, not anecdotal —
//! see the `serving_scale` bench.
//!
//! Python never runs on the request path: after `make artifacts` the `kansas`
//! binary and all examples are self-contained. Without artifacts, synthetic
//! models ([`kan::QuantizedModel::synthetic`]) keep the serving stack,
//! tests, and benches fully exercisable offline.

pub mod bench;
pub mod bspline;
pub mod quant;
pub mod tensor;
pub mod arch;
pub mod sim;
pub mod cost;
pub mod arkane;
pub mod workloads;
pub mod kan;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod coordinator;
pub mod loadgen;
pub mod report;
pub mod config;
pub mod experiments;
pub mod util;
