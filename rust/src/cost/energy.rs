//! Energy model reproducing Table I's "Normalized Energy" row.
//!
//! Methodology (paper Sec. V-A): energy = power x cycles; on a KAN
//! workload a scalar PE needs (G+P) = M times more cycles than an N:M PE
//! (N = P+1, M = G+P), so
//!
//! `normalized_energy(N:M) = (power(N:M) / power(1:1)) / M`.

use super::pe::PeCost;

/// Energy of an N:M PE running a KAN workload, normalized to the scalar
/// (1:1) PE running the same workload.
pub fn normalized_energy(n: usize, m: usize) -> f64 {
    let p = PeCost::of_nm(n, m).power_mw;
    let p11 = PeCost::of_nm(1, 1).power_mw;
    (p / p11) / m as f64
}

/// Absolute energy estimate in nanojoules for `cycles` at `power_mw`,
/// 500 MHz (2 ns period).
pub fn energy_nj(power_mw: f64, cycles: u64) -> f64 {
    power_mw * 1e-3 * cycles as f64 * 2e-9 * 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_normalized_energy_row() {
        // paper Table I: 1.00, 0.57, 0.44, 0.37, 0.47, 0.40
        let want = [
            (1, 1, 1.00),
            (1, 2, 0.57),
            (2, 4, 0.44),
            (2, 6, 0.37),
            (4, 6, 0.47),
            (4, 8, 0.40),
        ];
        for (n, m, e) in want {
            let got = normalized_energy(n, m);
            assert!(
                (got - e).abs() < 0.005,
                "{n}:{m}: got {got:.3}, paper {e}"
            );
        }
    }

    #[test]
    fn nm_always_beats_scalar_on_kan() {
        // every published N:M point consumes less energy than 1:1
        for (n, m) in [(1, 2), (2, 4), (2, 6), (4, 6), (4, 8), (4, 13)] {
            assert!(normalized_energy(n, m) < 1.0, "{n}:{m}");
        }
    }

    #[test]
    fn energy_nj_linear() {
        assert!((energy_nj(1.0, 500_000_000) - 1e6).abs() < 1.0);
    }
}
