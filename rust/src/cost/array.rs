//! Array-level area composition (Fig. 8's equal-area comparison axis).

use crate::arch::ArrayConfig;

use super::pe::PeCost;
use super::BSPLINE_UNIT_UM2;

/// Post-synthesis area estimate for an array: R*C PEs plus one B-spline
/// unit per row (both the conventional SA and KAN-SAs include the units —
/// the conventional baseline also evaluates B-splines on the fly, it just
/// streams the dense expansion into scalar PEs; see paper Sec. V intro).
pub fn array_area_um2(cfg: &ArrayConfig) -> f64 {
    let pe = PeCost::of(cfg.pe).area_um2;
    (cfg.rows * cfg.cols) as f64 * pe + cfg.rows as f64 * BSPLINE_UNIT_UM2
}

pub fn array_area_mm2(cfg: &ArrayConfig) -> f64 {
    array_area_um2(cfg) * 1e-6
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArrayConfig;

    #[test]
    fn paper_equal_area_pair() {
        // Fig. 8: conventional 32x32 ~ 0.50 mm^2, KAN-SAs 16x16 4:8 ~ 0.47 mm^2
        let conv = array_area_mm2(&ArrayConfig::conventional(32, 32));
        let kan = array_area_mm2(&ArrayConfig::kan_sas(16, 16, 4, 8));
        assert!((conv - 0.50).abs() < 0.02, "conventional 32x32 area {conv}");
        assert!((kan - 0.47).abs() < 0.02, "KAN-SAs 16x16 area {kan}");
    }

    #[test]
    fn area_scales_with_rc() {
        let a = array_area_mm2(&ArrayConfig::conventional(8, 8));
        let b = array_area_mm2(&ArrayConfig::conventional(16, 16));
        assert!(b / a > 3.5 && b / a < 4.5);
    }
}
