//! PE delay / power / area model, calibrated to Table I.
//!
//! Table I (ST28nm FD-SOI, 8-bit inputs, 32-bit accumulate, 500 MHz):
//!
//! | N:M        | 1:1  | 1:2  | 2:4  | 2:6  | 4:6  | 4:8  |
//! | delay (ns) | 1.02 | 1.05 | 1.15 | 1.19 | 1.28 | 1.31 |
//! | power (mW) | 0.35 | 0.40 | 0.62 | 0.77 | 0.98 | 1.12 |
//!
//! The published points are returned exactly; other N:M use the analytic
//! composition below (critical path = multiplier + mux stages + adder
//! tree; power/area = per-block sums), whose parameters are fitted to
//! the anchors (unit tests bound the residuals).

use crate::arch::PeKind;

/// Calibration table: (n, m, delay_ns, power_mw).
const TABLE1: &[(usize, usize, f64, f64)] = &[
    (1, 1, 1.02, 0.35),
    (1, 2, 1.05, 0.40),
    (2, 4, 1.15, 0.62),
    (2, 6, 1.19, 0.77),
    (4, 6, 1.28, 0.98),
    (4, 8, 1.31, 1.12),
];

/// Analytic model parameters (fitted to TABLE1; see module docs).
const DELAY_BASE_NS: f64 = 1.02; // int8 mult + 32-bit acc + reg setup
const DELAY_ADDER_STAGE_NS: f64 = 0.085; // per extra adder-tree level
const DELAY_MUX_STAGE_NS: f64 = 0.033; // per mux level (log2 M)

const POWER_BASE_MW: f64 = 0.196; // accumulator + clocking
const POWER_MULT_MW: f64 = 0.0845; // per multiplier lane
const POWER_REG_MW: f64 = 0.0588; // per coefficient register
const POWER_MUX_MW: f64 = 0.0037; // per mux crosspoint (n*m)

const AREA_BASE_UM2: f64 = 287.0; // accumulator + control + output reg
const AREA_MULT_UM2: f64 = 150.0; // 8-bit multiplier lane
const AREA_REG_UM2: f64 = 12.0; // 8-bit coefficient register
const AREA_MUX_UM2: f64 = 25.0; // mux crosspoint (n*m)

fn log2_ceil(x: usize) -> u32 {
    assert!(x >= 1);
    usize::BITS - (x - 1).leading_zeros()
}

/// Cost of one PE.
#[derive(Clone, Copy, Debug)]
pub struct PeCost {
    pub delay_ns: f64,
    pub power_mw: f64,
    pub area_um2: f64,
}

impl PeCost {
    pub fn of(pe: PeKind) -> Self {
        let (n, m) = match pe {
            PeKind::Scalar => (1, 1),
            PeKind::Vector { n, m } => (n, m),
        };
        Self::of_nm(n, m)
    }

    pub fn of_nm(n: usize, m: usize) -> Self {
        assert!(n >= 1 && m >= n, "need M >= N >= 1, got {n}:{m}");
        let area = area_model(n, m);
        if let Some(&(_, _, d, p)) = TABLE1.iter().find(|&&(tn, tm, _, _)| tn == n && tm == m) {
            return Self { delay_ns: d, power_mw: p, area_um2: area };
        }
        Self { delay_ns: delay_model(n, m), power_mw: power_model(n, m), area_um2: area }
    }

    /// Max clock frequency implied by the critical path.
    pub fn fmax_mhz(&self) -> f64 {
        1000.0 / self.delay_ns
    }
}

/// Critical path: base MAC + extra adder-tree levels (N products + the
/// incoming psum = N+1 operands) + mux select levels (log2 M).
pub fn delay_model(n: usize, m: usize) -> f64 {
    let extra_adder_levels = (log2_ceil(n + 1).saturating_sub(1)) as f64;
    let mux_levels = log2_ceil(m) as f64;
    DELAY_BASE_NS + DELAY_ADDER_STAGE_NS * extra_adder_levels + DELAY_MUX_STAGE_NS * mux_levels
}

/// Activity-based power at 500 MHz: per-block contributions.
pub fn power_model(n: usize, m: usize) -> f64 {
    POWER_BASE_MW
        + POWER_MULT_MW * n as f64
        + POWER_REG_MW * m as f64
        + POWER_MUX_MW * (n * m) as f64
}

/// Standard-cell area: lanes + coefficient registers + mux crosspoints.
pub fn area_model(n: usize, m: usize) -> f64 {
    AREA_BASE_UM2
        + AREA_MULT_UM2 * n as f64
        + AREA_REG_UM2 * m as f64
        + AREA_MUX_UM2 * (n * m) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_points_exact() {
        for &(n, m, d, p) in TABLE1 {
            let c = PeCost::of_nm(n, m);
            assert_eq!(c.delay_ns, d, "{n}:{m} delay");
            assert_eq!(c.power_mw, p, "{n}:{m} power");
        }
    }

    #[test]
    fn analytic_close_to_anchors() {
        // the fitted formulas must stay near the published points so that
        // interpolated N:M configs are credible
        for &(n, m, d, p) in TABLE1 {
            let dd = delay_model(n, m);
            let pp = power_model(n, m);
            assert!((dd - d).abs() / d < 0.06, "{n}:{m} delay {dd} vs {d}");
            assert!((pp - p).abs() / p < 0.03, "{n}:{m} power {pp} vs {p}");
        }
    }

    #[test]
    fn delay_monotone_in_n_and_m() {
        assert!(delay_model(2, 4) > delay_model(1, 2));
        assert!(delay_model(4, 8) > delay_model(2, 8));
        assert!(delay_model(4, 13) > delay_model(4, 8));
    }

    #[test]
    fn scalar_area_anchor() {
        // fitted so conventional 32x32 + 32 B-spline units ~ 0.50 mm^2
        let a = PeCost::of(PeKind::Scalar).area_um2;
        assert!((a - 474.0).abs() < 2.0, "scalar PE area {a}");
    }

    #[test]
    fn vector_4_8_area_anchor() {
        // fitted so KAN-SAs 16x16 4:8 + 16 units ~ 0.47 mm^2
        let a = PeCost::of_nm(4, 8).area_um2;
        assert!((1650.0..1950.0).contains(&a), "4:8 PE area {a}");
    }

    #[test]
    fn meets_500mhz_at_all_table_points() {
        for &(n, m, _, _) in TABLE1 {
            // paper synthesizes at 500 MHz target; delays < 2 ns period
            assert!(PeCost::of_nm(n, m).fmax_mhz() > 500.0);
        }
    }

    #[test]
    fn mnist_kan_4_13_extrapolation_sane() {
        let c = PeCost::of_nm(4, 13);
        assert!(c.delay_ns > 1.31 && c.delay_ns < 1.6);
        assert!(c.power_mw > 1.12 && c.power_mw < 2.0);
    }
}
