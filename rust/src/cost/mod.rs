//! Synthesis-calibrated cost models (delay / power / area / energy).
//!
//! The paper reports post-synthesis numbers on ST 28nm FD-SOI (Synopsys
//! DC). We cannot run a PDK here, so `pe` reproduces Table I through (a)
//! an exact calibration table at the six published design points and (b)
//! an analytic gate-composition formula — multiplier lanes, the M-to-N
//! one-hot mux, the (N+1)-operand adder tree — fitted to those anchors
//! for interpolation to other N:M. Area anchors come from the paper's
//! Fig. 8 equal-area pair (conventional 32x32 = 0.50 mm^2, KAN-SAs 16x16
//! 4:8 = 0.47 mm^2) and the 450 um^2 B-spline unit. See DESIGN.md
//! "Substitutions".

pub mod array;
pub mod energy;
pub mod pe;

pub use array::array_area_mm2;
pub use energy::normalized_energy;
pub use pe::PeCost;

/// Paper Sec. V-B: tabulation-based B-spline unit standard-cell area.
pub const BSPLINE_UNIT_UM2: f64 = 450.0;

/// FPMax single-precision FMA (paper's ArKANe area reference [24]).
pub const FPMAX_FMA_MM2: f64 = 0.0081;
/// FPMax FMA pipeline latency in cycles.
pub const FPMAX_FMA_LATENCY: u64 = 4;
