//! ArKANe baseline model (paper Sec. V-B comparison).
//!
//! ArKANe [13] accelerates the *recursive* Cox-de Boor evaluation with a
//! wavefront schedule over P+1 floating-point FMA PEs: evaluating one
//! B-spline takes `(P+1) * PE_latency` cycles, and pipelining brings all
//! `G+P` activations for `M_in` inputs to
//!
//! `cycles = (P+1) * PE_latency + (G + P - 1) + M_in`.
//!
//! The paper sizes the FP32 FMA with FPMax [24] (0.0081 mm^2, latency 4)
//! and observes that the same area as ArKANe's 4 FMAs fits 72 tabulation
//! units (450 um^2 each), each retrieving *all* G+P values in one cycle —
//! a >= 72x steady-state speedup. This module computes both sides.

use crate::cost::{BSPLINE_UNIT_UM2, FPMAX_FMA_LATENCY, FPMAX_FMA_MM2};

/// ArKANe wavefront cycles to produce all `G+P` activations for `m_in`
/// inputs (paper's formula).
pub fn arkane_cycles(g: usize, p: usize, m_in: u64) -> u64 {
    (p as u64 + 1) * FPMAX_FMA_LATENCY + (g + p - 1) as u64 + m_in
}

/// ArKANe estimated area: P+1 FPMax FMAs.
pub fn arkane_area_mm2(p: usize) -> f64 {
    (p + 1) as f64 * FPMAX_FMA_MM2
}

/// Tabulation-unit cycles for `m_in` inputs on `units` parallel units
/// (one input per unit per cycle).
pub fn tabulation_cycles(m_in: u64, units: u64) -> u64 {
    m_in.div_ceil(units)
}

/// How many 450 um^2 tabulation units fit in ArKANe's area (the paper's
/// "72 B-spline units to feed 72 rows").
pub fn units_in_arkane_area(p: usize) -> u64 {
    (arkane_area_mm2(p) / (BSPLINE_UNIT_UM2 * 1e-6)) as u64
}

/// Equal-area speedup of tabulation over ArKANe for `m_in` inputs.
pub fn equal_area_speedup(g: usize, p: usize, m_in: u64) -> f64 {
    let units = units_in_arkane_area(p);
    arkane_cycles(g, p, m_in) as f64 / tabulation_cycles(m_in, units) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_unit_count_is_72() {
        // 4 x 0.0081 mm^2 / 450 um^2 = 72
        assert_eq!(units_in_arkane_area(3), 72);
    }

    #[test]
    fn speedup_at_least_72x_for_high_m() {
        // paper: "a minimum of 72x speedup for high values of M"
        let s = equal_area_speedup(5, 3, 1_000_000);
        assert!(s >= 72.0, "speedup {s}");
        // and it converges to exactly 72x from above
        assert!(s < 73.0, "speedup {s}");
    }

    #[test]
    fn speedup_saturates_to_72_from_above() {
        // small batches amortize ArKANe's pipeline fill worse, so the
        // equal-area advantage is *larger* for small M and converges to
        // the 72x steady state from above
        let s_small = equal_area_speedup(5, 3, 72);
        let s_big = equal_area_speedup(5, 3, 72_000);
        assert!(s_small > s_big, "{s_small} -> {s_big}");
        assert!(s_big >= 72.0 && s_big < 72.1, "{s_big}");
    }

    #[test]
    fn arkane_formula_components() {
        // (P+1)*4 + (G+P-1) + M
        assert_eq!(arkane_cycles(5, 3, 100), 16 + 7 + 100);
        assert_eq!(arkane_cycles(3, 1, 1), 8 + 3 + 1);
    }

    #[test]
    fn tabulation_single_cycle_per_input_per_unit() {
        assert_eq!(tabulation_cycles(72, 72), 1);
        assert_eq!(tabulation_cycles(100, 72), 2);
        assert_eq!(tabulation_cycles(1, 72), 1);
    }
}
