//! Open-loop traffic generation against the serving pool.
//!
//! Closed-loop clients (submit, wait, repeat) can never overload a
//! server — their arrival rate adapts to service rate, which is exactly
//! the coordinated-omission trap. The generator here is **open-loop**:
//! arrivals follow a Poisson process at the scenario's offered rate
//! whether or not earlier requests have finished, so queue growth, shed
//! rate, and tail latency under overload are measured rather than hidden.
//!
//! A [`Scenario`] is a named mix of piecewise-constant-rate phases,
//! mirroring the application mixes of the paper's Fig. 8 one level up
//! (each served row still carries its per-app simulated cycle cost):
//!
//! * `steady` — one flat phase; the throughput/latency baseline;
//! * `diurnal` — a sinusoid-shaped ramp between base and peak rate, the
//!   slow capacity sweep;
//! * `flash-crowd` — flat baseline with a sudden multi-x spike in the
//!   middle, the admission-control stress test;
//! * `skewed-burst` — like flash-crowd, but the spike *concentrates on
//!   one tenant* of a [`run_mix`] model mix (a [`Focus`] on the phase):
//!   the fair-dispatch stress test, where one model's burst must not
//!   starve the others.
//!
//! [`run`] drives a [`ModelHandle`] and returns a [`LoadReport`]
//! (offered vs achieved rate, shed counts, latency percentiles).
//! [`run_mix`] is the multi-tenant variant: a weighted model mix over
//! one gateway — the serving-tier version of the paper's Fig. 8
//! application mixes — reporting per-model *and* aggregate outcomes.
//! Both are generic over a [`RowDriver`], so the same arrival process
//! drives an in-process [`ModelHandle`] or a network [`RemoteHandle`]
//! (`kansas load --connect`) — the latency gap between the two at the
//! same sweep is the wire-protocol overhead.
//! [`run_churn`] drives a **registry-churn** scenario: the same
//! open-loop arrival process while a scripted [`ChurnEvent`] timeline
//! hot-adds, re-weights, and removes tenants on the live gateway —
//! the stress test for the dynamic registry. [`closed_loop`] is the
//! saturation counterpart used by the `serving_scale` bench to measure
//! peak rows/sec per replica count.

use std::sync::mpsc::channel;
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::net::{RemoteHandle, RemoteTicket};
use crate::coordinator::{
    DrainMode, Gateway, LatencyStats, Metrics, ModelHandle, ServeError, Ticket,
};
use crate::kan::{Engine, QuantizedModel};
use crate::util::rng::Rng;

/// Concentrate a fraction of a phase's arrivals on one tenant of a
/// [`run_mix`] model mix (the rest draw from the other tenants by their
/// mix weights). Ignored by single-model runs.
#[derive(Clone, Copy, Debug)]
pub struct Focus {
    /// Index into the [`run_mix`] entries (clamped to the mix size).
    pub entry: usize,
    /// Fraction of arrivals routed to `entry`. Values outside
    /// `0.0..=1.0` are clamped at use, so the drawn arrival stream and
    /// the reported per-model `offered_rps` always agree.
    pub share: f64,
}

/// One constant-rate segment of a scenario.
#[derive(Clone, Debug)]
pub struct Phase {
    /// Offered Poisson arrival rate during this phase.
    pub rate_rps: f64,
    /// Phase length.
    pub duration: Duration,
    /// Optional one-tenant arrival concentration (skewed bursts).
    pub focus: Option<Focus>,
}

/// A named piecewise-constant offered-load schedule.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub phases: Vec<Phase>,
}

impl Scenario {
    /// One flat phase at `rate_rps` — the throughput/latency baseline.
    pub fn steady(rate_rps: f64, duration: Duration) -> Self {
        Self { name: "steady".into(), phases: vec![Phase { rate_rps, duration, focus: None }] }
    }

    /// Diurnal ramp: a half-sine day between `base_rps` and `peak_rps`,
    /// sampled as 8 piecewise-constant steps.
    pub fn diurnal(base_rps: f64, peak_rps: f64, duration: Duration) -> Self {
        const STEPS: u32 = 8;
        let step = duration / STEPS;
        let phases = (0..STEPS)
            .map(|i| {
                let frac = (i as f64 + 0.5) / STEPS as f64;
                let level = (std::f64::consts::PI * frac).sin();
                Phase {
                    rate_rps: base_rps + (peak_rps - base_rps) * level,
                    duration: step,
                    focus: None,
                }
            })
            .collect();
        Self { name: "diurnal".into(), phases }
    }

    /// Flash crowd: steady baseline, a `spike_mult`x spike for the middle
    /// fifth, then recovery.
    pub fn flash_crowd(base_rps: f64, spike_mult: f64, duration: Duration) -> Self {
        let fifth = duration / 5;
        Self {
            name: "flash-crowd".into(),
            phases: vec![
                Phase { rate_rps: base_rps, duration: fifth * 2, focus: None },
                Phase { rate_rps: base_rps * spike_mult, duration: fifth, focus: None },
                Phase { rate_rps: base_rps, duration: fifth * 2, focus: None },
            ],
        }
    }

    /// Skewed burst: a flash crowd whose spike *concentrates on one
    /// tenant* — during the middle-fifth burst, `focus.share` of
    /// arrivals go to mix entry `focus.entry` and only the remainder is
    /// split over the other tenants. Baseline and recovery phases draw
    /// by the mix weights as usual. This is the fair-dispatch stress
    /// scenario: under fixed dispatch the focused tenant's burst
    /// head-of-line blocks the minority tenants' queue entries, while
    /// weighted DRR + stealing keeps serving them.
    pub fn skewed_burst(
        base_rps: f64,
        spike_mult: f64,
        duration: Duration,
        focus: Focus,
    ) -> Self {
        let fifth = duration / 5;
        Self {
            name: "skewed-burst".into(),
            phases: vec![
                Phase { rate_rps: base_rps, duration: fifth * 2, focus: None },
                Phase { rate_rps: base_rps * spike_mult, duration: fifth, focus: Some(focus) },
                Phase { rate_rps: base_rps, duration: fifth * 2, focus: None },
            ],
        }
    }

    /// Named mixes for CLIs and benches. `rate_rps` is the headline rate:
    /// steady runs flat at it, diurnal peaks at it (base = rate/4),
    /// flash-crowd spikes to 2x it (base = rate/2, 4x spike), and
    /// skewed-burst does the same with ~10:1 of the burst concentrated
    /// on the first mix entry.
    pub fn by_name(name: &str, rate_rps: f64, duration: Duration) -> Option<Self> {
        match name {
            "steady" => Some(Self::steady(rate_rps, duration)),
            "diurnal" => Some(Self::diurnal(rate_rps * 0.25, rate_rps, duration)),
            "flash-crowd" | "flash_crowd" => Some(Self::flash_crowd(rate_rps * 0.5, 4.0, duration)),
            "skewed-burst" | "skewed_burst" => Some(Self::skewed_burst(
                rate_rps * 0.5,
                4.0,
                duration,
                // ~10:1 concentration on the first tenant during the burst
                Focus { entry: 0, share: 10.0 / 11.0 },
            )),
            _ => None,
        }
    }

    pub fn total_duration(&self) -> Duration {
        self.phases.iter().map(|p| p.duration).sum()
    }

    /// Expected number of arrivals over the whole schedule.
    pub fn expected_arrivals(&self) -> f64 {
        self.phases.iter().map(|p| p.rate_rps * p.duration.as_secs_f64()).sum()
    }

    /// Time-averaged offered rate.
    pub fn offered_rps(&self) -> f64 {
        let secs = self.total_duration().as_secs_f64();
        if secs == 0.0 {
            return 0.0;
        }
        self.expected_arrivals() / secs
    }
}

/// Outcome counts and latency distribution of one generator run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub scenario: String,
    /// Submissions the generator attempted (admitted + shed + failed).
    pub submitted: u64,
    /// Requests answered with logits.
    pub ok: u64,
    /// Requests answered without inference: `QueueFull` (at submit or by
    /// eviction) or `DeadlineExceeded` — the gateway's `shed` bucket.
    pub shed: u64,
    /// Other terminal errors (pool closed mid-run, inference failures).
    pub failed: u64,
    /// Wall time from first arrival to last response collected.
    pub wall: Duration,
    pub offered_rps: f64,
    /// Completed requests per second of wall time.
    pub achieved_rps: f64,
    pub latency: Option<LatencyStats>,
}

impl LoadReport {
    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            return 0.0;
        }
        self.shed as f64 / self.submitted as f64
    }

    /// One-line human summary for benches and the CLI.
    pub fn summary(&self) -> String {
        let lat = match &self.latency {
            Some(l) => format!("p50 {} us  p99 {} us", l.p50_us, l.p99_us),
            None => "no completions".to_string(),
        };
        format!(
            "{:<12} offered {:>7.0} rps  achieved {:>7.0} rps  ok {:>6}  shed {:>5} ({:>5.1}%)  {lat}",
            self.scenario,
            self.offered_rps,
            self.achieved_rps,
            self.ok,
            self.shed,
            100.0 * self.shed_rate()
        )
    }
}

/// Sleep to an absolute instant with sub-millisecond accuracy: coarse
/// `thread::sleep` for the bulk, yield-spin for the last stretch.
fn sleep_until(t: Instant) {
    loop {
        let now = Instant::now();
        if now >= t {
            return;
        }
        let left = t - now;
        if left > Duration::from_millis(2) {
            thread::sleep(left - Duration::from_millis(1));
        } else {
            thread::yield_now();
        }
    }
}

/// What the generators need from a serving endpoint: acquire a row
/// buffer, submit it, and later resolve the pending ticket to a
/// `(queue_us, service_us)` latency split. Implemented by the
/// in-process [`ModelHandle`] and by the network-front-door
/// [`RemoteHandle`], so [`run`], [`run_mix`], and [`closed_loop`] drive
/// either through identical arrival logic.
///
/// For the remote driver, "service" is everything after server-side
/// queueing *as observed by the client* — engine time plus framing and
/// wire time — so remote latency totals are end-to-end and the gap vs
/// in-process rows at the same sweep is the protocol overhead.
pub trait RowDriver: Clone + Send + 'static {
    /// Pending-response token returned by [`RowDriver::submit_row`].
    type Ticket: Send + 'static;
    /// Model name for per-model report rows.
    fn name(&self) -> &str;
    /// Quantized input-row width.
    fn in_dim(&self) -> usize;
    /// An empty row buffer to fill (pooled where the driver supports it).
    fn acquire_row(&self) -> Vec<u8>;
    /// Submit one quantized `in_dim`-wide row without waiting.
    fn submit_row(&self, row: Vec<u8>) -> Result<Self::Ticket, ServeError>;
    /// Block until the ticket resolves; `Ok((queue_us, service_us))`.
    fn wait(t: Self::Ticket) -> Result<(u64, u64), ServeError>;
}

impl RowDriver for ModelHandle {
    type Ticket = Ticket;
    fn name(&self) -> &str {
        ModelHandle::name(self)
    }
    fn in_dim(&self) -> usize {
        ModelHandle::in_dim(self)
    }
    fn acquire_row(&self) -> Vec<u8> {
        ModelHandle::acquire_row(self)
    }
    fn submit_row(&self, row: Vec<u8>) -> Result<Ticket, ServeError> {
        self.submit_q(row)
    }
    fn wait(t: Ticket) -> Result<(u64, u64), ServeError> {
        t.wait().map(|r| (r.queue_us, r.service_us))
    }
}

impl RowDriver for RemoteHandle {
    type Ticket = RemoteTicket;
    fn name(&self) -> &str {
        RemoteHandle::name(self)
    }
    fn in_dim(&self) -> usize {
        RemoteHandle::in_dim(self)
    }
    fn acquire_row(&self) -> Vec<u8> {
        RemoteHandle::acquire_row(self)
    }
    fn submit_row(&self, row: Vec<u8>) -> Result<RemoteTicket, ServeError> {
        self.submit_q(row)
    }
    fn wait(t: RemoteTicket) -> Result<(u64, u64), ServeError> {
        // queue_us is the server's own split; the remainder of the
        // client-observed E2E (service + framing + wire) is "service"
        t.wait().map(|r| (r.queue_us, r.e2e_us.saturating_sub(r.queue_us)))
    }
}

/// Drive `handle` with the scenario's open-loop Poisson arrivals; block
/// until every in-flight ticket resolves. Deterministic per `seed` in
/// which inputs are generated (arrival *times* are wall-clock, so counts
/// are statistical).
pub fn run<H: RowDriver>(handle: &H, scenario: &Scenario, seed: u64) -> LoadReport {
    let mix = run_mix(&[MixEntry { handle: handle.clone(), weight: 1.0 }], scenario, seed);
    LoadReport { scenario: scenario.name.clone(), ..mix.total }
}

/// One tenant of a weighted multi-model mix.
#[derive(Clone)]
pub struct MixEntry<H = ModelHandle> {
    pub handle: H,
    /// Relative arrival weight (normalized over the mix).
    pub weight: f64,
}

/// Outcome of a [`run_mix`] drive: aggregate plus one report per tenant.
#[derive(Clone, Debug)]
pub struct MixReport {
    /// Whole-mix totals (`scenario` = `"<name>+mix"` for >1 model).
    pub total: LoadReport,
    /// Per-model reports, in `entries` order (`scenario` = model name;
    /// `offered_rps` is the model's weighted share of the schedule).
    pub per_model: Vec<LoadReport>,
}

/// Probability that one arrival goes to mix entry `i`: the mix-weight
/// split, skewed by an optional [`Focus`]. The single source of truth
/// for the arrival distribution — [`draw_model`] samples it and
/// [`expected_arrivals_per_entry`] integrates it, so the generated
/// stream and the reported per-model `offered_rps` cannot diverge.
fn entry_share<H>(
    entries: &[MixEntry<H>],
    total_weight: f64,
    focus: Option<&Focus>,
    i: usize,
) -> f64 {
    let n = entries.len();
    if let Some(f) = focus {
        if n == 1 {
            return 1.0;
        }
        let target = f.entry.min(n - 1);
        let fshare = f.share.clamp(0.0, 1.0);
        let rest = total_weight - entries[target].weight;
        return if i == target {
            // with no other weighted entries, the non-focused
            // remainder falls back to the target too
            if rest > 0.0 {
                fshare
            } else {
                1.0
            }
        } else if rest > 0.0 {
            (1.0 - fshare) * entries[i].weight / rest
        } else {
            0.0
        };
    }
    entries[i].weight / total_weight
}

/// Weighted tenant draw for one arrival: samples the [`entry_share`]
/// distribution (with probability `focus.share` the focused entry,
/// otherwise the other tenants at their relative weights — a skewed
/// burst still trickles background traffic to the minority models).
fn draw_model<H>(
    rng: &mut Rng,
    entries: &[MixEntry<H>],
    total_weight: f64,
    focus: Option<&Focus>,
) -> usize {
    let n = entries.len();
    let mut u = rng.next_f64();
    for i in 0..n - 1 {
        let s = entry_share(entries, total_weight, focus, i);
        if u < s {
            return i;
        }
        u -= s;
    }
    n - 1
}

/// Expected arrival count for each mix entry over the whole schedule:
/// the per-phase [`entry_share`] integrated against the rate schedule
/// (drives the per-model `offered_rps` in [`MixReport`]).
fn expected_arrivals_per_entry<H>(entries: &[MixEntry<H>], scenario: &Scenario) -> Vec<f64> {
    let n = entries.len();
    let total_weight: f64 = entries.iter().map(|e| e.weight).sum();
    (0..n)
        .map(|i| {
            scenario
                .phases
                .iter()
                .map(|ph| {
                    ph.rate_rps
                        * ph.duration.as_secs_f64()
                        * entry_share(entries, total_weight, ph.focus.as_ref(), i)
                })
                .sum()
        })
        .collect()
}

/// Drive a weighted mix of models — the paper's Fig. 8 application mixes
/// at the serving tier — with one open-loop Poisson arrival process.
/// Each arrival is assigned to a model by weighted draw (optionally
/// skewed toward one tenant during a [`Focus`]ed burst phase), so every
/// tenant sees Poisson traffic at its share of the offered rate; all
/// models contend for the same gateway admission queue and worker
/// fleet. Blocks until every in-flight ticket resolves.
pub fn run_mix<H: RowDriver>(entries: &[MixEntry<H>], scenario: &Scenario, seed: u64) -> MixReport {
    assert!(!entries.is_empty(), "mix needs at least one model");
    let total_weight: f64 = entries.iter().map(|e| e.weight).sum();
    assert!(total_weight > 0.0, "mix needs positive total weight");
    let n = entries.len();
    let (tick_tx, tick_rx) = channel::<(usize, H::Ticket)>();
    // collector: resolves tickets concurrently so the generator never
    // waits on responses (open loop); tallies per model
    let collector = thread::spawn(move || {
        let mut per: Vec<(Metrics, u64, u64, u64)> =
            (0..n).map(|_| (Metrics::exact(), 0, 0, 0)).collect();
        while let Ok((m, t)) = tick_rx.recv() {
            let slot = &mut per[m];
            match H::wait(t) {
                Ok((queue_us, service_us)) => {
                    slot.1 += 1;
                    slot.0.record_request_split(
                        Duration::from_micros(queue_us),
                        Duration::from_micros(service_us),
                    );
                }
                // the gateway counts deadline expiry inside `shed` (it
                // answered without inference); mirror that here
                Err(ServeError::QueueFull) | Err(ServeError::DeadlineExceeded) => slot.2 += 1,
                Err(_) => slot.3 += 1,
            }
        }
        per
    });

    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let mut phase_start = t0;
    let mut submitted = vec![0u64; n];
    let mut shed_at_submit = vec![0u64; n];
    let mut failed_at_submit = vec![0u64; n];
    'phases: for ph in &scenario.phases {
        let phase_end = phase_start + ph.duration;
        if ph.rate_rps > 0.0 {
            let mut cursor = phase_start;
            loop {
                let dt = -(1.0 - rng.next_f64()).ln() / ph.rate_rps;
                cursor += Duration::from_secs_f64(dt);
                if cursor >= phase_end {
                    break;
                }
                sleep_until(cursor);
                // weighted (or focus-skewed) model draw, then that
                // model's input shape
                let idx = draw_model(&mut rng, entries, total_weight, ph.focus.as_ref());
                let handle = &entries[idx].handle;
                let mut row = handle.acquire_row();
                row.extend((0..handle.in_dim()).map(|_| rng.below(256) as u8));
                submitted[idx] += 1;
                match handle.submit_row(row) {
                    Ok(t) => {
                        let _ = tick_tx.send((idx, t));
                    }
                    Err(ServeError::QueueFull) => shed_at_submit[idx] += 1,
                    Err(ServeError::Closed) => {
                        failed_at_submit[idx] += 1;
                        break 'phases;
                    }
                    Err(_) => failed_at_submit[idx] += 1,
                }
            }
        }
        sleep_until(phase_end);
        phase_start = phase_end;
    }
    drop(tick_tx);
    let per = collector.join().expect("collector");
    let wall = t0.elapsed();
    let mut merged = Metrics::exact();
    let mut per_model = Vec::with_capacity(n);
    let (mut t_sub, mut t_ok, mut t_shed, mut t_failed) = (0u64, 0u64, 0u64, 0u64);
    let expected = expected_arrivals_per_entry(entries, scenario);
    let sched_secs = scenario.total_duration().as_secs_f64();
    for (i, (m, ok, shed_in_flight, failed_in_flight)) in per.into_iter().enumerate() {
        let shed = shed_at_submit[i] + shed_in_flight;
        let failed = failed_at_submit[i] + failed_in_flight;
        t_sub += submitted[i];
        t_ok += ok;
        t_shed += shed;
        t_failed += failed;
        per_model.push(LoadReport {
            scenario: entries[i].handle.name().to_string(),
            submitted: submitted[i],
            ok,
            shed,
            failed,
            wall,
            offered_rps: if sched_secs > 0.0 { expected[i] / sched_secs } else { 0.0 },
            achieved_rps: ok as f64 / wall.as_secs_f64(),
            latency: m.latency(),
        });
        merged.merge(&m);
    }
    let total = LoadReport {
        scenario: if n == 1 {
            scenario.name.clone()
        } else {
            format!("{}+mix", scenario.name)
        },
        submitted: t_sub,
        ok: t_ok,
        shed: t_shed,
        failed: t_failed,
        wall,
        offered_rps: scenario.offered_rps(),
        achieved_rps: t_ok as f64 / wall.as_secs_f64(),
        latency: merged.latency(),
    };
    MixReport { total, per_model }
}

/// One timed control-plane mutation applied during [`run_churn`].
#[derive(Clone, Debug)]
pub enum ChurnAction {
    /// Hot-add a synthetic tenant ([`Gateway::add_model_weighted`]) and
    /// start routing arrivals to it.
    Add {
        /// Model name to register (and report under).
        name: String,
        /// Synthetic model dims (`IN x .. x OUT`).
        dims: Vec<usize>,
        /// Service weight for the weighted fair scheduler.
        weight: u32,
        /// Relative arrival weight within the mix once added.
        mix_weight: f64,
    },
    /// Re-weight a live tenant (by registered name) via
    /// [`Gateway::set_weight`].
    SetWeight {
        /// Target tenant name.
        name: String,
        /// New service weight (>= 1).
        weight: u32,
    },
    /// Stop sending to a tenant, then remove it from the gateway.
    /// [`Gateway::remove_model`] blocks until the tenant's backlog
    /// drains, pausing the arrival loop — real churn stalls the
    /// operator, not the fleet.
    Remove {
        /// Target tenant name.
        name: String,
        /// Serve or shed the backlog.
        mode: DrainMode,
    },
}

/// A [`ChurnAction`] scheduled at an offset from the run's start.
/// [`run_churn`] applies events in list order once their offset
/// elapses, so scripts should be sorted by `at`.
#[derive(Clone, Debug)]
pub struct ChurnEvent {
    /// When to apply the action, relative to the first arrival.
    pub at: Duration,
    /// What to do.
    pub action: ChurnAction,
}

/// The default churn script used by `kansas serve --scenario churn` and
/// the registry-churn tests: hot-add a HAR-shaped tenant a quarter into
/// the run, quadruple its service weight at the midpoint, and remove it
/// (serving its backlog) at three quarters.
pub fn default_churn_events(total: Duration) -> Vec<ChurnEvent> {
    vec![
        ChurnEvent {
            at: total.mul_f64(0.25),
            action: ChurnAction::Add {
                name: "hotswap".to_string(),
                dims: vec![16, 32, 6],
                weight: 1,
                mix_weight: 1.0,
            },
        },
        ChurnEvent {
            at: total.mul_f64(0.50),
            action: ChurnAction::SetWeight { name: "hotswap".to_string(), weight: 4 },
        },
        ChurnEvent {
            at: total.mul_f64(0.75),
            action: ChurnAction::Remove { name: "hotswap".to_string(), mode: DrainMode::Serve },
        },
    ]
}

/// Weighted draw over a possibly-sparse weight vector (removed tenants
/// carry weight 0); `None` when no weight is positive.
fn draw_weighted(rng: &mut Rng, weights: &[f64]) -> Option<usize> {
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return None;
    }
    let mut u = rng.next_f64() * total;
    for (i, &w) in weights.iter().enumerate() {
        if w <= 0.0 {
            continue;
        }
        if u < w {
            return Some(i);
        }
        u -= w;
    }
    weights.iter().rposition(|&w| w > 0.0)
}

/// The generator's mutable view of a churning mix: the entry list only
/// grows (removed tenants keep their report slot with arrival weight 0,
/// mirroring how gateway slots are never reused).
struct ChurnMix {
    entries: Vec<MixEntry>,
    /// Arrival weight per entry; 0 once the tenant is removed.
    arr_weights: Vec<f64>,
    submitted: Vec<u64>,
    shed_at_submit: Vec<u64>,
    failed_at_submit: Vec<u64>,
}

impl ChurnMix {
    fn new(entries: Vec<MixEntry>) -> Self {
        let n = entries.len();
        let arr_weights = entries.iter().map(|e| e.weight).collect();
        Self {
            entries,
            arr_weights,
            submitted: vec![0; n],
            shed_at_submit: vec![0; n],
            failed_at_submit: vec![0; n],
        }
    }

    /// Latest *active* entry registered under `name`. Removed entries
    /// keep their slots (arrival weight 0), and the gateway allows
    /// re-adding a removed tenant's name — a plain first-match would
    /// silently target the dead entry after a remove→add cycle.
    fn find(&self, name: &str) -> Option<usize> {
        (0..self.entries.len())
            .rev()
            .find(|&i| self.arr_weights[i] > 0.0 && self.entries[i].handle.name() == name)
    }

    /// Apply one churn event against the live gateway. Control-plane
    /// rejections (duplicate name, already-removed tenant) are
    /// deliberately non-fatal: the traffic run continues and the
    /// gateway's own stats show what happened.
    fn apply(&mut self, gateway: &Gateway, action: &ChurnAction, seed: u64) {
        match action {
            ChurnAction::Add { name, dims, weight, mix_weight } => {
                let engine = Engine::new(QuantizedModel::synthetic(
                    name,
                    dims,
                    5,
                    3,
                    seed.wrapping_add(self.entries.len() as u64),
                ));
                if let Ok(handle) = gateway.add_model_weighted(name, engine, *weight) {
                    self.entries.push(MixEntry { handle, weight: *mix_weight });
                    self.arr_weights.push(*mix_weight);
                    self.submitted.push(0);
                    self.shed_at_submit.push(0);
                    self.failed_at_submit.push(0);
                }
            }
            ChurnAction::SetWeight { name, weight } => {
                if let Some(i) = self.find(name) {
                    let _ = gateway.set_weight(self.entries[i].handle.model_id(), *weight);
                }
            }
            ChurnAction::Remove { name, mode } => {
                if let Some(i) = self.find(name) {
                    if self.arr_weights[i] > 0.0 {
                        // stop sending first, then drain: no arrival can
                        // race the removal into an UnknownModel failure
                        self.arr_weights[i] = 0.0;
                        let _ =
                            gateway.remove_model(self.entries[i].handle.model_id(), *mode);
                    }
                }
            }
        }
    }
}

/// Drive a weighted mix through a scripted **registry churn**: open-loop
/// Poisson arrivals (like [`run_mix`], without [`Focus`] skew) while
/// the [`ChurnEvent`] timeline hot-adds, re-weights, and removes
/// tenants on the live `gateway`. Events fire between arrivals once
/// their offset elapses; events scheduled past the last arrival are
/// applied before the report is assembled. Blocks until every in-flight
/// ticket resolves.
///
/// Per-model reports come back in entry order (hot-added tenants
/// append); `offered_rps` is the *observed* submission rate — with the
/// tenant set changing mid-run, the static schedule split of
/// [`run_mix`] has no meaning here.
pub fn run_churn(
    gateway: &Gateway,
    entries: Vec<MixEntry>,
    scenario: &Scenario,
    events: &[ChurnEvent],
    seed: u64,
) -> MixReport {
    assert!(!entries.is_empty(), "churn mix needs at least one initial model");
    let (tick_tx, tick_rx) = channel::<(usize, Ticket)>();
    // collector: resolves tickets concurrently so the generator never
    // waits on responses (open loop); grows with hot-added tenants
    let collector = thread::spawn(move || {
        let mut per: Vec<(Metrics, u64, u64, u64)> = Vec::new();
        while let Ok((m, t)) = tick_rx.recv() {
            if per.len() <= m {
                per.resize_with(m + 1, || (Metrics::exact(), 0, 0, 0));
            }
            let slot = &mut per[m];
            match t.wait() {
                Ok(resp) => {
                    slot.1 += 1;
                    slot.0.record_request_split(
                        Duration::from_micros(resp.queue_us),
                        Duration::from_micros(resp.service_us),
                    );
                }
                Err(ServeError::QueueFull) | Err(ServeError::DeadlineExceeded) => slot.2 += 1,
                Err(_) => slot.3 += 1,
            }
        }
        per
    });

    let mut mix = ChurnMix::new(entries);
    let mut next_event = 0usize;
    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let mut phase_start = t0;
    'phases: for ph in &scenario.phases {
        let phase_end = phase_start + ph.duration;
        if ph.rate_rps > 0.0 {
            let mut cursor = phase_start;
            loop {
                let dt = -(1.0 - rng.next_f64()).ln() / ph.rate_rps;
                cursor += Duration::from_secs_f64(dt);
                if cursor >= phase_end {
                    break;
                }
                sleep_until(cursor);
                while next_event < events.len() && t0.elapsed() >= events[next_event].at {
                    mix.apply(gateway, &events[next_event].action, seed);
                    next_event += 1;
                }
                let Some(idx) = draw_weighted(&mut rng, &mix.arr_weights) else {
                    continue;
                };
                let handle = &mix.entries[idx].handle;
                let mut row = handle.acquire_row();
                row.extend((0..handle.in_dim()).map(|_| rng.below(256) as u8));
                mix.submitted[idx] += 1;
                match handle.submit_q(row) {
                    Ok(t) => {
                        let _ = tick_tx.send((idx, t));
                    }
                    Err(ServeError::QueueFull) => mix.shed_at_submit[idx] += 1,
                    Err(ServeError::Closed) => {
                        mix.failed_at_submit[idx] += 1;
                        break 'phases;
                    }
                    Err(_) => mix.failed_at_submit[idx] += 1,
                }
            }
        }
        sleep_until(phase_end);
        phase_start = phase_end;
    }
    // trailing events (e.g. a remove scheduled at 100%) still apply, so
    // the script's end state is the report's end state
    while next_event < events.len() {
        mix.apply(gateway, &events[next_event].action, seed);
        next_event += 1;
    }
    drop(tick_tx);
    let mut per = collector.join().expect("collector");
    let n = mix.entries.len();
    per.resize_with(n, || (Metrics::exact(), 0, 0, 0));
    let wall = t0.elapsed();
    let mut merged = Metrics::exact();
    let mut per_model = Vec::with_capacity(n);
    let (mut t_sub, mut t_ok, mut t_shed, mut t_failed) = (0u64, 0u64, 0u64, 0u64);
    for (i, (m, ok, shed_in_flight, failed_in_flight)) in per.into_iter().enumerate() {
        let shed = mix.shed_at_submit[i] + shed_in_flight;
        let failed = mix.failed_at_submit[i] + failed_in_flight;
        t_sub += mix.submitted[i];
        t_ok += ok;
        t_shed += shed;
        t_failed += failed;
        per_model.push(LoadReport {
            scenario: mix.entries[i].handle.name().to_string(),
            submitted: mix.submitted[i],
            ok,
            shed,
            failed,
            wall,
            // observed, not scheduled: the tenant set changed mid-run
            offered_rps: mix.submitted[i] as f64 / wall.as_secs_f64(),
            achieved_rps: ok as f64 / wall.as_secs_f64(),
            latency: m.latency(),
        });
        merged.merge(&m);
    }
    let total = LoadReport {
        scenario: format!("{}+churn", scenario.name),
        submitted: t_sub,
        ok: t_ok,
        shed: t_shed,
        failed: t_failed,
        wall,
        // observed like the per-model rows — drain pauses and early
        // exits make the scheduled rate a fiction here
        offered_rps: t_sub as f64 / wall.as_secs_f64(),
        achieved_rps: t_ok as f64 / wall.as_secs_f64(),
        latency: merged.latency(),
    };
    MixReport { total, per_model }
}

/// Closed-loop saturation: `clients` threads hammer the pool (submit,
/// wait, repeat) until `duration` elapses — or until a thread has issued
/// `per_client` requests, when a budget is given. Measures peak service
/// capacity rather than behaviour at a fixed offered rate; `offered_rps`
/// is the attempt rate (including shed), `achieved_rps` the completion
/// rate.
pub fn closed_loop<H: RowDriver>(
    handle: &H,
    clients: usize,
    duration: Duration,
    per_client: Option<usize>,
    seed: u64,
) -> LoadReport {
    let in_dim = handle.in_dim();
    let t0 = Instant::now();
    let deadline = t0 + duration;
    let budget = per_client.unwrap_or(usize::MAX);
    let mut threads = Vec::with_capacity(clients);
    for c in 0..clients {
        let h = handle.clone();
        threads.push(thread::spawn(move || {
            let mut rng = Rng::new(seed.wrapping_add((c as u64).wrapping_mul(0x9E37_79B9)));
            let mut m = Metrics::exact();
            let (mut ok, mut shed, mut failed) = (0u64, 0u64, 0u64);
            let mut sent = 0usize;
            while sent < budget && Instant::now() < deadline {
                sent += 1;
                let mut row = h.acquire_row();
                row.extend((0..in_dim).map(|_| rng.below(256) as u8));
                match h.submit_row(row).and_then(H::wait) {
                    Ok((queue_us, service_us)) => {
                        ok += 1;
                        m.record_request_split(
                            Duration::from_micros(queue_us),
                            Duration::from_micros(service_us),
                        );
                    }
                    Err(ServeError::QueueFull) | Err(ServeError::DeadlineExceeded) => shed += 1,
                    Err(ServeError::Closed) => {
                        failed += 1;
                        break;
                    }
                    Err(_) => failed += 1,
                }
            }
            (m, ok, shed, failed)
        }));
    }
    let mut merged = Metrics::exact();
    let (mut ok, mut shed, mut failed) = (0u64, 0u64, 0u64);
    for t in threads {
        let (m, o, s, f) = t.join().expect("client thread");
        merged.merge(&m);
        ok += o;
        shed += s;
        failed += f;
    }
    let wall = t0.elapsed();
    LoadReport {
        scenario: "closed-loop".into(),
        submitted: ok + shed + failed,
        ok,
        shed,
        failed,
        wall,
        offered_rps: (ok + shed + failed) as f64 / wall.as_secs_f64(),
        achieved_rps: ok as f64 / wall.as_secs_f64(),
        latency: merged.latency(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArrayConfig;
    use crate::coordinator::{BatchPolicy, Pool, PoolConfig, ShedPolicy};
    use crate::kan::{Engine, QuantizedModel};

    fn tiny_pool(replicas: usize, queue_cap: usize, shed: ShedPolicy) -> Pool {
        let engine = Engine::new(QuantizedModel::synthetic("lg", &[4, 8, 3], 5, 3, 1));
        Pool::start(
            engine,
            PoolConfig {
                replicas,
                queue_cap,
                shed,
                policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
                sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
                dispatch: crate::coordinator::Dispatch::FairSteal,
                quota: crate::coordinator::QuotaPolicy::None,
                telemetry: crate::coordinator::TelemetryConfig::default(),
                ..Default::default()
            },
        )
    }

    #[test]
    fn skewed_burst_shape_and_draw() {
        let total = Duration::from_millis(1000);
        let s = Scenario::skewed_burst(50.0, 4.0, total, Focus { entry: 1, share: 0.9 });
        assert_eq!(s.phases.len(), 3);
        assert_eq!(s.total_duration(), total);
        assert!(s.phases[0].focus.is_none() && s.phases[2].focus.is_none());
        let f = s.phases[1].focus.expect("burst phase carries the focus");
        assert_eq!(f.entry, 1);
        assert!((s.phases[1].rate_rps - 200.0).abs() < 1e-9);
        assert!(Scenario::by_name("skewed-burst", 10.0, total).is_some());

        // the draw statistics follow the focus: ~90% on entry 1 during
        // the burst, weight-proportional otherwise
        let pool = tiny_pool(1, 8, ShedPolicy::RejectNew);
        let entries = [
            MixEntry { handle: pool.handle(), weight: 3.0 },
            MixEntry { handle: pool.handle(), weight: 1.0 },
        ];
        let mut rng = Rng::new(7);
        let mut hits = [0usize; 2];
        for _ in 0..4000 {
            hits[draw_model(&mut rng, &entries, 4.0, Some(&f))] += 1;
        }
        let share1 = hits[1] as f64 / 4000.0;
        assert!((0.85..=0.95).contains(&share1), "focused share {share1}");
        let mut hits = [0usize; 2];
        for _ in 0..4000 {
            hits[draw_model(&mut rng, &entries, 4.0, None)] += 1;
        }
        let share0 = hits[0] as f64 / 4000.0;
        assert!((0.70..=0.80).contains(&share0), "weighted share {share0}");
        pool.shutdown();

        // expected per-entry arrivals integrate the focus over phases:
        // baseline 50 rps x 0.8s split 3:1 by weight, burst 200 rps x
        // 0.2s split 10%/90% by the focus
        let exp = expected_arrivals_per_entry(&entries, &s);
        assert!((exp[0] - (40.0 * 0.75 + 40.0 * 0.1)).abs() < 1e-9, "got {}", exp[0]);
        assert!((exp[1] - (40.0 * 0.25 + 40.0 * 0.9)).abs() < 1e-9, "got {}", exp[1]);
        assert!((exp[0] + exp[1] - s.expected_arrivals()).abs() < 1e-9);
    }

    /// [`expected_arrivals_per_entry`] integrates the same
    /// [`entry_share`] distribution [`draw_model`] samples — so the
    /// empirical assignment frequencies of a phase-by-phase simulated
    /// arrival stream must match the integral within chi-squared
    /// tolerance, for a flat scenario, a focused burst, and a churned
    /// (mid-run entry removal) schedule alike. Fixed seed: the check is
    /// deterministic, not flake-budgeted.
    #[test]
    fn expected_arrivals_match_empirical_draw_frequencies() {
        fn chi_squared<H>(
            rng: &mut Rng,
            entries: &[MixEntry<H>],
            scenario: &Scenario,
        ) -> (f64, Vec<f64>, Vec<f64>) {
            let total_weight: f64 = entries.iter().map(|e| e.weight).sum();
            let exp = expected_arrivals_per_entry(entries, scenario);
            let mut obs = vec![0f64; entries.len()];
            for ph in &scenario.phases {
                let draws = (ph.rate_rps * ph.duration.as_secs_f64()).round() as usize;
                for _ in 0..draws {
                    obs[draw_model(rng, entries, total_weight, ph.focus.as_ref())] += 1.0;
                }
            }
            let n_exp: f64 = exp.iter().sum();
            let n_obs: f64 = obs.iter().sum();
            assert!(
                (n_exp - n_obs).abs() < 1.0,
                "the integral and the simulated stream agree on total arrivals \
                 ({n_exp} vs {n_obs})"
            );
            let stat = exp
                .iter()
                .zip(&obs)
                .map(|(e, o)| (o - e).powi(2) / e.max(1e-9))
                .sum();
            (stat, exp, obs)
        }

        let pool = tiny_pool(1, 8, ShedPolicy::RejectNew);
        let entries = [
            MixEntry { handle: pool.handle(), weight: 5.0 },
            MixEntry { handle: pool.handle(), weight: 2.0 },
            MixEntry { handle: pool.handle(), weight: 1.0 },
        ];
        let mut rng = Rng::new(13);
        let dur = Duration::from_millis(1000);
        // chi-squared at 2 dof: 13.8 is the 99.9th percentile; double it
        // so the fixed-seed check sits far from the boundary
        const BOUND: f64 = 27.6;
        for s in [
            Scenario::steady(4_000.0, dur),
            Scenario::skewed_burst(2_000.0, 4.0, dur, Focus { entry: 2, share: 0.8 }),
        ] {
            let (stat, exp, obs) = chi_squared(&mut rng, &entries, &s);
            assert!(stat < BOUND, "{}: chi-squared {stat} (exp {exp:?}, obs {obs:?})", s.name);
        }

        // churn: the entry list itself changes mid-schedule (the third
        // tenant removed halfway) — the integral applies per segment,
        // and after the removal the survivors re-split by weight (5:2)
        let seg = Scenario::steady(2_000.0, Duration::from_millis(500));
        let (stat, exp, obs) = chi_squared(&mut rng, &entries, &seg);
        assert!(stat < BOUND, "churn pre-removal: chi-squared {stat} (exp {exp:?}, obs {obs:?})");
        let survivors = &entries[..2];
        let (stat, exp, obs) = chi_squared(&mut rng, survivors, &seg);
        assert!(stat < BOUND, "churn post-removal: chi-squared {stat} (exp {exp:?}, obs {obs:?})");
        assert!(
            (exp[0] / exp[1] - 2.5).abs() < 1e-9,
            "survivors inherit the removed tenant's share by weight"
        );
        pool.shutdown();
    }

    #[test]
    fn scenario_shapes() {
        let total = Duration::from_millis(1000);
        let s = Scenario::steady(100.0, total);
        assert_eq!(s.total_duration(), total);
        assert!((s.expected_arrivals() - 100.0).abs() < 1e-9);
        assert!((s.offered_rps() - 100.0).abs() < 1e-9);

        let d = Scenario::diurnal(10.0, 100.0, total);
        assert_eq!(d.phases.len(), 8);
        assert_eq!(d.total_duration(), total);
        let peak = d.phases.iter().map(|p| p.rate_rps).fold(0.0f64, f64::max);
        let low = d.phases.iter().map(|p| p.rate_rps).fold(f64::INFINITY, f64::min);
        assert!(peak > low, "ramp must actually ramp");
        assert!(peak <= 100.0 + 1e-9 && low >= 10.0 - 1e-9);

        let f = Scenario::flash_crowd(50.0, 4.0, total);
        assert_eq!(f.phases.len(), 3);
        assert!((f.phases[1].rate_rps - 200.0).abs() < 1e-9);
        assert_eq!(f.total_duration(), total);

        assert!(Scenario::by_name("steady", 10.0, total).is_some());
        assert!(Scenario::by_name("diurnal", 10.0, total).is_some());
        assert!(Scenario::by_name("flash-crowd", 10.0, total).is_some());
        assert!(Scenario::by_name("bogus", 10.0, total).is_none());
    }

    #[test]
    fn open_loop_conserves_outcomes() {
        let pool = tiny_pool(2, 64, ShedPolicy::RejectNew);
        let sc = Scenario::steady(2000.0, Duration::from_millis(150));
        let rep = run(&pool.handle(), &sc, 11);
        let stats = pool.shutdown();
        assert_eq!(rep.submitted, rep.ok + rep.shed + rep.failed, "every arrival has one outcome");
        assert!(rep.ok > 0, "a 2-replica pool must serve something at 2k rps");
        assert_eq!(rep.failed, 0, "healthy pool, valid inputs: no failures");
        assert_eq!(stats.completed, rep.ok);
        assert_eq!(stats.shed, rep.shed);
        assert_eq!(stats.submitted, rep.submitted);
        assert_eq!(rep.latency.unwrap().count as u64, rep.ok);
        assert_eq!(rep.scenario, "steady");
    }

    #[test]
    fn mix_conserves_per_model_and_weights_traffic() {
        use crate::coordinator::{Dispatch, GatewayBuilder, GatewayConfig, QuotaPolicy};
        let mut b = GatewayBuilder::with_config(GatewayConfig {
            replicas: 2,
            queue_cap: 64,
            shed: ShedPolicy::RejectNew,
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
            dispatch: Dispatch::FairSteal,
            quota: QuotaPolicy::None,
            telemetry: crate::coordinator::TelemetryConfig::default(),
            ..Default::default()
        });
        let eb = Engine::new(QuantizedModel::synthetic("big", &[4, 8, 3], 5, 3, 1));
        let es = Engine::new(QuantizedModel::synthetic("small", &[6, 4, 2], 5, 3, 2));
        let big = b.register("big", eb);
        let small = b.register("small", es);
        let gw = b.start();
        let entries = [
            MixEntry { handle: gw.handle(big), weight: 3.0 },
            MixEntry { handle: gw.handle(small), weight: 1.0 },
        ];
        let sc = Scenario::steady(2000.0, Duration::from_millis(200));
        let mix = run_mix(&entries, &sc, 17);
        let stats = gw.shutdown();
        assert_eq!(mix.per_model.len(), 2);
        assert_eq!(mix.per_model[0].scenario, "big");
        assert_eq!(mix.total.scenario, "steady+mix");
        let mut total_ok = 0;
        for (rep, ms) in mix.per_model.iter().zip(&stats.per_model) {
            assert_eq!(rep.submitted, rep.ok + rep.shed + rep.failed, "per-model conservation");
            assert_eq!(ms.submitted, rep.submitted, "generator and gateway agree");
            assert_eq!(ms.completed, rep.ok);
            assert!(ms.conserved());
            total_ok += rep.ok;
        }
        assert_eq!(mix.total.ok, total_ok);
        assert!(
            mix.per_model[0].submitted > mix.per_model[1].submitted,
            "3:1 weighting skews traffic"
        );
        assert!((mix.per_model[0].offered_rps - 1500.0).abs() < 1e-6);
        assert!((mix.per_model[1].offered_rps - 500.0).abs() < 1e-6);
        assert!(mix.total.ok > 0);
    }

    #[test]
    fn closed_loop_reports_capacity() {
        let pool = tiny_pool(2, 64, ShedPolicy::Block);
        let rep = closed_loop(&pool.handle(), 4, Duration::from_millis(120), None, 3);
        let stats = pool.shutdown();
        assert!(rep.ok > 0);
        assert_eq!(rep.shed, 0, "Block policy never sheds");
        assert_eq!(stats.completed, rep.ok);
        assert!(rep.achieved_rps > 0.0);
    }

    #[test]
    fn churn_run_applies_events_and_conserves() {
        use crate::coordinator::{Dispatch, GatewayBuilder, GatewayConfig, QuotaPolicy};
        let mut b = GatewayBuilder::with_config(GatewayConfig {
            replicas: 2,
            queue_cap: 256,
            shed: ShedPolicy::RejectNew,
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
            sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
            dispatch: Dispatch::FairSteal,
            quota: QuotaPolicy::weighted(),
            telemetry: crate::coordinator::TelemetryConfig::default(),
            ..Default::default()
        });
        let e0 = Engine::new(QuantizedModel::synthetic("base0", &[4, 8, 3], 5, 3, 1));
        let e1 = Engine::new(QuantizedModel::synthetic("base1", &[6, 4, 2], 5, 3, 2));
        let a = b.register("base0", e0);
        let c = b.register("base1", e1);
        let gw = b.start();
        let entries = vec![
            MixEntry { handle: gw.handle(a), weight: 1.0 },
            MixEntry { handle: gw.handle(c), weight: 1.0 },
        ];
        let sc = Scenario::steady(1500.0, Duration::from_millis(400));
        let events = default_churn_events(sc.total_duration());
        let mix = run_churn(&gw, entries, &sc, &events, 29);
        let stats = gw.shutdown();
        assert_eq!(mix.per_model.len(), 3, "the hot-added tenant reports too");
        assert_eq!(mix.per_model[2].scenario, "hotswap");
        for (rep, ms) in mix.per_model.iter().zip(&stats.per_model) {
            assert_eq!(
                rep.submitted,
                rep.ok + rep.shed + rep.failed,
                "{}: generator conservation",
                rep.scenario
            );
            assert_eq!(ms.submitted, rep.submitted, "{}: gateway agrees", ms.name);
            assert!(ms.conserved(), "{}: {ms:?}", ms.name);
        }
        assert!(stats.conserved());
        // add (+1), set_weight (+1), remove (+2) on the start epoch of 1
        assert!(stats.epoch >= 5, "churn must move the registry epoch, got {}", stats.epoch);
        let hot = &mix.per_model[2];
        assert!(hot.ok > 0, "hot-added tenant was served: {hot:?}");
        assert_eq!(hot.failed, 0, "no responses lost across add/reweight/remove");
        assert!(!stats.per_model[2].live, "hotswap removed again by the script");
        assert!(stats.per_model[0].live && stats.per_model[1].live);
        assert_eq!(mix.total.scenario, "steady+churn");
    }

    #[test]
    fn draw_weighted_skips_zeroed_entries() {
        let mut rng = Rng::new(3);
        assert_eq!(draw_weighted(&mut rng, &[]), None);
        assert_eq!(draw_weighted(&mut rng, &[0.0, 0.0]), None);
        for _ in 0..200 {
            assert_eq!(draw_weighted(&mut rng, &[0.0, 5.0, 0.0]), Some(1));
        }
        let mut hits = [0usize; 3];
        for _ in 0..3000 {
            hits[draw_weighted(&mut rng, &[3.0, 0.0, 1.0]).unwrap()] += 1;
        }
        assert_eq!(hits[1], 0, "zero-weight entries never drawn");
        let share0 = hits[0] as f64 / 3000.0;
        assert!((0.68..=0.82).contains(&share0), "3:1 split, got {share0}");
    }

    #[test]
    fn closed_loop_respects_request_budget() {
        let pool = tiny_pool(1, 64, ShedPolicy::Block);
        let rep = closed_loop(&pool.handle(), 3, Duration::from_secs(30), Some(5), 3);
        let stats = pool.shutdown();
        assert_eq!(rep.submitted, 15, "3 clients x 5 requests");
        assert_eq!(rep.ok, 15);
        assert_eq!(stats.completed, 15);
        assert!(rep.wall < Duration::from_secs(30), "budget ends the run, not the deadline");
    }
}
