//! Open-loop traffic generation against the serving pool.
//!
//! Closed-loop clients (submit, wait, repeat) can never overload a
//! server — their arrival rate adapts to service rate, which is exactly
//! the coordinated-omission trap. The generator here is **open-loop**:
//! arrivals follow a Poisson process at the scenario's offered rate
//! whether or not earlier requests have finished, so queue growth, shed
//! rate, and tail latency under overload are measured rather than hidden.
//!
//! A [`Scenario`] is a named mix of piecewise-constant-rate phases,
//! mirroring the application mixes of the paper's Fig. 8 one level up
//! (each served row still carries its per-app simulated cycle cost):
//!
//! * `steady` — one flat phase; the throughput/latency baseline;
//! * `diurnal` — a sinusoid-shaped ramp between base and peak rate, the
//!   slow capacity sweep;
//! * `flash-crowd` — flat baseline with a sudden multi-x spike in the
//!   middle, the admission-control stress test.
//!
//! [`run`] drives a [`PoolHandle`] and returns a [`LoadReport`]
//! (offered vs achieved rate, shed counts, latency percentiles).
//! [`closed_loop`] is the saturation counterpart used by the
//! `serving_scale` bench to measure peak rows/sec per replica count.

use std::sync::mpsc::channel;
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::{LatencyStats, Metrics, PoolError, PoolHandle, Ticket};
use crate::util::rng::Rng;

/// One constant-rate segment of a scenario.
#[derive(Clone, Debug)]
pub struct Phase {
    pub rate_rps: f64,
    pub duration: Duration,
}

/// A named piecewise-constant offered-load schedule.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub phases: Vec<Phase>,
}

impl Scenario {
    pub fn steady(rate_rps: f64, duration: Duration) -> Self {
        Self { name: "steady".into(), phases: vec![Phase { rate_rps, duration }] }
    }

    /// Diurnal ramp: a half-sine day between `base_rps` and `peak_rps`,
    /// sampled as 8 piecewise-constant steps.
    pub fn diurnal(base_rps: f64, peak_rps: f64, duration: Duration) -> Self {
        const STEPS: u32 = 8;
        let step = duration / STEPS;
        let phases = (0..STEPS)
            .map(|i| {
                let frac = (i as f64 + 0.5) / STEPS as f64;
                let level = (std::f64::consts::PI * frac).sin();
                Phase { rate_rps: base_rps + (peak_rps - base_rps) * level, duration: step }
            })
            .collect();
        Self { name: "diurnal".into(), phases }
    }

    /// Flash crowd: steady baseline, a `spike_mult`x spike for the middle
    /// fifth, then recovery.
    pub fn flash_crowd(base_rps: f64, spike_mult: f64, duration: Duration) -> Self {
        let fifth = duration / 5;
        Self {
            name: "flash-crowd".into(),
            phases: vec![
                Phase { rate_rps: base_rps, duration: fifth * 2 },
                Phase { rate_rps: base_rps * spike_mult, duration: fifth },
                Phase { rate_rps: base_rps, duration: fifth * 2 },
            ],
        }
    }

    /// Named mixes for CLIs and benches. `rate_rps` is the headline rate:
    /// steady runs flat at it, diurnal peaks at it (base = rate/4), and
    /// flash-crowd spikes to 2x it (base = rate/2, 4x spike).
    pub fn by_name(name: &str, rate_rps: f64, duration: Duration) -> Option<Self> {
        match name {
            "steady" => Some(Self::steady(rate_rps, duration)),
            "diurnal" => Some(Self::diurnal(rate_rps * 0.25, rate_rps, duration)),
            "flash-crowd" | "flash_crowd" => Some(Self::flash_crowd(rate_rps * 0.5, 4.0, duration)),
            _ => None,
        }
    }

    pub fn total_duration(&self) -> Duration {
        self.phases.iter().map(|p| p.duration).sum()
    }

    /// Expected number of arrivals over the whole schedule.
    pub fn expected_arrivals(&self) -> f64 {
        self.phases.iter().map(|p| p.rate_rps * p.duration.as_secs_f64()).sum()
    }

    /// Time-averaged offered rate.
    pub fn offered_rps(&self) -> f64 {
        let secs = self.total_duration().as_secs_f64();
        if secs == 0.0 {
            return 0.0;
        }
        self.expected_arrivals() / secs
    }
}

/// Outcome counts and latency distribution of one generator run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub scenario: String,
    /// Submissions the generator attempted (admitted + shed + failed).
    pub submitted: u64,
    /// Requests answered with logits.
    pub ok: u64,
    /// Requests answered `QueueFull` (at submit or by eviction).
    pub shed: u64,
    /// Other terminal errors (pool closed mid-run, inference failures).
    pub failed: u64,
    /// Wall time from first arrival to last response collected.
    pub wall: Duration,
    pub offered_rps: f64,
    /// Completed requests per second of wall time.
    pub achieved_rps: f64,
    pub latency: Option<LatencyStats>,
}

impl LoadReport {
    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            return 0.0;
        }
        self.shed as f64 / self.submitted as f64
    }

    /// One-line human summary for benches and the CLI.
    pub fn summary(&self) -> String {
        let lat = match &self.latency {
            Some(l) => format!("p50 {} us  p99 {} us", l.p50_us, l.p99_us),
            None => "no completions".to_string(),
        };
        format!(
            "{:<12} offered {:>7.0} rps  achieved {:>7.0} rps  ok {:>6}  shed {:>5} ({:>5.1}%)  {lat}",
            self.scenario,
            self.offered_rps,
            self.achieved_rps,
            self.ok,
            self.shed,
            100.0 * self.shed_rate()
        )
    }
}

/// Sleep to an absolute instant with sub-millisecond accuracy: coarse
/// `thread::sleep` for the bulk, yield-spin for the last stretch.
fn sleep_until(t: Instant) {
    loop {
        let now = Instant::now();
        if now >= t {
            return;
        }
        let left = t - now;
        if left > Duration::from_millis(2) {
            thread::sleep(left - Duration::from_millis(1));
        } else {
            thread::yield_now();
        }
    }
}

/// Drive `handle` with the scenario's open-loop Poisson arrivals; block
/// until every in-flight ticket resolves. Deterministic per `seed` in
/// which inputs are generated (arrival *times* are wall-clock, so counts
/// are statistical).
pub fn run(handle: &PoolHandle, scenario: &Scenario, seed: u64) -> LoadReport {
    let in_dim = handle.in_dim();
    let (tick_tx, tick_rx) = channel::<Ticket>();
    // collector: resolves tickets concurrently so the generator never
    // waits on responses (open loop)
    let collector = thread::spawn(move || {
        let mut m = Metrics::default();
        let (mut ok, mut shed, mut failed) = (0u64, 0u64, 0u64);
        while let Ok(t) = tick_rx.recv() {
            match t.wait() {
                Ok(resp) => {
                    ok += 1;
                    m.record_request(Duration::from_micros(resp.latency_us));
                }
                Err(PoolError::QueueFull) => shed += 1,
                Err(_) => failed += 1,
            }
        }
        (m, ok, shed, failed)
    });

    let mut rng = Rng::new(seed);
    let t0 = Instant::now();
    let mut phase_start = t0;
    let mut submitted = 0u64;
    let mut shed_at_submit = 0u64;
    let mut failed_at_submit = 0u64;
    'phases: for ph in &scenario.phases {
        let phase_end = phase_start + ph.duration;
        if ph.rate_rps > 0.0 {
            let mut cursor = phase_start;
            loop {
                let dt = -(1.0 - rng.next_f64()).ln() / ph.rate_rps;
                cursor += Duration::from_secs_f64(dt);
                if cursor >= phase_end {
                    break;
                }
                sleep_until(cursor);
                let x_q: Vec<u8> = (0..in_dim).map(|_| rng.below(256) as u8).collect();
                submitted += 1;
                match handle.submit_q(x_q) {
                    Ok(t) => {
                        let _ = tick_tx.send(t);
                    }
                    Err(PoolError::QueueFull) => shed_at_submit += 1,
                    Err(PoolError::Closed) => {
                        failed_at_submit += 1;
                        break 'phases;
                    }
                    Err(_) => failed_at_submit += 1,
                }
            }
        }
        sleep_until(phase_end);
        phase_start = phase_end;
    }
    drop(tick_tx);
    let (m, ok, shed_in_flight, failed_in_flight) = collector.join().expect("collector");
    let wall = t0.elapsed();
    LoadReport {
        scenario: scenario.name.clone(),
        submitted,
        ok,
        shed: shed_at_submit + shed_in_flight,
        failed: failed_at_submit + failed_in_flight,
        wall,
        offered_rps: scenario.offered_rps(),
        achieved_rps: ok as f64 / wall.as_secs_f64(),
        latency: m.latency(),
    }
}

/// Closed-loop saturation: `clients` threads hammer the pool (submit,
/// wait, repeat) until `duration` elapses — or until a thread has issued
/// `per_client` requests, when a budget is given. Measures peak service
/// capacity rather than behaviour at a fixed offered rate; `offered_rps`
/// is the attempt rate (including shed), `achieved_rps` the completion
/// rate.
pub fn closed_loop(
    handle: &PoolHandle,
    clients: usize,
    duration: Duration,
    per_client: Option<usize>,
    seed: u64,
) -> LoadReport {
    let in_dim = handle.in_dim();
    let t0 = Instant::now();
    let deadline = t0 + duration;
    let budget = per_client.unwrap_or(usize::MAX);
    let mut threads = Vec::with_capacity(clients);
    for c in 0..clients {
        let h = handle.clone();
        threads.push(thread::spawn(move || {
            let mut rng = Rng::new(seed.wrapping_add((c as u64).wrapping_mul(0x9E37_79B9)));
            let mut m = Metrics::default();
            let (mut ok, mut shed, mut failed) = (0u64, 0u64, 0u64);
            let mut sent = 0usize;
            while sent < budget && Instant::now() < deadline {
                sent += 1;
                let x_q: Vec<u8> = (0..in_dim).map(|_| rng.below(256) as u8).collect();
                match h.infer_q(x_q) {
                    Ok(r) => {
                        ok += 1;
                        m.record_request(Duration::from_micros(r.latency_us));
                    }
                    Err(PoolError::QueueFull) => shed += 1,
                    Err(PoolError::Closed) => {
                        failed += 1;
                        break;
                    }
                    Err(_) => failed += 1,
                }
            }
            (m, ok, shed, failed)
        }));
    }
    let mut merged = Metrics::default();
    let (mut ok, mut shed, mut failed) = (0u64, 0u64, 0u64);
    for t in threads {
        let (m, o, s, f) = t.join().expect("client thread");
        merged.merge(&m);
        ok += o;
        shed += s;
        failed += f;
    }
    let wall = t0.elapsed();
    LoadReport {
        scenario: "closed-loop".into(),
        submitted: ok + shed + failed,
        ok,
        shed,
        failed,
        wall,
        offered_rps: (ok + shed + failed) as f64 / wall.as_secs_f64(),
        achieved_rps: ok as f64 / wall.as_secs_f64(),
        latency: merged.latency(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArrayConfig;
    use crate::coordinator::{BatchPolicy, Pool, PoolConfig, ShedPolicy};
    use crate::kan::{Engine, QuantizedModel};

    fn tiny_pool(replicas: usize, queue_cap: usize, shed: ShedPolicy) -> Pool {
        let engine = Engine::new(QuantizedModel::synthetic("lg", &[4, 8, 3], 5, 3, 1));
        Pool::start(
            engine,
            PoolConfig {
                replicas,
                queue_cap,
                shed,
                policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
                sim_array: ArrayConfig::kan_sas(8, 8, 4, 8),
            },
        )
    }

    #[test]
    fn scenario_shapes() {
        let total = Duration::from_millis(1000);
        let s = Scenario::steady(100.0, total);
        assert_eq!(s.total_duration(), total);
        assert!((s.expected_arrivals() - 100.0).abs() < 1e-9);
        assert!((s.offered_rps() - 100.0).abs() < 1e-9);

        let d = Scenario::diurnal(10.0, 100.0, total);
        assert_eq!(d.phases.len(), 8);
        assert_eq!(d.total_duration(), total);
        let peak = d.phases.iter().map(|p| p.rate_rps).fold(0.0f64, f64::max);
        let low = d.phases.iter().map(|p| p.rate_rps).fold(f64::INFINITY, f64::min);
        assert!(peak > low, "ramp must actually ramp");
        assert!(peak <= 100.0 + 1e-9 && low >= 10.0 - 1e-9);

        let f = Scenario::flash_crowd(50.0, 4.0, total);
        assert_eq!(f.phases.len(), 3);
        assert!((f.phases[1].rate_rps - 200.0).abs() < 1e-9);
        assert_eq!(f.total_duration(), total);

        assert!(Scenario::by_name("steady", 10.0, total).is_some());
        assert!(Scenario::by_name("diurnal", 10.0, total).is_some());
        assert!(Scenario::by_name("flash-crowd", 10.0, total).is_some());
        assert!(Scenario::by_name("bogus", 10.0, total).is_none());
    }

    #[test]
    fn open_loop_conserves_outcomes() {
        let pool = tiny_pool(2, 64, ShedPolicy::RejectNew);
        let sc = Scenario::steady(2000.0, Duration::from_millis(150));
        let rep = run(&pool.handle(), &sc, 11);
        let stats = pool.shutdown();
        assert_eq!(rep.submitted, rep.ok + rep.shed + rep.failed, "every arrival has one outcome");
        assert!(rep.ok > 0, "a 2-replica pool must serve something at 2k rps");
        assert_eq!(rep.failed, 0, "healthy pool, valid inputs: no failures");
        assert_eq!(stats.completed, rep.ok);
        assert_eq!(stats.shed, rep.shed);
        assert_eq!(stats.submitted, rep.submitted);
        assert_eq!(rep.latency.unwrap().count as u64, rep.ok);
        assert_eq!(rep.scenario, "steady");
    }

    #[test]
    fn closed_loop_reports_capacity() {
        let pool = tiny_pool(2, 64, ShedPolicy::Block);
        let rep = closed_loop(&pool.handle(), 4, Duration::from_millis(120), None, 3);
        let stats = pool.shutdown();
        assert!(rep.ok > 0);
        assert_eq!(rep.shed, 0, "Block policy never sheds");
        assert_eq!(stats.completed, rep.ok);
        assert!(rep.achieved_rps > 0.0);
    }

    #[test]
    fn closed_loop_respects_request_budget() {
        let pool = tiny_pool(1, 64, ShedPolicy::Block);
        let rep = closed_loop(&pool.handle(), 3, Duration::from_secs(30), Some(5), 3);
        let stats = pool.shutdown();
        assert_eq!(rep.submitted, 15, "3 clients x 5 requests");
        assert_eq!(rep.ok, 15);
        assert_eq!(stats.completed, 15);
        assert!(rep.wall < Duration::from_secs(30), "budget ends the run, not the deadline");
    }
}
