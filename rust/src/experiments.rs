//! Experiment drivers: one function per paper table/figure, shared by the
//! `kansas` CLI and the bench targets (DESIGN.md "experiment index").

use crate::arch::ArrayConfig;
use crate::arkane;
use crate::cost::{array_area_mm2, normalized_energy, PeCost};
use crate::report::{write_csv, AsciiPlot, Table};
use crate::sim::{analytic, SimStats};
use crate::sim::workload::Workload;
use crate::workloads;

/// Utilization for Figs. 7a/8 is measured over the *spline* GEMMs — the
/// B-spline sparsity effect the figures isolate. (The paper's
/// conventional-SA MNIST-KAN utilization of ~30% equals the 4/13 density
/// bound exactly, which the dense base-term GEMMs would otherwise lift
/// to ~35%.) Runtime (Fig. 7b) includes every GEMM, base terms and all.
fn spline_util(cfg: &crate::arch::ArrayConfig, wls: &[Workload]) -> f64 {
    let spline: Vec<Workload> =
        wls.iter().filter(|w| w.kind.is_kan()).cloned().collect();
    analytic::simulate_app(cfg, &spline).utilization()
}

/// Table I: PE delay / power / normalized energy across N:M points.
pub fn table1() -> Table {
    let points = [(1usize, 1usize), (1, 2), (2, 4), (2, 6), (4, 6), (4, 8)];
    let mut t = Table::new(&["N:M", "Delay (ns)", "Power (mW)", "Norm. Energy", "Area (um^2)"])
        .with_title("Table I — PE synthesis model (ST28nm anchors; 8-bit in, 32-bit acc, 500 MHz)");
    for (n, m) in points {
        let c = PeCost::of_nm(n, m);
        t.row(vec![
            if (n, m) == (1, 1) { "1:1 (scalar)".into() } else { format!("{n}:{m}") },
            format!("{:.2}", c.delay_ns),
            format!("{:.2}", c.power_mw),
            format!("{:.2}", normalized_energy(n, m)),
            format!("{:.0}", c.area_um2),
        ]);
    }
    t
}

/// Table II: the collected KAN workloads.
pub fn table2() -> Table {
    let mut t = Table::new(&["Application", "Layers", "G", "P", "GEMMs", "MACs (dense)"])
        .with_title("Table II — collected KAN workloads");
    for app in workloads::table2() {
        let wls = workloads::app_workloads(&app, workloads::DEFAULT_BS, None);
        let layers = if app.name == "ResKAN18" {
            "20 ConvKAN layers".to_string()
        } else {
            app.nets
                .iter()
                .map(|n| format!("{n:?}"))
                .collect::<Vec<_>>()
                .join(", ")
        };
        let macs: u64 = wls.iter().map(|w| w.dense_macs()).sum();
        t.row(vec![
            app.name.to_string(),
            layers,
            app.g.to_string(),
            app.p.to_string(),
            wls.len().to_string(),
            macs.to_string(),
        ]);
    }
    t
}

/// One point of the Fig. 7 design-space sweep.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub cfg: ArrayConfig,
    pub area_mm2: f64,
    /// Mean PE utilization across applications.
    pub mean_util: f64,
    /// Mean runtime (cycles) across applications.
    pub mean_cycles: f64,
}

/// The array sizes swept in Fig. 7 (square points are the paper's
/// markers; rectangular points fill the curve).
pub fn fig7_sizes() -> Vec<(usize, usize)> {
    vec![
        (2, 2), (2, 4), (4, 4), (4, 8), (8, 8), (8, 16), (16, 16), (16, 32), (32, 32),
    ]
}

/// Fig. 7 sweep for one PE family. `kan_sas = false` -> conventional
/// scalar arrays; `true` -> 4:8 vector arrays (G=5, P=3 override, as the
/// paper fixes).
pub fn fig7_sweep(kan_sas: bool) -> Vec<SweepPoint> {
    let apps = workloads::fig7_workloads();
    fig7_sizes()
        .into_iter()
        .map(|(r, c)| {
            let cfg = if kan_sas {
                ArrayConfig::kan_sas(r, c, 4, 8)
            } else {
                ArrayConfig::conventional(r, c)
            };
            let per_app: Vec<SimStats> = apps
                .iter()
                .map(|(_, wls)| analytic::simulate_app(&cfg, wls))
                .collect();
            let mean_util = apps.iter().map(|(_, wls)| spline_util(&cfg, wls)).sum::<f64>()
                / apps.len() as f64;
            let mean_cycles =
                per_app.iter().map(|s| s.cycles as f64).sum::<f64>() / per_app.len() as f64;
            SweepPoint { cfg, area_mm2: array_area_mm2(&cfg), mean_util, mean_cycles }
        })
        .collect()
}

/// Render Fig. 7a (utilization vs area) and 7b (cycles vs area), write
/// CSVs next to `out_dir`, and return the ASCII plots.
pub fn fig7(out_dir: Option<&std::path::Path>) -> (String, String) {
    let conv = fig7_sweep(false);
    let kan = fig7_sweep(true);
    let ua = AsciiPlot::new(
        "Fig. 7a — avg PE utilization vs area (G=5, P=3, all apps except MNIST-KAN)",
        "area mm^2",
        "utilization",
    )
    .log_axes(true, false)
    .series("conventional SA", 'o', conv.iter().map(|p| (p.area_mm2, p.mean_util)).collect())
    .series("KAN-SAs", '#', kan.iter().map(|p| (p.area_mm2, p.mean_util)).collect());
    let ub = AsciiPlot::new(
        "Fig. 7b — avg runtime (cycles) vs area",
        "area mm^2",
        "cycles",
    )
    .log_axes(true, true)
    .series("conventional SA", 'o', conv.iter().map(|p| (p.area_mm2, p.mean_cycles)).collect())
    .series("KAN-SAs", '#', kan.iter().map(|p| (p.area_mm2, p.mean_cycles)).collect());

    if let Some(dir) = out_dir {
        let rows: Vec<Vec<String>> = conv
            .iter()
            .map(|p| ("conventional", p))
            .chain(kan.iter().map(|p| ("kan_sas", p)))
            .map(|(fam, p)| {
                vec![
                    fam.to_string(),
                    p.cfg.rows.to_string(),
                    p.cfg.cols.to_string(),
                    format!("{:.6}", p.area_mm2),
                    format!("{:.4}", p.mean_util),
                    format!("{:.1}", p.mean_cycles),
                ]
            })
            .collect();
        let _ = write_csv(
            &dir.join("fig7.csv"),
            &["family", "rows", "cols", "area_mm2", "mean_util", "mean_cycles"],
            &rows,
        );
    }
    (ua.render(), ub.render())
}

/// Fig. 8: per-application utilization, KAN-SAs 16x16 (per-app N:M) vs
/// conventional 32x32 — the paper's similar-area pair.
pub fn fig8() -> (Table, f64, Vec<(String, f64, f64)>) {
    let conv_cfg = ArrayConfig::conventional(32, 32);
    let mut t = Table::new(&[
        "Application", "conv 32x32 util %", "KAN-SAs 16x16 util %", "improvement pp",
    ])
    .with_title(format!(
        "Fig. 8 — PE utilization (conventional {:.2} mm^2 vs KAN-SAs 4:8 {:.2} mm^2)",
        array_area_mm2(&conv_cfg),
        array_area_mm2(&ArrayConfig::kan_sas(16, 16, 4, 8))
    )
    .as_str());
    let mut rows = Vec::new();
    let mut improvements = Vec::new();
    for (name, g, p, wls) in workloads::fig8_workloads() {
        let kan_cfg = ArrayConfig::kan_sas(16, 16, p + 1, g + p);
        let cu = spline_util(&conv_cfg, &wls);
        let ku = spline_util(&kan_cfg, &wls);
        improvements.push((ku - cu) * 100.0);
        rows.push((name.clone(), cu, ku));
        t.row(vec![
            name,
            format!("{:.1}", cu * 100.0),
            format!("{:.1}", ku * 100.0),
            format!("{:+.1}", (ku - cu) * 100.0),
        ]);
    }
    let avg = improvements.iter().sum::<f64>() / improvements.len() as f64;
    t.row(vec![
        "AVERAGE".into(),
        format!("{:.1}", rows.iter().map(|r| r.1).sum::<f64>() / rows.len() as f64 * 100.0),
        format!("{:.1}", rows.iter().map(|r| r.2).sum::<f64>() / rows.len() as f64 * 100.0),
        format!("{avg:+.1}"),
    ]);
    (t, avg, rows)
}

/// Sec. V-B: the ArKANe comparison.
pub fn arkane_comparison() -> Table {
    let mut t = Table::new(&[
        "M inputs", "ArKANe cycles", "tab. units (equal area)", "tab. cycles", "speedup x",
    ])
    .with_title("Sec. V-B — B-spline evaluation: tabulation vs ArKANe (G=5, P=3, equal area)");
    let units = arkane::units_in_arkane_area(3);
    for m_in in [72u64, 720, 7_200, 72_000, 720_000] {
        t.row(vec![
            m_in.to_string(),
            arkane::arkane_cycles(5, 3, m_in).to_string(),
            units.to_string(),
            arkane::tabulation_cycles(m_in, units).to_string(),
            format!("{:.1}", arkane::equal_area_speedup(5, 3, m_in)),
        ]);
    }
    t
}

/// Headline check used by tests and EXPERIMENTS.md: the equal-area cycle
/// ratio between conventional and KAN-SAs at matched area (Fig. 7b's
/// "~2x at the same area").
pub fn equal_area_cycle_ratio() -> f64 {
    // conventional 32x32 (0.50 mm^2) vs KAN-SAs 16x16 (0.47 mm^2)
    let apps = workloads::fig7_workloads();
    let conv = ArrayConfig::conventional(32, 32);
    let kan = ArrayConfig::kan_sas(16, 16, 4, 8);
    let c: f64 = apps.iter().map(|(_, w)| analytic::simulate_app(&conv, w).cycles as f64).sum();
    let k: f64 = apps.iter().map(|(_, w)| analytic::simulate_app(&kan, w).cycles as f64).sum();
    c / k
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders_all_points() {
        let s = table1().render();
        for label in ["1:1 (scalar)", "1:2", "2:4", "2:6", "4:6", "4:8"] {
            assert!(s.contains(label), "{label} missing:\n{s}");
        }
    }

    #[test]
    fn fig7_kan_dominates_everywhere() {
        let conv = fig7_sweep(false);
        let kan = fig7_sweep(true);
        for (c, k) in conv.iter().zip(&kan) {
            assert!(k.mean_util > c.mean_util, "{}", c.cfg.label());
        }
    }

    #[test]
    fn fig7_utilization_shrinks_with_array_size() {
        // imperfect tiling bites harder as arrays grow (paper Fig. 7a trend)
        let conv = fig7_sweep(false);
        assert!(conv.first().unwrap().mean_util > conv.last().unwrap().mean_util);
    }

    #[test]
    fn fig8_average_improvement_matches_paper_band() {
        // paper: 39.9% average absolute improvement, max 69.3% (MNIST-KAN)
        let (_t, avg, rows) = fig8();
        assert!(avg > 25.0 && avg < 55.0, "avg improvement {avg}pp");
        let mnist = rows.iter().find(|r| r.0 == "MNIST-KAN").unwrap();
        let delta = (mnist.2 - mnist.1) * 100.0;
        assert!(delta > 50.0, "MNIST-KAN improvement {delta}pp (paper: 69.3)");
        // MNIST-KAN conventional utilization ~30% (4/13 bound)
        assert!(mnist.1 < 0.31, "MNIST-KAN conv util {}", mnist.1);
        assert!(mnist.2 > 0.9, "MNIST-KAN KAN-SAs util {}", mnist.2);
    }

    #[test]
    fn equal_area_speedup_near_2x() {
        // paper Fig. 7b: ~2x cycles reduction at equal area
        let r = equal_area_cycle_ratio();
        assert!(r > 1.5 && r < 3.0, "equal-area cycle ratio {r}");
    }

    #[test]
    fn arkane_table_lists_72x() {
        let s = arkane_comparison().render();
        assert!(s.contains("72"), "{s}");
    }
}
