//! B-splines: the f64 Cox-de Boor reference, the quantized tabulation, and
//! the bit-accurate hardware B-spline unit (paper Sec. III-B).
//!
//! Correctness chain: `reference` mirrors `python/compile/kernels/ref.py`
//! (same recursion); `lut` mirrors `quantize.build_lut_q`; `unit` mirrors
//! `quantize.bspline_unit_q` exactly (same integer ops) and is replayed
//! against exported golden vectors in the integration tests. `packed` is
//! the paper-exact Fig. 5 half-table ROM with inverted addressing,
//! demonstrating the 2x storage saving at <=1 LSB cost.

pub mod lut;
pub mod packed;
pub mod reference;
pub mod unit;

pub use lut::Lut;
pub use unit::BsplineUnit;
