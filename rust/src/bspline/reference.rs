//! f64 Cox-de Boor reference evaluator (Eqs. 2-3 of the paper).
//!
//! Mirrors `python/compile/kernels/ref.py`; used as the oracle for the
//! integer unit and for property tests of the sparsity structure that the
//! simulator relies on (local support => at most P+1 non-zeros).

/// Extended uniform knot vector `t_0 .. t_{G+2P}` (paper Fig. 2): the
/// input domain `[lo, hi]` is `[t_P, t_{P+G}]`, extended by P intervals
/// on each side.
pub fn make_grid(g: usize, p: usize, lo: f64, hi: f64) -> Vec<f64> {
    assert!(g >= 1, "grid size G must be >= 1");
    assert!(hi > lo, "domain must satisfy hi > lo");
    let dx = (hi - lo) / g as f64;
    (0..=g + 2 * p)
        .map(|i| lo + dx * (i as f64 - p as f64))
        .collect()
}

/// Number of degree-P basis functions on the extended grid: `G + P`.
pub fn num_bases(g: usize, p: usize) -> usize {
    g + p
}

/// Evaluate all `G+P` degree-`p` B-splines at `x` via the Cox-de Boor
/// recursion. `knots` must come from [`make_grid`].
pub fn cox_de_boor(x: f64, knots: &[f64], p: usize) -> Vec<f64> {
    let n_int = knots.len() - 1; // G + 2P intervals
    // degree 0: indicators (final interval right-closed)
    let mut b: Vec<f64> = (0..n_int)
        .map(|i| {
            let inside = x >= knots[i] && x < knots[i + 1];
            let last = i == n_int - 1 && x == knots[i + 1];
            if inside || last {
                1.0
            } else {
                0.0
            }
        })
        .collect();
    for d in 1..=p {
        let n = n_int - d;
        let mut next = vec![0.0; n];
        for i in 0..n {
            let dl = knots[i + d] - knots[i];
            let dr = knots[i + d + 1] - knots[i + 1];
            let wl = if dl > 0.0 { (x - knots[i]) / dl } else { 0.0 };
            let wr = if dr > 0.0 { (knots[i + d + 1] - x) / dr } else { 0.0 };
            next[i] = wl * b[i] + wr * b[i + 1];
        }
        b = next;
    }
    b
}

/// Cardinal B-spline `B_{0,P}` on integer knots `0..=P+1` — the function
/// the hardware tabulates (translation/scale invariance, Eq. 4).
pub fn cardinal_bspline(u: f64, p: usize) -> f64 {
    if !(0.0..(p as f64 + 1.0)).contains(&u) {
        return 0.0;
    }
    let mut b: Vec<f64> = (0..=p)
        .map(|i| if u >= i as f64 && u < i as f64 + 1.0 { 1.0 } else { 0.0 })
        .collect();
    for d in 1..=p {
        let n = (p + 1) - d;
        let mut next = vec![0.0; n];
        for i in 0..n {
            let wl = (u - i as f64) / d as f64;
            let wr = ((i + d + 1) as f64 - u) / d as f64;
            next[i] = wl * b[i] + wr * b[i + 1];
        }
        b = next;
    }
    b[0]
}

/// Peak value of the cardinal spline (at the support midpoint); the
/// quantized LUT maps this to 255.
pub fn cardinal_peak(p: usize) -> f64 {
    cardinal_bspline((p as f64 + 1.0) / 2.0, p)
}

/// Interval index k with `x in [t_k, t_{k+1})`, clamped into the input
/// domain: k in `[P, G+P-1]` (the hardware Compare unit).
pub fn interval_index(x: f64, g: usize, p: usize, lo: f64, hi: f64) -> usize {
    let dx = (hi - lo) / g as f64;
    let u = ((x.clamp(lo, hi)) - lo) / dx;
    (u.floor() as usize).min(g - 1) + p
}

/// The N:M sparse view: values of the `P+1` (potentially) non-zero bases
/// `B_{k-P} .. B_k` plus the index k.
pub fn nonzero_bases(x: f64, g: usize, p: usize, lo: f64, hi: f64) -> (Vec<f64>, usize) {
    let knots = make_grid(g, p, lo, hi);
    let dense = cox_de_boor(x.clamp(lo, hi), &knots, p);
    let k = interval_index(x, g, p, lo, hi);
    let vals = (0..=p).map(|j| dense[k - p + j]).collect();
    (vals, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{check, Rng};

    #[test]
    fn partition_of_unity() {
        for (g, p) in [(5, 3), (3, 3), (10, 3), (4, 1), (6, 2), (1, 0)] {
            let knots = make_grid(g, p, -1.0, 1.0);
            for i in 0..=100 {
                let x = -1.0 + 2.0 * i as f64 / 100.0;
                let sum: f64 = cox_de_boor(x, &knots, p).iter().sum();
                assert!((sum - 1.0).abs() < 1e-12, "g={g} p={p} x={x} sum={sum}");
            }
        }
    }

    #[test]
    fn local_support_at_most_p_plus_1() {
        check(200, 21, |rng: &mut Rng| {
            let g = 1 + rng.below(12);
            let p = rng.below(4);
            let x = rng.uniform(-1.0, 1.0);
            let knots = make_grid(g, p, -1.0, 1.0);
            let nnz = cox_de_boor(x, &knots, p).iter().filter(|v| **v > 1e-14).count();
            assert!(nnz <= p + 1, "g={g} p={p} x={x} nnz={nnz}");
        });
    }

    #[test]
    fn nonzero_window_covers_all_mass() {
        check(200, 22, |rng: &mut Rng| {
            let g = 1 + rng.below(10);
            let p = 1 + rng.below(3);
            let x = rng.uniform(-1.5, 1.5);
            let (vals, _k) = nonzero_bases(x, g, p, -1.0, 1.0);
            let sum: f64 = vals.iter().sum();
            assert!((sum - 1.0).abs() < 1e-12, "window must sum to 1, got {sum}");
        });
    }

    #[test]
    fn cardinal_symmetry_and_peak() {
        for p in 1..=4 {
            for i in 0..=200 {
                let u = (p as f64 + 1.0) * i as f64 / 200.0;
                let a = cardinal_bspline(u, p);
                let b = cardinal_bspline(p as f64 + 1.0 - u, p);
                assert!((a - b).abs() < 1e-12, "p={p} u={u}");
            }
            assert!(cardinal_peak(p) > 0.0);
        }
        // known closed-form values for the cubic
        assert!((cardinal_bspline(1.0, 3) - 1.0 / 6.0).abs() < 1e-12);
        assert!((cardinal_bspline(2.0, 3) - 2.0 / 3.0).abs() < 1e-12);
        assert!((cardinal_peak(3) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn translation_invariance_eq4() {
        // B_{t_i,P}(x) == B_{0,P}((x - t_0)/dx - i)
        let (g, p) = (5usize, 3usize);
        let knots = make_grid(g, p, -1.0, 1.0);
        let dx = 2.0 / g as f64;
        check(100, 23, |rng: &mut Rng| {
            let x = rng.uniform(-1.0, 1.0 - 1e-9);
            let dense = cox_de_boor(x, &knots, p);
            let u = (x + 1.0) / dx + p as f64;
            for (i, &want) in dense.iter().enumerate() {
                let got = cardinal_bspline(u - i as f64, p);
                assert!((got - want).abs() < 1e-12, "i={i} x={x}");
            }
        });
    }

    #[test]
    fn interval_index_clamps() {
        assert_eq!(interval_index(-9.0, 5, 3, -1.0, 1.0), 3);
        assert_eq!(interval_index(9.0, 5, 3, -1.0, 1.0), 7);
        assert_eq!(interval_index(0.0, 5, 3, -1.0, 1.0), 5); // middle of G=5
    }

    #[test]
    fn matches_python_oracle_spot_values() {
        // values computed with python/compile/kernels/ref.py for g=5,p=3
        let knots = make_grid(5, 3, -1.0, 1.0);
        let b = cox_de_boor(0.1, &knots, 3);
        let sum: f64 = b.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert_eq!(b.len(), 8);
    }
}
