//! Quantized tabulation of the cardinal B-spline (the unit's ROM).
//!
//! Mirrors `python/compile/quantize.py::build_lut_q` bit-for-bit:
//! `LUT[a][j] = round(B_{0,P}(a/256 + P - j) / s_B)` with
//! `s_B = peak / 255` — column j is in *ascending* basis order
//! (`k - P + j`), i.e. the hardware's reverse-packed read is already
//! resolved. 256 rows = the paper's 8-bit address.

use crate::bspline::reference::{cardinal_bspline, cardinal_peak};
use crate::util::round_clamp;

pub const LUT_SIZE: usize = 256;

#[derive(Clone, Debug)]
pub struct Lut {
    /// Row-major `(256, P+1)` uint8 table.
    values: Vec<u8>,
    /// Spline degree P.
    pub degree: usize,
    /// Dequantization scale: stored `v` represents `v * scale`.
    pub scale: f64,
}

impl Lut {
    /// Build the table for degree `p` (P >= 1; P=0 is a discontinuous
    /// indicator the 8-bit address cannot represent — same restriction as
    /// the python kernel).
    pub fn build(p: usize) -> Self {
        assert!(p >= 1, "tabulated unit requires degree P >= 1");
        let peak = cardinal_peak(p);
        let scale = peak / 255.0;
        let mut values = Vec::with_capacity(LUT_SIZE * (p + 1));
        for a in 0..LUT_SIZE {
            let xa = a as f64 / LUT_SIZE as f64;
            for j in 0..=p {
                let u = xa + (p - j) as f64;
                values.push(round_clamp(cardinal_bspline(u, p) / scale, 0, 255) as u8);
            }
        }
        Self { values, degree: p, scale }
    }

    /// Load a table exported by python (`l<i>.lut` tensor in a .kanq).
    pub fn from_raw(values: Vec<u8>, degree: usize, scale: f64) -> Self {
        assert_eq!(values.len(), LUT_SIZE * (degree + 1), "lut size mismatch");
        Self { values, degree, scale }
    }

    /// Row `addr`: the `P+1` non-zero basis values (ascending basis order).
    #[inline]
    pub fn row(&self, addr: u8) -> &[u8] {
        let w = self.degree + 1;
        &self.values[addr as usize * w..(addr as usize + 1) * w]
    }

    pub fn raw(&self) -> &[u8] {
        &self.values
    }

    /// ROM size in bits (for the cost model: the paper's unit stores half
    /// of this thanks to symmetry — see `packed`).
    pub fn rom_bits(&self) -> usize {
        self.values.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bspline::reference::cardinal_bspline;

    #[test]
    fn matches_reference_within_lsb() {
        for p in 1..=3 {
            let lut = Lut::build(p);
            for a in 0..LUT_SIZE {
                let xa = a as f64 / 256.0;
                for j in 0..=p {
                    let want = cardinal_bspline(xa + (p - j) as f64, p);
                    let got = lut.row(a as u8)[j] as f64 * lut.scale;
                    assert!(
                        (got - want).abs() <= lut.scale / 2.0 + 1e-12,
                        "p={p} a={a} j={j}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn full_range_quantization() {
        for p in 1..=3 {
            let lut = Lut::build(p);
            assert_eq!(lut.raw().iter().copied().max(), Some(255), "p={p}");
        }
    }

    #[test]
    fn rows_sum_to_one() {
        // partition of unity survives quantization to ~1 LSB per entry
        let lut = Lut::build(3);
        for a in 0..LUT_SIZE {
            let sum: f64 = lut.row(a as u8).iter().map(|&v| v as f64 * lut.scale).sum();
            assert!((sum - 1.0).abs() < 4.0 * lut.scale, "a={a} sum={sum}");
        }
    }

    #[test]
    fn rom_bits_p3() {
        assert_eq!(Lut::build(3).rom_bits(), 256 * 4 * 8);
    }

    #[test]
    #[should_panic(expected = "P >= 1")]
    fn degree_zero_rejected() {
        Lut::build(0);
    }
}
