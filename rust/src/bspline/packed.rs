//! The paper-exact Fig. 5 ROM: half-table storage with inverted addressing.
//!
//! Symmetry of the cardinal spline about `(P+1)/2` means only the interval
//! `[0, (P+1)/2]` needs storing. For the cubic (P=3) case the hardware
//! stores 256 rows of *two* packed values `(B(x_a), B(x_a + 1))` covering
//! `[0, 2]`; a read at `addr` yields the values for basis indices `k` and
//! `k-1`, and a second read at the bitwise complement `~addr` yields — in
//! reverse order — the values for `k-2` and `k-3`:
//!
//! ```text
//! B(x_a + 2) = B(2 - x_a) ~= row[~addr][1]
//! B(x_a + 3) = B(1 - x_a) ~= row[~addr][0]
//! ```
//!
//! `~addr = 255 - addr` maps `x_a -> (255 - 256*x_a)/256 = 1 - x_a - 1/256`,
//! one address LSB away from the exact mirror, so the packed unit is
//! allowed a 1-2 LSB deviation from the full table (`Lut`). The paper's
//! example values (0, 32 at addr 0; reversed 127, 32 at ~addr) correspond
//! to rows of this ROM. Storage: 256 x 2 bytes vs 256 x 4 — the 2x saving
//! the paper's 450 um^2 unit area assumes.

use super::lut::{Lut, LUT_SIZE};
use crate::bspline::reference::{cardinal_bspline, cardinal_peak};
use crate::util::round_clamp;

/// Half-table ROM for cubic (P=3) B-splines, as synthesized in the paper.
#[derive(Clone, Debug)]
pub struct PackedLut {
    /// 256 rows x 2 packed values: `(B(x_a), B(x_a + 1))`.
    rows: Vec<[u8; 2]>,
    pub scale: f64,
}

impl PackedLut {
    pub fn build() -> Self {
        let p = 3;
        let peak = cardinal_peak(p);
        let scale = peak / 255.0;
        let rows = (0..LUT_SIZE)
            .map(|a| {
                let xa = a as f64 / LUT_SIZE as f64;
                [
                    round_clamp(cardinal_bspline(xa, p) / scale, 0, 255) as u8,
                    round_clamp(cardinal_bspline(xa + 1.0, p) / scale, 0, 255) as u8,
                ]
            })
            .collect();
        Self { rows, scale }
    }

    /// One evaluation: returns the 4 non-zero cubic basis values in
    /// ascending basis order `k-3 .. k` (matching `Lut::row` + flip).
    #[inline]
    pub fn fetch(&self, addr: u8) -> [u8; 4] {
        let direct = self.rows[addr as usize]; // (B(x_a), B(x_a+1)) -> bases k, k-1
        let mirror = self.rows[!addr as usize]; // ~addr: bases k-2, k-3 reversed
        // ascending order k-3, k-2, k-1, k:
        [mirror[0], mirror[1], direct[1], direct[0]]
    }

    /// ROM size in bits: half of the full table.
    pub fn rom_bits(&self) -> usize {
        self.rows.len() * 2 * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn within_two_lsb_of_full_table() {
        let full = Lut::build(3);
        let packed = PackedLut::build();
        for a in 0..=255u8 {
            let want = full.row(a);
            let got = packed.fetch(a);
            for j in 0..4 {
                let d = (want[j] as i32 - got[j] as i32).abs();
                assert!(d <= 2, "addr={a} j={j}: packed {} vs full {}", got[j], want[j]);
            }
        }
    }

    #[test]
    fn paper_example_addr_zero() {
        // Fig. 5: at x_addr = 0 the direct read is (0, 32)-like: B(0) = 0
        // and B(1) = 1/6 -> small; the mirrored read gives the peak-side
        // values in reverse.
        let packed = PackedLut::build();
        let row = packed.rows[0];
        assert_eq!(row[0], 0); // B(0) = 0
        assert!(row[1] > 0 && row[1] < 80); // B(1) = 1/6 scaled
        let out = packed.fetch(0);
        // ascending k-3..k: B(1-0)=B(1), B(2-0)=B(2)=peak-ish, B(1), B(0)
        assert_eq!(out[3], 0);
        assert!(out[1] >= 250); // B(2) = 2/3 = peak -> 255 region
    }

    #[test]
    fn storage_is_half() {
        assert_eq!(PackedLut::build().rom_bits() * 2, Lut::build(3).rom_bits());
    }

    #[test]
    fn symmetric_pairs() {
        // fetch(a) ascending == reverse of fetch at the mirrored address,
        // up to the 1-LSB addressing skew tolerance
        let packed = PackedLut::build();
        for a in 0..=255u8 {
            let fwd = packed.fetch(a);
            let bwd = packed.fetch(!a);
            for j in 0..4 {
                let d = (fwd[j] as i32 - bwd[3 - j] as i32).abs();
                assert!(d <= 2, "addr={a} j={j}");
            }
        }
    }
}
