//! The bit-accurate B-spline unit (paper Fig. 5): Compare -> Align -> LUT.
//!
//! Identical integer arithmetic to `python/compile/quantize.py::
//! bspline_unit_q` (golden-tested):
//!
//! ```text
//! ki   = (x_q * G) >> 8          Compare: interval search over the grid
//! addr = x_q * G - (ki << 8)     Align: Eq. 5 — fractional part * 256
//! vals = LUT[addr]               one-cycle fetch of all P+1 non-zeros
//! k    = ki + P                  index streamed to the N:M PEs (Fig. 6)
//! ```
//!
//! The unit is the component the paper sizes at 450 um^2 and credits with
//! the >= 72x speedup over ArKANe's recursive dataflow (Sec. V-B): one
//! fetch yields *all* `G+P` basis values (the other `G-1` are exactly
//! zero by local support).

use super::lut::Lut;

/// Output of one evaluation: the P+1 (potentially) non-zero activations
/// in ascending basis order `k-P .. k`, plus the interval index k.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseActivations {
    pub vals: Vec<u8>,
    pub k: usize,
}

#[derive(Clone, Debug)]
pub struct BsplineUnit {
    lut: Lut,
    g: usize,
    p: usize,
}

impl BsplineUnit {
    pub fn new(lut: Lut, g: usize) -> Self {
        assert!(g >= 1);
        let p = lut.degree;
        Self { lut, g, p }
    }

    pub fn grid(&self) -> usize {
        self.g
    }

    pub fn degree(&self) -> usize {
        self.p
    }

    pub fn lut(&self) -> &Lut {
        &self.lut
    }

    /// Evaluate one quantized input. Pure integer ops; one "cycle".
    #[inline]
    pub fn eval(&self, x_q: u8) -> SparseActivations {
        let (vals, k) = self.eval_into(x_q);
        SparseActivations { vals: vals.to_vec(), k }
    }

    /// Allocation-free variant used by the hot loops: returns the LUT row
    /// slice directly plus k.
    #[inline]
    pub fn eval_into(&self, x_q: u8) -> (&[u8], usize) {
        let xq = x_q as usize;
        let ki = (xq * self.g) >> 8; // in [0, G-1] since x_q <= 255
        let addr = (xq * self.g - (ki << 8)) as u8;
        (self.lut.row(addr), ki + self.p)
    }

    /// Evaluate a batch of rows: `(BS, K)` u8 -> vals `(BS, K, P+1)` and
    /// k `(BS, K)`.
    pub fn eval_batch(&self, x_q: &[u8]) -> (Vec<u8>, Vec<usize>) {
        let mut vals = Vec::new();
        let mut ks = Vec::new();
        self.eval_batch_into(x_q, &mut vals, &mut ks);
        (vals, ks)
    }

    /// Batch evaluation into caller-owned buffers (cleared first) —
    /// allocation-free once the buffers have warmed up, for callers that
    /// stream many batches through one pair of arenas.
    pub fn eval_batch_into(&self, x_q: &[u8], vals: &mut Vec<u8>, ks: &mut Vec<usize>) {
        let n = self.p + 1;
        vals.clear();
        vals.reserve(x_q.len() * n);
        ks.clear();
        ks.reserve(x_q.len());
        for &x in x_q {
            let (row, k) = self.eval_into(x);
            vals.extend_from_slice(row);
            ks.push(k);
        }
    }

    /// Scatter one evaluation to the dense `G+P` vector (what a
    /// conventional SA would consume) — used by the simulator's
    /// conventional-SA path and by equivalence tests.
    pub fn eval_dense(&self, x_q: u8) -> Vec<u8> {
        let mut out = vec![0u8; self.g + self.p];
        let (vals, k) = self.eval_into(x_q);
        for (j, &v) in vals.iter().enumerate() {
            out[k - self.p + j] = v;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bspline::reference;
    use crate::util::rng::{check, Rng};

    fn unit(g: usize, p: usize) -> BsplineUnit {
        BsplineUnit::new(Lut::build(p), g)
    }

    #[test]
    fn matches_float_oracle() {
        // same tolerance budget as python/tests/test_quantize.py
        for (g, p) in [(5, 3), (3, 3), (10, 3), (4, 1), (6, 2)] {
            let u = unit(g, p);
            let tol = u.lut().scale + (g as f64 / 256.0) * 1.1;
            for xq in 0..=255u8 {
                let x = (xq as f64 - 128.0) / 128.0;
                let (vals, k) = u.eval_into(xq);
                let (rvals, rk) = reference::nonzero_bases(x, g, p, -1.0, 1.0);
                assert_eq!(k, rk, "g={g} p={p} xq={xq}");
                for (j, (&v, &rv)) in vals.iter().zip(&rvals).enumerate() {
                    let got = v as f64 * u.lut().scale;
                    assert!((got - rv).abs() <= tol, "g={g} p={p} xq={xq} j={j}: {got} vs {rv}");
                }
            }
        }
    }

    #[test]
    fn k_in_valid_range() {
        check(300, 31, |rng: &mut Rng| {
            let g = 1 + rng.below(12);
            let p = 1 + rng.below(3);
            let u = unit(g, p);
            let (_vals, k) = u.eval_into(rng.below(256) as u8);
            assert!(k >= p && k <= g + p - 1, "g={g} p={p} k={k}");
        });
    }

    #[test]
    fn dense_scatter_preserves_values() {
        check(100, 32, |rng: &mut Rng| {
            let g = 1 + rng.below(10);
            let p = 1 + rng.below(3);
            let u = unit(g, p);
            let xq = rng.below(256) as u8;
            let dense = u.eval_dense(xq);
            let (vals, k) = u.eval_into(xq);
            assert_eq!(dense.len(), g + p);
            let sum_d: u32 = dense.iter().map(|&v| v as u32).sum();
            let sum_v: u32 = vals.iter().map(|&v| v as u32).sum();
            assert_eq!(sum_d, sum_v);
            for (j, &v) in vals.iter().enumerate() {
                assert_eq!(dense[k - p + j], v);
            }
            // everything outside the window is zero (local support)
            for (i, &v) in dense.iter().enumerate() {
                if i + p < k || i > k {
                    assert_eq!(v, 0, "leak at basis {i} (k={k})");
                }
            }
        });
    }

    #[test]
    fn partition_of_unity_quantized() {
        let u = unit(5, 3);
        for xq in 0..=255u8 {
            let (vals, _) = u.eval_into(xq);
            let sum: f64 = vals.iter().map(|&v| v as f64 * u.lut().scale).sum();
            assert!((sum - 1.0).abs() < 0.02, "xq={xq} sum={sum}");
        }
    }

    #[test]
    fn batch_matches_scalar() {
        let u = unit(7, 2);
        let xs: Vec<u8> = (0..=255).collect();
        let (vals, ks) = u.eval_batch(&xs);
        for (i, &x) in xs.iter().enumerate() {
            let (v, k) = u.eval_into(x);
            assert_eq!(&vals[i * 3..(i + 1) * 3], v);
            assert_eq!(ks[i], k);
        }
    }

    #[test]
    fn batch_into_reuses_buffers() {
        let u = unit(5, 3);
        let (mut vals, mut ks) = (Vec::new(), Vec::new());
        u.eval_batch_into(&[0, 128, 255], &mut vals, &mut ks);
        assert_eq!((vals.clone(), ks.clone()), u.eval_batch(&[0, 128, 255]));
        // a second, smaller batch through the same buffers: cleared, not appended
        u.eval_batch_into(&[7], &mut vals, &mut ks);
        assert_eq!((vals, ks), u.eval_batch(&[7]));
    }

    #[test]
    fn edge_inputs() {
        let u = unit(5, 3);
        assert_eq!(u.eval_into(0).1, 3); // first interval -> k = P
        assert_eq!(u.eval_into(255).1, 7); // last interval -> k = G+P-1
    }
}
