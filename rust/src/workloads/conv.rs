//! ResKAN18: ResNet-18 with ConvKAN layers (every scalar conv weight
//! replaced by a learnable spline), im2col-lowered to KAN GEMMs.
//!
//! CIFAR-10 geometry (32x32 input, conv1 kept 3x3/stride-1 as usual for
//! CIFAR variants). 20 ConvKAN layers, matching the paper's count:
//! conv1, 16 block convs (4 stages x 2 basic blocks x 2 convs), and 3
//! 1x1 downsample convs (stages 2-4).

use crate::sim::workload::Workload;

/// (name, c_in, c_out, kernel, stride, input HxW)
const LAYERS: &[(&str, usize, usize, usize, usize, usize)] = &[
    ("conv1", 3, 64, 3, 1, 32),
    // stage 1: 64 -> 64, 32x32
    ("s1b1c1", 64, 64, 3, 1, 32),
    ("s1b1c2", 64, 64, 3, 1, 32),
    ("s1b2c1", 64, 64, 3, 1, 32),
    ("s1b2c2", 64, 64, 3, 1, 32),
    // stage 2: 64 -> 128, stride 2 (16x16), + 1x1 downsample
    ("s2b1c1", 64, 128, 3, 2, 32),
    ("s2b1c2", 128, 128, 3, 1, 16),
    ("s2ds", 64, 128, 1, 2, 32),
    ("s2b2c1", 128, 128, 3, 1, 16),
    ("s2b2c2", 128, 128, 3, 1, 16),
    // stage 3: 128 -> 256, stride 2 (8x8), + 1x1 downsample
    ("s3b1c1", 128, 256, 3, 2, 16),
    ("s3b1c2", 256, 256, 3, 1, 8),
    ("s3ds", 128, 256, 1, 2, 16),
    ("s3b2c1", 256, 256, 3, 1, 8),
    ("s3b2c2", 256, 256, 3, 1, 8),
    // stage 4: 256 -> 512, stride 2 (4x4), + 1x1 downsample
    ("s4b1c1", 256, 512, 3, 2, 8),
    ("s4b1c2", 512, 512, 3, 1, 4),
    ("s4ds", 256, 512, 1, 2, 8),
    ("s4b2c1", 512, 512, 3, 1, 4),
    ("s4b2c2", 512, 512, 3, 1, 4),
];

/// im2col: a conv over `HxW` with stride `s` yields `(H/s)*(W/s)`
/// activation rows of `c_in * k * k` features; ConvKAN expands each
/// feature into its `G+P` B-spline activations.
pub fn reskan18_workloads(g: usize, p: usize) -> Vec<Workload> {
    LAYERS
        .iter()
        .map(|&(name, cin, cout, k, s, hw)| {
            let out_hw = hw / s;
            let rows = out_hw * out_hw; // one image (see module docs)
            let feats = cin * k * k;
            Workload::kan(&format!("ResKAN18/{name}"), rows, feats, cout, g, p)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exactly_twenty_layers() {
        assert_eq!(LAYERS.len(), 20); // paper Table II: "20 ConvKAN layers"
        assert_eq!(reskan18_workloads(3, 3).len(), 20);
    }

    #[test]
    fn conv1_shape() {
        let wls = reskan18_workloads(3, 3);
        assert_eq!(wls[0].bs, 32 * 32);
        assert_eq!(wls[0].k_feats, 3 * 9);
        assert_eq!(wls[0].n_out, 64);
        assert_eq!(wls[0].expanded_reduction(), 27 * 6);
    }

    #[test]
    fn downsample_is_1x1() {
        let wls = reskan18_workloads(3, 3);
        let ds = wls.iter().find(|w| w.name.contains("s2ds")).unwrap();
        assert_eq!(ds.k_feats, 64); // 1x1 kernel: c_in features
        assert_eq!(ds.bs, 16 * 16); // stride 2 halves the map
    }

    #[test]
    fn strides_shrink_rows() {
        let wls = reskan18_workloads(3, 3);
        let s4 = wls.iter().find(|w| w.name.contains("s4b2c2")).unwrap();
        assert_eq!(s4.bs, 16); // 4x4 map
        assert_eq!(s4.k_feats, 512 * 9);
    }
}
