//! The Table II application registry: every KAN application the paper
//! collects from prior work, expanded into the GEMM workloads (spline
//! term + MLP base term) that the simulator executes.
//!
//! Parameters the paper leaves implicit are fixed here and documented:
//! * batch size: 32 rows per fully-connected workload (the paper sweeps
//!   none; BS only shifts the fill/drain amortization identically for
//!   both arrays);
//! * Catch22-KAN's X (UCR class count, "< 60"): 10;
//! * CF-KAN's X: the paper's three dataset sizes, we default to 6969;
//! * ConvKAN (ResKAN18): im2col lowering with one CIFAR-10 image
//!   (32x32), so a conv contributes `H*W x Cin*k*k` activation rows.

pub mod conv;

use crate::sim::workload::Workload;

/// Default batch rows for fully-connected workloads.
pub const DEFAULT_BS: usize = 32;

/// One collected application: a set of networks with shared (G, P).
#[derive(Clone, Debug)]
pub struct App {
    pub name: &'static str,
    /// Each inner vec is one network's layer widths.
    pub nets: Vec<Vec<usize>>,
    pub g: usize,
    pub p: usize,
    /// Include the Eq. 1 MLP base term as an extra dense GEMM per layer.
    pub include_base: bool,
}

impl App {
    /// Expand into GEMM workloads, optionally overriding (G, P) — Fig. 7
    /// fixes G=5, P=3 across applications.
    pub fn workloads(&self, bs: usize, override_gp: Option<(usize, usize)>) -> Vec<Workload> {
        let (g, p) = override_gp.unwrap_or((self.g, self.p));
        let mut out = Vec::new();
        for (ni, net) in self.nets.iter().enumerate() {
            for (li, win) in net.windows(2).enumerate() {
                let (k, n) = (win[0], win[1]);
                let name = format!("{}/net{}/l{}", self.name, ni, li);
                out.push(Workload::kan(&name, bs, k, n, g, p));
                if self.include_base {
                    out.push(Workload::dense(&format!("{name}/base"), bs, k, n));
                }
            }
        }
        out
    }
}

/// The Table II collection. `CF-KAN`'s X and `Catch22`'s class count are
/// fixed as documented in the module docs.
pub fn table2() -> Vec<App> {
    vec![
        App {
            name: "5G-STARDUST",
            nets: vec![vec![168, 40, 40, 40, 24]],
            g: 5,
            p: 3,
            include_base: true,
        },
        App {
            name: "Catch22-KAN",
            nets: vec![vec![22, 10]],
            g: 3,
            p: 3,
            include_base: false,
        },
        App {
            name: "CF-KAN",
            nets: vec![vec![6969, 512, 6969]],
            g: 2,
            p: 3,
            include_base: false,
        },
        App {
            name: "U-KAN",
            nets: vec![vec![512, 1024, 512], vec![512, 512]],
            g: 5,
            p: 3,
            include_base: true,
        },
        App {
            name: "GKAN",
            nets: vec![vec![200, 16, 7], vec![100, 20, 7]],
            g: 3, // paper explores G in {2,3}, P in {1,2,3}; default 3,3
            p: 3,
            include_base: false,
        },
        App {
            name: "Prefetcher",
            nets: vec![vec![5, 64, 128]],
            g: 4,
            p: 3,
            include_base: true,
        },
        App {
            name: "MNIST-KAN",
            nets: vec![vec![784, 64, 10]],
            g: 10,
            p: 3,
            include_base: true,
        },
        App {
            name: "ResKAN18",
            nets: vec![], // conv layers generated in `conv`
            g: 3,
            p: 3,
            include_base: false,
        },
    ]
}

/// Workloads for one app, resolving the ConvKAN special case.
pub fn app_workloads(app: &App, bs: usize, override_gp: Option<(usize, usize)>) -> Vec<Workload> {
    if app.name == "ResKAN18" {
        let (g, p) = override_gp.unwrap_or((app.g, app.p));
        conv::reskan18_workloads(g, p)
    } else {
        app.workloads(bs, override_gp)
    }
}

/// All apps expanded, Fig. 7 style: G=5, P=3 override, MNIST-KAN excluded
/// (the paper excludes it from the sweep because it requires G=10).
pub fn fig7_workloads() -> Vec<(String, Vec<Workload>)> {
    table2()
        .iter()
        .filter(|a| a.name != "MNIST-KAN")
        .map(|a| (a.name.to_string(), app_workloads(a, DEFAULT_BS, Some((5, 3)))))
        .collect()
}

/// All apps with native (G, P), Fig. 8 style.
pub fn fig8_workloads() -> Vec<(String, usize, usize, Vec<Workload>)> {
    table2()
        .iter()
        .map(|a| (a.name.to_string(), a.g, a.p, app_workloads(a, DEFAULT_BS, None)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::workload::GemmKind;

    #[test]
    fn table2_has_all_eight_apps() {
        let apps = table2();
        assert_eq!(apps.len(), 8);
        let names: Vec<_> = apps.iter().map(|a| a.name).collect();
        for want in [
            "5G-STARDUST", "Catch22-KAN", "CF-KAN", "U-KAN", "GKAN", "Prefetcher",
            "MNIST-KAN", "ResKAN18",
        ] {
            assert!(names.contains(&want), "{want} missing");
        }
    }

    #[test]
    fn stardust_layer_count() {
        let app = &table2()[0];
        let wls = app.workloads(32, None);
        // 4 layers x (spline + base)
        assert_eq!(wls.len(), 8);
        assert_eq!(wls[0].k_feats, 168);
        assert_eq!(wls[0].n_out, 40);
        assert!(matches!(wls[0].kind, GemmKind::KanSpline { g: 5, p: 3 }));
        assert!(matches!(wls[1].kind, GemmKind::Dense));
    }

    #[test]
    fn fig7_excludes_mnist_and_overrides_gp() {
        let wls = fig7_workloads();
        assert_eq!(wls.len(), 7);
        for (app, list) in &wls {
            assert_ne!(app, "MNIST-KAN");
            for wl in list {
                if let GemmKind::KanSpline { g, p } = wl.kind {
                    assert_eq!((g, p), (5, 3), "{app}/{}", wl.name);
                }
            }
        }
    }

    #[test]
    fn fig8_keeps_native_gp() {
        let wls = fig8_workloads();
        assert_eq!(wls.len(), 8);
        let mnist = wls.iter().find(|(n, ..)| n == "MNIST-KAN").unwrap();
        assert_eq!((mnist.1, mnist.2), (10, 3));
        assert!(!mnist.3.is_empty());
    }

    #[test]
    fn catch22_matches_paper_shape() {
        // paper: B matrix of dimensions (BS, 22 * (G+P))
        let app = table2().into_iter().find(|a| a.name == "Catch22-KAN").unwrap();
        let wls = app.workloads(16, None);
        assert_eq!(wls.len(), 1);
        assert_eq!(wls[0].expanded_reduction(), 22 * 6);
    }
}

/// GKAN hyperparameter variants the paper explores (G in {2,3}, P in
/// {1,2,3}) — used by the ablation bench to show how N:M shapes the
/// utilization gap.
pub fn gkan_variants() -> Vec<(usize, usize, Vec<Workload>)> {
    let nets = [vec![200usize, 16, 7], vec![100, 20, 7]];
    let mut out = Vec::new();
    for g in [2usize, 3] {
        for p in [1usize, 2, 3] {
            let mut wls = Vec::new();
            for (ni, net) in nets.iter().enumerate() {
                for (li, win) in net.windows(2).enumerate() {
                    wls.push(Workload::kan(
                        &format!("GKAN[g{g}p{p}]/net{ni}/l{li}"),
                        DEFAULT_BS,
                        win[0],
                        win[1],
                        g,
                        p,
                    ));
                }
            }
            out.push((g, p, wls));
        }
    }
    out
}

/// CF-KAN dataset-size variants from Table II: X in {2810, 34395, 6969}.
pub fn cfkan_variants() -> Vec<(usize, Vec<Workload>)> {
    [2810usize, 34395, 6969]
        .into_iter()
        .map(|x| {
            let net = [x, 512, x];
            let wls = net
                .windows(2)
                .enumerate()
                .map(|(li, win)| {
                    Workload::kan(&format!("CF-KAN[x{x}]/l{li}"), DEFAULT_BS, win[0], win[1], 2, 3)
                })
                .collect();
            (x, wls)
        })
        .collect()
}
