//! Affine integer quantization — the rust half of the bit-exact integer
//! pipeline specified in `python/compile/quantize.py` (Jacob et al. [18]
//! style, as the paper's Sec. V uses).
//!
//! Conventions (shared with python, asserted by golden tests):
//! * activations: uint8, zero-point 128, scale 1/128 over the spline
//!   domain `[-1, 127/128]`;
//! * weights: int8 symmetric per-tensor;
//! * accumulation: i32 (u8 x i8 products), i64 after requant multipliers;
//! * requantization: `y_q = clamp(128 + (t + 2^(SHIFT-1)) >> SHIFT)` with
//!   SHIFT = 24 and per-layer integer multipliers m1/m2.

use crate::util::round_clamp;

/// Activation zero point (the quantized value of x = 0).
pub const ZP: i64 = 128;
/// Requantization fixed-point shift.
pub const SHIFT: u32 = 24;

/// Float (spline-domain) activation -> uint8.
pub fn quantize_activation(x: f32) -> u8 {
    round_clamp(x as f64 * 128.0 + ZP as f64, 0, 255) as u8
}

/// uint8 activation -> float.
pub fn dequantize_activation(q: u8) -> f32 {
    (q as f32 - ZP as f32) / 128.0
}

pub fn quantize_activations(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::new();
    quantize_activations_into(xs, &mut out);
    out
}

/// Quantize into a caller-owned buffer (cleared first) — the
/// allocation-free staging path used by `kan::plan::Scratch`.
pub fn quantize_activations_into(xs: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.extend(xs.iter().map(|&x| quantize_activation(x)));
}

/// Symmetric per-tensor int8 quantization; returns (values, scale).
pub fn quantize_symmetric(w: &[f32]) -> (Vec<i8>, f32) {
    let amax = w.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
    let q = w
        .iter()
        .map(|&x| round_clamp((x / scale) as f64, -127, 127) as i8)
        .collect();
    (q, scale)
}

/// Integer ReLU around the zero point: uint8 -> [0, 127] at scale 1/128.
pub fn relu_q(x_q: u8) -> u8 {
    x_q.saturating_sub(ZP as u8)
}

/// The fixed-point requantization of [18]: i64 accumulator -> next-layer
/// uint8 activation. Arithmetic shift implements floor division by 2^SHIFT
/// (matching numpy's `>>` on int64).
pub fn requantize(t: i64) -> u8 {
    let y = (t + (1i64 << (SHIFT - 1))) >> SHIFT;
    (y + ZP).clamp(0, 255) as u8
}

/// Combine the spline/base i32 accumulators with the per-layer
/// fixed-point multipliers into the i64 pre-requantization value `t`.
/// The canonical step-4 expression — `kan::plan` routes both the final
/// layer's logits and the fused inter-layer path through this so the
/// two can never drift apart.
#[inline(always)]
pub fn combine(acc: i32, acc_base: i32, m1: i64, m2: i64) -> i64 {
    acc as i64 * m1 + acc_base as i64 * m2
}

/// Fused combine + requantize: i32 accumulators -> next-layer uint8
/// activation without materializing the intermediate i64 buffer. By
/// construction bit-exact with `requantize(combine(..))` — that IS the
/// body — which is what lets the engine's inter-layer path skip the
/// separate i64 pass (see `kan::plan::LayerPlan::forward_requant_into`).
#[inline(always)]
pub fn requantize_combined(acc: i32, acc_base: i32, m1: i64, m2: i64) -> u8 {
    requantize(combine(acc, acc_base, m1, m2))
}

/// Build the per-layer requant multiplier: `round(scale * 128 * 2^SHIFT)`.
/// (`scale` is the float factor that dequantizes the i32 accumulator.)
pub fn requant_multiplier(scale: f64) -> i64 {
    crate::util::round_half_even(scale * 128.0 * (1u64 << SHIFT) as f64) as i64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{check, Rng};

    #[test]
    fn activation_anchors() {
        assert_eq!(quantize_activation(0.0), 128);
        assert_eq!(quantize_activation(-1.0), 0);
        assert_eq!(quantize_activation(1.0), 255);
        assert_eq!(quantize_activation(-2.0), 0); // saturates
        assert_eq!(quantize_activation(0.5), 192);
    }

    #[test]
    fn activation_roundtrip_error_bounded() {
        check(200, 5, |rng: &mut Rng| {
            let x = rng.uniform(-1.0, 127.0 / 128.0) as f32;
            let err = (dequantize_activation(quantize_activation(x)) - x).abs();
            assert!(err <= 0.5 / 128.0 + 1e-6, "x={x} err={err}");
        });
    }

    #[test]
    fn symmetric_roundtrip_error_bounded() {
        check(50, 6, |rng: &mut Rng| {
            let w: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
            let (q, s) = quantize_symmetric(&w);
            for (&qi, &wi) in q.iter().zip(&w) {
                assert!((qi as f32 * s - wi).abs() <= s / 2.0 + 1e-6);
            }
        });
    }

    #[test]
    fn symmetric_zero_tensor() {
        let (q, s) = quantize_symmetric(&[0.0; 8]);
        assert!(q.iter().all(|&x| x == 0));
        assert_eq!(s, 1.0);
    }

    #[test]
    fn relu_q_anchors() {
        assert_eq!(relu_q(0), 0);
        assert_eq!(relu_q(128), 0);
        assert_eq!(relu_q(129), 1);
        assert_eq!(relu_q(255), 127);
    }

    #[test]
    fn requantize_matches_python_spec() {
        // mirrors python/tests/test_quantize.py::test_requantize_rounding
        assert_eq!(requantize(0), 128);
        assert_eq!(requantize(1i64 << SHIFT), 129);
        assert_eq!(requantize(-(1i64 << SHIFT)), 127);
        // saturation
        assert_eq!(requantize(1i64 << 62), 255);
        assert_eq!(requantize(-(1i64 << 62)), 0);
    }

    #[test]
    fn combine_and_fused_requantize_match_unfused() {
        // i32 accumulator extremes x multiplier extremes: the fused
        // helper must equal the two-step chain everywhere
        check(500, 7, |rng: &mut Rng| {
            let a1 = rng.range_i64(i32::MIN as i64, i32::MAX as i64) as i32;
            let a2 = rng.range_i64(i32::MIN as i64, i32::MAX as i64) as i32;
            let m1 = rng.range_i64(-(1 << 32), 1 << 32);
            let m2 = rng.range_i64(-(1 << 32), 1 << 32);
            let t = combine(a1, a2, m1, m2);
            assert_eq!(t, a1 as i64 * m1 + a2 as i64 * m2);
            assert_eq!(requantize_combined(a1, a2, m1, m2), requantize(t));
        });
    }

    #[test]
    fn requantize_floor_division_negative() {
        // numpy >> is floor division; check a value just below a boundary
        let t = -(1i64 << (SHIFT - 1)) - 1; // rounds to -1 after shift
        assert_eq!(requantize(t), 127);
        let t2 = -(1i64 << (SHIFT - 1)); // exactly -0.5: (t + half) >> s == 0
        assert_eq!(requantize(t2), 128);
    }
}
