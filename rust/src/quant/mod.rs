//! Affine integer quantization — the rust half of the bit-exact integer
//! pipeline specified in `python/compile/quantize.py` (Jacob et al. [18]
//! style, as the paper's Sec. V uses).
//!
//! Conventions (shared with python, asserted by golden tests):
//! * activations: uint8, zero-point 128, scale 1/128 over the spline
//!   domain `[-1, 127/128]`;
//! * weights: int8 symmetric per-tensor;
//! * accumulation: i32 (u8 x i8 products), i64 after requant multipliers;
//! * requantization: `y_q = clamp(128 + (t + 2^(SHIFT-1)) >> SHIFT)` with
//!   SHIFT = 24 and per-layer integer multipliers m1/m2.

use crate::util::round_clamp;

/// Activation zero point (the quantized value of x = 0).
pub const ZP: i64 = 128;
/// Requantization fixed-point shift.
pub const SHIFT: u32 = 24;

/// Float (spline-domain) activation -> uint8.
pub fn quantize_activation(x: f32) -> u8 {
    round_clamp(x as f64 * 128.0 + ZP as f64, 0, 255) as u8
}

/// uint8 activation -> float.
pub fn dequantize_activation(q: u8) -> f32 {
    (q as f32 - ZP as f32) / 128.0
}

pub fn quantize_activations(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::new();
    quantize_activations_into(xs, &mut out);
    out
}

/// Quantize into a caller-owned buffer (cleared first) — the
/// allocation-free staging path used by `kan::plan::Scratch`.
pub fn quantize_activations_into(xs: &[f32], out: &mut Vec<u8>) {
    out.clear();
    out.extend(xs.iter().map(|&x| quantize_activation(x)));
}

/// Symmetric per-tensor int8 quantization; returns (values, scale).
pub fn quantize_symmetric(w: &[f32]) -> (Vec<i8>, f32) {
    let amax = w.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
    let q = w
        .iter()
        .map(|&x| round_clamp((x / scale) as f64, -127, 127) as i8)
        .collect();
    (q, scale)
}

/// Integer ReLU around the zero point: uint8 -> [0, 127] at scale 1/128.
pub fn relu_q(x_q: u8) -> u8 {
    x_q.saturating_sub(ZP as u8)
}

/// The fixed-point requantization of [18]: i64 accumulator -> next-layer
/// uint8 activation. Arithmetic shift implements floor division by 2^SHIFT
/// (matching numpy's `>>` on int64).
pub fn requantize(t: i64) -> u8 {
    let y = (t + (1i64 << (SHIFT - 1))) >> SHIFT;
    (y + ZP).clamp(0, 255) as u8
}

/// Combine the spline/base i32 accumulators with the per-layer
/// fixed-point multipliers into the i64 pre-requantization value `t`.
/// The canonical step-4 expression — `kan::plan` routes both the final
/// layer's logits and the fused inter-layer path through this so the
/// two can never drift apart.
#[inline(always)]
pub fn combine(acc: i32, acc_base: i32, m1: i64, m2: i64) -> i64 {
    acc as i64 * m1 + acc_base as i64 * m2
}

/// Fused combine + requantize: i32 accumulators -> next-layer uint8
/// activation without materializing the intermediate i64 buffer. By
/// construction bit-exact with `requantize(combine(..))` — that IS the
/// body — which is what lets the engine's inter-layer path skip the
/// separate i64 pass (see `kan::plan::LayerPlan::forward_requant_into`).
#[inline(always)]
pub fn requantize_combined(acc: i32, acc_base: i32, m1: i64, m2: i64) -> u8 {
    requantize(combine(acc, acc_base, m1, m2))
}

/// Build the per-layer requant multiplier: `round(scale * 128 * 2^SHIFT)`.
/// (`scale` is the float factor that dequantizes the i32 accumulator.)
pub fn requant_multiplier(scale: f64) -> i64 {
    crate::util::round_half_even(scale * 128.0 * (1u64 << SHIFT) as f64) as i64
}

// ---------------------------------------------------------------------------
// Packed int4 ("nibble") storage — the sub-8-bit weight format.
//
// Layout contract (shared with `python/compile/aot.py` and the packed
// kernel paths in `kan::kernel`): element `2i` lives in the LOW nibble of
// byte `i`, element `2i+1` in the HIGH nibble; an odd-length row leaves
// the final high nibble zero. Values are two's-complement int4 in
// [-8, 7].
// ---------------------------------------------------------------------------

/// Bytes needed to hold `n` packed int4 values (two per byte, rounded up).
#[inline(always)]
pub const fn packed4_len(n: usize) -> usize {
    n.div_ceil(2)
}

/// Sign-extend the low 4 bits of `nib` as two's-complement int4.
#[inline(always)]
pub fn sext4(nib: u8) -> i8 {
    (((nib & 0x0F) ^ 8) as i8) - 8
}

/// Pack int4 values (each in [-8, 7]) two-per-byte, low nibble first.
pub fn pack_i4(vals: &[i8]) -> Vec<u8> {
    debug_assert!(vals.iter().all(|&v| (-8..=7).contains(&v)), "int4 range");
    let mut out = Vec::with_capacity(packed4_len(vals.len()));
    let mut chunks = vals.chunks_exact(2);
    for pair in &mut chunks {
        out.push((pair[0] as u8 & 0x0F) | ((pair[1] as u8 & 0x0F) << 4));
    }
    if let [last] = chunks.remainder() {
        out.push(*last as u8 & 0x0F);
    }
    out
}

/// Unpack `n` int4 values from the packed-nibble layout of [`pack_i4`].
pub fn unpack_i4(packed: &[u8], n: usize) -> Vec<i8> {
    debug_assert_eq!(packed.len(), packed4_len(n));
    (0..n).map(|i| sext4(packed[i >> 1] >> ((i & 1) * 4))).collect()
}

/// Demote one int8 weight to int4 by rounding to the nearest multiple of
/// 16 (`floor((w + 8) / 16)`, clamped to the int4 range). Exact scale
/// compensation is integer: a demoted layer's requant multipliers are
/// multiplied by 16, so `w4 * (m * 16) ~= w8 * m`.
#[inline(always)]
pub fn demote_i8_to_i4(w: i8) -> i8 {
    (((w as i32 + 8) >> 4).clamp(-8, 7)) as i8
}

/// Normalized RMS error of demoting an int8 tensor to int4:
/// `sqrt(sum((w - 16*demote(w))^2) / sum(w^2))`, 0 for an all-zero
/// tensor. The per-layer precision budget (`QuantizedModel::
/// with_precision_budget`) compares against this.
pub fn demotion_error(w: &[i8]) -> f64 {
    let (mut e2, mut w2) = (0f64, 0f64);
    for &v in w {
        let q = demote_i8_to_i4(v) as f64 * 16.0;
        let d = v as f64 - q;
        e2 += d * d;
        w2 += (v as f64) * (v as f64);
    }
    if w2 == 0.0 {
        0.0
    } else {
        (e2 / w2).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{check, Rng};

    #[test]
    fn activation_anchors() {
        assert_eq!(quantize_activation(0.0), 128);
        assert_eq!(quantize_activation(-1.0), 0);
        assert_eq!(quantize_activation(1.0), 255);
        assert_eq!(quantize_activation(-2.0), 0); // saturates
        assert_eq!(quantize_activation(0.5), 192);
    }

    #[test]
    fn activation_roundtrip_error_bounded() {
        check(200, 5, |rng: &mut Rng| {
            let x = rng.uniform(-1.0, 127.0 / 128.0) as f32;
            let err = (dequantize_activation(quantize_activation(x)) - x).abs();
            assert!(err <= 0.5 / 128.0 + 1e-6, "x={x} err={err}");
        });
    }

    #[test]
    fn symmetric_roundtrip_error_bounded() {
        check(50, 6, |rng: &mut Rng| {
            let w: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
            let (q, s) = quantize_symmetric(&w);
            for (&qi, &wi) in q.iter().zip(&w) {
                assert!((qi as f32 * s - wi).abs() <= s / 2.0 + 1e-6);
            }
        });
    }

    #[test]
    fn symmetric_zero_tensor() {
        let (q, s) = quantize_symmetric(&[0.0; 8]);
        assert!(q.iter().all(|&x| x == 0));
        assert_eq!(s, 1.0);
    }

    #[test]
    fn relu_q_anchors() {
        assert_eq!(relu_q(0), 0);
        assert_eq!(relu_q(128), 0);
        assert_eq!(relu_q(129), 1);
        assert_eq!(relu_q(255), 127);
    }

    #[test]
    fn requantize_matches_python_spec() {
        // mirrors python/tests/test_quantize.py::test_requantize_rounding
        assert_eq!(requantize(0), 128);
        assert_eq!(requantize(1i64 << SHIFT), 129);
        assert_eq!(requantize(-(1i64 << SHIFT)), 127);
        // saturation
        assert_eq!(requantize(1i64 << 62), 255);
        assert_eq!(requantize(-(1i64 << 62)), 0);
    }

    #[test]
    fn combine_and_fused_requantize_match_unfused() {
        // i32 accumulator extremes x multiplier extremes: the fused
        // helper must equal the two-step chain everywhere
        check(500, 7, |rng: &mut Rng| {
            let a1 = rng.range_i64(i32::MIN as i64, i32::MAX as i64) as i32;
            let a2 = rng.range_i64(i32::MIN as i64, i32::MAX as i64) as i32;
            let m1 = rng.range_i64(-(1 << 32), 1 << 32);
            let m2 = rng.range_i64(-(1 << 32), 1 << 32);
            let t = combine(a1, a2, m1, m2);
            assert_eq!(t, a1 as i64 * m1 + a2 as i64 * m2);
            assert_eq!(requantize_combined(a1, a2, m1, m2), requantize(t));
        });
    }

    #[test]
    fn nibble_anchors() {
        // sign boundaries and the zero row
        assert_eq!(sext4(0x0), 0);
        assert_eq!(sext4(0x7), 7);
        assert_eq!(sext4(0x8), -8);
        assert_eq!(sext4(0xF), -1);
        // high bits beyond the nibble are ignored
        assert_eq!(sext4(0xF8), -8);
        assert_eq!(pack_i4(&[-8, 7]), vec![0x78]);
        assert_eq!(pack_i4(&[-1]), vec![0x0F]);
        assert_eq!(packed4_len(0), 0);
        assert_eq!(packed4_len(1), 1);
        assert_eq!(packed4_len(2), 1);
        assert_eq!(packed4_len(7), 4);
    }

    #[test]
    fn nibble_roundtrip_property() {
        // pack -> unpack is the identity over random int4 tensors,
        // including the -8/+7 sign boundaries and odd-length tails
        check(200, 40, |rng: &mut Rng| {
            let n = rng.below(65); // even, odd, and empty lengths
            let mut vals: Vec<i8> = (0..n).map(|_| rng.range_i64(-8, 7) as i8).collect();
            // force sign-boundary values into every non-empty tensor
            if n >= 2 {
                vals[0] = -8;
                vals[n - 1] = 7;
            }
            let packed = pack_i4(&vals);
            assert_eq!(packed.len(), packed4_len(n));
            if n % 2 == 1 {
                assert_eq!(packed[n / 2] >> 4, 0, "odd tail leaves high nibble zero");
            }
            assert_eq!(unpack_i4(&packed, n), vals);
        });
    }

    #[test]
    fn demotion_rounds_to_nearest_sixteen() {
        assert_eq!(demote_i8_to_i4(0), 0);
        assert_eq!(demote_i8_to_i4(7), 0);
        assert_eq!(demote_i8_to_i4(8), 1);
        assert_eq!(demote_i8_to_i4(-9), -1);
        assert_eq!(demote_i8_to_i4(-8), 0);
        assert_eq!(demote_i8_to_i4(127), 7); // clamped from 8
        assert_eq!(demote_i8_to_i4(-128), -8);
        check(300, 41, |rng: &mut Rng| {
            let w = rng.range_i64(-128, 127) as i8;
            let q = demote_i8_to_i4(w);
            assert!((-8..=7).contains(&q));
            // nearest multiple of 16 within the clamp
            if (-120..=119).contains(&w) {
                assert!((w as i32 - q as i32 * 16).abs() <= 8, "w={w} q={q}");
            }
        });
    }

    #[test]
    fn demotion_error_bounds() {
        assert_eq!(demotion_error(&[0i8; 16]), 0.0);
        // exact multiples of 16 demote losslessly
        assert_eq!(demotion_error(&[16, -32, 64, 112]), 0.0);
        let e = demotion_error(&[3, -5, 7]);
        assert!(e > 0.9, "tiny weights demote to zero: err ~ 1, got {e}");
        check(50, 42, |rng: &mut Rng| {
            let w: Vec<i8> = (0..64).map(|_| rng.range_i64(-127, 127) as i8).collect();
            let e = demotion_error(&w);
            assert!((0.0..=1.0 + 1e-9).contains(&e), "err={e}");
        });
    }

    #[test]
    fn requantize_floor_division_negative() {
        // numpy >> is floor division; check a value just below a boundary
        let t = -(1i64 << (SHIFT - 1)) - 1; // rounds to -1 after shift
        assert_eq!(requantize(t), 127);
        let t2 = -(1i64 << (SHIFT - 1)); // exactly -0.5: (t + half) >> s == 0
        assert_eq!(requantize(t2), 128);
    }
}
