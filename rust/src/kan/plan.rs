//! Compile/execute split for the integer engine: everything the PE
//! datapath resolves at configuration time — LUT ROMs, N:M window widths,
//! widened MAC tables, requant multipliers, buffer sizes — is compiled
//! *once* into an [`ExecutionPlan`]; steady-state inference then runs the
//! plan against a worker-owned [`Scratch`] arena with **zero heap
//! allocations** (asserted by `tests/zero_alloc.rs`), the software mirror
//! of systolic execution where no state is re-derived per activation
//! stream (paper Sec. IV).
//!
//! The split is bit-exact: a plan executes the same integer arithmetic as
//! the pre-plan engine, so the golden replay vectors are byte-identical.

use crate::bspline::BsplineUnit;
use crate::quant;

use super::model::{LayerParams, QuantizedModel};

/// One layer, fully resolved for execution: the prebuilt B-spline unit,
/// i16-widened coefficient/base tables (sign-extended int8 — the widening
/// lets LLVM vectorize the i16 -> i32 MAC loops ~1.7x better, see
/// EXPERIMENTS.md §Perf), dims, degree window, and requant multipliers.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    pub in_dim: usize,
    pub out_dim: usize,
    /// Grid size G. Introspection metadata only — execution reads it
    /// through `unit`/`num_bases`; kept so a plan layer answers the same
    /// shape questions as its source `LayerParams` (e.g. building a
    /// matching per-layer `ArrayConfig`).
    pub grid: usize,
    pub degree: usize,
    /// `grid + degree` — coefficient rows per input feature.
    pub num_bases: usize,
    /// Prebuilt B-spline unit (owns its LUT ROM copy).
    pub unit: BsplineUnit,
    /// `(K, M, N)` spline coefficients, widened to i16.
    pub coeff16: Vec<i16>,
    /// `(K, N)` base-path weights, widened to i16.
    pub base16: Vec<i16>,
    pub m1: i64,
    pub m2: i64,
}

impl LayerPlan {
    pub fn compile(l: &LayerParams) -> Self {
        Self {
            in_dim: l.in_dim,
            out_dim: l.out_dim,
            grid: l.grid,
            degree: l.degree,
            num_bases: l.num_bases(),
            unit: BsplineUnit::new(l.lut.clone(), l.grid),
            coeff16: l.coeff.data().iter().map(|&w| w as i16).collect(),
            base16: l.base.data().iter().map(|&w| w as i16).collect(),
            m1: l.m1,
            m2: l.m2,
        }
    }

    /// Bytes of derived (widened) tables this plan layer adds on top of
    /// the model's own storage.
    pub fn derived_bytes(&self) -> usize {
        (self.coeff16.len() + self.base16.len()) * 2
    }

    /// Forward one layer into caller-provided buffers: uint8 activations
    /// `(BS, K)` -> i64 accumulators `t (BS, N)`. Allocation-free.
    ///
    /// Hot-path layout (see EXPERIMENTS.md §Perf): *feature-major* — the
    /// outer loop walks input features so each feature's `M x N` int8
    /// coefficient block (832 B for MNIST-KAN layer 1) stays in L1 while
    /// every batch row consumes it, instead of streaming the full 650 KB
    /// coefficient tensor once per row. This mirrors the accelerator's
    /// weight-stationary reuse, which is why it wins.
    pub fn forward_into(
        &self,
        x_q: &[u8],
        bs: usize,
        acc: &mut [i32],
        acc_base: &mut [i32],
        t: &mut [i64],
    ) {
        let (kdim, n, p, m) = (self.in_dim, self.out_dim, self.degree, self.num_bases);
        debug_assert_eq!(x_q.len(), bs * kdim);
        debug_assert_eq!(acc.len(), bs * n);
        debug_assert_eq!(acc_base.len(), bs * n);
        debug_assert_eq!(t.len(), bs * n);
        acc.fill(0);
        acc_base.fill(0);
        let (coeff, base) = (self.coeff16.as_slice(), self.base16.as_slice());
        // batch blocking: keep the active accumulator slice L1-resident
        // while a feature's coefficient block streams through (measured
        // ~17% over unblocked feature-major; EXPERIMENTS.md §Perf)
        const BB: usize = 16;
        for b0 in (0..bs).step_by(BB) {
            let bl = BB.min(bs - b0);
            for feat in 0..kdim {
                let crow = &coeff[feat * m * n..(feat + 1) * m * n];
                let brow = &base[feat * n..(feat + 1) * n];
                for b in b0..b0 + bl {
                    let xq = x_q[b * kdim + feat];
                    // 1. B-spline unit (one LUT fetch for all P+1 non-zeros)
                    let (vals, k) = self.unit.eval_into(xq);
                    // 2. N:M spline MACs: window [k-P, k] of this feature's
                    //    M coefficient rows
                    let arow = &mut acc[b * n..(b + 1) * n];
                    let wbase = (k - p) * n;
                    if p == 3 {
                        // fused 4-row vector MAC (one accumulator pass instead
                        // of four): the software mirror of the 4-lane PE
                        let (v0, v1, v2, v3) =
                            (vals[0] as i32, vals[1] as i32, vals[2] as i32, vals[3] as i32);
                        let w = &crow[wbase..wbase + 4 * n];
                        let (w0, rest) = w.split_at(n);
                        let (w1, rest) = rest.split_at(n);
                        let (w2, w3) = rest.split_at(n);
                        for ((((a, &x0), &x1), &x2), &x3) in
                            arow.iter_mut().zip(w0).zip(w1).zip(w2).zip(w3)
                        {
                            *a += v0 * x0 as i32
                                + v1 * x1 as i32
                                + v2 * x2 as i32
                                + v3 * x3 as i32;
                        }
                    } else {
                        for (j, &v) in vals.iter().enumerate() {
                            if v == 0 {
                                continue;
                            }
                            let v = v as i32;
                            let wrow = &crow[wbase + j * n..wbase + (j + 1) * n];
                            for (a, &w) in arow.iter_mut().zip(wrow) {
                                *a += v * w as i32;
                            }
                        }
                    }
                    // 3. base path (integer ReLU)
                    let r = quant::relu_q(xq) as i32;
                    if r != 0 {
                        let arow = &mut acc_base[b * n..(b + 1) * n];
                        for (a, &w) in arow.iter_mut().zip(brow) {
                            *a += r * w as i32;
                        }
                    }
                }
            }
        }
        // 4. combine with the fixed-point multipliers
        for ((tt, &a1), &a2) in t.iter_mut().zip(acc.iter()).zip(acc_base.iter()) {
            *tt = a1 as i64 * self.m1 + a2 as i64 * self.m2;
        }
    }
}

/// The whole model, compiled for execution: per-layer [`LayerPlan`]s plus
/// the sizing spec for the ping-pong activation buffers a [`Scratch`]
/// must provide. Built once in `Engine::from_shared` and `Arc`-shared by
/// every replica.
#[derive(Clone, Debug)]
pub struct ExecutionPlan {
    pub layers: Vec<LayerPlan>,
    in_dim: usize,
    out_dim: usize,
    /// Widest accumulator row (max out_dim over layers) — sizes
    /// `Scratch::{acc, acc_base, t}` per batch row.
    max_out: usize,
    /// Widest requantized activation row (max out_dim over *non-last*
    /// layers) — sizes the ping-pong activation buffers per batch row.
    max_act: usize,
}

impl ExecutionPlan {
    pub fn compile(model: &QuantizedModel) -> Self {
        assert!(!model.layers.is_empty(), "plan needs at least one layer");
        let layers: Vec<LayerPlan> = model.layers.iter().map(LayerPlan::compile).collect();
        let max_out = layers.iter().map(|l| l.out_dim).max().unwrap_or(0);
        let n = layers.len();
        let max_act = layers[..n - 1].iter().map(|l| l.out_dim).max().unwrap_or(0);
        Self { layers, in_dim: model.in_dim(), out_dim: model.out_dim(), max_out, max_act }
    }

    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Bytes of derived per-layer tables (the plan's storage on top of
    /// the model's int8 tensors).
    pub fn derived_bytes(&self) -> usize {
        self.layers.iter().map(LayerPlan::derived_bytes).sum()
    }

    /// Execute the plan on externally provided quantized inputs. Returns
    /// the final-layer i64 accumulators `(bs, out_dim)` living in the
    /// scratch. Allocation-free once `scratch` has warmed up at this (or
    /// any larger) batch size.
    pub fn execute<'s>(&self, x_q: &[u8], bs: usize, scratch: &'s mut Scratch) -> &'s [i64] {
        debug_assert_eq!(x_q.len(), bs * self.in_dim);
        scratch.ensure(self, bs);
        self.run(Some(x_q), bs, scratch)
    }

    /// Execute on inputs previously gathered into the scratch's staging
    /// buffer (see [`Scratch::stage_input`]) — the serving-pool path,
    /// where workers gather request rows straight into staging instead of
    /// building a batch `Vec` per dispatch.
    pub fn execute_staged<'s>(&self, bs: usize, scratch: &'s mut Scratch) -> &'s [i64] {
        debug_assert_eq!(scratch.staging.len(), bs * self.in_dim);
        scratch.ensure(self, bs);
        self.run(None, bs, scratch)
    }

    fn run<'s>(&self, external: Option<&[u8]>, bs: usize, scratch: &'s mut Scratch) -> &'s [i64] {
        let Scratch { acc, acc_base, t, act, staging } = scratch;
        let [buf_a, buf_b] = act;
        // `prev` holds the current layer's input activations (for i > 0);
        // `cur` receives its requantized output, then the two swap.
        let (mut prev, mut cur): (&mut Vec<u8>, &mut Vec<u8>) = (buf_a, buf_b);
        let n_layers = self.layers.len();
        for (i, lp) in self.layers.iter().enumerate() {
            let (k, n) = (lp.in_dim, lp.out_dim);
            let x: &[u8] = if i == 0 {
                match external {
                    Some(x) => x,
                    None => &staging[..bs * k],
                }
            } else {
                &prev[..bs * k]
            };
            lp.forward_into(x, bs, &mut acc[..bs * n], &mut acc_base[..bs * n], &mut t[..bs * n]);
            if i + 1 < n_layers {
                for (d, &v) in cur[..bs * n].iter_mut().zip(t[..bs * n].iter()) {
                    *d = quant::requantize(v);
                }
                std::mem::swap(&mut prev, &mut cur);
            }
        }
        &t[..bs * self.out_dim]
    }
}

/// Worker-owned mutable execution state: accumulators, the final-layer
/// i64 buffer, ping-pong requantized-activation buffers, and an input
/// staging buffer for batch gather. Grow-only — after one forward at a
/// pool's peak batch size, every subsequent forward (at that size or
/// smaller) performs **zero heap allocations**.
///
/// A `Scratch` is plain mutable state with no lock: each pool worker (and
/// the `Server`'s single worker) owns one; `Engine`'s compatibility
/// wrappers keep a lazily-grown private one behind a mutex.
#[derive(Debug, Default)]
pub struct Scratch {
    /// Spline-path i32 accumulators, `bs * max_out`.
    acc: Vec<i32>,
    /// Base-path i32 accumulators, `bs * max_out`.
    acc_base: Vec<i32>,
    /// Final-layer i64 accumulators (the forward's output), `bs * max_out`.
    t: Vec<i64>,
    /// Ping-pong buffers for requantized inter-layer activations.
    act: [Vec<u8>; 2],
    /// Quantized-input staging for batch gather / float quantization.
    staging: Vec<u8>,
}

impl Scratch {
    /// An empty arena; grows on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// An arena pre-sized for `plan` at batch sizes up to `max_bs`, so
    /// even the first forward is allocation-free.
    pub fn for_plan(plan: &ExecutionPlan, max_bs: usize) -> Self {
        let mut s = Self::new();
        s.fit(plan, max_bs);
        s
    }

    /// Grow the arena to fit `plan` at batch sizes up to `max_bs`
    /// (staging included). Callable repeatedly with *different* plans —
    /// a multi-tenant gateway worker serves every registered model out
    /// of one scratch by fitting it to each model's plan once, ending up
    /// sized to the widest.
    pub fn fit(&mut self, plan: &ExecutionPlan, max_bs: usize) {
        self.ensure(plan, max_bs);
        let staged = max_bs * plan.in_dim;
        if self.staging.capacity() < staged {
            self.staging.reserve(staged - self.staging.len());
        }
    }

    /// Grow (never shrink) to fit one forward of `plan` at `bs` rows.
    fn ensure(&mut self, plan: &ExecutionPlan, bs: usize) {
        let n = bs * plan.max_out;
        if self.acc.len() < n {
            self.acc.resize(n, 0);
        }
        if self.acc_base.len() < n {
            self.acc_base.resize(n, 0);
        }
        if self.t.len() < n {
            self.t.resize(n, 0);
        }
        let a = bs * plan.max_act;
        for buf in &mut self.act {
            if buf.len() < a {
                buf.resize(a, 0);
            }
        }
    }

    /// Clear the staging buffer and reserve `len` bytes; the caller then
    /// gathers quantized input rows with `extend_from_slice`. The reserve
    /// is amortized: after warmup at the peak batch size it never
    /// reallocates.
    pub fn stage_input(&mut self, len: usize) -> &mut Vec<u8> {
        self.staging.clear();
        self.staging.reserve(len);
        &mut self.staging
    }

    /// Rows * in_dim bytes currently staged (see [`Scratch::stage_input`]).
    pub fn staged_len(&self) -> usize {
        self.staging.len()
    }

    /// Bytes currently held by the arena (capacity, not length).
    pub fn capacity_bytes(&self) -> usize {
        self.acc.capacity() * 4
            + self.acc_base.capacity() * 4
            + self.t.capacity() * 8
            + self.act.iter().map(|b| b.capacity()).sum::<usize>()
            + self.staging.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> QuantizedModel {
        QuantizedModel::synthetic("plan", &[6, 9, 4, 3], 5, 3, 11)
    }

    #[test]
    fn compile_resolves_all_layers() {
        let m = model();
        let plan = ExecutionPlan::compile(&m);
        assert_eq!(plan.layers.len(), 3);
        assert_eq!(plan.in_dim(), 6);
        assert_eq!(plan.out_dim(), 3);
        assert_eq!(plan.max_out, 9);
        assert_eq!(plan.max_act, 9, "last layer's width never hits the act buffers");
        for (lp, l) in plan.layers.iter().zip(&m.layers) {
            assert_eq!(lp.num_bases, l.num_bases());
            assert_eq!(lp.coeff16.len(), l.coeff.len());
            assert_eq!(
                lp.coeff16.iter().map(|&w| w as i64).sum::<i64>(),
                l.coeff.data().iter().map(|&w| w as i64).sum::<i64>(),
                "widening must be value-preserving"
            );
        }
        assert!(plan.derived_bytes() > 0);
    }

    #[test]
    fn execute_matches_across_scratch_states() {
        let m = model();
        let plan = ExecutionPlan::compile(&m);
        let x_q: Vec<u8> = (0..2 * 6).map(|i| (i * 37 % 256) as u8).collect();
        let mut fresh = Scratch::new();
        let want = plan.execute(&x_q, 2, &mut fresh).to_vec();
        // pre-sized and reused arenas produce the identical bytes
        let mut sized = Scratch::for_plan(&plan, 8);
        assert_eq!(plan.execute(&x_q, 2, &mut sized), &want[..]);
        assert_eq!(plan.execute(&x_q, 2, &mut sized), &want[..]);
        // staged path too
        sized.stage_input(x_q.len()).extend_from_slice(&x_q);
        assert_eq!(plan.execute_staged(2, &mut sized), &want[..]);
    }

    #[test]
    fn fit_covers_multiple_plans() {
        // a gateway worker's scratch: fitted to two differently-shaped
        // plans, it must execute both without growing
        let wide = ExecutionPlan::compile(&QuantizedModel::synthetic("w", &[12, 20, 6], 5, 3, 1));
        let tall = ExecutionPlan::compile(&QuantizedModel::synthetic("t", &[3, 40, 2], 5, 3, 2));
        let mut s = Scratch::new();
        s.fit(&wide, 8);
        s.fit(&tall, 8);
        let cap = s.capacity_bytes();
        let xw: Vec<u8> = (0..8 * 12).map(|i| (i % 256) as u8).collect();
        let xt: Vec<u8> = (0..8 * 3).map(|i| (i % 256) as u8).collect();
        s.stage_input(xw.len()).extend_from_slice(&xw);
        assert_eq!(wide.execute_staged(8, &mut s).len(), 8 * 6);
        s.stage_input(xt.len()).extend_from_slice(&xt);
        assert_eq!(tall.execute_staged(8, &mut s).len(), 8 * 2);
        assert_eq!(s.capacity_bytes(), cap, "fitted scratch must not grow in service");
    }

    #[test]
    fn scratch_grows_monotonically() {
        let plan = ExecutionPlan::compile(&model());
        let mut s = Scratch::new();
        s.ensure(&plan, 4);
        let cap4 = s.capacity_bytes();
        s.ensure(&plan, 2);
        assert_eq!(s.capacity_bytes(), cap4, "shrinking batch must not shrink the arena");
        s.ensure(&plan, 16);
        assert!(s.capacity_bytes() > cap4);
    }

    #[test]
    fn single_layer_model_needs_no_act_buffers() {
        let m = QuantizedModel::synthetic("one", &[4, 3], 5, 3, 2);
        let plan = ExecutionPlan::compile(&m);
        assert_eq!(plan.max_act, 0);
        let mut s = Scratch::new();
        let t = plan.execute(&[0, 128, 60, 255], 1, &mut s);
        assert_eq!(t.len(), 3);
        assert!(s.act.iter().all(|b| b.is_empty()));
    }
}
