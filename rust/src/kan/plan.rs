//! Compile/execute split for the integer engine: everything the PE
//! datapath resolves at configuration time — LUT ROMs, N:M window widths,
//! widened MAC tables, requant multipliers, buffer sizes, the SIMD
//! kernel, the batch blocking — is compiled *once* into an
//! [`ExecutionPlan`]; steady-state inference then runs the plan against a
//! worker-owned [`Scratch`] arena with **zero heap allocations**
//! (asserted by `tests/zero_alloc.rs`), the software mirror of systolic
//! execution where no state is re-derived per activation stream (paper
//! Sec. IV).
//!
//! Three compile-time resolutions feed the hot path (see
//! EXPERIMENTS.md §Perf):
//!
//! * **Kernel dispatch** ([`super::kernel`]): the i16 -> i32 MAC inner
//!   loops run through per-arch SIMD implementations selected once by
//!   runtime CPU-feature detection (`KANSAS_FORCE_KERNEL` pins a path);
//! * **Fused requantize**: non-final layers combine the two accumulators
//!   with the fixed-point multipliers and requantize to uint8 in ONE
//!   pass ([`LayerPlan::forward_requant_into`]) — the i64 `t` buffer is
//!   materialized only for the final layer's logits;
//! * **Batch-block autotuning**: the batch blocking `bb` is measured per
//!   layer at plan compile (candidates timed on synthetic rows) and the
//!   winner cached process-wide per `(in_dim, out_dim, G, P, kernel)`
//!   shape, so compiling a replica of an already-seen shape is free.
//!
//! The split is bit-exact: a plan executes the same integer arithmetic as
//! the pre-plan engine on every kernel path and blocking, so the golden
//! replay vectors are byte-identical.

use crate::bspline::BsplineUnit;
use crate::quant;
use crate::tensor::Tensor;

use super::kernel::{Kernel, KernelKind};
use super::model::{LayerParams, Precision, QuantizedModel};

/// One layer, fully resolved for execution: the prebuilt B-spline unit,
/// the weight tables in their execution format — i16-widened for int8
/// layers (the widening feeds the SIMD kernels' 16-bit multiplier
/// lanes), nibble-packed for int4 layers (half the bytes per MAC; the
/// kernels sign-extend in-register) — dims, degree window, requant
/// multipliers, the resolved MAC kernel, and the autotuned batch block.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    pub in_dim: usize,
    pub out_dim: usize,
    /// Grid size G. Introspection metadata only — execution reads it
    /// through `unit`/`num_bases`; kept so a plan layer answers the same
    /// shape questions as its source `LayerParams` (e.g. building a
    /// matching per-layer `ArrayConfig`).
    pub grid: usize,
    pub degree: usize,
    /// `grid + degree` — coefficient rows per input feature.
    pub num_bases: usize,
    /// Prebuilt B-spline unit (owns its LUT ROM copy).
    pub unit: BsplineUnit,
    /// Weight storage precision — selects which table family below is
    /// populated and which kernel entry points the hot loop calls.
    pub precision: Precision,
    /// `(K, M, N)` spline coefficients, widened to i16 (int8 layers;
    /// empty on int4 layers).
    pub coeff16: Vec<i16>,
    /// `(K, N)` base-path weights, widened to i16 (int8 layers).
    pub base16: Vec<i16>,
    /// `(K, M, RB)` nibble-packed spline coefficients, `RB =
    /// packed4_len(N)` bytes per output row (int4 layers; empty on int8
    /// layers).
    pub coeff4: Vec<u8>,
    /// `(K, RB)` nibble-packed base-path weights (int4 layers).
    pub base4: Vec<u8>,
    pub m1: i64,
    pub m2: i64,
    /// Resolved MAC kernel (cached function pointers; see
    /// [`super::kernel`]). Shared by all layers of one plan.
    pub kernel: Kernel,
    /// Batch block: rows per blocking step of the feature-major loop
    /// (autotuned at compile; `KANSAS_BB` overrides, `KANSAS_AUTOTUNE=0`
    /// pins the default).
    pub bb: usize,
}

/// The blocking used before autotuning existed (PR 2-6), and the value
/// autotune falls back to for shapes too small to time meaningfully.
pub const DEFAULT_BB: usize = 16;

impl LayerPlan {
    /// Compile with the runtime-dispatched kernel (see
    /// [`Kernel::dispatch`]).
    pub fn compile(l: &LayerParams) -> Self {
        Self::compile_with(l, Kernel::dispatch())
    }

    /// Compile for a specific kernel — the entry point benches and the
    /// differential kernel tests use to pin a path without touching the
    /// process environment.
    pub fn compile_with(l: &LayerParams, kernel: Kernel) -> Self {
        // Exactly one table family is populated per layer: int8 layers
        // widen to i16; int4 layers pack two's-complement nibbles per
        // OUTPUT ROW (row stride `packed4_len(out_dim)` bytes, so every
        // row starts byte-aligned and odd widths pad one zero nibble).
        let packed = l.precision == Precision::Int4;
        let widen = |t: &Tensor<i8>| -> Vec<i16> {
            if packed {
                Vec::new()
            } else {
                t.data().iter().map(|&w| w as i16).collect()
            }
        };
        let pack = |t: &Tensor<i8>| -> Vec<u8> {
            if packed {
                t.data().chunks_exact(l.out_dim).flat_map(quant::pack_i4).collect()
            } else {
                Vec::new()
            }
        };
        let mut lp = Self {
            in_dim: l.in_dim,
            out_dim: l.out_dim,
            grid: l.grid,
            degree: l.degree,
            num_bases: l.num_bases(),
            unit: BsplineUnit::new(l.lut.clone(), l.grid),
            precision: l.precision,
            coeff16: widen(&l.coeff),
            base16: widen(&l.base),
            coeff4: pack(&l.coeff),
            base4: pack(&l.base),
            m1: l.m1,
            m2: l.m2,
            kernel,
            bb: DEFAULT_BB,
        };
        lp.bb = autotune::best_bb(&lp);
        lp
    }

    /// Bytes of derived tables this plan layer adds on top of the
    /// model's own storage: 2 bytes/weight widened for int8 layers, half
    /// a byte/weight packed for int4 layers.
    pub fn derived_bytes(&self) -> usize {
        (self.coeff16.len() + self.base16.len()) * 2 + self.coeff4.len() + self.base4.len()
    }

    /// Steps 1-3 of the layer forward (B-spline unit, N:M spline MACs,
    /// base path) at an explicit batch block, leaving the two i32
    /// accumulators filled. Shared by both combine variants below and by
    /// the autotuner (which times candidate blockings through it).
    ///
    /// Hot-path layout (see EXPERIMENTS.md §Perf): *feature-major* — the
    /// outer loop walks input features so each feature's `M x N` int8
    /// coefficient block (832 B for MNIST-KAN layer 1) stays in L1 while
    /// every batch row consumes it, instead of streaming the full 650 KB
    /// coefficient tensor once per row. This mirrors the accelerator's
    /// weight-stationary reuse, which is why it wins. Batch blocking
    /// keeps the active accumulator slice L1-resident while a feature's
    /// coefficient block streams through.
    fn accumulate_with_bb(
        &self,
        bb: usize,
        x_q: &[u8],
        bs: usize,
        acc: &mut [i32],
        acc_base: &mut [i32],
    ) {
        debug_assert_eq!(x_q.len(), bs * self.in_dim);
        debug_assert_eq!(acc.len(), bs * self.out_dim);
        debug_assert_eq!(acc_base.len(), bs * self.out_dim);
        debug_assert!(bb >= 1);
        acc.fill(0);
        acc_base.fill(0);
        match self.precision {
            Precision::Int8 => self.accumulate_dense(bb, x_q, bs, acc, acc_base),
            Precision::Int4 => self.accumulate_packed(bb, x_q, bs, acc, acc_base),
        }
    }

    /// Int8 body of [`LayerPlan::accumulate_with_bb`]: i16-widened rows
    /// through the dense kernel entry points.
    fn accumulate_dense(
        &self,
        bb: usize,
        x_q: &[u8],
        bs: usize,
        acc: &mut [i32],
        acc_base: &mut [i32],
    ) {
        let (kdim, n, p) = (self.in_dim, self.out_dim, self.degree);
        let m = self.num_bases;
        let (coeff, base) = (self.coeff16.as_slice(), self.base16.as_slice());
        let kernel = self.kernel;
        for b0 in (0..bs).step_by(bb) {
            let bl = bb.min(bs - b0);
            for feat in 0..kdim {
                let crow = &coeff[feat * m * n..(feat + 1) * m * n];
                let brow = &base[feat * n..(feat + 1) * n];
                for b in b0..b0 + bl {
                    let xq = x_q[b * kdim + feat];
                    // 1. B-spline unit (one LUT fetch for all P+1 non-zeros)
                    let (vals, k) = self.unit.eval_into(xq);
                    // 2. N:M spline MACs: window [k-P, k] of this feature's
                    //    M coefficient rows
                    let arow = &mut acc[b * n..(b + 1) * n];
                    let wbase = (k - p) * n;
                    if p == 3 {
                        // fused 4-row vector MAC (one accumulator pass
                        // instead of four): the software mirror of the
                        // 4-lane PE, dispatched to the SIMD kernel
                        let v = [vals[0] as i16, vals[1] as i16, vals[2] as i16, vals[3] as i16];
                        kernel.mac4(arow, &crow[wbase..wbase + 4 * n], v);
                    } else {
                        for (j, &v) in vals.iter().enumerate() {
                            if v == 0 {
                                continue;
                            }
                            let wrow = &crow[wbase + j * n..wbase + (j + 1) * n];
                            kernel.axpy(arow, wrow, v as i16);
                        }
                    }
                    // 3. base path (integer ReLU)
                    let r = quant::relu_q(xq);
                    if r != 0 {
                        kernel.axpy(&mut acc_base[b * n..(b + 1) * n], brow, r as i16);
                    }
                }
            }
        }
    }

    /// Int4 twin of [`LayerPlan::accumulate_dense`]: identical loop
    /// structure, but rows are nibble-packed at stride `RB =
    /// packed4_len(N)` bytes and flow through the packed kernel entry
    /// points, which sign-extend in-register. Bit-exact with the dense
    /// body on a value-identical table (asserted by
    /// `packed_layers_match_widened_dense`).
    fn accumulate_packed(
        &self,
        bb: usize,
        x_q: &[u8],
        bs: usize,
        acc: &mut [i32],
        acc_base: &mut [i32],
    ) {
        let (kdim, n, p) = (self.in_dim, self.out_dim, self.degree);
        let m = self.num_bases;
        let rb = quant::packed4_len(n);
        let (coeff, base) = (self.coeff4.as_slice(), self.base4.as_slice());
        let kernel = self.kernel;
        for b0 in (0..bs).step_by(bb) {
            let bl = bb.min(bs - b0);
            for feat in 0..kdim {
                let crow = &coeff[feat * m * rb..(feat + 1) * m * rb];
                let brow = &base[feat * rb..(feat + 1) * rb];
                for b in b0..b0 + bl {
                    let xq = x_q[b * kdim + feat];
                    let (vals, k) = self.unit.eval_into(xq);
                    let arow = &mut acc[b * n..(b + 1) * n];
                    let wbase = (k - p) * rb;
                    if p == 3 {
                        let v = [vals[0] as i16, vals[1] as i16, vals[2] as i16, vals[3] as i16];
                        kernel.mac4_p4(arow, &crow[wbase..wbase + 4 * rb], v);
                    } else {
                        for (j, &v) in vals.iter().enumerate() {
                            if v == 0 {
                                continue;
                            }
                            let wrow = &crow[wbase + j * rb..wbase + (j + 1) * rb];
                            kernel.axpy_p4(arow, wrow, v as i16);
                        }
                    }
                    let r = quant::relu_q(xq);
                    if r != 0 {
                        kernel.axpy_p4(&mut acc_base[b * n..(b + 1) * n], brow, r as i16);
                    }
                }
            }
        }
    }

    /// Forward one layer into caller-provided buffers: uint8 activations
    /// `(BS, K)` -> i64 accumulators `t (BS, N)`. Allocation-free. This
    /// is the *final-layer* (and debug/per-layer) entry point — the
    /// inter-layer path uses [`LayerPlan::forward_requant_into`], which
    /// never materializes `t`.
    pub fn forward_into(
        &self,
        x_q: &[u8],
        bs: usize,
        acc: &mut [i32],
        acc_base: &mut [i32],
        t: &mut [i64],
    ) {
        debug_assert_eq!(t.len(), bs * self.out_dim);
        self.accumulate_with_bb(self.bb, x_q, bs, acc, acc_base);
        // 4. combine with the fixed-point multipliers
        for ((tt, &a1), &a2) in t.iter_mut().zip(acc.iter()).zip(acc_base.iter()) {
            *tt = quant::combine(a1, a2, self.m1, self.m2);
        }
    }

    /// Forward one layer with the requantize FUSED into the combine
    /// loop: uint8 activations `(BS, K)` -> next-layer uint8 activations
    /// `(BS, N)`, in one pass over the accumulators. The separate i64
    /// `t` buffer (and its second memory pass) exists only for the final
    /// layer's logits. Bit-exact with `forward_into` + `requantize` by
    /// construction — the fused loop evaluates the identical expression
    /// per element (see `quant::requantize_combined`).
    pub fn forward_requant_into(
        &self,
        x_q: &[u8],
        bs: usize,
        acc: &mut [i32],
        acc_base: &mut [i32],
        out: &mut [u8],
    ) {
        debug_assert_eq!(out.len(), bs * self.out_dim);
        self.accumulate_with_bb(self.bb, x_q, bs, acc, acc_base);
        // 4+5. combine and requantize, fused
        for ((o, &a1), &a2) in out.iter_mut().zip(acc.iter()).zip(acc_base.iter()) {
            *o = quant::requantize_combined(a1, a2, self.m1, self.m2);
        }
    }
}

/// Per-layer batch-block autotuning: time 2-3 candidate blockings at
/// plan compile on synthetic rows, cache the winner process-wide per
/// `(in_dim, out_dim, G, P, kernel, precision)` shape — precision is
/// part of the key because packed int4 layers move half the bytes per
/// feature pass and can prefer a different blocking. Replicas (`Engine::clone`)
/// share the compiled plan outright; this cache additionally makes
/// *recompiles* of an already-seen shape (`Engine::from_shared` on
/// another model of the same architecture, test suites, churn re-adds)
/// skip the measurement entirely. The choice only affects speed — every
/// blocking is bit-exact — so timing noise can never corrupt results.
mod autotune {
    use std::collections::HashMap;
    use std::sync::{Mutex, OnceLock};
    use std::time::Instant;

    use super::{KernelKind, LayerPlan, Precision, DEFAULT_BB};

    /// Candidate blockings. 16 is the measured pre-autotune default;
    /// 8 wins for wide accumulator rows (less L1 pressure per block),
    /// 32 for narrow ones (more coefficient reuse per feature pass).
    const CANDIDATES: [usize; 3] = [8, 16, 32];
    /// Rows used for the timing runs — two blocks of the largest
    /// candidate, so every candidate executes its steady-state shape.
    const TUNE_BS: usize = 2 * 32;
    /// Shapes whose per-forward MAC count is below this aren't worth
    /// timing (noise exceeds the win); they take the default. Also keeps
    /// plan compiles in shape-heavy test suites effectively free.
    const MIN_TUNE_MACS: usize = 1 << 14;

    type ShapeKey = (usize, usize, usize, usize, KernelKind, Precision);

    fn cache() -> &'static Mutex<HashMap<ShapeKey, usize>> {
        static CACHE: OnceLock<Mutex<HashMap<ShapeKey, usize>>> = OnceLock::new();
        CACHE.get_or_init(|| Mutex::new(HashMap::new()))
    }

    /// Resolve the batch block for `lp`: env override, then cache, then
    /// measurement.
    pub(super) fn best_bb(lp: &LayerPlan) -> usize {
        if let Ok(v) = std::env::var("KANSAS_BB") {
            if let Ok(bb) = v.trim().parse::<usize>() {
                return bb.max(1);
            }
            eprintln!("KANSAS_BB={v}: not a positive integer, ignoring");
        }
        if matches!(std::env::var("KANSAS_AUTOTUNE").as_deref(), Ok("0") | Ok("off")) {
            return DEFAULT_BB;
        }
        let work = lp.in_dim * lp.out_dim * (lp.degree + 1);
        if work < MIN_TUNE_MACS {
            return DEFAULT_BB;
        }
        let key: ShapeKey =
            (lp.in_dim, lp.out_dim, lp.grid, lp.degree, lp.kernel.kind(), lp.precision);
        if let Some(&bb) = cache().lock().unwrap().get(&key) {
            return bb;
        }
        let bb = measure(lp);
        cache().lock().unwrap().insert(key, bb);
        bb
    }

    /// Time each candidate (one warmup + best-of-2 timed reps of a
    /// `TUNE_BS`-row accumulate) and return the fastest. Compile-time
    /// only — the buffers allocated here never touch the serving path.
    fn measure(lp: &LayerPlan) -> usize {
        let n = lp.out_dim;
        let x_q: Vec<u8> = (0..TUNE_BS * lp.in_dim)
            .map(|i| (i.wrapping_mul(131) % 256) as u8)
            .collect();
        let mut acc = vec![0i32; TUNE_BS * n];
        let mut acc_base = vec![0i32; TUNE_BS * n];
        let mut best = (DEFAULT_BB, std::time::Duration::MAX);
        for &bb in &CANDIDATES {
            lp.accumulate_with_bb(bb, &x_q, TUNE_BS, &mut acc, &mut acc_base); // warmup
            let mut fastest = std::time::Duration::MAX;
            for _ in 0..2 {
                let t0 = Instant::now();
                lp.accumulate_with_bb(bb, &x_q, TUNE_BS, &mut acc, &mut acc_base);
                fastest = fastest.min(t0.elapsed());
            }
            std::hint::black_box(&acc);
            if fastest < best.1 {
                best = (bb, fastest);
            }
        }
        best.0
    }
}

/// The whole model, compiled for execution: per-layer [`LayerPlan`]s plus
/// the sizing spec for the ping-pong activation buffers a [`Scratch`]
/// must provide. Built once in `Engine::from_shared` and `Arc`-shared by
/// every replica.
#[derive(Clone, Debug)]
pub struct ExecutionPlan {
    pub layers: Vec<LayerPlan>,
    in_dim: usize,
    out_dim: usize,
    /// Widest accumulator row (max out_dim over layers) — sizes
    /// `Scratch::{acc, acc_base}` per batch row.
    max_out: usize,
    /// Widest requantized activation row (max out_dim over *non-last*
    /// layers) — sizes the ping-pong activation buffers per batch row.
    max_act: usize,
}

impl ExecutionPlan {
    /// Compile with the runtime-dispatched MAC kernel (honors
    /// `KANSAS_FORCE_KERNEL`; see [`Kernel::dispatch`]).
    pub fn compile(model: &QuantizedModel) -> Self {
        Self::compile_with(model, Kernel::dispatch())
    }

    /// Compile against an explicit kernel — used by benches (scalar
    /// baseline rows) and the differential kernel tests.
    pub fn compile_with(model: &QuantizedModel, kernel: Kernel) -> Self {
        assert!(!model.layers.is_empty(), "plan needs at least one layer");
        let layers: Vec<LayerPlan> =
            model.layers.iter().map(|l| LayerPlan::compile_with(l, kernel)).collect();
        let max_out = layers.iter().map(|l| l.out_dim).max().unwrap_or(0);
        let n = layers.len();
        let max_act = layers[..n - 1].iter().map(|l| l.out_dim).max().unwrap_or(0);
        Self { layers, in_dim: model.in_dim(), out_dim: model.out_dim(), max_out, max_act }
    }

    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// The MAC kernel this plan executes with (resolved once at
    /// compile; every layer shares it).
    pub fn kernel_kind(&self) -> KernelKind {
        self.layers[0].kernel.kind()
    }

    /// The autotuned batch block of each layer, in layer order — the
    /// perf-report companion of [`ExecutionPlan::kernel_kind`]
    /// (`BENCH_engine.json` rows, `kansas serve` startup).
    pub fn batch_blocks(&self) -> Vec<usize> {
        self.layers.iter().map(|l| l.bb).collect()
    }

    /// The storage precision of each layer, in layer order — the
    /// mixed-precision companion of [`ExecutionPlan::batch_blocks`] for
    /// serving reports.
    pub fn precisions(&self) -> Vec<Precision> {
        self.layers.iter().map(|l| l.precision).collect()
    }

    /// Bytes of derived per-layer tables (the plan's storage on top of
    /// the model's int8 tensors).
    pub fn derived_bytes(&self) -> usize {
        self.layers.iter().map(LayerPlan::derived_bytes).sum()
    }

    /// Execute the plan on externally provided quantized inputs. Returns
    /// the final-layer i64 accumulators `(bs, out_dim)` living in the
    /// scratch. Allocation-free once `scratch` has warmed up at this (or
    /// any larger) batch size.
    pub fn execute<'s>(&self, x_q: &[u8], bs: usize, scratch: &'s mut Scratch) -> &'s [i64] {
        debug_assert_eq!(x_q.len(), bs * self.in_dim);
        scratch.ensure(self, bs);
        self.run(Some(x_q), bs, scratch)
    }

    /// Execute on inputs previously gathered into the scratch's staging
    /// buffer (see [`Scratch::stage_input`]) — the serving-pool path,
    /// where workers gather request rows straight into scratch staging
    /// instead of building a batch `Vec` per dispatch.
    pub fn execute_staged<'s>(&self, bs: usize, scratch: &'s mut Scratch) -> &'s [i64] {
        debug_assert_eq!(scratch.staging.len(), bs * self.in_dim);
        scratch.ensure(self, bs);
        self.run(None, bs, scratch)
    }

    fn run<'s>(&self, external: Option<&[u8]>, bs: usize, scratch: &'s mut Scratch) -> &'s [i64] {
        let Scratch { acc, acc_base, t, act, staging } = scratch;
        let [buf_a, buf_b] = act;
        // `prev` holds the current layer's input activations (for i > 0);
        // `cur` receives its requantized output, then the two swap.
        let (mut prev, mut cur): (&mut Vec<u8>, &mut Vec<u8>) = (buf_a, buf_b);
        let n_layers = self.layers.len();
        for (i, lp) in self.layers.iter().enumerate() {
            let (k, n) = (lp.in_dim, lp.out_dim);
            let x: &[u8] = if i == 0 {
                match external {
                    Some(x) => x,
                    None => &staging[..bs * k],
                }
            } else {
                &prev[..bs * k]
            };
            if i + 1 < n_layers {
                // inter-layer: fused combine + requantize straight into
                // the next activation buffer — no i64 `t` materialized
                lp.forward_requant_into(
                    x,
                    bs,
                    &mut acc[..bs * n],
                    &mut acc_base[..bs * n],
                    &mut cur[..bs * n],
                );
                std::mem::swap(&mut prev, &mut cur);
            } else {
                // final layer: the i64 accumulators ARE the output
                lp.forward_into(
                    x,
                    bs,
                    &mut acc[..bs * n],
                    &mut acc_base[..bs * n],
                    &mut t[..bs * n],
                );
            }
        }
        &t[..bs * self.out_dim]
    }
}

/// Worker-owned mutable execution state: accumulators, the final-layer
/// i64 buffer, ping-pong requantized-activation buffers, and an input
/// staging buffer for batch gather. Grow-only — after one forward at a
/// pool's peak batch size, every subsequent forward (at that size or
/// smaller) performs **zero heap allocations**.
///
/// A `Scratch` is plain mutable state with no lock: each pool worker (and
/// the `Server`'s single worker) owns one; `Engine`'s compatibility
/// wrappers keep a lazily-grown private one behind a mutex.
#[derive(Debug, Default)]
pub struct Scratch {
    /// Spline-path i32 accumulators, `bs * max_out`.
    acc: Vec<i32>,
    /// Base-path i32 accumulators, `bs * max_out`.
    acc_base: Vec<i32>,
    /// Final-layer i64 accumulators (the forward's output),
    /// `bs * out_dim`. Since the requantize fusion, only the LAST
    /// layer's logits land here — inter-layer values never exist as i64.
    t: Vec<i64>,
    /// Ping-pong buffers for requantized inter-layer activations.
    act: [Vec<u8>; 2],
    /// Quantized-input staging for batch gather / float quantization.
    staging: Vec<u8>,
}

impl Scratch {
    /// An empty arena; grows on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// An arena pre-sized for `plan` at batch sizes up to `max_bs`, so
    /// even the first forward is allocation-free.
    pub fn for_plan(plan: &ExecutionPlan, max_bs: usize) -> Self {
        let mut s = Self::new();
        s.fit(plan, max_bs);
        s
    }

    /// Grow the arena to fit `plan` at batch sizes up to `max_bs`
    /// (staging included). Callable repeatedly with *different* plans —
    /// a multi-tenant gateway worker serves every registered model out
    /// of one scratch by fitting it to each model's plan once, ending up
    /// sized to the widest.
    pub fn fit(&mut self, plan: &ExecutionPlan, max_bs: usize) {
        self.ensure(plan, max_bs);
        let staged = max_bs * plan.in_dim;
        if self.staging.capacity() < staged {
            self.staging.reserve(staged - self.staging.len());
        }
    }

    /// Grow (never shrink) to fit one forward of `plan` at `bs` rows.
    fn ensure(&mut self, plan: &ExecutionPlan, bs: usize) {
        let n = bs * plan.max_out;
        if self.acc.len() < n {
            self.acc.resize(n, 0);
        }
        if self.acc_base.len() < n {
            self.acc_base.resize(n, 0);
        }
        // `t` only ever holds the final layer's logits (the fused
        // requantize keeps inter-layer i64 values out of memory), so it
        // is sized by out_dim, not max_out
        let tn = bs * plan.out_dim;
        if self.t.len() < tn {
            self.t.resize(tn, 0);
        }
        let a = bs * plan.max_act;
        for buf in &mut self.act {
            if buf.len() < a {
                buf.resize(a, 0);
            }
        }
    }

    /// Clear the staging buffer and reserve `len` bytes; the caller then
    /// gathers quantized input rows with `extend_from_slice`. The reserve
    /// is amortized: after warmup at the peak batch size it never
    /// reallocates.
    pub fn stage_input(&mut self, len: usize) -> &mut Vec<u8> {
        self.staging.clear();
        self.staging.reserve(len);
        &mut self.staging
    }

    /// Rows * in_dim bytes currently staged (see [`Scratch::stage_input`]).
    pub fn staged_len(&self) -> usize {
        self.staging.len()
    }

    /// Bytes currently held by the arena (capacity, not length).
    pub fn capacity_bytes(&self) -> usize {
        self.acc.capacity() * 4
            + self.acc_base.capacity() * 4
            + self.t.capacity() * 8
            + self.act.iter().map(|b| b.capacity()).sum::<usize>()
            + self.staging.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> QuantizedModel {
        QuantizedModel::synthetic("plan", &[6, 9, 4, 3], 5, 3, 11)
    }

    #[test]
    fn compile_resolves_all_layers() {
        let m = model();
        let plan = ExecutionPlan::compile(&m);
        assert_eq!(plan.layers.len(), 3);
        assert_eq!(plan.in_dim(), 6);
        assert_eq!(plan.out_dim(), 3);
        assert_eq!(plan.max_out, 9);
        assert_eq!(plan.max_act, 9, "last layer's width never hits the act buffers");
        assert!(Kernel::available().contains(&plan.kernel_kind()));
        assert_eq!(plan.batch_blocks().len(), 3);
        assert!(plan.batch_blocks().iter().all(|&bb| bb >= 1));
        for (lp, l) in plan.layers.iter().zip(&m.layers) {
            assert_eq!(lp.num_bases, l.num_bases());
            assert_eq!(lp.coeff16.len(), l.coeff.len());
            assert_eq!(
                lp.coeff16.iter().map(|&w| w as i64).sum::<i64>(),
                l.coeff.data().iter().map(|&w| w as i64).sum::<i64>(),
                "widening must be value-preserving"
            );
        }
        assert!(plan.derived_bytes() > 0);
    }

    #[test]
    fn execute_matches_across_scratch_states() {
        let m = model();
        let plan = ExecutionPlan::compile(&m);
        let x_q: Vec<u8> = (0..2 * 6).map(|i| (i * 37 % 256) as u8).collect();
        let mut fresh = Scratch::new();
        let want = plan.execute(&x_q, 2, &mut fresh).to_vec();
        // pre-sized and reused arenas produce the identical bytes
        let mut sized = Scratch::for_plan(&plan, 8);
        assert_eq!(plan.execute(&x_q, 2, &mut sized), &want[..]);
        assert_eq!(plan.execute(&x_q, 2, &mut sized), &want[..]);
        // staged path too
        sized.stage_input(x_q.len()).extend_from_slice(&x_q);
        assert_eq!(plan.execute_staged(2, &mut sized), &want[..]);
    }

    #[test]
    fn fused_requant_matches_per_layer_chain() {
        // the fused inter-layer path must byte-match the unfused chain
        // (forward_into + separate requantize pass) on every layer
        let m = model();
        let plan = ExecutionPlan::compile(&m);
        let bs = 5usize;
        let x_q: Vec<u8> = (0..bs * 6).map(|i| (i * 53 % 256) as u8).collect();
        // unfused reference chain over plain buffers
        let mut cur = x_q.clone();
        let mut want_t = Vec::new();
        for lp in &plan.layers {
            let n = lp.out_dim;
            let mut acc = vec![0i32; bs * n];
            let mut acc_base = vec![0i32; bs * n];
            let mut t = vec![0i64; bs * n];
            lp.forward_into(&cur, bs, &mut acc, &mut acc_base, &mut t);
            // and the fused variant must agree at this very layer
            let mut fused = vec![0u8; bs * n];
            let mut acc2 = vec![0i32; bs * n];
            let mut acc_base2 = vec![0i32; bs * n];
            lp.forward_requant_into(&cur, bs, &mut acc2, &mut acc_base2, &mut fused);
            let unfused: Vec<u8> = t.iter().map(|&v| quant::requantize(v)).collect();
            assert_eq!(fused, unfused, "fused requantize diverged");
            cur = unfused;
            want_t = t;
        }
        let mut s = Scratch::new();
        assert_eq!(plan.execute(&x_q, bs, &mut s), &want_t[..]);
    }

    #[test]
    fn bb_candidates_are_bit_exact() {
        // blocking is a pure scheduling choice: every bb yields the
        // identical accumulators (so autotune noise can't change results)
        let m = model();
        let plan = ExecutionPlan::compile(&m);
        let lp = &plan.layers[0];
        let bs = 37usize; // deliberately not a multiple of any candidate
        let x_q: Vec<u8> = (0..bs * lp.in_dim).map(|i| (i * 91 % 256) as u8).collect();
        let n = lp.out_dim;
        let mut want: Option<(Vec<i32>, Vec<i32>)> = None;
        for bb in [1usize, 3, 8, 16, 32, 64] {
            let mut acc = vec![0i32; bs * n];
            let mut acc_base = vec![0i32; bs * n];
            lp.accumulate_with_bb(bb, &x_q, bs, &mut acc, &mut acc_base);
            match &want {
                None => want = Some((acc, acc_base)),
                Some((wa, wb)) => {
                    assert_eq!(&acc, wa, "bb={bb} spline accumulators diverge");
                    assert_eq!(&acc_base, wb, "bb={bb} base accumulators diverge");
                }
            }
        }
    }

    #[test]
    fn packed_layers_match_widened_dense() {
        // Int4 -> Int8 widening via `with_precisions` is value-preserving
        // (same weights, same multipliers — only the storage format
        // changes), so the packed path must reproduce the dense path bit
        // for bit on every kernel. Odd out_dims (9, 3) exercise the
        // padded tail nibble.
        let m4 =
            QuantizedModel::synthetic_mixed("p4", &[6, 9, 4, 3], 5, 3, 11, &[Precision::Int4; 3]);
        let m8 = m4.with_precisions(&[Precision::Int8; 3]);
        let x_q: Vec<u8> = (0..5 * 6).map(|i| (i * 41 % 256) as u8).collect();
        for kind in Kernel::available() {
            let k = Kernel::forced(kind).unwrap();
            let dense = ExecutionPlan::compile_with(&m8, k);
            let packed = ExecutionPlan::compile_with(&m4, k);
            let mut s1 = Scratch::new();
            let mut s2 = Scratch::new();
            let want = dense.execute(&x_q, 5, &mut s1).to_vec();
            assert_eq!(packed.execute(&x_q, 5, &mut s2), &want[..], "kernel {kind}");
        }
    }

    #[test]
    fn mixed_plan_tables_and_bytes() {
        let prec = [Precision::Int4, Precision::Int8, Precision::Int4];
        let m = QuantizedModel::synthetic_mixed("mx", &[6, 9, 4, 3], 5, 3, 11, &prec);
        let plan = ExecutionPlan::compile(&m);
        assert_eq!(plan.precisions(), prec.to_vec());
        for (lp, l) in plan.layers.iter().zip(&m.layers) {
            let rb = quant::packed4_len(lp.out_dim);
            match lp.precision {
                Precision::Int4 => {
                    assert!(lp.coeff16.is_empty() && lp.base16.is_empty());
                    assert_eq!(lp.coeff4.len(), lp.in_dim * lp.num_bases * rb);
                    assert_eq!(lp.base4.len(), lp.in_dim * rb);
                    // packed rows decode back to the model's weights
                    let row0 = quant::unpack_i4(&lp.coeff4[..rb], lp.out_dim);
                    assert_eq!(&row0[..], &l.coeff.data()[..lp.out_dim]);
                }
                Precision::Int8 => {
                    assert!(lp.coeff4.is_empty() && lp.base4.is_empty());
                    assert_eq!(lp.coeff16.len(), l.coeff.len());
                }
            }
        }
        // packed layers hold their tables in strictly fewer derived bytes
        let dense = ExecutionPlan::compile(&m.with_precisions(&[Precision::Int8; 3]));
        assert!(plan.derived_bytes() < dense.derived_bytes());
    }

    #[test]
    fn packed_bb_candidates_are_bit_exact() {
        // blocking stays a pure scheduling choice on the packed path too
        let m =
            QuantizedModel::synthetic_mixed("pbb", &[6, 9, 4, 3], 5, 3, 11, &[Precision::Int4; 3]);
        let plan = ExecutionPlan::compile(&m);
        let lp = &plan.layers[0];
        let bs = 37usize;
        let x_q: Vec<u8> = (0..bs * lp.in_dim).map(|i| (i * 91 % 256) as u8).collect();
        let n = lp.out_dim;
        let mut want: Option<(Vec<i32>, Vec<i32>)> = None;
        for bb in [1usize, 3, 8, 16, 32, 64] {
            let mut acc = vec![0i32; bs * n];
            let mut acc_base = vec![0i32; bs * n];
            lp.accumulate_with_bb(bb, &x_q, bs, &mut acc, &mut acc_base);
            match &want {
                None => want = Some((acc, acc_base)),
                Some((wa, wb)) => {
                    assert_eq!(&acc, wa, "bb={bb} packed spline accumulators diverge");
                    assert_eq!(&acc_base, wb, "bb={bb} packed base accumulators diverge");
                }
            }
        }
    }

    #[test]
    fn compile_with_pins_the_kernel() {
        let m = model();
        let scalar = ExecutionPlan::compile_with(&m, Kernel::scalar());
        assert_eq!(scalar.kernel_kind(), KernelKind::Scalar);
        let x_q: Vec<u8> = (0..4 * 6).map(|i| (i * 29 % 256) as u8).collect();
        let mut s1 = Scratch::new();
        let want = scalar.execute(&x_q, 4, &mut s1).to_vec();
        // every available kernel reproduces the scalar plan bit for bit
        for kind in Kernel::available() {
            let plan = ExecutionPlan::compile_with(&m, Kernel::forced(kind).unwrap());
            assert_eq!(plan.kernel_kind(), kind);
            let mut s = Scratch::new();
            assert_eq!(plan.execute(&x_q, 4, &mut s), &want[..], "kernel {kind}");
        }
    }

    #[test]
    fn fit_covers_multiple_plans() {
        // a gateway worker's scratch: fitted to two differently-shaped
        // plans, it must execute both without growing
        let wide = ExecutionPlan::compile(&QuantizedModel::synthetic("w", &[12, 20, 6], 5, 3, 1));
        let tall = ExecutionPlan::compile(&QuantizedModel::synthetic("t", &[3, 40, 2], 5, 3, 2));
        let mut s = Scratch::new();
        s.fit(&wide, 8);
        s.fit(&tall, 8);
        let cap = s.capacity_bytes();
        let xw: Vec<u8> = (0..8 * 12).map(|i| (i % 256) as u8).collect();
        let xt: Vec<u8> = (0..8 * 3).map(|i| (i % 256) as u8).collect();
        s.stage_input(xw.len()).extend_from_slice(&xw);
        assert_eq!(wide.execute_staged(8, &mut s).len(), 8 * 6);
        s.stage_input(xt.len()).extend_from_slice(&xt);
        assert_eq!(tall.execute_staged(8, &mut s).len(), 8 * 2);
        assert_eq!(s.capacity_bytes(), cap, "fitted scratch must not grow in service");
    }

    #[test]
    fn scratch_grows_monotonically() {
        let plan = ExecutionPlan::compile(&model());
        let mut s = Scratch::new();
        s.ensure(&plan, 4);
        let cap4 = s.capacity_bytes();
        s.ensure(&plan, 2);
        assert_eq!(s.capacity_bytes(), cap4, "shrinking batch must not shrink the arena");
        s.ensure(&plan, 16);
        assert!(s.capacity_bytes() > cap4);
    }

    #[test]
    fn single_layer_model_needs_no_act_buffers() {
        let m = QuantizedModel::synthetic("one", &[4, 3], 5, 3, 2);
        let plan = ExecutionPlan::compile(&m);
        assert_eq!(plan.max_act, 0);
        let mut s = Scratch::new();
        let t = plan.execute(&[0, 128, 60, 255], 1, &mut s);
        assert_eq!(t.len(), 3);
        assert!(s.act.iter().all(|b| b.is_empty()));
    }

    #[test]
    fn kansas_bb_env_is_clamped() {
        // KANSAS_BB is read per compile; serialize around the env write.
        // All kernels/blockings are bit-exact, so concurrent tests that
        // merely compile plans can't be corrupted by this value.
        std::env::set_var("KANSAS_BB", "0");
        let plan = ExecutionPlan::compile(&model());
        assert!(plan.batch_blocks().iter().all(|&bb| bb == 1), "bb=0 must clamp to 1");
        std::env::set_var("KANSAS_BB", "24");
        let plan = ExecutionPlan::compile(&model());
        assert!(plan.batch_blocks().iter().all(|&bb| bb == 24));
        std::env::remove_var("KANSAS_BB");
    }
}
