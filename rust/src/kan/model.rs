//! Quantized KAN model: .kanq loading, parameter layout, and per-layer
//! storage precision (int8 or packed int4 — see `quant::pack_i4`).

use std::fmt;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::bspline::Lut;
use crate::quant;
use crate::tensor::Tensor;
use crate::util::container::Container;
use crate::util::json::Value;

/// Per-layer weight storage precision. `Int8` is the classic format;
/// `Int4` layers store coefficients/base weights as two's-complement
/// nibbles (two per byte) in artifacts and compiled plans, halving table
/// memory and doubling coefficients per SIMD load. In-memory
/// `LayerParams` tensors always hold the *unpacked* int8 values (an int4
/// layer's values simply stay within [-8, 7]); plan compile re-packs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    /// int8 symmetric weights (one byte per value).
    Int8,
    /// Packed int4 weights (two nibble values per byte).
    Int4,
}

impl Precision {
    /// Stable lowercase name — the artifact meta vocabulary and the
    /// string reported by `kansas serve` / `BENCH_engine.json`.
    pub fn name(self) -> &'static str {
        match self {
            Precision::Int8 => "int8",
            Precision::Int4 => "int4",
        }
    }

    /// Parse an artifact meta / `KANSAS_FORCE_PRECISION` value.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "int8" => Some(Precision::Int8),
            "int4" => Some(Precision::Int4),
            _ => None,
        }
    }

    /// Bits per stored weight.
    pub fn bits(self) -> usize {
        match self {
            Precision::Int8 => 8,
            Precision::Int4 => 4,
        }
    }
}

impl fmt::Display for Precision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One quantized KAN layer's parameters.
#[derive(Clone, Debug)]
pub struct LayerParams {
    pub in_dim: usize,
    pub out_dim: usize,
    pub grid: usize,
    pub degree: usize,
    /// The B-spline unit's ROM (256 x (P+1) uint8 + scale).
    pub lut: Lut,
    /// Spline coefficients `(K, M, N)` int8 (values within [-8, 7] when
    /// `precision` is `Int4`).
    pub coeff: Tensor<i8>,
    /// Base-path weights `(K, N)` int8 (same range rule).
    pub base: Tensor<i8>,
    /// Requantization multipliers (fixed-point, SHIFT bits).
    pub m1: i64,
    pub m2: i64,
    /// Float dequant scales (reporting only; classification never needs
    /// floats).
    pub s1: f64,
    pub s2: f64,
    /// Storage precision of this layer's weight tables (artifact and
    /// compiled-plan format; the tensors above are always unpacked).
    pub precision: Precision,
}

impl LayerParams {
    pub fn num_bases(&self) -> usize {
        self.grid + self.degree
    }

    /// Normalized RMS error this layer would incur if demoted int8 ->
    /// int4 (0 for an already-int4 layer). See `quant::demotion_error`.
    pub fn demotion_error(&self) -> f64 {
        if self.precision == Precision::Int4 {
            return 0.0;
        }
        let mut all = Vec::with_capacity(self.coeff.len() + self.base.len());
        all.extend_from_slice(self.coeff.data());
        all.extend_from_slice(self.base.data());
        quant::demotion_error(&all)
    }

    /// This layer demoted to int4: weights rounded to the nearest
    /// multiple of 16 and divided by it, requant multipliers (and the
    /// reporting scales) multiplied by exactly 16 to compensate.
    pub fn demoted(&self) -> LayerParams {
        let q = |t: &Tensor<i8>| {
            let v: Vec<i8> = t.data().iter().map(|&w| quant::demote_i8_to_i4(w)).collect();
            Tensor::from_vec(v, t.shape())
        };
        LayerParams {
            coeff: q(&self.coeff),
            base: q(&self.base),
            m1: self.m1 * 16,
            m2: self.m2 * 16,
            s1: self.s1 * 16.0,
            s2: self.s2 * 16.0,
            precision: Precision::Int4,
            ..self.clone()
        }
    }
}

/// A stack of quantized KAN layers loaded from a `.kanq` artifact.
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    pub name: String,
    pub dims: Vec<usize>,
    pub layers: Vec<LayerParams>,
}

impl QuantizedModel {
    pub fn load(path: &Path) -> Result<Self> {
        Self::from_container(&Container::open(path)?)
    }

    /// Parse a `KANQ0001` container — the body of
    /// [`QuantizedModel::load`], callable on in-memory bytes (tests
    /// fabricate packed-int4 artifacts through
    /// `Container::from_bytes` without touching disk).
    pub fn from_container(c: &Container) -> Result<Self> {
        c.expect_magic(b"KANQ0001")?;
        let h = &c.header;
        let name = h.get("name").and_then(Value::as_str).context("name")?.to_string();
        let dims: Vec<usize> = h
            .get("dims")
            .and_then(Value::as_arr)
            .context("dims")?
            .iter()
            .map(|v| v.as_usize().context("dim"))
            .collect::<Result<_>>()?;
        let shift = h.get("shift").and_then(Value::as_i64).context("shift")?;
        if shift != crate::quant::SHIFT as i64 {
            bail!("artifact SHIFT {shift} != engine SHIFT {}", crate::quant::SHIFT);
        }
        let meta = h.get("layers").and_then(Value::as_arr).context("layers")?;
        if meta.len() + 1 != dims.len() {
            bail!("layer count {} inconsistent with dims {:?}", meta.len(), dims);
        }

        let mut layers = Vec::with_capacity(meta.len());
        for (i, lm) in meta.iter().enumerate() {
            let grid = lm.get("grid").and_then(Value::as_usize).context("grid")?;
            let degree = lm.get("degree").and_then(Value::as_usize).context("degree")?;
            let in_dim = lm.get("in_dim").and_then(Value::as_usize).context("in_dim")?;
            let out_dim = lm.get("out_dim").and_then(Value::as_usize).context("out_dim")?;
            let s_b = lm.get("s_b").and_then(Value::as_f64).context("s_b")?;

            // absent precision meta means int8 — every pre-existing
            // artifact loads unchanged
            let precision = match lm.get("precision").and_then(Value::as_str) {
                None => Precision::Int8,
                Some(s) => Precision::parse(s)
                    .with_context(|| format!("layer {i} unknown precision {s:?}"))?,
            };

            let (lut_raw, lut_shape) = c.u8(&format!("l{i}.lut"))?;
            if lut_shape != [256, degree + 1] {
                bail!("layer {i} lut shape {lut_shape:?}");
            }
            let (coeff, base) = match precision {
                Precision::Int8 => {
                    let (coeff_raw, cs) = c.i8(&format!("l{i}.coeff"))?;
                    if cs != [in_dim, grid + degree, out_dim] {
                        bail!("layer {i} coeff shape {cs:?}");
                    }
                    let (base_raw, bs) = c.i8(&format!("l{i}.base"))?;
                    if bs != [in_dim, out_dim] {
                        bail!("layer {i} base shape {bs:?}");
                    }
                    (Tensor::from_vec(coeff_raw, &cs), Tensor::from_vec(base_raw, &bs))
                }
                Precision::Int4 => {
                    // packed nibbles on disk (row stride ceil(N/2) bytes);
                    // unpack to int8 tensors — plan compile re-packs
                    let rb = quant::packed4_len(out_dim);
                    let (c4, cs) = c.u8(&format!("l{i}.coeff4"))?;
                    if cs != [in_dim, grid + degree, rb] {
                        bail!("layer {i} coeff4 shape {cs:?}");
                    }
                    let (b4, bsh) = c.u8(&format!("l{i}.base4"))?;
                    if bsh != [in_dim, rb] {
                        bail!("layer {i} base4 shape {bsh:?}");
                    }
                    let unpack = |packed: &[u8]| -> Vec<i8> {
                        packed
                            .chunks_exact(rb)
                            .flat_map(|row| quant::unpack_i4(row, out_dim))
                            .collect()
                    };
                    (
                        Tensor::from_vec(unpack(&c4), &[in_dim, grid + degree, out_dim]),
                        Tensor::from_vec(unpack(&b4), &[in_dim, out_dim]),
                    )
                }
            };
            layers.push(LayerParams {
                in_dim,
                out_dim,
                grid,
                degree,
                lut: Lut::from_raw(lut_raw, degree, s_b),
                coeff,
                base,
                m1: lm.get("m1").and_then(Value::as_i64).context("m1")?,
                m2: lm.get("m2").and_then(Value::as_i64).context("m2")?,
                s1: lm.get("s1").and_then(Value::as_f64).context("s1")?,
                s2: lm.get("s2").and_then(Value::as_f64).context("s2")?,
                precision,
            });
        }
        Ok(Self { name, dims, layers })
    }

    /// Deterministic random model for tests, benches, and artifact-free
    /// serving runs (`kansas serve --synthetic`). The weights are noise —
    /// the integer datapath does the same work as a trained model of the
    /// same shape, which is all throughput/latency measurement needs.
    /// Requant multipliers are sized so mid-layer activations use a
    /// reasonable slice of the uint8 range instead of saturating.
    ///
    /// All layers are int8 unless `KANSAS_FORCE_PRECISION` (`int8|int4`)
    /// forces a uniform precision — the hook the CI int4 legs use to run
    /// every synthetic-model test through the packed kernel paths.
    pub fn synthetic(name: &str, dims: &[usize], grid: usize, degree: usize, seed: u64) -> Self {
        assert!(dims.len() >= 2, "need at least one layer");
        let mut forced = Precision::Int8;
        if let Ok(want) = std::env::var("KANSAS_FORCE_PRECISION") {
            match Precision::parse(&want) {
                Some(p) => forced = p,
                None => eprintln!(
                    "KANSAS_FORCE_PRECISION={want}: unknown precision (want int8|int4); \
                     using int8"
                ),
            }
        }
        Self::synthetic_mixed(name, dims, grid, degree, seed, &vec![forced; dims.len() - 1])
    }

    /// [`QuantizedModel::synthetic`] with an explicit per-layer precision
    /// vector (`precisions.len() == dims.len() - 1`). Int4 layers draw
    /// weights natively in [-8, 7] with requant multipliers 16x the int8
    /// ones, so activation magnitudes stay comparable across precisions.
    pub fn synthetic_mixed(
        name: &str,
        dims: &[usize],
        grid: usize,
        degree: usize,
        seed: u64,
        precisions: &[Precision],
    ) -> Self {
        assert!(dims.len() >= 2, "need at least one layer");
        assert_eq!(precisions.len(), dims.len() - 1, "one precision per layer");
        let mut rng = crate::util::rng::Rng::new(seed);
        let m = grid + degree;
        let layers = dims
            .windows(2)
            .zip(precisions)
            .map(|(w, &precision)| {
                let (k, n) = (w[0], w[1]);
                let (lo, hi, m1, m2) = match precision {
                    Precision::Int8 => (-60i64, 60i64, 9000i64, 3000i64),
                    Precision::Int4 => (-8, 7, 72000, 24000),
                };
                let coeff: Vec<i8> =
                    (0..k * m * n).map(|_| rng.range_i64(lo, hi) as i8).collect();
                let base: Vec<i8> = (0..k * n).map(|_| rng.range_i64(lo, hi) as i8).collect();
                LayerParams {
                    in_dim: k,
                    out_dim: n,
                    grid,
                    degree,
                    lut: Lut::build(degree),
                    coeff: Tensor::from_vec(coeff, &[k, m, n]),
                    base: Tensor::from_vec(base, &[k, n]),
                    m1,
                    m2,
                    s1: 1.0,
                    s2: 1.0,
                    precision,
                }
            })
            .collect();
        Self { name: name.to_string(), dims: dims.to_vec(), layers }
    }

    /// A copy of this model with the given per-layer precisions. Int8 ->
    /// int4 demotes (see [`LayerParams::demoted`] — lossy by rounding to
    /// multiples of 16); int4 -> int8 is a pure storage-format change
    /// (same values dense, bit-exact outputs).
    pub fn with_precisions(&self, precisions: &[Precision]) -> Self {
        assert_eq!(precisions.len(), self.layers.len(), "one precision per layer");
        let layers = self
            .layers
            .iter()
            .zip(precisions)
            .map(|(l, &p)| {
                if l.precision == p {
                    l.clone()
                } else if p == Precision::Int4 {
                    l.demoted()
                } else {
                    let mut widened = l.clone();
                    widened.precision = Precision::Int8;
                    widened
                }
            })
            .collect();
        Self { name: self.name.clone(), dims: self.dims.clone(), layers }
    }

    /// Per-layer mixed precision chosen from a quantization-error budget:
    /// demote every layer whose normalized RMS demotion error (see
    /// [`LayerParams::demotion_error`]) is within `budget`, keep the rest
    /// int8. `budget >= 1.0` demotes everything; `budget < 0` nothing.
    pub fn with_precision_budget(&self, budget: f64) -> Self {
        let precisions: Vec<Precision> = self
            .layers
            .iter()
            .map(|l| {
                if l.precision == Precision::Int4 || l.demotion_error() <= budget {
                    Precision::Int4
                } else {
                    Precision::Int8
                }
            })
            .collect();
        self.with_precisions(&precisions)
    }

    /// Per-layer storage precisions, in layer order.
    pub fn precisions(&self) -> Vec<Precision> {
        self.layers.iter().map(|l| l.precision).collect()
    }

    pub fn in_dim(&self) -> usize {
        self.dims[0]
    }

    pub fn out_dim(&self) -> usize {
        *self.dims.last().unwrap()
    }

    /// Total int8 parameters (coefficients + base weights).
    pub fn num_params(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.coeff.len() + l.base.len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifact(name: &str) -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(name);
        p.exists().then_some(p)
    }

    #[test]
    fn loads_quickstart_artifact() {
        let Some(path) = artifact("quickstart_kan.kanq") else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = QuantizedModel::load(&path).unwrap();
        assert_eq!(m.dims, vec![4, 8, 3]);
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.layers[0].grid, 5);
        assert_eq!(m.layers[0].degree, 3);
        assert!(m.num_params() > 0);
    }

    #[test]
    fn synthetic_is_deterministic_and_runs() {
        let a = QuantizedModel::synthetic("syn", &[4, 8, 3], 5, 3, 7);
        let b = QuantizedModel::synthetic("syn", &[4, 8, 3], 5, 3, 7);
        assert_eq!(a.layers.len(), 2);
        assert_eq!(a.in_dim(), 4);
        assert_eq!(a.out_dim(), 3);
        assert_eq!(a.layers[0].coeff.data(), b.layers[0].coeff.data());
        let e = crate::kan::Engine::new(a);
        let fwd = e.forward_from_q(&[0, 128, 37, 255], 1).unwrap();
        assert_eq!(fwd.t.len(), 3);
    }

    #[test]
    fn rejects_wrong_magic() {
        let Some(path) = artifact("quickstart_kan_golden.kgld") else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(QuantizedModel::load(&path).is_err());
    }

    #[test]
    fn precision_names_round_trip() {
        for p in [Precision::Int8, Precision::Int4] {
            assert_eq!(Precision::parse(p.name()), Some(p));
            assert_eq!(p.to_string(), p.name());
        }
        assert_eq!(Precision::Int8.bits(), 8);
        assert_eq!(Precision::Int4.bits(), 4);
        assert_eq!(Precision::parse(" INT4 "), Some(Precision::Int4));
        assert_eq!(Precision::parse("fp8"), None);
    }

    #[test]
    fn synthetic_mixed_ranges_and_multipliers() {
        use Precision::*;
        let m = QuantizedModel::synthetic_mixed("mix", &[4, 8, 3], 5, 3, 7, &[Int4, Int8]);
        assert_eq!(m.precisions(), vec![Int4, Int8]);
        let l0 = &m.layers[0];
        assert!(l0.coeff.data().iter().all(|&w| (-8..=7).contains(&w)));
        assert!(l0.base.data().iter().all(|&w| (-8..=7).contains(&w)));
        assert_eq!((l0.m1, l0.m2), (72000, 24000));
        assert_eq!((m.layers[1].m1, m.layers[1].m2), (9000, 3000));
        // deterministic
        let m2 = QuantizedModel::synthetic_mixed("mix", &[4, 8, 3], 5, 3, 7, &[Int4, Int8]);
        assert_eq!(m.layers[0].coeff.data(), m2.layers[0].coeff.data());
    }

    #[test]
    fn demotion_scales_multipliers_exactly() {
        let m = QuantizedModel::synthetic("d", &[4, 6, 3], 5, 3, 9);
        let d = m.with_precisions(&[Precision::Int4, Precision::Int4]);
        for (l8, l4) in m.layers.iter().zip(&d.layers) {
            assert_eq!(l4.precision, Precision::Int4);
            assert_eq!(l4.m1, l8.m1 * 16);
            assert_eq!(l4.m2, l8.m2 * 16);
            assert!(l4.coeff.data().iter().all(|&w| (-8..=7).contains(&w)));
            for (&w8, &w4) in l8.coeff.data().iter().zip(l4.coeff.data()) {
                assert_eq!(w4, crate::quant::demote_i8_to_i4(w8));
            }
        }
        // widening back is a storage-format change only: values unchanged
        let w = d.with_precisions(&[Precision::Int8, Precision::Int8]);
        for (l4, l8) in d.layers.iter().zip(&w.layers) {
            assert_eq!(l8.precision, Precision::Int8);
            assert_eq!(l4.coeff.data(), l8.coeff.data());
            assert_eq!((l4.m1, l4.m2), (l8.m1, l8.m2));
        }
    }

    #[test]
    fn precision_budget_selects_layers() {
        let m = QuantizedModel::synthetic("b", &[4, 6, 3], 5, 3, 13);
        // synthetic int8 weights (-60..60) demote with error in (0, 1)
        for l in &m.layers {
            let e = l.demotion_error();
            assert!(e > 0.0 && e < 1.0, "err={e}");
        }
        let all4 = m.with_precision_budget(1.0);
        assert!(all4.precisions().iter().all(|&p| p == Precision::Int4));
        assert!(m.with_precision_budget(-1.0).precisions().iter().all(|&p| p == Precision::Int8));
        // already-int4 layers stay int4 under any budget
        assert!(all4.with_precision_budget(-1.0).precisions().iter().all(|&p| p
            == Precision::Int4));
    }

    /// Serialize a model the way `python/compile/aot.py::export_kanq`
    /// does — int4 layers as packed `coeff4`/`base4` uint8 tensors — so
    /// the loader's nibble decode is pinned without needing `make
    /// artifacts`.
    fn container_bytes(m: &QuantizedModel) -> Vec<u8> {
        use std::collections::BTreeMap;
        let mut body: Vec<u8> = Vec::new();
        let mut table: BTreeMap<String, Value> = BTreeMap::new();
        let mut put = |table: &mut BTreeMap<String, Value>,
                       body: &mut Vec<u8>,
                       name: String,
                       dtype: &str,
                       shape: &[usize],
                       bytes: Vec<u8>| {
            let mut t = BTreeMap::new();
            t.insert("dtype".to_string(), Value::str(dtype));
            t.insert(
                "shape".to_string(),
                Value::arr(shape.iter().map(|&d| Value::num(d as f64))),
            );
            t.insert("offset".to_string(), Value::num(body.len() as f64));
            t.insert("nbytes".to_string(), Value::num(bytes.len() as f64));
            table.insert(name, Value::Obj(t));
            body.extend_from_slice(&bytes);
        };
        let mut metas = Vec::new();
        for (i, l) in m.layers.iter().enumerate() {
            let rb = crate::quant::packed4_len(l.out_dim);
            put(
                &mut table,
                &mut body,
                format!("l{i}.lut"),
                "uint8",
                &[256, l.degree + 1],
                l.lut.raw().to_vec(),
            );
            let pack = |t: &Tensor<i8>| -> Vec<u8> {
                t.data()
                    .chunks_exact(l.out_dim)
                    .flat_map(|row| crate::quant::pack_i4(row))
                    .collect()
            };
            match l.precision {
                Precision::Int8 => {
                    let as_bytes = |t: &Tensor<i8>| t.data().iter().map(|&v| v as u8).collect();
                    put(
                        &mut table,
                        &mut body,
                        format!("l{i}.coeff"),
                        "int8",
                        l.coeff.shape(),
                        as_bytes(&l.coeff),
                    );
                    put(
                        &mut table,
                        &mut body,
                        format!("l{i}.base"),
                        "int8",
                        l.base.shape(),
                        as_bytes(&l.base),
                    );
                }
                Precision::Int4 => {
                    put(
                        &mut table,
                        &mut body,
                        format!("l{i}.coeff4"),
                        "uint8",
                        &[l.in_dim, l.num_bases(), rb],
                        pack(&l.coeff),
                    );
                    put(
                        &mut table,
                        &mut body,
                        format!("l{i}.base4"),
                        "uint8",
                        &[l.in_dim, rb],
                        pack(&l.base),
                    );
                }
            }
            let mut lm = BTreeMap::new();
            lm.insert("grid".to_string(), Value::num(l.grid as f64));
            lm.insert("degree".to_string(), Value::num(l.degree as f64));
            lm.insert("in_dim".to_string(), Value::num(l.in_dim as f64));
            lm.insert("out_dim".to_string(), Value::num(l.out_dim as f64));
            lm.insert("s_b".to_string(), Value::num(l.lut.scale));
            lm.insert("m1".to_string(), Value::num(l.m1 as f64));
            lm.insert("m2".to_string(), Value::num(l.m2 as f64));
            lm.insert("s1".to_string(), Value::num(l.s1));
            lm.insert("s2".to_string(), Value::num(l.s2));
            if l.precision != Precision::Int8 {
                lm.insert("precision".to_string(), Value::str(l.precision.name()));
            }
            metas.push(Value::Obj(lm));
        }
        let mut h = BTreeMap::new();
        h.insert("name".to_string(), Value::str(m.name.clone()));
        h.insert("dims".to_string(), Value::arr(m.dims.iter().map(|&d| Value::num(d as f64))));
        h.insert("shift".to_string(), Value::num(crate::quant::SHIFT as f64));
        h.insert("layers".to_string(), Value::arr(metas));
        h.insert("tensors".to_string(), Value::Obj(table));
        let header = Value::Obj(h).render();
        let mut raw = Vec::new();
        raw.extend_from_slice(b"KANQ0001");
        raw.extend_from_slice(&(header.len() as u32).to_le_bytes());
        raw.extend_from_slice(header.as_bytes());
        raw.extend_from_slice(&body);
        raw
    }

    #[test]
    fn int4_artifact_roundtrip_in_memory() {
        use Precision::*;
        // odd out_dims force packed rows with tail nibbles
        let m = QuantizedModel::synthetic_mixed("pk", &[4, 7, 3], 5, 3, 21, &[Int4, Int8]);
        let c = Container::from_bytes(container_bytes(&m)).unwrap();
        let got = QuantizedModel::from_container(&c).unwrap();
        assert_eq!(got.precisions(), vec![Int4, Int8]);
        assert_eq!(got.dims, m.dims);
        for (a, b) in m.layers.iter().zip(&got.layers) {
            assert_eq!(a.coeff.data(), b.coeff.data(), "coeff nibbles must decode exactly");
            assert_eq!(a.base.data(), b.base.data());
            assert_eq!((a.m1, a.m2), (b.m1, b.m2));
        }
        // and the loaded model computes: engine forward runs
        let e = crate::kan::Engine::new(got);
        assert_eq!(e.forward_from_q(&[0, 128, 37, 255], 1).unwrap().t.len(), 3);
    }
}
