//! Quantized KAN model: .kanq loading and parameter layout.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::bspline::Lut;
use crate::tensor::Tensor;
use crate::util::container::Container;
use crate::util::json::Value;

/// One quantized KAN layer's parameters.
#[derive(Clone, Debug)]
pub struct LayerParams {
    pub in_dim: usize,
    pub out_dim: usize,
    pub grid: usize,
    pub degree: usize,
    /// The B-spline unit's ROM (256 x (P+1) uint8 + scale).
    pub lut: Lut,
    /// Spline coefficients `(K, M, N)` int8.
    pub coeff: Tensor<i8>,
    /// Base-path weights `(K, N)` int8.
    pub base: Tensor<i8>,
    /// Requantization multipliers (fixed-point, SHIFT bits).
    pub m1: i64,
    pub m2: i64,
    /// Float dequant scales (reporting only; classification never needs
    /// floats).
    pub s1: f64,
    pub s2: f64,
}

impl LayerParams {
    pub fn num_bases(&self) -> usize {
        self.grid + self.degree
    }
}

/// A stack of quantized KAN layers loaded from a `.kanq` artifact.
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    pub name: String,
    pub dims: Vec<usize>,
    pub layers: Vec<LayerParams>,
}

impl QuantizedModel {
    pub fn load(path: &Path) -> Result<Self> {
        let c = Container::open(path)?;
        c.expect_magic(b"KANQ0001")?;
        let h = &c.header;
        let name = h.get("name").and_then(Value::as_str).context("name")?.to_string();
        let dims: Vec<usize> = h
            .get("dims")
            .and_then(Value::as_arr)
            .context("dims")?
            .iter()
            .map(|v| v.as_usize().context("dim"))
            .collect::<Result<_>>()?;
        let shift = h.get("shift").and_then(Value::as_i64).context("shift")?;
        if shift != crate::quant::SHIFT as i64 {
            bail!("artifact SHIFT {shift} != engine SHIFT {}", crate::quant::SHIFT);
        }
        let meta = h.get("layers").and_then(Value::as_arr).context("layers")?;
        if meta.len() + 1 != dims.len() {
            bail!("layer count {} inconsistent with dims {:?}", meta.len(), dims);
        }

        let mut layers = Vec::with_capacity(meta.len());
        for (i, lm) in meta.iter().enumerate() {
            let grid = lm.get("grid").and_then(Value::as_usize).context("grid")?;
            let degree = lm.get("degree").and_then(Value::as_usize).context("degree")?;
            let in_dim = lm.get("in_dim").and_then(Value::as_usize).context("in_dim")?;
            let out_dim = lm.get("out_dim").and_then(Value::as_usize).context("out_dim")?;
            let s_b = lm.get("s_b").and_then(Value::as_f64).context("s_b")?;

            let (lut_raw, lut_shape) = c.u8(&format!("l{i}.lut"))?;
            if lut_shape != [256, degree + 1] {
                bail!("layer {i} lut shape {lut_shape:?}");
            }
            let (coeff_raw, cs) = c.i8(&format!("l{i}.coeff"))?;
            if cs != [in_dim, grid + degree, out_dim] {
                bail!("layer {i} coeff shape {cs:?}");
            }
            let (base_raw, bs) = c.i8(&format!("l{i}.base"))?;
            if bs != [in_dim, out_dim] {
                bail!("layer {i} base shape {bs:?}");
            }
            layers.push(LayerParams {
                in_dim,
                out_dim,
                grid,
                degree,
                lut: Lut::from_raw(lut_raw, degree, s_b),
                coeff: Tensor::from_vec(coeff_raw, &cs),
                base: Tensor::from_vec(base_raw, &bs),
                m1: lm.get("m1").and_then(Value::as_i64).context("m1")?,
                m2: lm.get("m2").and_then(Value::as_i64).context("m2")?,
                s1: lm.get("s1").and_then(Value::as_f64).context("s1")?,
                s2: lm.get("s2").and_then(Value::as_f64).context("s2")?,
            });
        }
        Ok(Self { name, dims, layers })
    }

    /// Deterministic random model for tests, benches, and artifact-free
    /// serving runs (`kansas serve --synthetic`). The weights are noise —
    /// the integer datapath does the same work as a trained model of the
    /// same shape, which is all throughput/latency measurement needs.
    /// Requant multipliers are sized so mid-layer activations use a
    /// reasonable slice of the uint8 range instead of saturating.
    pub fn synthetic(name: &str, dims: &[usize], grid: usize, degree: usize, seed: u64) -> Self {
        assert!(dims.len() >= 2, "need at least one layer");
        let mut rng = crate::util::rng::Rng::new(seed);
        let m = grid + degree;
        let layers = dims
            .windows(2)
            .map(|w| {
                let (k, n) = (w[0], w[1]);
                let coeff: Vec<i8> =
                    (0..k * m * n).map(|_| rng.range_i64(-60, 60) as i8).collect();
                let base: Vec<i8> = (0..k * n).map(|_| rng.range_i64(-60, 60) as i8).collect();
                LayerParams {
                    in_dim: k,
                    out_dim: n,
                    grid,
                    degree,
                    lut: Lut::build(degree),
                    coeff: Tensor::from_vec(coeff, &[k, m, n]),
                    base: Tensor::from_vec(base, &[k, n]),
                    m1: 9000,
                    m2: 3000,
                    s1: 1.0,
                    s2: 1.0,
                }
            })
            .collect();
        Self { name: name.to_string(), dims: dims.to_vec(), layers }
    }

    pub fn in_dim(&self) -> usize {
        self.dims[0]
    }

    pub fn out_dim(&self) -> usize {
        *self.dims.last().unwrap()
    }

    /// Total int8 parameters (coefficients + base weights).
    pub fn num_params(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.coeff.len() + l.base.len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifact(name: &str) -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(name);
        p.exists().then_some(p)
    }

    #[test]
    fn loads_quickstart_artifact() {
        let Some(path) = artifact("quickstart_kan.kanq") else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = QuantizedModel::load(&path).unwrap();
        assert_eq!(m.dims, vec![4, 8, 3]);
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.layers[0].grid, 5);
        assert_eq!(m.layers[0].degree, 3);
        assert!(m.num_params() > 0);
    }

    #[test]
    fn synthetic_is_deterministic_and_runs() {
        let a = QuantizedModel::synthetic("syn", &[4, 8, 3], 5, 3, 7);
        let b = QuantizedModel::synthetic("syn", &[4, 8, 3], 5, 3, 7);
        assert_eq!(a.layers.len(), 2);
        assert_eq!(a.in_dim(), 4);
        assert_eq!(a.out_dim(), 3);
        assert_eq!(a.layers[0].coeff.data(), b.layers[0].coeff.data());
        let e = crate::kan::Engine::new(a);
        let fwd = e.forward_from_q(&[0, 128, 37, 255], 1).unwrap();
        assert_eq!(fwd.t.len(), 3);
    }

    #[test]
    fn rejects_wrong_magic() {
        let Some(path) = artifact("quickstart_kan_golden.kgld") else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(QuantizedModel::load(&path).is_err());
    }
}
