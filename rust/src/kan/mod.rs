//! The bit-exact integer KAN inference engine (the paper's accelerated
//! datapath, executed functionally).
//!
//! Loads `.kanq` artifacts exported by `python/compile/aot.py` and runs
//! integer-only inference: B-spline unit -> N:M spline GEMM -> integer
//! ReLU base path -> fixed-point requantization, layer by layer. Every
//! operation mirrors `python/compile/quantize.py`; the exported golden
//! vectors must replay *exactly* (integration tests in `rust/tests/`).

pub mod engine;
pub mod kernel;
pub mod model;
pub mod plan;

pub use engine::Engine;
pub use kernel::{Kernel, KernelKind};
pub use model::{LayerParams, Precision, QuantizedModel};
pub use plan::{ExecutionPlan, LayerPlan, Scratch};
