//! Integer-only forward pass — the functional twin of the KAN-SAs
//! datapath, bit-exact against `python/compile/quantize.py`.
//!
//! Per layer (paper Eq. 1, quantized):
//!
//! 1. **B-spline unit** per input feature: `(vals[P+1], k)` from the LUT
//!    (Sec. III-B);
//! 2. **N:M spline GEMM**: `acc += vals[j] * coeff[feat, k-P+j, out]` —
//!    exactly what one column of vector PEs accumulates (Sec. IV-B);
//! 3. **base path**: integer ReLU then a dense i32 GEMM;
//! 4. **requantize**: `t = acc1*m1 + acc2*m2` (i64) -> next uint8
//!    activations, or raw `t` logits at the last layer. On the serving
//!    path this step is *fused* for non-final layers: combine and
//!    requantize happen in one pass over the i32 accumulators without
//!    ever materializing `t` (see `plan::LayerPlan::forward_requant_into`).
//!
//! The MAC inner loops of steps 2-3 run through the SIMD kernel layer
//! ([`super::kernel`]), resolved once per plan compile.
//!
//! The engine follows a compile/execute split (see [`super::plan`]): all
//! per-layer state is resolved once into an [`ExecutionPlan`] when the
//! engine is built — mirroring the accelerator, which wires LUT ROMs and
//! window widths before the first activation streams in — and the hot
//! path [`Engine::forward_into`] runs the plan against a caller-owned
//! [`Scratch`] with zero steady-state heap allocations.

use std::sync::{Arc, Mutex};

use anyhow::{ensure, Result};

use crate::quant;
use crate::sim::SimStats;
use crate::sim::analytic;
use crate::sim::workload::Workload;
use crate::arch::ArrayConfig;

use super::kernel::Kernel;
use super::model::QuantizedModel;
use super::plan::{ExecutionPlan, Scratch};

/// Inference engine over a loaded quantized model.
///
/// All parameter state is behind `Arc`: cloning an `Engine` produces a
/// replica that *aliases* the same model weights and compiled
/// [`ExecutionPlan`] (LUT ROMs, widened MAC tables), so an N-replica
/// serving pool (`coordinator::pool`) costs ~1x model memory regardless
/// of N. Verified by [`Engine::shares_weights_with`] and the aliasing
/// test below. Each clone gets its own (empty) compatibility scratch.
#[derive(Debug)]
pub struct Engine {
    pub model: Arc<QuantizedModel>,
    plan: Arc<ExecutionPlan>,
    /// Lazily-grown scratch backing the allocating compatibility wrappers
    /// ([`Engine::forward`] / [`Engine::forward_from_q`]). The mutex is
    /// uncontended in practice — serving workers own their `Scratch` and
    /// call [`Engine::forward_into`] / [`Engine::forward_staged`] instead.
    /// Grow-only: one huge-batch wrapper call pins that arena size for
    /// the engine's lifetime (batch-size-bound callers like
    /// [`Engine::accuracy`] chunk their input; callers that need the
    /// memory back should own a `Scratch` and drop it).
    scratch: Mutex<Scratch>,
}

impl Clone for Engine {
    fn clone(&self) -> Self {
        Self {
            model: Arc::clone(&self.model),
            plan: Arc::clone(&self.plan),
            scratch: Mutex::new(Scratch::new()),
        }
    }
}

/// Result of a batched forward pass.
#[derive(Clone, Debug)]
pub struct Forward {
    /// Final-layer i64 accumulators `(BS, out_dim)` (monotone in the
    /// float logits — argmax is classification).
    pub t: Vec<i64>,
    pub bs: usize,
    pub out_dim: usize,
}

impl Forward {
    pub fn logits_f64(&self) -> Vec<f64> {
        // dequantize for reporting: t / (128 * 2^SHIFT) (see python)
        let denom = 128.0 * (1u64 << quant::SHIFT) as f64;
        self.t.iter().map(|&v| v as f64 / denom).collect()
    }

    pub fn predictions(&self) -> Vec<usize> {
        self.t.chunks_exact(self.out_dim).map(|row| crate::util::argmax(row)).collect()
    }
}

impl Engine {
    pub fn new(model: QuantizedModel) -> Self {
        Self::from_shared(Arc::new(model))
    }

    /// Build an engine over an already-shared model, compiling its
    /// [`ExecutionPlan`] once (additional replicas should just `clone()`
    /// an existing engine, which also shares the compiled plan).
    pub fn from_shared(model: Arc<QuantizedModel>) -> Self {
        let plan = Arc::new(ExecutionPlan::compile(&model));
        Self { model, plan, scratch: Mutex::new(Scratch::new()) }
    }

    /// Build an engine whose plan is pinned to a specific MAC kernel
    /// instead of runtime dispatch — how the benches produce the
    /// forced-scalar baseline rows and the kernel tests compare paths
    /// without mutating the process environment.
    pub fn with_kernel(model: QuantizedModel, kernel: Kernel) -> Self {
        let model = Arc::new(model);
        let plan = Arc::new(ExecutionPlan::compile_with(&model, kernel));
        Self { model, plan, scratch: Mutex::new(Scratch::new()) }
    }

    /// The compiled execution plan (shared by all replicas).
    pub fn plan(&self) -> &ExecutionPlan {
        &self.plan
    }

    /// Input feature count (`model.in_dim()`, hoisted for callers that
    /// hold many engines — e.g. a multi-model gateway sizing buffers).
    pub fn in_dim(&self) -> usize {
        self.model.in_dim()
    }

    /// Output row width (`model.out_dim()`).
    pub fn out_dim(&self) -> usize {
        self.model.out_dim()
    }

    /// True when `self` and `other` alias the same parameter storage —
    /// i.e. they are replicas of one model, not independent copies.
    pub fn shares_weights_with(&self, other: &Engine) -> bool {
        Arc::ptr_eq(&self.model, &other.model) && Arc::ptr_eq(&self.plan, &other.plan)
    }

    /// Bytes of parameter + compiled-plan storage. Counted once per
    /// model: clones share the same allocations, so a pool's weight
    /// footprint is `param_bytes()` regardless of replica count.
    pub fn param_bytes(&self) -> usize {
        let model: usize = self
            .model
            .layers
            .iter()
            .map(|l| l.coeff.len() + l.base.len() + l.lut.raw().len())
            .sum();
        model + self.plan.derived_bytes()
    }

    /// Forward one layer of the compiled plan: uint8 activations
    /// `(BS, K)` -> i64 `t (BS, N)`. A debug/test entry point (golden
    /// replay inspects per-layer activations); the serving path executes
    /// the whole plan via [`Engine::forward_into`].
    pub fn layer_forward(&self, layer_idx: usize, x_q: &[u8], bs: usize) -> Vec<i64> {
        let lp = &self.plan.layers[layer_idx];
        let n = lp.out_dim;
        let mut acc = vec![0i32; bs * n];
        let mut acc_base = vec![0i32; bs * n];
        let mut t = vec![0i64; bs * n];
        lp.forward_into(x_q, bs, &mut acc, &mut acc_base, &mut t);
        t
    }

    /// Allocation-free full forward from uint8 inputs: executes the plan
    /// against a caller-owned scratch and returns the final-layer i64
    /// accumulators `(bs, out_dim)` living in that scratch. After the
    /// scratch has warmed up at a batch size, subsequent calls at that
    /// size (or smaller) perform zero heap allocations
    /// (`tests/zero_alloc.rs` asserts this with a counting allocator).
    pub fn forward_into<'s>(
        &self,
        x_q: &[u8],
        bs: usize,
        scratch: &'s mut Scratch,
    ) -> Result<&'s [i64]> {
        ensure!(
            x_q.len() == bs * self.model.in_dim(),
            "input size {} != bs {} x in_dim {}",
            x_q.len(),
            bs,
            self.model.in_dim()
        );
        Ok(self.plan.execute(x_q, bs, scratch))
    }

    /// Allocation-free forward over inputs already gathered into the
    /// scratch's staging buffer (see [`Scratch::stage_input`]) — the
    /// serving-pool path: workers copy request rows straight into staging
    /// and execute, with no intermediate batch `Vec`.
    pub fn forward_staged<'s>(&self, bs: usize, scratch: &'s mut Scratch) -> Result<&'s [i64]> {
        ensure!(
            scratch.staged_len() == bs * self.model.in_dim(),
            "staged input size {} != bs {} x in_dim {}",
            scratch.staged_len(),
            bs,
            self.model.in_dim()
        );
        Ok(self.plan.execute_staged(bs, scratch))
    }

    /// Full forward from uint8 inputs (compatibility wrapper: runs
    /// [`Engine::forward_into`] over the engine's lazily-owned scratch
    /// and copies the result out into an owned [`Forward`]).
    pub fn forward_from_q(&self, x_q: &[u8], bs: usize) -> Result<Forward> {
        let mut scratch = self.scratch.lock().unwrap();
        let t = self.forward_into(x_q, bs, &mut scratch)?;
        Ok(Forward { t: t.to_vec(), bs, out_dim: self.model.out_dim() })
    }

    /// Full forward from float (spline-domain) inputs (compatibility
    /// wrapper; quantizes into the scratch's staging buffer).
    pub fn forward(&self, x: &[f32], bs: usize) -> Result<Forward> {
        let mut scratch = self.scratch.lock().unwrap();
        quant::quantize_activations_into(x, scratch.stage_input(x.len()));
        let t = self.forward_staged(bs, &mut scratch)?;
        Ok(Forward { t: t.to_vec(), bs, out_dim: self.model.out_dim() })
    }

    /// Accuracy over a labelled set. One scratch serves every chunk, so
    /// the sweep allocates only during the first batch.
    pub fn accuracy(&self, x: &[f32], labels: &[i32], bs_chunk: usize) -> Result<f64> {
        let in_dim = self.model.in_dim();
        let out_dim = self.model.out_dim();
        let n = labels.len();
        ensure!(x.len() == n * in_dim);
        let mut scratch = self.scratch.lock().unwrap();
        let mut correct = 0usize;
        for start in (0..n).step_by(bs_chunk) {
            let bs = bs_chunk.min(n - start);
            let chunk = &x[start * in_dim..(start + bs) * in_dim];
            quant::quantize_activations_into(chunk, scratch.stage_input(chunk.len()));
            let t = self.forward_staged(bs, &mut scratch)?;
            for (row, &want) in t.chunks_exact(out_dim).zip(&labels[start..start + bs]) {
                if crate::util::argmax(row) as i32 == want {
                    correct += 1;
                }
            }
        }
        Ok(correct as f64 / n as f64)
    }

    /// The model's layers as simulator workloads (spline + base GEMMs),
    /// used to attach cycle/utilization estimates to served batches.
    pub fn workloads(&self, bs: usize) -> Vec<Workload> {
        let mut out = Vec::new();
        for (i, l) in self.model.layers.iter().enumerate() {
            out.push(Workload::kan(
                &format!("{}/l{i}", self.model.name),
                bs,
                l.in_dim,
                l.out_dim,
                l.grid,
                l.degree,
            ));
            out.push(Workload::dense(
                &format!("{}/l{i}/base", self.model.name),
                bs,
                l.in_dim,
                l.out_dim,
            ));
        }
        out
    }

    /// Simulated cost of one batch on a given accelerator config (must be
    /// compatible with every layer's N:M — use per-layer configs if G/P
    /// differ). Scalar configs always work.
    pub fn simulate_batch(&self, cfg: &ArrayConfig, bs: usize) -> SimStats {
        let mut total = SimStats::default();
        for wl in self.workloads(bs) {
            let c = if analytic::compatible(cfg, &wl) {
                *cfg
            } else {
                // instantiate the matching N:M at the same R x C (the mux
                // depth is a design-time parameter; see DESIGN.md)
                match wl.kind {
                    crate::sim::workload::GemmKind::KanSpline { g, p } => {
                        ArrayConfig::kan_sas(cfg.rows, cfg.cols, p + 1, g + p)
                    }
                    _ => *cfg,
                }
            };
            total += analytic::simulate(&c, &wl);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bspline::Lut;
    use crate::kan::LayerParams;
    use crate::tensor::Tensor;

    /// Hand-built single-layer model for closed-form checks.
    fn tiny_model() -> QuantizedModel {
        let (g, p, k, n) = (3usize, 3usize, 2usize, 2usize);
        let m = g + p;
        let lut = Lut::build(p);
        // coeff[feat, basis, out] = 1 everywhere: spline term becomes
        // sum of all basis values = 255-ish per feature (partition of unity)
        let coeff = Tensor::from_vec(vec![1i8; k * m * n], &[k, m, n]);
        let base = Tensor::from_vec(vec![0i8; k * n], &[k, n]);
        QuantizedModel {
            name: "tiny".into(),
            dims: vec![k, n],
            layers: vec![LayerParams {
                in_dim: k,
                out_dim: n,
                grid: g,
                degree: p,
                lut,
                coeff,
                base,
                m1: 1,
                m2: 1,
                s1: 1.0,
                s2: 1.0,
                precision: crate::kan::Precision::Int8,
            }],
        }
    }

    /// Bit-exact scalar reference: dense B-spline expansion + dense
    /// GEMMs + the same requant chain, written with none of the engine's
    /// layout/blocking tricks. The oracle for the plan refactor.
    fn oracle_forward(model: &QuantizedModel, x_q: &[u8], bs: usize) -> Vec<i64> {
        let mut cur = x_q.to_vec();
        let mut t = Vec::new();
        for (li, l) in model.layers.iter().enumerate() {
            let (k, n, m) = (l.in_dim, l.out_dim, l.num_bases());
            let unit = crate::bspline::BsplineUnit::new(l.lut.clone(), l.grid);
            t = vec![0i64; bs * n];
            for b in 0..bs {
                for out in 0..n {
                    let mut a1 = 0i32;
                    let mut a2 = 0i32;
                    for feat in 0..k {
                        let xq = cur[b * k + feat];
                        let dense = unit.eval_dense(xq);
                        for (basis, &v) in dense.iter().enumerate() {
                            a1 += v as i32
                                * l.coeff.data()[feat * m * n + basis * n + out] as i32;
                        }
                        a2 += quant::relu_q(xq) as i32 * l.base.data()[feat * n + out] as i32;
                    }
                    t[b * n + out] = a1 as i64 * l.m1 + a2 as i64 * l.m2;
                }
            }
            if li + 1 < model.layers.len() {
                cur = t.iter().map(|&v| quant::requantize(v)).collect();
            }
        }
        t
    }

    #[test]
    fn partition_of_unity_through_engine() {
        // with all-ones coefficients the spline accumulator per output is
        // sum over features of (sum of that feature's P+1 basis values),
        // which the LUT keeps within a few LSB of 255/lut-peak each
        let e = Engine::new(tiny_model());
        let fwd = e.forward_from_q(&[0, 128, 37, 255], 2).unwrap();
        let scale = e.model.layers[0].lut.scale;
        for &t in &fwd.t {
            let per_feat = t as f64 * scale / 2.0; // 2 features
            assert!((per_feat - 1.0).abs() < 0.03, "t={t} per_feat={per_feat}");
        }
    }

    #[test]
    fn predictions_argmax() {
        let f = Forward { t: vec![5, 9, 1, -3, -1, -2], bs: 2, out_dim: 3 };
        assert_eq!(f.predictions(), vec![1, 1]);
    }

    #[test]
    fn logits_f64_monotone_with_t() {
        let f = Forward { t: vec![-(1i64 << 31), 0, 1i64 << 31], bs: 1, out_dim: 3 };
        let l = f.logits_f64();
        assert_eq!(l.len(), 3);
        assert!(l[0] < l[1] && l[1] < l[2]);
        assert_eq!(l[1], 0.0);
    }

    #[test]
    fn engine_matches_naive_dense_expansion() {
        // spline GEMM via the sparse window == dense B @ flattened coeffs
        use crate::sim::synth;
        use crate::tensor::matmul_u8_i8;
        use crate::util::rng::{check, Rng};
        check(25, 61, |rng: &mut Rng| {
            let g = 1 + rng.below(8);
            let p = 1 + rng.below(3);
            let k = 1 + rng.below(5);
            let n = 1 + rng.below(4);
            let bs = 1 + rng.below(4);
            let m = g + p;
            let coeff = synth::coefficients(k, m, n, rng);
            let mut model = tiny_model();
            model.dims = vec![k, n];
            model.layers[0] = LayerParams {
                in_dim: k,
                out_dim: n,
                grid: g,
                degree: p,
                lut: Lut::build(p),
                coeff: coeff.clone(),
                base: Tensor::from_vec(vec![0i8; k * n], &[k, n]),
                m1: 1,
                m2: 0,
                s1: 1.0,
                s2: 1.0,
                precision: crate::kan::Precision::Int8,
            };
            let e = Engine::new(model);
            let x_q: Vec<u8> = (0..bs * k).map(|_| rng.below(256) as u8).collect();
            let fwd = e.forward_from_q(&x_q, bs).unwrap();

            // dense expansion through the same unit
            let unit = crate::bspline::BsplineUnit::new(Lut::build(p), g);
            let mut dense = Vec::with_capacity(bs * k * m);
            for &xq in &x_q {
                dense.extend_from_slice(&unit.eval_dense(xq));
            }
            let a = Tensor::from_vec(dense, &[bs, k * m]);
            let w = synth::flatten_coeff(&coeff);
            let want = matmul_u8_i8(&a, &w);
            let got: Vec<i32> = fwd.t.iter().map(|&v| v as i32).collect();
            assert_eq!(&got, want.data());
        });
    }

    #[test]
    fn forward_into_bit_exact_vs_oracle() {
        // property test over random (G, P, dims, bs): the planned
        // zero-allocation path must reproduce the scalar dense-expansion
        // oracle bit for bit, multi-layer models and base path included
        use crate::util::rng::{check, Rng};
        check(20, 77, |rng: &mut Rng| {
            let g = 1 + rng.below(8);
            let p = 1 + rng.below(3);
            let n_layers = 1 + rng.below(3);
            let dims: Vec<usize> = (0..=n_layers).map(|_| 1 + rng.below(6)).collect();
            let bs = 1 + rng.below(5);
            let model = QuantizedModel::synthetic("prop", &dims, g, p, rng.below(1 << 30) as u64);
            let x_q: Vec<u8> = (0..bs * dims[0]).map(|_| rng.below(256) as u8).collect();
            let want = oracle_forward(&model, &x_q, bs);
            let e = Engine::new(model);
            let mut scratch = Scratch::new();
            let got = e.forward_into(&x_q, bs, &mut scratch).unwrap();
            assert_eq!(got, &want[..], "g={g} p={p} dims={dims:?} bs={bs}");
            // and the allocating wrapper agrees with the planned path
            assert_eq!(e.forward_from_q(&x_q, bs).unwrap().t, want);
        });
    }

    #[test]
    fn packed_engine_matches_oracle() {
        // a mixed-precision model runs the packed int4 kernel path for
        // its first layer; the scalar dense-expansion oracle reads the
        // model's UNPACKED tensors, so agreement proves the packed
        // storage round-trips through the hot path bit for bit
        use crate::kan::Precision;
        let model = QuantizedModel::synthetic_mixed(
            "pk",
            &[5, 7, 4],
            5,
            3,
            33,
            &[Precision::Int4, Precision::Int8],
        );
        let x_q: Vec<u8> = (0..3 * 5).map(|i| (i * 67 % 256) as u8).collect();
        let want = oracle_forward(&model, &x_q, 3);
        let e = Engine::new(model);
        let mut s = Scratch::new();
        assert_eq!(e.forward_into(&x_q, 3, &mut s).unwrap(), &want[..]);
    }

    #[test]
    fn scratch_reuse_across_mismatched_batch_sizes() {
        // grow/shrink/grow through ONE scratch must equal fresh-scratch
        // runs byte for byte (stale arena contents must never leak in)
        use crate::util::rng::Rng;
        let model = QuantizedModel::synthetic("reuse", &[5, 7, 4], 5, 3, 23);
        let e = Engine::new(model);
        let mut rng = Rng::new(99);
        let mut shared = Scratch::new();
        for &bs in &[4usize, 1, 16, 3, 16, 2, 9] {
            let x_q: Vec<u8> = (0..bs * 5).map(|_| rng.below(256) as u8).collect();
            let got = e.forward_into(&x_q, bs, &mut shared).unwrap().to_vec();
            let mut fresh = Scratch::new();
            let want = e.forward_into(&x_q, bs, &mut fresh).unwrap();
            assert_eq!(got, want, "bs={bs} diverged between reused and fresh scratch");
        }
    }

    #[test]
    fn staged_path_matches_external_input() {
        let e = Engine::new(QuantizedModel::synthetic("staged", &[4, 6, 3], 5, 3, 8));
        let x_q = vec![3u8, 200, 90, 17, 0, 255, 128, 64];
        let mut s = Scratch::new();
        let want = e.forward_into(&x_q, 2, &mut s).unwrap().to_vec();
        s.stage_input(x_q.len()).extend_from_slice(&x_q);
        assert_eq!(e.forward_staged(2, &mut s).unwrap(), &want[..]);
        // staged length must match bs * in_dim
        s.stage_input(3).extend_from_slice(&[1, 2, 3]);
        assert!(e.forward_staged(2, &mut s).is_err());
    }

    #[test]
    fn rejects_bad_input_size() {
        let e = Engine::new(tiny_model());
        assert!(e.forward_from_q(&[0, 1, 2], 2).is_err());
        let mut s = Scratch::new();
        assert!(e.forward_into(&[0, 1, 2], 2, &mut s).is_err());
    }

    #[test]
    fn clones_alias_one_weight_allocation() {
        // pool replicas are engine clones: they must share (not copy) the
        // coefficient storage, so N replicas cost ~1x model memory
        let a = Engine::new(tiny_model());
        let b = a.clone();
        assert!(a.shares_weights_with(&b));
        assert_eq!(
            a.model.layers[0].coeff.data().as_ptr(),
            b.model.layers[0].coeff.data().as_ptr(),
            "coefficient tensors must alias one allocation"
        );
        assert_eq!(
            a.plan().layers[0].coeff16.as_ptr(),
            b.plan().layers[0].coeff16.as_ptr(),
            "compiled plans must alias one allocation"
        );
        assert_eq!(a.param_bytes(), b.param_bytes());
        assert!(a.param_bytes() > 0);
        // an independent engine over an equal model does NOT alias
        let c = Engine::new(tiny_model());
        assert!(!a.shares_weights_with(&c));
        // replicas stay bit-identical
        let x_q = vec![3u8, 200, 90, 17];
        assert_eq!(a.forward_from_q(&x_q, 2).unwrap().t, b.forward_from_q(&x_q, 2).unwrap().t);
    }

    #[test]
    fn pinned_kernel_engines_match_dispatch() {
        use crate::kan::kernel::Kernel;
        let model = QuantizedModel::synthetic("pin", &[5, 8, 3], 5, 3, 41);
        let x_q: Vec<u8> = (0..3 * 5).map(|i| (i * 67 % 256) as u8).collect();
        let scalar = Engine::with_kernel(model.clone(), Kernel::scalar());
        let want = scalar.forward_from_q(&x_q, 3).unwrap().t;
        let dispatched = Engine::new(model.clone());
        assert!(Kernel::available().contains(&dispatched.plan().kernel_kind()));
        assert_eq!(dispatched.forward_from_q(&x_q, 3).unwrap().t, want);
        for kind in Kernel::available() {
            let e = Engine::with_kernel(model.clone(), Kernel::forced(kind).unwrap());
            assert_eq!(e.plan().kernel_kind(), kind);
            assert_eq!(e.forward_from_q(&x_q, 3).unwrap().t, want, "kernel {kind}");
        }
    }

    #[test]
    fn workloads_cover_layers() {
        let e = Engine::new(tiny_model());
        let wls = e.workloads(16);
        assert_eq!(wls.len(), 2); // spline + base
        assert_eq!(wls[0].bs, 16);
    }
}
