//! Integer-only forward pass — the functional twin of the KAN-SAs
//! datapath, bit-exact against `python/compile/quantize.py`.
//!
//! Per layer (paper Eq. 1, quantized):
//!
//! 1. **B-spline unit** per input feature: `(vals[P+1], k)` from the LUT
//!    (Sec. III-B);
//! 2. **N:M spline GEMM**: `acc += vals[j] * coeff[feat, k-P+j, out]` —
//!    exactly what one column of vector PEs accumulates (Sec. IV-B);
//! 3. **base path**: integer ReLU then a dense i32 GEMM;
//! 4. **requantize**: `t = acc1*m1 + acc2*m2` (i64) -> next uint8
//!    activations, or raw `t` logits at the last layer.

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::quant;
use crate::sim::SimStats;
use crate::sim::analytic;
use crate::sim::workload::Workload;
use crate::arch::ArrayConfig;

use super::model::{LayerParams, QuantizedModel};

/// Inference engine over a loaded quantized model.
///
/// All parameter state is behind `Arc`: cloning an `Engine` produces a
/// replica that *aliases* the same model weights, LUT ROMs, and widened
/// MAC tables, so an N-replica serving pool (`coordinator::pool`) costs
/// ~1x model memory regardless of N. Verified by
/// [`Engine::shares_weights_with`] and the aliasing test below.
#[derive(Clone, Debug)]
pub struct Engine {
    pub model: Arc<QuantizedModel>,
    tables: Arc<EngineTables>,
}

/// Derived read-only per-layer state shared across replicas.
#[derive(Debug)]
struct EngineTables {
    /// One B-spline unit per layer, built once (perf: `layer_forward` is
    /// the serving hot path; constructing a unit clones the LUT).
    units: Vec<crate::bspline::BsplineUnit>,
    /// i16-widened copies of the int8 coefficient/base tensors. Values
    /// are identical (sign-extended); the widening lets LLVM vectorize
    /// the i16 -> i32 MAC loops ~1.7x better than i8 -> i32 (see
    /// EXPERIMENTS.md §Perf). Bit-exactness is untouched — golden tests
    /// still pass — it is purely a storage-width change.
    coeff16: Vec<Vec<i16>>,
    base16: Vec<Vec<i16>>,
}

/// Result of a batched forward pass.
#[derive(Clone, Debug)]
pub struct Forward {
    /// Final-layer i64 accumulators `(BS, out_dim)` (monotone in the
    /// float logits — argmax is classification).
    pub t: Vec<i64>,
    pub bs: usize,
    pub out_dim: usize,
}

impl Forward {
    pub fn logits_f64(&self, last: &LayerParams) -> Vec<f64> {
        // dequantize for reporting: t / (128 * 2^SHIFT) (see python)
        let denom = 128.0 * (1u64 << quant::SHIFT) as f64;
        let _ = last;
        self.t.iter().map(|&v| v as f64 / denom).collect()
    }

    pub fn predictions(&self) -> Vec<usize> {
        self.t.chunks_exact(self.out_dim).map(|row| crate::util::argmax(row)).collect()
    }
}

impl Engine {
    pub fn new(model: QuantizedModel) -> Self {
        Self::from_shared(Arc::new(model))
    }

    /// Build an engine over an already-shared model (additional replicas
    /// should just `clone()` an existing engine, which also shares the
    /// derived tables).
    pub fn from_shared(model: Arc<QuantizedModel>) -> Self {
        let units = model
            .layers
            .iter()
            .map(|l| crate::bspline::BsplineUnit::new(l.lut.clone(), l.grid))
            .collect();
        let coeff16 = model
            .layers
            .iter()
            .map(|l| l.coeff.data().iter().map(|&w| w as i16).collect())
            .collect();
        let base16 = model
            .layers
            .iter()
            .map(|l| l.base.data().iter().map(|&w| w as i16).collect())
            .collect();
        Self { model, tables: Arc::new(EngineTables { units, coeff16, base16 }) }
    }

    /// True when `self` and `other` alias the same parameter storage —
    /// i.e. they are replicas of one model, not independent copies.
    pub fn shares_weights_with(&self, other: &Engine) -> bool {
        Arc::ptr_eq(&self.model, &other.model) && Arc::ptr_eq(&self.tables, &other.tables)
    }

    /// Bytes of parameter + derived-table storage. Counted once per model:
    /// clones share the same allocations, so a pool's weight footprint is
    /// `param_bytes()` regardless of replica count.
    pub fn param_bytes(&self) -> usize {
        let model: usize = self
            .model
            .layers
            .iter()
            .map(|l| l.coeff.len() + l.base.len() + l.lut.raw().len())
            .sum();
        let widened: usize = self
            .tables
            .coeff16
            .iter()
            .chain(self.tables.base16.iter())
            .map(|v| v.len() * 2)
            .sum();
        model + widened
    }

    /// Forward one layer: uint8 activations `(BS, K)` -> i64 `t (BS, N)`.
    ///
    /// Hot-path layout (see EXPERIMENTS.md §Perf): *feature-major* — the
    /// outer loop walks input features so each feature's `M x N` int8
    /// coefficient block (832 B for MNIST-KAN layer 1) stays in L1 while
    /// every batch row consumes it, instead of streaming the full 650 KB
    /// coefficient tensor once per row. This mirrors the accelerator's
    /// weight-stationary reuse, which is why it wins.
    pub fn layer_forward(&self, layer: &LayerParams, x_q: &[u8], bs: usize) -> Vec<i64> {
        // resolve the prebuilt unit + widened weights for this layer (the
        // public signature takes &LayerParams for testability; fall back
        // to building on the fly if handed a foreign layer)
        let idx = self
            .model
            .layers
            .iter()
            .position(|l| std::ptr::eq(l.lut.raw(), layer.lut.raw()));
        let (unit, coeff, base);
        let (unit_owned, coeff_owned, base_owned);
        match idx {
            Some(i) => {
                unit = &self.tables.units[i];
                coeff = self.tables.coeff16[i].as_slice();
                base = self.tables.base16[i].as_slice();
            }
            None => {
                unit_owned = crate::bspline::BsplineUnit::new(layer.lut.clone(), layer.grid);
                coeff_owned = layer.coeff.data().iter().map(|&w| w as i16).collect::<Vec<_>>();
                base_owned = layer.base.data().iter().map(|&w| w as i16).collect::<Vec<_>>();
                unit = &unit_owned;
                coeff = coeff_owned.as_slice();
                base = base_owned.as_slice();
            }
        }
        let (kdim, n, p) = (layer.in_dim, layer.out_dim, layer.degree);
        debug_assert_eq!(x_q.len(), bs * kdim);
        let m = layer.num_bases();

        let mut acc = vec![0i32; bs * n];
        let mut acc_base = vec![0i32; bs * n];
        // batch blocking: keep the active accumulator slice L1-resident
        // while a feature's coefficient block streams through (measured
        // ~17% over unblocked feature-major; EXPERIMENTS.md §Perf)
        const BB: usize = 16;
        for b0 in (0..bs).step_by(BB) {
        let bl = BB.min(bs - b0);
        for feat in 0..kdim {
            let crow = &coeff[feat * m * n..(feat + 1) * m * n];
            let brow = &base[feat * n..(feat + 1) * n];
            for b in b0..b0 + bl {
                let xq = x_q[b * kdim + feat];
                // 1. B-spline unit (one LUT fetch for all P+1 non-zeros)
                let (vals, k) = unit.eval_into(xq);
                // 2. N:M spline MACs: window [k-P, k] of this feature's
                //    M coefficient rows
                let arow = &mut acc[b * n..(b + 1) * n];
                let wbase = (k - p) * n;
                if p == 3 {
                    // fused 4-row vector MAC (one accumulator pass instead
                    // of four): the software mirror of the 4-lane PE
                    let (v0, v1, v2, v3) =
                        (vals[0] as i32, vals[1] as i32, vals[2] as i32, vals[3] as i32);
                    let w = &crow[wbase..wbase + 4 * n];
                    let (w0, rest) = w.split_at(n);
                    let (w1, rest) = rest.split_at(n);
                    let (w2, w3) = rest.split_at(n);
                    for ((((a, &x0), &x1), &x2), &x3) in
                        arow.iter_mut().zip(w0).zip(w1).zip(w2).zip(w3)
                    {
                        *a += v0 * x0 as i32 + v1 * x1 as i32 + v2 * x2 as i32 + v3 * x3 as i32;
                    }
                } else {
                    for (j, &v) in vals.iter().enumerate() {
                        if v == 0 {
                            continue;
                        }
                        let v = v as i32;
                        let wrow = &crow[wbase + j * n..wbase + (j + 1) * n];
                        for (a, &w) in arow.iter_mut().zip(wrow) {
                            *a += v * w as i32;
                        }
                    }
                }
                // 3. base path (integer ReLU)
                let r = quant::relu_q(xq) as i32;
                if r != 0 {
                    let arow = &mut acc_base[b * n..(b + 1) * n];
                    for (a, &w) in arow.iter_mut().zip(brow) {
                        *a += r * w as i32;
                    }
                }
            }
        }
        }
        // 4. combine with the fixed-point multipliers
        let mut t = vec![0i64; bs * n];
        for ((tt, &a1), &a2) in t.iter_mut().zip(&acc).zip(&acc_base) {
            *tt = a1 as i64 * layer.m1 + a2 as i64 * layer.m2;
        }
        t
    }

    /// Full forward from uint8 inputs.
    pub fn forward_from_q(&self, x_q: &[u8], bs: usize) -> Result<Forward> {
        ensure!(
            x_q.len() == bs * self.model.in_dim(),
            "input size {} != bs {} x in_dim {}",
            x_q.len(),
            bs,
            self.model.in_dim()
        );
        let n_layers = self.model.layers.len();
        let mut cur = x_q.to_vec();
        let mut t = Vec::new();
        for (i, layer) in self.model.layers.iter().enumerate() {
            t = self.layer_forward(layer, &cur, bs);
            if i + 1 < n_layers {
                cur = t.iter().map(|&v| quant::requantize(v)).collect();
            }
        }
        Ok(Forward { t, bs, out_dim: self.model.out_dim() })
    }

    /// Full forward from float (spline-domain) inputs.
    pub fn forward(&self, x: &[f32], bs: usize) -> Result<Forward> {
        self.forward_from_q(&quant::quantize_activations(x), bs)
    }

    /// Accuracy over a labelled set.
    pub fn accuracy(&self, x: &[f32], labels: &[i32], bs_chunk: usize) -> Result<f64> {
        let in_dim = self.model.in_dim();
        let n = labels.len();
        ensure!(x.len() == n * in_dim);
        let mut correct = 0usize;
        for start in (0..n).step_by(bs_chunk) {
            let bs = bs_chunk.min(n - start);
            let fwd = self.forward(&x[start * in_dim..(start + bs) * in_dim], bs)?;
            for (pred, &want) in fwd.predictions().iter().zip(&labels[start..start + bs]) {
                if *pred as i32 == want {
                    correct += 1;
                }
            }
        }
        Ok(correct as f64 / n as f64)
    }

    /// The model's layers as simulator workloads (spline + base GEMMs),
    /// used to attach cycle/utilization estimates to served batches.
    pub fn workloads(&self, bs: usize) -> Vec<Workload> {
        let mut out = Vec::new();
        for (i, l) in self.model.layers.iter().enumerate() {
            out.push(Workload::kan(
                &format!("{}/l{i}", self.model.name),
                bs,
                l.in_dim,
                l.out_dim,
                l.grid,
                l.degree,
            ));
            out.push(Workload::dense(
                &format!("{}/l{i}/base", self.model.name),
                bs,
                l.in_dim,
                l.out_dim,
            ));
        }
        out
    }

    /// Simulated cost of one batch on a given accelerator config (must be
    /// compatible with every layer's N:M — use per-layer configs if G/P
    /// differ). Scalar configs always work.
    pub fn simulate_batch(&self, cfg: &ArrayConfig, bs: usize) -> SimStats {
        let mut total = SimStats::default();
        for wl in self.workloads(bs) {
            let c = if analytic::compatible(cfg, &wl) {
                *cfg
            } else {
                // instantiate the matching N:M at the same R x C (the mux
                // depth is a design-time parameter; see DESIGN.md)
                match wl.kind {
                    crate::sim::workload::GemmKind::KanSpline { g, p } => {
                        ArrayConfig::kan_sas(cfg.rows, cfg.cols, p + 1, g + p)
                    }
                    _ => *cfg,
                }
            };
            total += analytic::simulate(&c, &wl);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bspline::Lut;
    use crate::tensor::Tensor;

    /// Hand-built single-layer model for closed-form checks.
    fn tiny_model() -> QuantizedModel {
        let (g, p, k, n) = (3usize, 3usize, 2usize, 2usize);
        let m = g + p;
        let lut = Lut::build(p);
        // coeff[feat, basis, out] = 1 everywhere: spline term becomes
        // sum of all basis values = 255-ish per feature (partition of unity)
        let coeff = Tensor::from_vec(vec![1i8; k * m * n], &[k, m, n]);
        let base = Tensor::from_vec(vec![0i8; k * n], &[k, n]);
        QuantizedModel {
            name: "tiny".into(),
            dims: vec![k, n],
            layers: vec![LayerParams {
                in_dim: k,
                out_dim: n,
                grid: g,
                degree: p,
                lut,
                coeff,
                base,
                m1: 1,
                m2: 1,
                s1: 1.0,
                s2: 1.0,
            }],
        }
    }

    #[test]
    fn partition_of_unity_through_engine() {
        // with all-ones coefficients the spline accumulator per output is
        // sum over features of (sum of that feature's P+1 basis values),
        // which the LUT keeps within a few LSB of 255/lut-peak each
        let e = Engine::new(tiny_model());
        let fwd = e.forward_from_q(&[0, 128, 37, 255], 2).unwrap();
        let scale = e.model.layers[0].lut.scale;
        for &t in &fwd.t {
            let per_feat = t as f64 * scale / 2.0; // 2 features
            assert!((per_feat - 1.0).abs() < 0.03, "t={t} per_feat={per_feat}");
        }
    }

    #[test]
    fn predictions_argmax() {
        let f = Forward { t: vec![5, 9, 1, -3, -1, -2], bs: 2, out_dim: 3 };
        assert_eq!(f.predictions(), vec![1, 1]);
    }

    #[test]
    fn engine_matches_naive_dense_expansion() {
        // spline GEMM via the sparse window == dense B @ flattened coeffs
        use crate::sim::synth;
        use crate::tensor::matmul_u8_i8;
        use crate::util::rng::{check, Rng};
        check(25, 61, |rng: &mut Rng| {
            let g = 1 + rng.below(8);
            let p = 1 + rng.below(3);
            let k = 1 + rng.below(5);
            let n = 1 + rng.below(4);
            let bs = 1 + rng.below(4);
            let m = g + p;
            let coeff = synth::coefficients(k, m, n, rng);
            let mut model = tiny_model();
            model.dims = vec![k, n];
            model.layers[0] = LayerParams {
                in_dim: k,
                out_dim: n,
                grid: g,
                degree: p,
                lut: Lut::build(p),
                coeff: coeff.clone(),
                base: Tensor::from_vec(vec![0i8; k * n], &[k, n]),
                m1: 1,
                m2: 0,
                s1: 1.0,
                s2: 1.0,
            };
            let e = Engine::new(model);
            let x_q: Vec<u8> = (0..bs * k).map(|_| rng.below(256) as u8).collect();
            let fwd = e.forward_from_q(&x_q, bs).unwrap();

            // dense expansion through the same unit
            let unit = crate::bspline::BsplineUnit::new(Lut::build(p), g);
            let mut dense = Vec::with_capacity(bs * k * m);
            for &xq in &x_q {
                dense.extend_from_slice(&unit.eval_dense(xq));
            }
            let a = Tensor::from_vec(dense, &[bs, k * m]);
            let w = synth::flatten_coeff(&coeff);
            let want = matmul_u8_i8(&a, &w);
            let got: Vec<i32> = fwd.t.iter().map(|&v| v as i32).collect();
            assert_eq!(&got, want.data());
        });
    }

    #[test]
    fn rejects_bad_input_size() {
        let e = Engine::new(tiny_model());
        assert!(e.forward_from_q(&[0, 1, 2], 2).is_err());
    }

    #[test]
    fn clones_alias_one_weight_allocation() {
        // pool replicas are engine clones: they must share (not copy) the
        // coefficient storage, so N replicas cost ~1x model memory
        let a = Engine::new(tiny_model());
        let b = a.clone();
        assert!(a.shares_weights_with(&b));
        assert_eq!(
            a.model.layers[0].coeff.data().as_ptr(),
            b.model.layers[0].coeff.data().as_ptr(),
            "coefficient tensors must alias one allocation"
        );
        assert_eq!(
            a.tables.coeff16[0].as_ptr(),
            b.tables.coeff16[0].as_ptr(),
            "widened MAC tables must alias one allocation"
        );
        assert_eq!(a.param_bytes(), b.param_bytes());
        assert!(a.param_bytes() > 0);
        // an independent engine over an equal model does NOT alias
        let c = Engine::new(tiny_model());
        assert!(!a.shares_weights_with(&c));
        // replicas stay bit-identical
        let x_q = vec![3u8, 200, 90, 17];
        assert_eq!(a.forward_from_q(&x_q, 2).unwrap().t, b.forward_from_q(&x_q, 2).unwrap().t);
    }

    #[test]
    fn workloads_cover_layers() {
        let e = Engine::new(tiny_model());
        let wls = e.workloads(16);
        assert_eq!(wls.len(), 2); // spline + base
        assert_eq!(wls[0].bs, 16);
    }
}
