//! Explicit SIMD MAC kernels with runtime CPU-feature dispatch — the
//! software analogue of the paper's dense PE array (Sec. IV): where the
//! accelerator maps the non-recursive B-spline evaluation onto MAC lanes
//! wired at configuration time, we map the i16 -> i32 widening MAC inner
//! loops onto the host's vector lanes, resolved **once** at
//! [`ExecutionPlan`](super::plan::ExecutionPlan) compile into cached
//! function pointers.
//!
//! Two primitives cover every hot loop in `LayerPlan::forward_into`:
//!
//! * [`Kernel::mac4`] — the fused 4-row spline MAC for degree-3 windows
//!   (`acc[i] += v0*w0[i] + v1*w1[i] + v2*w2[i] + v3*w3[i]`), the
//!   dominant path for every P=3 model;
//! * [`Kernel::axpy`] — the single-row MAC (`acc[i] += v * w[i]`) used by
//!   generic-degree spline windows and the ReLU·weight base path.
//!
//! Each has a packed-int4 twin ([`Kernel::mac4_p4`] /
//! [`Kernel::axpy_p4`]) reading nibble-packed weight rows (two int4
//! values per byte, `quant::pack_i4` layout) and sign-extending
//! in-register — int4 layers stream half the weight bytes per MAC. The
//! plan picks dense or packed per layer at compile from its
//! `Precision`; both variants exist on every kernel kind (the scalar
//! reference included).
//!
//! Implementations:
//!
//! | kind     | gate                                   | vector body |
//! |----------|----------------------------------------|-------------|
//! | `scalar` | always compiled                        | LLVM autovectorized (the PR-6 baseline) |
//! | `avx2`   | `simd` feature + runtime `avx2`        | `_mm256_madd_epi16` pair-MACs (mac4), `_mm256_mullo_epi32` widening (axpy) |
//! | `avx512` | `avx512` feature + runtime `avx512f`   | 512-bit widening MACs (requires rustc >= 1.89 for stable AVX-512 intrinsics) |
//! | `neon`   | `simd` feature on aarch64              | `vmlal_s16` widening MACs |
//!
//! **Bit-exactness contract:** every kernel performs the identical i32
//! wrapping arithmetic as the scalar reference — products are exact
//! (|v| <= 255, |w| <= 127 after i8 -> i16 widening, so every partial
//! product fits in 24 bits) and i32 addition is associative under
//! wrapping, so lane order cannot change results. The golden replay
//! vectors are byte-identical on every dispatch path
//! (`tests/golden_replay.rs`), and `tests/kernels.rs` differentially
//! tests each compiled path against the scalar reference over random
//! shapes including remainder lanes.
//!
//! **Dispatch order:** `avx512` > `avx2` > `neon` > `scalar`, best
//! supported wins; the `KANSAS_FORCE_KERNEL` environment variable
//! (`scalar|avx2|avx512|neon`) pins a path for testing. Forcing an
//! unavailable path warns on stderr once and falls back to the best
//! available, so a forced run degrades rather than aborts.

use std::fmt;

/// Identifies one compiled kernel implementation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Portable reference path (always compiled; the dispatch fallback).
    Scalar,
    /// 256-bit AVX2 path (x86_64, `simd` feature, runtime-detected).
    Avx2,
    /// 512-bit AVX-512F path (x86_64, `avx512` feature, runtime-detected).
    Avx512,
    /// 128-bit NEON path (aarch64, `simd` feature; baseline on aarch64).
    Neon,
}

impl KernelKind {
    /// Stable lowercase name — the `KANSAS_FORCE_KERNEL` vocabulary and
    /// the string reported in `BENCH_engine.json` / `kansas serve`.
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Avx2 => "avx2",
            KernelKind::Avx512 => "avx512",
            KernelKind::Neon => "neon",
        }
    }

    /// Parse a `KANSAS_FORCE_KERNEL` value.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelKind::Scalar),
            "avx2" => Some(KernelKind::Avx2),
            "avx512" => Some(KernelKind::Avx512),
            "neon" => Some(KernelKind::Neon),
            _ => None,
        }
    }
}

impl fmt::Display for KernelKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Fused 4-row MAC: `acc[i] += v[0]*w[i] + v[1]*w[n+i] + v[2]*w[2n+i] +
/// v[3]*w[3n+i]` for `i in 0..n`, with `w.len() == 4 * n`.
type Mac4Fn = unsafe fn(acc: &mut [i32], w: &[i16], v: [i16; 4]);
/// Single-row MAC: `acc[i] += v * w[i]` with `w.len() == acc.len()`.
type AxpyFn = unsafe fn(acc: &mut [i32], w: &[i16], v: i16);
/// Packed-int4 fused 4-row MAC: as [`Mac4Fn`] but `w` holds four
/// consecutive nibble-packed rows of `rb = packed4_len(n)` bytes each
/// (`w.len() == 4 * rb`); weights are sign-extended in-register.
type Mac4PackedFn = unsafe fn(acc: &mut [i32], w: &[u8], v: [i16; 4]);
/// Packed-int4 single-row MAC: `w.len() == packed4_len(acc.len())`.
type AxpyPackedFn = unsafe fn(acc: &mut [i32], w: &[u8], v: i16);

/// A resolved kernel: the dispatch `kind` plus cached function pointers
/// for the MAC primitives (dense i16 and packed-int4 variants). `Copy`,
/// so every [`LayerPlan`] (`super::plan::LayerPlan`) embeds its own
/// resolved copy and the hot path never re-detects CPU features.
///
/// The only constructors are [`Kernel::dispatch`], [`Kernel::forced`],
/// and [`Kernel::scalar`]; all three guarantee the invariant that the
/// stored pointers target implementations the running CPU supports,
/// which is what makes the (module-private) unsafe calls sound.
#[derive(Clone, Copy)]
pub struct Kernel {
    kind: KernelKind,
    mac4: Mac4Fn,
    axpy: AxpyFn,
    mac4_p4: Mac4PackedFn,
    axpy_p4: AxpyPackedFn,
}

impl fmt::Debug for Kernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Kernel").field("kind", &self.kind).finish()
    }
}

impl Kernel {
    /// The portable reference kernel (always available).
    pub fn scalar() -> Self {
        Self {
            kind: KernelKind::Scalar,
            mac4: scalar::mac4,
            axpy: scalar::axpy,
            mac4_p4: scalar::mac4_p4,
            axpy_p4: scalar::axpy_p4,
        }
    }

    /// Every kernel kind compiled into this binary AND supported by the
    /// running CPU, in dispatch-preference order (best first, scalar
    /// last). Test suites iterate this to differentially exercise each
    /// path that can actually run here.
    pub fn available() -> Vec<KernelKind> {
        let mut kinds = Vec::new();
        #[cfg(all(feature = "avx512", target_arch = "x86_64"))]
        if std::arch::is_x86_feature_detected!("avx512f") {
            kinds.push(KernelKind::Avx512);
        }
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        if std::arch::is_x86_feature_detected!("avx2") {
            kinds.push(KernelKind::Avx2);
        }
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        kinds.push(KernelKind::Neon);
        kinds.push(KernelKind::Scalar);
        kinds
    }

    /// The kernel for `kind`, or `None` when that path is not compiled
    /// in (feature/arch gate) or the CPU lacks the features. This is the
    /// race-free way for tests to pin a path — no env mutation needed.
    pub fn forced(kind: KernelKind) -> Option<Self> {
        match kind {
            KernelKind::Scalar => Some(Self::scalar()),
            #[cfg(all(feature = "simd", target_arch = "x86_64"))]
            KernelKind::Avx2 => std::arch::is_x86_feature_detected!("avx2").then(|| Self {
                kind,
                mac4: x86::mac4_avx2,
                axpy: x86::axpy_avx2,
                mac4_p4: x86::mac4_p4_avx2,
                axpy_p4: x86::axpy_p4_avx2,
            }),
            // the packed nibble decode is 128/256-bit (no 512-bit madd
            // analogue pays off at these row widths), so the avx512 kind
            // carries the AVX2 packed variants — every avx512f CPU has
            // avx2, but the dispatch invariant is verified, not assumed
            #[cfg(all(feature = "avx512", target_arch = "x86_64"))]
            KernelKind::Avx512 => (std::arch::is_x86_feature_detected!("avx512f")
                && std::arch::is_x86_feature_detected!("avx2"))
            .then(|| Self {
                kind,
                mac4: x86::mac4_avx512,
                axpy: x86::axpy_avx512,
                mac4_p4: x86::mac4_p4_avx2,
                axpy_p4: x86::axpy_p4_avx2,
            }),
            #[cfg(all(feature = "simd", target_arch = "aarch64"))]
            KernelKind::Neon => Some(Self {
                kind,
                mac4: neon::mac4_neon,
                axpy: neon::axpy_neon,
                mac4_p4: neon::mac4_p4_neon,
                axpy_p4: neon::axpy_p4_neon,
            }),
            #[allow(unreachable_patterns)]
            _ => None,
        }
    }

    /// Resolve the kernel to execute with: the best compiled-and-
    /// supported path, unless `KANSAS_FORCE_KERNEL` pins one. Called
    /// once per `ExecutionPlan` compile; the result is cached in the
    /// plan's layers as plain function pointers.
    pub fn dispatch() -> Self {
        if let Ok(want) = std::env::var("KANSAS_FORCE_KERNEL") {
            match KernelKind::parse(&want) {
                Some(kind) => match Self::forced(kind) {
                    Some(k) => return k,
                    None => eprintln!(
                        "KANSAS_FORCE_KERNEL={want}: kernel not compiled in or unsupported \
                         on this CPU; falling back to best available"
                    ),
                },
                None => eprintln!(
                    "KANSAS_FORCE_KERNEL={want}: unknown kernel (want scalar|avx2|avx512|neon); \
                     falling back to best available"
                ),
            }
        }
        let best = *Self::available().first().expect("scalar kernel is always available");
        Self::forced(best).expect("available() kinds are constructible")
    }

    /// The dispatch path this kernel resolves to.
    pub fn kind(&self) -> KernelKind {
        self.kind
    }

    /// Fused 4-row widening MAC over one output row: for `i in 0..n`
    /// (`n = acc.len()`), `acc[i] += v[0]*w[i] + v[1]*w[n+i] +
    /// v[2]*w[2n+i] + v[3]*w[3n+i]`. `w` must hold exactly the four
    /// consecutive coefficient rows (`w.len() == 4 * acc.len()`).
    #[inline(always)]
    pub fn mac4(&self, acc: &mut [i32], w: &[i16], v: [i16; 4]) {
        debug_assert_eq!(w.len(), 4 * acc.len());
        // SAFETY: the constructors only hand out pointers to paths whose
        // CPU features were runtime-verified; slice lengths are checked
        // by the caller contract above.
        unsafe { (self.mac4)(acc, w, v) }
    }

    /// Single-row widening MAC: `acc[i] += v * w[i]`.
    #[inline(always)]
    pub fn axpy(&self, acc: &mut [i32], w: &[i16], v: i16) {
        debug_assert_eq!(w.len(), acc.len());
        // SAFETY: as in `mac4`.
        unsafe { (self.axpy)(acc, w, v) }
    }

    /// Packed-int4 fused 4-row MAC — the int4-layer twin of
    /// [`Kernel::mac4`]. `w` holds four consecutive nibble-packed
    /// coefficient rows, each `packed4_len(acc.len())` bytes (layout per
    /// `quant::pack_i4`: element `2i` low nibble, `2i+1` high nibble);
    /// weights are sign-extended and widened in-register.
    #[inline(always)]
    pub fn mac4_p4(&self, acc: &mut [i32], w: &[u8], v: [i16; 4]) {
        debug_assert_eq!(w.len(), 4 * crate::quant::packed4_len(acc.len()));
        // SAFETY: as in `mac4`.
        unsafe { (self.mac4_p4)(acc, w, v) }
    }

    /// Packed-int4 single-row MAC — the int4-layer twin of
    /// [`Kernel::axpy`], `w.len() == packed4_len(acc.len())`.
    #[inline(always)]
    pub fn axpy_p4(&self, acc: &mut [i32], w: &[u8], v: i16) {
        debug_assert_eq!(w.len(), crate::quant::packed4_len(acc.len()));
        // SAFETY: as in `mac4`.
        unsafe { (self.axpy_p4)(acc, w, v) }
    }
}

impl Default for Kernel {
    fn default() -> Self {
        Self::dispatch()
    }
}

/// Portable reference implementations — the bit-exactness oracle for
/// every vector path and the dispatch fallback. Written exactly like the
/// pre-kernel inner loops in `plan.rs` so LLVM's autovectorization keeps
/// the PR-6 baseline performance on machines with no compiled SIMD path.
mod scalar {
    /// See [`Kernel::mac4`](super::Kernel::mac4).
    pub(super) unsafe fn mac4(acc: &mut [i32], w: &[i16], v: [i16; 4]) {
        let n = acc.len();
        let (v0, v1, v2, v3) = (v[0] as i32, v[1] as i32, v[2] as i32, v[3] as i32);
        let (w0, rest) = w.split_at(n);
        let (w1, rest) = rest.split_at(n);
        let (w2, w3) = rest.split_at(n);
        for ((((a, &x0), &x1), &x2), &x3) in acc.iter_mut().zip(w0).zip(w1).zip(w2).zip(w3) {
            *a += v0 * x0 as i32 + v1 * x1 as i32 + v2 * x2 as i32 + v3 * x3 as i32;
        }
    }

    /// See [`Kernel::axpy`](super::Kernel::axpy).
    pub(super) unsafe fn axpy(acc: &mut [i32], w: &[i16], v: i16) {
        let v = v as i32;
        for (a, &x) in acc.iter_mut().zip(w) {
            *a += v * x as i32;
        }
    }

    use crate::quant::{packed4_len, sext4};

    /// See [`Kernel::mac4_p4`](super::Kernel::mac4_p4): four packed rows
    /// of `rb` bytes, nibbles decoded per element.
    pub(super) unsafe fn mac4_p4(acc: &mut [i32], w: &[u8], v: [i16; 4]) {
        let rb = packed4_len(acc.len());
        let (v0, v1, v2, v3) = (v[0] as i32, v[1] as i32, v[2] as i32, v[3] as i32);
        let (w0, rest) = w.split_at(rb);
        let (w1, rest) = rest.split_at(rb);
        let (w2, w3) = rest.split_at(rb);
        for (i, a) in acc.iter_mut().enumerate() {
            let (b, sh) = (i >> 1, (i & 1) * 4);
            *a += v0 * sext4(w0[b] >> sh) as i32
                + v1 * sext4(w1[b] >> sh) as i32
                + v2 * sext4(w2[b] >> sh) as i32
                + v3 * sext4(w3[b] >> sh) as i32;
        }
    }

    /// See [`Kernel::axpy_p4`](super::Kernel::axpy_p4).
    pub(super) unsafe fn axpy_p4(acc: &mut [i32], w: &[u8], v: i16) {
        let v = v as i32;
        for (i, a) in acc.iter_mut().enumerate() {
            *a += v * sext4(w[i >> 1] >> ((i & 1) * 4)) as i32;
        }
    }
}

/// x86_64 vector paths. AVX2 uses `_mm256_madd_epi16` pair-MACs for the
/// fused 4-row kernel (two coefficient rows interleave into one madd)
/// and `_mm256_cvtepi16_epi32` + `_mm256_mullo_epi32` widening for axpy;
/// AVX-512 (behind the `avx512` feature — stable intrinsics need
/// rustc >= 1.89) is the same widening scheme at 512-bit width.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    /// AVX2 fused 4-row MAC. Vector body covers 16 outputs per
    /// iteration; the tail falls back to the scalar reference (remainder
    /// lanes are covered by `tests/kernels.rs`).
    ///
    /// The madd trick: `unpacklo/hi_epi16(w0, w1)` interleaves two
    /// coefficient rows into `(w0[i], w1[i])` i16 pairs;
    /// `_mm256_madd_epi16` with the broadcast pair `(v0, v1)` then
    /// yields exact i32 `v0*w0[i] + v1*w1[i]` per lane (saturation is
    /// impossible: |v| <= 255, |w| <= 127). Unpack works per 128-bit
    /// lane, so the two madd results come out in lane-crossed order
    /// ([0-3 | 8-11] and [4-7 | 12-15]); `permute2x128` restores
    /// canonical order before accumulating.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn mac4_avx2(acc: &mut [i32], w: &[i16], v: [i16; 4]) {
        let n = acc.len();
        let vv01 = _mm256_set1_epi32(((v[1] as i32) << 16) | (v[0] as u16 as i32));
        let vv23 = _mm256_set1_epi32(((v[3] as i32) << 16) | (v[2] as u16 as i32));
        let wp = w.as_ptr();
        let ap = acc.as_mut_ptr();
        let mut i = 0usize;
        while i + 16 <= n {
            let w0 = _mm256_loadu_si256(wp.add(i) as *const __m256i);
            let w1 = _mm256_loadu_si256(wp.add(n + i) as *const __m256i);
            let w2 = _mm256_loadu_si256(wp.add(2 * n + i) as *const __m256i);
            let w3 = _mm256_loadu_si256(wp.add(3 * n + i) as *const __m256i);
            let s_lo = _mm256_madd_epi16(_mm256_unpacklo_epi16(w0, w1), vv01);
            let s_hi = _mm256_madd_epi16(_mm256_unpackhi_epi16(w0, w1), vv01);
            let t_lo = _mm256_madd_epi16(_mm256_unpacklo_epi16(w2, w3), vv23);
            let t_hi = _mm256_madd_epi16(_mm256_unpackhi_epi16(w2, w3), vv23);
            let sum_lo = _mm256_add_epi32(s_lo, t_lo); // [0-3 | 8-11]
            let sum_hi = _mm256_add_epi32(s_hi, t_hi); // [4-7 | 12-15]
            let first = _mm256_permute2x128_si256(sum_lo, sum_hi, 0x20); // [0-7]
            let second = _mm256_permute2x128_si256(sum_lo, sum_hi, 0x31); // [8-15]
            let a0 = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            let a1 = _mm256_loadu_si256(ap.add(i + 8) as *const __m256i);
            _mm256_storeu_si256(ap.add(i) as *mut __m256i, _mm256_add_epi32(a0, first));
            _mm256_storeu_si256(ap.add(i + 8) as *mut __m256i, _mm256_add_epi32(a1, second));
            i += 16;
        }
        if i < n {
            tail_mac4(&mut acc[i..], w, n, i, v);
        }
    }

    /// AVX2 single-row MAC: widen 8 i16 weights to i32
    /// (`cvtepi16_epi32`), multiply by the broadcast value
    /// (`mullo_epi32` — exact, products fit in 24 bits), accumulate.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_avx2(acc: &mut [i32], w: &[i16], v: i16) {
        let n = acc.len();
        let vv = _mm256_set1_epi32(v as i32);
        let wp = w.as_ptr();
        let ap = acc.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let w32 = _mm256_cvtepi16_epi32(_mm_loadu_si128(wp.add(i) as *const __m128i));
            let prod = _mm256_mullo_epi32(w32, vv);
            let a = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            _mm256_storeu_si256(ap.add(i) as *mut __m256i, _mm256_add_epi32(a, prod));
            i += 8;
        }
        while i < n {
            acc[i] += v as i32 * w[i] as i32;
            i += 1;
        }
    }

    /// Scalar tail for the fused 4-row kernels: finishes outputs
    /// `[done..n)` given the full 4-row `w` (row stride `n`).
    #[inline]
    fn tail_mac4(acc_tail: &mut [i32], w: &[i16], n: usize, done: usize, v: [i16; 4]) {
        let (v0, v1, v2, v3) = (v[0] as i32, v[1] as i32, v[2] as i32, v[3] as i32);
        for (off, a) in acc_tail.iter_mut().enumerate() {
            let i = done + off;
            *a += v0 * w[i] as i32
                + v1 * w[n + i] as i32
                + v2 * w[2 * n + i] as i32
                + v3 * w[3 * n + i] as i32;
        }
    }

    /// Decode 16 packed int4 weights (8 bytes at `p`) into a 256-bit
    /// vector of 16 sign-extended i16 lanes, preserving element order.
    ///
    /// Per-byte nibble split: `srli_epi16` shifts 16-bit lanes, so after
    /// the shift each byte's low nibble holds its own original high
    /// nibble plus 4 bits bled in from the neighbour — the `& 0x0F` mask
    /// kills the bleed. `unpacklo_epi8(lo, hi)` restores element order
    /// (elements 2i / 2i+1 from byte i); `(x ^ 8) - 8` sign-extends the
    /// 4-bit two's-complement values in 8-bit lanes.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn load_nib16(p: *const u8) -> __m256i {
        let raw = _mm_loadl_epi64(p as *const __m128i);
        let mask = _mm_set1_epi8(0x0F);
        let lo = _mm_and_si128(raw, mask);
        let hi = _mm_and_si128(_mm_srli_epi16::<4>(raw), mask);
        let inter = _mm_unpacklo_epi8(lo, hi);
        let k = _mm_set1_epi8(8);
        let signed = _mm_sub_epi8(_mm_xor_si128(inter, k), k);
        _mm256_cvtepi8_epi16(signed)
    }

    /// AVX2 packed-int4 fused 4-row MAC: nibble-decode each row with
    /// [`load_nib16`], then the identical madd pair-MAC body as
    /// [`mac4_avx2`] — 16 outputs per iteration from half the weight
    /// load bandwidth. Bit-exact: decoded weights are the same i16
    /// values the dense path widens from int8.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn mac4_p4_avx2(acc: &mut [i32], w: &[u8], v: [i16; 4]) {
        let n = acc.len();
        let rb = crate::quant::packed4_len(n);
        let vv01 = _mm256_set1_epi32(((v[1] as i32) << 16) | (v[0] as u16 as i32));
        let vv23 = _mm256_set1_epi32(((v[3] as i32) << 16) | (v[2] as u16 as i32));
        let wp = w.as_ptr();
        let ap = acc.as_mut_ptr();
        let mut i = 0usize;
        while i + 16 <= n {
            // i is a multiple of 16, so i/2 is byte-exact into each row
            let w0 = load_nib16(wp.add(i / 2));
            let w1 = load_nib16(wp.add(rb + i / 2));
            let w2 = load_nib16(wp.add(2 * rb + i / 2));
            let w3 = load_nib16(wp.add(3 * rb + i / 2));
            let s_lo = _mm256_madd_epi16(_mm256_unpacklo_epi16(w0, w1), vv01);
            let s_hi = _mm256_madd_epi16(_mm256_unpackhi_epi16(w0, w1), vv01);
            let t_lo = _mm256_madd_epi16(_mm256_unpacklo_epi16(w2, w3), vv23);
            let t_hi = _mm256_madd_epi16(_mm256_unpackhi_epi16(w2, w3), vv23);
            let sum_lo = _mm256_add_epi32(s_lo, t_lo); // [0-3 | 8-11]
            let sum_hi = _mm256_add_epi32(s_hi, t_hi); // [4-7 | 12-15]
            let first = _mm256_permute2x128_si256(sum_lo, sum_hi, 0x20); // [0-7]
            let second = _mm256_permute2x128_si256(sum_lo, sum_hi, 0x31); // [8-15]
            let a0 = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            let a1 = _mm256_loadu_si256(ap.add(i + 8) as *const __m256i);
            _mm256_storeu_si256(ap.add(i) as *mut __m256i, _mm256_add_epi32(a0, first));
            _mm256_storeu_si256(ap.add(i + 8) as *mut __m256i, _mm256_add_epi32(a1, second));
            i += 16;
        }
        if i < n {
            tail_mac4_p4(&mut acc[i..], w, rb, i, v);
        }
    }

    /// AVX2 packed-int4 single-row MAC: one [`load_nib16`] feeds two
    /// widened `mullo_epi32` accumulates (16 outputs per iteration).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn axpy_p4_avx2(acc: &mut [i32], w: &[u8], v: i16) {
        let n = acc.len();
        let vv = _mm256_set1_epi32(v as i32);
        let wp = w.as_ptr();
        let ap = acc.as_mut_ptr();
        let mut i = 0usize;
        while i + 16 <= n {
            let w16 = load_nib16(wp.add(i / 2));
            let lo32 = _mm256_cvtepi16_epi32(_mm256_castsi256_si128(w16));
            let hi32 = _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(w16));
            let a0 = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            let a1 = _mm256_loadu_si256(ap.add(i + 8) as *const __m256i);
            _mm256_storeu_si256(
                ap.add(i) as *mut __m256i,
                _mm256_add_epi32(a0, _mm256_mullo_epi32(lo32, vv)),
            );
            _mm256_storeu_si256(
                ap.add(i + 8) as *mut __m256i,
                _mm256_add_epi32(a1, _mm256_mullo_epi32(hi32, vv)),
            );
            i += 16;
        }
        while i < n {
            acc[i] += v as i32 * crate::quant::sext4(w[i >> 1] >> ((i & 1) * 4)) as i32;
            i += 1;
        }
    }

    /// Scalar tail for the packed fused 4-row kernels: finishes outputs
    /// `[done..n)` given the full 4-row packed `w` (row stride `rb`).
    #[inline]
    fn tail_mac4_p4(acc_tail: &mut [i32], w: &[u8], rb: usize, done: usize, v: [i16; 4]) {
        let (v0, v1, v2, v3) = (v[0] as i32, v[1] as i32, v[2] as i32, v[3] as i32);
        let nib =
            |row: usize, i: usize| crate::quant::sext4(w[row * rb + (i >> 1)] >> ((i & 1) * 4));
        for (off, a) in acc_tail.iter_mut().enumerate() {
            let i = done + off;
            *a += v0 * nib(0, i) as i32
                + v1 * nib(1, i) as i32
                + v2 * nib(2, i) as i32
                + v3 * nib(3, i) as i32;
        }
    }

    /// AVX-512F fused 4-row MAC: four widening multiply-accumulates over
    /// 16 i32 lanes per iteration (`cvtepi16_epi32` from 256-bit i16
    /// loads, `mullo_epi32` at 512-bit).
    #[cfg(feature = "avx512")]
    #[target_feature(enable = "avx512f")]
    pub(super) unsafe fn mac4_avx512(acc: &mut [i32], w: &[i16], v: [i16; 4]) {
        let n = acc.len();
        let vv: [__m512i; 4] = [
            _mm512_set1_epi32(v[0] as i32),
            _mm512_set1_epi32(v[1] as i32),
            _mm512_set1_epi32(v[2] as i32),
            _mm512_set1_epi32(v[3] as i32),
        ];
        let wp = w.as_ptr();
        let ap = acc.as_mut_ptr();
        let mut i = 0usize;
        while i + 16 <= n {
            let mut a = _mm512_loadu_si512(ap.add(i).cast());
            for (row, vr) in vv.iter().enumerate() {
                let w32 = _mm512_cvtepi16_epi32(_mm256_loadu_si256(
                    wp.add(row * n + i) as *const __m256i
                ));
                a = _mm512_add_epi32(a, _mm512_mullo_epi32(w32, *vr));
            }
            _mm512_storeu_si512(ap.add(i).cast(), a);
            i += 16;
        }
        if i < n {
            tail_mac4(&mut acc[i..], w, n, i, v);
        }
    }

    /// AVX-512F single-row MAC (512-bit version of [`axpy_avx2`]).
    #[cfg(feature = "avx512")]
    #[target_feature(enable = "avx512f")]
    pub(super) unsafe fn axpy_avx512(acc: &mut [i32], w: &[i16], v: i16) {
        let n = acc.len();
        let vv = _mm512_set1_epi32(v as i32);
        let wp = w.as_ptr();
        let ap = acc.as_mut_ptr();
        let mut i = 0usize;
        while i + 16 <= n {
            let w32 = _mm512_cvtepi16_epi32(_mm256_loadu_si256(wp.add(i) as *const __m256i));
            let a = _mm512_loadu_si512(ap.add(i).cast());
            _mm512_storeu_si512(ap.add(i).cast(), _mm512_add_epi32(a, _mm512_mullo_epi32(w32, vv)));
            i += 16;
        }
        while i < n {
            acc[i] += v as i32 * w[i] as i32;
            i += 1;
        }
    }
}

/// aarch64 NEON paths: `vmlal_s16` widening multiply-accumulate (the
/// literal hardware analogue of the paper's i16 MAC lanes), 8 outputs
/// per iteration across two 128-bit accumulator registers.
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod neon {
    use std::arch::aarch64::*;

    /// NEON fused 4-row MAC.
    pub(super) unsafe fn mac4_neon(acc: &mut [i32], w: &[i16], v: [i16; 4]) {
        let n = acc.len();
        let vd: [int16x4_t; 4] = [
            vdup_n_s16(v[0]),
            vdup_n_s16(v[1]),
            vdup_n_s16(v[2]),
            vdup_n_s16(v[3]),
        ];
        let wp = w.as_ptr();
        let ap = acc.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let mut lo = vld1q_s32(ap.add(i));
            let mut hi = vld1q_s32(ap.add(i + 4));
            for (row, vr) in vd.iter().enumerate() {
                let wr = vld1q_s16(wp.add(row * n + i));
                lo = vmlal_s16(lo, vget_low_s16(wr), *vr);
                hi = vmlal_s16(hi, vget_high_s16(wr), *vr);
            }
            vst1q_s32(ap.add(i), lo);
            vst1q_s32(ap.add(i + 4), hi);
            i += 8;
        }
        while i < n {
            acc[i] += v[0] as i32 * w[i] as i32
                + v[1] as i32 * w[n + i] as i32
                + v[2] as i32 * w[2 * n + i] as i32
                + v[3] as i32 * w[3 * n + i] as i32;
            i += 1;
        }
    }

    /// NEON single-row MAC.
    pub(super) unsafe fn axpy_neon(acc: &mut [i32], w: &[i16], v: i16) {
        let n = acc.len();
        let vd = vdup_n_s16(v);
        let wp = w.as_ptr();
        let ap = acc.as_mut_ptr();
        let mut i = 0usize;
        while i + 8 <= n {
            let wr = vld1q_s16(wp.add(i));
            let lo = vmlal_s16(vld1q_s32(ap.add(i)), vget_low_s16(wr), vd);
            let hi = vmlal_s16(vld1q_s32(ap.add(i + 4)), vget_high_s16(wr), vd);
            vst1q_s32(ap.add(i), lo);
            vst1q_s32(ap.add(i + 4), hi);
            i += 8;
        }
        while i < n {
            acc[i] += v as i32 * w[i] as i32;
            i += 1;
        }
    }

    /// Decode 16 packed int4 weights (8 bytes at `p`) into 16
    /// sign-extended i8 lanes in element order: per-byte nibble split
    /// (`vand`/`vshr_n_u8`), interleave (`vzip_u8`), then the
    /// `(x ^ 8) - 8` two's-complement sign extension.
    #[inline]
    unsafe fn nib16(p: *const u8) -> int8x16_t {
        let raw = vld1_u8(p);
        let lo = vand_u8(raw, vdup_n_u8(0x0F));
        let hi = vshr_n_u8::<4>(raw);
        let z = vzip_u8(lo, hi);
        let all = vreinterpretq_s8_u8(vcombine_u8(z.0, z.1));
        let k = vdupq_n_s8(8);
        vsubq_s8(veorq_s8(all, k), k)
    }

    /// NEON packed-int4 fused 4-row MAC: one [`nib16`] decode per row
    /// feeds widening `vmlal_s16` accumulates — 16 outputs per iteration
    /// across four accumulator registers.
    pub(super) unsafe fn mac4_p4_neon(acc: &mut [i32], w: &[u8], v: [i16; 4]) {
        let n = acc.len();
        let rb = crate::quant::packed4_len(n);
        let vd: [int16x4_t; 4] = [
            vdup_n_s16(v[0]),
            vdup_n_s16(v[1]),
            vdup_n_s16(v[2]),
            vdup_n_s16(v[3]),
        ];
        let wp = w.as_ptr();
        let ap = acc.as_mut_ptr();
        let mut i = 0usize;
        while i + 16 <= n {
            let mut a0 = vld1q_s32(ap.add(i));
            let mut a1 = vld1q_s32(ap.add(i + 4));
            let mut a2 = vld1q_s32(ap.add(i + 8));
            let mut a3 = vld1q_s32(ap.add(i + 12));
            for (row, vr) in vd.iter().enumerate() {
                let w8 = nib16(wp.add(row * rb + i / 2));
                let wlo = vmovl_s8(vget_low_s8(w8));
                let whi = vmovl_s8(vget_high_s8(w8));
                a0 = vmlal_s16(a0, vget_low_s16(wlo), *vr);
                a1 = vmlal_s16(a1, vget_high_s16(wlo), *vr);
                a2 = vmlal_s16(a2, vget_low_s16(whi), *vr);
                a3 = vmlal_s16(a3, vget_high_s16(whi), *vr);
            }
            vst1q_s32(ap.add(i), a0);
            vst1q_s32(ap.add(i + 4), a1);
            vst1q_s32(ap.add(i + 8), a2);
            vst1q_s32(ap.add(i + 12), a3);
            i += 16;
        }
        while i < n {
            let nib = |row: usize| {
                crate::quant::sext4(w[row * rb + (i >> 1)] >> ((i & 1) * 4)) as i32
            };
            acc[i] += v[0] as i32 * nib(0)
                + v[1] as i32 * nib(1)
                + v[2] as i32 * nib(2)
                + v[3] as i32 * nib(3);
            i += 1;
        }
    }

    /// NEON packed-int4 single-row MAC.
    pub(super) unsafe fn axpy_p4_neon(acc: &mut [i32], w: &[u8], v: i16) {
        let n = acc.len();
        let vd = vdup_n_s16(v);
        let wp = w.as_ptr();
        let ap = acc.as_mut_ptr();
        let mut i = 0usize;
        while i + 16 <= n {
            let w8 = nib16(wp.add(i / 2));
            let wlo = vmovl_s8(vget_low_s8(w8));
            let whi = vmovl_s8(vget_high_s8(w8));
            vst1q_s32(ap.add(i), vmlal_s16(vld1q_s32(ap.add(i)), vget_low_s16(wlo), vd));
            vst1q_s32(ap.add(i + 4), vmlal_s16(vld1q_s32(ap.add(i + 4)), vget_high_s16(wlo), vd));
            vst1q_s32(ap.add(i + 8), vmlal_s16(vld1q_s32(ap.add(i + 8)), vget_low_s16(whi), vd));
            vst1q_s32(
                ap.add(i + 12),
                vmlal_s16(vld1q_s32(ap.add(i + 12)), vget_high_s16(whi), vd),
            );
            i += 16;
        }
        while i < n {
            acc[i] += v as i32 * crate::quant::sext4(w[i >> 1] >> ((i & 1) * 4)) as i32;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{check, Rng};

    /// Scalar oracles computed independently of the kernel plumbing.
    fn want_mac4(acc: &[i32], w: &[i16], v: [i16; 4]) -> Vec<i32> {
        let n = acc.len();
        (0..n)
            .map(|i| {
                acc[i]
                    + v[0] as i32 * w[i] as i32
                    + v[1] as i32 * w[n + i] as i32
                    + v[2] as i32 * w[2 * n + i] as i32
                    + v[3] as i32 * w[3 * n + i] as i32
            })
            .collect()
    }

    fn want_axpy(acc: &[i32], w: &[i16], v: i16) -> Vec<i32> {
        acc.iter().zip(w).map(|(&a, &x)| a + v as i32 * x as i32).collect()
    }

    #[test]
    fn dispatch_always_resolves() {
        let k = Kernel::dispatch();
        assert!(Kernel::available().contains(&k.kind()));
        // scalar is always the last resort
        assert_eq!(*Kernel::available().last().unwrap(), KernelKind::Scalar);
    }

    #[test]
    fn kind_names_round_trip() {
        for kind in
            [KernelKind::Scalar, KernelKind::Avx2, KernelKind::Avx512, KernelKind::Neon]
        {
            assert_eq!(KernelKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(KernelKind::parse("AVX2"), Some(KernelKind::Avx2));
        assert_eq!(KernelKind::parse("sse9"), None);
    }

    #[test]
    fn forced_scalar_always_available() {
        assert_eq!(Kernel::forced(KernelKind::Scalar).unwrap().kind(), KernelKind::Scalar);
    }

    #[test]
    fn every_available_kernel_matches_oracle() {
        // random row widths crossing every vector width and remainder
        // (1..50 covers 8/16-lane bodies plus 1..15-lane tails)
        check(60, 4242, |rng: &mut Rng| {
            let n = 1 + rng.below(50);
            let acc0: Vec<i32> = (0..n).map(|_| rng.range_i64(-1 << 20, 1 << 20) as i32).collect();
            let w4: Vec<i16> = (0..4 * n).map(|_| rng.range_i64(-127, 128) as i16).collect();
            let v4 = [
                rng.below(256) as i16,
                rng.below(256) as i16,
                rng.below(256) as i16,
                rng.below(256) as i16,
            ];
            let v1 = rng.below(256) as i16;
            let m_want = want_mac4(&acc0, &w4, v4);
            let a_want = want_axpy(&acc0, &w4[..n], v1);
            for kind in Kernel::available() {
                let k = Kernel::forced(kind).unwrap();
                let mut acc = acc0.clone();
                k.mac4(&mut acc, &w4, v4);
                assert_eq!(acc, m_want, "mac4 {kind} n={n}");
                let mut acc = acc0.clone();
                k.axpy(&mut acc, &w4[..n], v1);
                assert_eq!(acc, a_want, "axpy {kind} n={n}");
            }
        });
    }

    /// Packed-path oracles: unpack the nibbles with the quant helpers
    /// and run the dense oracle math.
    fn want_mac4_p4(acc: &[i32], w: &[u8], v: [i16; 4]) -> Vec<i32> {
        let n = acc.len();
        let rb = crate::quant::packed4_len(n);
        let rows: Vec<Vec<i8>> =
            w.chunks_exact(rb).map(|r| crate::quant::unpack_i4(r, n)).collect();
        (0..n)
            .map(|i| {
                acc[i]
                    + v.iter()
                        .zip(&rows)
                        .map(|(&vr, row)| vr as i32 * row[i] as i32)
                        .sum::<i32>()
            })
            .collect()
    }

    #[test]
    fn every_available_kernel_matches_packed_oracle() {
        // widths crossing the 16-lane packed body plus odd tails (the
        // final high nibble of an odd row must never contribute)
        check(60, 7777, |rng: &mut Rng| {
            let n = 1 + rng.below(50);
            let rb = crate::quant::packed4_len(n);
            let acc0: Vec<i32> = (0..n).map(|_| rng.range_i64(-1 << 20, 1 << 20) as i32).collect();
            // pack per row so odd-width tails appear in every row
            let w4: Vec<u8> = (0..4)
                .flat_map(|_| {
                    let row: Vec<i8> = (0..n).map(|_| rng.range_i64(-8, 7) as i8).collect();
                    crate::quant::pack_i4(&row)
                })
                .collect();
            assert_eq!(w4.len(), 4 * rb);
            let v4 = [
                rng.below(256) as i16,
                rng.below(256) as i16,
                rng.below(256) as i16,
                rng.below(256) as i16,
            ];
            let v1 = rng.below(256) as i16;
            let m_want = want_mac4_p4(&acc0, &w4, v4);
            let a_want = want_mac4_p4(&acc0, &w4[..rb], [v1, 0, 0, 0]);
            for kind in Kernel::available() {
                let k = Kernel::forced(kind).unwrap();
                let mut acc = acc0.clone();
                k.mac4_p4(&mut acc, &w4, v4);
                assert_eq!(acc, m_want, "mac4_p4 {kind} n={n}");
                let mut acc = acc0.clone();
                k.axpy_p4(&mut acc, &w4[..rb], v1);
                assert_eq!(acc, a_want, "axpy_p4 {kind} n={n}");
            }
        });
    }

    #[test]
    fn packed_kernels_handle_sign_boundaries() {
        // every lane at the extremes -8/+7 through the vector body
        let n = 37usize; // 2 full 16-lane iterations + 5-lane tail, odd
        let row: Vec<i8> = (0..n).map(|i| if i % 2 == 0 { -8 } else { 7 }).collect();
        let packed = crate::quant::pack_i4(&row);
        let w4: Vec<u8> = (0..4).flat_map(|_| packed.clone()).collect();
        let want = want_mac4_p4(&vec![0; n], &w4, [255, 1, 128, 3]);
        for kind in Kernel::available() {
            let k = Kernel::forced(kind).unwrap();
            let mut acc = vec![0i32; n];
            k.mac4_p4(&mut acc, &w4, [255, 1, 128, 3]);
            assert_eq!(acc, want, "{kind}");
        }
    }

    #[test]
    fn accumulation_is_additive_across_calls() {
        // kernels accumulate (never overwrite): two calls == sum of both
        let n = 19usize;
        let w: Vec<i16> = (0..4 * n).map(|i| (i as i16 % 251) - 125).collect();
        for kind in Kernel::available() {
            let k = Kernel::forced(kind).unwrap();
            let mut acc = vec![0i32; n];
            k.mac4(&mut acc, &w, [1, 2, 3, 4]);
            k.axpy(&mut acc, &w[..n], 7);
            let mut want = want_mac4(&vec![0; n], &w, [1, 2, 3, 4]);
            want = want_axpy(&want, &w[..n], 7);
            assert_eq!(acc, want, "{kind}");
        }
    }
}
