//! PJRT runtime: load the AOT-lowered HLO text and execute the fp32 KAN
//! forward from rust — python never runs on this path.
//!
//! The interchange is **HLO text** (not serialized `HloModuleProto`):
//! jax >= 0.5 emits protos with 64-bit instruction ids which the image's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids. Weights
//! and per-layer B-spline LUTs are explicit leading parameters whose
//! order is recorded in the `.kwts` container — the runtime uploads them
//! once and reuses them for every batch.

pub mod engine;

pub use engine::{FloatEngine, ModelArtifacts};
