//! PJRT execution engine for the AOT fp32 forward pass.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::container::Container;
use crate::util::json::Value;

/// Locations of one model's AOT artifacts.
#[derive(Clone, Debug)]
pub struct ModelArtifacts {
    pub name: String,
    pub dir: PathBuf,
}

impl ModelArtifacts {
    pub fn new(dir: &Path, name: &str) -> Self {
        Self { name: name.to_string(), dir: dir.to_path_buf() }
    }

    pub fn hlo_path(&self, batch: usize) -> PathBuf {
        self.dir.join(format!("{}_b{}.hlo.txt", self.name, batch))
    }

    pub fn weights_path(&self) -> PathBuf {
        self.dir.join(format!("{}.kwts", self.name))
    }

    /// Batch sizes with an exported HLO module.
    pub fn available_batches(&self) -> Result<Vec<usize>> {
        let c = Container::open(&self.weights_path())?;
        c.expect_magic(b"KWTS0001")?;
        c.header
            .get("batch_sizes")
            .and_then(Value::as_arr)
            .context("batch_sizes")?
            .iter()
            .map(|v| v.as_usize().context("batch size"))
            .collect()
    }
}

/// A compiled fp32 forward for one static batch size, weights resident.
pub struct FloatEngine {
    exe: xla::PjRtLoadedExecutable,
    /// Weight literals in the recorded parameter order (input appended
    /// per call).
    weights: Vec<xla::Literal>,
    pub batch: usize,
    pub in_dim: usize,
    pub out_dim: usize,
    pub name: String,
}

impl FloatEngine {
    /// Compile `artifacts/<name>_b<batch>.hlo.txt` on the PJRT CPU client
    /// and upload the `.kwts` weights.
    pub fn load(client: &xla::PjRtClient, art: &ModelArtifacts, batch: usize) -> Result<Self> {
        let hlo = art.hlo_path(batch);
        if !hlo.exists() {
            bail!("missing {} (run `make artifacts`)", hlo.display());
        }
        let proto = xla::HloModuleProto::from_text_file(
            hlo.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;

        let wts = Container::open(&art.weights_path())?;
        wts.expect_magic(b"KWTS0001")?;
        let order: Vec<String> = wts
            .header
            .get("order")
            .and_then(Value::as_arr)
            .context("order")?
            .iter()
            .map(|v| Ok(v.as_str().context("order entry")?.to_string()))
            .collect::<Result<_>>()?;
        let mut weights = Vec::with_capacity(order.len());
        let mut in_dim = 0usize;
        let mut out_dim = 0usize;
        for name in &order {
            let (data, shape) = wts.f32(name)?;
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&data).reshape(&dims)?;
            // first layer coeff (K, M, N) fixes in_dim; last base (K, N)
            // fixes out_dim
            if name == "l0.coeff" {
                in_dim = shape[0];
            }
            if name.ends_with(".base") {
                out_dim = shape[1];
            }
            weights.push(lit);
        }
        Ok(Self { exe, weights, batch, in_dim, out_dim, name: art.name.clone() })
    }

    /// Execute one batch: `x` is `(batch, in_dim)` row-major fp32; returns
    /// `(batch, out_dim)` logits.
    pub fn execute(&self, x: &[f32]) -> Result<Vec<f32>> {
        if x.len() != self.batch * self.in_dim {
            bail!("input len {} != {}x{}", x.len(), self.batch, self.in_dim);
        }
        let xl = xla::Literal::vec1(x).reshape(&[self.batch as i64, self.in_dim as i64])?;
        let mut args: Vec<&xla::Literal> = self.weights.iter().collect();
        args.push(&xl);
        let result = self.exe.execute(&args)?[0][0].to_literal_sync()?;
        // the module was lowered with return_tuple=True
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    pub fn predictions(&self, logits: &[f32]) -> Vec<usize> {
        logits.chunks_exact(self.out_dim).map(|row| crate::util::argmax(row)).collect()
    }
}
