//! A counting global allocator for zero-allocation assertions.
//!
//! The planned execution core (`kan::plan`) promises steady-state
//! forwards with zero heap allocations; that promise is only worth
//! anything if it is *measured*. Binaries opt in by installing the
//! allocator:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: kan_sas::util::alloc_count::CountingAllocator =
//!     kan_sas::util::alloc_count::CountingAllocator;
//!
//! let before = alloc_count::allocations();
//! // ... hot path ...
//! assert_eq!(alloc_count::allocations() - before, 0);
//! ```
//!
//! Used by `tests/zero_alloc.rs` (hard assertion) and the
//! `e2e_inference` bench (reports allocs-per-forward in
//! `BENCH_engine.json`). Counts are process-wide, so measured sections
//! must not race other allocating threads.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);
static REALLOCATIONS: AtomicU64 = AtomicU64::new(0);

/// `System`, instrumented with allocation counters. Zero-cost when not
/// installed as the `#[global_allocator]`.
pub struct CountingAllocator;

// SAFETY: delegates every operation to `System` unchanged; the counters
// are side effects only.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        REALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

/// Fresh allocations (`alloc` + `alloc_zeroed`) since process start.
pub fn allocations() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// Reallocations (`Vec` growth etc.) since process start.
pub fn reallocations() -> u64 {
    REALLOCATIONS.load(Ordering::Relaxed)
}

/// Total allocator events (allocations + reallocations) — the number a
/// zero-allocation hot path must hold constant.
pub fn events() -> u64 {
    allocations() + reallocations()
}
