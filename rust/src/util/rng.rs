//! Seeded PRNG (SplitMix64) + a tiny property-testing harness.
//!
//! `proptest` is not available in the offline image; `check` below gives
//! the same workflow for the invariants this crate cares about: run a
//! property over many seeded random cases and report the failing seed so
//! a regression can be pinned.

/// SplitMix64: tiny, fast, well-distributed; perfectly adequate for test
/// case generation and synthetic workload sampling (not cryptographic).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform usize in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo);
        lo + (self.next_u64() % ((hi - lo) as u64 + 1)) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Run `prop` over `cases` seeded random cases; panic with the failing
/// seed on the first violation. Use inside `#[test]` functions:
///
/// ```
/// use kan_sas::util::rng::{check, Rng};
/// check(100, 42, |rng: &mut Rng| {
///     let x = rng.uniform(-1.0, 1.0);
///     assert!(x.abs() <= 1.0);
/// });
/// ```
pub fn check<F: FnMut(&mut Rng)>(cases: u64, base_seed: u64, mut prop: F) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x1000_0000_01B3)
            .wrapping_add(case);
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            eprintln!("property failed at case {case} (seed {seed})");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<u64> = (0..8).map({ let mut r = Rng::new(7); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = Rng::new(7); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let x = rng.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&x));
        }
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut rng = Rng::new(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[rng.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_has_reasonable_moments() {
        let mut rng = Rng::new(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(4);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0;
        check(25, 9, |_| count += 1);
        assert_eq!(count, 25);
    }
}
