//! Reader for the artifact tensor container written by `python/compile/aot.py`.
//!
//! Layout: 8-byte magic | u32 LE header length | UTF-8 JSON header | raw
//! little-endian tensor blobs. The header's `tensors` table maps names to
//! `{dtype, shape, offset, nbytes}` with offsets relative to the end of
//! the header. Three magics are in use: `KANQ0001` (quantized model),
//! `KGLD0001` (golden vectors), `KWTS0001` (fp32 weights for the PJRT
//! runtime).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::json::Value;

#[derive(Debug, Clone)]
pub struct TensorInfo {
    pub dtype: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

#[derive(Debug)]
pub struct Container {
    pub magic: [u8; 8],
    pub header: Value,
    tensors: BTreeMap<String, TensorInfo>,
    body: Vec<u8>,
}

impl Container {
    pub fn open(path: &Path) -> Result<Self> {
        let raw = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Self::from_bytes(raw).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn from_bytes(raw: Vec<u8>) -> Result<Self> {
        if raw.len() < 12 {
            bail!("container too short ({} bytes)", raw.len());
        }
        let mut magic = [0u8; 8];
        magic.copy_from_slice(&raw[..8]);
        let hlen = u32::from_le_bytes(raw[8..12].try_into().unwrap()) as usize;
        if raw.len() < 12 + hlen {
            bail!("truncated header (want {hlen} bytes)");
        }
        let header_text = std::str::from_utf8(&raw[12..12 + hlen]).context("header not utf-8")?;
        let header = Value::parse(header_text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut tensors = BTreeMap::new();
        let table = header
            .get("tensors")
            .and_then(Value::as_obj)
            .context("header missing tensors table")?;
        let body = raw[12 + hlen..].to_vec();
        for (name, t) in table {
            let info = TensorInfo {
                dtype: t.get("dtype").and_then(Value::as_str).context("dtype")?.to_string(),
                shape: t
                    .get("shape")
                    .and_then(Value::as_arr)
                    .context("shape")?
                    .iter()
                    .map(|v| v.as_usize().context("shape dim"))
                    .collect::<Result<_>>()?,
                offset: t.get("offset").and_then(Value::as_usize).context("offset")?,
                nbytes: t.get("nbytes").and_then(Value::as_usize).context("nbytes")?,
            };
            if info.offset + info.nbytes > body.len() {
                bail!("tensor {name} overruns body");
            }
            tensors.insert(name.clone(), info);
        }
        Ok(Self { magic, header, tensors, body })
    }

    pub fn expect_magic(&self, want: &[u8; 8]) -> Result<()> {
        if &self.magic != want {
            bail!(
                "bad magic {:?} (want {:?})",
                String::from_utf8_lossy(&self.magic),
                String::from_utf8_lossy(want)
            );
        }
        Ok(())
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.keys().map(|s| s.as_str())
    }

    pub fn info(&self, name: &str) -> Result<&TensorInfo> {
        self.tensors.get(name).with_context(|| format!("tensor '{name}' missing"))
    }

    fn bytes_of(&self, name: &str, dtype: &str, elem: usize) -> Result<(&[u8], &TensorInfo)> {
        let info = self.info(name)?;
        if info.dtype != dtype {
            bail!("tensor '{name}' has dtype {} (want {dtype})", info.dtype);
        }
        let n: usize = info.shape.iter().product();
        if n * elem != info.nbytes {
            bail!("tensor '{name}' size mismatch");
        }
        Ok((&self.body[info.offset..info.offset + info.nbytes], info))
    }

    pub fn u8(&self, name: &str) -> Result<(Vec<u8>, Vec<usize>)> {
        let (b, info) = self.bytes_of(name, "uint8", 1)?;
        Ok((b.to_vec(), info.shape.clone()))
    }

    pub fn i8(&self, name: &str) -> Result<(Vec<i8>, Vec<usize>)> {
        let (b, info) = self.bytes_of(name, "int8", 1)?;
        Ok((b.iter().map(|&x| x as i8).collect(), info.shape.clone()))
    }

    pub fn i32(&self, name: &str) -> Result<(Vec<i32>, Vec<usize>)> {
        let (b, info) = self.bytes_of(name, "int32", 4)?;
        Ok((
            b.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
            info.shape.clone(),
        ))
    }

    pub fn i64(&self, name: &str) -> Result<(Vec<i64>, Vec<usize>)> {
        let (b, info) = self.bytes_of(name, "int64", 8)?;
        Ok((
            b.chunks_exact(8).map(|c| i64::from_le_bytes(c.try_into().unwrap())).collect(),
            info.shape.clone(),
        ))
    }

    pub fn f32(&self, name: &str) -> Result<(Vec<f32>, Vec<usize>)> {
        let (b, info) = self.bytes_of(name, "float32", 4)?;
        Ok((
            b.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            info.shape.clone(),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a container in-memory exactly the way aot.write_container does.
    fn sample(magic: &[u8; 8]) -> Vec<u8> {
        let data: Vec<u8> = vec![1, 2, 3, 4, 5, 6];
        let header = format!(
            r#"{{"name": "t", "tensors": {{"x": {{"dtype": "uint8", "shape": [2, 3], "offset": 0, "nbytes": {}}}}}}}"#,
            data.len()
        );
        let mut raw = Vec::new();
        raw.extend_from_slice(magic);
        raw.extend_from_slice(&(header.len() as u32).to_le_bytes());
        raw.extend_from_slice(header.as_bytes());
        raw.extend_from_slice(&data);
        raw
    }

    #[test]
    fn roundtrip() {
        let c = Container::from_bytes(sample(b"KANQ0001")).unwrap();
        c.expect_magic(b"KANQ0001").unwrap();
        let (v, shape) = c.u8("x").unwrap();
        assert_eq!(v, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(shape, vec![2, 3]);
        assert_eq!(c.header.get("name").unwrap().as_str(), Some("t"));
    }

    #[test]
    fn wrong_magic_rejected() {
        let c = Container::from_bytes(sample(b"KANQ0001")).unwrap();
        assert!(c.expect_magic(b"KGLD0001").is_err());
    }

    #[test]
    fn wrong_dtype_rejected() {
        let c = Container::from_bytes(sample(b"KANQ0001")).unwrap();
        assert!(c.i8("x").is_err());
        assert!(c.f32("x").is_err());
    }

    #[test]
    fn missing_tensor_rejected() {
        let c = Container::from_bytes(sample(b"KANQ0001")).unwrap();
        assert!(c.u8("nope").is_err());
    }

    #[test]
    fn truncated_rejected() {
        let mut raw = sample(b"KANQ0001");
        raw.truncate(raw.len() - 3); // cut into the tensor body
        assert!(Container::from_bytes(raw).is_err());
        assert!(Container::from_bytes(vec![1, 2, 3]).is_err());
    }
}
