//! Minimal JSON reader (std-only; serde is unavailable offline).
//!
//! Supports the full JSON grammar the artifact headers and config files
//! use: objects, arrays, strings (with escapes), numbers, booleans, null.
//! Parsing is strict: trailing garbage and malformed documents are errors.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    pub fn parse(text: &str) -> Result<Value, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- construction helpers (for the machine-readable bench artifacts) --

    pub fn obj(entries: impl IntoIterator<Item = (&'static str, Value)>) -> Value {
        Value::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: impl IntoIterator<Item = Value>) -> Value {
        Value::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    /// Serialize back to JSON text. Round-trips through [`Value::parse`]
    /// (non-finite numbers, which JSON cannot express, degrade to
    /// `null`); integral numbers print without a fractional part so
    /// counters stay readable. Object keys are emitted in `BTreeMap`
    /// order, so output is deterministic.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_to(&mut out, 0);
        out
    }

    fn render_to(&self, out: &mut String, indent: usize) {
        use std::fmt::Write;
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/inf; null keeps the document parseable
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => render_str(s, out),
            Value::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                    v.render_to(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Value::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                    render_str(k, out);
                    out.push_str(": ");
                    v.render_to(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
        }
    }

    /// `obj["a"]["b"][2]`-style path lookup, `/`-separated.
    pub fn path(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for part in path.split('/') {
            cur = match cur {
                Value::Obj(m) => m.get(part)?,
                Value::Arr(v) => v.get(part.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }
}

/// Escape + quote one string (shared by string values and object keys —
/// keys need the same treatment or a quote in a key breaks the document).
fn render_str(s: &str, out: &mut String) {
    use std::fmt::Write;
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // re-sync to char boundary for multibyte UTF-8
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.bytes.len() && (self.bytes[end] & 0xC0) == 0x80 {
                        end += 1;
                    }
                    if end > self.pos {
                        out.push_str(std::str::from_utf8(&self.bytes[start..end]).map_err(|_| self.err("bad utf8"))?);
                        self.pos = end;
                    } else {
                        out.push(c as char);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(Value::parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("\"hi\\n\"").unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(v.path("a/2/b").unwrap().as_str(), Some("c"));
        assert_eq!(v.path("d/e").unwrap().as_bool(), Some(false));
        assert_eq!(v.path("a/0").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("{'a': 1}").is_err());
        assert!(Value::parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Value::parse("\"\\u0041\"").unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn multibyte_utf8_passthrough() {
        assert_eq!(Value::parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
    }

    #[test]
    fn render_round_trips() {
        let v = Value::obj([
            ("name", Value::str("bench \"x\"\n")),
            ("count", Value::num(42.0)),
            ("rate", Value::num(1.5)),
            ("ok", Value::Bool(true)),
            ("items", Value::arr([Value::num(1.0), Value::Null])),
            ("empty", Value::arr([])),
        ]);
        let text = v.render();
        assert_eq!(Value::parse(&text).unwrap(), v);
        assert!(text.contains("\"count\": 42"), "integral numbers render bare: {text}");
        assert!(text.contains("\"rate\": 1.5"));
    }

    #[test]
    fn render_escapes_object_keys() {
        let mut m = std::collections::BTreeMap::new();
        m.insert("he\"llo\\\n".to_string(), Value::Num(1.0));
        let v = Value::Obj(m);
        let text = v.render();
        assert_eq!(Value::parse(&text).unwrap(), v, "keys with quotes must round-trip: {text}");
    }

    #[test]
    fn render_degrades_non_finite_to_null() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let v = Value::obj([("x", Value::num(bad))]);
            let text = v.render();
            assert!(text.contains("\"x\": null"), "non-finite must render as null: {text}");
            assert!(Value::parse(&text).is_ok(), "rendered document must stay parseable");
        }
    }

    #[test]
    fn typed_accessors() {
        let v = Value::parse(r#"{"n": 3, "f": 3.5, "s": "x", "a": [1]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("f").unwrap().as_i64(), None); // non-integral
        assert_eq!(v.get("f").unwrap().as_f64(), Some(3.5));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert!(v.get("missing").is_none());
    }
}
