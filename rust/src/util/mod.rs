//! Shared low-level utilities: seeded PRNG + property-test harness, a
//! minimal JSON reader, and the binary tensor-container reader for the
//! artifacts produced by `python/compile/aot.py`.
//!
//! Everything here is std-only — the offline build image vendors only the
//! `xla` crate's dependency closure, so serde/proptest/criterion are
//! replaced by small in-tree equivalents.

pub mod container;
pub mod json;
pub mod rng;

/// numpy-compatible rounding: round half to even ("banker's rounding").
///
/// `python/compile/quantize.py` uses `np.round` / python `round`, both of
/// which round ties to even; `f64::round` rounds ties away from zero. The
/// integer pipeline must be bit-exact across the two languages, so every
/// float->int conversion on the artifact path goes through this.
pub fn round_half_even(x: f64) -> f64 {
    if (x - x.trunc()).abs() == 0.5 {
        // tie: pick the even neighbour
        let down = x.floor();
        let up = x.ceil();
        if (down as i64) % 2 == 0 {
            down
        } else {
            up
        }
    } else {
        x.round()
    }
}

/// Clamp to an inclusive integer range after banker's rounding.
pub fn round_clamp(x: f64, lo: i64, hi: i64) -> i64 {
    (round_half_even(x) as i64).clamp(lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_half_even_matches_numpy() {
        // (input, np.round(input))
        for (x, want) in [
            (0.5, 0.0),
            (1.5, 2.0),
            (2.5, 2.0),
            (3.5, 4.0),
            (-0.5, 0.0),
            (-1.5, -2.0),
            (-2.5, -2.0),
            (0.4999, 0.0),
            (0.5001, 1.0),
            (127.5, 128.0),
            (126.5, 126.0),
            (-127.5, -128.0),
        ] {
            assert_eq!(round_half_even(x), want, "x={x}");
        }
    }

    #[test]
    fn round_clamp_saturates() {
        assert_eq!(round_clamp(300.0, 0, 255), 255);
        assert_eq!(round_clamp(-1.2, 0, 255), 0);
        assert_eq!(round_clamp(12.3, 0, 255), 12);
    }
}
