//! Shared low-level utilities: seeded PRNG + property-test harness, a
//! minimal JSON reader/writer, the binary tensor-container reader for
//! the artifacts produced by `python/compile/aot.py`, and a counting
//! allocator backing the zero-allocation assertions.
//!
//! Everything here is std-only — the offline build image vendors only the
//! `xla` crate's dependency closure, so serde/proptest/criterion are
//! replaced by small in-tree equivalents.

pub mod alloc_count;
pub mod container;
pub mod json;
pub mod rng;

/// numpy-compatible rounding: round half to even ("banker's rounding").
///
/// `python/compile/quantize.py` uses `np.round` / python `round`, both of
/// which round ties to even; `f64::round` rounds ties away from zero. The
/// integer pipeline must be bit-exact across the two languages, so every
/// float->int conversion on the artifact path goes through this.
pub fn round_half_even(x: f64) -> f64 {
    if (x - x.trunc()).abs() == 0.5 {
        // tie: pick the even neighbour
        let down = x.floor();
        let up = x.ceil();
        if (down as i64) % 2 == 0 {
            down
        } else {
            up
        }
    } else {
        x.round()
    }
}

/// Clamp to an inclusive integer range after banker's rounding.
pub fn round_clamp(x: f64, lo: i64, hi: i64) -> i64 {
    (round_half_even(x) as i64).clamp(lo, hi)
}

/// Index of the largest element; on ties the *last* maximal index wins
/// (the `Iterator::max_by_key` convention every pre-dedup argmax here
/// used, so golden predictions are unchanged). Incomparable values (NaN
/// — detected as `x != x`) never become or displace the best: any
/// comparable element beats an incomparable one, even a leading NaN.
/// Panics on an empty slice.
pub fn argmax<T: PartialOrd>(xs: &[T]) -> usize {
    assert!(!xs.is_empty(), "argmax of empty slice");
    let incomparable = |x: &T| x != x;
    let mut best = 0;
    for i in 1..xs.len() {
        match xs[i].partial_cmp(&xs[best]) {
            Some(std::cmp::Ordering::Greater | std::cmp::Ordering::Equal) => best = i,
            Some(std::cmp::Ordering::Less) => {}
            // NaN on one side: a comparable candidate evicts a NaN best
            None => {
                if incomparable(&xs[best]) && !incomparable(&xs[i]) {
                    best = i;
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_half_even_matches_numpy() {
        // (input, np.round(input))
        for (x, want) in [
            (0.5, 0.0),
            (1.5, 2.0),
            (2.5, 2.0),
            (3.5, 4.0),
            (-0.5, 0.0),
            (-1.5, -2.0),
            (-2.5, -2.0),
            (0.4999, 0.0),
            (0.5001, 1.0),
            (127.5, 128.0),
            (126.5, 126.0),
            (-127.5, -128.0),
        ] {
            assert_eq!(round_half_even(x), want, "x={x}");
        }
    }

    #[test]
    fn round_clamp_saturates() {
        assert_eq!(round_clamp(300.0, 0, 255), 255);
        assert_eq!(round_clamp(-1.2, 0, 255), 0);
        assert_eq!(round_clamp(12.3, 0, 255), 12);
    }

    #[test]
    fn argmax_matches_max_by_key_convention() {
        assert_eq!(argmax(&[5i64, 9, 1]), 1);
        assert_eq!(argmax(&[-3i64, -1, -2]), 1);
        assert_eq!(argmax(&[7i64]), 0);
        // ties: last maximal index, like Iterator::max_by_key
        assert_eq!(argmax(&[2i64, 5, 5, 1]), 2);
        assert_eq!(
            argmax(&[3i64, 3, 3]),
            [3i64, 3, 3].iter().enumerate().max_by_key(|&(_, v)| *v).unwrap().0
        );
        // floats, NaN never wins — even in the leading (seed) position
        assert_eq!(argmax(&[0.5f32, f32::NAN, 2.0, 1.0]), 2);
        assert_eq!(argmax(&[f32::NAN, 0.5, 2.0, 1.0]), 2);
        assert_eq!(argmax(&[f32::NAN, -1.0]), 1);
        assert_eq!(argmax(&[f32::NAN]), 0);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn argmax_empty_panics() {
        argmax::<i64>(&[]);
    }
}
